# Convenience entry points. The rust build is hermetic; `artifacts` is
# only needed for the PJRT backend (requires jax).

.PHONY: build test verify static-gate race-gate lint bench-baseline stress cluster-stress warm-bench sim-serve cost-bench api-smoke tier-test tier-bench artifacts pytest probe

build:
	cargo build --release

test:
	cargo build --release && cargo test -q

# The full verification gate in one command — what CI runs, locally:
# static structural gate, concurrency/unsafe race gate, fmt, clippy
# -D warnings, tier-1 build+tests, doctests, the design-rule sweep,
# and the release stress/cluster suites.
verify: static-gate race-gate
	cargo fmt --check
	cargo clippy --all-targets -- -D warnings
	cargo build --release
	cargo test -q
	cargo test --doc
	cargo run --release -- lint --all
	cargo test --release --test stress_server --test cluster_server
	$(MAKE) tier-test

# Static design-rule checker (DRC) over every configs/*.json, the
# design catalogue, and the default serving shape. Exit 1 on any
# Error-severity finding; deterministic sorted output.
lint:
	cargo run --release -- lint --all

# Toolchain-free structural checks (runs anywhere python3 exists):
# balanced delimiters, mod-tree vs filesystem, Cargo target
# registration, crate-root import resolution, feature-gate names.
static-gate:
	python3 tools/verify.py

# Concurrency + unsafe-contract gate (toolchain-free, python3 only):
# inter-procedural lock-order graph (deadlock cycles, locks across
# Condvar waits and long/blocking calls), unsafe/SAFETY-comment and
# #[target_feature] guard audit, shared-state hygiene. Runs its own
# negative-fixture self-test first so the rules are proven live.
race-gate:
	python3 -m tools.analyze --self-test
	python3 -m tools.analyze

# Refresh the committed BENCH_*.json baselines (release mode only —
# a debug-mode file is marked "build_mode": "debug" and must not be
# committed as a baseline).
bench-baseline:
	cargo bench --bench serve_throughput
	cargo bench --bench prepared_cache
	cargo bench --bench cost_model
	cargo bench --bench kernel_tiers

# full serving stress suite (500-job mixed streams, seeds 1-5)
stress:
	cargo test --release --test stress_server

# shard/router cluster suite (router smoke across shard counts,
# cross-shard conservation, drain-under-load, placement rejection,
# N=1 parity) plus a 2-shard CLI smoke
cluster-stress:
	cargo test --release --test cluster_server
	cargo run --release -- serve --shards 2 --workers 2 --jobs 96 --mix mm-heavy

# prepared-artifact cache: warm-vs-cold per-job cost + build-once check
warm-bench:
	cargo bench --bench prepared_cache

# end-to-end smoke of the unified pipeline: serve a mixed stream on the
# sim backend (predicted latency/energy on every result, cost-aware
# placement, predicted-vs-measured report)
sim-serve:
	cargo run --release -- serve --backend sim --workers 2 --jobs 96 --mix mm-heavy

# survey the AIE cost model's predictions (and check determinism)
cost-bench:
	cargo bench --bench cost_model

# kernel-tier parity suite, twice: once under the environment's tier
# (simd where the CPU has AVX2+FMA) and once with the scalar tier
# forced — the runtime-fallback drill every SIMD change must survive
tier-test:
	cargo test --release --test kernel_tiers
	EA4RCA_KERNEL_TIER=scalar cargo test --release --test kernel_tiers

# scalar vs simd vs simd+pool micro-batch throughput per hot kernel,
# plus the >=4x batched-f32-matmul acceptance line (BENCH_kernel_tiers)
tier-bench:
	cargo bench --bench kernel_tiers

# the design-entry facade end to end: config round-trips, builder/JSON/
# apps parity, predict-without-a-runtime, and Design::deploy smoke on
# the interp + sim backends
api-smoke:
	cargo test --release --test api_facade

# AOT-lower the Layer-1/2 graphs to artifacts/*.hlo.txt + manifest.json
artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

pytest:
	cd python && pytest -q

probe:
	cargo run --release --example runtime_probe
