"""AOT compiler: lower every PU model to HLO text + a manifest.

This is the only place Python touches the build. ``make artifacts`` runs
it once; afterwards the rust binary is self-contained.

Interchange format is HLO **text**, not ``.serialize()`` — the image's
xla_extension 0.5.1 rejects jax>=0.5's 64-bit-instruction-id protos, while
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Every artifact gets an entry in ``artifacts/manifest.json`` that the rust
runtime parses (with its own hand-rolled JSON reader):

    {"artifacts": [{"name": ..., "file": ..., "inputs": [{"shape": [...],
      "dtype": "f32"}, ...], "outputs": [...]}, ...]}
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import mm_lowbit

_DTYPE_TAG = {"float32": "f32", "int32": "i32"}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def artifact_catalogue():
    """(name, fn, example_arg_specs) for every artifact we ship.

    One HLO module per PU variant — HLO is shape-static, so each FFT size
    is its own artifact (the rust runtime picks by name).
    """
    f32, i32 = jnp.float32, jnp.int32
    cat = [
        # single-core kernels (used by MM-T probe and runtime smoke tests)
        ("mm32", lambda a, b: (model.kmm.mm32(a, b),),
         [_spec((32, 32), f32), _spec((32, 32), f32)]),
        ("mm32_acc", lambda a, b, c: (model.kmm.mm32_acc(a, b, c),),
         [_spec((32, 32), f32)] * 3),
        # low-bit variants (paper §4.3's energy-efficiency claim)
        ("mm32_i8", lambda a, b: (mm_lowbit.mm32_i8(a, b),),
         [_spec((32, 32), i32)] * 2),
        ("mm32_i16", lambda a, b: (mm_lowbit.mm32_i16(a, b),),
         [_spec((32, 32), i32)] * 2),
        ("mmt_cascade8", lambda a, b: (model.mmt_cascade8(a, b),),
         [_spec((32, 256), f32), _spec((256, 32), f32)]),
        # PU-level graphs
        # the explicit Parallel<16>*Cascade<4> graph, NOT the fused-grid
        # pallas form: on the CPU PJRT backend the explicit 64-dot graph
        # executes 1.7x faster (278 us vs 470 us; 0.77x of the pure-dot
        # roofline) — EXPERIMENTS.md §Perf L2.
        ("mm_pu128", lambda a, b: (model.mm_pu128(a, b),),
         [_spec((128, 128), f32), _spec((128, 128), f32)]),
        ("filter2d_pu8", lambda t, k: (model.filter2d_pu8(t, k),),
         [_spec((8, 36, 36), i32), _spec((5, 5), i32)]),
    ]
    for n in (1024, 2048, 4096, 8192):
        cat.append(
            (f"fft{n}", lambda re, im: tuple(model.fft_pu(re, im)),
             [_spec((n,), f32), _spec((n,), f32)])
        )
    return cat


def lower_entry(name, fn, specs):
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    # The HLO text printer elides large literals ("...") and the
    # downstream 0.5.1 parser fills garbage — a silent-corruption trap.
    # Large constants must be expressed as traced ops instead (see
    # kernels/fft.py stage_twiddles_traced).
    if "..." in text:
        raise ValueError(
            f"artifact {name!r} contains elided constants — move large "
            "literals into traced ops (iota/cos/...) before lowering"
        )
    out_info = jax.eval_shape(fn, *specs)
    inputs = [
        {"shape": list(s.shape), "dtype": _DTYPE_TAG[str(s.dtype)]}
        for s in specs
    ]
    outputs = [
        {"shape": list(o.shape), "dtype": _DTYPE_TAG[str(o.dtype)]}
        for o in out_info
    ]
    return text, inputs, outputs


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts",
                    help="artifact output directory")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of artifact names")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None
    manifest = {"artifacts": []}
    for name, fn, specs in artifact_catalogue():
        if only is not None and name not in only:
            continue
        text, inputs, outputs = lower_entry(name, fn, specs)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest["artifacts"].append(
            {
                "name": name,
                "file": fname,
                "inputs": inputs,
                "outputs": outputs,
                "sha256_16": digest,
            }
        )
        print(f"  wrote {path} ({len(text)} chars, sha {digest})")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  wrote {os.path.join(args.out_dir, 'manifest.json')} "
          f"({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
