"""Layer-2 JAX models — one function per PU variant of the paper's Table 4.

Each function is the *compute graph of one PU iteration* and is what gets
AOT-lowered to an HLO artifact the rust coordinator executes via PJRT.
The PU-internal structure (Parallel / Cascade organisation, DAC fan-out)
is expressed in the graph shape so the lowered HLO mirrors the paper's
Figure 7 dataflow; the *timing* of that dataflow is the rust simulator's
job.

PU catalogue (paper Table 4):

* MM       — CC = Parallel<16> * Cascade<4>: 64 cores computing a
             128x128x128 MM per iteration. :func:`mm_pu128`.
* Filter2D — CC = Parallel<8>: 8 cores, one 32x32 output tile each.
             :func:`filter2d_pu8`.
* FFT      — PST#1 Butterfly + PST#2 Parallel<2>*Cascade<3>:
             an N-point radix-2 FFT. :func:`fft_pu`.
* MM-T     — CC = Cascade<8>: a pure-compute 8-stage cascade of 32x32x32
             MMs (the AIE throughput probe). :func:`mmt_cascade8`.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import fft as kfft
from .kernels import filter2d as kfilter
from .kernels import mm32 as kmm

BLOCK = kmm.BLOCK  # 32


# ---------------------------------------------------------------------------
# MM PU: Parallel<16> * Cascade<4>  ->  128 x 128 x 128 per iteration
# ---------------------------------------------------------------------------

def mm_pu128(a, b):
    """One MM-PU iteration: C(128x128) = A(128x128) @ B(128x128).

    Structure mirrors Figure 7(a): 16 parallel groups each own one of the
    4x4 output blocks; inside a group, a Cascade<4> chain accumulates the
    four K-slabs through :func:`kernels.mm32.mm32_acc` — the accumulator
    passed between stages is what the AIE cascade wires carry.
    """
    n_blk = a.shape[0] // BLOCK  # 4
    rows = []
    for i in range(n_blk):
        row = []
        for j in range(n_blk):
            a_blk = a[i * BLOCK : (i + 1) * BLOCK, 0:BLOCK]
            b_blk = b[0:BLOCK, j * BLOCK : (j + 1) * BLOCK]
            acc = kmm.mm32(a_blk, b_blk)  # cascade head (core 0)
            for k in range(1, n_blk):  # cascade stages 1..3
                a_blk = a[i * BLOCK : (i + 1) * BLOCK, k * BLOCK : (k + 1) * BLOCK]
                b_blk = b[k * BLOCK : (k + 1) * BLOCK, j * BLOCK : (j + 1) * BLOCK]
                acc = kmm.mm32_acc(a_blk, b_blk, acc)
            row.append(acc)
        rows.append(jnp.concatenate(row, axis=1))
    return jnp.concatenate(rows, axis=0)


def mm_pu128_grid(a, b):
    """Same PU computation as :func:`mm_pu128` but as a single grid-tiled
    pallas_call (:func:`kernels.mm32.mm_tiled`). Lowers to 2.8x smaller
    HLO than the explicit graph but executes 1.7x *slower* on the CPU
    PJRT backend (the interpret-lowered grid becomes a while-loop XLA
    cannot fuse as well as 64 explicit dots) — so the AOT path ships the
    explicit form; see EXPERIMENTS.md §Perf L2."""
    return kmm.mm_tiled(a, b)


# ---------------------------------------------------------------------------
# Filter2D PU: Parallel<8>  ->  eight 32x32 tiles per iteration
# ---------------------------------------------------------------------------

def filter2d_pu8(tiles, kern):
    """One Filter2D-PU iteration: 8 halo tiles in, 8 filtered tiles out.

    tiles: (8, 36, 36) int32, kern: (5, 5) int32 -> (8, 32, 32) int32.
    The batch dimension is the Parallel<8> core index.
    """
    return kfilter.filter2d_batch(tiles, kern)


# ---------------------------------------------------------------------------
# FFT PU: Butterfly PST chained log2(N) times
# ---------------------------------------------------------------------------

def _bit_reverse_permute(x):
    """Bit-reversal as reshape -> axis-reversal -> reshape.

    Equivalent to ``x[bit_reverse_indices(n)]`` but expressed as a dense
    transpose: the downstream xla_extension 0.5.1 compiler MIScompiles a
    fancy-index gather feeding >= 3 chained (interpret-lowered) Pallas
    stages — all-zero outputs — while the transpose form round-trips
    correctly at every size (see EXPERIMENTS.md, 'HLO round-trip
    gotchas').
    """
    n = x.shape[0]
    bits = n.bit_length() - 1
    return x.reshape((2,) * bits).transpose(tuple(reversed(range(bits)))).reshape(n)


def fft_pu(re, im):
    """One FFT-PU iteration: an N-point radix-2 DIT FFT.

    Bit-reversal permutation (the DAC's data organisation duty, DCA mode)
    followed by log2(N) butterfly stages (PST#1's Butterfly component;
    the final three stages correspond to PST#2's Parallel<2>*Cascade<3>
    group in the paper's placement — same arithmetic, different cores).
    """
    n = re.shape[0]
    re = _bit_reverse_permute(re)
    im = _bit_reverse_permute(im)
    h = 1
    while h < n:
        # traced twiddles: baked constants this large would be elided by
        # the HLO-text interchange (see kernels/fft.py)
        wre, wim = kfft.stage_twiddles_traced(h)
        g = n // (2 * h)
        sre, sim = kfft.butterfly_stage(
            re.reshape(g, 2, h),
            im.reshape(g, 2, h),
            wre,
            wim,
        )
        re = sre.reshape(n)
        im = sim.reshape(n)
        h *= 2
    return re, im


# ---------------------------------------------------------------------------
# MM-T: Cascade<8> pure-compute probe
# ---------------------------------------------------------------------------

def mmt_cascade8(a, b):
    """One MM-T chain: C(32x32) = sum_{k<8} A_k @ B_k over a Cascade<8>.

    a: (32, 256) float32 (8 K-slabs), b: (256, 32) float32.
    CHL/THR data engine: operands stay resident, the chain just re-runs —
    this is the paper's AIE-only throughput measurement (Table 9).
    """
    acc = kmm.mm32(a[:, 0:BLOCK], b[0:BLOCK, :])
    for k in range(1, 8):
        acc = kmm.mm32_acc(
            a[:, k * BLOCK : (k + 1) * BLOCK],
            b[k * BLOCK : (k + 1) * BLOCK, :],
            acc,
        )
    return acc


# ---------------------------------------------------------------------------
# Whole-image Filter2D helper (oracle-side tiling used by tests)
# ---------------------------------------------------------------------------

def filter2d_tiles_from_image(img, tile=kfilter.TILE, halo=kfilter.HALO):
    """Split a (H+4, W+4) padded image into (n_tiles, 36, 36) halo tiles.

    This is the TPC's task-decomposition logic, written in numpy so tests
    can check the rust TPC against it.
    """
    img = np.asarray(img)
    h_out = img.shape[0] - halo
    w_out = img.shape[1] - halo
    assert h_out % tile == 0 and w_out % tile == 0
    tiles = []
    for ti in range(h_out // tile):
        for tj in range(w_out // tile):
            tiles.append(
                img[
                    ti * tile : ti * tile + tile + halo,
                    tj * tile : tj * tile + tile + halo,
                ]
            )
    return np.stack(tiles)


def filter2d_image_from_tiles(tiles, h_out, w_out, tile=kfilter.TILE):
    """Inverse of :func:`filter2d_tiles_from_image` for output tiles."""
    tiles = np.asarray(tiles)
    out = np.zeros((h_out, w_out), dtype=tiles.dtype)
    n_w = w_out // tile
    for n, t in enumerate(tiles):
        ti, tj = divmod(n, n_w)
        out[ti * tile : (ti + 1) * tile, tj * tile : (tj + 1) * tile] = t
    return out
