"""Low-bit 32x32x32 MM Pallas kernels (int8 / int16 operands, int32
accumulate).

The paper (§4.3): "If the low bit types such as Int8 or Int16 are used,
higher energy efficiency will be obtained, which has huge advantages
over the GPU." These kernels back that claim's reproduction
(`benches/ablate_dtype.rs`): same 32^3 subtask, narrower operands — the
AIE datapath packs 4x/2x more MACs per cycle and the wires carry 4x/2x
fewer bytes.

Operands arrive as int32 tensors holding int8/int16 values (PJRT's CPU
literal path in the xla 0.1.6 crate marshals i32 cleanly; the dtype
narrowing is asserted in the kernel's contract and checked by tests).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 32

I8_MIN, I8_MAX = -128, 127
I16_MIN, I16_MAX = -(2**15), 2**15 - 1


def _mm32_i8_kernel(a_ref, b_ref, o_ref):
    a = a_ref[...].astype(jnp.int8).astype(jnp.int32)
    b = b_ref[...].astype(jnp.int8).astype(jnp.int32)
    o_ref[...] = jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )


def _mm32_i16_kernel(a_ref, b_ref, o_ref):
    a = a_ref[...].astype(jnp.int16).astype(jnp.int32)
    b = b_ref[...].astype(jnp.int16).astype(jnp.int32)
    o_ref[...] = jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )


@functools.partial(jax.jit, static_argnames=())
def mm32_i8(a, b):
    """C(int32) = A(int8) @ B(int8) for a 32^3 subtask.

    Inputs are int32 tensors carrying int8-range values; the kernel
    truncates to int8 first (so out-of-range inputs wrap exactly like
    the hardware's narrow datapath would).
    """
    return pl.pallas_call(
        _mm32_i8_kernel,
        out_shape=jax.ShapeDtypeStruct((BLOCK, BLOCK), jnp.int32),
        interpret=True,
    )(a, b)


@functools.partial(jax.jit, static_argnames=())
def mm32_i16(a, b):
    """C(int32) = A(int16) @ B(int16) for a 32^3 subtask."""
    return pl.pallas_call(
        _mm32_i16_kernel,
        out_shape=jax.ShapeDtypeStruct((BLOCK, BLOCK), jnp.int32),
        interpret=True,
    )(a, b)


def mm_i8_ref(a, b):
    """Oracle: int8-wrapped operands, exact int32 accumulation."""
    a8 = jnp.asarray(a).astype(jnp.int8).astype(jnp.int32)
    b8 = jnp.asarray(b).astype(jnp.int8).astype(jnp.int32)
    return jax.lax.dot_general(
        a8, b8, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )


def mm_i16_ref(a, b):
    a16 = jnp.asarray(a).astype(jnp.int16).astype(jnp.int32)
    b16 = jnp.asarray(b).astype(jnp.int16).astype(jnp.int32)
    return jax.lax.dot_general(
        a16, b16, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )
