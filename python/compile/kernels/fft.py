"""Radix-2 DIT FFT butterfly Pallas kernel — the paper's Butterfly CC mode.

The paper's FFT PU has two processing structures (Table 4): PST#1 is a
dedicated Butterfly component, PST#2 a Parallel<2>*Cascade<3> group. Here
the butterfly stage is the L1 kernel; the L2 model (model.py) chains the
log2(N) stages and the bit-reversal permutation.

Paper dtype is cint16. The CPU-PJRT substrate carries complex data as two
float32 planes (DESIGN.md substitution table); the *timing* model in the
rust simulator still uses cint16 byte widths.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _butterfly_kernel(re_ref, im_ref, wre_ref, wim_ref, ore_ref, oim_ref):
    tr = re_ref[:, 0, :]
    ti = im_ref[:, 0, :]
    br = re_ref[:, 1, :]
    bi = im_ref[:, 1, :]
    # bottom leg rotated by the twiddle, then the +/- combine
    pr = br * wre_ref[...] - bi * wim_ref[...]
    pi = br * wim_ref[...] + bi * wre_ref[...]
    ore_ref[:, 0, :] = tr + pr
    oim_ref[:, 0, :] = ti + pi
    ore_ref[:, 1, :] = tr - pr
    oim_ref[:, 1, :] = ti - pi


def butterfly_stage(re, im, wre, wim):
    """One radix-2 stage over data reshaped to (groups, 2, half).

    re, im:   (g, 2, h) float32 — top/bottom butterfly legs
    wre, wim: (h,)      float32 — stage twiddle factors W_{2h}^j
    """
    g, two, h = re.shape
    assert two == 2 and wre.shape == (h,)
    shape = jax.ShapeDtypeStruct((g, 2, h), jnp.float32)
    return pl.pallas_call(
        _butterfly_kernel,
        out_shape=(shape, shape),
        interpret=True,
    )(re, im, wre, wim)


@functools.lru_cache(maxsize=None)
def bit_reverse_indices(n: int) -> np.ndarray:
    """Bit-reversal permutation for an n-point radix-2 FFT (n power of 2)."""
    bits = int(n).bit_length() - 1
    assert 1 << bits == n, "n must be a power of two"
    idx = np.arange(n)
    rev = np.zeros(n, dtype=np.int64)
    for b in range(bits):
        rev |= ((idx >> b) & 1) << (bits - 1 - b)
    return rev


@functools.lru_cache(maxsize=None)
def stage_twiddles(h: int) -> tuple[np.ndarray, np.ndarray]:
    """Twiddles W_{2h}^j = exp(-2*pi*i*j/(2h)) for j in [0, h) (numpy)."""
    j = np.arange(h)
    w = np.exp(-2j * np.pi * j / (2 * h))
    return w.real.astype(np.float32), w.imag.astype(np.float32)


def stage_twiddles_traced(h: int):
    """Twiddles as *traced* ops (iota -> cos/sin) rather than a baked
    constant array.

    Large constants MUST NOT appear in AOT-lowered modules: the HLO
    *text* printer elides literals beyond a size threshold ("...") and
    the downstream parser fills garbage — the interchange-format trap of
    this build (EXPERIMENTS.md, 'HLO round-trip gotchas'). XLA
    constant-folds the iota+cos at compile time anyway, so the kernel
    cost is identical.
    """
    j = jnp.arange(h, dtype=jnp.float32)
    ang = -jnp.pi * j / h
    return jnp.cos(ang), jnp.sin(ang)
