"""5x5 Filter2D Pallas kernel — one AIE core's base Filter2D task.

The paper splits images into 32x32 tiles (Table 4 / §4.3: "the split task
size is 32x32 image blocks"); a 5x5 filter therefore needs a 2-pixel halo,
so the per-core input is a 36x36 tile and the output a 32x32 tile.
Data type is int32 as in the paper's Filter2D evaluation (Table 3).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 32  # output tile edge (the paper's split size)
TAPS = 5  # filter edge
HALO = TAPS - 1  # 2 pixels each side
IN_TILE = TILE + HALO  # 36


def _filter2d_kernel(x_ref, k_ref, o_ref):
    acc = jnp.zeros((TILE, TILE), jnp.int32)
    # 25 shifted MACs — the unrolled form the AIE VLIW kernel would use.
    for u in range(TAPS):
        for v in range(TAPS):
            acc = acc + x_ref[u : u + TILE, v : v + TILE] * k_ref[u, v]
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=())
def filter2d_tile(x, k):
    """32x32 output tile of a 5x5 int32 filter over a 36x36 halo tile."""
    return pl.pallas_call(
        _filter2d_kernel,
        out_shape=jax.ShapeDtypeStruct((TILE, TILE), jnp.int32),
        interpret=True,
    )(x, k)


def _filter2d_batch_kernel(x_ref, k_ref, o_ref):
    acc = jnp.zeros(o_ref.shape, jnp.int32)
    for u in range(TAPS):
        for v in range(TAPS):
            acc = acc + x_ref[:, u : u + TILE, v : v + TILE] * k_ref[u, v]
    o_ref[...] = acc


def filter2d_batch(x, k):
    """Batched tile filter — the Parallel<8> CC: 8 cores, one tile each.

    x: (batch, 36, 36) int32, k: (5, 5) int32 -> (batch, 32, 32) int32.
    """
    batch = x.shape[0]
    return pl.pallas_call(
        _filter2d_batch_kernel,
        out_shape=jax.ShapeDtypeStruct((batch, TILE, TILE), jnp.int32),
        interpret=True,
    )(x, k)
