"""32x32x32 matrix-multiply Pallas kernel — one AIE core's base MM task.

The paper (following CHARM [47]) fixes the single-core subtask at
32x32x32 float: three 32x32 operands fit the 32 KiB AIE core memory
(12 KiB) while saturating the vector unit. On our substrate the same
choice is VMEM-shaped: one 32x32 block per BlockSpec tile.

Two entry points:

* :func:`mm32`      — C = A @ B                   (head of a cascade)
* :func:`mm32_acc`  — C = ACC + A @ B             (interior cascade stage;
                       the accumulator is what AIE cascade wires carry)
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 32  # the paper's single-core tile edge


def _mm32_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _mm32_acc_kernel(a_ref, b_ref, acc_ref, o_ref):
    o_ref[...] = acc_ref[...] + jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=())
def mm32(a, b):
    """C = A @ B for 32x32 float32 blocks (single AIE core subtask)."""
    return pl.pallas_call(
        _mm32_kernel,
        out_shape=jax.ShapeDtypeStruct((BLOCK, BLOCK), jnp.float32),
        interpret=True,
    )(a, b)


@functools.partial(jax.jit, static_argnames=())
def mm32_acc(a, b, acc):
    """C = ACC + A @ B — one interior stage of a Cascade<k> chain."""
    return pl.pallas_call(
        _mm32_acc_kernel,
        out_shape=jax.ShapeDtypeStruct((BLOCK, BLOCK), jnp.float32),
        interpret=True,
    )(a, b, acc)


def _mm_block_kernel(a_ref, b_ref, o_ref, *, nk):
    """Grid-tiled MM kernel: one (i, j) output block per grid step,
    K swept inside the kernel in BLOCK-wide slabs (the cascade loop)."""
    acc = jnp.zeros((BLOCK, BLOCK), jnp.float32)
    for k in range(nk):
        acc = acc + jnp.dot(
            a_ref[:, k * BLOCK : (k + 1) * BLOCK],
            b_ref[k * BLOCK : (k + 1) * BLOCK, :],
            preferred_element_type=jnp.float32,
        )
    o_ref[...] = acc


def mm_tiled(a, b):
    """M x K x N float MM tiled into 32x32x32 subtasks via a Pallas grid.

    This is the whole-PU dataflow in one pallas_call: grid = (M/32, N/32)
    output tiles, each accumulating K/32 cascade stages. Shapes must be
    multiples of 32 (the DU pads tasks to full TBs, Table 4 / §4.2).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and m % BLOCK == 0 and k % BLOCK == 0 and n % BLOCK == 0
    nk = k // BLOCK
    return pl.pallas_call(
        functools.partial(_mm_block_kernel, nk=nk),
        grid=(m // BLOCK, n // BLOCK),
        in_specs=[
            pl.BlockSpec((BLOCK, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, BLOCK), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((BLOCK, BLOCK), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)
