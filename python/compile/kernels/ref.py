"""Pure-jnp correctness oracles for every Layer-1 kernel and Layer-2 model.

These are the ground truth the pytest suite (and hypothesis sweeps) check
the Pallas kernels against — the CORE correctness signal of the build path.
"""

import jax.numpy as jnp
import numpy as np


def mm_ref(a, b):
    """Reference matrix multiply at any size."""
    return jnp.dot(
        a.astype(jnp.float32),
        b.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def mm_acc_ref(a, b, acc):
    """Reference cascade stage: ACC + A @ B."""
    return acc + mm_ref(a, b)


def filter2d_ref(x, k):
    """Valid-mode 2-D correlation (the paper's Filter2D semantics).

    x: (H + 4, W + 4) int32 halo tile, k: (5, 5) int32 -> (H, W) int32.
    Exact integer arithmetic, loop form — deliberately naive.
    """
    taps = k.shape[0]
    h = x.shape[0] - (taps - 1)
    w = x.shape[1] - (taps - 1)
    acc = jnp.zeros((h, w), jnp.int32)
    for u in range(taps):
        for v in range(taps):
            acc = acc + x[u : u + h, v : v + w] * k[u, v]
    return acc


def filter2d_image_ref(img, k):
    """Whole-image valid-mode filter used to check tiled decomposition."""
    return filter2d_ref(img, k)


def fft_ref(re, im):
    """Reference FFT on split real/imag planes via numpy's complex FFT."""
    x = np.asarray(re, dtype=np.float64) + 1j * np.asarray(im, dtype=np.float64)
    y = np.fft.fft(x)
    return y.real.astype(np.float32), y.imag.astype(np.float32)
