"""Layer-1 Pallas kernels.

Each kernel is the fixed-size subtask a single AIE core solves in the
paper's accelerators (DESIGN.md §Hardware-Adaptation):

* ``mm32``      — 32x32x32 float matrix multiply (the paper's / CHARM's
                  optimal single-core AIE load), with and without a cascade
                  accumulator input.
* ``filter2d``  — 5x5 int32 2-D filter over a 32x32 tile (+2-pixel halo).
* ``fft``       — radix-2 DIT butterfly stage over complex data carried as
                  separate float32 real/imag planes (paper dtype cint16;
                  see DESIGN.md substitutions).

All kernels run with ``interpret=True`` so the AOT lowering produces plain
HLO executable on the CPU PJRT client (a real-TPU build would produce
Mosaic custom-calls the CPU plugin cannot run).
"""

from . import fft, filter2d, mm32, mm_lowbit, ref  # noqa: F401
