"""L1 filter2d Pallas kernel vs oracle + tiling decomposition checks."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import model
from compile.kernels import filter2d, ref

TILE, HALO, IN_TILE = filter2d.TILE, filter2d.HALO, filter2d.IN_TILE


def _tile(rng, lo=-128, hi=128, shape=(IN_TILE, IN_TILE)):
    return rng.integers(lo, hi, shape).astype(np.int32)


def _kern(rng, lo=-16, hi=16):
    return rng.integers(lo, hi, (5, 5)).astype(np.int32)


def test_tile_matches_ref(rng):
    x, k = _tile(rng), _kern(rng)
    np.testing.assert_array_equal(
        filter2d.filter2d_tile(x, k), ref.filter2d_ref(x, k)
    )


def test_batch_matches_per_tile(rng):
    x = rng.integers(-128, 128, (8, IN_TILE, IN_TILE)).astype(np.int32)
    k = _kern(rng)
    got = np.asarray(filter2d.filter2d_batch(x, k))
    want = np.stack([np.asarray(ref.filter2d_ref(t, k)) for t in x])
    np.testing.assert_array_equal(got, want)


def test_delta_kernel_is_identity(rng):
    """A centre-tap delta filter returns the interior of the halo tile."""
    x = _tile(rng)
    k = np.zeros((5, 5), np.int32)
    k[2, 2] = 1
    np.testing.assert_array_equal(
        filter2d.filter2d_tile(x, k), x[2 : 2 + TILE, 2 : 2 + TILE]
    )


def test_box_kernel_sums(rng):
    x = np.ones((IN_TILE, IN_TILE), np.int32)
    k = np.ones((5, 5), np.int32)
    np.testing.assert_array_equal(
        filter2d.filter2d_tile(x, k), np.full((TILE, TILE), 25, np.int32)
    )


def test_linearity(rng):
    """filter(x, k1 + k2) == filter(x, k1) + filter(x, k2)."""
    x = _tile(rng)
    k1, k2 = _kern(rng), _kern(rng)
    lhs = np.asarray(filter2d.filter2d_tile(x, k1 + k2))
    rhs = np.asarray(filter2d.filter2d_tile(x, k1)) + np.asarray(
        filter2d.filter2d_tile(x, k2)
    )
    np.testing.assert_array_equal(lhs, rhs)


@pytest.mark.parametrize("tiles_h,tiles_w", [(1, 1), (2, 2), (4, 2)])
def test_tiled_image_equals_whole_image(rng, tiles_h, tiles_w):
    """TPC decomposition: tiling + per-tile filter == whole-image filter."""
    h, w = tiles_h * TILE, tiles_w * TILE
    img = rng.integers(-100, 100, (h + HALO, w + HALO)).astype(np.int32)
    k = _kern(rng)
    tiles = model.filter2d_tiles_from_image(img)
    out_tiles = [np.asarray(filter2d.filter2d_tile(t, k)) for t in tiles]
    got = model.filter2d_image_from_tiles(np.stack(out_tiles), h, w)
    want = np.asarray(ref.filter2d_image_ref(img, k))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), batch=st.integers(1, 8))
def test_batch_property(seed, batch):
    """Hypothesis sweep over batch sizes and value ranges (int32 exact)."""
    r = np.random.default_rng(seed)
    x = r.integers(-(2**15), 2**15, (batch, IN_TILE, IN_TILE)).astype(np.int32)
    k = r.integers(-64, 64, (5, 5)).astype(np.int32)
    got = np.asarray(filter2d.filter2d_batch(x, k))
    want = np.stack([np.asarray(ref.filter2d_ref(t, k)) for t in x])
    np.testing.assert_array_equal(got, want)
