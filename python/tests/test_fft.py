"""L1 butterfly kernel + L2 FFT model vs numpy's FFT."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import model
from compile.kernels import fft, ref


def _sig(rng, n):
    return (
        rng.standard_normal(n).astype(np.float32),
        rng.standard_normal(n).astype(np.float32),
    )


def test_transpose_permute_equals_gather():
    """The dense reshape/transpose bit-reversal (the form that survives
    the HLO round-trip) must equal the fancy-index gather."""
    from compile.model import _bit_reverse_permute

    for n in (8, 64, 1024, 8192):
        x = np.arange(n, dtype=np.float32)
        got = np.asarray(_bit_reverse_permute(x))
        want = x[fft.bit_reverse_indices(n)]
        np.testing.assert_array_equal(got, want)


def test_bit_reverse_is_involution():
    for n in (8, 64, 1024):
        idx = fft.bit_reverse_indices(n)
        assert np.array_equal(idx[idx], np.arange(n))


def test_bit_reverse_small():
    np.testing.assert_array_equal(
        fft.bit_reverse_indices(8), [0, 4, 2, 6, 1, 5, 3, 7]
    )


def test_stage_twiddles_unit_circle():
    for h in (1, 4, 64, 512):
        wre, wim = fft.stage_twiddles(h)
        np.testing.assert_allclose(wre**2 + wim**2, 1.0, atol=1e-6)
        assert wre[0] == 1.0 and wim[0] == 0.0


def test_butterfly_stage_h1(rng):
    """h=1 stage is just pairwise (a+b, a-b)."""
    re, im = _sig(rng, 8)
    orr, oii = fft.butterfly_stage(
        re.reshape(4, 2, 1), im.reshape(4, 2, 1),
        np.ones(1, np.float32), np.zeros(1, np.float32),
    )
    orr, oii = np.asarray(orr), np.asarray(oii)
    np.testing.assert_allclose(orr[:, 0, 0], re[0::2] + re[1::2], atol=1e-6)
    np.testing.assert_allclose(orr[:, 1, 0], re[0::2] - re[1::2], atol=1e-6)
    np.testing.assert_allclose(oii[:, 0, 0], im[0::2] + im[1::2], atol=1e-6)


@pytest.mark.parametrize("n", [8, 64, 256, 1024, 2048, 4096])
def test_fft_matches_numpy(rng, n):
    re, im = _sig(rng, n)
    got_re, got_im = model.fft_pu(re, im)
    want_re, want_im = ref.fft_ref(re, im)
    tol = 1e-2 * np.sqrt(n)
    np.testing.assert_allclose(got_re, want_re, atol=tol)
    np.testing.assert_allclose(got_im, want_im, atol=tol)


def test_fft_impulse(rng):
    """FFT(delta) is all-ones — exact up to float assoc."""
    n = 1024
    re = np.zeros(n, np.float32)
    im = np.zeros(n, np.float32)
    re[0] = 1.0
    got_re, got_im = model.fft_pu(re, im)
    np.testing.assert_allclose(got_re, np.ones(n), atol=1e-5)
    np.testing.assert_allclose(got_im, np.zeros(n), atol=1e-5)


def test_fft_linearity(rng):
    n = 256
    re1, im1 = _sig(rng, n)
    re2, im2 = _sig(rng, n)
    a_re, a_im = model.fft_pu(re1 + re2, im1 + im2)
    b1_re, b1_im = model.fft_pu(re1, im1)
    b2_re, b2_im = model.fft_pu(re2, im2)
    np.testing.assert_allclose(a_re, np.asarray(b1_re) + np.asarray(b2_re),
                               atol=1e-3)
    np.testing.assert_allclose(a_im, np.asarray(b1_im) + np.asarray(b2_im),
                               atol=1e-3)


def test_fft_parseval(rng):
    """Energy conservation: sum|x|^2 * N == sum|X|^2."""
    n = 512
    re, im = _sig(rng, n)
    got_re, got_im = model.fft_pu(re, im)
    e_t = np.sum(re.astype(np.float64) ** 2 + im.astype(np.float64) ** 2)
    e_f = np.sum(
        np.asarray(got_re, np.float64) ** 2 + np.asarray(got_im, np.float64) ** 2
    )
    np.testing.assert_allclose(e_f, e_t * n, rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), log_n=st.integers(3, 11))
def test_fft_property(seed, log_n):
    """Hypothesis sweep over sizes 8..2048."""
    n = 1 << log_n
    r = np.random.default_rng(seed)
    re = r.standard_normal(n).astype(np.float32)
    im = r.standard_normal(n).astype(np.float32)
    got_re, got_im = model.fft_pu(re, im)
    want_re, want_im = ref.fft_ref(re, im)
    tol = 1e-2 * np.sqrt(n)
    np.testing.assert_allclose(got_re, want_re, atol=tol)
    np.testing.assert_allclose(got_im, want_im, atol=tol)
