"""L1 mm32 Pallas kernel vs pure-jnp oracle."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import mm32, ref

BLOCK = mm32.BLOCK


def _rand(rng, shape, scale=1.0):
    return (rng.standard_normal(shape) * scale).astype(np.float32)


def test_mm32_matches_ref(rng):
    a, b = _rand(rng, (BLOCK, BLOCK)), _rand(rng, (BLOCK, BLOCK))
    np.testing.assert_allclose(mm32.mm32(a, b), ref.mm_ref(a, b), atol=1e-4)


def test_mm32_acc_matches_ref(rng):
    a, b = _rand(rng, (BLOCK, BLOCK)), _rand(rng, (BLOCK, BLOCK))
    acc = _rand(rng, (BLOCK, BLOCK))
    np.testing.assert_allclose(
        mm32.mm32_acc(a, b, acc), ref.mm_acc_ref(a, b, acc), atol=1e-4
    )


def test_mm32_zero_inputs():
    z = np.zeros((BLOCK, BLOCK), np.float32)
    np.testing.assert_array_equal(mm32.mm32(z, z), z)


def test_mm32_identity(rng):
    a = _rand(rng, (BLOCK, BLOCK))
    eye = np.eye(BLOCK, dtype=np.float32)
    np.testing.assert_allclose(mm32.mm32(a, eye), a, atol=1e-5)
    np.testing.assert_allclose(mm32.mm32(eye, a), a, atol=1e-5)


def test_mm32_acc_is_additive(rng):
    """mm32_acc(a, b, acc) == mm32(a, b) + acc — the cascade invariant."""
    a, b = _rand(rng, (BLOCK, BLOCK)), _rand(rng, (BLOCK, BLOCK))
    acc = _rand(rng, (BLOCK, BLOCK))
    np.testing.assert_allclose(
        mm32.mm32_acc(a, b, acc),
        np.asarray(mm32.mm32(a, b)) + acc,
        atol=1e-5,
    )


@pytest.mark.parametrize(
    "m,k,n",
    [(32, 32, 32), (64, 32, 32), (32, 64, 32), (32, 32, 64),
     (64, 64, 64), (96, 128, 64), (128, 128, 128)],
)
def test_mm_tiled_shapes(rng, m, k, n):
    a, b = _rand(rng, (m, k)), _rand(rng, (k, n))
    got = mm32.mm_tiled(a, b)
    assert got.shape == (m, n)
    np.testing.assert_allclose(got, ref.mm_ref(a, b), atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
    mi=st.integers(1, 4),
    ki=st.integers(1, 4),
    ni=st.integers(1, 4),
)
def test_mm_tiled_property(seed, scale, mi, ki, ni):
    """Hypothesis sweep: tiled pallas MM == oracle over shapes/magnitudes."""
    r = np.random.default_rng(seed)
    a = _rand(r, (mi * BLOCK, ki * BLOCK), scale)
    b = _rand(r, (ki * BLOCK, ni * BLOCK), scale)
    got = np.asarray(mm32.mm_tiled(a, b))
    want = np.asarray(ref.mm_ref(a, b))
    np.testing.assert_allclose(
        got, want, atol=1e-4 * scale * scale * BLOCK * ki, rtol=1e-4
    )


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_mm32_special_values(seed):
    """Exact integers survive float MM exactly (no fused fuzz)."""
    r = np.random.default_rng(seed)
    a = r.integers(-8, 8, (BLOCK, BLOCK)).astype(np.float32)
    b = r.integers(-8, 8, (BLOCK, BLOCK)).astype(np.float32)
    got = np.asarray(mm32.mm32(a, b))
    want = a.astype(np.int64) @ b.astype(np.int64)
    np.testing.assert_array_equal(got.astype(np.int64), want)
