"""L2 PU graphs vs oracles — the per-iteration compute of each accelerator."""

import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def _rand(rng, shape):
    return rng.standard_normal(shape).astype(np.float32)


def test_mm_pu128_matches_ref(rng):
    a, b = _rand(rng, (128, 128)), _rand(rng, (128, 128))
    np.testing.assert_allclose(
        model.mm_pu128(a, b), ref.mm_ref(a, b), atol=1e-3
    )


def test_mm_pu128_grid_equals_explicit(rng):
    """The fused-grid lowering and the explicit Parallel<16>*Cascade<4>
    graph compute the same function (the AOT path uses the grid form)."""
    a, b = _rand(rng, (128, 128)), _rand(rng, (128, 128))
    np.testing.assert_allclose(
        model.mm_pu128_grid(a, b), model.mm_pu128(a, b), atol=1e-3
    )


def test_mmt_cascade8_matches_ref(rng):
    a, b = _rand(rng, (32, 256)), _rand(rng, (256, 32))
    np.testing.assert_allclose(
        model.mmt_cascade8(a, b), ref.mm_ref(a, b), atol=1e-3
    )


def test_filter2d_pu8_matches_ref(rng):
    t = rng.integers(-128, 128, (8, 36, 36)).astype(np.int32)
    k = rng.integers(-16, 16, (5, 5)).astype(np.int32)
    got = np.asarray(model.filter2d_pu8(t, k))
    want = np.stack([np.asarray(ref.filter2d_ref(ti, k)) for ti in t])
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("n", [1024, 2048, 4096])
def test_fft_pu_sizes(rng, n):
    re = rng.standard_normal(n).astype(np.float32)
    im = rng.standard_normal(n).astype(np.float32)
    got_re, got_im = model.fft_pu(re, im)
    want_re, want_im = ref.fft_ref(re, im)
    tol = 1e-2 * np.sqrt(n)
    np.testing.assert_allclose(got_re, want_re, atol=tol)
    np.testing.assert_allclose(got_im, want_im, atol=tol)


def test_tiles_roundtrip(rng):
    img = rng.integers(-50, 50, (68, 68)).astype(np.int32)  # 2x2 tiles + halo
    tiles = model.filter2d_tiles_from_image(img)
    assert tiles.shape == (4, 36, 36)
    # interior of each halo tile reassembles the unpadded interior image
    interiors = tiles[:, 2:34, 2:34]
    back = model.filter2d_image_from_tiles(interiors, 64, 64)
    np.testing.assert_array_equal(back, img[2:66, 2:66])
