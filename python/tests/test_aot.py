"""AOT path: lowering produces parseable HLO text + a consistent manifest."""

import json
import os

import numpy as np
import pytest

from compile import aot, model


def test_catalogue_names_unique():
    names = [n for n, _, _ in aot.artifact_catalogue()]
    assert len(names) == len(set(names))
    assert {"mm32", "mm_pu128", "filter2d_pu8", "fft1024",
            "mmt_cascade8"} <= set(names)


def test_lower_mm32_hlo_text():
    cat = {n: (f, s) for n, f, s in aot.artifact_catalogue()}
    fn, specs = cat["mm32"]
    text, inputs, outputs = aot.lower_entry("mm32", fn, specs)
    assert text.startswith("HloModule")
    assert "f32[32,32]" in text
    assert inputs == [{"shape": [32, 32], "dtype": "f32"}] * 2
    assert outputs == [{"shape": [32, 32], "dtype": "f32"}]


def test_lower_is_return_tuple():
    """We lower with return_tuple=True; the entry layout must be a tuple —
    the rust side unwraps with to_tuple*()."""
    cat = {n: (f, s) for n, f, s in aot.artifact_catalogue()}
    fn, specs = cat["mm32"]
    text, _, _ = aot.lower_entry("mm32", fn, specs)
    first = text.splitlines()[0]
    assert "->(f32[32,32]{1,0})" in first.replace(" ", "")


def test_manifest_on_disk_if_built():
    """If `make artifacts` has run, manifest must match the catalogue."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    man_path = os.path.join(art, "manifest.json")
    if not os.path.exists(man_path):
        pytest.skip("artifacts not built")
    man = json.load(open(man_path))
    names = {e["name"] for e in man["artifacts"]}
    assert names == {n for n, _, _ in aot.artifact_catalogue()}
    for e in man["artifacts"]:
        assert os.path.exists(os.path.join(art, e["file"])), e["file"]
        for t in e["inputs"] + e["outputs"]:
            assert t["dtype"] in ("f32", "i32")
            assert all(isinstance(d, int) and d > 0 for d in t["shape"])


def test_filter2d_artifact_int32():
    cat = {n: (f, s) for n, f, s in aot.artifact_catalogue()}
    fn, specs = cat["filter2d_pu8"]
    text, inputs, outputs = aot.lower_entry("filter2d_pu8", fn, specs)
    assert inputs[0] == {"shape": [8, 36, 36], "dtype": "i32"}
    assert outputs == [{"shape": [8, 32, 32], "dtype": "i32"}]
    assert "s32[" in text  # HLO spells int32 as s32
