import os
import sys

import numpy as np
import pytest

# Make `compile` importable when pytest is run from python/ or repo root.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


@pytest.fixture
def rng():
    return np.random.default_rng(0xEA4)
