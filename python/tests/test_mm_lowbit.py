"""Low-bit MM kernels vs exact integer oracles."""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import mm_lowbit


def test_mm32_i8_exact(rng):
    a = rng.integers(-128, 128, (32, 32)).astype(np.int32)
    b = rng.integers(-128, 128, (32, 32)).astype(np.int32)
    np.testing.assert_array_equal(
        mm_lowbit.mm32_i8(a, b), mm_lowbit.mm_i8_ref(a, b)
    )


def test_mm32_i16_exact(rng):
    a = rng.integers(-(2**15), 2**15, (32, 32)).astype(np.int32)
    b = rng.integers(-(2**15), 2**15, (32, 32)).astype(np.int32)
    np.testing.assert_array_equal(
        mm_lowbit.mm32_i16(a, b), mm_lowbit.mm_i16_ref(a, b)
    )


def test_i8_wraps_out_of_range(rng):
    """Out-of-range int32 inputs must wrap to int8 exactly (the narrow
    datapath contract)."""
    a = np.full((32, 32), 200, np.int32)  # 200 wraps to -56 as int8
    b = np.eye(32, dtype=np.int32)
    got = np.asarray(mm_lowbit.mm32_i8(a, b))
    assert got[0, 0] == -56


def test_i8_matches_int64_matmul_in_range(rng):
    a = rng.integers(-128, 128, (32, 32))
    b = rng.integers(-128, 128, (32, 32))
    want = (a.astype(np.int64) @ b.astype(np.int64)).astype(np.int32)
    got = np.asarray(mm_lowbit.mm32_i8(a.astype(np.int32), b.astype(np.int32)))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_i8_property(seed):
    r = np.random.default_rng(seed)
    a = r.integers(-128, 128, (32, 32)).astype(np.int32)
    b = r.integers(-128, 128, (32, 32)).astype(np.int32)
    got = np.asarray(mm_lowbit.mm32_i8(a, b))
    want = (a.astype(np.int64) @ b.astype(np.int64)).astype(np.int32)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_i16_property(seed):
    r = np.random.default_rng(seed)
    a = r.integers(-(2**15), 2**15, (32, 32)).astype(np.int32)
    b = r.integers(-(2**15), 2**15, (32, 32)).astype(np.int32)
    got = np.asarray(mm_lowbit.mm32_i16(a, b))
    want = (a.astype(np.int64) @ b.astype(np.int64)).astype(np.int32)
    np.testing.assert_array_equal(got, want)
