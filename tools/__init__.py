# Marks tools/ as a package so `python3 -m tools.analyze` resolves from
# the repo root. The scripts here are zero-dependency by policy (they
# must run in authoring containers that only ship a Python interpreter).
