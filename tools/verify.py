#!/usr/bin/env python3
"""Static verification gate for the EA4RCA Rust workspace.

The full gate is `cargo build --release && cargo test -q` plus clippy,
fmt, doc tests and the release suites (see `make verify`). Authoring
containers do not always ship a Rust toolchain, so this script is the
subset of the gate that is runnable anywhere with a Python interpreter:
a lexical / structural checker over every Rust source in the workspace.

It is NOT a compiler and passing it is necessary, not sufficient. It
catches the mechanical breakage class that desk-checking misses:

  1. unbalanced delimiters (paren/bracket/brace) after stripping
     comments, strings, char literals and raw strings;
  2. `mod foo;` declarations pointing at files that do not exist, and
     orphan .rs files not reachable from any mod declaration;
  3. Cargo.toml targets whose `path` does not exist, and test/bench/
     example files on disk that are not registered (autodiscovery is
     off, so an unregistered file silently never builds);
  4. `use crate::...` first-segment resolution against the real module
     tree and the crate root's public items/re-exports;
  5. duplicate top-level item definitions in one module;
  6. `#[cfg(feature = "...")]` gates naming features Cargo.toml does
     not declare (clippy/rustc would reject unexpected cfgs);
  7. leftover `todo!` / `unimplemented!` / `dbg!` in non-test code;
  8. `.unwrap()` / `.expect()` in non-test library code under
     rust/src/coordinator/, rust/src/api/ and rust/src/runtime/ — a
     panic on the serving path takes a worker thread (and every job
     queued behind it) down. Vetted sites are enumerated in
     tools/unwrap_allowlist.txt as `path:line-fragment` entries; a
     stale entry (matching no site) is an error so the list can't rot.

The concurrency / unsafe-contract layer (lock-order graph, SAFETY
comments, shared-state hygiene) lives in its own analyzer: see
`tools/analyze` (`make race-gate`).

Exit status: 0 clean, 1 findings. `--warn-only` downgrades to 0.
"""

import argparse
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# ---------------------------------------------------------------- lexer


def strip_tokens(src, path):
    """Remove comments, string/char literals from Rust source.

    Returns (stripped_text, errors). Stripped text preserves newlines so
    line numbers survive; removed spans are blanked with spaces.
    """
    out = []
    errors = []
    i, n = 0, len(src)
    line = 1

    def err(msg):
        errors.append("%s:%d: %s" % (path, line, msg))

    while i < n:
        c = src[i]
        nxt = src[i + 1] if i + 1 < n else ""
        if c == "\n":
            line += 1
            out.append(c)
            i += 1
        elif c == "/" and nxt == "/":
            while i < n and src[i] != "\n":
                i += 1
        elif c == "/" and nxt == "*":
            depth, start_line = 1, line
            i += 2
            while i < n and depth:
                if src[i] == "\n":
                    line += 1
                if src.startswith("/*", i):
                    depth += 1
                    i += 2
                elif src.startswith("*/", i):
                    depth -= 1
                    i += 2
                else:
                    i += 1
            if depth:
                errors.append(
                    "%s:%d: unterminated block comment" % (path, start_line)
                )
            out.append(" ")
        elif c in "rb" and _raw_string_at(src, i):
            hashes, j = _raw_string_at(src, i)
            close = '"' + "#" * hashes
            end = src.find(close, j)
            if end == -1:
                err("unterminated raw string")
                i = n
            else:
                line += src.count("\n", i, end)
                i = end + len(close)
            out.append('""')
        elif c == '"' or (c == "b" and nxt == '"'):
            i += 2 if c == "b" else 1
            start_line = line
            while i < n:
                if src[i] == "\\":
                    i += 2
                elif src[i] == '"':
                    i += 1
                    break
                else:
                    if src[i] == "\n":
                        line += 1
                    i += 1
            else:
                errors.append(
                    "%s:%d: unterminated string" % (path, start_line)
                )
            out.append('""')
        elif c == "'":
            # Char literal vs lifetime. A char literal closes with a
            # quote within a couple of tokens; a lifetime never closes.
            m = re.match(r"'(\\.[^']*|[^'\\])'", src[i:])
            if m:
                i += m.end()
                out.append("' '")
            else:
                out.append(c)
                i += 1
        else:
            out.append(c)
            i += 1
    return "".join(out), errors


def _raw_string_at(src, i):
    """Return (hash_count, index_after_open_quote) if a raw string
    starts at i, else None."""
    m = re.match(r'(?:r|br)(#*)"', src[i:])
    if not m:
        return None
    # Guard against identifiers ending in r, e.g. `var"` can't happen
    # lexically, but `foo.r#"` can't either; require non-ident before.
    if i > 0 and (src[i - 1].isalnum() or src[i - 1] == "_"):
        return None
    return (len(m.group(1)), i + m.end())


def check_balance(stripped, path):
    errors = []
    stack = []
    pairs = {")": "(", "]": "[", "}": "{"}
    line = 1
    for ch in stripped:
        if ch == "\n":
            line += 1
        elif ch in "([{":
            stack.append((ch, line))
        elif ch in ")]}":
            if not stack:
                errors.append(
                    "%s:%d: unmatched closing '%s'" % (path, line, ch)
                )
            else:
                opener, oline = stack.pop()
                if opener != pairs[ch]:
                    errors.append(
                        "%s:%d: mismatched '%s' (opened '%s' at line %d)"
                        % (path, line, ch, opener, oline)
                    )
    for opener, oline in stack:
        errors.append("%s:%d: unclosed '%s'" % (path, oline, opener))
    return errors


# ------------------------------------------------------------ module tree


def module_files(crate_root):
    """Walk `mod` declarations from the crate roots; return
    (reachable_files, errors, module_of_file)."""
    errors = []
    reachable = {}
    roots = []
    for name in ("lib.rs", "main.rs"):
        p = os.path.join(crate_root, name)
        if os.path.exists(p):
            roots.append((p, ()))
    seen = set()
    while roots:
        path, modpath = roots.pop()
        if path in seen:
            continue
        seen.add(path)
        reachable[path] = modpath
        try:
            src = open(path, encoding="utf-8").read()
        except OSError as e:
            errors.append("%s: unreadable: %s" % (path, e))
            continue
        stripped, _ = strip_tokens(src, path)
        base = os.path.dirname(path)
        is_root = os.path.basename(path) in ("lib.rs", "main.rs")
        is_mod_rs = os.path.basename(path) == "mod.rs"
        for m in re.finditer(
            r"^\s*(?:pub(?:\([^)]*\))?\s+)?mod\s+([A-Za-z_][A-Za-z0-9_]*)\s*;",
            stripped,
            re.M,
        ):
            name = m.group(1)
            if is_root or is_mod_rs:
                cand = [
                    os.path.join(base, name + ".rs"),
                    os.path.join(base, name, "mod.rs"),
                ]
            else:
                stem = os.path.splitext(os.path.basename(path))[0]
                cand = [
                    os.path.join(base, stem, name + ".rs"),
                    os.path.join(base, stem, name, "mod.rs"),
                ]
            hits = [c for c in cand if os.path.exists(c)]
            if not hits:
                errors.append(
                    "%s: `mod %s;` has no file (looked for %s)"
                    % (path, name, ", ".join(os.path.relpath(c, REPO) for c in cand))
                )
            else:
                roots.append((hits[0], modpath + (name,)))
    return reachable, errors


def orphan_files(crate_root, reachable):
    errors = []
    for dirpath, _, files in os.walk(crate_root):
        for f in files:
            if not f.endswith(".rs"):
                continue
            p = os.path.join(dirpath, f)
            if p not in reachable:
                errors.append(
                    "%s: not reachable from any `mod` declaration"
                    % os.path.relpath(p, REPO)
                )
    return errors


# --------------------------------------------------------- cargo targets


def cargo_targets(cargo_toml):
    """Minimal TOML scrape: return list of (section, name, path)."""
    targets = []
    section = None
    name = path = None
    for raw in open(cargo_toml, encoding="utf-8"):
        stripped = raw.strip()
        if stripped.startswith("[["):
            if section and path:
                targets.append((section, name, path))
            section = stripped.strip("[]")
            name = path = None
        elif stripped.startswith("["):
            if section and path:
                targets.append((section, name, path))
            section = None
        elif section and "=" in stripped:
            key, _, val = stripped.partition("=")
            key = key.strip()
            val = val.strip().strip('"')
            if key == "name":
                name = val
            elif key == "path":
                path = val
    if section and path:
        targets.append((section, name, path))
    return targets


def check_targets(cargo_toml):
    errors = []
    targets = cargo_targets(cargo_toml)
    registered = set()
    for section, name, path in targets:
        full = os.path.join(REPO, path)
        registered.add(os.path.normpath(full))
        if not os.path.exists(full):
            errors.append(
                "Cargo.toml: [[%s]] %s points at missing %s"
                % (section, name, path)
            )
    for d, section in (
        ("rust/tests", "test"),
        ("benches", "bench"),
        ("examples", "example"),
    ):
        full_d = os.path.join(REPO, d)
        if not os.path.isdir(full_d):
            continue
        for f in sorted(os.listdir(full_d)):
            if not f.endswith(".rs"):
                continue
            p = os.path.normpath(os.path.join(full_d, f))
            if p not in registered:
                errors.append(
                    "%s/%s: on disk but not registered as a [[%s]] target "
                    "(autodiscovery is off; it will never build)"
                    % (d, f, section)
                )
    return errors


def declared_features(cargo_toml):
    feats = set()
    in_features = False
    for raw in open(cargo_toml, encoding="utf-8"):
        s = raw.strip()
        if s.startswith("["):
            in_features = s == "[features]"
        elif in_features and "=" in s and not s.startswith("#"):
            feats.add(s.partition("=")[0].strip())
    return feats


# ------------------------------------------------------------- symbols


ITEM_RE = re.compile(
    r"^\s*(?:pub(?:\([^)]*\))?\s+)?"
    r"(?:unsafe\s+)?(?:async\s+)?(?:const\s+)?(?:extern\s+\S+\s+)?"
    r"(fn|struct|enum|trait|union|type|static|mod|macro_rules!)\s+"
    r"([A-Za-z_][A-Za-z0-9_]*)",
    re.M,
)
CONST_RE = re.compile(
    r"^\s*(?:pub(?:\([^)]*\))?\s+)?const\s+([A-Z_][A-Za-z0-9_]*)\s*:", re.M
)
USE_RE = re.compile(
    r"^\s*(?:pub(?:\([^)]*\))?\s+)?use\s+([A-Za-z_][A-Za-z0-9_:]*)", re.M
)


def top_level_spans(stripped):
    """Yield (offset, line) of positions at brace depth 0."""
    depth = 0
    line = 1
    spans = []
    for idx, ch in enumerate(stripped):
        if ch == "\n":
            line += 1
        elif ch == "{":
            depth += 1
        elif ch == "}":
            depth = max(0, depth - 1)
        spans.append(depth)
    return spans


def check_duplicates(stripped, path):
    """Duplicate top-level items of the same kind+name in one file."""
    depths = top_level_spans(stripped)
    seen = {}
    errors = []
    for m in ITEM_RE.finditer(stripped):
        if depths[m.start(2)] != 0:
            continue
        kind, name = m.group(1), m.group(2)
        if kind in ("mod",):  # `mod tests {}` + `mod x;` collisions are rare
            continue
        line = stripped.count("\n", 0, m.start()) + 1
        key = (kind, name)
        if key in seen:
            errors.append(
                "%s:%d: duplicate top-level %s `%s` (first at line %d)"
                % (path, line, kind, name, seen[key])
            )
        else:
            seen[key] = line
    return errors


def crate_root_names(crate_root):
    """Public names visible as crate::<name>: modules declared in
    lib.rs plus items and re-exports defined there."""
    names = set()
    lib = os.path.join(crate_root, "lib.rs")
    if not os.path.exists(lib):
        return names
    src = open(lib, encoding="utf-8").read()
    stripped, _ = strip_tokens(src, lib)
    for m in re.finditer(
        r"^\s*(?:pub(?:\([^)]*\))?\s+)?mod\s+([A-Za-z_][A-Za-z0-9_]*)", stripped, re.M
    ):
        names.add(m.group(1))
    for m in ITEM_RE.finditer(stripped):
        names.add(m.group(2))
    for m in re.finditer(
        r"^\s*pub\s+use\s+[A-Za-z_][A-Za-z0-9_:]*::\{([^}]*)\}", stripped, re.M
    ):
        for part in m.group(1).split(","):
            part = part.strip()
            if part:
                names.add(part.split(" as ")[-1].strip().split("::")[-1])
    for m in re.finditer(
        r"^\s*pub\s+use\s+([A-Za-z_][A-Za-z0-9_:]*)\s*(?:as\s+([A-Za-z_][A-Za-z0-9_]*))?;",
        stripped,
        re.M,
    ):
        names.add(m.group(2) or m.group(1).split("::")[-1])
    return names


def check_use_paths(stripped, path, root_names):
    errors = []
    for m in USE_RE.finditer(stripped):
        segs = m.group(1).split("::")
        if segs[0] != "crate" or len(segs) < 2:
            continue
        if segs[1] not in root_names:
            line = stripped.count("\n", 0, m.start()) + 1
            errors.append(
                "%s:%d: `use crate::%s` — `%s` is not a module or public "
                "item of the crate root" % (path, line, "::".join(segs[1:]), segs[1])
            )
    return errors


def check_cfg_features(stripped, path, feats):
    errors = []
    for m in re.finditer(r'feature\s*=\s*"([^"]+)"', stripped):
        if m.group(1) not in feats:
            line = stripped.count("\n", 0, m.start()) + 1
            errors.append(
                '%s:%d: cfg feature "%s" not declared in Cargo.toml [features]'
                % (path, line, m.group(1))
            )
    return errors


# ------------------------------------------------------ unwrap policy


UNWRAP_RE = re.compile(r"\.(unwrap|expect)\s*\(")
# Modules where a panic unwinds a serving worker, not just a CLI run.
# runtime/ joined the list when the worker pool + kernel tiers put it
# on the serving path (every shard worker owns a Runtime).
UNWRAP_DIRS = ("rust/src/coordinator/", "rust/src/api/", "rust/src/runtime/")
UNWRAP_ALLOWLIST = os.path.join("tools", "unwrap_allowlist.txt")


def load_unwrap_allowlist():
    """Parse tools/unwrap_allowlist.txt: one `path:line-fragment` per
    line, `#` comments. Returns [(path, fragment, raw_entry)]."""
    entries = []
    full = os.path.join(REPO, UNWRAP_ALLOWLIST)
    if not os.path.exists(full):
        return entries
    for raw in open(full, encoding="utf-8"):
        s = raw.strip()
        if not s or s.startswith("#"):
            continue
        p, _, frag = s.partition(":")
        if p and frag:
            entries.append((p.strip(), frag.strip(), s))
    return entries


def blank_test_blocks(stripped):
    """Blank the brace-matched block following every `#[cfg(test)]`
    (newlines kept, so line numbers survive)."""
    out = list(stripped)
    for m in re.finditer(r"#\s*\[\s*cfg\s*\(\s*test\s*\)\s*\]", stripped):
        i = stripped.find("{", m.end())
        if i == -1:
            continue
        depth, j = 0, i
        while j < len(stripped):
            if stripped[j] == "{":
                depth += 1
            elif stripped[j] == "}":
                depth -= 1
                if depth == 0:
                    break
            j += 1
        for k in range(i, min(j + 1, len(stripped))):
            if out[k] != "\n":
                out[k] = " "
    return "".join(out)


def check_unwraps(stripped, src, rel, allowlist, used):
    """`.unwrap()` / `.expect()` outside `#[cfg(test)]` blocks in the
    serving-path modules. Detection runs on stripped text (comments and
    string literals blanked); the allowlist fragment matches against the
    original source line, so entries can quote the expect message."""
    if not rel.startswith(UNWRAP_DIRS):
        return []
    errors = []
    code = blank_test_blocks(stripped)
    src_lines = src.splitlines()
    for idx, line_text in enumerate(code.splitlines(), 1):
        for m in UNWRAP_RE.finditer(line_text):
            original = src_lines[idx - 1] if idx <= len(src_lines) else line_text
            hit = None
            for p, frag, raw in allowlist:
                if p == rel and frag in original:
                    hit = raw
                    break
            if hit:
                used.add(hit)
            else:
                errors.append(
                    "%s:%d: .%s() in non-test library code — return a "
                    "Result, or vet the site into %s"
                    % (rel, idx, m.group(1), UNWRAP_ALLOWLIST)
                )
    return errors


def check_leftovers(stripped, path):
    warnings = []
    if "/tests/" in path or path.endswith("tests.rs"):
        return warnings
    for m in re.finditer(r"\b(todo!|unimplemented!|dbg!)\s*\(", stripped):
        line = stripped.count("\n", 0, m.start()) + 1
        warnings.append("%s:%d: leftover %s(...)" % (path, line, m.group(1)))
    return warnings


# ---------------------------------------------------------------- main


def rust_files():
    out = []
    for top in ("rust", "benches", "examples", "vendor"):
        for dirpath, _, files in os.walk(os.path.join(REPO, top)):
            for f in sorted(files):
                if f.endswith(".rs"):
                    out.append(os.path.join(dirpath, f))
    return sorted(out)


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--warn-only", action="store_true")
    args = ap.parse_args()

    errors = []
    warnings = []

    cargo_toml = os.path.join(REPO, "Cargo.toml")
    errors += check_targets(cargo_toml)
    feats = declared_features(cargo_toml)
    # cfg(test)/cfg(doctest) style cfgs plus cargo-implicit feature deps.
    feats |= {"default", "pjrt"}

    crate_root = os.path.join(REPO, "rust", "src")
    reachable, mod_errors = module_files(crate_root)
    errors += mod_errors
    errors += orphan_files(crate_root, reachable)
    for vend in ("vendor/anyhow/src", "vendor/xla/src"):
        vroot = os.path.join(REPO, vend)
        vreach, verr = module_files(vroot)
        errors += verr
        errors += orphan_files(vroot, vreach)

    root_names = crate_root_names(crate_root)
    allowlist = load_unwrap_allowlist()
    allow_used = set()

    for path in rust_files():
        rel = os.path.relpath(path, REPO)
        src = open(path, encoding="utf-8").read()
        stripped, lex_errors = strip_tokens(src, rel)
        errors += lex_errors
        errors += check_balance(stripped, rel)
        errors += check_duplicates(stripped, rel)
        errors += check_cfg_features(stripped, rel, feats)
        errors += check_unwraps(stripped, src, rel, allowlist, allow_used)
        warnings += check_leftovers(stripped, rel)
        if rel.startswith(("rust/tests", "benches", "examples")):
            # Integration targets import through the crate's public API.
            pass
        elif rel.startswith("rust/src"):
            errors += check_use_paths(stripped, rel, root_names)

    # A stale entry is an error, not a warning: it means the vetted site
    # changed (or vanished) and the justification no longer covers
    # anything — the allowlist must not rot into a blanket waiver.
    for _, _, raw in allowlist:
        if raw not in allow_used:
            errors.append(
                "%s: stale entry `%s` (no matching site) — remove it or "
                "re-point it at the current line" % (UNWRAP_ALLOWLIST, raw)
            )

    for w in warnings:
        print("warning: %s" % w)
    for e in errors:
        print("error: %s" % e)
    total = len(rust_files())
    if errors:
        print(
            "\nstatic gate: %d error(s), %d warning(s) across %d files"
            % (len(errors), len(warnings), total)
        )
        return 0 if args.warn_only else 1
    print(
        "static gate: OK (%d files checked, %d warning(s))"
        % (total, len(warnings))
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
