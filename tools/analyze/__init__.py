"""Toolchain-free concurrency + unsafe-contract static analyzer.

Three passes over ``rust/src/`` (see ``tools/analyze/__main__.py`` for
the rule table and exit contract):

* ``lockgraph``     — RACE-001/002/003: inter-procedural lock-order
  graph, condvar cross-waits, locks held across long calls.
* ``unsafe_audit``  — UNSAFE-001/002/003: SAFETY comments,
  ``#[target_feature]`` reachability guards, module allowlist.
* ``shared_state``  — RACE-010/011/012: ``static mut``, thread-private
  locks moved into spawns, non-counter ``Ordering::Relaxed``.

Zero-dependency Python in the same style as ``tools/verify.py``: the
lexer blanks comments/strings and ``#[cfg(test)]`` blocks, everything
downstream is regex + brace matching over the blanked text. This is a
*linter*, not a model checker — each pass documents what it can and
cannot prove in DESIGN.md ("Static analysis layers").
"""

from collections import namedtuple

# One diagnostic. `line_text` carries the original source line so
# allowlist fragments can match against what the author actually wrote
# (mirrors the unwrap allowlist contract in tools/verify.py).
Finding = namedtuple("Finding", "code path line message line_text")


def render(f):
    """Stable single-line rendering: `CODE path:line: message`."""
    return "%s %s:%d: %s" % (f.code, f.path, f.line, f.message)


def sort_findings(findings):
    """Deterministic order: by code, then path, then line, then text —
    the golden self-test pins the output byte-stable on this."""
    return sorted(findings, key=lambda f: (f.code, f.path, f.line, f.message))
