#!/usr/bin/env python3
"""Concurrency + unsafe-contract gate for the EA4RCA serving stack.

Usage:
    python3 -m tools.analyze               # analyze rust/src, exit 1 on findings
    python3 -m tools.analyze --self-test   # fixture corpus + golden run
    python3 -m tools.analyze --list-rules  # rule table

Rules (see DESIGN.md "Static analysis layers" for the full contract):

    RACE-001   lock-order cycle in the inter-procedural acquired-while-
               held graph (potential deadlock)
    RACE-002   lock held across a Condvar wait guarding a different lock
    RACE-003   blocking guard held across a long/blocking call
               (Backend::execute*, thread::scope, .join(), .recv*,
               thread::sleep) — directly or through the call graph
    RACE-010   `static mut`
    RACE-011   bare (non-Arc) lock local moved into a spawned thread
    RACE-012   Ordering::Relaxed outside a pure counter
    UNSAFE-001 unsafe fn/impl/block without a SAFETY comment
    UNSAFE-002 #[target_feature] fn called without a feature-detection
               guard in the caller
    UNSAFE-003 unsafe outside the modules vetted in
               tools/unsafe_allowlist.txt

Allowlists:
    tools/unsafe_allowlist.txt  path fragments of modules vetted to
                                contain unsafe (UNSAFE-003).
    tools/race_allowlist.txt    `path:fragment` entries suppressing an
                                individual RACE-xxx / UNSAFE-001/002
                                finding; the fragment must appear in the
                                flagged source line (or, for multi-site
                                findings like RACE-001, in the message).
    A stale entry in either list fails the gate (exit 1) — the same
    no-rot contract tools/verify.py enforces for the unwrap allowlist.

Exit status: 0 clean, 1 findings (or a failed self-test).
Zero-dependency Python by policy; runs in any authoring container.
"""

import argparse
import os
import sys

from . import render, sort_findings
from .lexer import REPO, functions, parse_file, rust_sources
from . import lockgraph, shared_state, unsafe_audit

RUST_SRC = os.path.join(REPO, "rust", "src")
UNSAFE_ALLOWLIST = os.path.join("tools", "unsafe_allowlist.txt")
RACE_ALLOWLIST = os.path.join("tools", "race_allowlist.txt")
FIXTURES = os.path.join(REPO, "tools", "analyze", "fixtures")

ALL_RULES = (
    "RACE-001", "RACE-002", "RACE-003", "RACE-010", "RACE-011", "RACE-012",
    "UNSAFE-001", "UNSAFE-002", "UNSAFE-003",
)


def load_fragments(rel_path, split_path=False):
    """Allowlist loader. `split_path=True` parses `path:fragment` pairs
    (race allowlist); otherwise each line is one path fragment (unsafe
    allowlist). Returns a list of entries plus the raw line for
    stale-entry accounting."""
    entries = []
    full = os.path.join(REPO, rel_path)
    if not os.path.exists(full):
        return entries
    for raw in open(full, encoding="utf-8"):
        s = raw.strip()
        if not s or s.startswith("#"):
            continue
        if split_path:
            p, _, frag = s.partition(":")
            if p and frag:
                entries.append(((p.strip(), frag.strip()), s))
        else:
            entries.append((s, s))
    return entries


def analyze_tree(sources, unsafe_allow, race_allow):
    """Run all three passes. Returns (findings, stats, stale_errors)."""
    fns_by_file = {sf.rel: functions(sf) for sf in sources}
    unsafe_used, race_used = set(), set()

    findings = []
    findings += lockgraph.analyze(sources, fns_by_file)
    findings += shared_state.analyze(sources, fns_by_file)
    findings += unsafe_audit.analyze(sources, fns_by_file, unsafe_allow, unsafe_used)

    # race allowlist: suppress individually vetted findings
    kept = []
    for f in findings:
        hit = None
        for (p, frag), raw in race_allow:
            if p == f.path and (frag in f.line_text or frag in f.message):
                hit = raw
                break
        if hit:
            race_used.add(hit)
        else:
            kept.append(f)

    stale = []
    for _, raw in race_allow:
        if raw not in race_used:
            stale.append(
                "%s: stale entry `%s` (suppresses nothing) — remove it"
                % (RACE_ALLOWLIST, raw)
            )

    nlocks = sum(
        len(v) for v in lockgraph.collect_decls(sources)[0].values()
    )
    stats = {
        "files": len(sources),
        "fns": sum(len(v) for v in fns_by_file.values()),
        "locks": nlocks,
    }
    return sort_findings(kept), stats, stale


def run_gate():
    sources = [parse_file(full, rel) for rel, full in rust_sources(RUST_SRC)]
    unsafe_allow = load_fragments(UNSAFE_ALLOWLIST)
    race_allow = load_fragments(RACE_ALLOWLIST, split_path=True)
    findings, stats, stale = analyze_tree(sources, unsafe_allow, race_allow)

    out = []
    for f in findings:
        out.append(render(f))
    for s in stale:
        out.append("allowlist-error %s" % s)
    if out:
        out.append(
            "race gate: %d finding(s) across %d files — fix them or vet "
            "them into the allowlists with a justification"
            % (len(findings) + len(stale), stats["files"])
        )
        print("\n".join(out))
        return 1
    print(
        "race gate: OK (%d files, %d fns, %d lock fields; "
        "lock-order + unsafe-contract + shared-state passes clean)"
        % (stats["files"], stats["fns"], stats["locks"])
    )
    return 0


def run_gate_to_string():
    """The golden self-test needs the gate's exact output twice."""
    import io
    from contextlib import redirect_stdout

    buf = io.StringIO()
    with redirect_stdout(buf):
        code = run_gate()
    return code, buf.getvalue()


def self_test():
    failures = []
    fixture_files = sorted(
        f for f in os.listdir(FIXTURES) if f.endswith(".rs")
    )
    covered = set()
    for fname in fixture_files:
        full = os.path.join(FIXTURES, fname)
        first = open(full, encoding="utf-8").readline()
        if "expect:" not in first:
            failures.append("%s: missing `// expect: CODE` header" % fname)
            continue
        expected = first.split("expect:")[1].strip()
        covered.add(expected)
        rel = "tools/analyze/fixtures/" + fname
        sf = parse_file(full, rel)
        # Fixtures exercising anything but the module policy run with
        # the fixtures dir allowlisted, so their (intentional) unsafe
        # doesn't drag UNSAFE-003 into every expectation. The UNSAFE-003
        # fixture runs with an empty allowlist, and unsafe-free fixtures
        # get none either (an unused entry would trip the stale check).
        has_unsafe = "unsafe" in sf.stripped
        unsafe_allow = (
            [("tools/analyze/fixtures", "tools/analyze/fixtures")]
            if has_unsafe and expected != "UNSAFE-003" else []
        )
        findings, _, _ = analyze_tree([sf], unsafe_allow, [])
        codes = {f.code for f in findings}
        if codes != {expected}:
            failures.append(
                "%s: expected exactly {%s}, analyzer said %s%s"
                % (fname, expected, sorted(codes) or "{}",
                   "".join("\n    " + render(f) for f in findings))
            )
    missing = [r for r in ALL_RULES if r not in covered]
    if missing:
        failures.append("no tripping fixture for rule(s): %s" % ", ".join(missing))

    # Golden run: the shipped tree is clean and the output byte-stable.
    code1, out1 = run_gate_to_string()
    code2, out2 = run_gate_to_string()
    if code1 != 0:
        failures.append("golden: shipped tree is not clean:\n%s" % out1)
    if out1 != out2:
        failures.append("golden: analyzer output is not byte-stable")

    if failures:
        print("self-test: %d failure(s)" % len(failures))
        for f in failures:
            print("  - %s" % f)
        return 1
    print(
        "self-test: OK (%d fixtures, %d rules covered, golden run "
        "byte-stable and clean)" % (len(fixture_files), len(covered))
    )
    return 0


def main():
    ap = argparse.ArgumentParser(
        prog="tools.analyze", description=__doc__.splitlines()[0]
    )
    ap.add_argument("--self-test", action="store_true",
                    help="run the fixture corpus + golden run")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule table and exit")
    args = ap.parse_args()
    if args.list_rules:
        print(__doc__)
        return 0
    if args.self_test:
        return self_test()
    return run_gate()


if __name__ == "__main__":
    sys.exit(main())
