"""Unsafe-contract pass: UNSAFE-001/002/003.

UNSAFE-001  every `unsafe fn` / `unsafe impl` / `unsafe {}` block must
            carry a SAFETY comment: a `// SAFETY:` / `// Safety:` line
            (or `/// # Safety` doc section) in the contiguous run of
            comments/attributes immediately above the `unsafe` token
            (or on the same line). Matched case-insensitively on the
            word "safety" so house styles don't churn.
UNSAFE-002  a `#[target_feature]` fn may only be *called* from (a) a fn
            that checks `is_x86_feature_detected!` / `cfg!(target_
            feature ...)` itself, (b) a fn that calls such a guard fn
            (transitively — `available()` counts), or (c) another
            `#[target_feature]` fn (already inside the contract).
            Everything else is an unguarded ISA call: UB on a CPU
            without the feature.
UNSAFE-003  `unsafe` appears only in modules vetted into
            `tools/unsafe_allowlist.txt` (path-fragment matched; a
            stale entry — matching no file that still contains
            `unsafe` — is an error, same contract as the unwrap
            allowlist).

Can prove: the textual presence of the contract comment and of a
feature-detection guard somewhere in the calling fn. Cannot prove: that
the comment is *true*, that the guard dominates the call on every
control-flow path, or anything about unsafe reached through function
pointers.
"""

import re

from . import Finding
from .lexer import line_of

UNSAFE_RE = re.compile(r"\bunsafe\b\s*(fn|impl|trait|\{)?")
TF_ATTR_RE = re.compile(r"#\s*\[\s*target_feature[^\]]*\]")
GUARD_RE = re.compile(r"is_x86_feature_detected\s*!|cfg\s*!\s*\(\s*target_feature")
SAFETY_RE = re.compile(r"safety", re.I)
# A call to `%s`: optional path prefix, then the name directly followed
# by `(`. The lookbehind must NOT exclude `!` — `if !available()` is a
# negated *call*; macro invocations are excluded by the `!` that would
# sit between the name and the paren instead.
CALL_NAME = r"(?<!\w)(?:\w+\s*::\s*)*%s\s*\("


def _unsafe_spans(sf):
    """[start, end) offsets of every `unsafe {}` block body and
    `unsafe fn` body in `sf.stripped`. A #[target_feature] fn is an
    `unsafe fn`, so a *call to it* can only occur inside one of these
    spans — a same-named call in safe code is a safe wrapper."""
    spans = []
    text = sf.stripped
    n = len(text)
    for m in UNSAFE_RE.finditer(text):
        kind = m.group(1)
        if kind == "{":
            i = m.end() - 1
        elif kind == "fn":
            i = text.find("{", m.end())
            semi = text.find(";", m.end())
            if i == -1 or (semi != -1 and semi < i):
                continue  # bodyless trait declaration
        else:
            continue
        depth, j = 0, i
        while j < n:
            if text[j] == "{":
                depth += 1
            elif text[j] == "}":
                depth -= 1
                if depth == 0:
                    break
            j += 1
        spans.append((i, min(j + 1, n)))
    return spans


def _has_safety_comment(sf, line):
    """SAFETY marker on the unsafe line itself or in the contiguous
    comment/attribute run directly above it (original source — comments
    are exactly what this rule is about)."""
    lines = sf.src_lines
    if 0 < line <= len(lines) and SAFETY_RE.search(_comment_part(lines[line - 1])):
        return True
    i = line - 2  # 0-based index of the line above
    while i >= 0:
        s = lines[i].strip()
        if s.startswith(("//", "#[", "#![")) or (s.startswith("pub") and "unsafe" in s):
            if SAFETY_RE.search(s):
                return True
            i -= 1
            continue
        break
    return False


def _comment_part(line):
    k = line.find("//")
    return line[k:] if k != -1 else ""


def analyze(sources, fns_by_file, allowlist_entries, allow_used):
    findings = []

    # --- per-file unsafe occurrences: UNSAFE-001 + UNSAFE-003
    files_with_unsafe = []
    for sf in sources:
        hits = []
        for m in UNSAFE_RE.finditer(sf.stripped):
            kind = m.group(1)
            if kind is None:
                # `unsafe` in some position we don't classify (e.g. a
                # fn-pointer type) — still unsafe surface for -003.
                kind = "use"
            hits.append((m.start(), kind))
        if not hits:
            continue
        files_with_unsafe.append(sf.rel)
        allowed = False
        for frag, raw in allowlist_entries:
            if frag in sf.rel:
                allowed = True
                allow_used.add(raw)
        for off, kind in hits:
            line = line_of(sf.stripped, off)
            what = {"{": "unsafe block"}.get(kind, "unsafe " + kind)
            if not allowed:
                findings.append(Finding(
                    "UNSAFE-003", sf.rel, line,
                    "%s in a module not vetted for unsafe — fix it or add "
                    "the module to tools/unsafe_allowlist.txt with a "
                    "justification" % what,
                    _src(sf, line),
                ))
            if not _has_safety_comment(sf, line):
                findings.append(Finding(
                    "UNSAFE-001", sf.rel, line,
                    "%s without a SAFETY comment — state the invariant that "
                    "makes it sound on the line(s) above" % what,
                    _src(sf, line),
                ))

    # --- UNSAFE-002: #[target_feature] fns reached without a guard
    tf_fns = set()   # (rel, name)
    for sf in sources:
        for m in TF_ATTR_RE.finditer(sf.stripped):
            nm = re.search(r"fn\s+(\w+)", sf.stripped[m.end():m.end() + 300])
            if nm:
                tf_fns.add(nm.group(1))

    if tf_fns:
        all_fns = []
        for sf in sources:
            for fn in fns_by_file[sf.rel]:
                body = sf.flat[fn.body_start:fn.body_end]
                all_fns.append((sf, fn, body))
        guarded = set()   # fn names containing a guard macro directly
        for _, fn, body in all_fns:
            if GUARD_RE.search(body):
                guarded.add(fn.name)
        # transitive: a fn that calls a guard fn is guarded
        changed = True
        while changed:
            changed = False
            for _, fn, body in all_fns:
                if fn.name in guarded:
                    continue
                for g in list(guarded):
                    if re.search(CALL_NAME % re.escape(g), body):
                        guarded.add(fn.name)
                        changed = True
                        break
        spans_by_rel = {}
        for sf, fn, body in all_fns:
            if fn.name in tf_fns:
                continue  # TF-to-TF calls live inside the contract
            if sf.rel not in spans_by_rel:
                spans_by_rel[sf.rel] = _unsafe_spans(sf)
            for t in sorted(tf_fns):
                for m in re.finditer(CALL_NAME % re.escape(t), body):
                    if fn.name in guarded:
                        continue
                    off = fn.body_start + m.start()
                    # a TF fn is `unsafe fn`: callable only inside an
                    # unsafe span — a match in safe code is a same-named
                    # safe wrapper, not the kernel.
                    if not any(a <= off < b for a, b in spans_by_rel[sf.rel]):
                        continue
                    line = line_of(sf.stripped, fn.body_start + m.start())
                    findings.append(Finding(
                        "UNSAFE-002", sf.rel, line,
                        "#[target_feature] fn `%s` called from `%s`, which "
                        "neither checks is_x86_feature_detected! nor calls a "
                        "guard fn — UB on CPUs without the feature"
                        % (t, fn.name),
                        _src(sf, line),
                    ))

    # --- stale allowlist entries are errors (same contract as the
    #     unwrap allowlist: the list must not rot)
    for frag, raw in allowlist_entries:
        if raw not in allow_used:
            findings.append(Finding(
                "UNSAFE-003", "tools/unsafe_allowlist.txt", 0,
                "stale entry `%s` — no analyzed file matching it still "
                "contains unsafe; remove it" % raw,
                "",
            ))
    return findings


def _src(sf, line):
    return sf.src_lines[line - 1] if 0 < line <= len(sf.src_lines) else ""
