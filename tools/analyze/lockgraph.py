"""Lock-order pass: RACE-001/002/003.

Model
-----
* A *lock* is a struct field declared `name: Mutex<..>` / `RwLock<..>`;
  a `Condvar` field is tracked separately for wait-site resolution.
  Lock identity is the field name qualified by the declaring file's stem
  (`shard.state`); a field name declared in several files collapses to
  the bare name only when an acquisition can't be attributed (merging is
  conservative: it can add edges, never hide them).
* A *guard* is born at `let g = lock_clean(&path.field)` /
  `let g = path.field.lock()...;` (with a small set of adapter calls
  like `.unwrap()` / `.unwrap_or_else(..)` / `.ok()` tolerated between
  the acquisition and the `;`), and dies at `drop(g)` or when its
  enclosing brace block closes — whichever comes first. An acquisition
  that is *not* such a binding is a transient: held to the end of its
  statement.
* Condvar waits (`g = cv.wait(g)`, `let (g2, ..) = cv.wait_timeout(g,
  ..)`) transfer the guard: the result binding guards the same lock.
* `try_lock`/`try_read`/`try_write` guards are non-blocking: they can't
  participate in a deadlock cycle as the *waiting* side and holding one
  across a long call is the documented fallback pattern (the interp
  scratch pool), so they are exempt from RACE-001 targets and RACE-003
  sources — but they still count as *held* when computing what a
  blocking acquisition waits behind.

Rules
-----
RACE-001  cycle in the inter-procedural acquired-while-held graph
          (potential deadlock).
RACE-002  a lock held across a `Condvar` wait that guards a *different*
          lock (the sleeping thread keeps the extra lock for the whole
          wait).
RACE-003  a blocking guard held across a long/blocking call —
          `Backend::execute`/`execute_batch`, `thread::scope`,
          `.join()`, `.recv()`/`.recv_timeout()`, `thread::sleep` —
          directly or transitively through the call graph.

What this pass can prove: every *textual* acquisition order and every
guard lifetime that follows the binding idioms above. What it cannot:
aliasing through references, guards smuggled through struct fields or
returned from functions, trait-object dispatch narrower than
"every fn with that bare name and matching self-ness".
"""

import re
from collections import defaultdict, namedtuple

from . import Finding
from .lexer import depth_array, line_of

DECL_RE = re.compile(
    r"(?:^|[({,\n]\s*)(?:pub(?:\s*\([^)]*\))?\s+)?([a-z_]\w*)\s*:\s*"
    r"((?:\w+::)*)(Mutex|RwLock|Condvar)\b(?!\s*::)"
)
ACQ_RE = re.compile(
    r"(?:\block_clean\s*\(\s*&?\s*(?P<lc>[\w.]+)\s*\))"
    r"|(?:(?<![\w.])(?P<recv>[\w.]+)\."
    r"(?P<meth>try_lock|try_read|try_write|lock|read|write)\s*\(\s*\))"
)
WAIT_RE = re.compile(
    r"(?P<cv>[\w.]+)\."
    r"(?P<wm>wait_timeout_while|wait_timeout|wait_while|wait)\s*\(\s*(?P<g>\w+)\b"
)
DROP_RE = re.compile(r"(?<![\w.])drop\s*\(\s*(\w+)\s*\)")
# `!` must stay out of the lookbehind: `if !flush_ready(..)` is a
# negated call, not a macro (a macro's `!` follows the name, where it
# already breaks the `name(` adjacency this regex requires).
CALL_RE = re.compile(r"(?<!\w)([a-z_]\w*)\s*(?:::\s*<[^>(]*>\s*)?\(")
ADAPTER_RE = re.compile(r"\s*\.\s*(unwrap|expect|unwrap_or_else|ok|map_err)\s*\(")

# Long/blocking calls a *blocking* guard must not be held across.
MARKERS = [
    ("Backend::execute", re.compile(r"\.execute\s*(?:::\s*<[^>(]*>\s*)?\(")),
    ("Backend::execute_batch", re.compile(r"\.execute_batch\s*\(")),
    ("thread::scope", re.compile(r"thread\s*::\s*scope\s*\(")),
    ("JoinHandle::join", re.compile(r"\.join\s*\(\s*\)")),
    ("channel recv", re.compile(r"\.recv(?:_timeout)?\s*\(")),
    ("thread::sleep", re.compile(r"thread\s*::\s*sleep\s*\(")),
]

# Method names that are lock/wait machinery, not user calls.
NOT_CALLEES = {
    "lock", "read", "write", "try_lock", "try_read", "try_write",
    "wait", "wait_timeout", "wait_while", "wait_timeout_while",
    "lock_clean", "drop",
}

BLOCKING_METHS = {"lock", "read", "write"}

Interval = namedtuple("Interval", "lock start end blocking line")


def _last_component(path_expr):
    return path_expr.rstrip(".").split(".")[-1]


def _stem(rel):
    return rel.rsplit("/", 1)[-1][:-3]


def _consume_adapters(flat, i, limit):
    while True:
        m = ADAPTER_RE.match(flat, i, limit)
        if not m:
            return i
        j = m.end() - 1  # at '('
        depth = 0
        while j < limit:
            if flat[j] == "(":
                depth += 1
            elif flat[j] == ")":
                depth -= 1
                if depth == 0:
                    break
            j += 1
        i = j + 1


class FnInfo:
    def __init__(self, fn, sf):
        self.fn = fn
        self.sf = sf
        self.intervals = []   # Interval list (guards + transients)
        self.acquisitions = []  # (lock, offset, blocking)
        self.waits = []       # (cv_lockid, offset, guarded_lock)
        self.calls = []       # (name, offset, is_method)
        self.markers = []     # (marker_name, offset)
        self.locks_used = set()
        self.marker_reach = set()  # marker names reachable (self + callees)


def collect_decls(sources):
    """(lock_decls, condvar_decls): name -> set of declaring rel paths."""
    locks, condvars = defaultdict(set), defaultdict(set)
    for sf in sources:
        for m in DECL_RE.finditer(sf.stripped):
            name, kind = m.group(1), m.group(3)
            (condvars if kind == "Condvar" else locks)[name].add(sf.rel)
    return locks, condvars


def _resolver(decls):
    def resolve(name, rel):
        files = decls.get(name)
        if not files:
            return None
        if rel in files:
            return "%s.%s" % (_stem(rel), name)
        if len(files) == 1:
            return "%s.%s" % (_stem(next(iter(files))), name)
        return name  # ambiguous: merged node
    return resolve


def build_fn_infos(sources, fns_by_file, resolve_lock, resolve_cv, fn_names):
    """Extract per-function events. `fn_names` maps bare name ->
    {"method": bool} describing whether any fn with that name is a
    method / free fn (for call-site resolution)."""
    infos = []
    for sf in sources:
        for fn in fns_by_file[sf.rel]:
            info = FnInfo(fn, sf)
            flat, bs, be = sf.flat, fn.body_start, fn.body_end
            depths = depth_array(sf.stripped, bs, be)
            guards = defaultdict(list)  # name -> [Interval index] (shadowing)

            def block_end(offset):
                d = depths[offset - bs]
                for i in range(offset + 1, be):
                    if depths[i - bs] < d:
                        return i
                return be

            # -- acquisitions (guards + transients)
            for m in ACQ_RE.finditer(flat, bs, be):
                target = m.group("lc") or m.group("recv")
                meth = m.group("meth")
                lock = resolve_lock(_last_component(target), sf.rel)
                if lock is None:
                    continue
                blocking = meth is None or meth in BLOCKING_METHS
                info.acquisitions.append((lock, m.start(), blocking))
                info.locks_used.add(lock)
                after = _consume_adapters(flat, m.end(), be)
                bind = re.search(
                    r"let\s+(?:mut\s+)?(\w+)\s*=\s*\Z",
                    flat[max(bs, m.start() - 60):m.start()],
                )
                if bind and bind.group(1) != "_" and re.match(r"\s*;", flat[after:after + 4]):
                    # `let _ = lock()` drops immediately in Rust, so `_`
                    # falls through to the transient branch below.
                    end = block_end(m.start())
                    guards[bind.group(1)].append(len(info.intervals))
                    info.intervals.append(
                        Interval(lock, m.start(), end, blocking,
                                 line_of(sf.stripped, m.start()))
                    )
                else:
                    semi = flat.find(";", m.end(), be)
                    end = semi if semi != -1 else be
                    info.intervals.append(
                        Interval(lock, m.start(), end, blocking,
                                 line_of(sf.stripped, m.start()))
                    )

            # -- condvar waits: RACE-002 sites + guard transfer
            for m in WAIT_RE.finditer(flat, bs, be):
                cv = resolve_cv(_last_component(m.group("cv")), sf.rel)
                if cv is None:
                    continue
                gname = m.group("g")
                idxs = guards.get(gname) or []
                # the innermost live binding at the wait site, else the
                # lexically latest one before it
                live = [i for i in idxs
                        if info.intervals[i].start < m.start() <= info.intervals[i].end]
                idx = live[-1] if live else (idxs[-1] if idxs else None)
                guarded = info.intervals[idx].lock if idx is not None else None
                info.waits.append((cv, m.start(), guarded))
                # transfer: `g2 = cv.wait(g)` / `let (g2, ..) = cv.wait_timeout(g, ..)`
                head = flat[max(bs, m.start() - 60):m.start()]
                tgt = re.search(r"(?:let\s+(?:mut\s+)?\(?\s*)?(\w+)\s*(?:,[^)=]*\)?)?\s*=\s*\Z", head)
                if tgt and tgt.group(1) != "_" and guarded is not None:
                    end = block_end(m.start())
                    guards[tgt.group(1)].append(len(info.intervals))
                    info.intervals.append(
                        Interval(guarded, m.start(), end, True,
                                 line_of(sf.stripped, m.start()))
                    )

            # -- explicit drops end every live same-named guard early
            for m in DROP_RE.finditer(flat, bs, be):
                for idx in guards.get(m.group(1), []):
                    iv = info.intervals[idx]
                    if iv.start < m.start() < iv.end:
                        info.intervals[idx] = iv._replace(end=m.start())

            # -- long-call markers
            for mname, mre in MARKERS:
                for m in mre.finditer(flat, bs, be):
                    info.markers.append((mname, m.start()))

            # -- calls into the local fn table
            for m in CALL_RE.finditer(flat, bs, be):
                name = m.group(1)
                if name in NOT_CALLEES or name not in fn_names:
                    continue
                is_method = m.start() > 0 and flat[m.start() - 1] == "."
                info.calls.append((name, m.start(), is_method))

            infos.append(info)
    return infos


def analyze(sources, fns_by_file):
    lock_decls, cv_decls = collect_decls(sources)
    resolve_lock = _resolver(lock_decls)
    resolve_cv = _resolver(cv_decls)

    # bare fn name -> [FnInfo]; also whether each named fn is a method
    # (takes self) so `.name(` only resolves to methods and `name(` /
    # `path::name(` only to free fns — this keeps e.g. `engine.run(..)`
    # (a &self method) from conflating with free `apps::mm::run(..)`.
    fn_names = set()
    for sf in sources:
        for fn in fns_by_file[sf.rel]:
            fn_names.add(fn.name)
    infos = build_fn_infos(sources, fns_by_file, resolve_lock, resolve_cv, fn_names)

    by_name = defaultdict(list)
    for info in infos:
        by_name[info.fn.name].append(info)
        sf = info.sf
        # method-ness: `self` in the parameter list right after the name
        sig_start = sf.flat.find("(", sf.flat.rfind("fn", 0, info.fn.body_start))
        sig = sf.flat[sig_start:sf.flat.find(")", sig_start) + 1] if sig_start != -1 else ""
        info.is_method = bool(re.search(r"(?:^|[(&\s])(?:mut\s+)?self\b", sig))

    def callees(info):
        out = []
        for name, off, is_method in info.calls:
            for cal in by_name.get(name, []):
                if cal is info:
                    continue
                if is_method == cal.is_method:
                    out.append((cal, name, off))
        return out

    # -- fixpoints: transitive locks_used and marker reachability
    for info in infos:
        info.marker_reach = {m for m, _ in info.markers}
    changed = True
    while changed:
        changed = False
        for info in infos:
            for cal, _, _ in callees(info):
                if not cal.locks_used <= info.locks_used:
                    info.locks_used |= cal.locks_used
                    changed = True
                if not cal.marker_reach <= info.marker_reach:
                    info.marker_reach |= cal.marker_reach
                    changed = True

    findings = []
    # Edges of the acquired-while-held graph: lock A -> lock B with the
    # site where B was acquired (or the call through which it will be).
    edges = defaultdict(list)  # (A, B) -> [(rel, line, how, b_blocking)]
    ever_blocking = defaultdict(bool)
    for info in infos:
        for lock, _, blocking in info.acquisitions:
            ever_blocking[lock] |= blocking

    for info in infos:
        sf, fn = info.sf, info.fn

        def held_at(off):
            return {iv.lock for iv in info.intervals if iv.start < off <= iv.end}

        for lock, off, blocking in info.acquisitions:
            for held in held_at(off):
                if held != lock:
                    edges[(held, lock)].append(
                        (sf.rel, line_of(sf.stripped, off), "acquired directly", blocking)
                    )
        for cal, name, off in callees(info):
            held = held_at(off)
            if not held:
                continue
            for lock in cal.locks_used:
                if lock not in held:
                    for h in held:
                        edges[(h, lock)].append(
                            (sf.rel, line_of(sf.stripped, off),
                             "via call to %s()" % name, ever_blocking[lock])
                        )

        # RACE-002: other locks held across a condvar wait
        for cv, off, guarded in info.waits:
            if guarded is None:
                continue
            for h in held_at(off):
                if h != guarded:
                    findings.append(Finding(
                        "RACE-002", sf.rel, line_of(sf.stripped, off),
                        "lock `%s` held across `%s` wait (which guards `%s`) — "
                        "the sleeping thread keeps `%s` locked for the whole wait"
                        % (h, cv, guarded, h),
                        _src_line(sf, line_of(sf.stripped, off)),
                    ))

        # RACE-003: blocking guard held across a long/blocking call
        seen = set()
        for iv in info.intervals:
            if not iv.blocking:
                continue
            for mname, off in info.markers:
                if iv.start < off <= iv.end:
                    key = (iv.lock, off)
                    if key in seen:
                        continue
                    seen.add(key)
                    findings.append(Finding(
                        "RACE-003", sf.rel, line_of(sf.stripped, off),
                        "lock `%s` held across %s — blocking/long call under a lock"
                        % (iv.lock, mname),
                        _src_line(sf, line_of(sf.stripped, off)),
                    ))
            for cal, name, off in callees(info):
                if iv.start < off <= iv.end and cal.marker_reach:
                    key = (iv.lock, off, name)
                    if key in seen:
                        continue
                    seen.add(key)
                    findings.append(Finding(
                        "RACE-003", sf.rel, line_of(sf.stripped, off),
                        "lock `%s` held across call to %s() which reaches %s"
                        % (iv.lock, name, sorted(cal.marker_reach)[0]),
                        _src_line(sf, line_of(sf.stripped, off)),
                    ))

    # RACE-001: cycles among blocking edges
    adj = defaultdict(set)
    for (a, b), sites in edges.items():
        if any(blk for (_, _, _, blk) in sites):
            adj[a].add(b)
    for cyc in _cycles(adj):
        sites = []
        for a, b in zip(cyc, cyc[1:] + cyc[:1]):
            rel, line, how, _ = sorted(edges[(a, b)])[0]
            sites.append("%s:%d (%s -> %s, %s)" % (rel, line, a, b, how))
        rel0, line0 = sorted(edges[(cyc[0], cyc[1 % len(cyc)])])[0][:2]
        findings.append(Finding(
            "RACE-001", rel0, line0,
            "potential deadlock: lock-order cycle %s; edges: %s"
            % (" -> ".join(cyc + [cyc[0]]), "; ".join(sites)),
            "",
        ))
    return findings


def _src_line(sf, line):
    return sf.src_lines[line - 1] if 0 < line <= len(sf.src_lines) else ""


def _cycles(adj):
    """Elementary cycles, canonicalized (rotated to the smallest node,
    deduped, sorted) — the graphs here are tiny, so a simple DFS per
    strongly-connected component is plenty."""
    # Tarjan SCCs, iteratively.
    index, low, on, stack, sccs = {}, {}, set(), [], []
    counter = [0]

    def strongconnect(v):
        work = [(v, iter(sorted(adj.get(v, ()))))]
        index[v] = low[v] = counter[0]
        counter[0] += 1
        stack.append(v)
        on.add(v)
        while work:
            node, it = work[-1]
            advanced = False
            for w in it:
                if w not in index:
                    index[w] = low[w] = counter[0]
                    counter[0] += 1
                    stack.append(w)
                    on.add(w)
                    work.append((w, iter(sorted(adj.get(w, ())))))
                    advanced = True
                    break
                elif w in on:
                    low[node] = min(low[node], index[w])
            if advanced:
                continue
            work.pop()
            if work:
                parent = work[-1][0]
                low[parent] = min(low[parent], low[node])
            if low[node] == index[node]:
                scc = []
                while True:
                    w = stack.pop()
                    on.discard(w)
                    scc.append(w)
                    if w == node:
                        break
                sccs.append(scc)

    nodes = set(adj) | {b for bs in adj.values() for b in bs}
    for v in sorted(nodes):
        if v not in index:
            strongconnect(v)

    out = []
    for scc in sccs:
        members = set(scc)
        if len(scc) == 1:
            v = scc[0]
            if v in adj.get(v, ()):
                out.append([v])
            continue
        # one representative cycle through the SCC: walk greedily from
        # the smallest node until it closes.
        start = min(scc)
        path, seen = [start], {start}
        node = start
        while True:
            nxts = sorted(n for n in adj.get(node, ()) if n in members)
            nxt = next((n for n in nxts if n == start), None)
            if nxt is None:
                nxt = next((n for n in nxts if n not in seen), None)
            if nxt is None or nxt == start:
                break
            path.append(nxt)
            seen.add(nxt)
            node = nxt
        out.append(path)
    return sorted(out)
