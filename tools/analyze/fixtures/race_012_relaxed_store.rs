// expect: RACE-012
// A Relaxed *store* used as a publication flag: nothing orders the
// writes that happened before it, so a reader that sees `true` may
// still read stale data. Publication needs Release (paired with an
// Acquire load).

use std::sync::atomic::{AtomicBool, Ordering};

fn publish(ready: &AtomicBool) {
    ready.store(true, Ordering::Relaxed);
}
