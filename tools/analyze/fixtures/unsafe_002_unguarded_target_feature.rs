// expect: UNSAFE-002
// A #[target_feature] kernel called from a wrapper that never checks
// is_x86_feature_detected! (and calls no guard fn): executing the AVX2
// instruction on a CPU without the feature is immediate UB (SIGILL at
// best).

/// # Safety
/// Caller must ensure AVX2 is available on the executing CPU.
#[target_feature(enable = "avx2")]
unsafe fn kernel(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        *x += 1.0;
    }
}

pub fn wrapper(xs: &mut [f32]) {
    // SAFETY: slice is valid — but nothing established AVX2 support,
    // which is exactly what this fixture is about.
    unsafe { kernel(xs) }
}
