// expect: RACE-011
// A bare Mutex local moved into a spawned thread: the lock is now
// private to that thread — nothing else can ever contend it, and the
// state it "guards" is lost when the thread exits. Share it with
// Arc::new(Mutex::new(..)) instead.

use std::sync::Mutex;

fn spawn_with_private_lock() {
    let shared = Mutex::new(0u32);
    std::thread::spawn(move || {
        let mut g = shared.lock().unwrap();
        *g += 1;
    });
}
