// expect: UNSAFE-001
// An unsafe block with no SAFETY comment: the invariant that makes the
// raw-pointer read sound lives only in the author's head.

fn read_first(xs: &[f32]) -> f32 {
    unsafe { *xs.as_ptr() }
}
