// expect: RACE-003
// A blocking guard held across Backend::execute_batch — the whole
// micro-batch's device time serializes every other taker of `cache`
// behind this one dispatch.

use std::sync::Mutex;

struct Worker {
    cache: Mutex<u32>,
}

fn dispatch_under_lock(w: &Worker, rt: &Runtime, jobs: &JobSet) {
    let guard = w.cache.lock().unwrap();
    let _results = rt.execute_batch(jobs);
    drop(guard);
}
