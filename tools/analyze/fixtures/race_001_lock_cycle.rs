// expect: RACE-001
// The seeded deadlock: two coordinator-style functions acquire the
// same pair of locks in opposite orders — submit_path holds `alpha`
// while (through drain_queue) taking `beta`; report_path holds `beta`
// while taking `alpha`. The analyzer must stitch the inter-procedural
// edge alpha -> beta through the call graph and close the cycle.

use std::sync::Mutex;

struct Coordinator {
    alpha: Mutex<u32>,
    beta: Mutex<u32>,
}

fn submit_path(c: &Coordinator) {
    let a = c.alpha.lock().unwrap();
    drain_queue(c);
    drop(a);
}

fn drain_queue(c: &Coordinator) {
    let b = c.beta.lock().unwrap();
    let _ = *b + 1;
}

fn report_path(c: &Coordinator) -> u32 {
    let b = c.beta.lock().unwrap();
    let a = c.alpha.lock().unwrap();
    *b + *a
}
