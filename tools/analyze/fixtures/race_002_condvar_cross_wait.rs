// expect: RACE-002
// A thread parks on `not_empty` (which guards `state`) while still
// holding `stats` — every other taker of `stats` now blocks for the
// whole wait, and if the waker needs `stats` to signal, nobody ever
// wakes.

use std::sync::{Condvar, Mutex};

struct Shard {
    state: Mutex<u32>,
    stats: Mutex<u32>,
    not_empty: Condvar,
}

fn wait_holding_extra(sh: &Shard) {
    let held = sh.stats.lock().unwrap();
    let mut st = sh.state.lock().unwrap();
    while *st == 0 {
        st = sh.not_empty.wait(st).unwrap();
    }
    drop(st);
    drop(held);
}
