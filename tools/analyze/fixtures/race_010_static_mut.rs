// expect: RACE-010
// A `static mut` global: every access is an unsynchronized data race
// waiting to happen (and unsafe to even touch). Use an atomic, a
// Mutex, or OnceLock.

static mut DISPATCH_COUNT: u64 = 0;

pub fn noop() {}
