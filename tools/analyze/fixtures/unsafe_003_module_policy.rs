// expect: UNSAFE-003
// Perfectly documented unsafe — in a module nobody vetted for unsafe.
// The module policy is the point: unsafe stays corralled in the
// allowlisted files where reviewers know to look.

fn read_last(xs: &[i64]) -> i64 {
    // SAFETY: the caller guarantees xs is non-empty, so len() - 1 is a
    // valid in-bounds offset from the base pointer.
    unsafe { *xs.as_ptr().add(xs.len() - 1) }
}
