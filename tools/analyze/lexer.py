"""Rust micro-lexer for the concurrency analyzer.

Shares the philosophy (and the blanking technique) of the lexer in
``tools/verify.py`` but is deliberately standalone: the analyzer must
keep working even when verify.py's internals move, and it needs two
extra products the gate does not — a *flat* view of each file (newlines
replaced by spaces so regexes cross statement-wrapping line breaks while
offsets still map back to real lines) and per-function body extraction
with a brace-depth array for guard-lifetime tracking.

Everything downstream operates on ``stripped`` text: comments, string
literals, char literals and raw strings blanked with spaces (newlines
preserved, quote *delimiters* kept so a blanked argument can never read
as empty parens — `.join(", ")` must not look like `.join()`), then
every ``#[cfg(test)]``-gated block blanked the same way. The *original* source is kept alongside for the one pass that needs
comments — the SAFETY-comment audit.
"""

import os
import re
from collections import namedtuple

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

# A parsed source file. `stripped` has comments/strings/test blocks
# blanked; `flat` is `stripped` with newlines turned into spaces (same
# length, so any offset is valid in both); `src_lines` is the original
# source split into lines (for SAFETY-comment lookup and allowlist
# fragment matching).
SourceFile = namedtuple("SourceFile", "rel stripped flat src_lines")

# One function item: name, file, 1-based line of the `fn` keyword, and
# the [body_start, body_end) offsets of its brace-delimited body within
# the file's stripped text (body_start points *at* the opening brace).
Fn = namedtuple("Fn", "name rel line body_start body_end")


def _raw_string_at(src, i):
    m = re.match(r'(?:r|br)(#*)"', src[i:])
    if not m:
        return None
    if i > 0 and (src[i - 1].isalnum() or src[i - 1] == "_"):
        return None
    return (len(m.group(1)), i + m.end())


def strip_tokens(src):
    """Blank comments, string/char literals and raw strings (spaces for
    removed spans, newlines preserved). Lexical *errors* are not this
    tool's business — tools/verify.py gates them; here a malformed file
    simply yields best-effort blanked text."""
    out = []
    i, n = 0, len(src)
    while i < n:
        c = src[i]
        nxt = src[i + 1] if i + 1 < n else ""
        if c == "\n":
            out.append(c)
            i += 1
        elif c == "/" and nxt == "/":
            while i < n and src[i] != "\n":
                out.append(" ")
                i += 1
        elif c == "/" and nxt == "*":
            depth = 1
            out.append("  ")
            i += 2
            while i < n and depth:
                if src.startswith("/*", i):
                    depth += 1
                    out.append("  ")
                    i += 2
                elif src.startswith("*/", i):
                    depth -= 1
                    out.append("  ")
                    i += 2
                else:
                    out.append("\n" if src[i] == "\n" else " ")
                    i += 1
        elif c in "rb" and _raw_string_at(src, i):
            _, j = _raw_string_at(src, i)
            hashes = _raw_string_at(src, i)[0]
            close = '"' + "#" * hashes
            end = src.find(close, j)
            end = n if end == -1 else end + len(close)
            for k in range(i, end):
                if src[k] == "\n":
                    out.append("\n")
                elif k in (j - 1, end - 1 - hashes) and src[k] == '"':
                    out.append('"')
                else:
                    out.append(" ")
            i = end
        elif c == '"' or (c == "b" and nxt == '"'):
            start = i
            i += 2 if c == "b" else 1
            while i < n:
                if src[i] == "\\":
                    i += 2
                elif src[i] == '"':
                    i += 1
                    break
                else:
                    i += 1
            stop = min(i, n)
            for k in range(start, stop):
                if src[k] == "\n":
                    out.append("\n")
                elif src[k] == '"' and (k <= start + 1 or k == stop - 1):
                    out.append('"')
                else:
                    out.append(" ")
        elif c == "'":
            m = re.match(r"'(\\.[^']*|[^'\\])'", src[i:])
            if m:
                out.append("'" + " " * (m.end() - 2) + "'")
                i += m.end()
            else:
                out.append(c)
                i += 1
        else:
            out.append(c)
            i += 1
    return "".join(out)


def blank_test_blocks(stripped):
    """Blank the brace-matched block following every ``#[cfg(test)]``
    (same technique as tools/verify.py): test-only code must not feed
    the lock graph — tests intentionally poison mutexes, spawn bare
    threads, etc."""
    out = list(stripped)
    for m in re.finditer(r"#\s*\[\s*cfg\s*\(\s*test\s*\)\s*\]", stripped):
        i = stripped.find("{", m.end())
        if i == -1:
            continue
        depth, j = 0, i
        while j < len(stripped):
            if stripped[j] == "{":
                depth += 1
            elif stripped[j] == "}":
                depth -= 1
                if depth == 0:
                    break
            j += 1
        for k in range(i, min(j + 1, len(stripped))):
            if out[k] != "\n":
                out[k] = " "
    return "".join(out)


def parse_file(path, rel):
    """Read + lex one file into a SourceFile."""
    src = open(path, encoding="utf-8").read()
    stripped = blank_test_blocks(strip_tokens(src))
    flat = stripped.replace("\n", " ")
    return SourceFile(rel, stripped, flat, src.splitlines())


def line_of(text, offset):
    """1-based line number of `offset` in `text` (works on stripped or
    flat text interchangeably — they are the same length)."""
    return text.count("\n", 0, offset) + 1


_FN_RE = re.compile(
    r"(?:^|[^\w#])fn\s+([A-Za-z_]\w*)\s*(?:<[^>{};]*>)?\s*\(", re.S
)


def functions(sf):
    """Extract every `fn` item with a brace body from a SourceFile.

    Walks `fn NAME ... (` matches, skips the signature to the first `{`
    at signature level (not inside the parameter list or a where-clause
    bound's braces — Rust signatures cannot contain `{` before the body
    except in const generics, which this tree does not use), then brace-
    matches the body. Trait-method *declarations* (`fn f(...);`) have no
    body and are skipped.
    """
    out = []
    text = sf.stripped
    n = len(text)
    for m in _FN_RE.finditer(text):
        name = m.group(1)
        # find the parameter list's closing paren
        i = m.end() - 1  # at '('
        depth = 0
        while i < n:
            if text[i] == "(":
                depth += 1
            elif text[i] == ")":
                depth -= 1
                if depth == 0:
                    break
            i += 1
        # scan to body '{' or a ';' that ends a bodyless declaration
        j = i + 1
        while j < n and text[j] not in "{;":
            j += 1
        if j >= n or text[j] == ";":
            continue
        # brace-match the body
        depth, k = 0, j
        while k < n:
            if text[k] == "{":
                depth += 1
            elif text[k] == "}":
                depth -= 1
                if depth == 0:
                    break
            k += 1
        out.append(Fn(name, sf.rel, line_of(text, m.start(1)), j, min(k + 1, n)))
    return out


def depth_array(text, start, end):
    """Brace depth at every offset in [start, end), relative to `start`
    (depth *before* processing the character at that offset). Used to
    scope guard lifetimes to their enclosing block."""
    depths = [0] * (end - start)
    d = 0
    for i in range(start, end):
        depths[i - start] = d
        if text[i] == "{":
            d += 1
        elif text[i] == "}":
            d -= 1
    return depths


def rust_sources(root):
    """Every .rs file under `root` (absolute), sorted by relative path
    for deterministic output."""
    out = []
    for dirpath, _, files in os.walk(root):
        for f in files:
            if f.endswith(".rs"):
                full = os.path.join(dirpath, f)
                out.append((os.path.relpath(full, REPO).replace(os.sep, "/"), full))
    return sorted(out)
