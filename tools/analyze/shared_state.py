"""Shared-state pass: RACE-010/011/012.

RACE-010  `static mut` — mutable global state; every access is unsafe
          and unsynchronized by construction. Use an atomic, a
          `Mutex`, or `OnceLock`.
RACE-011  a bare `Mutex`/`RwLock`/`Condvar` local (not wrapped in
          `Arc::new` on the same binding) moved into a `thread::spawn`/
          `scope.spawn` closure — the "shared" lock becomes private to
          one thread, which is virtually always a bug (nothing else
          can ever contend it, and the state it guards is lost).
RACE-012  `Ordering::Relaxed` anywhere except a pure counter: allowed
          forms are `.load(Ordering::Relaxed)` and
          `.fetch_add/.fetch_sub(<integer literal>, Ordering::Relaxed)`.
          A Relaxed store/swap/CAS (or a data-dependent fetch) is a
          publication attempt with no ordering — use Acquire/Release
          (or SeqCst) instead.

Can prove: the textual pattern. Cannot prove: locks smuggled into
spawns through struct fields, or that a flagged Relaxed is benign on
x86 (it may be — the rule is about portable intent).
"""

import re

from . import Finding
from .lexer import line_of

STATIC_MUT_RE = re.compile(r"\bstatic\s+mut\b")
BARE_LOCK_LET = re.compile(
    r"let\s+(?:mut\s+)?(\w+)\s*(?::[^=;]+)?=\s*"
    r"(?:(?:std\s*::\s*)?sync\s*::\s*)?(Mutex|RwLock|Condvar)\s*::\s*new\s*\("
)
SPAWN_RE = re.compile(r"(?:\bthread\s*::\s*|\.\s*)spawn\s*\(")
RELAXED_RE = re.compile(r"Ordering\s*::\s*Relaxed")
RELAXED_OK = [
    re.compile(r"\.\s*load\s*\(\s*Ordering\s*::\s*Relaxed\s*\)"),
    re.compile(
        r"\.\s*fetch_(?:add|sub)\s*\(\s*\d+(?:_\w+)?\s*,\s*Ordering\s*::\s*Relaxed\s*\)"
    ),
]


def _balanced_paren_span(flat, open_idx, limit):
    depth, j = 0, open_idx
    while j < limit:
        if flat[j] == "(":
            depth += 1
        elif flat[j] == ")":
            depth -= 1
            if depth == 0:
                return j + 1
        j += 1
    return limit


def analyze(sources, fns_by_file):
    findings = []
    for sf in sources:
        # RACE-010: static mut anywhere in the file
        for m in STATIC_MUT_RE.finditer(sf.stripped):
            line = line_of(sf.stripped, m.start())
            findings.append(Finding(
                "RACE-010", sf.rel, line,
                "`static mut` global — unsynchronized mutable state; use an "
                "atomic, a Mutex, or OnceLock",
                _src(sf, line),
            ))

        # RACE-012: non-counter Relaxed orderings
        ok_spans = []
        for pat in RELAXED_OK:
            ok_spans += [(m.start(), m.end()) for m in pat.finditer(sf.flat)]
        for m in RELAXED_RE.finditer(sf.flat):
            if any(s <= m.start() < e for s, e in ok_spans):
                continue
            line = line_of(sf.stripped, m.start())
            findings.append(Finding(
                "RACE-012", sf.rel, line,
                "Ordering::Relaxed outside a pure counter (only "
                "`.load(Relaxed)` and `.fetch_add/sub(<literal>, Relaxed)` "
                "are counter-shaped) — publication needs Acquire/Release",
                _src(sf, line),
            ))

        # RACE-011: bare lock locals moved into spawn closures
        for fn in fns_by_file[sf.rel]:
            flat, bs, be = sf.flat, fn.body_start, fn.body_end
            bare = {}  # local name -> offset of its bare-lock binding
            for m in BARE_LOCK_LET.finditer(flat, bs, be):
                bare[m.group(1)] = m.start()
            if not bare:
                continue
            for m in SPAWN_RE.finditer(flat, bs, be):
                open_idx = m.end() - 1
                end = _balanced_paren_span(flat, open_idx, be)
                arg = flat[open_idx:end]
                if not re.search(r"\bmove\b", arg[:160]):
                    continue
                for name, decl_off in sorted(bare.items()):
                    if decl_off < m.start() and re.search(r"\b%s\b" % re.escape(name), arg):
                        line = line_of(sf.stripped, m.start())
                        findings.append(Finding(
                            "RACE-011", sf.rel, line,
                            "bare `%s` (a lock not wrapped in Arc) moved into "
                            "a spawned thread — the lock becomes private to "
                            "that thread; share it via Arc::new(..) instead"
                            % name,
                            _src(sf, line),
                        ))
    return findings


def _src(sf, line):
    return sf.src_lines[line - 1] if 0 < line <= len(sf.src_lines) else ""
