//! Property-based tests on the coordinator/framework invariants, using
//! the in-tree prop framework (util::prop — the offline stand-in for
//! proptest). Each property runs across a deterministic seed/size sweep
//! and shrinks failures to the smallest failing size.

use ea4rca::coordinator::scheduler::{ExecMode, GroupSpec, SimEngine};
use ea4rca::engine::compute::cc::{parse_cc, CcMode};
use ea4rca::engine::compute::dac::{Dac, DacMode};
use ea4rca::engine::compute::dcc::{Dcc, DccMode};
use ea4rca::engine::compute::pu::{ProcessingStructure, ProcessingUnit};
use ea4rca::engine::data::du::DataUnit;
use ea4rca::engine::data::ssc::SscMode;
use ea4rca::engine::data::tpc::{TaskBlock, TpcMode};
use ea4rca::sim::core::KernelClass;
use ea4rca::sim::ddr::AmcMode;
use ea4rca::sim::params::HwParams;
use ea4rca::util::json::Json;
use ea4rca::util::prop::{check, close, ensure, Config};
use ea4rca::util::rng::Rng;

/// Random-but-valid group spec generator.
fn arb_group(rng: &mut Rng, size: usize) -> GroupSpec {
    let pus = rng.range_usize(1, 6);
    let parallel = 1 << rng.range_usize(0, 3); // 1,2,4,8
    let cascade = rng.range_usize(1, 4);
    let cc = match (parallel, cascade) {
        (1, 1) => CcMode::Single,
        (1, c) => CcMode::Cascade(c.max(2)),
        (n, 1) => CcMode::Parallel(n, Box::new(CcMode::Single)),
        (n, c) => CcMode::Parallel(n, Box::new(CcMode::Cascade(c.max(2)))),
    };
    let cores = cc.cores();
    let in_plio = rng.range_usize(1, 4);
    let out_plio = rng.range_usize(1, 2);
    let in_bytes = rng.range_usize(1, 64) * 1024;
    let out_bytes = rng.range_usize(1, 16) * 1024;
    let pu = ProcessingUnit::simple(
        "arb",
        vec![ProcessingStructure {
            dacs: vec![Dac::new(vec![DacMode::Swh], in_plio, cores)],
            cc,
            dccs: vec![Dcc::new(DccMode::Swh, out_plio, cores)],
        }],
        KernelClass::F32Mac,
        (rng.range_usize(1, 64) * 65536) as f64,
        in_bytes,
        out_bytes,
    );
    let tb_iters = rng.range_usize(1, 9) as u64;
    GroupSpec {
        name: "g".into(),
        du: DataUnit {
            name: "du".into(),
            amc_read: Some([AmcMode::Csb, AmcMode::Jub][rng.range_usize(0, 1)]),
            amc_write: Some(AmcMode::Csb),
            tpc: TpcMode::Cup,
            ssc_send: [SscMode::Phd, SscMode::Shd][rng.range_usize(0, 1)],
            ssc_recv: SscMode::Phd,
            tb: TaskBlock::new(rng.range_usize(1, 32) * 65536, tb_iters, out_bytes * pus),
            pus,
        },
        pu,
        engine_iters: 4 + size as u64,
mode: ExecMode::Regular,
    }
}

#[test]
fn prop_makespan_monotonic_in_iterations() {
    let p = HwParams::vck5000();
    let engine = SimEngine::new(p);
    check(Config::default().cases(40), "makespan monotonic", |rng, size| {
        let mut g = arb_group(rng, size);
        g.validate().map_err(|e| format!("invalid group: {e}"))?;
        let a = engine.run(std::slice::from_ref(&g)).makespan_secs;
        g.engine_iters += 10;
        let b = engine.run(std::slice::from_ref(&g)).makespan_secs;
        ensure(b >= a, || format!("iters+10 shrank makespan: {a} -> {b}"))
    });
}

#[test]
fn prop_duty_bounded() {
    let p = HwParams::vck5000();
    let engine = SimEngine::new(p);
    check(Config::default().cases(40), "duty in (0,1]", |rng, size| {
        let g = arb_group(rng, size);
        let r = engine.run(&[g]);
        ensure(r.compute_duty > 0.0 && r.compute_duty <= 1.0, || {
            format!("duty {}", r.compute_duty)
        })
    });
}

#[test]
fn prop_shd_never_faster_than_phd() {
    let p = HwParams::vck5000();
    let engine = SimEngine::new(p);
    check(Config::default().cases(30), "SHD >= PHD", |rng, size| {
        let mut g = arb_group(rng, size);
        g.du.ssc_send = SscMode::Phd;
        let phd = engine.run(std::slice::from_ref(&g)).makespan_secs;
        g.du.ssc_send = SscMode::Shd;
        let shd = engine.run(std::slice::from_ref(&g)).makespan_secs;
        ensure(shd >= phd * 0.999, || format!("shd {shd} < phd {phd}"))
    });
}

#[test]
fn prop_adding_a_group_never_speeds_the_first() {
    let p = HwParams::vck5000();
    let engine = SimEngine::new(p);
    check(Config::default().cases(25), "DDR contention slows", |rng, size| {
        let g1 = arb_group(rng, size);
        let g2 = arb_group(rng, size);
        let solo = engine.run(std::slice::from_ref(&g1)).makespan_secs;
        let duo = engine.run(&[g1.clone(), g2]).makespan_secs;
        ensure(duo >= solo * 0.999, || format!("duo {duo} < solo {solo}"))
    });
}

#[test]
fn prop_total_work_conserved() {
    // makespan >= pure-compute lower bound (engine_iters x compute phase)
    let p = HwParams::vck5000();
    let engine = SimEngine::new(p.clone());
    check(Config::default().cases(40), "compute lower bound", |rng, size| {
        let g = arb_group(rng, size);
        let lb = g.engine_iters as f64 * g.pu.compute_secs(&p);
        let r = engine.run(&[g]);
        ensure(r.makespan_secs >= lb, || {
            format!("makespan {} < compute-only bound {lb}", r.makespan_secs)
        })
    });
}

#[test]
fn prop_json_roundtrip() {
    check(Config::default().cases(60), "json roundtrip", |rng, size| {
        let v = arb_json(rng, size.min(12));
        let text = v.to_string_pretty();
        let back = Json::parse(&text).map_err(|e| format!("reparse: {e}"))?;
        ensure(back == v, || format!("roundtrip mismatch: {text}"))
    });
}

fn arb_json(rng: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { rng.range_usize(0, 3) } else { rng.range_usize(0, 5) } {
        0 => Json::Null,
        1 => Json::Bool(rng.bool()),
        2 => Json::Num((rng.range_i64(-1_000_000, 1_000_000) as f64) / 4.0),
        3 => {
            let len = rng.range_usize(0, 12);
            Json::Str(
                (0..len)
                    .map(|_| {
                        let c = rng.range_usize(1, 126) as u8 as char;
                        c
                    })
                    .collect(),
            )
        }
        4 => Json::Arr((0..rng.range_usize(0, 4)).map(|_| arb_json(rng, depth - 1)).collect()),
        _ => Json::Obj(
            (0..rng.range_usize(0, 4))
                .map(|i| (format!("k{i}"), arb_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn prop_cc_parse_roundtrip() {
    check(Config::default().cases(60), "cc notation roundtrip", |rng, _| {
        let cc = match rng.range_usize(0, 3) {
            0 => CcMode::Single,
            1 => CcMode::Cascade(rng.range_usize(2, 16)),
            2 => CcMode::Butterfly { cores: 1 << rng.range_usize(1, 4) },
            _ => CcMode::Parallel(
                rng.range_usize(2, 16),
                Box::new(if rng.bool() {
                    CcMode::Single
                } else {
                    CcMode::Cascade(rng.range_usize(2, 8))
                }),
            ),
        };
        let back = parse_cc(&cc.to_string()).map_err(|e| e)?;
        ensure(back == cc, || format!("{cc} reparsed as {back}"))
    });
}

#[test]
fn prop_power_monotonic_in_duty() {
    use ea4rca::sim::memory::ResourceUsage;
    use ea4rca::sim::power::{estimate, PowerBreakdownInput};
    let p = HwParams::vck5000();
    check(Config::default().cases(40), "power monotonic in duty", |rng, _| {
        let cores = rng.range_usize(1, 400);
        let d1 = rng.f64();
        let d2 = (d1 + rng.f64() * (1.0 - d1)).min(1.0);
        let mk = |duty| {
            estimate(
                &p,
                &PowerBreakdownInput {
                    usage: ResourceUsage { aie: cores, ..Default::default() },
                    active_aie: cores,
                    compute_duty: duty,
                    class: KernelClass::F32Mac,
                    ddr_gbps: 0.0,
                    active_plio: 0,
                },
            )
            .total()
        };
        ensure(mk(d2) >= mk(d1), || format!("duty {d1}->{d2} lowered power"))
    });
}

// ---------------------------------------------------------------------
// serving-path properties: micro-batched execution is a pure
// throughput optimisation — results and reply routing never change
// ---------------------------------------------------------------------

/// Random inputs matching one artifact's manifest metadata.
fn arb_inputs(
    rng: &mut Rng,
    meta: &ea4rca::runtime::ArtifactMeta,
) -> Vec<ea4rca::runtime::Tensor> {
    use ea4rca::runtime::{DType, Tensor};
    meta.inputs
        .iter()
        .map(|tm| match tm.dtype {
            DType::F32 => Tensor::f32(&tm.shape, rng.normal_vec(tm.elements())),
            DType::I32 => Tensor::i32(&tm.shape, rng.int_vec_i32(tm.elements(), -64, 64)),
        })
        .collect()
}

#[test]
fn prop_execute_batch_is_elementwise_equivalent() {
    use ea4rca::runtime::{BackendKind, Manifest, Runtime, Tensor};
    let rt = Runtime::with_backend(BackendKind::Interp, Manifest::default_dir()).unwrap();
    // small artifacts from every kernel family the interpreter batches
    let artifacts = ["mm32", "mm32_acc", "mm32_i8", "filter2d_pu8", "fft1024"];
    check(Config::default().cases(15), "execute_batch == k * execute", |rng, size| {
        let name = artifacts[rng.range_usize(0, artifacts.len() - 1)];
        let meta = rt.manifest().get(name).map_err(|e| format!("{e:#}"))?.clone();
        let k = 1 + size.min(5);
        let jobs: Vec<Vec<Tensor>> = (0..k).map(|_| arb_inputs(rng, &meta)).collect();
        let batched = rt
            .execute_batch(name, &jobs)
            .map_err(|e| format!("batch dispatch failed: {e:#}"))?;
        ensure(batched.len() == k, || format!("{name}: {} results for {k} jobs", batched.len()))?;
        for (i, (job, got)) in jobs.iter().zip(batched).enumerate() {
            let got = got.map_err(|e| format!("{name} job {i}: {e:#}"))?;
            let want = rt.execute(name, job).map_err(|e| format!("{name} job {i}: {e:#}"))?;
            ensure(got.len() == want.len(), || format!("{name} job {i}: arity"))?;
            // exact, not within-tolerance: both paths run the same
            // prepared state (the fft plan is cached per artifact and
            // shared; the stacked matmul keeps matmul_ref's
            // accumulation order), so batching is bitwise invisible
            for (g, w) in got.iter().zip(&want) {
                ensure(g == w, || {
                    format!("{name} job {i}: batched vs single outputs differ")
                })?
            }
        }
        Ok(())
    });
}

#[test]
fn prop_batcher_never_reorders_a_clients_replies() {
    use ea4rca::coordinator::server::{Server, ServerConfig};
    use ea4rca::runtime::{BackendKind, Manifest, Tensor};
    // One client submitting a same-artifact sequence: reply i must
    // carry job i's result (no cross-wiring through batch formation),
    // for any batch/linger shape. The marker rides in A[0,0] with B
    // the identity, so C[0,0] recovers which job produced the output.
    check(Config::default().cases(8), "per-client reply order", |rng, size| {
        let config = ServerConfig {
            n_workers: 1,
            max_batch: 1 + rng.range_usize(0, 5),
            max_linger: std::time::Duration::from_micros(rng.range_usize(0, 500) as u64),
            queue_cap: 64,
        };
        let server = Server::start_with_config(
            BackendKind::Interp,
            config,
            Manifest::default_dir(),
            &["mm32"],
        )
        .map_err(|e| format!("start: {e:#}"))?;
        let k = 2 + size.min(14);
        let mut eye = vec![0.0f32; 32 * 32];
        for d in 0..32 {
            eye[d * 32 + d] = 1.0;
        }
        let mut pending = Vec::new();
        for i in 0..k {
            let mut a = vec![0.0f32; 32 * 32];
            a[0] = (i + 1) as f32;
            let inputs = vec![
                Tensor::f32(&[32, 32], a),
                Tensor::f32(&[32, 32], eye.clone()),
            ];
            let p = server
                .submit_timeout(
                    "mm32",
                    inputs,
                    std::time::Duration::from_secs(30),
                )
                .map_err(|e| format!("submit {i}: {e}"))?;
            pending.push(p);
        }
        for (i, p) in pending.into_iter().enumerate() {
            let r = p.wait().map_err(|e| format!("job {i}: {e:#}"))?;
            let out = r.outputs.map_err(|e| format!("job {i}: {e:#}"))?;
            let c00 = out[0].as_f32().map_err(|e| format!("{e:#}"))?[0];
            ensure(c00 == (i + 1) as f32, || {
                format!("reply {i} carries marker {c00} (expected {})", i + 1)
            })?;
        }
        let report = server.shutdown().map_err(|e| format!("shutdown: {e:#}"))?;
        ensure(report.total_jobs == k as u64, || {
            format!("accepted {} of {k}", report.total_jobs)
        })
    });
}

#[test]
fn prop_stats_summary_bounds() {
    use ea4rca::util::stats::summarize;
    check(Config::default().cases(50), "summary bounds", |rng, size| {
        let n = 1 + size;
        let xs: Vec<f64> = (0..n).map(|_| rng.f64() * 100.0).collect();
        let s = summarize(&xs);
        ensure(s.min <= s.p50 && s.p50 <= s.p95 && s.p95 <= s.max, || {
            format!("{s:?}")
        })?;
        ensure(s.mean >= s.min && s.mean <= s.max, || format!("{s:?}"))?;
        close(
            s.mean,
            xs.iter().sum::<f64>() / n as f64,
            1e-9,
        )
    });
}
