//! Kernel-tier parity suite: pins the contracts of the SIMD tier and
//! the worker-pool batch path against the scalar reference kernels
//! (DESIGN.md, "Kernel dispatch tiers").
//!
//! The contract table these tests enforce:
//!
//! * integer matmul, filter2d, FFT butterflies — **bitwise** equal
//!   across tiers (wrapping int32 arithmetic and lane-identical IEEE
//!   f64 ops don't care which register width computed them);
//! * f32 matmul family — **tolerance** contract: FMA fuses the
//!   multiply-add into one rounding, so the SIMD tier may differ from
//!   scalar by at most `2 * k * eps_f32 * sum_p |a_ip * b_pj|` per
//!   element (two accumulation paths, each within the classic k*eps
//!   forward bound of the exact product);
//! * pooled vs sequential micro-batches — **bitwise** equal within a
//!   tier, for every kernel family (the pool fans the same per-job
//!   kernel over disjoint output chunks; it never changes arithmetic).
//!
//! Every test here passes on any CPU: on hardware without AVX2+FMA the
//! SIMD wrappers decline and the tiered kernels fall back to scalar, so
//! the parity claims hold trivially — and CI additionally runs this
//! whole suite a second time with `EA4RCA_KERNEL_TIER=scalar` to drill
//! the forced-fallback path on SIMD-capable machines too.

use ea4rca::runtime::backend::interp::InterpBackend;
use ea4rca::runtime::backend::Backend;
use ea4rca::runtime::tensor::{
    fft_ref, filter2d_job_into, filter2d_ref, matmul_i32_job_into, matmul_i32_ref, matmul_ref,
    matmul_tiered, DType, FftPlan,
};
use ea4rca::runtime::{BackendKind, KernelTier, Manifest, Runtime, Tensor, TierConfig};
use ea4rca::util::rng::Rng;

/// Random inputs for one job of an artifact, straight from its
/// manifest shapes.
fn gen_job(meta: &ea4rca::runtime::manifest::ArtifactMeta, rng: &mut Rng) -> Vec<Tensor> {
    meta.inputs
        .iter()
        .map(|tm| match tm.dtype {
            DType::F32 => Tensor::f32(&tm.shape, rng.normal_vec(tm.elements())),
            DType::I32 => Tensor::i32(&tm.shape, rng.int_vec_i32(tm.elements(), -200, 200)),
        })
        .collect()
}

// ---------------------------------------------------------------------
// bitwise contracts: integer kernels and FFT butterflies
// ---------------------------------------------------------------------

#[test]
fn int_matmul_simd_is_bitwise_scalar() {
    let mut rng = Rng::new(901);
    // paper shapes plus ragged ones that exercise every SIMD tail lane
    for (m, k, n) in [(32, 32, 32), (32, 256, 32), (7, 13, 9), (5, 4, 33), (1, 1, 17)] {
        let a = rng.int_vec_i32(m * k, -30_000, 30_000);
        let b = rng.int_vec_i32(k * n, -30_000, 30_000);
        let want = matmul_i32_ref(&a, &b, m, k, n);
        let mut got = vec![0i32; m * n];
        matmul_i32_job_into(&a, &b, m, k, n, &mut got, KernelTier::Simd);
        assert_eq!(got, want, "int matmul {m}x{k}x{n} must be bitwise across tiers");
    }
}

#[test]
fn int_matmul_wrapping_is_tier_invariant() {
    // overflow territory: wrapping int32 accumulation is associative,
    // so even saturating-looking inputs stay bitwise equal across tiers
    let m = 8;
    let a = vec![i32::MAX; m * m];
    let b = vec![2; m * m];
    let want = matmul_i32_ref(&a, &b, m, m, m);
    let mut got = vec![0i32; m * m];
    matmul_i32_job_into(&a, &b, m, m, m, &mut got, KernelTier::Simd);
    assert_eq!(got, want);
}

#[test]
fn filter2d_simd_is_bitwise_scalar() {
    let mut rng = Rng::new(902);
    for (h, w, taps) in [(36, 36, 5), (16, 11, 3), (9, 9, 7), (5, 40, 5)] {
        let x = rng.int_vec_i32(h * w, -128, 127);
        let k = rng.int_vec_i32(taps * taps, -16, 16);
        let want = filter2d_ref(&x, h, w, &k, taps);
        let mut got = vec![0i32; (h - taps + 1) * (w - taps + 1)];
        filter2d_job_into(&x, h, w, &k, taps, &mut got, KernelTier::Simd);
        assert_eq!(got, want, "filter2d {h}x{w} taps={taps}");
    }
}

#[test]
fn fft_butterflies_are_bitwise_across_tiers() {
    let mut rng = Rng::new(903);
    // 8 exercises the len<4 stages that stay scalar in both tiers;
    // 1024/4096 are the paper's serving sizes
    for n in [8usize, 64, 1024, 4096] {
        let plan = FftPlan::new(n);
        let re = rng.normal_vec(n);
        let im = rng.normal_vec(n);
        let (sr, si) = plan.run_with_tier(&re, &im, KernelTier::Scalar);
        let (vr, vi) = plan.run_with_tier(&re, &im, KernelTier::Simd);
        // compare bit patterns, not float equality: the claim is that
        // the SIMD stage performs the identical IEEE op sequence
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&vr), bits(&sr), "fft{n} re");
        assert_eq!(bits(&vi), bits(&si), "fft{n} im");
        // and the scalar tier is exactly the plain run() path
        let (rr, ri) = plan.run(&re, &im);
        assert_eq!(bits(&rr), bits(&sr), "fft{n} run() re");
        assert_eq!(bits(&ri), bits(&si), "fft{n} run() im");
    }
}

#[test]
fn fft_simd_tier_still_matches_the_recursive_oracle() {
    let mut rng = Rng::new(904);
    let n = 2048;
    let plan = FftPlan::new(n);
    let re = rng.normal_vec(n);
    let im = rng.normal_vec(n);
    let (vr, vi) = plan.run_with_tier(&re, &im, KernelTier::Simd);
    let (wr, wi) = fft_ref(&re, &im);
    let err = vr
        .iter()
        .chain(&vi)
        .zip(wr.iter().chain(&wi))
        .map(|(a, b)| (a - b).abs() as f64)
        .fold(0.0, f64::max);
    assert!(err < 1e-4, "fft{n} vs oracle: max err {err}");
}

// ---------------------------------------------------------------------
// tolerance contract: the f32 matmul family
// ---------------------------------------------------------------------

#[test]
fn f32_matmul_scalar_tier_is_bitwise_reference() {
    let mut rng = Rng::new(905);
    let (m, k, n) = (32, 256, 32);
    let a = rng.normal_vec(m * k);
    let b = rng.normal_vec(k * n);
    let got = matmul_tiered(&a, &b, m, k, n, KernelTier::Scalar);
    let want = matmul_ref(&a, &b, m, k, n);
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&got), bits(&want));
}

#[test]
fn f32_matmul_simd_stays_inside_the_pinned_bound() {
    // the DESIGN.md contract, enforced where it is claimed: per output
    // element, |simd - scalar| <= 2 * k * eps_f32 * sum_p |a_ip * b_pj|
    // (each accumulation order is within the classic k*eps forward
    // bound of the exact dot product; FMA only tightens its side)
    let mut rng = Rng::new(906);
    for (m, k, n) in [(32, 32, 32), (128, 128, 128), (32, 256, 32)] {
        let a = rng.normal_vec(m * k);
        let b = rng.normal_vec(k * n);
        let simd = matmul_tiered(&a, &b, m, k, n, KernelTier::Simd);
        let scalar = matmul_ref(&a, &b, m, k, n);
        let eps = f32::EPSILON as f64;
        for i in 0..m {
            for j in 0..n {
                let mag: f64 = (0..k)
                    .map(|p| (a[i * k + p] as f64 * b[p * n + j] as f64).abs())
                    .sum();
                let bound = 2.0 * k as f64 * eps * mag;
                let diff = (simd[i * n + j] as f64 - scalar[i * n + j] as f64).abs();
                assert!(
                    diff <= bound,
                    "{m}x{k}x{n} [{i},{j}]: |simd-scalar| = {diff:e} exceeds bound {bound:e}"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------
// pool contract: pooled == sequential, bitwise, per tier, every family
// ---------------------------------------------------------------------

#[test]
fn pooled_batches_are_bitwise_sequential_in_both_tiers() {
    let manifest = Manifest::builtin("artifacts");
    // Simd here is a *request*: on CPUs without AVX2+FMA the kernels
    // decline and run scalar, which keeps the parity claim intact
    for tier in [KernelTier::Scalar, KernelTier::Simd] {
        let seq = InterpBackend::with_tiers(TierConfig { tier, pool_threads: 1 });
        let pooled = InterpBackend::with_tiers(TierConfig { tier, pool_threads: 4 });
        let mut rng = Rng::new(907);
        for name in
            ["mm32", "mm32_acc", "mm32_i8", "mm32_i16", "filter2d_pu8", "fft1024", "mm_pu128"]
        {
            let meta = manifest.get(name).unwrap();
            let jobs: Vec<Vec<Tensor>> = (0..6).map(|_| gen_job(meta, &mut rng)).collect();
            let a = seq.execute_batch(meta, &jobs).unwrap();
            let b = pooled.execute_batch(meta, &jobs).unwrap();
            assert_eq!(a, b, "{name} ({tier} tier): pooling must not change bits");
        }
        assert!(
            pooled.cache_stats().pooled_batches >= 1,
            "6-job batches must engage the pool"
        );
        assert_eq!(seq.cache_stats().pooled_batches, 0);
    }
}

#[test]
fn tiny_batches_bypass_the_pool() {
    let manifest = Manifest::builtin("artifacts");
    let pooled = InterpBackend::with_tiers(TierConfig {
        tier: KernelTier::Scalar,
        pool_threads: 8,
    });
    let mut rng = Rng::new(908);
    let meta = manifest.get("mm32").unwrap();
    let jobs: Vec<Vec<Tensor>> = (0..2).map(|_| gen_job(meta, &mut rng)).collect();
    pooled.execute_batch(meta, &jobs).unwrap();
    // 2 jobs < MIN_PARALLEL_JOBS: spawn/join would cost more than it
    // saves, so the dispatch must stay on the calling thread
    assert_eq!(pooled.cache_stats().pooled_batches, 0);
}

// ---------------------------------------------------------------------
// the fallback knob and the runtime-level surfaces
// ---------------------------------------------------------------------

#[test]
fn forced_scalar_knob_pins_the_tier_everywhere() {
    // the pure resolution rule behind EA4RCA_KERNEL_TIER=scalar (CI
    // runs this whole suite under the real env var as well)
    let cfg = TierConfig::resolve(Some("scalar"), Some("1"), true, 8).unwrap();
    assert_eq!(cfg, TierConfig::scalar());

    let b = InterpBackend::with_tiers(cfg);
    assert!(b.platform().contains("scalar tier"), "{}", b.platform());
    let manifest = Manifest::builtin("artifacts");
    let mut rng = Rng::new(909);
    for name in ["mm32", "fft1024", "filter2d_pu8"] {
        let meta = manifest.get(name).unwrap();
        b.execute(meta, &gen_job(meta, &mut rng)).unwrap();
        assert_eq!(b.kernel_tier(meta), Some(KernelTier::Scalar), "{name}");
    }
    let cs = b.cache_stats();
    assert_eq!((cs.scalar_artifacts, cs.simd_artifacts), (3, 0));
}

#[test]
fn forced_simd_without_hardware_fails_loudly_not_quietly() {
    let err = TierConfig::resolve(Some("simd"), None, false, 4).unwrap_err().to_string();
    assert!(err.contains("AVX2"), "{err}");
    // while auto on the same machine degrades gracefully
    let cfg = TierConfig::resolve(Some("auto"), None, false, 4).unwrap();
    assert_eq!(cfg.tier, KernelTier::Scalar);
}

#[test]
fn runtime_reports_the_serving_tier() {
    let rt = Runtime::with_backend(BackendKind::Interp, "target/ea4rca-no-artifacts-here")
        .unwrap();
    assert_eq!(rt.kernel_tier("mm32"), None, "unprepared artifacts carry no tier");
    let mut rng = Rng::new(910);
    let a = Tensor::f32(&[32, 32], rng.normal_vec(1024));
    let b = Tensor::f32(&[32, 32], rng.normal_vec(1024));
    rt.execute("mm32", &[a, b]).unwrap();
    let tier = rt.kernel_tier("mm32").expect("prepared artifact must report its tier");
    // the per-artifact exec stats carry the same tier for the report
    assert_eq!(rt.stats()["mm32"].tier, Some(tier));
    let cs = rt.cache_stats();
    assert_eq!(cs.simd_artifacts + cs.scalar_artifacts, cs.builds);
}

#[test]
fn runtime_batches_match_singles_bitwise_for_every_family() {
    // end to end through Runtime::execute_batch, under whatever tier
    // and pool width the environment resolved — batching and pooling
    // must be invisible to a client, bit for bit
    let rt = Runtime::with_backend(BackendKind::Interp, "target/ea4rca-no-artifacts-here")
        .unwrap();
    let oracle = Runtime::with_backend(BackendKind::Interp, "target/ea4rca-no-artifacts-here")
        .unwrap();
    let mut rng = Rng::new(911);
    for name in ["mm32", "mm32_acc", "mm32_i8", "mm32_i16", "filter2d_pu8", "fft1024"] {
        let meta_inputs = rt.manifest().get(name).unwrap().clone();
        let jobs: Vec<Vec<Tensor>> = (0..6).map(|_| gen_job(&meta_inputs, &mut rng)).collect();
        let batched = rt.execute_batch(name, &jobs).unwrap();
        for (j, job) in jobs.iter().enumerate() {
            let single = oracle.execute(name, job).unwrap();
            assert_eq!(batched[j].as_ref().unwrap(), &single, "{name} job {j}");
        }
    }
}
