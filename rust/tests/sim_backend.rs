//! The unified artifact pipeline, end to end: the sim backend must be
//! numerically invisible (bitwise identical to the interpreter, single
//! job and micro-batch) while attaching deterministic AIE cost
//! predictions to every dispatch, and `serve`-shaped runs over it must
//! carry predicted latency/energy on every `JobResult` with a
//! predicted-vs-measured ledger in the `ServeReport`.

use std::time::Duration;

use ea4rca::coordinator::server::{serve_batch, Server, ServerConfig};
use ea4rca::runtime::{BackendKind, Manifest, Runtime, Tensor};
use ea4rca::util::rng::Rng;
use ea4rca::workload::{generate_stream, reference_outputs, seeded_inputs, Mix, TaskKind};

fn runtimes() -> (Runtime, Runtime) {
    (
        Runtime::with_backend(BackendKind::Sim, Manifest::default_dir()).unwrap(),
        Runtime::with_backend(BackendKind::Interp, Manifest::default_dir()).unwrap(),
    )
}

fn seeded_jobs(artifact: &str, n: usize, seed: u64) -> Vec<Vec<Tensor>> {
    let rt = Runtime::with_backend(BackendKind::Interp, Manifest::default_dir()).unwrap();
    let meta = rt.manifest().get(artifact).unwrap().clone();
    let mut rng = Rng::new(seed);
    (0..n).map(|_| seeded_inputs(&meta, &mut rng)).collect()
}

/// Satellite: SimBackend numerics are bitwise identical to InterpBackend
/// for the mm/filter2d/fft families, single-job and micro-batched.
#[test]
fn sim_matches_interp_bitwise() {
    let (sim, interp) = runtimes();
    for artifact in ["mm_pu128", "mm32", "filter2d_pu8", "fft1024", "fft2048"] {
        let jobs = seeded_jobs(artifact, 4, 0xEA4);
        // single job
        for (j, job) in jobs.iter().enumerate() {
            let a = sim.execute(artifact, job).unwrap();
            let b = interp.execute(artifact, job).unwrap();
            assert_eq!(a, b, "{artifact} job {j}: sim != interp");
        }
        // micro-batch on both backends, and batch == sequential on sim
        let batched_sim: Vec<_> = sim
            .execute_batch(artifact, &jobs)
            .unwrap()
            .into_iter()
            .map(Result::unwrap)
            .collect();
        let batched_interp: Vec<_> = interp
            .execute_batch(artifact, &jobs)
            .unwrap()
            .into_iter()
            .map(Result::unwrap)
            .collect();
        assert_eq!(batched_sim, batched_interp, "{artifact}: batched sim != interp");
        for (j, job) in jobs.iter().enumerate() {
            assert_eq!(
                batched_sim[j],
                sim.execute(artifact, job).unwrap(),
                "{artifact} job {j}: batch != sequential under sim"
            );
        }
    }
}

/// Satellite: predictions exist for every serving artifact, are
/// deterministic across repeated queries AND across fresh runtimes, and
/// grow with batch size.
#[test]
fn predictions_deterministic_across_runs() {
    let (sim, interp) = runtimes();
    for artifact in ["mm_pu128", "filter2d_pu8", "fft1024", "mmt_cascade8"] {
        let p = sim.predict(artifact, 1).unwrap_or_else(|| panic!("{artifact}: no prediction"));
        assert!(p.latency_secs > 0.0, "{artifact}");
        assert!(p.energy_j > 0.0, "{artifact}");
        assert!(p.power_w > 0.0, "{artifact}");
        // repeated query: identical to the bit
        let again = sim.predict(artifact, 1).unwrap();
        assert_eq!(p, again, "{artifact}: prediction not stable");
        // a fresh runtime rebuilds the cost model to the same numbers
        let fresh = Runtime::with_backend(BackendKind::Sim, Manifest::default_dir())
            .unwrap()
            .predict(artifact, 1)
            .unwrap();
        assert_eq!(
            p.latency_secs.to_bits(),
            fresh.latency_secs.to_bits(),
            "{artifact}: prediction differs across runtimes"
        );
        assert_eq!(p.energy_j.to_bits(), fresh.energy_j.to_bits(), "{artifact}");
        // batches take longer than single jobs, but amortize per job
        let p8 = sim.predict(artifact, 8).unwrap();
        assert!(p8.latency_secs > p.latency_secs, "{artifact}");
        assert!(
            p8.per_job_secs() <= p.per_job_secs() * 1.001,
            "{artifact}: batching must not cost more per job"
        );
        // the measuring-only backend predicts nothing
        assert!(interp.predict(artifact, 1).is_none(), "{artifact}");
    }
}

/// Oracle comparison with the stress suite's discipline: int tensors
/// exact, f32 within 1e-4 (the oracle `fft_ref` is a different — equally
/// valid — evaluation order from the serving `FftPlan`; bitwise
/// batch==sequential is asserted separately in
/// [`sim_matches_interp_bitwise`]).
fn assert_matches_oracle(got: &[Tensor], want: &[Tensor], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: output arity");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.shape(), w.shape(), "{what} output {i}: shape");
        match (g, w) {
            (Tensor::I32 { .. }, Tensor::I32 { .. }) => {
                assert_eq!(g, w, "{what} output {i}: int mismatch");
            }
            _ => {
                let d = g.max_abs_diff(w).expect("comparable tensors");
                assert!(d < 1e-4, "{what} output {i}: max |err| {d}");
            }
        }
    }
}

/// Acceptance: a mixed mm/filter2d/fft stream served on `--backend sim`
/// completes with every JobResult carrying predicted latency/energy,
/// numerics matching the reference oracle, and the ServeReport carrying
/// a predicted-vs-measured ledger for every artifact.
#[test]
fn serve_sim_backend_end_to_end() {
    let config = ServerConfig {
        n_workers: 2,
        max_batch: 4,
        max_linger: Duration::from_micros(200),
        queue_cap: 256,
    };
    let server = Server::start_with_config(
        BackendKind::Sim,
        config,
        Manifest::default_dir(),
        &["mm_pu128", "fft1024", "filter2d_pu8"],
    )
    .unwrap();
    // a mixed mm/fft/filter2d stream with guaranteed per-kind coverage:
    // 16 of each, interleaved
    let mut stream = Vec::new();
    for (i, kind) in [TaskKind::MmBlock, TaskKind::Fft1024, TaskKind::FilterBatch]
        .into_iter()
        .enumerate()
    {
        stream.extend(generate_stream(&Mix::single(kind), 16, 21 + i as u64));
    }
    // interleave kinds so micro-batches form across a genuinely mixed queue
    let mut mixed = Vec::with_capacity(48);
    for j in 0..16 {
        for k in 0..3 {
            mixed.push(std::mem::replace(
                &mut stream[k * 16 + j],
                (TaskKind::MmBlock, Vec::new()),
            ));
        }
    }
    let oracle: Vec<(TaskKind, Vec<Tensor>)> = mixed
        .iter()
        .map(|(k, inputs)| (*k, reference_outputs(*k, inputs)))
        .collect();
    let jobs: Vec<(String, Vec<Tensor>)> = mixed
        .into_iter()
        .map(|(k, i)| (k.artifact().to_string(), i))
        .collect();
    let (results, _) = serve_batch(&server, jobs).unwrap();
    assert_eq!(results.len(), 48);
    for (i, r) in results.iter().enumerate() {
        let outs = r.outputs.as_ref().unwrap();
        assert_matches_oracle(outs, &oracle[i].1, &format!("job {i} ({:?})", oracle[i].0));
        // every result carries the cost model's view of its dispatch
        let p = r.predicted.as_ref().unwrap_or_else(|| panic!("job {i}: no prediction"));
        assert!(p.latency_secs > 0.0, "job {i}");
        assert!(p.energy_j > 0.0, "job {i}");
        assert_eq!(p.batch, r.batch_size, "job {i}: prediction covers its batch");
    }
    let report = server.shutdown().unwrap();
    assert_eq!(report.completed_jobs(), 48);
    let pvm = report.predicted_vs_measured();
    for artifact in ["mm_pu128", "fft1024", "filter2d_pu8"] {
        let lane = pvm.get(artifact).unwrap_or_else(|| panic!("{artifact} missing"));
        assert_eq!(lane.predicted_batches, lane.batches, "{artifact}: every batch predicted");
        assert!(lane.predicted_exec_secs > 0.0, "{artifact}");
        assert!(lane.measured_exec_secs > 0.0, "{artifact}");
        assert!(lane.ratio().is_some(), "{artifact}");
    }
    // conservation: the ledger's job mass equals the served jobs
    let ledger_jobs: u64 = pvm.values().map(|s| s.jobs).sum();
    assert_eq!(ledger_jobs, 48);
}

/// The interpreter serving path is unchanged: no predictions, but the
/// ledger still carries measured costs.
#[test]
fn serve_interp_backend_predicts_nothing() {
    let server = Server::start_with_backend(
        BackendKind::Interp,
        2,
        Manifest::default_dir(),
        &["fft1024"],
    )
    .unwrap();
    let jobs: Vec<(String, Vec<Tensor>)> =
        generate_stream(&Mix::single(TaskKind::Fft1024), 12, 3)
            .into_iter()
            .map(|(k, i)| (k.artifact().to_string(), i))
            .collect();
    let (results, _) = serve_batch(&server, jobs).unwrap();
    assert!(results.iter().all(|r| r.outputs.is_ok()));
    assert!(results.iter().all(|r| r.predicted.is_none()));
    let report = server.shutdown().unwrap();
    let pvm = report.predicted_vs_measured();
    let lane = pvm.get("fft1024").unwrap();
    assert_eq!(lane.predicted_batches, 0);
    assert_eq!(lane.jobs, 12);
    assert!(lane.measured_exec_secs > 0.0);
    assert!(lane.ratio().is_none());
}

/// Cost-model-aware dispatch conserves work: a stream with wildly
/// different per-job costs (mm blocks vs tiny ffts) still lands every
/// job exactly once across the workers.
#[test]
fn cost_weighted_placement_conserves_jobs() {
    let config = ServerConfig {
        n_workers: 3,
        max_batch: 4,
        max_linger: Duration::from_micros(100),
        queue_cap: 256,
    };
    let server = Server::start_with_config(
        BackendKind::Sim,
        config,
        Manifest::default_dir(),
        &["mm_pu128", "fft1024"],
    )
    .unwrap();
    let jobs: Vec<(String, Vec<Tensor>)> = generate_stream(&Mix::mm_heavy(), 60, 17)
        .into_iter()
        .map(|(k, i)| (k.artifact().to_string(), i))
        .collect();
    let (results, _) = serve_batch(&server, jobs).unwrap();
    assert!(results.iter().all(|r| r.outputs.is_ok()));
    let report = server.shutdown().unwrap();
    assert_eq!(report.total_jobs, 60);
    assert_eq!(report.completed_jobs(), 60);
    let worker_jobs: u64 = report.workers.iter().map(|w| w.jobs).sum();
    assert_eq!(worker_jobs, 60);
    let hist_jobs: u64 = report
        .batch_hist
        .values()
        .flat_map(|h| h.iter().map(|(size, count)| *size as u64 * count))
        .sum();
    assert_eq!(hist_jobs, 60);
}
