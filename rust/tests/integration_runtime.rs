//! Integration tests over the runtime: every artifact executes and its
//! numerics match the rust-side oracles.
//!
//! These run on whatever backend `Runtime::new` selects —
//! the interpreter by default (always available, built-in manifest), or
//! PJRT with `EA4RCA_BACKEND=pjrt` on a `--features pjrt` build after
//! `make artifacts`. The assertions are backend-agnostic on purpose:
//! this is the contract any substrate must meet.

use ea4rca::apps::{fft, filter2d, mm, mmt};
use ea4rca::runtime::tensor::{fft_ref, filter2d_ref, matmul_ref};
use ea4rca::runtime::{Runtime, Tensor};
use ea4rca::util::rng::Rng;

fn runtime_or_skip() -> Option<Runtime> {
    // The default (interpreter) backend always constructs; an explicitly
    // requested PJRT backend may be unavailable — then these tests skip.
    match Runtime::new() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP: runtime unavailable ({e})");
            None
        }
    }
}

#[test]
fn default_runtime_is_always_available() {
    // guards the hermetic-build guarantee: no artifacts, no native libs,
    // and the runtime still comes up (on the interpreter)
    if std::env::var("EA4RCA_BACKEND").unwrap_or_default().is_empty() {
        Runtime::new().expect("default interpreter runtime must construct");
    }
}

fn max_err(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs() as f64).fold(0.0, f64::max)
}

#[test]
fn mm32_artifact_matches_oracle() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(1);
    let a = rng.normal_vec(1024);
    let b = rng.normal_vec(1024);
    let out = rt
        .execute(
            "mm32",
            &[Tensor::f32(&[32, 32], a.clone()), Tensor::f32(&[32, 32], b.clone())],
        )
        .unwrap();
    let want = matmul_ref(&a, &b, 32, 32, 32);
    assert!(max_err(out[0].as_f32().unwrap(), &want) < 1e-3);
}

#[test]
fn mm32_acc_artifact_is_cascade_stage() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(2);
    let a = rng.normal_vec(1024);
    let b = rng.normal_vec(1024);
    let acc = rng.normal_vec(1024);
    let out = rt
        .execute(
            "mm32_acc",
            &[
                Tensor::f32(&[32, 32], a.clone()),
                Tensor::f32(&[32, 32], b.clone()),
                Tensor::f32(&[32, 32], acc.clone()),
            ],
        )
        .unwrap();
    let mut want = matmul_ref(&a, &b, 32, 32, 32);
    for (w, c) in want.iter_mut().zip(&acc) {
        *w += c;
    }
    assert!(max_err(out[0].as_f32().unwrap(), &want) < 1e-3);
}

#[test]
fn mm_pu128_artifact_matches_oracle() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(3);
    let a = rng.normal_vec(128 * 128);
    let b = rng.normal_vec(128 * 128);
    let out = rt
        .execute(
            "mm_pu128",
            &[Tensor::f32(&[128, 128], a.clone()), Tensor::f32(&[128, 128], b.clone())],
        )
        .unwrap();
    let want = matmul_ref(&a, &b, 128, 128, 128);
    assert!(max_err(out[0].as_f32().unwrap(), &want) < 5e-3);
}

#[test]
fn mmt_cascade8_artifact_matches_oracle() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(4);
    let a = rng.normal_vec(32 * 256);
    let b = rng.normal_vec(256 * 32);
    let got = mmt::chain_via_pu(&rt, &a, &b).unwrap();
    let want = matmul_ref(&a, &b, 32, 256, 32);
    assert!(max_err(&got, &want) < 5e-3);
}

#[test]
fn filter2d_pu8_artifact_is_exact() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(5);
    let tiles = rng.int_vec_i32(8 * 36 * 36, -128, 127);
    let kern = rng.int_vec_i32(25, -16, 16);
    let out = rt
        .execute(
            "filter2d_pu8",
            &[
                Tensor::i32(&[8, 36, 36], tiles.clone()),
                Tensor::i32(&[5, 5], kern.clone()),
            ],
        )
        .unwrap();
    let got = out[0].as_i32().unwrap();
    for tile in 0..8 {
        let want = filter2d_ref(&tiles[tile * 36 * 36..(tile + 1) * 36 * 36], 36, 36, &kern, 5);
        assert_eq!(&got[tile * 1024..(tile + 1) * 1024], &want[..], "tile {tile}");
    }
}

#[test]
fn fft_artifacts_match_oracle() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(6);
    for n in [1024usize, 2048, 4096, 8192] {
        let re = rng.normal_vec(n);
        let im = rng.normal_vec(n);
        let (or_, oi) = fft::fft_via_pu(&rt, &re, &im).unwrap();
        let (wr, wi) = fft_ref(&re, &im);
        let tol = 1e-2 * (n as f64).sqrt();
        assert!(max_err(&or_, &wr) < tol, "re mismatch at n={n}");
        assert!(max_err(&oi, &wi) < tol, "im mismatch at n={n}");
    }
}

#[test]
fn whole_mm_task_through_pus() {
    // A full 256^3 MM through the DU decomposition + TPC accumulation.
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(7);
    let n = 256;
    let a = rng.normal_vec(n * n);
    let b = rng.normal_vec(n * n);
    let got = mm::matmul_via_pus(&rt, &a, &b, n).unwrap();
    let want = matmul_ref(&a, &b, n, n, n);
    assert!(max_err(&got, &want) < 2e-2);
}

#[test]
fn whole_filter2d_image_through_pus() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(8);
    let (h, w) = (64, 96);
    let img = rng.int_vec_i32((h + 4) * (w + 4), -100, 100);
    let kern = rng.int_vec_i32(25, -8, 8);
    let got = filter2d::filter_image_via_pus(&rt, &img, h, w, &kern).unwrap();
    let want = filter2d_ref(&img, h + 4, w + 4, &kern, 5);
    assert_eq!(got, want);
}

#[test]
fn ragged_mm_pads_and_crops() {
    // the adaptive-task-scale path: 130x70x200 through 128-block PUs
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(21);
    let (m, k, n) = (130, 70, 200);
    let a = rng.normal_vec(m * k);
    let b = rng.normal_vec(k * n);
    let got = mm::matmul_any(&rt, &a, &b, m, k, n).unwrap();
    let want = matmul_ref(&a, &b, m, k, n);
    assert_eq!(got.len(), m * n);
    assert!(max_err(&got, &want) < 1e-2);
}

#[test]
fn runtime_rejects_shape_mismatch() {
    let Some(rt) = runtime_or_skip() else { return };
    let bad = Tensor::f32(&[16, 16], vec![0.0; 256]);
    let err = rt.execute("mm32", &[bad.clone(), bad]).unwrap_err();
    assert!(err.to_string().contains("expected"), "{err}");
}

#[test]
fn runtime_rejects_wrong_arity() {
    let Some(rt) = runtime_or_skip() else { return };
    let t = Tensor::f32(&[32, 32], vec![0.0; 1024]);
    assert!(rt.execute("mm32", &[t]).is_err());
}

#[test]
fn runtime_rejects_unknown_artifact() {
    let Some(rt) = runtime_or_skip() else { return };
    assert!(rt.execute("nope", &[]).is_err());
}

#[test]
fn exec_stats_accumulate() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(9);
    let a = Tensor::f32(&[32, 32], rng.normal_vec(1024));
    let b = Tensor::f32(&[32, 32], rng.normal_vec(1024));
    for _ in 0..3 {
        rt.execute("mm32", &[a.clone(), b.clone()]).unwrap();
    }
    let stats = rt.stats();
    assert!(stats["mm32"].executions >= 3);
    assert!(rt.mean_exec_secs("mm32").unwrap() > 0.0);
}
