//! The cluster layer end to end: router smoke across shard counts with
//! every reply oracle-checked, cross-shard conservation accounting
//! (submitted == completed + shed, summed over shards), graceful drain
//! under load, readable undeployed-artifact rejection, and N=1 parity
//! with the legacy single-`Server` path.

use std::time::Duration;

use ea4rca::coordinator::router::{route_open_loop, ClusterConfig, RouteError, Router};
use ea4rca::coordinator::server::{Server, ServerConfig, SubmitError};
use ea4rca::runtime::{BackendKind, Manifest, Tensor};
use ea4rca::workload::{generate_stream, open_loop_stream, reference_outputs, Mix, TaskKind};

/// f32 comparison bound — same contract as the single-shard stress
/// suite: batched kernels match the reference accumulation order.
const TOL: f32 = 1e-4;

const ALL_ARTIFACTS: [&str; 4] = ["mm_pu128", "fft1024", "filter2d_pu8", "mmt_cascade8"];

fn assert_tensors_match(got: &[Tensor], want: &[Tensor], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: output arity");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.shape(), w.shape(), "{what} output {i}: shape");
        match (g, w) {
            (Tensor::I32 { .. }, Tensor::I32 { .. }) => {
                assert_eq!(g, w, "{what} output {i}: int mismatch");
            }
            _ => {
                let d = g.max_abs_diff(w).expect("comparable tensors");
                assert!(d < TOL as f64, "{what} output {i}: max |err| {d}");
            }
        }
    }
}

fn cluster_config(shards: usize, workers: usize, queue_cap: usize) -> ClusterConfig {
    ClusterConfig {
        shards,
        shard: ServerConfig {
            n_workers: workers,
            max_batch: 8,
            max_linger: Duration::from_micros(200),
            queue_cap,
        },
    }
}

/// Router smoke at N=2 and N=4: a mixed stream, every reply matched
/// against the `tensor::*_ref` oracles, and full conservation in the
/// merged cluster report.
#[test]
fn router_smoke_mixed_stream_oracle_match() {
    let n_jobs = if cfg!(debug_assertions) { 120 } else { 400 };
    for shards in [2usize, 4] {
        let router = Router::start(
            BackendKind::Interp,
            cluster_config(shards, 2, 128),
            Manifest::default_dir(),
            &ALL_ARTIFACTS,
        )
        .expect("router start");
        assert_eq!(router.shards(), shards);
        assert_eq!(router.live_shards(), shards);
        assert_eq!(router.workers(), shards * 2);

        // Oracles are computed BEFORE the first submit so the submit
        // loop is queue pushes only. With the reference computation
        // inline, arrivals run at the service rate, shard backlogs stay
        // near zero, and a near-idle router legally concentrates
        // placement on one shard (lowest cost hint, ties to the lowest
        // id) — the spread assertion below would then fail on a fast
        // machine. A tight burst keeps backlog non-zero from the
        // second submit on, so cost-weighted placement must spread.
        let stream: Vec<(TaskKind, Vec<Tensor>, Vec<Tensor>)> =
            generate_stream(&Mix::uniform(), n_jobs, 17)
                .into_iter()
                .map(|(kind, inputs)| {
                    let want = reference_outputs(kind, &inputs);
                    (kind, inputs, want)
                })
                .collect();
        let mut pending = Vec::with_capacity(n_jobs);
        let mut oracles = Vec::with_capacity(n_jobs);
        for (kind, inputs, want) in stream {
            oracles.push((kind, want));
            pending.push(router.submit(kind.artifact(), inputs).expect("submit"));
        }

        let mut shard_seen = vec![0u64; shards];
        for (i, (p, (kind, want))) in pending.into_iter().zip(&oracles).enumerate() {
            let result = p.wait().expect("reply");
            assert!(result.shard < shards, "job {i}: bogus shard id {}", result.shard);
            shard_seen[result.shard] += 1;
            let outputs = result
                .outputs
                .unwrap_or_else(|e| panic!("{shards}-shard job {i} ({kind:?}) failed: {e:#}"));
            assert_tensors_match(&outputs, want, &format!("{shards}-shard job {i} ({kind:?})"));
        }
        // a burst this size must overflow one shard's cheap slot: the
        // cost-weighted placement has to spread it
        assert!(
            shard_seen.iter().filter(|&&n| n > 0).count() >= 2,
            "{shards}-shard burst never left shard 0: {shard_seen:?}"
        );

        let report = router.shutdown().expect("shutdown");
        // conservation, cluster-wide and per shard
        assert_eq!(report.total_jobs, n_jobs as u64, "{shards} shards: accepted");
        assert_eq!(report.completed_jobs(), n_jobs as u64, "{shards} shards: completed");
        assert_eq!(report.shards.len(), shards);
        for (s, seen) in report.shards.iter().zip(&shard_seen) {
            assert_eq!(s.jobs, *seen, "shard {}: accepted vs replies seen", s.shard);
            assert_eq!(s.completed, *seen, "shard {}: completed vs replies seen", s.shard);
        }
        let by_shard: u64 = report.shards.iter().map(|s| s.jobs).sum();
        assert_eq!(by_shard, n_jobs as u64, "{shards} shards: per-shard sum");
        let hist_jobs: u64 = report
            .batch_hist
            .values()
            .flat_map(|h| h.iter().map(|(size, count)| *size as u64 * count))
            .sum();
        assert_eq!(hist_jobs, n_jobs as u64, "{shards} shards: histogram mass");
    }
}

/// Open-loop overload across 2 shards: offered == completed + shed,
/// summed over shards, and the stream id rides through to the report.
#[test]
fn cross_shard_conservation_under_shedding() {
    let n_jobs = if cfg!(debug_assertions) { 200 } else { 400 };
    let router = Router::start(
        BackendKind::Interp,
        cluster_config(2, 1, 4),
        Manifest::default_dir(),
        &["mmt_cascade8"],
    )
    .expect("router start");

    // a burst far beyond 2x1 workers with queue_cap 4: the cluster must
    // shed rather than stall the arrival clock
    let seed = 23u64;
    let arrivals = open_loop_stream(&Mix::single(TaskKind::MmtChain), n_jobs, seed, 50_000.0)
        .into_iter()
        .map(|a| (a.at_secs, a.kind.artifact().to_string(), a.stream, a.inputs));
    let (results, shed) = route_open_loop(&router, arrivals).expect("open loop");

    assert_eq!(results.len() as u64 + shed, n_jobs as u64, "offered = completed + shed");
    assert!(shed > 0, "a {n_jobs}-job burst against 2 queues of 4 must shed");
    for r in &results {
        assert!(r.shard < 2);
        assert_eq!(r.stream, seed, "stream id must ride through to the result");
        assert!(r.outputs.is_ok());
    }

    let report = router.shutdown().expect("shutdown");
    // shed jobs never entered any shard: accepted == completed == the
    // replies we hold, summed over shards
    assert_eq!(report.total_jobs, results.len() as u64);
    assert_eq!(report.completed_jobs(), results.len() as u64);
    let by_shard: u64 = report.shards.iter().map(|s| s.jobs).sum();
    assert_eq!(by_shard, results.len() as u64);
    // per-stream attribution survives the cross-shard merge
    assert_eq!(report.jobs_per_stream()[&seed], results.len() as u64);
}

/// Draining one shard under load keeps every already-admitted job's
/// reply, while the rest of the cluster keeps serving; the drained
/// ledger folds into the final merged report.
#[test]
fn drain_under_load_keeps_admitted_results() {
    let n_before = if cfg!(debug_assertions) { 60 } else { 160 };
    let mut router = Router::start(
        BackendKind::Interp,
        cluster_config(2, 1, 256),
        Manifest::default_dir(),
        &ALL_ARTIFACTS,
    )
    .expect("router start");

    let mut pending = Vec::new();
    let mut oracles = Vec::new();
    for (kind, inputs) in generate_stream(&Mix::uniform(), n_before, 41) {
        oracles.push((kind, reference_outputs(kind, &inputs)));
        pending.push(router.submit(kind.artifact(), inputs).expect("submit"));
    }

    // drain shard 0 mid-burst: stop admitting there, flush its queue,
    // join its threads — jobs it admitted keep their replies
    let drained = router.drain(0).expect("drain shard 0");
    assert_eq!(drained.shard, 0);
    assert_eq!(drained.completed_jobs(), drained.total_jobs, "drained shard flushed");
    assert_eq!(router.live_shards(), 1);
    // a second drain of the same shard is a readable error, not a hang
    let err = router.drain(0).unwrap_err().to_string();
    assert!(err.contains("shard 0"), "{err}");

    // the cluster keeps serving on the surviving shard
    let mut rng = ea4rca::util::rng::Rng::new(5);
    let inputs = TaskKind::MmBlock.gen_inputs(&mut rng);
    let want = reference_outputs(TaskKind::MmBlock, &inputs);
    let after = router.submit("mm_pu128", inputs).expect("post-drain submit");
    let r = after.wait().expect("post-drain reply");
    assert_eq!(r.shard, 1, "post-drain work must land on the live shard");
    assert_tensors_match(&r.outputs.expect("post-drain job ok"), &want, "post-drain mm");

    // every pre-drain job still gets its oracle-matched reply
    let mut completed = 0u64;
    for (i, (p, (kind, want))) in pending.into_iter().zip(&oracles).enumerate() {
        let result = p.wait().expect("pre-drain reply");
        completed += 1;
        let outputs = result
            .outputs
            .unwrap_or_else(|e| panic!("pre-drain job {i} ({kind:?}) failed: {e:#}"));
        assert_tensors_match(&outputs, want, &format!("pre-drain job {i} ({kind:?})"));
    }
    assert_eq!(completed, n_before as u64);

    // the merged report folds the retired shard's ledger back in
    let report = router.shutdown().expect("shutdown");
    assert_eq!(report.shards.len(), 2, "retired shard 0 must appear in the merge");
    assert_eq!(report.shards[0].shard, 0);
    assert_eq!(report.shards[0].jobs, drained.total_jobs);
    assert_eq!(report.total_jobs, n_before as u64 + 1);
    assert_eq!(report.completed_jobs(), n_before as u64 + 1);
}

/// Placement maps are enforced: an artifact deployed on no shard is a
/// readable rejection up front, and deployed artifacts route only to
/// their shards.
#[test]
fn undeployed_artifact_is_rejected_readably() {
    let router = Router::start_with_placement(
        BackendKind::Interp,
        cluster_config(2, 1, 64),
        Manifest::default_dir(),
        vec![vec!["mm_pu128".to_string()], vec!["fft1024".to_string()]],
        true,
    )
    .expect("router start");

    // deployed nowhere: rejected before any worker sees it
    let err = router.submit("filter2d_pu8", Vec::new()).unwrap_err();
    assert!(matches!(err, RouteError::Undeployed { .. }), "{err}");
    let msg = err.to_string();
    assert!(msg.contains("filter2d_pu8"), "{msg}");
    assert!(msg.contains("no shard"), "{msg}");
    assert!(msg.contains("mm_pu128") && msg.contains("fft1024"), "{msg}");

    // deployed artifacts land exactly on their shard
    let mut rng = ea4rca::util::rng::Rng::new(9);
    let mm = TaskKind::MmBlock.gen_inputs(&mut rng);
    let fft = TaskKind::Fft1024.gen_inputs(&mut rng);
    let r = router.submit("mm_pu128", mm).unwrap().wait().unwrap();
    assert_eq!(r.shard, 0, "mm_pu128 is deployed only on shard 0");
    assert!(r.outputs.is_ok());
    let r = router.submit("fft1024", fft).unwrap().wait().unwrap();
    assert_eq!(r.shard, 1, "fft1024 is deployed only on shard 1");
    assert!(r.outputs.is_ok());

    let report = router.shutdown().unwrap();
    assert_eq!(report.total_jobs, 2, "the rejected submit never counted");
    assert_eq!(report.shards[0].jobs, 1);
    assert_eq!(report.shards[1].jobs, 1);
}

/// The legacy `Server` and an N=1 `Router` are the same machine: same
/// stream, same config, same accounting, oracle-matched on both paths.
#[test]
fn n1_router_matches_legacy_server() {
    let n_jobs = if cfg!(debug_assertions) { 80 } else { 240 };
    let config = ServerConfig {
        n_workers: 2,
        max_batch: 8,
        max_linger: Duration::from_micros(200),
        queue_cap: 128,
    };

    let run_router = || -> (u64, u64) {
        let router = Router::start(
            BackendKind::Interp,
            ClusterConfig { shards: 1, shard: config.clone() },
            Manifest::default_dir(),
            &ALL_ARTIFACTS,
        )
        .unwrap();
        let mut pending = Vec::new();
        let mut oracles = Vec::new();
        for (kind, inputs) in generate_stream(&Mix::uniform(), n_jobs, 3) {
            oracles.push((kind, reference_outputs(kind, &inputs)));
            pending.push(router.submit(kind.artifact(), inputs).unwrap());
        }
        for (p, (kind, want)) in pending.into_iter().zip(&oracles) {
            let r = p.wait().unwrap();
            assert_eq!(r.shard, 0, "an N=1 cluster has only shard 0");
            assert_tensors_match(&r.outputs.unwrap(), want, &format!("router {kind:?}"));
        }
        let report = router.shutdown().unwrap();
        (report.total_jobs, report.completed_jobs())
    };

    let run_server = || -> (u64, u64) {
        let server = Server::start_with_config(
            BackendKind::Interp,
            config.clone(),
            Manifest::default_dir(),
            &ALL_ARTIFACTS,
        )
        .unwrap();
        let mut pending = Vec::new();
        let mut oracles = Vec::new();
        for (kind, inputs) in generate_stream(&Mix::uniform(), n_jobs, 3) {
            oracles.push((kind, reference_outputs(kind, &inputs)));
            pending.push(server.submit(kind.artifact(), inputs).unwrap());
        }
        for (p, (kind, want)) in pending.into_iter().zip(&oracles) {
            let r = p.wait().unwrap();
            assert_tensors_match(&r.outputs.unwrap(), want, &format!("server {kind:?}"));
        }
        let report = server.shutdown().unwrap();
        assert_eq!(report.shards.len(), 1, "the facade is the one-shard cluster");
        (report.total_jobs, report.completed_jobs())
    };

    let (router_accepted, router_completed) = run_router();
    let (server_accepted, server_completed) = run_server();
    assert_eq!(
        (router_accepted, router_completed),
        (server_accepted, server_completed),
        "N=1 router and legacy Server accounting"
    );
    assert_eq!(router_accepted, n_jobs as u64);
}

/// Regression for the smoke test's spread assertion: placement on an
/// idle cluster is driven by the cost books, so a slow-arrival stream
/// has no spread guarantee — the reason the smoke test submits in a
/// tight burst. Pin the deterministic core of that behaviour: with
/// *cold* books (no warm-up), every shard's cost hint is the same
/// floor, the first job tie-breaks to shard 0, and the next idle
/// submits prefer the still-unmeasured shards (whose hint stays at the
/// floor) over the one that now carries a real measured cost.
#[test]
fn idle_cold_cluster_placement_is_deterministic() {
    let router = Router::start(
        BackendKind::Interp,
        cluster_config(3, 1, 64),
        Manifest::default_dir(),
        &[], // no warm-up: every cost book starts empty
    )
    .expect("router start");
    let mut rng = ea4rca::util::rng::Rng::new(13);
    // submit one job at a time, waiting for each reply: the cluster is
    // idle again before every placement decision
    let mut seen = Vec::new();
    for _ in 0..3 {
        let inputs = TaskKind::MmBlock.gen_inputs(&mut rng);
        let r = router.submit("mm_pu128", inputs).unwrap().wait().unwrap();
        assert!(r.outputs.is_ok());
        seen.push(r.shard);
    }
    assert_eq!(
        seen,
        vec![0, 1, 2],
        "cold idle cluster must tie-break to shard 0, then explore unmeasured shards"
    );
    let report = router.shutdown().unwrap();
    for s in &report.shards {
        assert_eq!(s.jobs, 1, "shard {}: one idle-cluster job each", s.shard);
    }
}

/// Saturation spillover: when the cheapest shard's queue is full, a
/// non-blocking submit lands on the next eligible shard instead of
/// shedding — and a closed cluster reports `Closed`, not `Saturated`.
#[test]
fn try_submit_spills_before_shedding() {
    let router = Router::start(
        BackendKind::Interp,
        cluster_config(2, 1, 2),
        Manifest::default_dir(),
        &["mmt_cascade8"],
    )
    .expect("router start");
    let mut rng = ea4rca::util::rng::Rng::new(7);
    // far more than one queue (cap 2) holds: with spillover both shards
    // must end up carrying work before anything sheds
    let mut accepted = 0u64;
    let mut shed = 0u64;
    let mut pending = Vec::new();
    for _ in 0..64 {
        let inputs = TaskKind::MmtChain.gen_inputs(&mut rng);
        match router.try_submit("mmt_cascade8", inputs) {
            Ok(p) => {
                accepted += 1;
                pending.push(p);
            }
            Err(RouteError::Submit(SubmitError::Saturated)) => shed += 1,
            Err(e) => panic!("unexpected route error: {e}"),
        }
    }
    assert_eq!(accepted + shed, 64);
    for p in pending {
        assert!(p.wait().unwrap().outputs.is_ok());
    }
    let report = router.shutdown().unwrap();
    assert_eq!(report.total_jobs, accepted);
    if shed > 0 {
        // both queues had to fill before the first shed
        assert!(
            report.shards.iter().all(|s| s.jobs > 0),
            "shed with an idle shard: {:?}",
            report.shards
        );
    }
}
