//! Cross-module integration tests: the four accelerators on the full
//! framework stack (engine components -> scheduler -> controller ->
//! power), checking the paper's qualitative table shapes end to end.

use ea4rca::apps::{fft, filter2d, mm, mmt};
use ea4rca::baselines;
use ea4rca::codegen::config::PuConfig;
use ea4rca::codegen::generator;
use ea4rca::sim::params::HwParams;

fn p() -> HwParams {
    HwParams::vck5000()
}

// ---------------------------------------------------------------------
// Table 6 shapes
// ---------------------------------------------------------------------

#[test]
fn table6_gops_scales_with_pus_at_large_size() {
    let p = p();
    let g6 = mm::run(&p, 3072, 6, false).unwrap().gops;
    let g3 = mm::run(&p, 3072, 3, false).unwrap().gops;
    let g1 = mm::run(&p, 3072, 1, false).unwrap().gops;
    assert!(g6 > g3 * 1.7 && g3 > g1 * 1.7, "{g6} {g3} {g1}");
}

#[test]
fn table6_similar_gops_across_large_sizes() {
    // "Because the selected task scale is large ... similar GOPS can be
    // obtained under different task scales."
    let p = p();
    let a = mm::run(&p, 3072, 6, false).unwrap().gops;
    let b = mm::run(&p, 6144, 6, false).unwrap().gops;
    assert!((a - b).abs() / b < 0.05, "{a} vs {b}");
}

#[test]
fn table6_single_core_efficiency_rises_with_scale() {
    let p = p();
    let small = mm::run(&p, 768, 6, false).unwrap().gops_per_aie;
    let large = mm::run(&p, 6144, 6, false).unwrap().gops_per_aie;
    assert!(large > small * 1.3, "{small} -> {large}");
}

// ---------------------------------------------------------------------
// Table 7 shapes
// ---------------------------------------------------------------------

#[test]
fn table7_tiny_frame_tps_insensitive_to_pus() {
    let p = p();
    let t44 = filter2d::run(&p, 128, 128, 44, false).unwrap().tasks_per_sec;
    let t4 = filter2d::run(&p, 128, 128, 4, false).unwrap().tasks_per_sec;
    assert!((t44 - t4).abs() / t4 < 0.25, "{t44} vs {t4}");
    // and both land near the paper's ~6.2-6.5k tasks/s
    assert!(t4 > 4000.0 && t4 < 9000.0, "{t4}");
}

#[test]
fn table7_gops_grows_with_resolution() {
    let p = p();
    let g4k = filter2d::run(&p, 3480, 2160, 44, false).unwrap().gops;
    let g8k = filter2d::run(&p, 7680, 4320, 44, false).unwrap().gops;
    let g16k = filter2d::run(&p, 15360, 8640, 44, false).unwrap().gops;
    assert!(g8k > g4k && g16k > g8k, "{g4k} {g8k} {g16k}");
}

// ---------------------------------------------------------------------
// Table 8 shapes
// ---------------------------------------------------------------------

#[test]
fn table8_feasibility_grid() {
    let p = p();
    for (n, pus, feasible) in [
        (8192, 2, false),
        (8192, 4, true),
        (8192, 8, true),
        (4096, 2, true),
        (1024, 2, true),
    ] {
        let got = fft::run(&p, n, pus, 64, false).unwrap().is_some();
        assert_eq!(got, feasible, "{n}-pt {pus}PU");
    }
}

#[test]
fn table8_tps_scales_inversely_with_n() {
    let p = p();
    let mut prev = f64::INFINITY;
    for n in [1024, 2048, 4096, 8192] {
        let tps = fft::run(&p, n, 8, 2048, false).unwrap().unwrap().tasks_per_sec;
        assert!(tps < prev, "n={n}");
        prev = tps;
    }
}

// ---------------------------------------------------------------------
// Table 9 / Table 10 relations
// ---------------------------------------------------------------------

#[test]
fn mmt_outperforms_mm_per_core() {
    // MM-T (no data engine) must beat the MM accelerator per core:
    // paper 15.45 vs 8.90 GOPS/AIE.
    let p = p();
    let mmt_r = mmt::run(&p, 5_000, false).unwrap();
    let mm_r = mm::run(&p, 6144, 6, false).unwrap();
    let ratio = mmt_r.gops_per_aie / mm_r.gops_per_aie;
    assert!(ratio > 1.5 && ratio < 2.2, "ratio {ratio}");
}

#[test]
fn table10_ea4rca_wins() {
    let p = p();
    // MM vs CHARM
    let mm_r = mm::run(&p, 6144, 6, false).unwrap();
    assert!(mm_r.gops / 3270.0 > 0.9);
    assert!(mm_r.gops_per_w / 62.40 > 1.0);
    // Filter2D vs CCC2023 (>10x wins)
    let f = filter2d::run(&p, 3480, 2160, 44, false).unwrap();
    assert!(f.gops / 39.22 > 10.0);
    // FFT vs CCC2023
    let r = fft::run(&p, 4096, 8, 2048, false).unwrap().unwrap();
    assert!(r.tasks_per_sec / 135_685.21 > 2.0);
    // simulated baseline models agree with the published numbers
    assert!((baselines::charm::simulated_gops(&p) - 3270.0).abs() / 3270.0 < 0.2);
}

// ---------------------------------------------------------------------
// Codegen -> framework coherence
// ---------------------------------------------------------------------

#[test]
fn config_files_match_app_designs() {
    for (file, cores, plios) in
        [("configs/mm.json", 64, 12), ("configs/filter2d.json", 8, 2),
         ("configs/fft.json", 10, 2), ("configs/mmt.json", 8, 2)]
    {
        let cfg = PuConfig::from_file(std::path::Path::new(file)).unwrap();
        assert_eq!(cfg.pu.cores(), cores, "{file}");
        assert_eq!(cfg.pu.total_plios(), plios, "{file}");
        // and every config generates a valid project
        let proj = generator::generate(&cfg).unwrap();
        assert!(proj.graph_h.contains(&format!("class {}_pu", cfg.name)));
    }
}

#[test]
fn config_mm_pu_timing_equals_app_pu_timing() {
    // the config-file PU and the hand-built app PU are the same design
    let p = p();
    let cfg = PuConfig::from_file(std::path::Path::new("configs/mm.json")).unwrap();
    let app_pu = mm::mm_pu();
    assert!((cfg.pu.compute_secs(&p) - app_pu.compute_secs(&p)).abs() < 1e-9);
    assert!((cfg.pu.comm_secs(&p) - app_pu.comm_secs(&p)).abs() < 1e-9);
}

// ---------------------------------------------------------------------
// Trace / figure machinery
// ---------------------------------------------------------------------

#[test]
fn traced_run_renders_pipeline() {
    let p = p();
    let r = mm::run(&p, 768, 2, true).unwrap();
    let horizon = r.sim.trace.horizon_ps();
    assert!(horizon > 0);
    let txt = r.sim.trace.render(80, 0, horizon);
    assert!(txt.contains("G0.DU"));
    assert!(txt.contains('#'), "has compute spans");
    assert!(txt.contains('='), "has comm spans");
}

#[test]
fn untraced_run_is_lean() {
    let p = p();
    let r = mm::run(&p, 768, 6, false).unwrap();
    assert!(r.sim.trace.spans.is_empty());
}
