//! Golden-value tests for the interpreter backend: `Runtime::execute`
//! on an explicit `BackendKind::Interp` runtime must reproduce the
//! reference kernel semantics of `python/compile/kernels/ref.py`
//! (mirrored in `runtime::tensor`) within 1e-4, with zero files on disk
//! and zero native dependencies — the hermetic tier-1 contract.
//!
//! Plus serve-path smoke tests exercising `coordinator::server` with
//! more than one worker on the interpreter backend.

use ea4rca::coordinator::server::{serve_batch, Server};
use ea4rca::runtime::tensor::{fft_ref, filter2d_ref, matmul_ref};
use ea4rca::runtime::{BackendKind, Manifest, Runtime, Tensor};
use ea4rca::util::rng::Rng;
use ea4rca::workload::{generate_stream, Mix, TaskKind};

const TOL: f64 = 1e-4;

fn interp_runtime() -> Runtime {
    // a directory that can never contain a manifest.json: these golden
    // tests must always exercise the built-in catalogue, even after
    // `make artifacts` has populated ./artifacts
    Runtime::with_backend(BackendKind::Interp, "target/ea4rca-no-artifacts-here")
        .expect("interpreter runtime needs nothing on disk")
}

fn max_err(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs() as f64).fold(0.0, f64::max)
}

// ---------------------------------------------------------------------
// golden values vs the reference kernels
// ---------------------------------------------------------------------

#[test]
fn mm_artifacts_match_reference_within_tol() {
    let rt = interp_runtime();
    let mut rng = Rng::new(101);
    for (name, m, k, n) in
        [("mm32", 32, 32, 32), ("mm_pu128", 128, 128, 128), ("mmt_cascade8", 32, 256, 32)]
    {
        let a = rng.normal_vec(m * k);
        let b = rng.normal_vec(k * n);
        let out = rt
            .execute(
                name,
                &[Tensor::f32(&[m, k], a.clone()), Tensor::f32(&[k, n], b.clone())],
            )
            .unwrap();
        assert_eq!(out[0].shape(), &[m, n], "{name}");
        let err = max_err(out[0].as_f32().unwrap(), &matmul_ref(&a, &b, m, k, n));
        assert!(err < TOL, "{name}: max err {err}");
    }
}

#[test]
fn mm32_acc_is_a_cascade_stage() {
    let rt = interp_runtime();
    let mut rng = Rng::new(102);
    let a = rng.normal_vec(1024);
    let b = rng.normal_vec(1024);
    let acc = rng.normal_vec(1024);
    let out = rt
        .execute(
            "mm32_acc",
            &[
                Tensor::f32(&[32, 32], a.clone()),
                Tensor::f32(&[32, 32], b.clone()),
                Tensor::f32(&[32, 32], acc.clone()),
            ],
        )
        .unwrap();
    let mut want = matmul_ref(&a, &b, 32, 32, 32);
    for (w, c) in want.iter_mut().zip(&acc) {
        *w += c;
    }
    assert!(max_err(out[0].as_f32().unwrap(), &want) < TOL);
}

#[test]
fn filter2d_artifact_is_exact() {
    let rt = interp_runtime();
    let mut rng = Rng::new(103);
    let tiles = rng.int_vec_i32(8 * 36 * 36, -128, 127);
    let kern = rng.int_vec_i32(25, -16, 16);
    let out = rt
        .execute(
            "filter2d_pu8",
            &[
                Tensor::i32(&[8, 36, 36], tiles.clone()),
                Tensor::i32(&[5, 5], kern.clone()),
            ],
        )
        .unwrap();
    assert_eq!(out[0].shape(), &[8, 32, 32]);
    let got = out[0].as_i32().unwrap();
    for tile in 0..8 {
        let want = filter2d_ref(&tiles[tile * 36 * 36..(tile + 1) * 36 * 36], 36, 36, &kern, 5);
        assert_eq!(&got[tile * 1024..(tile + 1) * 1024], &want[..], "tile {tile}");
    }
}

#[test]
fn fft_artifacts_match_reference_within_tol() {
    let rt = interp_runtime();
    let mut rng = Rng::new(104);
    for n in [1024usize, 2048, 4096, 8192] {
        let re = rng.normal_vec(n);
        let im = rng.normal_vec(n);
        let out = rt
            .execute(
                &format!("fft{n}"),
                &[Tensor::f32(&[n], re.clone()), Tensor::f32(&[n], im.clone())],
            )
            .unwrap();
        let (wr, wi) = fft_ref(&re, &im);
        assert!(max_err(out[0].as_f32().unwrap(), &wr) < TOL, "fft{n} re");
        assert!(max_err(out[1].as_f32().unwrap(), &wi) < TOL, "fft{n} im");
    }
}

#[test]
fn lowbit_mm_wraps_like_the_narrow_datapath() {
    let rt = interp_runtime();
    let mut rng = Rng::new(105);
    // in-range operands: plain integer matmul
    let a = rng.int_vec_i32(1024, -128, 127);
    let b = rng.int_vec_i32(1024, -128, 127);
    let out = rt
        .execute(
            "mm32_i8",
            &[Tensor::i32(&[32, 32], a.clone()), Tensor::i32(&[32, 32], b.clone())],
        )
        .unwrap();
    let want: Vec<i64> = (0..32 * 32)
        .map(|idx| {
            let (i, j) = (idx / 32, idx % 32);
            (0..32).map(|p| a[i * 32 + p] as i64 * b[p * 32 + j] as i64).sum()
        })
        .collect();
    for (g, w) in out[0].as_i32().unwrap().iter().zip(&want) {
        assert_eq!(*g as i64, *w);
    }
    // out-of-range operands wrap to int8 before multiplying
    let mut a = vec![0i32; 1024];
    a[0] = 257; // wraps to 1
    let mut eye = vec![0i32; 1024];
    for i in 0..32 {
        eye[i * 32 + i] = 1;
    }
    let out = rt
        .execute("mm32_i8", &[Tensor::i32(&[32, 32], a), Tensor::i32(&[32, 32], eye)])
        .unwrap();
    assert_eq!(out[0].as_i32().unwrap()[0], 1);
}

// ---------------------------------------------------------------------
// runtime behaviour on the interpreter
// ---------------------------------------------------------------------

#[test]
fn works_with_no_artifact_directory_at_all() {
    let rt = Runtime::with_backend(BackendKind::Interp, "/definitely/not/a/real/dir").unwrap();
    let out = rt
        .execute(
            "mm32",
            &[
                Tensor::f32(&[32, 32], vec![1.0; 1024]),
                Tensor::f32(&[32, 32], vec![0.0; 1024]),
            ],
        )
        .unwrap();
    assert!(out[0].as_f32().unwrap().iter().all(|&v| v == 0.0));
}

#[test]
fn warmup_and_stats_work_on_interp() {
    let rt = interp_runtime();
    rt.warmup(&["mm32", "fft1024"]).unwrap();
    let mut rng = Rng::new(106);
    let a = Tensor::f32(&[32, 32], rng.normal_vec(1024));
    let b = Tensor::f32(&[32, 32], rng.normal_vec(1024));
    for _ in 0..3 {
        rt.execute("mm32", &[a.clone(), b.clone()]).unwrap();
    }
    let stats = rt.stats();
    assert_eq!(stats["mm32"].executions, 3);
    assert!(rt.mean_exec_secs("mm32").unwrap() > 0.0);
    assert_eq!(rt.backend_kind(), BackendKind::Interp);
    assert!(rt.platform().contains("interp"));
}

#[test]
fn prepare_runs_once_per_artifact_across_n_jobs() {
    // the prepared-artifact contract: setup (kernel resolve, shape
    // validation, fft plan build) is paid once per artifact per
    // runtime, no matter how many jobs run — every later job is a
    // cache hit
    let rt = interp_runtime();
    let mut rng = Rng::new(109);
    let fft_job = || {
        vec![
            Tensor::f32(&[1024], vec![1.0; 1024]),
            Tensor::f32(&[1024], vec![0.0; 1024]),
        ]
    };
    let mm_job = vec![
        Tensor::f32(&[32, 32], rng.normal_vec(1024)),
        Tensor::f32(&[32, 32], rng.normal_vec(1024)),
    ];
    for _ in 0..5 {
        rt.execute("fft1024", &fft_job()).unwrap();
    }
    let batch: Vec<Vec<Tensor>> = (0..3).map(|_| fft_job()).collect();
    rt.execute_batch("fft1024", &batch).unwrap();
    rt.execute("mm32", &mm_job).unwrap();

    let stats = rt.stats();
    assert_eq!(stats["fft1024"].prepare_builds, 1, "one plan build, ever");
    // 5 single executes + 1 batch dispatch consulted the guard after
    // the first build
    assert_eq!(stats["fft1024"].prepare_hits, 5);
    assert_eq!(stats["fft1024"].executions, 8);
    assert_eq!(stats["mm32"].prepare_builds, 1);

    // backend-level: two artifacts built, everything else cache hits
    let cs = rt.cache_stats();
    assert_eq!(cs.builds, 2, "fft1024 + mm32");
    assert!(cs.hits >= 6, "execute-path lookups must hit, got {cs:?}");

    // warming an already-run artifact builds nothing new
    rt.warmup(&["fft1024", "mm32"]).unwrap();
    assert_eq!(rt.cache_stats().builds, 2);
}

#[test]
fn runtime_execute_batch_counts_and_isolates_jobs() {
    let rt = interp_runtime();
    let mut rng = Rng::new(107);
    let a = rng.normal_vec(1024);
    let good = vec![
        Tensor::f32(&[32, 32], a.clone()),
        Tensor::f32(&[32, 32], vec![1.0; 1024]),
    ];
    // middle job has the wrong shape: it must fail alone
    let jobs = vec![
        good.clone(),
        vec![Tensor::f32(&[2, 2], vec![0.0; 4]), Tensor::f32(&[2, 2], vec![0.0; 4])],
        good,
    ];
    let results = rt.execute_batch("mm32", &jobs).unwrap();
    assert_eq!(results.len(), 3);
    assert!(results[0].is_ok());
    let err = results[1].as_ref().unwrap_err().to_string();
    assert!(err.contains("mm32"), "{err}");
    assert!(results[2].is_ok());
    // stats: 2 jobs ran through 1 batched dispatch
    let stats = rt.stats();
    assert_eq!(stats["mm32"].executions, 2);
    assert_eq!(stats["mm32"].batch_calls, 1);
    // batched output equals the single-execute output bit for bit
    let single = rt.execute("mm32", &jobs[0]).unwrap();
    assert_eq!(results[0].as_ref().unwrap()[0], single[0]);
    // artifact-level failure: unknown name fails the whole dispatch
    assert!(rt.execute_batch("nope", &jobs).is_err());
}

#[test]
fn unknown_artifact_in_manifest_is_a_readable_error() {
    // an on-disk manifest naming an artifact the interpreter has no
    // kernel for: preparing it must fail with the artifact name
    let dir = std::env::temp_dir().join("ea4rca_interp_unknown");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"artifacts": [
            {"name": "mystery_op", "file": "mystery_op.hlo.txt",
             "inputs": [{"shape": [4], "dtype": "f32"}],
             "outputs": [{"shape": [4], "dtype": "f32"}]}
        ]}"#,
    )
    .unwrap();
    let rt = Runtime::with_backend(BackendKind::Interp, &dir).unwrap();
    let err = rt
        .execute("mystery_op", &[Tensor::f32(&[4], vec![0.0; 4])])
        .unwrap_err()
        .to_string();
    assert!(err.contains("mystery_op"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// serve path on the interpreter, >1 worker
// ---------------------------------------------------------------------

#[test]
fn serve_smoke_multi_worker_mixed_stream() {
    let server = Server::start_with_backend(
        BackendKind::Interp,
        3,
        Manifest::default_dir(),
        &["mm_pu128", "fft1024", "filter2d_pu8", "mmt_cascade8"],
    )
    .unwrap();
    assert_eq!(server.workers(), 3);
    let jobs: Vec<(String, Vec<Tensor>)> = generate_stream(&Mix::uniform(), 30, 42)
        .into_iter()
        .map(|(k, i)| (k.artifact().to_string(), i))
        .collect();
    let (results, latency) = serve_batch(&server, jobs).unwrap();
    assert_eq!(results.len(), 30);
    assert!(results.iter().all(|r| r.outputs.is_ok()));
    assert!(latency.p95 >= latency.p50);
    let report = server.shutdown().unwrap();
    assert_eq!(report.total_jobs, 30);
    // least-loaded dispatch: nothing lost, nothing duplicated
    assert_eq!(report.completed_jobs(), 30);
    for w in &report.workers {
        assert_eq!(w.errors, 0, "worker {}", w.worker);
    }
    // every dispatched micro-batch is accounted for in the histogram
    let hist_jobs: u64 = report
        .batch_hist
        .values()
        .flat_map(|h| h.iter().map(|(size, count)| *size as u64 * count))
        .sum();
    assert_eq!(hist_jobs, 30);
}

#[test]
fn served_numerics_match_oracle() {
    let server =
        Server::start_with_backend(BackendKind::Interp, 2, Manifest::default_dir(), &[]).unwrap();
    let mut rng = Rng::new(7);
    let a = rng.normal_vec(128 * 128);
    let b = rng.normal_vec(128 * 128);
    let pending = server
        .submit(
            "mm_pu128",
            vec![
                Tensor::f32(&[128, 128], a.clone()),
                Tensor::f32(&[128, 128], b.clone()),
            ],
        )
        .unwrap();
    let result = pending.wait().unwrap();
    let out = result.outputs.unwrap();
    let want = matmul_ref(&a, &b, 128, 128, 128);
    assert!(max_err(out[0].as_f32().unwrap(), &want) < TOL);
    // a job for a missing artifact errors without killing the worker
    let pending = server.submit("nope", vec![]).unwrap();
    assert!(pending.wait().unwrap().outputs.is_err());
    server.shutdown().unwrap();
}

#[test]
fn generated_workload_shapes_are_served() {
    // every TaskKind the workload generator produces must execute on
    // the interpreter (shapes line up with the built-in manifest)
    let rt = interp_runtime();
    let mut rng = Rng::new(9);
    for kind in TaskKind::all() {
        let inputs = kind.gen_inputs(&mut rng);
        let out = rt.execute(kind.artifact(), &inputs);
        assert!(out.is_ok(), "{kind:?}: {}", out.err().unwrap());
    }
}
