//! Integration tests for the static design-rule checker (DRC): one
//! negative fixture per rule in the registry (asserted by `RuleId`),
//! the errors-fail / warnings-pass gate semantics on
//! `Design::generate()` / `Design::deploy()`, and a golden test
//! pinning `lint --all`'s rendered output byte-stable over the shipped
//! configs, the design catalogue, and the default serving shape.

use std::collections::BTreeSet;
use std::path::Path;

use ea4rca::analysis::{
    check_config, check_graph_text, check_placement, check_serving, lint_all,
    lint_config_text, Report, RuleId, ServeShape, Severity,
};
use ea4rca::api::designs;
use ea4rca::codegen::config::PuConfig;
use ea4rca::{DeployOptions, Design};

// --- config fixtures, one per design rule ------------------------------

/// DRC-001: the paper's MM PU at 7 copies — 7 x 64 = 448 cores > 400.
const MM7: &str = r#"{
    "name": "mm7", "kernel": "mm32", "class": "f32mac", "copies": 7,
    "psts": [{
        "dacs": [{"modes": ["SWH", "BDC"], "plios": 8, "serves": 64}],
        "cc": "Parallel<16>*Cascade<4>",
        "dccs": [{"mode": "SWH", "plios": 4, "serves": 64}]
    }],
    "ops_per_iter": 4194304, "in_bytes": 131072, "out_bytes": 65536
}"#;

/// DRC-002: 128 PLIOs per copy x 2 copies = 256 ports > 156, while the
/// 128 cores stay well inside the 400-core budget.
const WIDE: &str = r#"{
    "name": "wide", "kernel": "mm32", "class": "f32mac", "copies": 2,
    "psts": [{
        "dacs": [{"modes": ["SWH"], "plios": 64, "serves": 64}],
        "cc": "Parallel<64>*Single",
        "dccs": [{"mode": "SWH", "plios": 64, "serves": 64}]
    }],
    "ops_per_iter": 4194304, "in_bytes": 131072, "out_bytes": 65536
}"#;

/// DRC-003: 12-core PUs (1.5 columns) consume a 2-column span each; 33
/// copies = 396 cores fit the raw budget but only 25 place.
const FRAG: &str = r#"{
    "name": "frag", "kernel": "mm32", "class": "f32mac", "copies": 33,
    "psts": [{
        "dacs": [{"modes": ["SWH"], "plios": 1, "serves": 12}],
        "cc": "Parallel<4>*Cascade<3>",
        "dccs": [{"mode": "SWH", "plios": 1, "serves": 12}]
    }],
    "ops_per_iter": 786432, "in_bytes": 1024, "out_bytes": 1024
}"#;

/// DRC-004 (warning): a 16-deep cascade chain on an 8-row array.
const DEEP: &str = r#"{
    "name": "deep", "kernel": "mm32", "class": "f32mac", "copies": 1,
    "psts": [{
        "dacs": [{"modes": ["DIR"], "plios": 1, "serves": 1}],
        "cc": "Cascade<16>",
        "dccs": [{"mode": "DIR", "plios": 1, "serves": 1}]
    }],
    "ops_per_iter": 524288, "in_bytes": 0, "out_bytes": 0
}"#;

/// DRC-005 (+ DRC-012: the generator refuses the same arithmetic): a
/// DAC with more PLIO wires than leader cores to land them on.
const FAT: &str = r#"{
    "name": "fat", "kernel": "mm32", "class": "f32mac", "copies": 1,
    "psts": [{
        "dacs": [{"modes": ["SWH"], "plios": 4, "serves": 2}],
        "cc": "Cascade<2>",
        "dccs": [{"mode": "SWH", "plios": 1, "serves": 2}]
    }],
    "ops_per_iter": 524288, "in_bytes": 1024, "out_bytes": 1024
}"#;

/// DRC-006 (+ DRC-012): two DACs whose serve slices sum to 8 on a
/// 4-core CC.
const OVER: &str = r#"{
    "name": "over", "kernel": "mm32", "class": "f32mac", "copies": 1,
    "psts": [{
        "dacs": [{"modes": ["SWH"], "plios": 2, "serves": 4},
                 {"modes": ["BDC"], "plios": 2, "serves": 4}],
        "cc": "Parallel<4>*Single",
        "dccs": [{"mode": "SWH", "plios": 1, "serves": 4}]
    }],
    "ops_per_iter": 524288, "in_bytes": 1024, "out_bytes": 1024
}"#;

/// DRC-007: a kernel name the Kernel Manager has never heard of.
const MYSTERY: &str = r#"{
    "name": "mystery", "kernel": "nope", "class": "f32mac", "copies": 1,
    "psts": [{
        "dacs": [{"modes": ["DIR"], "plios": 1, "serves": 1}],
        "cc": "Cascade<8>",
        "dccs": [{"mode": "DIR", "plios": 1, "serves": 1}]
    }],
    "ops_per_iter": 524288, "in_bytes": 0, "out_bytes": 0
}"#;

/// DRC-008: the filter2d kernel (i32mac) under an f32mac PU class.
const MISMATCH: &str = r#"{
    "name": "mismatch", "kernel": "filter2d", "class": "f32mac", "copies": 1,
    "psts": [{
        "dacs": [{"modes": ["SWH"], "plios": 1, "serves": 8}],
        "cc": "Parallel<8>*Single",
        "dccs": [{"mode": "SWH", "plios": 1, "serves": 8}]
    }],
    "ops_per_iter": 409600, "in_bytes": 10368, "out_bytes": 8192
}"#;

/// DRC-010 (warning): the MM PU pushed to a 512 KiB input tile — comm
/// ~13.7 us per iteration against ~4.2 us of compute.
const CHATTY: &str = r#"{
    "name": "chatty", "kernel": "mm32", "class": "f32mac", "copies": 1,
    "psts": [{
        "dacs": [{"modes": ["SWH", "BDC"], "plios": 8, "serves": 64}],
        "cc": "Parallel<16>*Cascade<4>",
        "dccs": [{"mode": "SWH", "plios": 4, "serves": 64}]
    }],
    "ops_per_iter": 4194304, "in_bytes": 524288, "out_bytes": 65536
}"#;

/// DRC-011 (warning): a 16 MiB input tile double-buffered over 64
/// cores needs ~514 KiB per core against 32 KiB of local memory; the
/// inflated ops_per_iter keeps the design compute-bound so DRC-010
/// stays quiet.
const HOG: &str = r#"{
    "name": "hog", "kernel": "mm32", "class": "f32mac", "copies": 1,
    "psts": [{
        "dacs": [{"modes": ["SWH", "BDC"], "plios": 8, "serves": 64}],
        "cc": "Parallel<16>*Cascade<4>",
        "dccs": [{"mode": "SWH", "plios": 4, "serves": 64}]
    }],
    "ops_per_iter": 1000000000, "in_bytes": 16777216, "out_bytes": 65536
}"#;

// --- graph-text fixtures (DRC-013/014) ---------------------------------

/// DRC-013: in[0] wired to two cores (and k0[0].in fed twice).
const DOUBLE_WIRE_GRAPH: &str = "\
  input_plio  in[1];
  output_plio out[1];
  kernel k0[2];
  connect<stream>(in[0].out[0], k0[0].in[0]);
  connect<stream>(in[0].out[0], k0[1].in[0]);
  connect<stream>(k0[1].out[0], out[0].in[0]);
";

/// DRC-014: in[1] is declared but never wired to any core.
const DANGLING_GRAPH: &str = "\
  input_plio  in[2];
  output_plio out[1];
  kernel k0[2];
  connect<stream>(in[0].out[0], k0[0].in[0]);
  connect<stream>(k0[1].out[0], out[0].in[0]);
";

fn cfg(json: &str) -> PuConfig {
    PuConfig::from_json_text(json).expect("fixture configs parse")
}

fn mm_clean() -> PuConfig {
    let text = std::fs::read_to_string("configs/mm.json").expect("shipped config");
    cfg(&text)
}

/// Every rule in the registry paired with a report that must trip it.
fn fixture_reports() -> Vec<(RuleId, Report)> {
    let catalogue = designs::catalogue();
    let zero_workers = ServeShape { workers: 0, ..ServeShape::default() };
    let fat_batch = ServeShape { max_batch: 512, queue_cap: 256, ..ServeShape::default() };
    let firehose = ServeShape { rate: 1e9, ..ServeShape::default() };
    let arts = vec!["mm_pu128".to_string(), "fft1024".to_string()];
    let placement = vec![vec!["mm_pu128".to_string(), "ghost".to_string()], Vec::new()];
    vec![
        (RuleId::ConfigInvalid, lint_config_text("{ not json", "broken.json")),
        (RuleId::ArrayBudget, check_config(&cfg(MM7), None, "mm7")),
        (RuleId::PlioBudget, check_config(&cfg(WIDE), None, "wide")),
        (RuleId::UnplaceablePu, check_config(&cfg(FRAG), None, "frag")),
        (RuleId::CascadeLongChain, check_config(&cfg(DEEP), None, "deep")),
        (RuleId::PlioOversubscribed, check_config(&cfg(FAT), None, "fat")),
        (RuleId::CoreSliceOverrun, check_config(&cfg(OVER), None, "over")),
        (RuleId::KernelUnknown, check_config(&cfg(MYSTERY), None, "mystery")),
        (RuleId::KernelClassMismatch, check_config(&cfg(MISMATCH), None, "mismatch")),
        (RuleId::ArtifactNotBuiltin, check_config(&mm_clean(), Some("bogus"), "bogus")),
        (RuleId::CommBound, check_config(&cfg(CHATTY), None, "chatty")),
        (RuleId::CoreMemOverflow, check_config(&cfg(HOG), None, "hog")),
        (RuleId::GraphEmitFailed, check_config(&cfg(FAT), None, "fat")),
        (RuleId::GraphDoubleWire, check_graph_text(DOUBLE_WIRE_GRAPH, "double")),
        (RuleId::GraphDanglingPort, check_graph_text(DANGLING_GRAPH, "dangling")),
        (RuleId::PlacementStranded, check_placement(&arts, &placement, "deployment")),
        (RuleId::PlacementEmptyShard, check_placement(&arts, &placement, "deployment")),
        (RuleId::PlacementUnknownArtifact, check_placement(&arts, &placement, "deployment")),
        (RuleId::BatchExceedsQueue, check_serving(&catalogue, &fat_batch, "shape")),
        (RuleId::ZeroCapacity, check_serving(&catalogue, &zero_workers, "shape")),
        (RuleId::RateOverload, check_serving(&catalogue, &firehose, "shape")),
    ]
}

#[test]
fn every_rule_has_a_negative_fixture() {
    let fixtures = fixture_reports();
    let mut covered: BTreeSet<&'static str> = BTreeSet::new();
    for (rule, report) in &fixtures {
        assert!(
            report.has(*rule),
            "fixture for {} did not trip it; findings: {:?}",
            rule,
            report.sorted()
        );
        covered.insert(rule.code());
    }
    let all: BTreeSet<&'static str> = RuleId::ALL.iter().map(|r| r.code()).collect();
    assert_eq!(covered, all, "every registry rule needs a negative fixture");
}

// --- precision: fixtures trip their rule without collateral noise -----

#[test]
fn over_budget_trips_array_rule_without_plio_noise() {
    let r = check_config(&cfg(MM7), None, "mm7");
    assert!(r.has(RuleId::ArrayBudget));
    assert!(!r.has(RuleId::PlioBudget), "{:?}", r.sorted());
    // over-budget configs skip the placement dry-run (DRC-001 subsumes it)
    assert!(!r.has(RuleId::UnplaceablePu), "{:?}", r.sorted());
    assert!(r.has_errors());
}

#[test]
fn plio_budget_trips_without_core_noise() {
    let r = check_config(&cfg(WIDE), None, "wide");
    assert!(r.has(RuleId::PlioBudget));
    assert!(!r.has(RuleId::ArrayBudget), "{:?}", r.sorted());
}

#[test]
fn comm_bound_and_mem_overflow_do_not_cross_fire() {
    let chatty = check_config(&cfg(CHATTY), None, "chatty");
    assert!(chatty.has(RuleId::CommBound), "{:?}", chatty.sorted());
    assert!(!chatty.has(RuleId::CoreMemOverflow), "{:?}", chatty.sorted());
    assert!(!chatty.has_errors(), "comm-bound is a warning");

    let hog = check_config(&cfg(HOG), None, "hog");
    assert!(hog.has(RuleId::CoreMemOverflow), "{:?}", hog.sorted());
    assert!(!hog.has(RuleId::CommBound), "{:?}", hog.sorted());
}

#[test]
fn unknown_artifact_is_info_only() {
    let r = check_config(&mm_clean(), Some("bogus"), "bogus");
    assert!(r.has(RuleId::ArtifactNotBuiltin));
    assert!(!r.has_errors(), "{:?}", r.sorted());
    assert_eq!(r.count(Severity::Info), 1);
    assert_eq!(r.len(), 1, "the clean MM config gains exactly the artifact info");
}

#[test]
fn dangling_port_points_at_the_unwired_port() {
    let r = check_graph_text(DANGLING_GRAPH, "dangling");
    assert_eq!(r.len(), 1, "{:?}", r.sorted());
    let d = r.sorted()[0].clone();
    assert_eq!(d.rule, RuleId::GraphDanglingPort);
    assert_eq!(d.location.detail.as_deref(), Some("in[1]"));
}

#[test]
fn port_arithmetic_fixtures_also_fail_the_generator() {
    for (json, origin) in [(FAT, "fat"), (OVER, "over")] {
        let r = check_config(&cfg(json), None, origin);
        assert!(r.has(RuleId::GraphEmitFailed), "{origin}: {:?}", r.sorted());
    }
}

// --- gate semantics: errors fail generate/deploy, warnings pass --------

#[test]
fn error_findings_fail_generate_and_deploy_with_the_rule_code() {
    // over-budget designs construct fine (no budget check in the
    // builder) — the DRC gate is what stops them
    let d = Design::from_json_text(MM7).expect("constructs; the gate rejects later");
    let err = format!("{:#}", d.generate().unwrap_err());
    assert!(err.contains("fails the design-rule check"), "{err}");
    assert!(err.contains("DRC-001"), "{err}");
    assert!(err.contains("448"), "the diagnostic text carries the arithmetic: {err}");

    let err = format!("{:#}", d.deploy(&DeployOptions::default()).unwrap_err());
    assert!(err.contains("fails the design-rule check"), "{err}");
    assert!(err.contains("DRC-001"), "{err}");
}

#[test]
fn warning_findings_do_not_block_generate() {
    let d = Design::from_json_text(DEEP).unwrap();
    let r = d.check();
    assert!(r.has(RuleId::CascadeLongChain), "{:?}", r.sorted());
    assert!(!r.has_errors());
    assert!(d.generate().is_ok(), "warnings print, generation proceeds");
}

#[test]
fn catalogue_designs_pass_the_gate() {
    for d in designs::catalogue() {
        assert!(d.check().is_empty(), "design {} should be DRC-clean", d.name());
        assert!(d.generate().is_ok(), "design {} should generate", d.name());
    }
}

// --- the golden: lint --all over the shipped tree ----------------------

#[test]
fn lint_all_over_the_shipped_tree_is_clean_and_byte_stable() {
    let lint = lint_all(Path::new("configs"), &ServeShape::default());
    assert!(!lint.has_errors(), "{}", lint.render());
    let expected = "\
== fft.json
   OK
== filter2d.json
   OK
== mm.json
   OK
== mm_small.json
   OK
== mmt.json
   OK
== design(mm)
   OK
== design(filter2d)
   OK
== design(fft)
   OK
== design(mmt)
   OK
== serving(shards=1, workers=4, batch=8, queue=256, rate=closed)
   OK
lint: 10 subjects checked, 0 errors, 0 warnings, 0 infos
";
    assert_eq!(lint.render(), expected);
}

#[test]
fn lint_findings_render_sorted_and_deterministic() {
    let r = check_config(&cfg(FAT), None, "fat");
    let lines: Vec<String> = r.sorted().iter().map(|d| d.grouped_line()).collect();
    // rendering is a pure function of the findings: re-rendering the
    // same report must be a fixed point
    assert_eq!(lines, r.sorted().iter().map(|d| d.grouped_line()).collect::<Vec<_>>());
    assert!(lines.iter().any(|l| l.starts_with("error[DRC-005]")), "{lines:?}");
    assert!(lines.iter().any(|l| l.starts_with("error[DRC-012]")), "{lines:?}");
}
