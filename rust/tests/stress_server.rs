//! Concurrency stress test for the micro-batched serving path: a mixed
//! stream through 4 workers, with every reply checked against the
//! `tensor::*_ref` oracles and full conservation accounting (no job
//! lost, none duplicated, every dispatch in the histogram).
//!
//! The full load (500 jobs x seeds 1-5, the ISSUE acceptance sweep)
//! runs in release — CI has a dedicated `cargo test --release --test
//! stress_server` job. Debug tier-1 runs a reduced load so `cargo test
//! -q` stays fast.

use std::time::Duration;

use ea4rca::coordinator::server::{Server, ServerConfig};
use ea4rca::runtime::{BackendKind, Manifest, Tensor};
use ea4rca::workload::{generate_stream, reference_outputs, Mix, TaskKind};

/// f32 comparison bound. The batched kernels are built to match the
/// reference accumulation order exactly, so this is headroom, not a
/// licence to drift.
const TOL: f32 = 1e-4;

fn assert_tensors_match(got: &[Tensor], want: &[Tensor], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: output arity");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.shape(), w.shape(), "{what} output {i}: shape");
        match (g, w) {
            (Tensor::I32 { .. }, Tensor::I32 { .. }) => {
                assert_eq!(g, w, "{what} output {i}: int mismatch");
            }
            _ => {
                let d = g.max_abs_diff(w).expect("comparable tensors");
                assert!(d < TOL as f64, "{what} output {i}: max |err| {d}");
            }
        }
    }
}

fn stress_one_seed(seed: u64, n_jobs: usize) {
    let config = ServerConfig {
        n_workers: 4,
        max_batch: 8,
        max_linger: Duration::from_micros(200),
        queue_cap: 128,
    };
    let server = Server::start_with_config(
        BackendKind::Interp,
        config,
        Manifest::default_dir(),
        &["mm_pu128", "fft1024", "filter2d_pu8", "mmt_cascade8"],
    )
    .expect("server start");

    // oracle first (inputs move into the server on submit)
    let stream = generate_stream(&Mix::uniform(), n_jobs, seed);
    let mut pending = Vec::with_capacity(n_jobs);
    let mut oracles = Vec::with_capacity(n_jobs);
    for (kind, inputs) in stream {
        oracles.push((kind, reference_outputs(kind, &inputs)));
        // submit applies backpressure (bounded wait) rather than
        // blocking forever; under 4 live workers it never saturates
        // for 30 s, so unwrap doubles as a liveness assertion
        pending.push(server.submit(kind.artifact(), inputs).expect("submit"));
    }

    let mut worker_seen = vec![0u64; 4];
    for (i, (p, (kind, want))) in pending.into_iter().zip(&oracles).enumerate() {
        let result = p.wait().expect("worker dropped a job");
        assert!(result.queue_secs >= 0.0 && result.exec_secs >= 0.0, "job {i}");
        assert!(result.batch_size >= 1 && result.batch_size <= 8, "job {i}");
        let outputs = result
            .outputs
            .unwrap_or_else(|e| panic!("job {i} ({kind:?}) failed: {e:#}"));
        // only successful replies carry a real worker index
        assert!(result.worker < 4, "job {i}: bogus worker id");
        worker_seen[result.worker] += 1;
        assert_tensors_match(&outputs, want, &format!("seed {seed} job {i} ({kind:?})"));
    }

    let report = server.shutdown().expect("shutdown");
    // conservation: accepted == completed == per-worker sum == histogram
    assert_eq!(report.total_jobs, n_jobs as u64, "seed {seed}: accepted count");
    assert_eq!(report.completed_jobs(), n_jobs as u64, "seed {seed}: completed count");
    let by_worker: u64 = report.workers.iter().map(|w| w.jobs).sum();
    assert_eq!(by_worker, n_jobs as u64, "seed {seed}: worker sum");
    // the replies we counted per worker must agree with worker stats
    for w in &report.workers {
        assert_eq!(
            w.jobs, worker_seen[w.worker],
            "seed {seed}: worker {} reply count",
            w.worker
        );
        assert_eq!(w.errors, 0, "seed {seed}: worker {} errors", w.worker);
    }
    let hist_jobs: u64 = report
        .batch_hist
        .values()
        .flat_map(|h| h.iter().map(|(size, count)| *size as u64 * count))
        .sum();
    assert_eq!(hist_jobs, n_jobs as u64, "seed {seed}: histogram job count");
    let hist_batches: u64 = report.batch_hist.values().flat_map(|h| h.values()).sum();
    assert_eq!(hist_batches, report.batches, "seed {seed}: histogram batch count");
}

#[test]
fn stress_mixed_stream_across_seeds() {
    // release: the full acceptance sweep; debug: a reduced load so the
    // default tier-1 `cargo test -q` stays quick
    let (n_jobs, seeds): (usize, &[u64]) = if cfg!(debug_assertions) {
        (120, &[1, 2])
    } else {
        (500, &[1, 2, 3, 4, 5])
    };
    for &seed in seeds {
        stress_one_seed(seed, n_jobs);
    }
}

#[test]
fn stress_single_artifact_burst() {
    // every job the same artifact: maximal batching pressure, and the
    // histogram must still conserve jobs
    let n_jobs = if cfg!(debug_assertions) { 64 } else { 256 };
    let config = ServerConfig {
        n_workers: 4,
        max_batch: 8,
        max_linger: Duration::from_micros(200),
        queue_cap: 128,
    };
    let server = Server::start_with_config(
        BackendKind::Interp,
        config,
        Manifest::default_dir(),
        &["mmt_cascade8"],
    )
    .expect("server start");
    // Compute every oracle BEFORE the first submit. With the reference
    // computation inside the submit loop, arrivals are throttled to the
    // service rate and the queue can drain between submits — on a fast
    // machine every dispatch is then a singleton and the mean-batch
    // assertion below races. A tight submit loop (queue pushes only)
    // outruns the workers by construction, so batches must form.
    let stream: Vec<(TaskKind, Vec<Tensor>, Vec<Tensor>)> =
        generate_stream(&Mix::single(TaskKind::MmtChain), n_jobs, 31)
            .into_iter()
            .map(|(kind, inputs)| {
                let want = reference_outputs(kind, &inputs);
                (kind, inputs, want)
            })
            .collect();
    let mut pending = Vec::new();
    let mut oracles = Vec::new();
    for (kind, inputs, want) in stream {
        oracles.push(want);
        pending.push(server.submit(kind.artifact(), inputs).expect("submit"));
    }
    for (i, (p, want)) in pending.into_iter().zip(&oracles).enumerate() {
        let outputs = p.wait().expect("reply").outputs.expect("job ok");
        assert_tensors_match(&outputs, want, &format!("burst job {i}"));
    }
    let report = server.shutdown().expect("shutdown");
    assert_eq!(report.completed_jobs(), n_jobs as u64);
    assert!(
        report.mean_batch_size("mmt_cascade8").unwrap() > 1.0,
        "single-artifact burst never batched"
    );
}
