//! The design-entry facade end to end: JSON round-trips for every
//! shipped config, builder/JSON/apps parity, cost prediction without a
//! runtime, and `Design::deploy` smoke tests (typed submit → result →
//! shutdown report) on the interp and sim backends.

use std::path::Path;

use ea4rca::api::{designs, DeployOptions, Deployment, Design};
use ea4rca::codegen::config::PuConfig;
use ea4rca::runtime::{BackendKind, Tensor};
use ea4rca::util::rng::Rng;
use ea4rca::workload::{reference_outputs, TaskKind};

/// f32 comparison bound (same contract as the serving stress suite:
/// the batched kernels match the reference accumulation order, so this
/// is headroom, not licence to drift).
const TOL: f64 = 1e-4;

fn assert_tensors_match(got: &[Tensor], want: &[Tensor], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: output arity");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.shape(), w.shape(), "{what} output {i}: shape");
        match (g, w) {
            (Tensor::I32 { .. }, Tensor::I32 { .. }) => {
                assert_eq!(g, w, "{what} output {i}: int mismatch");
            }
            _ => {
                let d = g.max_abs_diff(w).expect("comparable tensors");
                assert!(d < TOL, "{what} output {i}: max |err| {d}");
            }
        }
    }
}

fn configs_dir() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("configs")
}

/// Back-compat acceptance: every JSON file in configs/ parses through
/// `Design::from_path` and round-trips `to_json` → `from_json_text`
/// back to the exact original `PuConfig`.
#[test]
fn every_shipped_config_roundtrips_through_the_facade() {
    let mut seen = 0;
    for entry in std::fs::read_dir(configs_dir()).expect("configs/ exists") {
        let path = entry.unwrap().path();
        if path.extension().map(|e| e == "json").unwrap_or(false) {
            seen += 1;
            let text = std::fs::read_to_string(&path).unwrap();
            let original = PuConfig::from_json_text(&text)
                .unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
            let design = Design::from_path(&path)
                .unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
            assert_eq!(design.config(), &original, "{}", path.display());
            let back = Design::from_json_text(&design.to_json_text())
                .unwrap_or_else(|e| panic!("{}: re-parse: {e:#}", path.display()));
            assert_eq!(back.config(), &original, "{}: round-trip", path.display());
        }
    }
    assert!(seen >= 5, "expected the shipped configs, found {seen}");
}

/// The builder catalogue, the JSON configs, and the apps' hand-built
/// PUs are three views of the same designs.
#[test]
fn builder_json_and_apps_agree() {
    for (design, file) in [
        (designs::mm(), "mm.json"),
        (designs::filter2d(), "filter2d.json"),
        (designs::fft(1024).unwrap(), "fft.json"),
        (designs::mmt(), "mmt.json"),
    ] {
        let json = Design::from_path(configs_dir().join(file)).unwrap();
        assert_eq!(design.config(), json.config(), "{file}");
        // the runtime artifact too: mmt.json carries the explicit
        // "artifact" override, the rest resolve via the Kernel Manager
        assert_eq!(design.artifact(), json.artifact(), "{file}");
    }
    let pairs = [
        (designs::mm(), ea4rca::apps::mm::mm_pu()),
        (designs::filter2d(), ea4rca::apps::filter2d::filter2d_pu()),
        (designs::fft(1024).unwrap(), ea4rca::apps::fft::fft_pu(1024)),
        (designs::mmt(), ea4rca::apps::mmt::mmt_pu()),
    ];
    for (design, mut reference) in pairs {
        reference.name = design.config().pu.name.clone();
        assert_eq!(design.config().pu, reference, "{}", design.name());
    }
}

/// `Design::predict` needs no runtime, is deterministic, and batching
/// amortizes the fixed dispatch overhead.
#[test]
fn predict_without_a_runtime() {
    for design in designs::catalogue() {
        let p1 = design.predict(1);
        let p1_again = design.predict(1);
        assert_eq!(
            p1.latency_secs.to_bits(),
            p1_again.latency_secs.to_bits(),
            "{}: prediction must be deterministic",
            design.name()
        );
        assert!(p1.latency_secs > 0.0, "{}", design.name());
        assert!(p1.power_w > 0.0 && p1.energy_j > 0.0, "{}", design.name());
        let p16 = design.predict(16);
        assert!(p16.latency_secs >= p1.latency_secs, "{}", design.name());
        assert!(
            p16.per_job_secs() <= p1.per_job_secs() * 1.001,
            "{}: batching must amortize dispatch",
            design.name()
        );
    }
}

/// End-to-end `Design::deploy` smoke on both always-available backends:
/// typed submit, oracle-checked result, predictions on sim, typed error
/// for an undeployed artifact, and a conserving shutdown report.
#[test]
fn deploy_smoke_on_interp_and_sim() {
    for kind in [BackendKind::Interp, BackendKind::Sim] {
        let opts = DeployOptions { backend: kind, workers: 2, ..DeployOptions::default() };
        let deployment = Deployment::start(&designs::catalogue(), &opts)
            .unwrap_or_else(|e| panic!("{}: start: {e:#}", kind.name()));
        assert_eq!(deployment.workers(), 2);

        let mut rng = Rng::new(11);
        let mut submitted = 0u64;
        for task in [TaskKind::MmBlock, TaskKind::Fft1024, TaskKind::FilterBatch] {
            let inputs = task.gen_inputs(&mut rng);
            let want = reference_outputs(task, &inputs);
            let result = deployment
                .submit_to(task.artifact(), inputs)
                .unwrap_or_else(|e| panic!("{}: submit {task:?}: {e:#}", kind.name()))
                .wait()
                .unwrap();
            submitted += 1;
            let outputs = result
                .outputs
                .as_ref()
                .unwrap_or_else(|e| panic!("{}: {task:?}: {e:#}", kind.name()));
            assert_tensors_match(outputs, &want, &format!("{} {task:?}", kind.name()));
            if kind == BackendKind::Sim {
                let p = result.predicted.expect("sim results carry a cost prediction");
                assert!(p.latency_secs > 0.0 && p.energy_j > 0.0);
            }
        }

        // typed submit: an artifact outside the deployment is an
        // immediate readable error, not a worker-side failure
        let err = deployment
            .submit_to("not_deployed", Vec::new())
            .unwrap_err()
            .to_string();
        assert!(err.contains("not_deployed"), "{err}");

        let report = deployment.shutdown().unwrap();
        assert_eq!(report.total_jobs, submitted, "{}", kind.name());
        assert_eq!(report.completed_jobs(), submitted, "{}", kind.name());
    }
}

/// Single-design deployment: `Design::deploy` + the synchronous
/// `execute` round trip.
#[test]
fn single_design_deploy_executes() {
    let design = designs::fft(1024).unwrap();
    let deployment = design
        .deploy(&DeployOptions { workers: 1, ..DeployOptions::default() })
        .unwrap();
    assert_eq!(deployment.artifacts(), &["fft1024".to_string()]);
    let mut rng = Rng::new(3);
    let inputs = TaskKind::Fft1024.gen_inputs(&mut rng);
    let want = reference_outputs(TaskKind::Fft1024, &inputs);
    let outputs = deployment.execute(inputs).unwrap();
    assert_tensors_match(&outputs, &want, "fft1024 execute");
    let report = deployment.shutdown().unwrap();
    assert_eq!(report.completed_jobs(), 1);
}

/// Designs whose runtime artifact overrides the Kernel Manager default
/// (mmt → mmt_cascade8, fft(n≠1024) → fft{n}) keep that override
/// through the JSON frontend: `to_json` emits an `"artifact"` key and
/// `from_json_text` reads it back, so the round trip is the identity
/// on the whole Design, not just its PuConfig.
#[test]
fn artifact_override_survives_the_json_roundtrip() {
    for design in [designs::mmt(), designs::fft(4096).unwrap()] {
        let text = design.to_json_text();
        assert!(text.contains("\"artifact\""), "{}: {text}", design.name());
        let back = Design::from_json_text(&text).unwrap();
        assert_eq!(back, design, "{}", design.name());
        assert_eq!(back.artifact(), design.artifact());
    }
    // no override -> no artifact key, byte-compatible with the shipped
    // config schema
    assert!(!designs::mm().to_json_text().contains("\"artifact\""));
}
