//! Integration tests for the serving layer (admission queue,
//! micro-batching, least-loaded workers, backpressure). The default
//! interpreter backend needs no artifacts on disk, so these always run.

use std::time::Duration;

use ea4rca::coordinator::server::{serve_batch, Server, ServerConfig, SubmitError};
use ea4rca::runtime::tensor::matmul_ref;
use ea4rca::runtime::{BackendKind, Manifest, Tensor};
use ea4rca::util::rng::Rng;
use ea4rca::workload::{generate_stream, Mix, TaskKind};

#[test]
fn serves_correct_numerics() {
    let server = Server::start(2, Manifest::default_dir(), &["mm_pu128"]).unwrap();
    let mut rng = Rng::new(1);
    let a = rng.normal_vec(128 * 128);
    let b = rng.normal_vec(128 * 128);
    let pending = server
        .submit(
            "mm_pu128",
            vec![
                Tensor::f32(&[128, 128], a.clone()),
                Tensor::f32(&[128, 128], b.clone()),
            ],
        )
        .unwrap();
    let result = pending.wait().unwrap();
    let out = result.outputs.unwrap();
    let want = matmul_ref(&a, &b, 128, 128, 128);
    let err = out[0]
        .as_f32()
        .unwrap()
        .iter()
        .zip(&want)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(err < 5e-3, "{err}");
    // the latency split is populated and consistent
    assert!(result.exec_secs > 0.0);
    assert!(result.queue_secs >= 0.0);
    assert!(result.latency_secs() >= result.exec_secs);
    assert!(result.batch_size >= 1);
    server.shutdown().unwrap();
}

#[test]
fn distributes_across_workers() {
    let server = Server::start(3, Manifest::default_dir(), &["fft1024"]).unwrap();
    let jobs: Vec<(String, Vec<Tensor>)> = generate_stream(
        &Mix::single(TaskKind::Fft1024),
        30,
        2,
    )
    .into_iter()
    .map(|(k, i)| (k.artifact().to_string(), i))
    .collect();
    let (results, latency) = serve_batch(&server, jobs).unwrap();
    assert_eq!(results.len(), 30);
    assert!(results.iter().all(|r| r.outputs.is_ok()));
    assert!(latency.p95 >= latency.p50);
    let report = server.shutdown().unwrap();
    assert_eq!(report.total_jobs, 30);
    // least-loaded dispatch: every job lands exactly once
    assert_eq!(report.completed_jobs(), 30);
    for w in &report.workers {
        assert_eq!(w.errors, 0, "worker {}", w.worker);
    }
    // the whole stream was one artifact; its histogram covers all jobs
    let hist = report.batch_hist.get("fft1024").expect("fft1024 served");
    let jobs_in_hist: u64 = hist.iter().map(|(size, count)| *size as u64 * count).sum();
    assert_eq!(jobs_in_hist, 30);
    assert!(report.mean_batch_size("fft1024").unwrap() >= 1.0);
}

#[test]
fn micro_batches_form_under_burst() {
    // a queue-stuffed burst of one artifact must coalesce into batches
    let config = ServerConfig {
        n_workers: 2,
        max_batch: 8,
        max_linger: Duration::from_millis(2),
        queue_cap: 256,
    };
    let server = Server::start_with_config(
        BackendKind::Interp,
        config,
        Manifest::default_dir(),
        &["mm_pu128"],
    )
    .unwrap();
    let jobs: Vec<(String, Vec<Tensor>)> =
        generate_stream(&Mix::single(TaskKind::MmBlock), 48, 5)
            .into_iter()
            .map(|(k, i)| (k.artifact().to_string(), i))
            .collect();
    let (results, _) = serve_batch(&server, jobs).unwrap();
    assert!(results.iter().all(|r| r.outputs.is_ok()));
    let report = server.shutdown().unwrap();
    assert_eq!(report.completed_jobs(), 48);
    // strictly fewer dispatches than jobs proves coalescing happened
    assert!(
        report.batches < 48,
        "48 jobs should form fewer than 48 batches, got {}",
        report.batches
    );
    assert!(report.mean_batch_size("mm_pu128").unwrap() > 1.0);
}

#[test]
fn bad_artifact_is_an_error_not_a_crash() {
    let server = Server::start(1, Manifest::default_dir(), &[]).unwrap();
    let pending = server.submit("does_not_exist", vec![]).unwrap();
    let result = pending.wait().unwrap();
    assert!(result.outputs.is_err());
    let report = server.shutdown().unwrap();
    assert_eq!(report.workers[0].errors, 1);
    // the worker survives the error and the server drains cleanly
}

#[test]
fn mixed_stream_end_to_end() {
    let server = Server::start(
        2,
        Manifest::default_dir(),
        &["mm_pu128", "fft1024", "filter2d_pu8", "mmt_cascade8"],
    )
    .unwrap();
    let jobs: Vec<(String, Vec<Tensor>)> = generate_stream(&Mix::uniform(), 24, 9)
        .into_iter()
        .map(|(k, i)| (k.artifact().to_string(), i))
        .collect();
    let (results, _) = serve_batch(&server, jobs).unwrap();
    assert!(results.iter().all(|r| r.outputs.is_ok()));
    server.shutdown().unwrap();
}

#[test]
fn zero_workers_rejected() {
    assert!(Server::start(0, Manifest::default_dir(), &[]).is_err());
}

#[test]
fn degenerate_configs_rejected() {
    let bad_batch = ServerConfig { max_batch: 0, ..ServerConfig::default() };
    assert!(Server::start_with_config(
        BackendKind::Interp,
        bad_batch,
        Manifest::default_dir(),
        &[]
    )
    .is_err());
    let bad_queue = ServerConfig { queue_cap: 0, ..ServerConfig::default() };
    assert!(Server::start_with_config(
        BackendKind::Interp,
        bad_queue,
        Manifest::default_dir(),
        &[]
    )
    .is_err());
}

/// Satellite regression: a rejected submission must not count toward
/// `ServeReport::total_jobs` (the old server bumped its counter before
/// the send could fail). Saturate a tiny queue, then reconcile counts.
#[test]
fn saturated_submissions_are_not_counted() {
    let config = ServerConfig {
        n_workers: 1,
        max_batch: 1,
        max_linger: Duration::ZERO,
        queue_cap: 2,
    };
    let server = Server::start_with_config(
        BackendKind::Interp,
        config,
        Manifest::default_dir(),
        &["mm_pu128"],
    )
    .unwrap();
    let mut rng = Rng::new(3);
    let mut accepted = Vec::new();
    let mut saturated = 0u64;
    // submission is orders of magnitude faster than a 128^3 matmul, so
    // a 64-job burst against a 2-slot queue must shed load
    for _ in 0..64 {
        let inputs = TaskKind::MmBlock.gen_inputs(&mut rng);
        match server.try_submit("mm_pu128", inputs) {
            Ok(p) => accepted.push(p),
            Err(SubmitError::Saturated) => saturated += 1,
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    assert!(saturated > 0, "64-job burst never saturated a 2-slot queue");
    assert!(!accepted.is_empty(), "nothing was admitted");
    // every accepted job still completes (no hang, clean drain)
    let n_accepted = accepted.len() as u64;
    for p in accepted {
        let r = p.wait().unwrap();
        assert!(r.outputs.is_ok());
    }
    let report = server.shutdown().unwrap();
    assert_eq!(report.total_jobs, n_accepted, "rejected submissions were counted");
    assert_eq!(report.completed_jobs(), n_accepted);
}

/// try_submit on a full queue returns Saturated immediately instead of
/// hanging, and submit_timeout gives up after its deadline.
#[test]
fn saturation_is_an_error_not_a_hang() {
    let config = ServerConfig {
        n_workers: 1,
        max_batch: 1,
        max_linger: Duration::ZERO,
        queue_cap: 1,
    };
    let server = Server::start_with_config(
        BackendKind::Interp,
        config,
        Manifest::default_dir(),
        &["mm_pu128"],
    )
    .unwrap();
    let mut rng = Rng::new(11);
    let mut accepted = Vec::new();
    // stuff the pipeline until admission refuses
    let mut refused = false;
    for _ in 0..64 {
        match server.try_submit("mm_pu128", TaskKind::MmBlock.gen_inputs(&mut rng)) {
            Ok(p) => accepted.push(p),
            Err(SubmitError::Saturated) => {
                refused = true;
                break;
            }
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    assert!(refused, "queue never saturated");
    // a bounded wait also surfaces saturation rather than blocking:
    // keep the queue full by measuring immediately after a refusal
    let t0 = std::time::Instant::now();
    let res = server.submit_timeout(
        "mm_pu128",
        TaskKind::MmBlock.gen_inputs(&mut rng),
        Duration::from_millis(1),
    );
    match res {
        // either the wait timed out (still saturated) or space opened
        // up in time — both are legal; a hang is not
        Ok(p) => accepted.push(p),
        Err(SubmitError::Saturated) => {}
        Err(e) => panic!("unexpected submit error: {e}"),
    }
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "submit_timeout took {:?}",
        t0.elapsed()
    );
    for p in accepted {
        assert!(p.wait().unwrap().outputs.is_ok());
    }
    server.shutdown().unwrap();
}
