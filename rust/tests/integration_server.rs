//! Integration tests for the serving layer (leader/worker, per-worker
//! backend instances). The default interpreter backend needs no
//! artifacts on disk, so these always run.

use ea4rca::coordinator::server::{serve_batch, Server};
use ea4rca::runtime::tensor::matmul_ref;
use ea4rca::runtime::{Manifest, Tensor};
use ea4rca::util::rng::Rng;
use ea4rca::workload::{generate_stream, Mix, TaskKind};

#[test]
fn serves_correct_numerics() {
    let mut server = Server::start(2, Manifest::default_dir(), &["mm_pu128"]).unwrap();
    let mut rng = Rng::new(1);
    let a = rng.normal_vec(128 * 128);
    let b = rng.normal_vec(128 * 128);
    let pending = server
        .submit(
            "mm_pu128",
            vec![
                Tensor::f32(&[128, 128], a.clone()),
                Tensor::f32(&[128, 128], b.clone()),
            ],
        )
        .unwrap();
    let result = pending.wait().unwrap();
    let out = result.outputs.unwrap();
    let want = matmul_ref(&a, &b, 128, 128, 128);
    let err = out[0]
        .as_f32()
        .unwrap()
        .iter()
        .zip(&want)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(err < 5e-3, "{err}");
    assert!(result.latency_secs > 0.0);
    server.shutdown().unwrap();
}

#[test]
fn distributes_across_workers() {
    let mut server = Server::start(3, Manifest::default_dir(), &["fft1024"]).unwrap();
    let jobs: Vec<(String, Vec<Tensor>)> = generate_stream(
        &Mix::single(TaskKind::Fft1024),
        30,
        2,
    )
    .into_iter()
    .map(|(k, i)| (k.artifact().to_string(), i))
    .collect();
    let (results, latency) = serve_batch(&mut server, jobs).unwrap();
    assert_eq!(results.len(), 30);
    assert!(results.iter().all(|r| r.outputs.is_ok()));
    assert!(latency.p95 >= latency.p50);
    let report = server.shutdown().unwrap();
    assert_eq!(report.total_jobs, 30);
    // round-robin: every worker saw exactly 10
    for w in &report.workers {
        assert_eq!(w.jobs, 10, "worker {}", w.worker);
        assert_eq!(w.errors, 0);
    }
}

#[test]
fn bad_artifact_is_an_error_not_a_crash() {
    let mut server = Server::start(1, Manifest::default_dir(), &[]).unwrap();
    let pending = server.submit("does_not_exist", vec![]).unwrap();
    let result = pending.wait().unwrap();
    assert!(result.outputs.is_err());
    let report = server.shutdown().unwrap();
    assert_eq!(report.workers[0].errors, 1);
    // the worker survives the error and the server drains cleanly
}

#[test]
fn mixed_stream_end_to_end() {
    let mut server = Server::start(
        2,
        Manifest::default_dir(),
        &["mm_pu128", "fft1024", "filter2d_pu8", "mmt_cascade8"],
    )
    .unwrap();
    let jobs: Vec<(String, Vec<Tensor>)> = generate_stream(&Mix::uniform(), 24, 9)
        .into_iter()
        .map(|(k, i)| (k.artifact().to_string(), i))
        .collect();
    let (results, _) = serve_batch(&mut server, jobs).unwrap();
    assert!(results.iter().all(|r| r.outputs.is_ok()));
    server.shutdown().unwrap();
}

#[test]
fn zero_workers_rejected() {
    assert!(Server::start(0, Manifest::default_dir(), &[]).is_err());
}
