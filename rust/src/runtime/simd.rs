//! Explicit x86_64 AVX2/FMA micro-kernels for the interpreter's hot
//! paths, in the GotoBLAS2 packed-micro-kernel tradition the paper's
//! AIE kernels mirror (broadcast one A element, stream a B row through
//! vector lanes, keep C live in registers).
//!
//! Every public function here is **safe** and returns `bool`: `true`
//! means the SIMD kernel ran, `false` means the caller must take its
//! scalar fallback (non-x86_64 build, or a CPU without AVX2+FMA). The
//! runtime feature check is an atomic-load-cheap `std::is_x86_feature_
//! detected!` consult; tier selection already happened once per backend
//! (see [`super::tier`]), this per-call gate is only what makes the
//! wrappers sound to call from safe code.
//!
//! Numerics contracts (pinned by `rust/tests/kernel_tiers.rs`, table in
//! DESIGN.md):
//!
//! * `matmul_i32` / `filter2d_i32` — wrapping int32 arithmetic is
//!   associative, so lane order is invisible: **bitwise identical** to
//!   the scalar kernels.
//! * `fft_stage` — each butterfly performs the same IEEE f64 mul/sub/
//!   add sequence as the scalar stage, two butterflies per vector:
//!   **bitwise identical**.
//! * `matmul_f32` — per output element the accumulation visits k in the
//!   same ascending order as the scalar kernel, but through
//!   `vfmadd231ps`: the fused multiply-add rounds once where the scalar
//!   kernel rounds twice, so results differ within the documented
//!   bound |simd − scalar| ≤ 2·k·ε_f32·Σ_p|a_ip·b_pj| per element
//!   (standard forward-error analysis; both accumulations are within
//!   γ_k·Σ|ab| of the exact dot product). The scalar tail lanes use
//!   `f32::mul_add` so the contract is uniform across n % 8 elements.

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    /// C[m,n] = A[m,k] @ B[k,n], row-major, overwriting `c`.
    ///
    /// j is blocked 4 vectors (32 floats) wide so four independent FMA
    /// chains hide the fused-add latency; k is the innermost loop with
    /// the C block held in registers (zero C traffic inside the k loop,
    /// the same accumulation order per element as the scalar kernels).
    ///
    /// # Safety
    /// Caller must ensure AVX2+FMA are available and slice lengths
    /// match (`a` = m*k, `b` = k*n, `c` = m*n) — the safe wrapper
    /// checks both.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn matmul_f32(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
        let bp = b.as_ptr();
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            let cp = crow.as_mut_ptr();
            let mut j = 0;
            while j + 32 <= n {
                let mut acc0 = _mm256_setzero_ps();
                let mut acc1 = _mm256_setzero_ps();
                let mut acc2 = _mm256_setzero_ps();
                let mut acc3 = _mm256_setzero_ps();
                for (p, &av) in arow.iter().enumerate() {
                    let avv = _mm256_set1_ps(av);
                    let row = bp.add(p * n + j);
                    acc0 = _mm256_fmadd_ps(avv, _mm256_loadu_ps(row), acc0);
                    acc1 = _mm256_fmadd_ps(avv, _mm256_loadu_ps(row.add(8)), acc1);
                    acc2 = _mm256_fmadd_ps(avv, _mm256_loadu_ps(row.add(16)), acc2);
                    acc3 = _mm256_fmadd_ps(avv, _mm256_loadu_ps(row.add(24)), acc3);
                }
                _mm256_storeu_ps(cp.add(j), acc0);
                _mm256_storeu_ps(cp.add(j + 8), acc1);
                _mm256_storeu_ps(cp.add(j + 16), acc2);
                _mm256_storeu_ps(cp.add(j + 24), acc3);
                j += 32;
            }
            while j + 8 <= n {
                let mut acc = _mm256_setzero_ps();
                for (p, &av) in arow.iter().enumerate() {
                    acc = _mm256_fmadd_ps(
                        _mm256_set1_ps(av),
                        _mm256_loadu_ps(bp.add(p * n + j)),
                        acc,
                    );
                }
                _mm256_storeu_ps(cp.add(j), acc);
                j += 8;
            }
            // scalar tail: fused like the lanes, so one tolerance
            // contract covers every element
            while j < n {
                let mut acc = 0.0f32;
                for (p, &av) in arow.iter().enumerate() {
                    acc = av.mul_add(b[p * n + j], acc);
                }
                crow[j] = acc;
                j += 1;
            }
        }
    }

    /// Wrapping-int32 matmul (the i8/i16 low-bit artifacts after their
    /// operand wrap). Bitwise identical to the scalar kernel.
    ///
    /// # Safety
    /// AVX2 available; slice lengths checked by the safe wrapper.
    #[target_feature(enable = "avx2")]
    pub unsafe fn matmul_i32(a: &[i32], b: &[i32], m: usize, k: usize, n: usize, c: &mut [i32]) {
        let bp = b.as_ptr();
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            let cp = crow.as_mut_ptr();
            let mut j = 0;
            while j + 16 <= n {
                let mut acc0 = _mm256_setzero_si256();
                let mut acc1 = _mm256_setzero_si256();
                for (p, &av) in arow.iter().enumerate() {
                    if av == 0 {
                        // exact for integers: adding 0 never changes bits
                        continue;
                    }
                    let avv = _mm256_set1_epi32(av);
                    let row = bp.add(p * n + j);
                    acc0 = _mm256_add_epi32(
                        acc0,
                        _mm256_mullo_epi32(avv, _mm256_loadu_si256(row as *const __m256i)),
                    );
                    acc1 = _mm256_add_epi32(
                        acc1,
                        _mm256_mullo_epi32(
                            avv,
                            _mm256_loadu_si256(row.add(8) as *const __m256i),
                        ),
                    );
                }
                _mm256_storeu_si256(cp.add(j) as *mut __m256i, acc0);
                _mm256_storeu_si256(cp.add(j + 8) as *mut __m256i, acc1);
                j += 16;
            }
            while j + 8 <= n {
                let mut acc = _mm256_setzero_si256();
                for (p, &av) in arow.iter().enumerate() {
                    if av == 0 {
                        continue;
                    }
                    acc = _mm256_add_epi32(
                        acc,
                        _mm256_mullo_epi32(
                            _mm256_set1_epi32(av),
                            _mm256_loadu_si256(bp.add(p * n + j) as *const __m256i),
                        ),
                    );
                }
                _mm256_storeu_si256(cp.add(j) as *mut __m256i, acc);
                j += 8;
            }
            while j < n {
                let mut acc = 0i32;
                for (p, &av) in arow.iter().enumerate() {
                    acc = acc.wrapping_add(av.wrapping_mul(b[p * n + j]));
                }
                crow[j] = acc;
                j += 1;
            }
        }
    }

    /// Valid-mode int32 correlation of one tile, 8 output columns per
    /// vector, kernel tap broadcast. Bitwise identical to
    /// `filter2d_ref` (wrapping integer arithmetic).
    ///
    /// # Safety
    /// AVX2 available; `x` holds at least `(oh+taps-1)*xw` elements
    /// with `ow+taps-1 <= xw`, `out` holds `oh*ow` — the safe wrapper
    /// checks all of it.
    #[target_feature(enable = "avx2")]
    pub unsafe fn filter2d_i32(
        x: &[i32],
        xw: usize,
        kern: &[i32],
        taps: usize,
        oh: usize,
        ow: usize,
        out: &mut [i32],
    ) {
        let xp = x.as_ptr();
        for i in 0..oh {
            let orow = &mut out[i * ow..(i + 1) * ow];
            let op = orow.as_mut_ptr();
            let mut j = 0;
            while j + 8 <= ow {
                let mut acc = _mm256_setzero_si256();
                for u in 0..taps {
                    let base = xp.add((i + u) * xw + j);
                    for v in 0..taps {
                        let kv = _mm256_set1_epi32(kern[u * taps + v]);
                        let xv = _mm256_loadu_si256(base.add(v) as *const __m256i);
                        acc = _mm256_add_epi32(acc, _mm256_mullo_epi32(kv, xv));
                    }
                }
                _mm256_storeu_si256(op.add(j) as *mut __m256i, acc);
                j += 8;
            }
            while j < ow {
                let mut acc = 0i32;
                for u in 0..taps {
                    for v in 0..taps {
                        let xv = x[(i + u) * xw + (j + v)];
                        acc = acc.wrapping_add(xv.wrapping_mul(kern[u * taps + v]));
                    }
                }
                orow[j] = acc;
                j += 1;
            }
        }
    }

    /// One radix-2 FFT stage (`len >= 4`) over the interleaved (re, im)
    /// f64 buffer: two butterflies per iteration through 256-bit lanes.
    ///
    /// Per butterfly the lane arithmetic is exactly the scalar stage's
    /// `tr = wr*or − wi*oi; ti = wr*oi + wi*or; e ± t` — `addsub`
    /// performs one IEEE sub on even lanes and one IEEE add on odd
    /// lanes of already-rounded products, so the result is bitwise
    /// identical to the scalar tier.
    ///
    /// # Safety
    /// AVX2 available; `buf.len()` = 2n with `len` dividing n,
    /// `tw.len()` = len (interleaved half-stage twiddles), `len >= 4`
    /// — the safe wrapper checks all of it.
    #[target_feature(enable = "avx2")]
    pub unsafe fn fft_stage(buf: &mut [f64], tw: &[f64], len: usize) {
        let n = buf.len() / 2;
        let half = len / 2;
        let bp = buf.as_mut_ptr();
        let mut start = 0;
        while start < n {
            let mut k = 0;
            while k < half {
                // [wr0, wi0, wr1, wi1] for butterflies k and k+1
                let w = _mm256_loadu_pd(tw.as_ptr().add(2 * k));
                let e_ptr = bp.add(2 * (start + k));
                let o_ptr = bp.add(2 * (start + k + half));
                let e = _mm256_loadu_pd(e_ptr);
                let o = _mm256_loadu_pd(o_ptr);
                let wr = _mm256_movedup_pd(w); //      [wr0, wr0, wr1, wr1]
                let wi = _mm256_permute_pd(w, 0b1111); // [wi0, wi0, wi1, wi1]
                let osw = _mm256_permute_pd(o, 0b0101); // [oi0, or0, oi1, or1]
                // even lanes wr*or − wi*oi (= tr), odd wr*oi + wi*or (= ti)
                let t = _mm256_addsub_pd(_mm256_mul_pd(wr, o), _mm256_mul_pd(wi, osw));
                _mm256_storeu_pd(e_ptr, _mm256_add_pd(e, t));
                _mm256_storeu_pd(o_ptr, _mm256_sub_pd(e, t));
                k += 2;
            }
            start += len;
        }
    }
}

/// Runtime capability gate for the SIMD tier: AVX2 (integer/f64 lanes)
/// plus FMA (the f32 matmul contract). `false` on non-x86_64 builds.
pub fn available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Batched f32 matmul over operands stacked along a leading batch dim
/// (`a` = [batch, m, k], `b` = [batch, k, n], `c` = [batch, m, n],
/// overwritten). Returns `false` (untouched `c`) when the SIMD tier is
/// unavailable. A single job is `batch == 1` — the single-job and
/// batched paths run the *same* kernel, so batching stays bitwise
/// invisible within the tier.
pub fn matmul_f32_batch_into(
    a: &[f32],
    b: &[f32],
    batch: usize,
    m: usize,
    k: usize,
    n: usize,
    c: &mut [f32],
) -> bool {
    assert_eq!(a.len(), batch * m * k, "stacked A shape mismatch");
    assert_eq!(b.len(), batch * k * n, "stacked B shape mismatch");
    assert_eq!(c.len(), batch * m * n, "stacked C shape mismatch");
    if !available() {
        return false;
    }
    #[cfg(target_arch = "x86_64")]
    {
        for t in 0..batch {
            // Safety: `available()` just confirmed AVX2+FMA; slice
            // bounds established by the asserts above.
            unsafe {
                x86::matmul_f32(
                    &a[t * m * k..(t + 1) * m * k],
                    &b[t * k * n..(t + 1) * k * n],
                    m,
                    k,
                    n,
                    &mut c[t * m * n..(t + 1) * m * n],
                );
            }
        }
        true
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        unreachable!("available() is false off x86_64")
    }
}

/// Wrapping-int32 matmul; `c` is overwritten. Returns `false`
/// (untouched `c`) when the SIMD tier is unavailable.
pub fn matmul_i32_into(a: &[i32], b: &[i32], m: usize, k: usize, n: usize, c: &mut [i32]) -> bool {
    assert_eq!(a.len(), m * k, "A shape mismatch");
    assert_eq!(b.len(), k * n, "B shape mismatch");
    assert_eq!(c.len(), m * n, "C shape mismatch");
    if !available() {
        return false;
    }
    #[cfg(target_arch = "x86_64")]
    {
        // Safety: AVX2 confirmed; bounds established above.
        unsafe { x86::matmul_i32(a, b, m, k, n, c) };
        true
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        unreachable!("available() is false off x86_64")
    }
}

/// Valid-mode int32 correlation of one `xh x xw` tile with a square
/// `taps x taps` kernel into `out` (`oh*ow`). Returns `false` when the
/// SIMD tier is unavailable.
pub fn filter2d_i32_into(
    x: &[i32],
    xh: usize,
    xw: usize,
    kern: &[i32],
    taps: usize,
    out: &mut [i32],
) -> bool {
    assert!(taps >= 1 && xh >= taps && xw >= taps, "tile smaller than the kernel");
    let (oh, ow) = (xh - (taps - 1), xw - (taps - 1));
    assert_eq!(x.len(), xh * xw, "tile shape mismatch");
    assert_eq!(kern.len(), taps * taps, "kernel shape mismatch");
    assert_eq!(out.len(), oh * ow, "output shape mismatch");
    if !available() {
        return false;
    }
    #[cfg(target_arch = "x86_64")]
    {
        // Safety: AVX2 confirmed; the asserts pin every access —
        // max load index (oh-1+taps-1)*xw + (ow-8)+taps-1+7 < xh*xw.
        unsafe { x86::filter2d_i32(x, xw, kern, taps, oh, ow, out) };
        true
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        unreachable!("available() is false off x86_64")
    }
}

/// One radix-2 FFT stage over the interleaved (re, im) f64 buffer.
/// `tw` is the stage's interleaved twiddle slice (`len` values = len/2
/// complex factors). Returns `false` when the SIMD tier is unavailable
/// or the stage is too narrow to vectorize (`len < 4` — the caller's
/// scalar stage handles it).
pub fn fft_stage(buf: &mut [f64], tw: &[f64], len: usize) -> bool {
    let n = buf.len() / 2;
    assert_eq!(buf.len() % 2, 0, "interleaved buffer must be even-length");
    assert!(len.is_power_of_two() && len <= n.max(1), "stage width out of range");
    assert_eq!(tw.len(), len, "stage twiddle slice must hold len/2 complex values");
    if len < 4 || !available() {
        return false;
    }
    #[cfg(target_arch = "x86_64")]
    {
        // Safety: AVX2 confirmed; len >= 4 makes half even, so the
        // 2-butterfly steps tile each group exactly.
        unsafe { x86::fft_stage(buf, tw, len) };
        true
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        unreachable!("available() is false off x86_64")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Cross-tier parity is pinned exhaustively in
    // rust/tests/kernel_tiers.rs; these unit tests cover the wrapper
    // contracts that hold on every machine.

    #[test]
    fn wrappers_refuse_nothing_silently() {
        // On a non-SIMD machine every wrapper must return false and
        // leave the output untouched; on a SIMD machine they must run.
        let a = vec![1.0f32; 4];
        let b = vec![1.0f32; 4];
        let mut c = vec![-1.0f32; 4];
        let ran = matmul_f32_batch_into(&a, &b, 1, 2, 2, 2, &mut c);
        assert_eq!(ran, available());
        if !ran {
            assert!(c.iter().all(|&v| v == -1.0), "fallback must not scribble");
        } else {
            assert_eq!(c, vec![2.0, 2.0, 2.0, 2.0]);
        }
    }

    #[test]
    fn narrow_fft_stage_defers_to_scalar() {
        // len == 2 stages are always the caller's scalar loop
        let mut buf = vec![0.0f64; 8];
        let tw = vec![1.0, 0.0];
        assert!(!fft_stage(&mut buf, &tw, 2));
    }

    #[test]
    #[should_panic(expected = "stacked A shape mismatch")]
    fn wrapper_asserts_shapes_before_any_unsafe() {
        let mut c = vec![0.0f32; 4];
        matmul_f32_batch_into(&[0.0; 3], &[0.0; 4], 1, 2, 2, 2, &mut c);
    }
}
