//! Kernel dispatch tiers for the interpreter backend.
//!
//! EA4RCA's premise is that kernel throughput, not communication, should
//! be the ceiling for regular CA algorithms — so the default numerics
//! path cannot stay scalar, unblocked-at-the-ISA-level Rust. The interp
//! backend now carries two kernel tiers:
//!
//! * [`KernelTier::Scalar`] — the portable reference kernels
//!   (`tensor::matmul_ref` and friends). Always available, on every
//!   architecture; the bitwise ground truth the parity suite pins the
//!   other tier against.
//! * [`KernelTier::Simd`] — explicit `std::arch` x86_64 AVX2/FMA
//!   kernels (see [`super::simd`]), selected only after runtime feature
//!   detection. Integer kernels and the FFT butterflies are bitwise
//!   identical to the scalar tier; the f32 matmul family trades bitwise
//!   equality for FMA lanes under a pinned tolerance contract (see
//!   DESIGN.md, "Kernel dispatch tiers").
//!
//! The tier is resolved **once per backend instance** (and recorded in
//! every `PreparedArtifact` it builds), never per call: detection is a
//! startup decision, the hot path only branches on an enum. On top of
//! either tier sits the worker-pool parallel batch path
//! ([`super::parallel`]), sized by [`TierConfig::pool_threads`].
//!
//! Knobs (environment, read at backend construction):
//!
//! * `EA4RCA_KERNEL_TIER` = `auto` (default) | `scalar` | `simd`.
//!   `scalar` forces the portable tier anywhere (the runtime-fallback
//!   drill CI runs); `simd` demands AVX2+FMA and fails loudly when the
//!   CPU lacks it, so a "fast" deployment can never silently degrade.
//! * `EA4RCA_POOL_THREADS` = worker-pool width for micro-batch fan-out
//!   (default: `available_parallelism`; `1` disables the pool — the
//!   right setting when the serving layer already runs one worker per
//!   core, see README).

use std::fmt;

use anyhow::{bail, Result};

use super::simd;

/// Which kernel implementation family serves an artifact.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelTier {
    /// Portable scalar reference kernels (every architecture).
    Scalar,
    /// x86_64 AVX2/FMA kernels behind runtime feature detection.
    Simd,
}

impl KernelTier {
    pub fn name(self) -> &'static str {
        match self {
            KernelTier::Scalar => "scalar",
            KernelTier::Simd => "simd",
        }
    }

    /// Whether this build + CPU can run the SIMD tier (runtime
    /// detection; always `false` off x86_64).
    pub fn simd_supported() -> bool {
        simd::available()
    }
}

impl fmt::Display for KernelTier {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// A micro-batch must be at least this many jobs before the worker pool
/// engages: below it, thread spawn + join costs more than the fan-out
/// saves (the sequential stacked kernels are already amortized).
pub const MIN_PARALLEL_JOBS: usize = 4;

/// The backend's resolved kernel-dispatch configuration: which tier
/// every `PreparedArtifact` will record, and how wide the micro-batch
/// worker pool fans out. Resolved once at backend construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TierConfig {
    pub tier: KernelTier,
    /// Worker-pool width for `execute_batch` fan-out (1 = disabled).
    pub pool_threads: usize,
}

impl TierConfig {
    /// Auto-detect: SIMD when the CPU supports it, pool as wide as the
    /// machine. Ignores the environment (see [`TierConfig::from_env`]).
    pub fn detect() -> TierConfig {
        TierConfig {
            tier: if simd::available() { KernelTier::Simd } else { KernelTier::Scalar },
            pool_threads: default_pool_threads(),
        }
    }

    /// The portable configuration: scalar kernels, no pool. What the
    /// parity suite compares everything against.
    pub fn scalar() -> TierConfig {
        TierConfig { tier: KernelTier::Scalar, pool_threads: 1 }
    }

    /// Strict environment resolution (`EA4RCA_KERNEL_TIER`,
    /// `EA4RCA_POOL_THREADS`): unknown values and an unsatisfiable
    /// `simd` request are loud errors. `BackendKind::create` uses this,
    /// so a CLI run with a bad knob fails readably at startup.
    pub fn from_env() -> Result<TierConfig> {
        TierConfig::resolve(
            std::env::var("EA4RCA_KERNEL_TIER").ok().as_deref(),
            std::env::var("EA4RCA_POOL_THREADS").ok().as_deref(),
            simd::available(),
            default_pool_threads(),
        )
    }

    /// Lenient environment resolution for infallible constructors
    /// (`InterpBackend::new`): a bad knob falls back to auto-detection
    /// with a note on stderr instead of a panic or a silent ignore.
    pub fn from_env_lenient() -> TierConfig {
        match TierConfig::from_env() {
            Ok(cfg) => cfg,
            Err(e) => {
                eprintln!("note: {e}; using the auto-detected kernel tier");
                TierConfig::detect()
            }
        }
    }

    /// The pure resolution rule behind [`TierConfig::from_env`], split
    /// out so tests can exercise every branch without touching
    /// process-global environment variables.
    pub fn resolve(
        tier_req: Option<&str>,
        pool_req: Option<&str>,
        simd_supported: bool,
        default_threads: usize,
    ) -> Result<TierConfig> {
        let tier = match tier_req {
            None | Some("") | Some("auto") => {
                if simd_supported {
                    KernelTier::Simd
                } else {
                    KernelTier::Scalar
                }
            }
            Some("scalar") => KernelTier::Scalar,
            Some("simd") => {
                if !simd_supported {
                    bail!(
                        "EA4RCA_KERNEL_TIER=simd but this CPU/build has no AVX2+FMA \
                         (use auto or scalar)"
                    );
                }
                KernelTier::Simd
            }
            Some(other) => {
                bail!(
                    "unknown EA4RCA_KERNEL_TIER {other:?} (expected auto | scalar | simd)"
                )
            }
        };
        let pool_threads = match pool_req {
            None | Some("") => default_threads.max(1),
            Some(s) => match s.parse::<usize>() {
                // 0 and 1 both mean "no pool": a pool of one thread is
                // the sequential path with extra steps
                Ok(n) => n.max(1),
                Err(_) => {
                    bail!("EA4RCA_POOL_THREADS must be an integer, got {s:?}")
                }
            },
        };
        Ok(TierConfig { tier, pool_threads })
    }
}

fn default_pool_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_follows_detection() {
        let on = TierConfig::resolve(None, None, true, 4).unwrap();
        assert_eq!(on.tier, KernelTier::Simd);
        assert_eq!(on.pool_threads, 4);
        let off = TierConfig::resolve(Some("auto"), None, false, 4).unwrap();
        assert_eq!(off.tier, KernelTier::Scalar);
    }

    #[test]
    fn scalar_is_always_satisfiable() {
        for supported in [true, false] {
            let cfg = TierConfig::resolve(Some("scalar"), None, supported, 8).unwrap();
            assert_eq!(cfg.tier, KernelTier::Scalar);
        }
    }

    #[test]
    fn forced_simd_without_hardware_is_a_readable_error() {
        let err = TierConfig::resolve(Some("simd"), None, false, 2).unwrap_err().to_string();
        assert!(err.contains("AVX2"), "{err}");
        assert_eq!(
            TierConfig::resolve(Some("simd"), None, true, 2).unwrap().tier,
            KernelTier::Simd
        );
    }

    #[test]
    fn unknown_tier_lists_the_vocabulary() {
        let err = TierConfig::resolve(Some("waffle"), None, true, 2).unwrap_err().to_string();
        assert!(err.contains("auto | scalar | simd"), "{err}");
    }

    #[test]
    fn pool_parsing_and_floor() {
        assert_eq!(TierConfig::resolve(None, Some("6"), false, 2).unwrap().pool_threads, 6);
        // 0 and 1 both disable the pool
        assert_eq!(TierConfig::resolve(None, Some("0"), false, 2).unwrap().pool_threads, 1);
        assert_eq!(TierConfig::resolve(None, Some("1"), false, 2).unwrap().pool_threads, 1);
        assert!(TierConfig::resolve(None, Some("many"), false, 2).is_err());
    }

    #[test]
    fn detection_agrees_with_the_simd_module() {
        assert_eq!(KernelTier::simd_supported(), simd::available());
        let cfg = TierConfig::detect();
        if KernelTier::simd_supported() {
            assert_eq!(cfg.tier, KernelTier::Simd);
        } else {
            assert_eq!(cfg.tier, KernelTier::Scalar);
        }
        assert!(cfg.pool_threads >= 1);
        assert_eq!(TierConfig::scalar(), TierConfig {
            tier: KernelTier::Scalar,
            pool_threads: 1
        });
    }

    #[test]
    fn tier_names_render() {
        assert_eq!(KernelTier::Scalar.name(), "scalar");
        assert_eq!(format!("{}", KernelTier::Simd), "simd");
    }
}
