//! Hand-rolled worker-pool fan-out for micro-batches (no registry
//! deps — the workspace is hermetic, so no rayon).
//!
//! The interp backend's `execute_batch` path turns a coalesced
//! micro-batch into `jobs` independent per-job kernel invocations over
//! disjoint slices of one stacked output buffer. [`for_each_job`]
//! splits that output into **contiguous per-thread chunks** with
//! `split_at_mut` and runs each chunk on a scoped `std::thread` worker:
//!
//! * Contiguous chunking keeps each worker streaming through adjacent
//!   cache lines instead of interleaving.
//! * `std::thread::scope` lets workers borrow the batch inputs and the
//!   output slices directly — no `Arc`, no `'static` bounds, no
//!   channels; the join is the scope exit.
//! * Each job runs the *same* kernel closure the sequential path runs,
//!   on the same disjoint slice, so the fan-out is invisible to the
//!   numerics: batch==sequential stays bitwise per tier (pinned by
//!   `rust/tests/kernel_tiers.rs`).
//!
//! The pool engages only when `threads > 1` and
//! `jobs >= MIN_PARALLEL_JOBS` (see [`super::tier`]); otherwise the
//! sequential loop runs inline with zero spawn cost.

use super::tier::MIN_PARALLEL_JOBS;

/// Run `job(t, out_t)` for every `t in 0..jobs`, where `out_t` is job
/// t's disjoint `job_len` slice of `out`. Fans out across up to
/// `threads` scoped workers when the batch is wide enough; runs the
/// identical sequential loop otherwise. Returns the number of worker
/// threads actually used (1 = sequential).
///
/// `job` must be `Sync` (shared by reference across workers) and is
/// handed disjoint output slices, so interior order is the caller's
/// kernel order — the parallel and sequential paths produce bitwise
/// identical buffers.
///
/// Panics if `out.len() != jobs * job_len`. A worker panic propagates
/// out of the scope (no torn silent state).
pub fn for_each_job<F>(
    out: &mut [f32],
    jobs: usize,
    job_len: usize,
    threads: usize,
    job: F,
) -> usize
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    for_each_job_impl(out, jobs, job_len, threads, job)
}

/// [`for_each_job`] for i32 outputs (the low-bit matmul and filter2d
/// artifacts). Same contract.
pub fn for_each_job_i32<F>(
    out: &mut [i32],
    jobs: usize,
    job_len: usize,
    threads: usize,
    job: F,
) -> usize
where
    F: Fn(usize, &mut [i32]) + Sync,
{
    for_each_job_impl(out, jobs, job_len, threads, job)
}

fn for_each_job_impl<T, F>(
    out: &mut [T],
    jobs: usize,
    job_len: usize,
    threads: usize,
    job: F,
) -> usize
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert_eq!(out.len(), jobs * job_len, "stacked output length mismatch");
    let workers = threads.min(jobs).max(1);
    if workers == 1 || jobs < MIN_PARALLEL_JOBS {
        for (t, chunk) in out.chunks_mut(job_len.max(1)).take(jobs).enumerate() {
            job(t, chunk);
        }
        return 1;
    }
    // Contiguous chunks: worker w takes jobs [w*per .. min((w+1)*per, jobs)).
    let per = jobs.div_ceil(workers);
    let jobref = &job;
    std::thread::scope(|scope| {
        let mut rest = &mut out[..];
        let mut first = 0;
        for _ in 0..workers {
            if first >= jobs {
                break;
            }
            let count = per.min(jobs - first);
            let (mine, tail) = rest.split_at_mut(count * job_len);
            rest = tail;
            let base = first;
            scope.spawn(move || {
                for (off, chunk) in mine.chunks_mut(job_len.max(1)).take(count).enumerate() {
                    jobref(base + off, chunk);
                }
            });
            first += count;
        }
    });
    workers
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill(t: usize, chunk: &mut [f32]) {
        for (i, v) in chunk.iter_mut().enumerate() {
            *v = (t * 1000 + i) as f32;
        }
    }

    #[test]
    fn parallel_matches_sequential_bitwise() {
        let jobs = 9; // deliberately not a multiple of the worker count
        let job_len = 7;
        let mut seq = vec![0.0f32; jobs * job_len];
        let used_seq = for_each_job(&mut seq, jobs, job_len, 1, fill);
        assert_eq!(used_seq, 1);
        for threads in [2, 3, 4, 16] {
            let mut par = vec![0.0f32; jobs * job_len];
            let used = for_each_job(&mut par, jobs, job_len, threads, fill);
            assert!(used >= 1 && used <= threads.min(jobs));
            assert_eq!(par, seq, "threads={threads}");
        }
    }

    #[test]
    fn small_batches_stay_sequential() {
        let jobs = MIN_PARALLEL_JOBS - 1;
        let mut out = vec![0.0f32; jobs * 3];
        assert_eq!(for_each_job(&mut out, jobs, 3, 8, fill), 1);
    }

    #[test]
    fn i32_variant_covers_every_job_once() {
        let jobs = 11;
        let job_len = 5;
        let mut out = vec![-1i32; jobs * job_len];
        for_each_job_i32(&mut out, jobs, job_len, 4, |t, chunk| {
            for v in chunk.iter_mut() {
                assert_eq!(*v, -1, "job {t} saw an already-written cell");
                *v = t as i32;
            }
        });
        for (idx, &v) in out.iter().enumerate() {
            assert_eq!(v, (idx / job_len) as i32);
        }
    }

    #[test]
    #[should_panic(expected = "stacked output length mismatch")]
    fn length_mismatch_is_loud() {
        let mut out = vec![0.0f32; 5];
        for_each_job(&mut out, 2, 3, 1, fill);
    }

    #[test]
    fn zero_jobs_is_a_noop() {
        let mut out: Vec<f32> = vec![];
        assert_eq!(for_each_job(&mut out, 0, 16, 8, fill), 1);
    }
}
