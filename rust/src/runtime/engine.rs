//! The runtime: manifest lookup, input validation, execution statistics
//! — backend-agnostic. The actual substrate (pure-Rust interpreter or
//! PJRT) lives behind [`Backend`]; this type owns everything the
//! substrate should not care about, so shape bugs surface with readable
//! errors instead of substrate aborts and stats are comparable across
//! backends.

use std::collections::{HashMap, HashSet};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::runtime::backend::{Backend, BackendKind, CacheStats, CostPrediction};
use crate::runtime::manifest::Manifest;
use crate::runtime::tensor::Tensor;
use crate::runtime::tier::KernelTier;
use crate::util::sync::lock_clean;

/// Per-artifact execution statistics (hot-path observability).
#[derive(Debug, Default, Clone)]
pub struct ExecStats {
    pub executions: u64,
    pub total_exec_secs: f64,
    /// Seconds spent preparing (compiling) the artifact on this backend.
    pub compile_secs: f64,
    /// How many backend calls were micro-batched `execute_batch`
    /// dispatches (each covering one or more of `executions`).
    pub batch_calls: u64,
    /// Times the runtime built this artifact's prepared state (the
    /// paper's one-time setup). Stays 1 for the life of a runtime.
    pub prepare_builds: u64,
    /// Times the prepared-artifact guard was consulted and the artifact
    /// was already built — the hot path never re-resolving metadata.
    pub prepare_hits: u64,
    /// Which kernel tier served this artifact (recorded at prepare
    /// time; `None` on substrates without a tier notion). Makes a
    /// debug-mode or non-AVX2 run self-describing in the serve report.
    pub tier: Option<KernelTier>,
}

/// The execution runtime. Thread-safe: preparation happens under a
/// lock and `execute` takes `&self`.
pub struct Runtime {
    backend: Box<dyn Backend>,
    kind: BackendKind,
    manifest: Manifest,
    prepared: Mutex<HashSet<String>>,
    stats: Mutex<HashMap<String, ExecStats>>,
}

impl Runtime {
    /// Create a runtime over the default artifact directory, selecting
    /// the backend from `$EA4RCA_BACKEND` (default: interpreter).
    pub fn new() -> Result<Runtime> {
        Runtime::with_dir(Manifest::default_dir())
    }

    /// Create a runtime over `dir`, backend from the environment.
    pub fn with_dir(dir: impl Into<std::path::PathBuf>) -> Result<Runtime> {
        Runtime::with_backend(BackendKind::from_env()?, dir)
    }

    /// Create a runtime with an explicit backend.
    pub fn with_backend(
        kind: BackendKind,
        dir: impl Into<std::path::PathBuf>,
    ) -> Result<Runtime> {
        let manifest = Manifest::load_or_builtin(dir.into())?;
        let backend = kind.create()?;
        Ok(Runtime {
            backend,
            kind,
            manifest,
            prepared: Mutex::new(HashSet::new()),
            stats: Mutex::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Which backend this runtime executes on.
    pub fn backend_kind(&self) -> BackendKind {
        self.kind
    }

    /// Human-readable substrate description.
    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    /// Prepare (compile) the artifact if this runtime has not yet: the
    /// single point where per-artifact setup happens. Returns `true`
    /// when the artifact was already prepared (a guard-set hit) so the
    /// caller can fold the hit count into a stats lock it takes anyway
    /// — the hot path pays one set lookup here, no extra lock and no
    /// String clone.
    fn prepare(&self, meta: &crate::runtime::manifest::ArtifactMeta) -> Result<bool> {
        let mut prepared = lock_clean(&self.prepared);
        if prepared.contains(&meta.name) {
            return Ok(true);
        }
        let t0 = Instant::now();
        self.backend.prepare(&self.manifest, meta)?;
        let dt = t0.elapsed().as_secs_f64();
        prepared.insert(meta.name.clone());
        let mut stats = lock_clean(&self.stats);
        let s = stats.entry(meta.name.clone()).or_default();
        s.compile_secs += dt;
        s.prepare_builds += 1;
        s.tier = self.backend.kernel_tier(meta);
        Ok(false)
    }

    /// Pre-compile a set of artifacts (startup warm-up).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            let meta = self.manifest.get(n)?;
            if self.prepare(meta)? {
                // not hot: account the redundant warm-up as a hit here
                lock_clean(&self.stats)
                    .entry(meta.name.clone())
                    .or_default()
                    .prepare_hits += 1;
            }
        }
        Ok(())
    }

    /// Execute artifact `name` on `inputs`, returning its outputs.
    ///
    /// Inputs are validated against the manifest (shape + dtype) before
    /// touching the backend, so shape bugs surface with readable errors
    /// instead of substrate aborts; output arity is validated on the way
    /// back.
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        // one manifest lookup, no meta clone: this is the serving hot path
        let meta = self.manifest.get(name)?;
        validate_inputs(meta, inputs)?;
        let prepared_hit = self.prepare(meta)?;

        let t0 = Instant::now();
        let outputs = self.backend.execute(meta, inputs)?;
        let dt = t0.elapsed().as_secs_f64();
        {
            let mut stats = lock_clean(&self.stats);
            let s = stats.entry(name.to_string()).or_default();
            s.executions += 1;
            s.total_exec_secs += dt;
            s.prepare_hits += prepared_hit as u64;
        }

        if outputs.len() != meta.outputs.len() {
            bail!(
                "artifact {name}: manifest says {} outputs, backend returned {}",
                meta.outputs.len(),
                outputs.len()
            );
        }
        Ok(outputs)
    }

    /// Execute a micro-batch of same-artifact jobs in one backend
    /// dispatch (manifest lookup, validation sweep, prepare, and stats
    /// update amortized over the whole batch).
    ///
    /// The outer `Result` covers artifact-level failures (unknown name,
    /// compile error) — nothing ran. The inner per-job `Result`s keep
    /// job isolation: a job with malformed inputs fails alone while the
    /// rest of the batch executes.
    pub fn execute_batch(
        &self,
        name: &str,
        jobs: &[Vec<Tensor>],
    ) -> Result<Vec<Result<Vec<Tensor>>>> {
        let meta = self.manifest.get(name)?;
        let prepared_hit = self.prepare(meta)?;

        // validation sweep: remember which jobs are runnable
        let verdicts: Vec<Option<anyhow::Error>> = jobs
            .iter()
            .map(|inputs| validate_inputs(meta, inputs).err())
            .collect();
        let valid: Vec<usize> =
            (0..jobs.len()).filter(|&i| verdicts[i].is_none()).collect();

        let t0 = Instant::now();
        let outputs: Vec<Result<Vec<Tensor>>> = if valid.len() == jobs.len() {
            // batched fast path: a failure here is artifact-level
            // (every job rode the same dispatch), so the outer ? is
            // the honest signal
            self.backend.execute_batch(meta, jobs)?.into_iter().map(Ok).collect()
        } else {
            // rare path: batch with malformed members — run the valid
            // ones per job rather than deep-copying tensors into a
            // dense sub-batch; a job's own backend error stays that
            // job's result instead of failing the whole batch
            valid.iter().map(|&i| self.backend.execute(meta, &jobs[i])).collect()
        };
        let dt = t0.elapsed().as_secs_f64();
        if outputs.len() != valid.len() {
            bail!(
                "artifact {name}: batch of {} jobs returned {} results",
                valid.len(),
                outputs.len()
            );
        }
        {
            // count only jobs that actually produced outputs (on the
            // fallback path a job's backend error is its own result,
            // not an execution)
            let ok_jobs = outputs.iter().filter(|r| r.is_ok()).count() as u64;
            let mut stats = lock_clean(&self.stats);
            let s = stats.entry(name.to_string()).or_default();
            s.executions += ok_jobs;
            s.total_exec_secs += dt;
            s.batch_calls += 1;
            s.prepare_hits += prepared_hit as u64;
        }

        // stitch per-job results back into submission order (valid
        // slots are placeholders until the loop below fills them)
        let mut results: Vec<Result<Vec<Tensor>>> = verdicts
            .into_iter()
            .map(|v| Err(v.unwrap_or_else(|| anyhow::anyhow!("unreached"))))
            .collect();
        for (&i, outs) in valid.iter().zip(outputs) {
            results[i] = match outs {
                Err(e) => Err(e),
                Ok(outs) if outs.len() != meta.outputs.len() => Err(anyhow::anyhow!(
                    "artifact {name}: manifest says {} outputs, backend returned {}",
                    meta.outputs.len(),
                    outs.len()
                )),
                Ok(outs) => Ok(outs),
            };
        }
        Ok(results)
    }

    pub fn stats(&self) -> HashMap<String, ExecStats> {
        lock_clean(&self.stats).clone()
    }

    /// Backend-level prepared-artifact cache counters (builds should
    /// equal the number of distinct artifacts this runtime has run).
    pub fn cache_stats(&self) -> CacheStats {
        self.backend.cache_stats()
    }

    /// Predicted cost of dispatching `batch` jobs of artifact `name`,
    /// when the backend carries a cost model (the sim backend runs the
    /// event-driven AIE lane simulation, memoized per batch size).
    /// `None` on measuring-only substrates or unknown artifacts.
    pub fn predict(&self, name: &str, batch: usize) -> Option<CostPrediction> {
        let meta = self.manifest.get(name).ok()?;
        self.backend.predict(meta, batch)
    }

    /// The kernel tier serving artifact `name` on this runtime's
    /// backend, once prepared (`None` for unprepared artifacts and
    /// tier-less substrates).
    pub fn kernel_tier(&self, name: &str) -> Option<KernelTier> {
        let meta = self.manifest.get(name).ok()?;
        self.backend.kernel_tier(meta)
    }

    /// Mean execution seconds for an artifact, if it has run.
    pub fn mean_exec_secs(&self, name: &str) -> Option<f64> {
        let stats = lock_clean(&self.stats);
        stats.get(name).and_then(|s| {
            (s.executions > 0).then(|| s.total_exec_secs / s.executions as f64)
        })
    }
}

/// Check one job's inputs against the manifest (arity, shape, dtype) so
/// shape bugs surface with readable errors instead of substrate aborts.
fn validate_inputs(
    meta: &crate::runtime::manifest::ArtifactMeta,
    inputs: &[Tensor],
) -> Result<()> {
    let name = &meta.name;
    if inputs.len() != meta.inputs.len() {
        bail!(
            "artifact {name}: expected {} inputs, got {}",
            meta.inputs.len(),
            inputs.len()
        );
    }
    for (i, (t, m)) in inputs.iter().zip(&meta.inputs).enumerate() {
        if t.shape() != m.shape.as_slice() || t.dtype() != m.dtype {
            bail!(
                "artifact {name} input {i}: expected {:?}{:?}, got {:?}{:?}",
                m.dtype,
                m.shape,
                t.dtype(),
                t.shape()
            );
        }
    }
    Ok(())
}
