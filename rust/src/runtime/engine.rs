//! PJRT execution engine: one CPU client, lazily-compiled executables
//! cached per artifact name, literal marshalling, and execution stats.
//!
//! Compilation happens once per artifact per process (the paper's analogue
//! is the `libadf.a` build); the serving hot path only marshals literals
//! and calls `execute`.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::runtime::manifest::Manifest;
use crate::runtime::tensor::Tensor;

/// Per-artifact execution statistics (hot-path observability).
#[derive(Debug, Default, Clone)]
pub struct ExecStats {
    pub executions: u64,
    pub total_exec_secs: f64,
    pub compile_secs: f64,
}

/// The PJRT runtime. Thread-safe: executables are compiled under a lock
/// and `execute` takes `&self`.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
    stats: Mutex<HashMap<String, ExecStats>>,
}

impl Runtime {
    /// Create a runtime over the default artifact directory.
    pub fn new() -> Result<Runtime> {
        Runtime::with_dir(Manifest::default_dir())
    }

    pub fn with_dir(dir: impl Into<std::path::PathBuf>) -> Result<Runtime> {
        let manifest = Manifest::load(dir.into())?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
            stats: Mutex::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the executable for `name`.
    fn executable(&self, name: &str) -> Result<()> {
        let mut cache = self.cache.lock().unwrap();
        if cache.contains_key(name) {
            return Ok(());
        }
        let path = self.manifest.hlo_path(name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name}"))?;
        let dt = t0.elapsed().as_secs_f64();
        cache.insert(name.to_string(), exe);
        self.stats
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .compile_secs += dt;
        Ok(())
    }

    /// Pre-compile a set of artifacts (startup warm-up).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Execute artifact `name` on `inputs`, returning its outputs.
    ///
    /// Inputs are validated against the manifest (shape + dtype) before
    /// touching PJRT, so shape bugs surface with readable errors instead
    /// of XLA aborts. The lowered modules use `return_tuple=True`, so the
    /// single result literal is a tuple unpacked per the manifest.
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let meta = self.manifest.get(name)?.clone();
        if inputs.len() != meta.inputs.len() {
            bail!(
                "artifact {name}: expected {} inputs, got {}",
                meta.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, m)) in inputs.iter().zip(&meta.inputs).enumerate() {
            if t.shape() != m.shape.as_slice() || t.dtype() != m.dtype {
                bail!(
                    "artifact {name} input {i}: expected {:?}{:?}, got {:?}{:?}",
                    m.dtype,
                    m.shape,
                    t.dtype(),
                    t.shape()
                );
            }
        }
        self.executable(name)?;

        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;

        let t0 = Instant::now();
        let cache = self.cache.lock().unwrap();
        let exe = cache.get(name).expect("compiled above");
        let result = exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing artifact {name}"))?[0][0]
            .to_literal_sync()?;
        drop(cache);
        let dt = t0.elapsed().as_secs_f64();
        {
            let mut stats = self.stats.lock().unwrap();
            let s = stats.entry(name.to_string()).or_default();
            s.executions += 1;
            s.total_exec_secs += dt;
        }

        // return_tuple=True: decompose the tuple literal per manifest arity.
        let parts = result
            .to_tuple()
            .with_context(|| format!("artifact {name}: expected tuple output"))?;
        if parts.len() != meta.outputs.len() {
            bail!(
                "artifact {name}: manifest says {} outputs, tuple has {}",
                meta.outputs.len(),
                parts.len()
            );
        }
        parts
            .iter()
            .zip(&meta.outputs)
            .map(|(lit, m)| Tensor::from_literal(lit, m.dtype, &m.shape))
            .collect()
    }

    pub fn stats(&self) -> HashMap<String, ExecStats> {
        self.stats.lock().unwrap().clone()
    }

    /// Mean execution seconds for an artifact, if it has run.
    pub fn mean_exec_secs(&self, name: &str) -> Option<f64> {
        let stats = self.stats.lock().unwrap();
        stats.get(name).and_then(|s| {
            (s.executions > 0).then(|| s.total_exec_secs / s.executions as f64)
        })
    }
}
