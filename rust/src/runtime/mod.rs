//! Runtime — loads the AOT-compiled HLO artifacts and executes them on the
//! PJRT CPU client. This is the only place the `xla` crate is touched; the
//! rest of the coordinator sees [`Tensor`]s and artifact names.
//!
//! Flow (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Python is never on this path: `make artifacts` has already lowered the
//! Layer-1/Layer-2 graphs to `artifacts/*.hlo.txt`.

pub mod engine;
pub mod manifest;
pub mod tensor;

pub use engine::Runtime;
pub use manifest::{ArtifactMeta, Manifest, TensorMeta};
pub use tensor::{DType, Tensor};
