//! Runtime — loads the AOT artifact manifest and executes artifacts on
//! a pluggable [`Backend`]: the pure-Rust interpreter (default, zero
//! native dependencies), the sim backend (interpreter numerics + the
//! event-driven AIE cost model attaching a [`CostPrediction`] to every
//! dispatch), or the PJRT CPU client (`--features pjrt`).
//! The rest of the coordinator sees [`Tensor`]s and artifact names; no
//! other module touches a substrate API.
//!
//! PJRT flow (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` ->
//! `HloModuleProto::from_text_file` -> `client.compile` -> `execute`,
//! over `artifacts/*.hlo.txt` lowered once by `make artifacts`.
//! Interpreter flow: dispatch on the artifact name to the reference
//! kernels mirrored from `python/compile/kernels/ref.py`, shapes from
//! the (built-in or on-disk) manifest. Python is never on either path.

pub mod backend;
pub mod engine;
pub mod manifest;
pub mod parallel;
pub mod simd;
pub mod tensor;
pub mod tier;

pub use backend::{Backend, BackendKind, CacheStats, CostPrediction};
pub use engine::Runtime;
pub use manifest::{ArtifactMeta, Manifest, PuTopology, TensorMeta};
pub use tensor::{DType, Tensor};
pub use tier::{KernelTier, TierConfig};
