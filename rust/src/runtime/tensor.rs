//! Host-side tensors marshalled in and out of PJRT literals.
//!
//! Only the two dtypes the artifacts use exist (f32, i32) — keeping this
//! enum closed lets every match be exhaustive.

#[cfg(feature = "pjrt")]
use anyhow::Context;
use anyhow::{bail, Result};

use super::simd;
use super::tier::KernelTier;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn tag(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
        }
    }

    pub fn from_tag(tag: &str) -> Result<DType> {
        match tag {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unknown dtype tag {other:?}"),
        }
    }

    pub fn byte_width(&self) -> usize {
        4
    }
}

/// Dense host tensor: shape + flat data. Row-major, matching the HLO
/// `{1,0}`-style default layouts the artifacts are lowered with.
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor::F32 { shape: shape.to_vec(), data }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor::I32 { shape: shape.to_vec(), data }
    }

    pub fn zeros(dtype: DType, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        match dtype {
            DType::F32 => Tensor::f32(shape, vec![0.0; n]),
            DType::I32 => Tensor::i32(shape, vec![0; n]),
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Tensor::F32 { .. } => DType::F32,
            Tensor::I32 { .. } => DType::I32,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len(),
            Tensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn byte_len(&self) -> usize {
        self.len() * self.dtype().byte_width()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    /// Convert to an XLA literal with this tensor's shape (PJRT backend
    /// marshalling; the interpreter never leaves host memory).
    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
            Tensor::I32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
        };
        Ok(lit)
    }

    /// Read a literal back into a host tensor of known shape/dtype.
    #[cfg(feature = "pjrt")]
    pub fn from_literal(lit: &xla::Literal, dtype: DType, shape: &[usize]) -> Result<Tensor> {
        let t = match dtype {
            DType::F32 => Tensor::f32(shape, lit.to_vec::<f32>().context("literal->f32")?),
            DType::I32 => Tensor::i32(shape, lit.to_vec::<i32>().context("literal->i32")?),
        };
        Ok(t)
    }

    /// Max |a-b| between two same-shaped f32 tensors (test helper).
    pub fn max_abs_diff(&self, other: &Tensor) -> Result<f64> {
        let a = self.as_f32()?;
        let b = other.as_f32()?;
        if a.len() != b.len() {
            bail!("length mismatch {} vs {}", a.len(), b.len());
        }
        Ok(a.iter()
            .zip(b)
            .map(|(x, y)| (x - y).abs() as f64)
            .fold(0.0, f64::max))
    }
}

/// Naive row-major matmul used as the rust-side oracle in tests and the
/// end-to-end example (numpy is not available at runtime, by design).
///
/// Every term is accumulated, even for zero A elements — skipping them
/// would change results for non-finite B (0 * inf = NaN) and break the
/// exact-equivalence contract with [`matmul_batch_ref`].
pub fn matmul_ref(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p];
            let brow = &b[p * n..(p + 1) * n];
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
    c
}

/// Cache-blocked matmul over operands stacked along a leading batch
/// dimension: `a` is `[batch, m, k]`, `b` is `[batch, k, n]`, the result
/// is `[batch, m, n]`. This is the interpreter's micro-batch fast path:
/// one output allocation for the whole batch, and a 4-way k-unrolled
/// inner kernel that keeps a C-row chunk live across four B rows
/// (4x less C load/store traffic than [`matmul_ref`]'s rank-1 updates).
///
/// Per output element the additions happen in the same ascending-k
/// order as [`matmul_ref`], so results are bitwise identical to `batch`
/// independent [`matmul_ref`] calls — batching must never change what a
/// client observes.
pub fn matmul_batch_ref(
    a: &[f32],
    b: &[f32],
    batch: usize,
    m: usize,
    k: usize,
    n: usize,
) -> Vec<f32> {
    let mut c = Vec::new();
    matmul_batch_into(a, b, batch, m, k, n, &mut c);
    c
}

/// [`matmul_batch_ref`] writing into a caller-owned buffer (cleared and
/// resized), so the serving hot path can reuse one allocation across
/// micro-batch dispatches instead of allocating `batch*m*n` floats per
/// batch. Numerics are identical — this is purely an allocation seam.
pub fn matmul_batch_into(
    a: &[f32],
    b: &[f32],
    batch: usize,
    m: usize,
    k: usize,
    n: usize,
    c: &mut Vec<f32>,
) {
    assert_eq!(a.len(), batch * m * k, "stacked A shape mismatch");
    assert_eq!(b.len(), batch * k * n, "stacked B shape mismatch");
    c.clear();
    c.resize(batch * m * n, 0.0f32);
    for t in 0..batch {
        matmul_block_into(
            &a[t * m * k..(t + 1) * m * k],
            &b[t * k * n..(t + 1) * k * n],
            m,
            k,
            n,
            &mut c[t * m * n..(t + 1) * m * n],
        );
    }
}

/// [`matmul_batch_into`] dispatched by kernel tier: the SIMD tier runs
/// each job through the AVX2/FMA micro-kernel (tolerance contract, see
/// DESIGN.md "Kernel dispatch tiers"), the scalar tier is exactly
/// [`matmul_batch_into`].
pub fn matmul_batch_into_tiered(
    a: &[f32],
    b: &[f32],
    batch: usize,
    m: usize,
    k: usize,
    n: usize,
    c: &mut Vec<f32>,
    tier: KernelTier,
) {
    assert_eq!(a.len(), batch * m * k, "stacked A shape mismatch");
    assert_eq!(b.len(), batch * k * n, "stacked B shape mismatch");
    c.clear();
    c.resize(batch * m * n, 0.0f32);
    if tier == KernelTier::Simd && simd::matmul_f32_batch_into(a, b, batch, m, k, n, c) {
        return;
    }
    for t in 0..batch {
        matmul_block_into(
            &a[t * m * k..(t + 1) * m * k],
            &b[t * k * n..(t + 1) * k * n],
            m,
            k,
            n,
            &mut c[t * m * n..(t + 1) * m * n],
        );
    }
}

/// One job's f32 matmul into a **zeroed** caller slice, dispatched by
/// tier. The single-job, sequential-batch and pooled-batch interp paths
/// all run exactly this kernel, which is what keeps batch==sequential
/// bitwise *within* a tier (cross-tier, the f32 family is a tolerance
/// contract — see DESIGN.md).
pub fn matmul_job_into(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    c: &mut [f32],
    tier: KernelTier,
) {
    if tier == KernelTier::Simd && simd::matmul_f32_batch_into(a, b, 1, m, k, n, c) {
        return;
    }
    matmul_block_into(a, b, m, k, n, c);
}

/// [`matmul_ref`] through the selected tier (fresh output allocation).
pub fn matmul_tiered(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    tier: KernelTier,
) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut c = vec![0.0f32; m * n];
    matmul_job_into(a, b, m, k, n, &mut c, tier);
    c
}

/// The scalar per-job body of [`matmul_batch_into`]: 4-way k-unrolled,
/// accumulating into a zeroed `c` slice. Per output element the
/// additions happen in [`matmul_ref`]'s ascending-k order, so this is
/// bitwise identical to [`matmul_ref`].
fn matmul_block_into(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        let mut p = 0;
        while p + 4 <= k {
            let (a0, a1, a2, a3) = (arow[p], arow[p + 1], arow[p + 2], arow[p + 3]);
            let b0 = &b[p * n..(p + 1) * n];
            let b1 = &b[(p + 1) * n..(p + 2) * n];
            let b2 = &b[(p + 2) * n..(p + 3) * n];
            let b3 = &b[(p + 3) * n..(p + 4) * n];
            for j in 0..n {
                let mut v = crow[j];
                v += a0 * b0[j];
                v += a1 * b1[j];
                v += a2 * b2[j];
                v += a3 * b3[j];
                crow[j] = v;
            }
            p += 4;
        }
        while p < k {
            let av = arow[p];
            let brow = &b[p * n..(p + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
            p += 1;
        }
    }
}

/// Integer matmul with exact int32 accumulation (wrapping, like the
/// hardware accumulator). Lives beside the f32 kernels so the tiers
/// share one home; the interp backend's low-bit artifacts wrap their
/// operands first.
pub fn matmul_i32_ref(a: &[i32], b: &[i32], m: usize, k: usize, n: usize) -> Vec<i32> {
    let mut c = vec![0i32; m * n];
    matmul_i32_scalar_into(a, b, m, k, n, &mut c);
    c
}

/// One job's int32 matmul into a **zeroed** caller slice, dispatched by
/// tier. Wrapping int32 arithmetic is associative, so both tiers are
/// bitwise identical to [`matmul_i32_ref`].
pub fn matmul_i32_job_into(
    a: &[i32],
    b: &[i32],
    m: usize,
    k: usize,
    n: usize,
    c: &mut [i32],
    tier: KernelTier,
) {
    if tier == KernelTier::Simd && simd::matmul_i32_into(a, b, m, k, n, c) {
        return;
    }
    matmul_i32_scalar_into(a, b, m, k, n, c);
}

fn matmul_i32_scalar_into(a: &[i32], b: &[i32], m: usize, k: usize, n: usize, c: &mut [i32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p];
            if av == 0 {
                // exact for integers: adding 0 never changes bits
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] = crow[j].wrapping_add(av.wrapping_mul(brow[j]));
            }
        }
    }
}

/// Precomputed radix-2 FFT plan: bit-reversal permutation plus the
/// twiddle factors of every stage, computed once per artifact and
/// shared by every transform — the interpreter's prepared-artifact
/// cache holds one plan per fft size, used by the single-job *and*
/// micro-batch paths (the trig calls dominate [`fft_ref`]'s cost; the
/// recursive oracle also reallocates at every level).
///
/// [`FftPlan::run`] evaluates the same butterfly dataflow as
/// [`fft_ref`] — identical twiddle angles, identical f64 arithmetic per
/// output — so planned FFT results match the recursive oracle, and any
/// two paths through the plan (scalar, SIMD, batched, pooled) match
/// each other bitwise: the SIMD stage performs the same IEEE mul/sub/
/// add sequence per butterfly, just two butterflies per vector.
pub struct FftPlan {
    n: usize,
    /// Bit-reversal permutation of the input indices.
    rev: Vec<u32>,
    /// Stage twiddles, interleaved (re, im) and concatenated: stage
    /// `len` contributes `len/2` factors `e^{-2πik/len}` (= `len` f64
    /// values), for len = 2, 4, …, n. Interleaved rather than tupled so
    /// the SIMD stage can load them directly — `(f64, f64)` layout is
    /// not guaranteed, `[f64]` is.
    tw: Vec<f64>,
}

impl FftPlan {
    pub fn new(n: usize) -> FftPlan {
        assert!(n.is_power_of_two(), "FFT size must be a power of two");
        let rev = if n <= 1 {
            vec![0u32; n]
        } else {
            let bits = n.trailing_zeros();
            (0..n as u32).map(|i| i.reverse_bits() >> (32 - bits)).collect()
        };
        let mut tw = Vec::with_capacity(2 * n.saturating_sub(1));
        let mut len = 2;
        while len <= n {
            for k in 0..len / 2 {
                let ang = -2.0 * std::f64::consts::PI * k as f64 / len as f64;
                tw.push(ang.cos());
                tw.push(ang.sin());
            }
            len <<= 1;
        }
        FftPlan { n, rev, tw }
    }

    pub fn points(&self) -> usize {
        self.n
    }

    /// Transform one split-plane (re, im) pair through the scalar tier.
    pub fn run(&self, re: &[f32], im: &[f32]) -> (Vec<f32>, Vec<f32>) {
        self.run_with_tier(re, im, KernelTier::Scalar)
    }

    /// Transform one split-plane (re, im) pair through the selected
    /// tier. Both tiers produce bitwise identical results (see the type
    /// docs); the tier only changes how many butterflies fly per
    /// instruction.
    pub fn run_with_tier(&self, re: &[f32], im: &[f32], tier: KernelTier) -> (Vec<f32>, Vec<f32>) {
        let n = self.n;
        assert_eq!(re.len(), n, "re plane length");
        assert_eq!(im.len(), n, "im plane length");
        if n <= 1 {
            return (re.to_vec(), im.to_vec());
        }
        // interleaved (re, im) working buffer — the layout both tiers
        // share
        let mut buf: Vec<f64> = Vec::with_capacity(2 * n);
        for i in 0..n {
            let s = self.rev[i] as usize;
            buf.push(re[s] as f64);
            buf.push(im[s] as f64);
        }
        let mut base = 0;
        let mut len = 2;
        while len <= n {
            let half = len / 2;
            let tw = &self.tw[2 * base..2 * base + len];
            // the len==2 stage and non-SIMD machines take the scalar
            // loop; a vectorized stage is bitwise identical to it
            if !(tier == KernelTier::Simd && simd::fft_stage(&mut buf, tw, len)) {
                for start in (0..n).step_by(len) {
                    for k in 0..half {
                        let (wr, wi) = (tw[2 * k], tw[2 * k + 1]);
                        let e = 2 * (start + k);
                        let o = 2 * (start + k + half);
                        let (er, ei) = (buf[e], buf[e + 1]);
                        let (or_, oi) = (buf[o], buf[o + 1]);
                        let tr = wr * or_ - wi * oi;
                        let ti = wr * oi + wi * or_;
                        buf[e] = er + tr;
                        buf[e + 1] = ei + ti;
                        buf[o] = er - tr;
                        buf[o + 1] = ei - ti;
                    }
                }
            }
            base += half;
            len <<= 1;
        }
        (
            buf.chunks_exact(2).map(|c| c[0] as f32).collect(),
            buf.chunks_exact(2).map(|c| c[1] as f32).collect(),
        )
    }
}

/// Rust-side valid-mode int32 filter oracle (mirrors python ref.py).
pub fn filter2d_ref(x: &[i32], xh: usize, xw: usize, k: &[i32], taps: usize) -> Vec<i32> {
    let oh = xh - (taps - 1);
    let ow = xw - (taps - 1);
    let mut out = vec![0i32; oh * ow];
    filter2d_scalar_into(x, xh, xw, k, taps, &mut out);
    out
}

/// One tile's valid-mode correlation into a caller slice (`oh*ow`,
/// overwritten), dispatched by tier. Wrapping int32 arithmetic makes
/// both tiers bitwise identical to [`filter2d_ref`].
pub fn filter2d_job_into(
    x: &[i32],
    xh: usize,
    xw: usize,
    k: &[i32],
    taps: usize,
    out: &mut [i32],
    tier: KernelTier,
) {
    if tier == KernelTier::Simd && simd::filter2d_i32_into(x, xh, xw, k, taps, out) {
        return;
    }
    filter2d_scalar_into(x, xh, xw, k, taps, out);
}

fn filter2d_scalar_into(x: &[i32], xh: usize, xw: usize, k: &[i32], taps: usize, out: &mut [i32]) {
    let oh = xh - (taps - 1);
    let ow = xw - (taps - 1);
    assert_eq!(out.len(), oh * ow, "output shape mismatch");
    for i in 0..oh {
        for j in 0..ow {
            let mut acc = 0i32;
            for u in 0..taps {
                for v in 0..taps {
                    acc = acc.wrapping_add(
                        x[(i + u) * xw + (j + v)].wrapping_mul(k[u * taps + v]),
                    );
                }
            }
            out[i * ow + j] = acc;
        }
    }
}

/// Rust-side complex FFT oracle (radix-2 recursive, f64 internally).
pub fn fft_ref(re: &[f32], im: &[f32]) -> (Vec<f32>, Vec<f32>) {
    let n = re.len();
    assert!(n.is_power_of_two());
    let mut buf: Vec<(f64, f64)> = re
        .iter()
        .zip(im)
        .map(|(&r, &i)| (r as f64, i as f64))
        .collect();
    fft_rec(&mut buf);
    (
        buf.iter().map(|c| c.0 as f32).collect(),
        buf.iter().map(|c| c.1 as f32).collect(),
    )
}

fn fft_rec(x: &mut [(f64, f64)]) {
    let n = x.len();
    if n <= 1 {
        return;
    }
    let mut even: Vec<(f64, f64)> = x.iter().step_by(2).copied().collect();
    let mut odd: Vec<(f64, f64)> = x.iter().skip(1).step_by(2).copied().collect();
    fft_rec(&mut even);
    fft_rec(&mut odd);
    for k in 0..n / 2 {
        let ang = -2.0 * std::f64::consts::PI * k as f64 / n as f64;
        let (wr, wi) = (ang.cos(), ang.sin());
        let (or_, oi) = odd[k];
        let t = (wr * or_ - wi * oi, wr * oi + wi * or_);
        x[k] = (even[k].0 + t.0, even[k].1 + t.1);
        x[k + n / 2] = (even[k].0 - t.0, even[k].1 - t.1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_and_bytes() {
        let t = Tensor::zeros(DType::F32, &[8, 4]);
        assert_eq!(t.len(), 32);
        assert_eq!(t.byte_len(), 128);
        assert_eq!(t.dtype(), DType::F32);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn tensor_rejects_bad_shape() {
        Tensor::f32(&[2, 2], vec![1.0; 3]);
    }

    #[test]
    fn dtype_tags_roundtrip() {
        for d in [DType::F32, DType::I32] {
            assert_eq!(DType::from_tag(d.tag()).unwrap(), d);
        }
        assert!(DType::from_tag("f64").is_err());
    }

    #[test]
    fn matmul_ref_small() {
        // [[1,2],[3,4]] @ [[1,0],[0,1]] = same
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let eye = vec![1.0, 0.0, 0.0, 1.0];
        assert_eq!(matmul_ref(&a, &eye, 2, 2, 2), a);
        // [[1,2],[3,4]] @ ones = [[3,3],[7,7]]
        let ones = vec![1.0; 4];
        assert_eq!(matmul_ref(&a, &ones, 2, 2, 2), vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn filter2d_ref_delta() {
        // 5x5 delta kernel picks the centred interior
        let xw = 6;
        let x: Vec<i32> = (0..36).collect();
        let mut k = vec![0i32; 25];
        k[12] = 1;
        let out = filter2d_ref(&x, 6, xw, &k, 5);
        assert_eq!(out, vec![x[2 * 6 + 2], x[2 * 6 + 3], x[3 * 6 + 2], x[3 * 6 + 3]]);
    }

    #[test]
    fn matmul_batch_matches_per_job_ref() {
        // stacked batch == independent matmul_ref calls, bit for bit
        let (batch, m, k, n) = (3usize, 5usize, 7usize, 4usize);
        let mut x = 0.37f32;
        let mut next = || {
            x = (x * 1.7 + 0.13) % 2.0 - 1.0;
            x
        };
        let a: Vec<f32> = (0..batch * m * k).map(|_| next()).collect();
        let b: Vec<f32> = (0..batch * k * n).map(|_| next()).collect();
        let got = matmul_batch_ref(&a, &b, batch, m, k, n);
        for t in 0..batch {
            let want = matmul_ref(&a[t * m * k..(t + 1) * m * k], &b[t * k * n..(t + 1) * k * n], m, k, n);
            assert_eq!(&got[t * m * n..(t + 1) * m * n], want.as_slice(), "job {t}");
        }
    }

    #[test]
    fn matmul_batch_handles_k_remainder() {
        // k not a multiple of the unroll width exercises the tail loop
        for k in [1usize, 2, 3, 5, 6] {
            let (m, n) = (3usize, 3usize);
            let a: Vec<f32> = (0..m * k).map(|i| i as f32 * 0.5 - 1.0).collect();
            let b: Vec<f32> = (0..k * n).map(|i| 1.0 - i as f32 * 0.25).collect();
            let got = matmul_batch_ref(&a, &b, 1, m, k, n);
            assert_eq!(got, matmul_ref(&a, &b, m, k, n), "k={k}");
        }
    }

    #[test]
    fn matmul_batch_into_reuses_and_resizes_the_buffer() {
        let a = vec![1.0f32, 2.0, 3.0, 4.0];
        let eye = vec![1.0f32, 0.0, 0.0, 1.0];
        // start with a dirty, oversized buffer: must be fully overwritten
        let mut c = vec![9.0f32; 64];
        matmul_batch_into(&a, &eye, 1, 2, 2, 2, &mut c);
        assert_eq!(c, a);
        // and grow a too-small one
        let mut c = Vec::new();
        matmul_batch_into(&a, &eye, 1, 2, 2, 2, &mut c);
        assert_eq!(c, matmul_ref(&a, &eye, 2, 2, 2));
    }

    #[test]
    fn scalar_tier_is_exactly_the_reference_kernels() {
        // the tiered entry points with KernelTier::Scalar must be
        // bitwise the reference kernels on every machine (the SIMD leg
        // is pinned machine-dependently in tests/kernel_tiers.rs)
        let (m, k, n) = (5usize, 7usize, 6usize);
        let a: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.31).sin()).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.17).cos()).collect();
        assert_eq!(matmul_tiered(&a, &b, m, k, n, KernelTier::Scalar), matmul_ref(&a, &b, m, k, n));
        let mut c = Vec::new();
        matmul_batch_into_tiered(&a[..m * k], &b[..k * n], 1, m, k, n, &mut c, KernelTier::Scalar);
        assert_eq!(c, matmul_ref(&a, &b, m, k, n));

        let ai: Vec<i32> = (0..m * k).map(|i| i as i32 % 7 - 3).collect();
        let bi: Vec<i32> = (0..k * n).map(|i| 5 - i as i32 % 9).collect();
        let mut ci = vec![0i32; m * n];
        matmul_i32_job_into(&ai, &bi, m, k, n, &mut ci, KernelTier::Scalar);
        assert_eq!(ci, matmul_i32_ref(&ai, &bi, m, k, n));

        let x: Vec<i32> = (0..36).collect();
        let kern: Vec<i32> = (0..9).map(|i| i - 4).collect();
        let mut out = vec![0i32; 16];
        filter2d_job_into(&x, 6, 6, &kern, 3, &mut out, KernelTier::Scalar);
        assert_eq!(out, filter2d_ref(&x, 6, 6, &kern, 3));
    }

    #[test]
    fn fft_run_is_the_scalar_tier() {
        let n = 64;
        let re: Vec<f32> = (0..n).map(|i| (i as f32 * 0.23).sin()).collect();
        let im: Vec<f32> = (0..n).map(|i| (i as f32 * 0.41).cos()).collect();
        let plan = FftPlan::new(n);
        assert_eq!(plan.run(&re, &im), plan.run_with_tier(&re, &im, KernelTier::Scalar));
    }

    #[test]
    fn matmul_i32_ref_identity_and_wrap() {
        // identity pick-out plus a wrapping product
        let a = vec![i32::MAX, 2, 3, 4];
        let eye = vec![1, 0, 0, 1];
        assert_eq!(matmul_i32_ref(&a, &eye, 2, 2, 2), a);
        let two = vec![2, 0, 0, 2];
        let c = matmul_i32_ref(&a, &two, 2, 2, 2);
        assert_eq!(c[0], i32::MAX.wrapping_mul(2));
    }

    #[test]
    fn fft_plan_matches_recursive_ref() {
        for n in [1usize, 2, 8, 64, 256] {
            let re: Vec<f32> = (0..n).map(|i| (i as f32 * 0.31).sin()).collect();
            let im: Vec<f32> = (0..n).map(|i| (i as f32 * 0.17).cos()).collect();
            let plan = FftPlan::new(n);
            assert_eq!(plan.points(), n);
            let (pr, pi) = plan.run(&re, &im);
            let (rr, ri) = fft_ref(&re, &im);
            for j in 0..n {
                assert!((pr[j] - rr[j]).abs() < 1e-4, "n={n} re[{j}]: {} vs {}", pr[j], rr[j]);
                assert!((pi[j] - ri[j]).abs() < 1e-4, "n={n} im[{j}]: {} vs {}", pi[j], ri[j]);
            }
        }
    }

    #[test]
    fn fft_plan_impulse() {
        let plan = FftPlan::new(8);
        let mut re = vec![0.0f32; 8];
        re[0] = 1.0;
        let (or_, oi) = plan.run(&re, &[0.0; 8]);
        assert!(or_.iter().all(|&v| (v - 1.0).abs() < 1e-6));
        assert!(oi.iter().all(|&v| v.abs() < 1e-6));
    }

    #[test]
    fn fft_ref_impulse() {
        let mut re = vec![0.0f32; 8];
        re[0] = 1.0;
        let im = vec![0.0f32; 8];
        let (or_, oi) = fft_ref(&re, &im);
        assert!(or_.iter().all(|&v| (v - 1.0).abs() < 1e-6));
        assert!(oi.iter().all(|&v| v.abs() < 1e-6));
    }

    #[test]
    fn fft_ref_parseval() {
        let re: Vec<f32> = (0..16).map(|i| (i as f32 * 0.37).sin()).collect();
        let im = vec![0.0f32; 16];
        let (or_, oi) = fft_ref(&re, &im);
        let et: f64 = re.iter().map(|&v| (v as f64).powi(2)).sum();
        let ef: f64 = or_
            .iter()
            .zip(&oi)
            .map(|(&r, &i)| (r as f64).powi(2) + (i as f64).powi(2))
            .sum();
        assert!((ef - et * 16.0).abs() < 1e-3 * ef.max(1.0));
    }
}
