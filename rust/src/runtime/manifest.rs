//! Artifact manifest parsing — `artifacts/manifest.json` is written by
//! `python/compile/aot.py` and describes every HLO module the runtime can
//! load: input/output shapes + dtypes keyed by artifact name.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::runtime::tensor::DType;
use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct TensorMeta {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorMeta {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn byte_len(&self) -> usize {
        self.elements() * self.dtype.byte_width()
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorMeta>,
    pub outputs: Vec<TensorMeta>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

fn tensor_meta(j: &Json) -> Result<TensorMeta> {
    let shape = j
        .get("shape")
        .and_then(Json::as_arr)
        .context("tensor meta missing shape")?
        .iter()
        .map(|d| d.as_usize().context("shape dim must be a positive integer"))
        .collect::<Result<Vec<_>>>()?;
    let dtype = DType::from_tag(
        j.get("dtype")
            .and_then(Json::as_str)
            .context("tensor meta missing dtype")?,
    )?;
    Ok(TensorMeta { shape, dtype })
}

impl Manifest {
    /// Parse `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        Manifest::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let root = Json::parse(text).context("manifest is not valid JSON")?;
        let entries = root
            .get("artifacts")
            .and_then(Json::as_arr)
            .context("manifest missing 'artifacts' array")?;
        let mut artifacts = BTreeMap::new();
        for e in entries {
            let name = e
                .get("name")
                .and_then(Json::as_str)
                .context("artifact missing name")?
                .to_string();
            let file = e
                .get("file")
                .and_then(Json::as_str)
                .context("artifact missing file")?
                .to_string();
            let inputs = e
                .get("inputs")
                .and_then(Json::as_arr)
                .context("artifact missing inputs")?
                .iter()
                .map(tensor_meta)
                .collect::<Result<Vec<_>>>()?;
            let outputs = e
                .get("outputs")
                .and_then(Json::as_arr)
                .context("artifact missing outputs")?
                .iter()
                .map(tensor_meta)
                .collect::<Result<Vec<_>>>()?;
            if artifacts
                .insert(name.clone(), ArtifactMeta { name: name.clone(), file, inputs, outputs })
                .is_some()
            {
                bail!("duplicate artifact name {name:?}");
            }
        }
        Ok(Manifest { dir, artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts.get(name).with_context(|| {
            format!(
                "artifact {name:?} not in manifest (have: {:?})",
                self.artifacts.keys().collect::<Vec<_>>()
            )
        })
    }

    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.get(name)?.file))
    }

    /// Default artifact directory: $EA4RCA_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("EA4RCA_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{"artifacts": [
        {"name": "mm32", "file": "mm32.hlo.txt",
         "inputs": [{"shape": [32, 32], "dtype": "f32"},
                    {"shape": [32, 32], "dtype": "f32"}],
         "outputs": [{"shape": [32, 32], "dtype": "f32"}]},
        {"name": "filter2d_pu8", "file": "filter2d_pu8.hlo.txt",
         "inputs": [{"shape": [8, 36, 36], "dtype": "i32"},
                    {"shape": [5, 5], "dtype": "i32"}],
         "outputs": [{"shape": [8, 32, 32], "dtype": "i32"}]}
    ]}"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        let mm = m.get("mm32").unwrap();
        assert_eq!(mm.inputs.len(), 2);
        assert_eq!(mm.inputs[0].shape, vec![32, 32]);
        assert_eq!(mm.inputs[0].dtype, DType::F32);
        assert_eq!(mm.outputs[0].byte_len(), 32 * 32 * 4);
        let f = m.get("filter2d_pu8").unwrap();
        assert_eq!(f.inputs[0].elements(), 8 * 36 * 36);
        assert_eq!(f.inputs[0].dtype, DType::I32);
        assert_eq!(m.hlo_path("mm32").unwrap(), PathBuf::from("/tmp/a/mm32.hlo.txt"));
    }

    #[test]
    fn unknown_artifact_is_error() {
        let m = Manifest::parse(SAMPLE, PathBuf::from(".")).unwrap();
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn rejects_duplicates() {
        let dup = r#"{"artifacts": [
            {"name": "a", "file": "a", "inputs": [], "outputs": []},
            {"name": "a", "file": "b", "inputs": [], "outputs": []}
        ]}"#;
        assert!(Manifest::parse(dup, PathBuf::from(".")).is_err());
    }

    #[test]
    fn rejects_bad_dtype() {
        let bad = r#"{"artifacts": [
            {"name": "a", "file": "a",
             "inputs": [{"shape": [1], "dtype": "f16"}], "outputs": []}
        ]}"#;
        assert!(Manifest::parse(bad, PathBuf::from(".")).is_err());
    }
}
