//! Artifact manifest parsing — `artifacts/manifest.json` is written by
//! `python/compile/aot.py` and describes every HLO module the runtime can
//! load: input/output shapes + dtypes keyed by artifact name.
//!
//! When no manifest has been built (`make artifacts` needs Python+JAX),
//! [`Manifest::load_or_builtin`] falls back to [`Manifest::builtin`], a
//! Rust mirror of the AOT artifact catalogue. The interpreter backend
//! needs only the shape/dtype metadata, so the whole runtime works with
//! zero files on disk; the PJRT backend still requires the `.hlo.txt`
//! files and reports a readable error if they are missing.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::codegen::config::PuConfig;
use crate::engine::compute::pu::ProcessingUnit;
use crate::runtime::tensor::DType;
use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct TensorMeta {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorMeta {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn byte_len(&self) -> usize {
        self.elements() * self.dtype.byte_width()
    }
}

/// The PU topology behind an artifact — the Graph Configuration facts a
/// cost model needs: the DAC/CC/DCC structure (whose modes *are* the
/// transfer methods), core count, per-iteration op/byte counts, and how
/// many copies the design deploys. Carried by [`ArtifactMeta`] when the
/// manifest (or the codegen pipeline) supplies it; backends with a cost
/// model derive a default for catalogue artifacts that lack one.
#[derive(Debug, Clone, PartialEq)]
pub struct PuTopology {
    /// Full PU structure: PSTs (DACs, CC, DCCs), kernel class,
    /// per-iteration ops and wire bytes.
    pub pu: ProcessingUnit,
    /// PU copies the design deploys (the config file's `copies`).
    pub copies: usize,
}

impl PuTopology {
    /// The config → artifact handoff: an artifact generated from a Graph
    /// Configuration File carries that configuration's PU topology.
    pub fn from_config(cfg: &PuConfig) -> PuTopology {
        PuTopology { pu: cfg.pu.clone(), copies: cfg.copies.max(1) }
    }

    /// AIE cores of one PU copy.
    pub fn cores(&self) -> usize {
        self.pu.cores()
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorMeta>,
    pub outputs: Vec<TensorMeta>,
    /// PU topology, when the artifact carries one (manifest `pu_config`
    /// entries, or attached programmatically from a `codegen::PuConfig`).
    pub topology: Option<PuTopology>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

fn tensor_meta(j: &Json) -> Result<TensorMeta> {
    let shape = j
        .get("shape")
        .and_then(Json::as_arr)
        .context("tensor meta missing shape")?
        .iter()
        .map(|d| d.as_usize().context("shape dim must be a positive integer"))
        .collect::<Result<Vec<_>>>()?;
    let dtype = DType::from_tag(
        j.get("dtype")
            .and_then(Json::as_str)
            .context("tensor meta missing dtype")?,
    )?;
    Ok(TensorMeta { shape, dtype })
}

impl Manifest {
    /// Parse `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        Manifest::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let root = Json::parse(text).context("manifest is not valid JSON")?;
        let entries = root
            .get("artifacts")
            .and_then(Json::as_arr)
            .context("manifest missing 'artifacts' array")?;
        let mut artifacts = BTreeMap::new();
        for e in entries {
            let name = e
                .get("name")
                .and_then(Json::as_str)
                .context("artifact missing name")?
                .to_string();
            let file = e
                .get("file")
                .and_then(Json::as_str)
                .context("artifact missing file")?
                .to_string();
            let inputs = e
                .get("inputs")
                .and_then(Json::as_arr)
                .context("artifact missing inputs")?
                .iter()
                .map(tensor_meta)
                .collect::<Result<Vec<_>>>()?;
            let outputs = e
                .get("outputs")
                .and_then(Json::as_arr)
                .context("artifact missing outputs")?
                .iter()
                .map(tensor_meta)
                .collect::<Result<Vec<_>>>()?;
            // optional: the artifact's Graph Configuration (the codegen
            // pipeline's config → artifact handoff), inlined verbatim in
            // the config-file schema
            let topology = match e.get("pu_config") {
                Some(pj) => Some(PuTopology::from_config(
                    &PuConfig::from_json(pj)
                        .with_context(|| format!("artifact {name}: invalid pu_config"))?,
                )),
                None => None,
            };
            if artifacts
                .insert(
                    name.clone(),
                    ArtifactMeta { name: name.clone(), file, inputs, outputs, topology },
                )
                .is_some()
            {
                bail!("duplicate artifact name {name:?}");
            }
        }
        Ok(Manifest { dir, artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts.get(name).with_context(|| {
            format!(
                "artifact {name:?} not in manifest (have: {:?})",
                self.artifacts.keys().collect::<Vec<_>>()
            )
        })
    }

    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.get(name)?.file))
    }

    /// Load `<dir>/manifest.json` if present, otherwise fall back to the
    /// built-in catalogue. A *malformed* on-disk manifest is still an
    /// error — silently shadowing a broken build would hide real bugs.
    pub fn load_or_builtin(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        if dir.join("manifest.json").is_file() {
            Manifest::load(dir)
        } else {
            Ok(Manifest::builtin(dir))
        }
    }

    /// The built-in artifact catalogue: a Rust mirror of
    /// `python/compile/aot.py::artifact_catalogue` (names, shapes,
    /// dtypes). File names follow the same `<name>.hlo.txt` convention
    /// so a later `make artifacts` drops the HLO next to the metadata.
    pub fn builtin(dir: impl Into<PathBuf>) -> Manifest {
        fn t(shape: &[usize], dtype: DType) -> TensorMeta {
            TensorMeta { shape: shape.to_vec(), dtype }
        }
        let mut artifacts = BTreeMap::new();
        let mut add = |name: &str, inputs: Vec<TensorMeta>, outputs: Vec<TensorMeta>| {
            artifacts.insert(
                name.to_string(),
                ArtifactMeta {
                    name: name.to_string(),
                    file: format!("{name}.hlo.txt"),
                    inputs,
                    outputs,
                    // catalogue artifacts carry no explicit topology; a
                    // cost-model backend derives the paper's structures
                    topology: None,
                },
            );
        };
        let f = DType::F32;
        let i = DType::I32;
        // single-core kernels
        add("mm32", vec![t(&[32, 32], f), t(&[32, 32], f)], vec![t(&[32, 32], f)]);
        add(
            "mm32_acc",
            vec![t(&[32, 32], f), t(&[32, 32], f), t(&[32, 32], f)],
            vec![t(&[32, 32], f)],
        );
        // low-bit variants (paper §4.3): int32 tensors carrying
        // int8/int16-range values
        add("mm32_i8", vec![t(&[32, 32], i), t(&[32, 32], i)], vec![t(&[32, 32], i)]);
        add("mm32_i16", vec![t(&[32, 32], i), t(&[32, 32], i)], vec![t(&[32, 32], i)]);
        add(
            "mmt_cascade8",
            vec![t(&[32, 256], f), t(&[256, 32], f)],
            vec![t(&[32, 32], f)],
        );
        // PU-level graphs
        add(
            "mm_pu128",
            vec![t(&[128, 128], f), t(&[128, 128], f)],
            vec![t(&[128, 128], f)],
        );
        add(
            "filter2d_pu8",
            vec![t(&[8, 36, 36], i), t(&[5, 5], i)],
            vec![t(&[8, 32, 32], i)],
        );
        for n in [1024usize, 2048, 4096, 8192] {
            add(
                &format!("fft{n}"),
                vec![t(&[n], f), t(&[n], f)],
                vec![t(&[n], f), t(&[n], f)],
            );
        }
        Manifest { dir: dir.into(), artifacts }
    }

    /// Default artifact directory: $EA4RCA_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("EA4RCA_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{"artifacts": [
        {"name": "mm32", "file": "mm32.hlo.txt",
         "inputs": [{"shape": [32, 32], "dtype": "f32"},
                    {"shape": [32, 32], "dtype": "f32"}],
         "outputs": [{"shape": [32, 32], "dtype": "f32"}]},
        {"name": "filter2d_pu8", "file": "filter2d_pu8.hlo.txt",
         "inputs": [{"shape": [8, 36, 36], "dtype": "i32"},
                    {"shape": [5, 5], "dtype": "i32"}],
         "outputs": [{"shape": [8, 32, 32], "dtype": "i32"}]}
    ]}"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        let mm = m.get("mm32").unwrap();
        assert_eq!(mm.inputs.len(), 2);
        assert_eq!(mm.inputs[0].shape, vec![32, 32]);
        assert_eq!(mm.inputs[0].dtype, DType::F32);
        assert_eq!(mm.outputs[0].byte_len(), 32 * 32 * 4);
        let f = m.get("filter2d_pu8").unwrap();
        assert_eq!(f.inputs[0].elements(), 8 * 36 * 36);
        assert_eq!(f.inputs[0].dtype, DType::I32);
        assert_eq!(m.hlo_path("mm32").unwrap(), PathBuf::from("/tmp/a/mm32.hlo.txt"));
    }

    #[test]
    fn unknown_artifact_is_error() {
        let m = Manifest::parse(SAMPLE, PathBuf::from(".")).unwrap();
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn rejects_duplicates() {
        let dup = r#"{"artifacts": [
            {"name": "a", "file": "a", "inputs": [], "outputs": []},
            {"name": "a", "file": "b", "inputs": [], "outputs": []}
        ]}"#;
        assert!(Manifest::parse(dup, PathBuf::from(".")).is_err());
    }

    #[test]
    fn builtin_mirrors_aot_catalogue() {
        let m = Manifest::builtin("artifacts");
        // the artifact set python/compile/aot.py ships
        for name in [
            "mm32", "mm32_acc", "mm32_i8", "mm32_i16", "mmt_cascade8", "mm_pu128",
            "filter2d_pu8", "fft1024", "fft2048", "fft4096", "fft8192",
        ] {
            assert!(m.get(name).is_ok(), "{name} missing from builtin manifest");
        }
        assert_eq!(m.artifacts.len(), 11);
        let mm = m.get("mm_pu128").unwrap();
        assert_eq!(mm.inputs[0].shape, vec![128, 128]);
        assert_eq!(mm.outputs[0].dtype, DType::F32);
        let fft = m.get("fft2048").unwrap();
        assert_eq!(fft.inputs.len(), 2);
        assert_eq!(fft.outputs[0].shape, vec![2048]);
        assert_eq!(m.hlo_path("mm32").unwrap(), PathBuf::from("artifacts/mm32.hlo.txt"));
    }

    #[test]
    fn load_or_builtin_falls_back() {
        let m = Manifest::load_or_builtin("/definitely/not/a/real/dir").unwrap();
        assert!(m.get("mm32").is_ok());
    }

    #[test]
    fn load_or_builtin_still_rejects_malformed_manifest() {
        let dir = std::env::temp_dir().join("ea4rca_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), "not json").unwrap();
        assert!(Manifest::load_or_builtin(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn artifact_can_carry_a_pu_topology() {
        // the manifest inlines the Graph Configuration File schema under
        // "pu_config" — the config → artifact handoff of the pipeline
        let text = r#"{"artifacts": [
            {"name": "mm_custom", "file": "mm_custom.hlo.txt",
             "inputs": [{"shape": [128, 128], "dtype": "f32"},
                        {"shape": [128, 128], "dtype": "f32"}],
             "outputs": [{"shape": [128, 128], "dtype": "f32"}],
             "pu_config": {
                "name": "mm", "kernel": "mm32", "class": "f32mac", "copies": 6,
                "psts": [{
                    "dacs": [{"modes": ["SWH", "BDC"], "plios": 8, "serves": 64}],
                    "cc": "Parallel<16>*Cascade<4>",
                    "dccs": [{"mode": "SWH", "plios": 4, "serves": 64}]
                }],
                "ops_per_iter": 4194304, "in_bytes": 131072, "out_bytes": 65536
             }}
        ]}"#;
        let m = Manifest::parse(text, PathBuf::from(".")).unwrap();
        let meta = m.get("mm_custom").unwrap();
        let topo = meta.topology.as_ref().expect("topology carried");
        assert_eq!(topo.cores(), 64);
        assert_eq!(topo.copies, 6);
        assert_eq!(topo.pu.total_plios(), 12);
        // plain entries still parse with no topology
        let plain = Manifest::parse(SAMPLE, PathBuf::from(".")).unwrap();
        assert!(plain.get("mm32").unwrap().topology.is_none());
    }

    #[test]
    fn malformed_pu_config_is_an_error() {
        let text = r#"{"artifacts": [
            {"name": "a", "file": "a", "inputs": [], "outputs": [],
             "pu_config": {"name": "x"}}
        ]}"#;
        let err = Manifest::parse(text, PathBuf::from(".")).unwrap_err();
        assert!(format!("{err:#}").contains("pu_config"), "{err:#}");
    }

    #[test]
    fn rejects_bad_dtype() {
        let bad = r#"{"artifacts": [
            {"name": "a", "file": "a",
             "inputs": [{"shape": [1], "dtype": "f16"}], "outputs": []}
        ]}"#;
        assert!(Manifest::parse(bad, PathBuf::from(".")).is_err());
    }
}
