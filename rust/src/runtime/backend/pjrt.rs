//! The PJRT backend: one CPU client, lazily-compiled executables cached
//! per artifact name, literal marshalling (the original engine path,
//! now behind the [`Backend`] seam and the `pjrt` feature).
//!
//! Compilation happens once per artifact per process (the paper's
//! analogue is the `libadf.a` build); the serving hot path only
//! marshals literals and calls `execute`. Flow (see
//! /opt/xla-example/load_hlo): `PjRtClient::cpu()` ->
//! `HloModuleProto::from_text_file` -> `client.compile` -> `execute`.
//!
//! Builds everywhere via the vendor/xla facade; *executing* needs the
//! real xla-rs crate linked in (README.md "Building with PJRT").

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::runtime::manifest::{ArtifactMeta, Manifest};
use crate::runtime::tensor::Tensor;
use crate::util::sync::lock_clean;

use super::{Backend, CacheStats};

/// PJRT substrate: client + executable cache. Not `Send` in general
/// (the real xla client is thread-bound), which is why the serving
/// layer builds one backend instance per worker thread.
///
/// The executable cache *is* this backend's prepared-artifact layer:
/// [`Backend::prepare`] is the single compile point (the paper's
/// `libadf.a` build), and the execute paths only look executables up —
/// an unprepared artifact is a readable error, never a hidden compile
/// on the hot path. Build/hit counters surface through
/// [`Backend::cache_stats`] like the interpreter's.
///
/// Executables are cached behind `Arc` so the execute paths clone the
/// handle and release the cache lock *before* running: holding the map
/// lock across `execute` would serialize every caller of this backend
/// behind one job's device time (the lock-order gate's RACE-003 lint
/// caught exactly that in the original layout).
pub struct PjrtBackend {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    builds: AtomicU64,
    hits: AtomicU64,
}

impl PjrtBackend {
    pub fn new() -> Result<PjrtBackend> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtBackend {
            client,
            cache: Mutex::new(HashMap::new()),
            builds: AtomicU64::new(0),
            hits: AtomicU64::new(0),
        })
    }

    /// Clone the prepared executable handle for `meta`, holding the
    /// cache lock only for the map lookup — never across device time.
    fn executable(&self, meta: &ArtifactMeta) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        let cache = lock_clean(&self.cache);
        let Some(exe) = cache.get(&meta.name) else {
            bail!("artifact {} was not prepared before execute", meta.name);
        };
        self.hits.fetch_add(1, Ordering::Relaxed);
        Ok(Arc::clone(exe))
    }
}

impl Backend for PjrtBackend {
    fn platform(&self) -> String {
        format!("pjrt ({})", self.client.platform_name())
    }

    fn prepare(&self, manifest: &Manifest, meta: &ArtifactMeta) -> Result<()> {
        let mut cache = lock_clean(&self.cache);
        if cache.contains_key(&meta.name) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(());
        }
        let path = manifest.hlo_path(&meta.name)?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {}", meta.name))?;
        self.builds.fetch_add(1, Ordering::Relaxed);
        cache.insert(meta.name.clone(), Arc::new(exe));
        Ok(())
    }

    fn cache_stats(&self) -> CacheStats {
        CacheStats {
            builds: self.builds.load(Ordering::Relaxed),
            hits: self.hits.load(Ordering::Relaxed),
            // PJRT has no kernel-tier notion: XLA owns its codegen
            ..CacheStats::default()
        }
    }

    fn execute(&self, meta: &ArtifactMeta, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let exe = self.executable(meta)?;
        run_one(&exe, meta, inputs)
    }

    /// Micro-batch path: one executable-cache lookup (and lock) for the
    /// whole batch; each job still marshals its own literals — PJRT has
    /// no cross-job fusion for distinct operand sets.
    fn execute_batch(&self, meta: &ArtifactMeta, jobs: &[Vec<Tensor>]) -> Result<Vec<Vec<Tensor>>> {
        let exe = self.executable(meta)?;
        jobs.iter().map(|inputs| run_one(&exe, meta, inputs)).collect()
    }
}

/// Marshal one job through a compiled executable and decompose the
/// tuple output per the manifest arity (return_tuple=True lowering).
fn run_one(
    exe: &xla::PjRtLoadedExecutable,
    meta: &ArtifactMeta,
    inputs: &[Tensor],
) -> Result<Vec<Tensor>> {
    let literals: Vec<xla::Literal> = inputs
        .iter()
        .map(|t| t.to_literal())
        .collect::<Result<_>>()?;
    let result = exe
        .execute::<xla::Literal>(&literals)
        .with_context(|| format!("executing artifact {}", meta.name))?[0][0]
        .to_literal_sync()?;
    let parts = result
        .to_tuple()
        .with_context(|| format!("artifact {}: expected tuple output", meta.name))?;
    if parts.len() != meta.outputs.len() {
        bail!(
            "artifact {}: manifest says {} outputs, tuple has {}",
            meta.name,
            meta.outputs.len(),
            parts.len()
        );
    }
    parts
        .iter()
        .zip(&meta.outputs)
        .map(|(lit, m)| Tensor::from_literal(lit, m.dtype, &m.shape))
        .collect()
}
