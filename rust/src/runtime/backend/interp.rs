//! The pure-Rust interpreter backend: executes artifact *semantics*
//! directly from the reference kernels (`runtime::tensor::{matmul_ref,
//! filter2d_ref, fft_ref}` — the Rust mirrors of
//! `python/compile/kernels/ref.py`), dispatched by artifact name and
//! shaped by the manifest metadata.
//!
//! This is the default substrate: real numerics with zero native
//! dependencies, so `exec`, `serve` and the integration tests run in a
//! hermetic environment. Dimensions come from the manifest (not
//! hard-coded), so any mm/fft/filter2d-shaped artifact a future AOT
//! catalogue adds executes without code changes here.
//!
//! Per-artifact setup is paid once: [`Backend::prepare`] resolves the
//! kernel dispatch, validates the metadata shapes, and builds a
//! [`PreparedArtifact`] (FFT plan with bit-reversal + per-stage
//! twiddles, matmul blocking dims, filter2d tiling metadata, **and the
//! kernel tier that will serve the artifact**) into a per-backend cache
//! keyed by artifact name. The execute paths only look that state up —
//! the single-job and micro-batch paths share the *same* prepared
//! state, so within a tier their results are bitwise identical.
//!
//! Two performance layers sit on top of the reference semantics (see
//! DESIGN.md, "Kernel dispatch tiers"):
//!
//! * a kernel tier ([`KernelTier`]) resolved once per backend from
//!   `EA4RCA_KERNEL_TIER` + runtime CPU detection — scalar reference
//!   kernels or explicit AVX2/FMA micro-kernels ([`super::super::simd`]);
//! * a worker-pool batch path ([`super::super::parallel`]) that fans a
//!   micro-batch of `>= MIN_PARALLEL_JOBS` jobs across
//!   `EA4RCA_POOL_THREADS` scoped threads, running the *same* per-job
//!   kernel on disjoint output chunks — so pooling never changes bits.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::runtime::manifest::{ArtifactMeta, Manifest};
use crate::runtime::parallel;
use crate::runtime::tensor::{
    filter2d_job_into, matmul_i32_job_into, matmul_job_into, matmul_tiered, FftPlan, Tensor,
};
use crate::runtime::tier::{KernelTier, TierConfig};
use crate::util::sync::lock_clean;

use super::{Backend, CacheStats};

/// How the interpreter realises one artifact family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kernel {
    /// C[m,n] = A[m,k] @ B[k,n], f32 (covers mm32, mm_pu128 and the
    /// mmt_cascade8 chain, whose 8 chained 32^3 stages sum to one
    /// 32x256x32 product).
    MatmulF32,
    /// C = A @ B + ACC, f32 (the cascade-stage kernel mm32_acc).
    MatmulAccF32,
    /// Integer matmul with operands wrapped to `bits` first (the
    /// mm32_i8/mm32_i16 low-bit contract: int32 tensors carrying
    /// narrow values; out-of-range inputs wrap like the hardware's
    /// narrow datapath).
    MatmulInt { bits: u32 },
    /// Batched valid-mode 2-D correlation over int32 halo tiles.
    Filter2d,
    /// Radix-2 FFT over split re/im f32 planes.
    Fft,
}

/// Resolve the kernel for an artifact name (+ metadata sanity).
fn kernel_for(meta: &ArtifactMeta) -> Result<Kernel> {
    let name = meta.name.as_str();
    let kernel = if name.starts_with("fft") {
        Kernel::Fft
    } else if name.starts_with("filter2d") {
        Kernel::Filter2d
    } else if name == "mm32_i8" {
        Kernel::MatmulInt { bits: 8 }
    } else if name == "mm32_i16" {
        Kernel::MatmulInt { bits: 16 }
    } else if name.starts_with("mm") && meta.inputs.len() == 3 {
        Kernel::MatmulAccF32
    } else if name.starts_with("mm") {
        Kernel::MatmulF32
    } else {
        bail!(
            "interpreter backend has no kernel for artifact {name:?} \
             (knows mm*, filter2d*, fft*)"
        );
    };
    Ok(kernel)
}

/// Matmul dims from the manifest: A[m,k] @ B[k,n].
fn mm_dims(meta: &ArtifactMeta) -> Result<(usize, usize, usize)> {
    if meta.inputs.len() < 2 {
        bail!("artifact {}: matmul needs two operands", meta.name);
    }
    let (a, b) = (&meta.inputs[0], &meta.inputs[1]);
    if a.shape.len() != 2 || b.shape.len() != 2 || a.shape[1] != b.shape[0] {
        bail!(
            "artifact {}: incompatible matmul shapes {:?} x {:?}",
            meta.name,
            a.shape,
            b.shape
        );
    }
    Ok((a.shape[0], a.shape[1], b.shape[1]))
}

/// Wrap an i32 value onto a narrower two's-complement width.
fn wrap_to_bits(v: i32, bits: u32) -> i32 {
    let shift = 32 - bits;
    (v << shift) >> shift
}

/// Reusable per-artifact execution state, built once by
/// [`Backend::prepare`] (or lazily on first use) and shared by the
/// single-job and micro-batch paths. This is the interpreter's analogue
/// of the paper's one-time graph construction + twiddle generation.
/// The tier is part of the prepared state: an artifact is served by one
/// kernel family for the life of the cache entry, and the serve report
/// can say which (see [`Backend::kernel_tier`]).
struct PreparedArtifact {
    tier: KernelTier,
    kind: PreparedKind,
}

enum PreparedKind {
    /// Blocking descriptor: A[m,k] @ B[k,n].
    MatmulF32 { m: usize, k: usize, n: usize },
    MatmulAccF32 { m: usize, k: usize, n: usize },
    MatmulInt { bits: u32, m: usize, k: usize, n: usize },
    /// Tiling metadata: input tile dims, kernel taps, output dims.
    Filter2d { batch: usize, ih: usize, iw: usize, taps: usize, oh: usize, ow: usize },
    /// Bit-reversal table + per-stage twiddles, built once per size.
    Fft { plan: FftPlan },
}

impl PreparedArtifact {
    /// Resolve kernel dispatch + validate the metadata shapes, so
    /// execute-time errors are only about data.
    fn build(meta: &ArtifactMeta, tier: KernelTier) -> Result<PreparedArtifact> {
        let kind = match kernel_for(meta)? {
            Kernel::MatmulF32 => {
                let (m, k, n) = mm_dims(meta)?;
                PreparedKind::MatmulF32 { m, k, n }
            }
            Kernel::MatmulAccF32 => {
                let (m, k, n) = mm_dims(meta)?;
                if meta.inputs[2].shape != [m, n] {
                    bail!(
                        "artifact {}: accumulator shape {:?} must match the product [{m}, {n}]",
                        meta.name,
                        meta.inputs[2].shape
                    );
                }
                PreparedKind::MatmulAccF32 { m, k, n }
            }
            Kernel::MatmulInt { bits } => {
                let (m, k, n) = mm_dims(meta)?;
                PreparedKind::MatmulInt { bits, m, k, n }
            }
            Kernel::Filter2d => {
                if meta.inputs.len() != 2 {
                    bail!("artifact {}: filter2d needs tiles + kernel inputs", meta.name);
                }
                let (x, k) = (&meta.inputs[0], &meta.inputs[1]);
                if x.shape.len() != 3 || k.shape.len() != 2 || k.shape[0] != k.shape[1] {
                    bail!(
                        "artifact {}: filter2d expects [batch, h, w] tiles and a square \
                         kernel, got {:?} / {:?}",
                        meta.name,
                        x.shape,
                        k.shape
                    );
                }
                let taps = k.shape[0];
                if x.shape[1] < taps || x.shape[2] < taps {
                    bail!("artifact {}: tile smaller than the kernel", meta.name);
                }
                let (batch, ih, iw) = (x.shape[0], x.shape[1], x.shape[2]);
                PreparedKind::Filter2d {
                    batch,
                    ih,
                    iw,
                    taps,
                    oh: ih - (taps - 1),
                    ow: iw - (taps - 1),
                }
            }
            Kernel::Fft => {
                let n = meta
                    .inputs
                    .first()
                    .and_then(|t| t.shape.first())
                    .copied()
                    .unwrap_or(0);
                if meta.inputs.len() != 2 || !n.is_power_of_two() {
                    bail!(
                        "artifact {}: fft expects two power-of-two planes, got {:?}",
                        meta.name,
                        meta.inputs.iter().map(|t| &t.shape).collect::<Vec<_>>()
                    );
                }
                PreparedKind::Fft { plan: FftPlan::new(n) }
            }
        };
        Ok(PreparedArtifact { tier, kind })
    }
}

/// Operand-stacking buffers reused across micro-batch dispatches (one
/// set per backend instance; serving workers each own a backend, so the
/// lock is uncontended there).
#[derive(Default)]
struct BatchScratch {
    a: Vec<f32>,
    b: Vec<f32>,
    c: Vec<f32>,
}

/// The interpreter substrate: a prepared-artifact cache (kernel
/// dispatch + validated shapes + plans + tier, built once per artifact)
/// plus the reference-kernel execute paths.
pub struct InterpBackend {
    tiers: TierConfig,
    cache: Mutex<HashMap<String, Arc<PreparedArtifact>>>,
    builds: AtomicU64,
    hits: AtomicU64,
    simd_artifacts: AtomicU64,
    scalar_artifacts: AtomicU64,
    pooled_batches: AtomicU64,
    scratch: Mutex<BatchScratch>,
}

impl InterpBackend {
    /// Environment-configured backend (lenient: a malformed knob falls
    /// back to auto-detection with a stderr note). The CLI entry points
    /// go through [`InterpBackend::from_env`] instead, which fails
    /// loudly.
    pub fn new() -> InterpBackend {
        InterpBackend::with_tiers(TierConfig::from_env_lenient())
    }

    /// Strict environment resolution: a malformed `EA4RCA_KERNEL_TIER` /
    /// `EA4RCA_POOL_THREADS`, or `simd` forced on a CPU without
    /// AVX2+FMA, is a startup error instead of a silent degrade.
    pub fn from_env() -> Result<InterpBackend> {
        Ok(InterpBackend::with_tiers(TierConfig::from_env()?))
    }

    /// Explicit tier configuration (tests, benches, embedders).
    pub fn with_tiers(tiers: TierConfig) -> InterpBackend {
        InterpBackend {
            tiers,
            cache: Mutex::new(HashMap::new()),
            builds: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            simd_artifacts: AtomicU64::new(0),
            scalar_artifacts: AtomicU64::new(0),
            pooled_batches: AtomicU64::new(0),
            scratch: Mutex::new(BatchScratch::default()),
        }
    }

    /// The resolved kernel-dispatch configuration this backend serves
    /// with.
    pub fn tier_config(&self) -> TierConfig {
        self.tiers
    }

    /// Cache lookup, building on miss. The lock is held across a build
    /// so concurrent first-uses of one artifact construct its plan once.
    fn prepared_for(&self, meta: &ArtifactMeta) -> Result<Arc<PreparedArtifact>> {
        let mut cache = lock_clean(&self.cache);
        if let Some(p) = cache.get(&meta.name) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(p));
        }
        let built = Arc::new(PreparedArtifact::build(meta, self.tiers.tier)?);
        self.builds.fetch_add(1, Ordering::Relaxed);
        match built.tier {
            KernelTier::Simd => self.simd_artifacts.fetch_add(1, Ordering::Relaxed),
            KernelTier::Scalar => self.scalar_artifacts.fetch_add(1, Ordering::Relaxed),
        };
        cache.insert(meta.name.clone(), Arc::clone(&built));
        Ok(built)
    }

    fn note_pool(&self, workers_used: usize) {
        if workers_used > 1 {
            self.pooled_batches.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// One job through prepared state (shared by execute and the
    /// non-stacking batch paths).
    fn run_one(&self, prep: &PreparedArtifact, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let tier = prep.tier;
        match &prep.kind {
            PreparedKind::MatmulF32 { m, k, n } => {
                let (m, k, n) = (*m, *k, *n);
                let c = matmul_tiered(inputs[0].as_f32()?, inputs[1].as_f32()?, m, k, n, tier);
                Ok(vec![Tensor::f32(&[m, n], c)])
            }
            PreparedKind::MatmulAccF32 { m, k, n } => {
                let (m, k, n) = (*m, *k, *n);
                let mut c = matmul_tiered(inputs[0].as_f32()?, inputs[1].as_f32()?, m, k, n, tier);
                for (ci, acc) in c.iter_mut().zip(inputs[2].as_f32()?) {
                    *ci += acc;
                }
                Ok(vec![Tensor::f32(&[m, n], c)])
            }
            PreparedKind::MatmulInt { bits, m, k, n } => {
                let (bits, m, k, n) = (*bits, *m, *k, *n);
                let a: Vec<i32> =
                    inputs[0].as_i32()?.iter().map(|&v| wrap_to_bits(v, bits)).collect();
                let b: Vec<i32> =
                    inputs[1].as_i32()?.iter().map(|&v| wrap_to_bits(v, bits)).collect();
                let mut c = vec![0i32; m * n];
                matmul_i32_job_into(&a, &b, m, k, n, &mut c, tier);
                Ok(vec![Tensor::i32(&[m, n], c)])
            }
            PreparedKind::Filter2d { batch, ih, iw, taps, oh, ow } => {
                let (batch, ih, iw, taps, oh, ow) = (*batch, *ih, *iw, *taps, *oh, *ow);
                let tiles = inputs[0].as_i32()?;
                let kern = inputs[1].as_i32()?;
                let mut out = vec![0i32; batch * oh * ow];
                for t in 0..batch {
                    filter2d_job_into(
                        &tiles[t * ih * iw..(t + 1) * ih * iw],
                        ih,
                        iw,
                        kern,
                        taps,
                        &mut out[t * oh * ow..(t + 1) * oh * ow],
                        tier,
                    );
                }
                Ok(vec![Tensor::i32(&[batch, oh, ow], out)])
            }
            PreparedKind::Fft { plan } => {
                let n = plan.points();
                let (re, im) = plan.run_with_tier(inputs[0].as_f32()?, inputs[1].as_f32()?, tier);
                Ok(vec![Tensor::f32(&[n], re), Tensor::f32(&[n], im)])
            }
        }
    }
}

impl Default for InterpBackend {
    fn default() -> Self {
        InterpBackend::new()
    }
}

impl Backend for InterpBackend {
    fn platform(&self) -> String {
        format!(
            "interp-cpu (pure-Rust reference kernels; {} tier, pool={})",
            self.tiers.tier, self.tiers.pool_threads
        )
    }

    fn prepare(&self, _manifest: &Manifest, meta: &ArtifactMeta) -> Result<()> {
        self.prepared_for(meta).map(|_| ())
    }

    fn cache_stats(&self) -> CacheStats {
        CacheStats {
            builds: self.builds.load(Ordering::Relaxed),
            hits: self.hits.load(Ordering::Relaxed),
            simd_artifacts: self.simd_artifacts.load(Ordering::Relaxed),
            scalar_artifacts: self.scalar_artifacts.load(Ordering::Relaxed),
            pooled_batches: self.pooled_batches.load(Ordering::Relaxed),
        }
    }

    fn kernel_tier(&self, meta: &ArtifactMeta) -> Option<KernelTier> {
        lock_clean(&self.cache).get(&meta.name).map(|p| p.tier)
    }

    fn execute(&self, meta: &ArtifactMeta, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let prep = self.prepared_for(meta)?;
        self.run_one(&prep, inputs)
    }

    /// The micro-batch fast path: stack compatible jobs along a leading
    /// batch dimension; the kernel/shape/tier metadata comes out of the
    /// prepared-artifact cache (resolved once per artifact, not per
    /// dispatch). Batches of `>= MIN_PARALLEL_JOBS` jobs additionally
    /// fan out across the worker pool ([`parallel::for_each_job`]) when
    /// `pool_threads > 1` — each worker runs the *same* per-job kernel
    /// on its disjoint output chunk, so pooled and sequential results
    /// are bitwise identical within a tier.
    ///
    /// * mm — operands packed into `[batch, m, k]` / `[batch, k, n]`
    ///   (into per-backend scratch reused across dispatches) and run
    ///   per job through [`matmul_job_into`] (scalar leg bitwise
    ///   identical to `matmul_ref`; SIMD leg under the DESIGN.md
    ///   tolerance contract).
    /// * fft — the *cached* [`FftPlan`] (bit-reversal table + per-stage
    ///   twiddles) is shared by every transform in the batch and by the
    ///   single-job path, so batched and sequential results are bitwise
    ///   identical and the trig cost is paid once per artifact, ever.
    /// * filter2d / int mm / acc mm — per-job kernels through the same
    ///   tiered entry points as `execute`, pooled when wide enough.
    fn execute_batch(&self, meta: &ArtifactMeta, jobs: &[Vec<Tensor>]) -> Result<Vec<Vec<Tensor>>> {
        if jobs.is_empty() {
            return Ok(Vec::new());
        }
        let prep = self.prepared_for(meta)?;
        if jobs.len() < 2 {
            return jobs.iter().map(|inputs| self.run_one(&prep, inputs)).collect();
        }
        let tier = prep.tier;
        let threads = self.tiers.pool_threads;
        match &prep.kind {
            PreparedKind::MatmulF32 { m, k, n } => {
                let (m, k, n) = (*m, *k, *n);
                let batch = jobs.len();
                // per-backend scratch; fall back to a throwaway set if
                // another dispatch holds it (shared-backend callers)
                let mut fallback = BatchScratch::default();
                let mut guard = self.scratch.try_lock().ok();
                let sc: &mut BatchScratch = match guard.as_deref_mut() {
                    Some(g) => g,
                    None => &mut fallback,
                };
                sc.a.clear();
                sc.a.reserve(batch * m * k);
                sc.b.clear();
                sc.b.reserve(batch * k * n);
                for job in jobs {
                    sc.a.extend_from_slice(job[0].as_f32()?);
                    sc.b.extend_from_slice(job[1].as_f32()?);
                }
                let BatchScratch { a, b, c } = sc;
                c.clear();
                c.resize(batch * m * n, 0.0f32);
                let (a, b): (&[f32], &[f32]) = (a, b);
                let used = parallel::for_each_job(c, batch, m * n, threads, |t, ct| {
                    matmul_job_into(
                        &a[t * m * k..(t + 1) * m * k],
                        &b[t * k * n..(t + 1) * k * n],
                        m,
                        k,
                        n,
                        ct,
                        tier,
                    )
                });
                self.note_pool(used);
                Ok(c
                    .chunks_exact(m * n)
                    .map(|cj| vec![Tensor::f32(&[m, n], cj.to_vec())])
                    .collect())
            }
            PreparedKind::MatmulAccF32 { m, k, n } => {
                let (m, k, n) = (*m, *k, *n);
                let ins: Vec<(&[f32], &[f32], &[f32])> = jobs
                    .iter()
                    .map(|j| Ok((j[0].as_f32()?, j[1].as_f32()?, j[2].as_f32()?)))
                    .collect::<Result<_>>()?;
                let mut out = vec![0.0f32; jobs.len() * m * n];
                let used = parallel::for_each_job(&mut out, jobs.len(), m * n, threads, |t, ct| {
                    let (a, b, acc) = ins[t];
                    matmul_job_into(a, b, m, k, n, ct, tier);
                    for (v, &ac) in ct.iter_mut().zip(acc) {
                        *v += ac;
                    }
                });
                self.note_pool(used);
                Ok(out
                    .chunks_exact(m * n)
                    .map(|cj| vec![Tensor::f32(&[m, n], cj.to_vec())])
                    .collect())
            }
            PreparedKind::MatmulInt { bits, m, k, n } => {
                let (bits, m, k, n) = (*bits, *m, *k, *n);
                let ins: Vec<(&[i32], &[i32])> = jobs
                    .iter()
                    .map(|j| Ok((j[0].as_i32()?, j[1].as_i32()?)))
                    .collect::<Result<_>>()?;
                let mut out = vec![0i32; jobs.len() * m * n];
                let used =
                    parallel::for_each_job_i32(&mut out, jobs.len(), m * n, threads, |t, ct| {
                        // operand wrapping rides the worker, not the
                        // dispatcher thread
                        let (ar, br) = ins[t];
                        let a: Vec<i32> = ar.iter().map(|&v| wrap_to_bits(v, bits)).collect();
                        let b: Vec<i32> = br.iter().map(|&v| wrap_to_bits(v, bits)).collect();
                        matmul_i32_job_into(&a, &b, m, k, n, ct, tier);
                    });
                self.note_pool(used);
                Ok(out
                    .chunks_exact(m * n)
                    .map(|cj| vec![Tensor::i32(&[m, n], cj.to_vec())])
                    .collect())
            }
            PreparedKind::Filter2d { batch, ih, iw, taps, oh, ow } => {
                let (fb, ih, iw, taps, oh, ow) = (*batch, *ih, *iw, *taps, *oh, *ow);
                let job_len = fb * oh * ow;
                let ins: Vec<(&[i32], &[i32])> = jobs
                    .iter()
                    .map(|j| Ok((j[0].as_i32()?, j[1].as_i32()?)))
                    .collect::<Result<_>>()?;
                let mut out = vec![0i32; jobs.len() * job_len];
                let used =
                    parallel::for_each_job_i32(&mut out, jobs.len(), job_len, threads, |t, ot| {
                        let (tiles, kern) = ins[t];
                        for ti in 0..fb {
                            filter2d_job_into(
                                &tiles[ti * ih * iw..(ti + 1) * ih * iw],
                                ih,
                                iw,
                                kern,
                                taps,
                                &mut ot[ti * oh * ow..(ti + 1) * oh * ow],
                                tier,
                            );
                        }
                    });
                self.note_pool(used);
                Ok(out
                    .chunks_exact(job_len)
                    .map(|cj| vec![Tensor::i32(&[fb, oh, ow], cj.to_vec())])
                    .collect())
            }
            PreparedKind::Fft { plan } => {
                let n = plan.points();
                let ins: Vec<(&[f32], &[f32])> = jobs
                    .iter()
                    .map(|j| Ok((j[0].as_f32()?, j[1].as_f32()?)))
                    .collect::<Result<_>>()?;
                if threads > 1 && jobs.len() >= crate::runtime::tier::MIN_PARALLEL_JOBS {
                    // pooled path: stacked [batch, 2n] output, each job's
                    // transform computed (and copied) on its worker
                    let mut out = vec![0.0f32; jobs.len() * 2 * n];
                    let used =
                        parallel::for_each_job(&mut out, jobs.len(), 2 * n, threads, |t, ot| {
                            let (re, im) = plan.run_with_tier(ins[t].0, ins[t].1, tier);
                            ot[..n].copy_from_slice(&re);
                            ot[n..].copy_from_slice(&im);
                        });
                    self.note_pool(used);
                    Ok(out
                        .chunks_exact(2 * n)
                        .map(|cj| {
                            vec![
                                Tensor::f32(&[n], cj[..n].to_vec()),
                                Tensor::f32(&[n], cj[n..].to_vec()),
                            ]
                        })
                        .collect())
                } else {
                    ins.iter()
                        .map(|(re, im)| {
                            let (re, im) = plan.run_with_tier(re, im, tier);
                            Ok(vec![Tensor::f32(&[n], re), Tensor::f32(&[n], im)])
                        })
                        .collect()
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend_and_manifest() -> (InterpBackend, Manifest) {
        (InterpBackend::new(), Manifest::builtin("artifacts"))
    }

    #[test]
    fn every_builtin_artifact_has_a_kernel() {
        let (b, m) = backend_and_manifest();
        for meta in m.artifacts.values() {
            b.prepare(&m, meta).unwrap_or_else(|e| panic!("{}: {e}", meta.name));
        }
    }

    #[test]
    fn unknown_artifact_is_a_readable_error() {
        let meta = ArtifactMeta {
            name: "weird_thing".into(),
            file: "weird_thing.hlo.txt".into(),
            inputs: vec![],
            outputs: vec![],
            topology: None,
        };
        let err = kernel_for(&meta).unwrap_err().to_string();
        assert!(err.contains("weird_thing"), "{err}");
    }

    #[test]
    fn wrap_to_bits_is_twos_complement() {
        assert_eq!(wrap_to_bits(127, 8), 127);
        assert_eq!(wrap_to_bits(128, 8), -128);
        assert_eq!(wrap_to_bits(-129, 8), 127);
        assert_eq!(wrap_to_bits(300, 8), 44);
        assert_eq!(wrap_to_bits(32768, 16), -32768);
        assert_eq!(wrap_to_bits(5, 16), 5);
    }

    #[test]
    fn mm32_acc_adds_the_accumulator() {
        let (b, m) = backend_and_manifest();
        let meta = m.get("mm32_acc").unwrap();
        let a = Tensor::f32(&[32, 32], vec![1.0; 1024]);
        let eye = {
            let mut d = vec![0.0f32; 1024];
            for i in 0..32 {
                d[i * 32 + i] = 1.0;
            }
            Tensor::f32(&[32, 32], d)
        };
        let acc = Tensor::f32(&[32, 32], vec![0.5; 1024]);
        let out = b.execute(meta, &[a, eye, acc]).unwrap();
        assert!(out[0].as_f32().unwrap().iter().all(|&v| (v - 1.5).abs() < 1e-6));
    }

    #[test]
    fn execute_batch_matches_execute_for_every_family() {
        use crate::util::rng::Rng;
        let (b, m) = backend_and_manifest();
        let mut rng = Rng::new(41);
        for name in ["mm32", "mm32_acc", "mm32_i8", "filter2d_pu8", "fft1024"] {
            let meta = m.get(name).unwrap();
            let jobs: Vec<Vec<Tensor>> = (0..3)
                .map(|_| {
                    meta.inputs
                        .iter()
                        .map(|tm| match tm.dtype {
                            crate::runtime::tensor::DType::F32 => {
                                Tensor::f32(&tm.shape, rng.normal_vec(tm.elements()))
                            }
                            crate::runtime::tensor::DType::I32 => {
                                Tensor::i32(&tm.shape, rng.int_vec_i32(tm.elements(), -10, 10))
                            }
                        })
                        .collect()
                })
                .collect();
            let batched = b.execute_batch(meta, &jobs).unwrap();
            assert_eq!(batched.len(), jobs.len(), "{name}");
            for (j, job) in jobs.iter().enumerate() {
                let single = b.execute(meta, job).unwrap();
                // exact: every family routes the batch through the same
                // prepared state — and the same tiered per-job kernel —
                // as the single-job path, so batching is bitwise
                // invisible within a tier
                assert_eq!(single, batched[j], "{name} job {j}");
            }
        }
    }

    #[test]
    fn pooled_batches_match_sequential_bitwise() {
        use crate::util::rng::Rng;
        // same tier, pool on vs off: results must be bitwise identical
        // (each worker runs the identical per-job kernel on a disjoint
        // chunk) — this holds on any machine because the tier is pinned
        let seq = InterpBackend::with_tiers(TierConfig::scalar());
        let pooled = InterpBackend::with_tiers(TierConfig {
            tier: KernelTier::Scalar,
            pool_threads: 4,
        });
        let m = Manifest::builtin("artifacts");
        let mut rng = Rng::new(47);
        for name in ["mm32", "mm32_acc", "mm32_i16", "filter2d_pu8", "fft1024"] {
            let meta = m.get(name).unwrap();
            let jobs: Vec<Vec<Tensor>> = (0..6)
                .map(|_| {
                    meta.inputs
                        .iter()
                        .map(|tm| match tm.dtype {
                            crate::runtime::tensor::DType::F32 => {
                                Tensor::f32(&tm.shape, rng.normal_vec(tm.elements()))
                            }
                            crate::runtime::tensor::DType::I32 => {
                                Tensor::i32(&tm.shape, rng.int_vec_i32(tm.elements(), -40, 40))
                            }
                        })
                        .collect()
                })
                .collect();
            let a = seq.execute_batch(meta, &jobs).unwrap();
            let b = pooled.execute_batch(meta, &jobs).unwrap();
            assert_eq!(a, b, "{name}");
        }
        // the pool actually engaged (6 jobs >= MIN_PARALLEL_JOBS)
        assert!(pooled.cache_stats().pooled_batches >= 1);
        assert_eq!(seq.cache_stats().pooled_batches, 0);
    }

    #[test]
    fn prepared_cache_builds_once_and_counts_hits() {
        use crate::util::rng::Rng;
        let (b, m) = backend_and_manifest();
        let meta = m.get("fft1024").unwrap();
        assert_eq!(b.cache_stats(), CacheStats::default());
        assert_eq!(b.kernel_tier(meta), None, "tier is recorded at build time");
        b.prepare(&m, meta).unwrap(); // the one build
        assert_eq!(b.kernel_tier(meta), Some(b.tier_config().tier));
        let mut rng = Rng::new(43);
        let job = vec![
            Tensor::f32(&[1024], rng.normal_vec(1024)),
            Tensor::f32(&[1024], rng.normal_vec(1024)),
        ];
        for _ in 0..5 {
            b.execute(meta, &job).unwrap();
        }
        let jobs = vec![job.clone(), job.clone(), job];
        b.execute_batch(meta, &jobs).unwrap();
        let cs = b.cache_stats();
        assert_eq!(cs.builds, 1, "fft plan must be built exactly once");
        // 5 executes + 1 batch dispatch, each one cache lookup
        assert_eq!(cs.hits, 6);
        // the one build is attributed to exactly one tier counter
        assert_eq!(cs.simd_artifacts + cs.scalar_artifacts, 1);
        // re-preparing is also just a hit
        b.prepare(&m, meta).unwrap();
        assert_eq!(b.cache_stats().hits, 7);
        assert_eq!(b.cache_stats().builds, 1);
    }

    #[test]
    fn forced_scalar_config_reports_itself() {
        let b = InterpBackend::with_tiers(TierConfig::scalar());
        let m = Manifest::builtin("artifacts");
        let meta = m.get("mm32").unwrap();
        b.prepare(&m, meta).unwrap();
        assert_eq!(b.kernel_tier(meta), Some(KernelTier::Scalar));
        let cs = b.cache_stats();
        assert_eq!((cs.scalar_artifacts, cs.simd_artifacts), (1, 0));
        assert!(b.platform().contains("scalar tier"), "{}", b.platform());
    }

    #[test]
    fn single_and_batch_fft_share_the_plan_exactly() {
        use crate::util::rng::Rng;
        let (b, m) = backend_and_manifest();
        let meta = m.get("fft2048").unwrap();
        let mut rng = Rng::new(44);
        let jobs: Vec<Vec<Tensor>> = (0..3)
            .map(|_| {
                vec![
                    Tensor::f32(&[2048], rng.normal_vec(2048)),
                    Tensor::f32(&[2048], rng.normal_vec(2048)),
                ]
            })
            .collect();
        let batched = b.execute_batch(meta, &jobs).unwrap();
        for (j, job) in jobs.iter().enumerate() {
            let single = b.execute(meta, job).unwrap();
            // bitwise, not within-tolerance: both paths run the same
            // plan through the same tier
            assert_eq!(single, batched[j], "job {j}");
        }
    }

    #[test]
    fn execute_batch_of_one_matches_execute() {
        let (b, m) = backend_and_manifest();
        let meta = m.get("mm32").unwrap();
        let a = Tensor::f32(&[32, 32], vec![0.5; 1024]);
        let eye = Tensor::f32(&[32, 32], vec![1.0; 1024]);
        let jobs = vec![vec![a.clone(), eye.clone()]];
        let batched = b.execute_batch(meta, &jobs).unwrap();
        let single = b.execute(meta, &[a, eye]).unwrap();
        assert_eq!(batched[0], single);
    }

    #[test]
    fn int_mm_wraps_operands() {
        let (b, m) = backend_and_manifest();
        let meta = m.get("mm32_i8").unwrap();
        // 130 wraps to -126 as int8; identity B picks it out
        let mut a = vec![0i32; 1024];
        a[0] = 130;
        let mut eye = vec![0i32; 1024];
        for i in 0..32 {
            eye[i * 32 + i] = 1;
        }
        let out = b
            .execute(meta, &[Tensor::i32(&[32, 32], a), Tensor::i32(&[32, 32], eye)])
            .unwrap();
        assert_eq!(out[0].as_i32().unwrap()[0], -126);
    }
}
