//! The pure-Rust interpreter backend: executes artifact *semantics*
//! directly from the reference kernels (`runtime::tensor::{matmul_ref,
//! filter2d_ref, fft_ref}` — the Rust mirrors of
//! `python/compile/kernels/ref.py`), dispatched by artifact name and
//! shaped by the manifest metadata.
//!
//! This is the default substrate: real numerics with zero native
//! dependencies, so `exec`, `serve` and the integration tests run in a
//! hermetic environment. Dimensions come from the manifest (not
//! hard-coded), so any mm/fft/filter2d-shaped artifact a future AOT
//! catalogue adds executes without code changes here.
//!
//! Per-artifact setup is paid once: [`Backend::prepare`] resolves the
//! kernel dispatch, validates the metadata shapes, and builds a
//! [`PreparedArtifact`] (FFT plan with bit-reversal + per-stage
//! twiddles, matmul blocking dims, filter2d tiling metadata) into a
//! per-backend cache keyed by artifact name. The execute paths only
//! look that state up — the single-job and micro-batch fft paths share
//! the *same* plan, so their results are bitwise identical.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::runtime::manifest::{ArtifactMeta, Manifest};
use crate::runtime::tensor::{
    filter2d_ref, matmul_batch_into, matmul_ref, FftPlan, Tensor,
};

use super::{Backend, CacheStats};

/// How the interpreter realises one artifact family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kernel {
    /// C[m,n] = A[m,k] @ B[k,n], f32 (covers mm32, mm_pu128 and the
    /// mmt_cascade8 chain, whose 8 chained 32^3 stages sum to one
    /// 32x256x32 product).
    MatmulF32,
    /// C = A @ B + ACC, f32 (the cascade-stage kernel mm32_acc).
    MatmulAccF32,
    /// Integer matmul with operands wrapped to `bits` first (the
    /// mm32_i8/mm32_i16 low-bit contract: int32 tensors carrying
    /// narrow values; out-of-range inputs wrap like the hardware's
    /// narrow datapath).
    MatmulInt { bits: u32 },
    /// Batched valid-mode 2-D correlation over int32 halo tiles.
    Filter2d,
    /// Radix-2 FFT over split re/im f32 planes.
    Fft,
}

/// Resolve the kernel for an artifact name (+ metadata sanity).
fn kernel_for(meta: &ArtifactMeta) -> Result<Kernel> {
    let name = meta.name.as_str();
    let kernel = if name.starts_with("fft") {
        Kernel::Fft
    } else if name.starts_with("filter2d") {
        Kernel::Filter2d
    } else if name == "mm32_i8" {
        Kernel::MatmulInt { bits: 8 }
    } else if name == "mm32_i16" {
        Kernel::MatmulInt { bits: 16 }
    } else if name.starts_with("mm") && meta.inputs.len() == 3 {
        Kernel::MatmulAccF32
    } else if name.starts_with("mm") {
        Kernel::MatmulF32
    } else {
        bail!(
            "interpreter backend has no kernel for artifact {name:?} \
             (knows mm*, filter2d*, fft*)"
        );
    };
    Ok(kernel)
}

/// Matmul dims from the manifest: A[m,k] @ B[k,n].
fn mm_dims(meta: &ArtifactMeta) -> Result<(usize, usize, usize)> {
    if meta.inputs.len() < 2 {
        bail!("artifact {}: matmul needs two operands", meta.name);
    }
    let (a, b) = (&meta.inputs[0], &meta.inputs[1]);
    if a.shape.len() != 2 || b.shape.len() != 2 || a.shape[1] != b.shape[0] {
        bail!(
            "artifact {}: incompatible matmul shapes {:?} x {:?}",
            meta.name,
            a.shape,
            b.shape
        );
    }
    Ok((a.shape[0], a.shape[1], b.shape[1]))
}

/// Wrap an i32 value onto a narrower two's-complement width.
fn wrap_to_bits(v: i32, bits: u32) -> i32 {
    let shift = 32 - bits;
    (v << shift) >> shift
}

/// Integer matmul with exact int32 accumulation (wrapping, like the
/// hardware accumulator).
fn matmul_i32(a: &[i32], b: &[i32], m: usize, k: usize, n: usize) -> Vec<i32> {
    let mut c = vec![0i32; m * n];
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p];
            if av == 0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] = crow[j].wrapping_add(av.wrapping_mul(brow[j]));
            }
        }
    }
    c
}

/// Reusable per-artifact execution state, built once by
/// [`Backend::prepare`] (or lazily on first use) and shared by the
/// single-job and micro-batch paths. This is the interpreter's analogue
/// of the paper's one-time graph construction + twiddle generation.
enum PreparedArtifact {
    /// Blocking descriptor: A[m,k] @ B[k,n].
    MatmulF32 { m: usize, k: usize, n: usize },
    MatmulAccF32 { m: usize, k: usize, n: usize },
    MatmulInt { bits: u32, m: usize, k: usize, n: usize },
    /// Tiling metadata: input tile dims, kernel taps, output dims.
    Filter2d { batch: usize, ih: usize, iw: usize, taps: usize, oh: usize, ow: usize },
    /// Bit-reversal table + per-stage twiddles, built once per size.
    Fft { plan: FftPlan },
}

impl PreparedArtifact {
    /// Resolve kernel dispatch + validate the metadata shapes, so
    /// execute-time errors are only about data.
    fn build(meta: &ArtifactMeta) -> Result<PreparedArtifact> {
        match kernel_for(meta)? {
            Kernel::MatmulF32 => {
                let (m, k, n) = mm_dims(meta)?;
                Ok(PreparedArtifact::MatmulF32 { m, k, n })
            }
            Kernel::MatmulAccF32 => {
                let (m, k, n) = mm_dims(meta)?;
                if meta.inputs[2].shape != [m, n] {
                    bail!(
                        "artifact {}: accumulator shape {:?} must match the product [{m}, {n}]",
                        meta.name,
                        meta.inputs[2].shape
                    );
                }
                Ok(PreparedArtifact::MatmulAccF32 { m, k, n })
            }
            Kernel::MatmulInt { bits } => {
                let (m, k, n) = mm_dims(meta)?;
                Ok(PreparedArtifact::MatmulInt { bits, m, k, n })
            }
            Kernel::Filter2d => {
                if meta.inputs.len() != 2 {
                    bail!("artifact {}: filter2d needs tiles + kernel inputs", meta.name);
                }
                let (x, k) = (&meta.inputs[0], &meta.inputs[1]);
                if x.shape.len() != 3 || k.shape.len() != 2 || k.shape[0] != k.shape[1] {
                    bail!(
                        "artifact {}: filter2d expects [batch, h, w] tiles and a square \
                         kernel, got {:?} / {:?}",
                        meta.name,
                        x.shape,
                        k.shape
                    );
                }
                let taps = k.shape[0];
                if x.shape[1] < taps || x.shape[2] < taps {
                    bail!("artifact {}: tile smaller than the kernel", meta.name);
                }
                let (batch, ih, iw) = (x.shape[0], x.shape[1], x.shape[2]);
                Ok(PreparedArtifact::Filter2d {
                    batch,
                    ih,
                    iw,
                    taps,
                    oh: ih - (taps - 1),
                    ow: iw - (taps - 1),
                })
            }
            Kernel::Fft => {
                let n = meta
                    .inputs
                    .first()
                    .and_then(|t| t.shape.first())
                    .copied()
                    .unwrap_or(0);
                if meta.inputs.len() != 2 || !n.is_power_of_two() {
                    bail!(
                        "artifact {}: fft expects two power-of-two planes, got {:?}",
                        meta.name,
                        meta.inputs.iter().map(|t| &t.shape).collect::<Vec<_>>()
                    );
                }
                Ok(PreparedArtifact::Fft { plan: FftPlan::new(n) })
            }
        }
    }
}

/// Operand-stacking buffers reused across micro-batch dispatches (one
/// set per backend instance; serving workers each own a backend, so the
/// lock is uncontended there).
#[derive(Default)]
struct BatchScratch {
    a: Vec<f32>,
    b: Vec<f32>,
    c: Vec<f32>,
}

/// The interpreter substrate: a prepared-artifact cache (kernel
/// dispatch + validated shapes + plans, built once per artifact) plus
/// the reference-kernel execute paths.
pub struct InterpBackend {
    cache: Mutex<HashMap<String, Arc<PreparedArtifact>>>,
    builds: AtomicU64,
    hits: AtomicU64,
    scratch: Mutex<BatchScratch>,
}

impl InterpBackend {
    pub fn new() -> InterpBackend {
        InterpBackend {
            cache: Mutex::new(HashMap::new()),
            builds: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            scratch: Mutex::new(BatchScratch::default()),
        }
    }

    /// Cache lookup, building on miss. The lock is held across a build
    /// so concurrent first-uses of one artifact construct its plan once.
    fn prepared_for(&self, meta: &ArtifactMeta) -> Result<Arc<PreparedArtifact>> {
        let mut cache = self.cache.lock().unwrap();
        if let Some(p) = cache.get(&meta.name) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(p));
        }
        let built = Arc::new(PreparedArtifact::build(meta)?);
        self.builds.fetch_add(1, Ordering::Relaxed);
        cache.insert(meta.name.clone(), Arc::clone(&built));
        Ok(built)
    }

    /// One job through prepared state (shared by execute and the
    /// non-stacking batch paths).
    fn run_one(&self, prep: &PreparedArtifact, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        match prep {
            PreparedArtifact::MatmulF32 { m, k, n } => {
                let (m, k, n) = (*m, *k, *n);
                let c = matmul_ref(inputs[0].as_f32()?, inputs[1].as_f32()?, m, k, n);
                Ok(vec![Tensor::f32(&[m, n], c)])
            }
            PreparedArtifact::MatmulAccF32 { m, k, n } => {
                let (m, k, n) = (*m, *k, *n);
                let mut c = matmul_ref(inputs[0].as_f32()?, inputs[1].as_f32()?, m, k, n);
                for (ci, acc) in c.iter_mut().zip(inputs[2].as_f32()?) {
                    *ci += acc;
                }
                Ok(vec![Tensor::f32(&[m, n], c)])
            }
            PreparedArtifact::MatmulInt { bits, m, k, n } => {
                let (bits, m, k, n) = (*bits, *m, *k, *n);
                let a: Vec<i32> =
                    inputs[0].as_i32()?.iter().map(|&v| wrap_to_bits(v, bits)).collect();
                let b: Vec<i32> =
                    inputs[1].as_i32()?.iter().map(|&v| wrap_to_bits(v, bits)).collect();
                Ok(vec![Tensor::i32(&[m, n], matmul_i32(&a, &b, m, k, n))])
            }
            PreparedArtifact::Filter2d { batch, ih, iw, taps, oh, ow } => {
                let (batch, ih, iw, taps, oh, ow) = (*batch, *ih, *iw, *taps, *oh, *ow);
                let tiles = inputs[0].as_i32()?;
                let kern = inputs[1].as_i32()?;
                let mut out = Vec::with_capacity(batch * oh * ow);
                for t in 0..batch {
                    let tile = &tiles[t * ih * iw..(t + 1) * ih * iw];
                    out.extend(filter2d_ref(tile, ih, iw, kern, taps));
                }
                Ok(vec![Tensor::i32(&[batch, oh, ow], out)])
            }
            PreparedArtifact::Fft { plan } => {
                let n = plan.points();
                let (re, im) = plan.run(inputs[0].as_f32()?, inputs[1].as_f32()?);
                Ok(vec![Tensor::f32(&[n], re), Tensor::f32(&[n], im)])
            }
        }
    }
}

impl Default for InterpBackend {
    fn default() -> Self {
        InterpBackend::new()
    }
}

impl Backend for InterpBackend {
    fn platform(&self) -> String {
        "interp-cpu (pure-Rust reference kernels)".to_string()
    }

    fn prepare(&self, _manifest: &Manifest, meta: &ArtifactMeta) -> Result<()> {
        self.prepared_for(meta).map(|_| ())
    }

    fn cache_stats(&self) -> CacheStats {
        CacheStats {
            builds: self.builds.load(Ordering::Relaxed),
            hits: self.hits.load(Ordering::Relaxed),
        }
    }

    fn execute(&self, meta: &ArtifactMeta, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let prep = self.prepared_for(meta)?;
        self.run_one(&prep, inputs)
    }

    /// The micro-batch fast path: stack compatible jobs along a leading
    /// batch dimension; the kernel/shape metadata comes out of the
    /// prepared-artifact cache (resolved once per artifact, not per
    /// dispatch).
    ///
    /// * mm — operands packed into `[batch, m, k]` / `[batch, k, n]`
    ///   (into per-backend scratch reused across dispatches) and run
    ///   through the cache-blocked [`matmul_batch_into`] kernel
    ///   (bitwise-identical accumulation order to `matmul_ref`).
    /// * fft — the *cached* [`FftPlan`] (bit-reversal table + per-stage
    ///   twiddles) is shared by every transform in the batch and by the
    ///   single-job path, so batched and sequential results are bitwise
    ///   identical and the trig cost is paid once per artifact, ever.
    /// * filter2d — per-job kernels differ, so tiles run per job but
    ///   with the dispatch/dims resolved once.
    /// * everything else falls back to the per-job loop.
    fn execute_batch(&self, meta: &ArtifactMeta, jobs: &[Vec<Tensor>]) -> Result<Vec<Vec<Tensor>>> {
        if jobs.is_empty() {
            return Ok(Vec::new());
        }
        let prep = self.prepared_for(meta)?;
        if jobs.len() < 2 {
            return jobs.iter().map(|inputs| self.run_one(&prep, inputs)).collect();
        }
        match &*prep {
            PreparedArtifact::MatmulF32 { m, k, n } => {
                let (m, k, n) = (*m, *k, *n);
                let batch = jobs.len();
                // per-backend scratch; fall back to a throwaway set if
                // another dispatch holds it (shared-backend callers)
                let mut fallback = BatchScratch::default();
                let mut guard = self.scratch.try_lock().ok();
                let sc: &mut BatchScratch = match guard.as_deref_mut() {
                    Some(g) => g,
                    None => &mut fallback,
                };
                sc.a.clear();
                sc.a.reserve(batch * m * k);
                sc.b.clear();
                sc.b.reserve(batch * k * n);
                for job in jobs {
                    sc.a.extend_from_slice(job[0].as_f32()?);
                    sc.b.extend_from_slice(job[1].as_f32()?);
                }
                let BatchScratch { a, b, c } = sc;
                matmul_batch_into(a, b, batch, m, k, n, c);
                Ok(c
                    .chunks_exact(m * n)
                    .map(|cj| vec![Tensor::f32(&[m, n], cj.to_vec())])
                    .collect())
            }
            PreparedArtifact::Fft { plan } => {
                let n = plan.points();
                jobs.iter()
                    .map(|job| {
                        let (re, im) = plan.run(job[0].as_f32()?, job[1].as_f32()?);
                        Ok(vec![Tensor::f32(&[n], re), Tensor::f32(&[n], im)])
                    })
                    .collect()
            }
            PreparedArtifact::Filter2d { .. }
            | PreparedArtifact::MatmulAccF32 { .. }
            | PreparedArtifact::MatmulInt { .. } => {
                jobs.iter().map(|inputs| self.run_one(&prep, inputs)).collect()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend_and_manifest() -> (InterpBackend, Manifest) {
        (InterpBackend::new(), Manifest::builtin("artifacts"))
    }

    #[test]
    fn every_builtin_artifact_has_a_kernel() {
        let (b, m) = backend_and_manifest();
        for meta in m.artifacts.values() {
            b.prepare(&m, meta).unwrap_or_else(|e| panic!("{}: {e}", meta.name));
        }
    }

    #[test]
    fn unknown_artifact_is_a_readable_error() {
        let meta = ArtifactMeta {
            name: "weird_thing".into(),
            file: "weird_thing.hlo.txt".into(),
            inputs: vec![],
            outputs: vec![],
            topology: None,
        };
        let err = kernel_for(&meta).unwrap_err().to_string();
        assert!(err.contains("weird_thing"), "{err}");
    }

    #[test]
    fn wrap_to_bits_is_twos_complement() {
        assert_eq!(wrap_to_bits(127, 8), 127);
        assert_eq!(wrap_to_bits(128, 8), -128);
        assert_eq!(wrap_to_bits(-129, 8), 127);
        assert_eq!(wrap_to_bits(300, 8), 44);
        assert_eq!(wrap_to_bits(32768, 16), -32768);
        assert_eq!(wrap_to_bits(5, 16), 5);
    }

    #[test]
    fn mm32_acc_adds_the_accumulator() {
        let (b, m) = backend_and_manifest();
        let meta = m.get("mm32_acc").unwrap();
        let a = Tensor::f32(&[32, 32], vec![1.0; 1024]);
        let eye = {
            let mut d = vec![0.0f32; 1024];
            for i in 0..32 {
                d[i * 32 + i] = 1.0;
            }
            Tensor::f32(&[32, 32], d)
        };
        let acc = Tensor::f32(&[32, 32], vec![0.5; 1024]);
        let out = b.execute(meta, &[a, eye, acc]).unwrap();
        assert!(out[0].as_f32().unwrap().iter().all(|&v| (v - 1.5).abs() < 1e-6));
    }

    #[test]
    fn execute_batch_matches_execute_for_every_family() {
        use crate::util::rng::Rng;
        let (b, m) = backend_and_manifest();
        let mut rng = Rng::new(41);
        for name in ["mm32", "mm32_acc", "mm32_i8", "filter2d_pu8", "fft1024"] {
            let meta = m.get(name).unwrap();
            let jobs: Vec<Vec<Tensor>> = (0..3)
                .map(|_| {
                    meta.inputs
                        .iter()
                        .map(|tm| match tm.dtype {
                            crate::runtime::tensor::DType::F32 => {
                                Tensor::f32(&tm.shape, rng.normal_vec(tm.elements()))
                            }
                            crate::runtime::tensor::DType::I32 => {
                                Tensor::i32(&tm.shape, rng.int_vec_i32(tm.elements(), -10, 10))
                            }
                        })
                        .collect()
                })
                .collect();
            let batched = b.execute_batch(meta, &jobs).unwrap();
            assert_eq!(batched.len(), jobs.len(), "{name}");
            for (j, job) in jobs.iter().enumerate() {
                let single = b.execute(meta, job).unwrap();
                // exact: every family routes the batch through the same
                // prepared state as the single-job path (the fft plan is
                // shared, the stacked matmul accumulates in matmul_ref's
                // order), so batching is bitwise invisible
                assert_eq!(single, batched[j], "{name} job {j}");
            }
        }
    }

    #[test]
    fn prepared_cache_builds_once_and_counts_hits() {
        use crate::util::rng::Rng;
        let (b, m) = backend_and_manifest();
        let meta = m.get("fft1024").unwrap();
        assert_eq!(b.cache_stats(), CacheStats::default());
        b.prepare(&m, meta).unwrap(); // the one build
        let mut rng = Rng::new(43);
        let job = vec![
            Tensor::f32(&[1024], rng.normal_vec(1024)),
            Tensor::f32(&[1024], rng.normal_vec(1024)),
        ];
        for _ in 0..5 {
            b.execute(meta, &job).unwrap();
        }
        let jobs = vec![job.clone(), job.clone(), job];
        b.execute_batch(meta, &jobs).unwrap();
        let cs = b.cache_stats();
        assert_eq!(cs.builds, 1, "fft plan must be built exactly once");
        // 5 executes + 1 batch dispatch, each one cache lookup
        assert_eq!(cs.hits, 6);
        // re-preparing is also just a hit
        b.prepare(&m, meta).unwrap();
        assert_eq!(b.cache_stats(), CacheStats { builds: 1, hits: 7 });
    }

    #[test]
    fn single_and_batch_fft_share_the_plan_exactly() {
        use crate::util::rng::Rng;
        let (b, m) = backend_and_manifest();
        let meta = m.get("fft2048").unwrap();
        let mut rng = Rng::new(44);
        let jobs: Vec<Vec<Tensor>> = (0..3)
            .map(|_| {
                vec![
                    Tensor::f32(&[2048], rng.normal_vec(2048)),
                    Tensor::f32(&[2048], rng.normal_vec(2048)),
                ]
            })
            .collect();
        let batched = b.execute_batch(meta, &jobs).unwrap();
        for (j, job) in jobs.iter().enumerate() {
            let single = b.execute(meta, job).unwrap();
            // bitwise, not within-tolerance: both paths run FftPlan::run
            assert_eq!(single, batched[j], "job {j}");
        }
    }

    #[test]
    fn execute_batch_of_one_matches_execute() {
        let (b, m) = backend_and_manifest();
        let meta = m.get("mm32").unwrap();
        let a = Tensor::f32(&[32, 32], vec![0.5; 1024]);
        let eye = Tensor::f32(&[32, 32], vec![1.0; 1024]);
        let jobs = vec![vec![a.clone(), eye.clone()]];
        let batched = b.execute_batch(meta, &jobs).unwrap();
        let single = b.execute(meta, &[a, eye]).unwrap();
        assert_eq!(batched[0], single);
    }

    #[test]
    fn int_mm_wraps_operands() {
        let (b, m) = backend_and_manifest();
        let meta = m.get("mm32_i8").unwrap();
        // 130 wraps to -126 as int8; identity B picks it out
        let mut a = vec![0i32; 1024];
        a[0] = 130;
        let mut eye = vec![0i32; 1024];
        for i in 0..32 {
            eye[i * 32 + i] = 1;
        }
        let out = b
            .execute(meta, &[Tensor::i32(&[32, 32], a), Tensor::i32(&[32, 32], eye)])
            .unwrap();
        assert_eq!(out[0].as_i32().unwrap()[0], -126);
    }
}
