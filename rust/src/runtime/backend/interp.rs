//! The pure-Rust interpreter backend: executes artifact *semantics*
//! directly from the reference kernels (`runtime::tensor::{matmul_ref,
//! filter2d_ref, fft_ref}` — the Rust mirrors of
//! `python/compile/kernels/ref.py`), dispatched by artifact name and
//! shaped by the manifest metadata.
//!
//! This is the default substrate: real numerics with zero native
//! dependencies, so `exec`, `serve` and the integration tests run in a
//! hermetic environment. Dimensions come from the manifest (not
//! hard-coded), so any mm/fft/filter2d-shaped artifact a future AOT
//! catalogue adds executes without code changes here.

use anyhow::{bail, Result};

use crate::runtime::manifest::{ArtifactMeta, Manifest};
use crate::runtime::tensor::{
    fft_ref, filter2d_ref, matmul_batch_ref, matmul_ref, FftPlan, Tensor,
};

use super::Backend;

/// How the interpreter realises one artifact family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kernel {
    /// C[m,n] = A[m,k] @ B[k,n], f32 (covers mm32, mm_pu128 and the
    /// mmt_cascade8 chain, whose 8 chained 32^3 stages sum to one
    /// 32x256x32 product).
    MatmulF32,
    /// C = A @ B + ACC, f32 (the cascade-stage kernel mm32_acc).
    MatmulAccF32,
    /// Integer matmul with operands wrapped to `bits` first (the
    /// mm32_i8/mm32_i16 low-bit contract: int32 tensors carrying
    /// narrow values; out-of-range inputs wrap like the hardware's
    /// narrow datapath).
    MatmulInt { bits: u32 },
    /// Batched valid-mode 2-D correlation over int32 halo tiles.
    Filter2d,
    /// Radix-2 FFT over split re/im f32 planes.
    Fft,
}

/// Resolve the kernel for an artifact name (+ metadata sanity).
fn kernel_for(meta: &ArtifactMeta) -> Result<Kernel> {
    let name = meta.name.as_str();
    let kernel = if name.starts_with("fft") {
        Kernel::Fft
    } else if name.starts_with("filter2d") {
        Kernel::Filter2d
    } else if name == "mm32_i8" {
        Kernel::MatmulInt { bits: 8 }
    } else if name == "mm32_i16" {
        Kernel::MatmulInt { bits: 16 }
    } else if name.starts_with("mm") && meta.inputs.len() == 3 {
        Kernel::MatmulAccF32
    } else if name.starts_with("mm") {
        Kernel::MatmulF32
    } else {
        bail!(
            "interpreter backend has no kernel for artifact {name:?} \
             (knows mm*, filter2d*, fft*)"
        );
    };
    Ok(kernel)
}

/// Matmul dims from the manifest: A[m,k] @ B[k,n].
fn mm_dims(meta: &ArtifactMeta) -> Result<(usize, usize, usize)> {
    if meta.inputs.len() < 2 {
        bail!("artifact {}: matmul needs two operands", meta.name);
    }
    let (a, b) = (&meta.inputs[0], &meta.inputs[1]);
    if a.shape.len() != 2 || b.shape.len() != 2 || a.shape[1] != b.shape[0] {
        bail!(
            "artifact {}: incompatible matmul shapes {:?} x {:?}",
            meta.name,
            a.shape,
            b.shape
        );
    }
    Ok((a.shape[0], a.shape[1], b.shape[1]))
}

/// Wrap an i32 value onto a narrower two's-complement width.
fn wrap_to_bits(v: i32, bits: u32) -> i32 {
    let shift = 32 - bits;
    (v << shift) >> shift
}

/// Integer matmul with exact int32 accumulation (wrapping, like the
/// hardware accumulator).
fn matmul_i32(a: &[i32], b: &[i32], m: usize, k: usize, n: usize) -> Vec<i32> {
    let mut c = vec![0i32; m * n];
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p];
            if av == 0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] = crow[j].wrapping_add(av.wrapping_mul(brow[j]));
            }
        }
    }
    c
}

/// The interpreter substrate. Stateless — "preparing" an artifact is
/// just resolving its kernel, which doubles as early validation.
pub struct InterpBackend;

impl InterpBackend {
    pub fn new() -> InterpBackend {
        InterpBackend
    }
}

impl Default for InterpBackend {
    fn default() -> Self {
        InterpBackend::new()
    }
}

impl Backend for InterpBackend {
    fn platform(&self) -> String {
        "interp-cpu (pure-Rust reference kernels)".to_string()
    }

    fn prepare(&self, _manifest: &Manifest, meta: &ArtifactMeta) -> Result<()> {
        let kernel = kernel_for(meta)?;
        // validate the metadata shapes once, so execute-time errors are
        // only about data
        match kernel {
            Kernel::MatmulF32 | Kernel::MatmulInt { .. } => {
                mm_dims(meta)?;
            }
            Kernel::MatmulAccF32 => {
                let (m, _, n) = mm_dims(meta)?;
                if meta.inputs[2].shape != [m, n] {
                    bail!(
                        "artifact {}: accumulator shape {:?} must match the product [{m}, {n}]",
                        meta.name,
                        meta.inputs[2].shape
                    );
                }
            }
            Kernel::Filter2d => {
                if meta.inputs.len() != 2 {
                    bail!("artifact {}: filter2d needs tiles + kernel inputs", meta.name);
                }
                let (x, k) = (&meta.inputs[0], &meta.inputs[1]);
                if x.shape.len() != 3 || k.shape.len() != 2 || k.shape[0] != k.shape[1] {
                    bail!(
                        "artifact {}: filter2d expects [batch, h, w] tiles and a square \
                         kernel, got {:?} / {:?}",
                        meta.name,
                        x.shape,
                        k.shape
                    );
                }
                let taps = k.shape[0];
                if x.shape[1] < taps || x.shape[2] < taps {
                    bail!("artifact {}: tile smaller than the kernel", meta.name);
                }
            }
            Kernel::Fft => {
                let n = meta
                    .inputs
                    .first()
                    .and_then(|t| t.shape.first())
                    .copied()
                    .unwrap_or(0);
                if meta.inputs.len() != 2 || !n.is_power_of_two() {
                    bail!(
                        "artifact {}: fft expects two power-of-two planes, got {:?}",
                        meta.name,
                        meta.inputs.iter().map(|t| &t.shape).collect::<Vec<_>>()
                    );
                }
            }
        }
        Ok(())
    }

    fn execute(&self, meta: &ArtifactMeta, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        match kernel_for(meta)? {
            Kernel::MatmulF32 => {
                let (m, k, n) = mm_dims(meta)?;
                let c = matmul_ref(inputs[0].as_f32()?, inputs[1].as_f32()?, m, k, n);
                Ok(vec![Tensor::f32(&[m, n], c)])
            }
            Kernel::MatmulAccF32 => {
                let (m, k, n) = mm_dims(meta)?;
                let mut c = matmul_ref(inputs[0].as_f32()?, inputs[1].as_f32()?, m, k, n);
                for (ci, acc) in c.iter_mut().zip(inputs[2].as_f32()?) {
                    *ci += acc;
                }
                Ok(vec![Tensor::f32(&[m, n], c)])
            }
            Kernel::MatmulInt { bits } => {
                let (m, k, n) = mm_dims(meta)?;
                let a: Vec<i32> =
                    inputs[0].as_i32()?.iter().map(|&v| wrap_to_bits(v, bits)).collect();
                let b: Vec<i32> =
                    inputs[1].as_i32()?.iter().map(|&v| wrap_to_bits(v, bits)).collect();
                Ok(vec![Tensor::i32(&[m, n], matmul_i32(&a, &b, m, k, n))])
            }
            Kernel::Filter2d => {
                let (batch, ih, iw) =
                    (meta.inputs[0].shape[0], meta.inputs[0].shape[1], meta.inputs[0].shape[2]);
                let taps = meta.inputs[1].shape[0];
                let (oh, ow) = (ih - (taps - 1), iw - (taps - 1));
                let tiles = inputs[0].as_i32()?;
                let kern = inputs[1].as_i32()?;
                let mut out = Vec::with_capacity(batch * oh * ow);
                for t in 0..batch {
                    let tile = &tiles[t * ih * iw..(t + 1) * ih * iw];
                    out.extend(filter2d_ref(tile, ih, iw, kern, taps));
                }
                Ok(vec![Tensor::i32(&[batch, oh, ow], out)])
            }
            Kernel::Fft => {
                let n = meta.inputs[0].shape[0];
                let (re, im) = fft_ref(inputs[0].as_f32()?, inputs[1].as_f32()?);
                Ok(vec![Tensor::f32(&[n], re), Tensor::f32(&[n], im)])
            }
        }
    }

    /// The micro-batch fast path: stack compatible jobs along a leading
    /// batch dimension and resolve the kernel/shape metadata once for
    /// the whole batch.
    ///
    /// * mm — operands packed into `[batch, m, k]` / `[batch, k, n]`
    ///   and run through the cache-blocked [`matmul_batch_ref`] kernel
    ///   (bitwise-identical accumulation order to `matmul_ref`).
    /// * fft — one [`FftPlan`] (bit-reversal table + per-stage
    ///   twiddles) shared by every transform in the batch; the trig
    ///   calls and per-level allocations of the recursive oracle are
    ///   paid once instead of per job.
    /// * filter2d — per-job kernels differ, so tiles run per job but
    ///   with the dispatch/dims resolved once.
    /// * everything else falls back to the per-job loop.
    fn execute_batch(&self, meta: &ArtifactMeta, jobs: &[Vec<Tensor>]) -> Result<Vec<Vec<Tensor>>> {
        if jobs.len() < 2 {
            return jobs.iter().map(|inputs| self.execute(meta, inputs)).collect();
        }
        match kernel_for(meta)? {
            Kernel::MatmulF32 => {
                let (m, k, n) = mm_dims(meta)?;
                let batch = jobs.len();
                let mut a = Vec::with_capacity(batch * m * k);
                let mut b = Vec::with_capacity(batch * k * n);
                for job in jobs {
                    a.extend_from_slice(job[0].as_f32()?);
                    b.extend_from_slice(job[1].as_f32()?);
                }
                let c = matmul_batch_ref(&a, &b, batch, m, k, n);
                Ok(c
                    .chunks_exact(m * n)
                    .map(|cj| vec![Tensor::f32(&[m, n], cj.to_vec())])
                    .collect())
            }
            Kernel::Fft => {
                let n = meta.inputs[0].shape[0];
                let plan = FftPlan::new(n);
                jobs.iter()
                    .map(|job| {
                        let (re, im) = plan.run(job[0].as_f32()?, job[1].as_f32()?);
                        Ok(vec![Tensor::f32(&[n], re), Tensor::f32(&[n], im)])
                    })
                    .collect()
            }
            Kernel::Filter2d => {
                let (batch, ih, iw) =
                    (meta.inputs[0].shape[0], meta.inputs[0].shape[1], meta.inputs[0].shape[2]);
                let taps = meta.inputs[1].shape[0];
                let (oh, ow) = (ih - (taps - 1), iw - (taps - 1));
                jobs.iter()
                    .map(|job| {
                        let tiles = job[0].as_i32()?;
                        let kern = job[1].as_i32()?;
                        let mut out = Vec::with_capacity(batch * oh * ow);
                        for t in 0..batch {
                            let tile = &tiles[t * ih * iw..(t + 1) * ih * iw];
                            out.extend(filter2d_ref(tile, ih, iw, kern, taps));
                        }
                        Ok(vec![Tensor::i32(&[batch, oh, ow], out)])
                    })
                    .collect()
            }
            Kernel::MatmulAccF32 | Kernel::MatmulInt { .. } => {
                jobs.iter().map(|inputs| self.execute(meta, inputs)).collect()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend_and_manifest() -> (InterpBackend, Manifest) {
        (InterpBackend::new(), Manifest::builtin("artifacts"))
    }

    #[test]
    fn every_builtin_artifact_has_a_kernel() {
        let (b, m) = backend_and_manifest();
        for meta in m.artifacts.values() {
            b.prepare(&m, meta).unwrap_or_else(|e| panic!("{}: {e}", meta.name));
        }
    }

    #[test]
    fn unknown_artifact_is_a_readable_error() {
        let meta = ArtifactMeta {
            name: "weird_thing".into(),
            file: "weird_thing.hlo.txt".into(),
            inputs: vec![],
            outputs: vec![],
        };
        let err = kernel_for(&meta).unwrap_err().to_string();
        assert!(err.contains("weird_thing"), "{err}");
    }

    #[test]
    fn wrap_to_bits_is_twos_complement() {
        assert_eq!(wrap_to_bits(127, 8), 127);
        assert_eq!(wrap_to_bits(128, 8), -128);
        assert_eq!(wrap_to_bits(-129, 8), 127);
        assert_eq!(wrap_to_bits(300, 8), 44);
        assert_eq!(wrap_to_bits(32768, 16), -32768);
        assert_eq!(wrap_to_bits(5, 16), 5);
    }

    #[test]
    fn mm32_acc_adds_the_accumulator() {
        let (b, m) = backend_and_manifest();
        let meta = m.get("mm32_acc").unwrap();
        let a = Tensor::f32(&[32, 32], vec![1.0; 1024]);
        let eye = {
            let mut d = vec![0.0f32; 1024];
            for i in 0..32 {
                d[i * 32 + i] = 1.0;
            }
            Tensor::f32(&[32, 32], d)
        };
        let acc = Tensor::f32(&[32, 32], vec![0.5; 1024]);
        let out = b.execute(meta, &[a, eye, acc]).unwrap();
        assert!(out[0].as_f32().unwrap().iter().all(|&v| (v - 1.5).abs() < 1e-6));
    }

    #[test]
    fn execute_batch_matches_execute_for_every_family() {
        use crate::util::rng::Rng;
        let (b, m) = backend_and_manifest();
        let mut rng = Rng::new(41);
        for name in ["mm32", "mm32_acc", "mm32_i8", "filter2d_pu8", "fft1024"] {
            let meta = m.get(name).unwrap();
            let jobs: Vec<Vec<Tensor>> = (0..3)
                .map(|_| {
                    meta.inputs
                        .iter()
                        .map(|tm| match tm.dtype {
                            crate::runtime::tensor::DType::F32 => {
                                Tensor::f32(&tm.shape, rng.normal_vec(tm.elements()))
                            }
                            crate::runtime::tensor::DType::I32 => {
                                Tensor::i32(&tm.shape, rng.int_vec_i32(tm.elements(), -10, 10))
                            }
                        })
                        .collect()
                })
                .collect();
            let batched = b.execute_batch(meta, &jobs).unwrap();
            assert_eq!(batched.len(), jobs.len(), "{name}");
            for (j, job) in jobs.iter().enumerate() {
                let single = b.execute(meta, job).unwrap();
                assert_eq!(single.len(), batched[j].len(), "{name} job {j}");
                for (s, bt) in single.iter().zip(&batched[j]) {
                    match s {
                        Tensor::I32 { .. } => assert_eq!(s, bt, "{name} job {j}"),
                        Tensor::F32 { .. } => {
                            let d = s.max_abs_diff(bt).unwrap();
                            assert!(d < 1e-6, "{name} job {j}: max diff {d}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn execute_batch_of_one_matches_execute() {
        let (b, m) = backend_and_manifest();
        let meta = m.get("mm32").unwrap();
        let a = Tensor::f32(&[32, 32], vec![0.5; 1024]);
        let eye = Tensor::f32(&[32, 32], vec![1.0; 1024]);
        let jobs = vec![vec![a.clone(), eye.clone()]];
        let batched = b.execute_batch(meta, &jobs).unwrap();
        let single = b.execute(meta, &[a, eye]).unwrap();
        assert_eq!(batched[0], single);
    }

    #[test]
    fn int_mm_wraps_operands() {
        let (b, m) = backend_and_manifest();
        let meta = m.get("mm32_i8").unwrap();
        // 130 wraps to -126 as int8; identity B picks it out
        let mut a = vec![0i32; 1024];
        a[0] = 130;
        let mut eye = vec![0i32; 1024];
        for i in 0..32 {
            eye[i * 32 + i] = 1;
        }
        let out = b
            .execute(meta, &[Tensor::i32(&[32, 32], a), Tensor::i32(&[32, 32], eye)])
            .unwrap();
        assert_eq!(out[0].as_i32().unwrap()[0], -126);
    }
}
