//! The execution-substrate seam of the runtime.
//!
//! EA4RCA's core idea is decoupling the algorithm graph from the
//! execution substrate (the paper's Graph Code Generator targets AIE
//! silicon; this reproduction targets whatever can run the numerics).
//! [`Backend`] is that seam on the serving side: the
//! [`Runtime`](crate::runtime::Runtime) owns manifest lookup, input
//! validation and stats, and delegates compile/execute to a backend:
//!
//! * [`interp::InterpBackend`] (default) — a pure-Rust interpreter that
//!   executes the artifact semantics via the reference kernels mirrored
//!   from `python/compile/kernels/ref.py` (mm, filter2d, fft). Zero
//!   native dependencies; runs from the built-in manifest alone.
//! * [`pjrt::PjrtBackend`] (`--features pjrt`) — the original
//!   `xla::PjRtClient` path: parse the AOT HLO text, compile once per
//!   process, execute literals. Needs the native XLA extension at link
//!   time (see vendor/xla and README.md).
//!
//! Backend selection: explicit via
//! [`Runtime::with_backend`](crate::runtime::Runtime::with_backend), or
//! `EA4RCA_BACKEND=interp|pjrt` for the CLI entry points (default
//! `interp`).

pub mod interp;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use anyhow::{bail, Result};

use crate::runtime::manifest::{ArtifactMeta, Manifest};
use crate::runtime::tensor::Tensor;

/// Prepared-artifact cache counters (see [`Backend::cache_stats`]).
///
/// The paper's whole performance argument is paying setup once (graph
/// build, twiddle generation, placement) and streaming data through a
/// fixed pipeline; these counters make that invariant observable:
/// `builds` should stay at one per artifact per backend instance no
/// matter how many jobs run.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    /// Prepared artifacts constructed (compiled / planned) — by
    /// `prepare` or lazily on first use.
    pub builds: u64,
    /// Lookups served from the cache without rebuilding anything.
    pub hits: u64,
}

/// An execution substrate for AOT artifacts.
///
/// Contract: the runtime calls [`Backend::prepare`] for an artifact
/// before its first [`Backend::execute`], and validates inputs against
/// the manifest before either call. `prepare` builds the artifact's
/// reusable state (compiled executable, FFT plan, blocking descriptors)
/// exactly once into a per-backend prepared-artifact cache; the
/// execute paths only look that state up. All methods take `&self` and
/// must be callable concurrently.
pub trait Backend {
    /// Human-readable substrate description (for `ea4rca info`).
    fn platform(&self) -> String;

    /// Compile/instantiate `meta` (idempotent). `manifest` supplies the
    /// artifact directory for substrates that load files.
    fn prepare(&self, manifest: &Manifest, meta: &ArtifactMeta) -> Result<()>;

    /// Build/hit counters of the prepared-artifact cache. The default
    /// (all zeros) is for substrates with nothing to cache.
    fn cache_stats(&self) -> CacheStats {
        CacheStats::default()
    }

    /// Execute the artifact on already-validated inputs.
    fn execute(&self, meta: &ArtifactMeta, inputs: &[Tensor]) -> Result<Vec<Tensor>>;

    /// Execute one artifact over a micro-batch of jobs (each element of
    /// `jobs` is one job's full input list, already validated). Returns
    /// one output list per job, in job order.
    ///
    /// The default is a plain loop over [`Backend::execute`]; substrates
    /// that can amortize work across compatible jobs (the interpreter
    /// stacks them along a leading batch dimension) override this. The
    /// serving layer's micro-batcher guarantees every job in a batch
    /// targets the same artifact.
    ///
    /// Contract: batching is a throughput optimisation only — per-job
    /// results must match what `execute` would have returned for the
    /// same inputs (the tier-1 property tests enforce 1e-6 agreement).
    fn execute_batch(&self, meta: &ArtifactMeta, jobs: &[Vec<Tensor>]) -> Result<Vec<Vec<Tensor>>> {
        jobs.iter().map(|inputs| self.execute(meta, inputs)).collect()
    }
}

/// Which backend implementation to instantiate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust reference-kernel interpreter (always available).
    Interp,
    /// PJRT over AOT HLO artifacts (requires the `pjrt` feature).
    Pjrt,
}

impl BackendKind {
    /// Parse `$EA4RCA_BACKEND` (unset -> the default interpreter).
    pub fn from_env() -> Result<BackendKind> {
        match std::env::var("EA4RCA_BACKEND").ok().as_deref() {
            None | Some("") | Some("interp") => Ok(BackendKind::Interp),
            Some("pjrt") => Ok(BackendKind::Pjrt),
            Some(other) => bail!("unknown EA4RCA_BACKEND {other:?} (expected interp | pjrt)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Interp => "interp",
            BackendKind::Pjrt => "pjrt",
        }
    }

    /// Instantiate the backend.
    pub fn create(self) -> Result<Box<dyn Backend>> {
        match self {
            BackendKind::Interp => Ok(Box::new(interp::InterpBackend::new())),
            BackendKind::Pjrt => {
                #[cfg(feature = "pjrt")]
                {
                    Ok(Box::new(pjrt::PjrtBackend::new()?))
                }
                #[cfg(not(feature = "pjrt"))]
                {
                    bail!(
                        "this binary was built without the `pjrt` feature; \
                         rebuild with `cargo build --features pjrt` or use the \
                         default interpreter backend"
                    )
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interp_is_always_available() {
        let b = BackendKind::Interp.create().unwrap();
        assert!(b.platform().contains("interp"));
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_without_feature_is_a_readable_error() {
        let err = BackendKind::Pjrt.create().err().unwrap().to_string();
        assert!(err.contains("pjrt"), "{err}");
    }

    #[test]
    fn kind_names() {
        assert_eq!(BackendKind::Interp.name(), "interp");
        assert_eq!(BackendKind::Pjrt.name(), "pjrt");
    }
}
