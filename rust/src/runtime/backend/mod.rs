//! The execution-substrate seam of the runtime.
//!
//! EA4RCA's core idea is decoupling the algorithm graph from the
//! execution substrate (the paper's Graph Code Generator targets AIE
//! silicon; this reproduction targets whatever can run the numerics).
//! [`Backend`] is that seam on the serving side: the
//! [`Runtime`](crate::runtime::Runtime) owns manifest lookup, input
//! validation and stats, and delegates compile/execute to a backend:
//!
//! * [`interp::InterpBackend`] (default) — a pure-Rust interpreter that
//!   executes the artifact semantics via the reference kernels mirrored
//!   from `python/compile/kernels/ref.py` (mm, filter2d, fft). Zero
//!   native dependencies; runs from the built-in manifest alone.
//! * [`sim::SimBackend`] — the unified pipeline: interpreter numerics
//!   (bitwise identical outputs) with the event-driven AIE model from
//!   `sim`/`coordinator::scheduler` run per dispatch as a *cost model*,
//!   attaching predicted latency, energy and phase breakdown to every
//!   result (see [`Backend::predict`]).
//! * [`pjrt::PjrtBackend`] (`--features pjrt`) — the original
//!   `xla::PjRtClient` path: parse the AOT HLO text, compile once per
//!   process, execute literals. Needs the native XLA extension at link
//!   time (see vendor/xla and README.md).
//!
//! Backend selection: explicit via
//! [`Runtime::with_backend`](crate::runtime::Runtime::with_backend), or
//! `EA4RCA_BACKEND=interp|sim|pjrt` for the CLI entry points (default
//! `interp`; the `--backend` flag wins over the environment).

pub mod interp;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod sim;

use anyhow::{bail, Result};

use crate::runtime::manifest::{ArtifactMeta, Manifest};
use crate::runtime::tensor::Tensor;

/// Prepared-artifact cache counters (see [`Backend::cache_stats`]).
///
/// The paper's whole performance argument is paying setup once (graph
/// build, twiddle generation, placement) and streaming data through a
/// fixed pipeline; these counters make that invariant observable:
/// `builds` should stay at one per artifact per backend instance no
/// matter how many jobs run.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    /// Prepared artifacts constructed (compiled / planned) — by
    /// `prepare` or lazily on first use.
    pub builds: u64,
    /// Lookups served from the cache without rebuilding anything.
    pub hits: u64,
    /// Of `builds`: artifacts prepared onto the SIMD kernel tier.
    pub simd_artifacts: u64,
    /// Of `builds`: artifacts prepared onto the scalar kernel tier —
    /// so a debug-mode or non-AVX2 run is self-describing.
    pub scalar_artifacts: u64,
    /// Micro-batch dispatches that actually fanned out across the
    /// worker pool (> 1 worker; see `runtime::parallel`).
    pub pooled_batches: u64,
}

/// Predicted execution cost of one dispatch (a single job or a
/// micro-batch) on the modelled AIE substrate, produced by a backend
/// that carries a cost model (see [`Backend::predict`]).
///
/// Predictions come from the same event-driven DU-PU simulation that
/// reproduces the paper's tables, run over the artifact's PU topology;
/// they are deterministic for a given (artifact, batch) pair.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostPrediction {
    /// Jobs in the dispatch this prediction covers.
    pub batch: usize,
    /// Predicted wall-clock of the whole dispatch on the AIE substrate
    /// (the sim makespan: dispatch + comm/compute phases + write-back).
    pub latency_secs: f64,
    /// Predicted average power draw of the lane (W).
    pub power_w: f64,
    /// Predicted energy for the dispatch (J) = power x latency.
    pub energy_j: f64,
    /// Phase breakdown: AIE compute busy seconds (per-PU lockstep time).
    pub compute_secs: f64,
    /// PLIO communication phase seconds.
    pub comm_secs: f64,
    /// DDR fetch seconds (operand streaming).
    pub fetch_secs: f64,
    /// Dependency-stall seconds.
    pub stall_secs: f64,
}

impl CostPrediction {
    /// Amortized per-job latency share of the dispatch.
    pub fn per_job_secs(&self) -> f64 {
        self.latency_secs / self.batch.max(1) as f64
    }
}

/// An execution substrate for AOT artifacts.
///
/// Contract: the runtime calls [`Backend::prepare`] for an artifact
/// before its first [`Backend::execute`], and validates inputs against
/// the manifest before either call. `prepare` builds the artifact's
/// reusable state (compiled executable, FFT plan, blocking descriptors)
/// exactly once into a per-backend prepared-artifact cache; the
/// execute paths only look that state up. All methods take `&self` and
/// must be callable concurrently.
pub trait Backend {
    /// Human-readable substrate description (for `ea4rca info`).
    fn platform(&self) -> String;

    /// Compile/instantiate `meta` (idempotent). `manifest` supplies the
    /// artifact directory for substrates that load files.
    fn prepare(&self, manifest: &Manifest, meta: &ArtifactMeta) -> Result<()>;

    /// Build/hit counters of the prepared-artifact cache. The default
    /// (all zeros) is for substrates with nothing to cache.
    fn cache_stats(&self) -> CacheStats {
        CacheStats::default()
    }

    /// The kernel tier that serves this artifact, once prepared — the
    /// interp/sim backends record it in the prepared-artifact cache so
    /// the serve report can say which kernel family ran. The default
    /// `None` is for substrates without a tier notion (PJRT) or
    /// artifacts not yet prepared.
    fn kernel_tier(&self, _meta: &ArtifactMeta) -> Option<crate::runtime::tier::KernelTier> {
        None
    }

    /// Predicted cost of dispatching `batch` jobs of this artifact, for
    /// substrates that carry a cost model (the sim backend). The default
    /// `None` is for substrates that only measure.
    fn predict(&self, _meta: &ArtifactMeta, _batch: usize) -> Option<CostPrediction> {
        None
    }

    /// Execute the artifact on already-validated inputs.
    fn execute(&self, meta: &ArtifactMeta, inputs: &[Tensor]) -> Result<Vec<Tensor>>;

    /// Execute one artifact over a micro-batch of jobs (each element of
    /// `jobs` is one job's full input list, already validated). Returns
    /// one output list per job, in job order.
    ///
    /// The default is a plain loop over [`Backend::execute`]; substrates
    /// that can amortize work across compatible jobs (the interpreter
    /// stacks them along a leading batch dimension) override this. The
    /// serving layer's micro-batcher guarantees every job in a batch
    /// targets the same artifact.
    ///
    /// Contract: batching is a throughput optimisation only — per-job
    /// results must match what `execute` would have returned for the
    /// same inputs (the tier-1 property tests enforce 1e-6 agreement).
    fn execute_batch(&self, meta: &ArtifactMeta, jobs: &[Vec<Tensor>]) -> Result<Vec<Vec<Tensor>>> {
        jobs.iter().map(|inputs| self.execute(meta, inputs)).collect()
    }
}

/// Which backend implementation to instantiate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust reference-kernel interpreter (always available).
    Interp,
    /// Interpreter numerics + event-driven AIE cost model (always
    /// available; every result gains a [`CostPrediction`]).
    Sim,
    /// PJRT over AOT HLO artifacts (requires the `pjrt` feature).
    Pjrt,
}

impl BackendKind {
    /// Parse a backend name (`interp | sim | pjrt`) — the shared parser
    /// behind the `--backend` flag and `$EA4RCA_BACKEND`.
    pub fn parse(s: &str) -> Result<BackendKind> {
        match s {
            "interp" => Ok(BackendKind::Interp),
            "sim" => Ok(BackendKind::Sim),
            "pjrt" => Ok(BackendKind::Pjrt),
            other => bail!("unknown backend {other:?} (expected interp | sim | pjrt)"),
        }
    }

    /// Parse `$EA4RCA_BACKEND` (unset -> the default interpreter). The
    /// CLI `--backend` flag, when given, wins over this.
    pub fn from_env() -> Result<BackendKind> {
        match std::env::var("EA4RCA_BACKEND").ok().as_deref() {
            None | Some("") => Ok(BackendKind::Interp),
            Some(s) => match BackendKind::parse(s) {
                Ok(kind) => Ok(kind),
                Err(_) => {
                    bail!("unknown EA4RCA_BACKEND {s:?} (expected interp | sim | pjrt)")
                }
            },
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Interp => "interp",
            BackendKind::Sim => "sim",
            BackendKind::Pjrt => "pjrt",
        }
    }

    /// Instantiate the backend. Tiered backends resolve
    /// `EA4RCA_KERNEL_TIER` / `EA4RCA_POOL_THREADS` strictly here, so a
    /// CLI run with a malformed knob (or `simd` forced on a CPU without
    /// AVX2+FMA) fails readably at startup instead of degrading.
    pub fn create(self) -> Result<Box<dyn Backend>> {
        match self {
            BackendKind::Interp => Ok(Box::new(interp::InterpBackend::from_env()?)),
            BackendKind::Sim => Ok(Box::new(sim::SimBackend::from_env()?)),
            BackendKind::Pjrt => {
                #[cfg(feature = "pjrt")]
                {
                    Ok(Box::new(pjrt::PjrtBackend::new()?))
                }
                #[cfg(not(feature = "pjrt"))]
                {
                    bail!(
                        "this binary was built without the `pjrt` feature; \
                         rebuild with `cargo build --features pjrt` or use the \
                         default interpreter backend"
                    )
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interp_is_always_available() {
        let b = BackendKind::Interp.create().unwrap();
        assert!(b.platform().contains("interp"));
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_without_feature_is_a_readable_error() {
        let err = BackendKind::Pjrt.create().err().unwrap().to_string();
        assert!(err.contains("pjrt"), "{err}");
    }

    #[test]
    fn kind_names() {
        assert_eq!(BackendKind::Interp.name(), "interp");
        assert_eq!(BackendKind::Sim.name(), "sim");
        assert_eq!(BackendKind::Pjrt.name(), "pjrt");
    }

    #[test]
    fn parse_kinds() {
        assert_eq!(BackendKind::parse("interp").unwrap(), BackendKind::Interp);
        assert_eq!(BackendKind::parse("sim").unwrap(), BackendKind::Sim);
        assert_eq!(BackendKind::parse("pjrt").unwrap(), BackendKind::Pjrt);
        assert!(BackendKind::parse("waffle").is_err());
    }

    #[test]
    fn sim_is_always_available() {
        let b = BackendKind::Sim.create().unwrap();
        assert!(b.platform().contains("sim"), "{}", b.platform());
    }

    #[test]
    fn per_job_share() {
        let p = CostPrediction {
            batch: 4,
            latency_secs: 8e-6,
            power_w: 10.0,
            energy_j: 8e-5,
            compute_secs: 4e-6,
            comm_secs: 2e-6,
            fetch_secs: 1e-6,
            stall_secs: 0.0,
        };
        assert!((p.per_job_secs() - 2e-6).abs() < 1e-18);
    }
}
