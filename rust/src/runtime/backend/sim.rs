//! The unified-pipeline backend: interpreter numerics + the event-driven
//! AIE model as a per-dispatch *cost model*.
//!
//! EA4RCA is a top-down pipeline — Graph Configuration File → generated
//! graph → running accelerator — and this backend is where the repo's
//! two halves meet it. Numerics delegate to [`InterpBackend`] (outputs
//! are bitwise identical to the default backend, batched or not), while
//! every artifact also gets a [`CostModel`]: its PU topology (carried on
//! [`ArtifactMeta`] from a `pu_config` manifest entry, or derived from
//! the paper's accelerator structures for the built-in catalogue) is
//! deployed as a [`GroupSpec::serving_lane`] and run through the same
//! [`SimEngine`] that reproduces Tables 6-9. One serving job maps to one
//! PU engine iteration, so a micro-batch of `k` jobs is a `k`-iteration
//! lane run — the prediction covers dispatch overhead, DDR fetch, PLIO
//! communication phases, AIE compute, and write-back, with power/energy
//! from the analytic PDM substitute.
//!
//! Predictions are deterministic (the simulator is pure integer-ps
//! arithmetic) and memoized per (artifact, batch size), so the serving
//! hot path pays a table lookup, not a simulation.

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{bail, Result};

use crate::apps::{fft, filter2d, mm, mmt};
use crate::coordinator::scheduler::{GroupSpec, SimEngine};
use crate::engine::compute::cc::CcMode;
use crate::engine::compute::dac::{Dac, DacMode};
use crate::engine::compute::dcc::{Dcc, DccMode};
use crate::engine::compute::pu::{ProcessingStructure, ProcessingUnit};
use crate::runtime::manifest::{ArtifactMeta, Manifest, PuTopology, TensorMeta};
use crate::runtime::tensor::{DType, Tensor};
use crate::sim::core::{fft_ops, filter_ops, mm_ops, KernelClass};
use crate::sim::memory::ResourceUsage;
use crate::sim::params::HwParams;
use crate::sim::power::{estimate, PowerBreakdownInput};
use crate::util::sync::lock_clean;

use super::interp::InterpBackend;
use super::{Backend, CacheStats, CostPrediction};

/// Bytes an artifact's tensors occupy on the serving wire.
fn wire_bytes(metas: &[TensorMeta]) -> usize {
    metas.iter().map(TensorMeta::byte_len).sum()
}

/// Derive the cost-model topology for a catalogue artifact that carries
/// none: the paper's accelerator PU structure for the family, with the
/// per-iteration op count and wire bytes taken from the artifact's own
/// shapes (so `mm32` and `mm_pu128` get different costs from the same
/// family rule). A carried topology always wins.
pub fn derive_topology(meta: &ArtifactMeta) -> Result<PuTopology> {
    if let Some(t) = &meta.topology {
        return Ok(t.clone());
    }
    let name = meta.name.as_str();
    let in_bytes = wire_bytes(&meta.inputs);
    let out_bytes = wire_bytes(&meta.outputs);

    let mut pu = if name.starts_with("fft") {
        let n = meta
            .inputs
            .first()
            .and_then(|t| t.shape.first())
            .copied()
            .unwrap_or(0);
        if n == 0 {
            bail!("artifact {name}: fft topology needs a sample count");
        }
        let mut pu = fft::fft_pu(n);
        pu.ops_per_iter = fft_ops(n);
        pu
    } else if name.starts_with("filter2d") {
        if meta.inputs.len() != 2 || meta.inputs[0].shape.len() != 3 {
            bail!("artifact {name}: filter2d topology needs [batch, h, w] tiles");
        }
        let (batch, ih, iw) = (
            meta.inputs[0].shape[0],
            meta.inputs[0].shape[1],
            meta.inputs[0].shape[2],
        );
        let taps = meta.inputs[1].shape.first().copied().unwrap_or(1).max(1);
        let (oh, ow) = (ih.saturating_sub(taps - 1), iw.saturating_sub(taps - 1));
        let mut pu = filter2d::filter2d_pu();
        pu.ops_per_iter = batch as f64 * filter_ops(oh * ow, taps);
        pu
    } else if name.starts_with("mmt") {
        // one chain iteration == one serving job through the cascade
        mmt::mmt_pu()
    } else if name.starts_with("mm") {
        if meta.inputs.len() < 2
            || meta.inputs[0].shape.len() != 2
            || meta.inputs[1].shape.len() != 2
        {
            bail!("artifact {name}: matmul topology needs two 2-D operands");
        }
        let (m, k) = (meta.inputs[0].shape[0], meta.inputs[0].shape[1]);
        let n = meta.inputs[1].shape[1];
        let class = if meta.inputs[0].dtype == DType::I32 {
            KernelClass::I32Mac
        } else {
            KernelClass::F32Mac
        };
        if m > 32 || k > 32 || n > 32 {
            // PU-scale block product: the paper's MM PU (Fig 7a)
            let mut pu = mm::mm_pu();
            pu.ops_per_iter = mm_ops(m, k, n);
            pu
        } else {
            // single-core kernel artifact: one AIE core, direct wiring
            ProcessingUnit::simple(
                name,
                vec![ProcessingStructure {
                    dacs: vec![Dac::new(vec![DacMode::Swh], 1, 1)],
                    cc: CcMode::Single,
                    dccs: vec![Dcc::new(DccMode::Swh, 1, 1)],
                }],
                class,
                mm_ops(m, k, n),
                in_bytes,
                out_bytes,
            )
        }
    } else {
        bail!(
            "no PU topology for artifact {name:?} — carry one in the manifest \
             (`pu_config`) or use a known family (mm*, mmt*, filter2d*, fft*)"
        );
    };

    // the serving wire moves the artifact's actual tensors
    pu.in_bytes_per_iter = in_bytes;
    pu.out_bytes_per_iter = out_bytes;
    pu.validate().map_err(anyhow::Error::msg)?;
    Ok(PuTopology { pu, copies: 1 })
}

/// Predict the cost of a `batch`-job dispatch on `topo` deployed as a
/// serving lane: the jobs spread across the deployed PU copies (every
/// copy solves one job per engine iteration), so a carried `copies: 6`
/// topology predicts genuinely different latency/power than a single
/// copy. Pure and deterministic — shared by this backend's memoized
/// cost model and the design facade's `Design::predict`, which runs it
/// straight off a built design with no runtime in sight.
pub fn predict_lane(
    p: &HwParams,
    name: &str,
    topo: &PuTopology,
    batch: usize,
) -> CostPrediction {
    let copies = topo.copies.max(1);
    let usage = ResourceUsage {
        aie: topo.pu.cores() * copies,
        plio: topo.pu.total_plios() * copies,
        ..Default::default()
    };
    let iters = (batch.max(1) as u64).div_ceil(copies as u64);
    let lane = GroupSpec::serving_lane(name, topo.pu.clone(), iters, copies);
    let report = SimEngine::new(p.clone()).with_trace(true).run(&[lane]);
    let g = &report.groups[0];
    let fetch_ps = report
        .trace
        .phase_totals_ps()
        .get("fetch")
        .copied()
        .unwrap_or(0);
    let power = estimate(
        p,
        &PowerBreakdownInput {
            usage,
            active_aie: topo.pu.cores() * copies,
            compute_duty: report.compute_duty,
            class: topo.pu.class,
            ddr_gbps: report.ddr_gbps,
            active_plio: topo.pu.total_plios() * copies,
        },
    )
    .total();
    CostPrediction {
        batch: batch.max(1),
        latency_secs: report.makespan_secs,
        power_w: power,
        energy_j: power * report.makespan_secs,
        compute_secs: HwParams::secs(g.compute_busy_ps),
        comm_secs: HwParams::secs(g.comm_busy_ps),
        fetch_secs: HwParams::secs(fetch_ps),
        stall_secs: HwParams::secs(g.stall_ps),
    }
}

/// One artifact's cost model: its serving-lane topology plus a memo of
/// deterministic per-batch-size predictions.
struct CostModel {
    topo: PuTopology,
    memo: HashMap<usize, CostPrediction>,
}

impl CostModel {
    fn build(meta: &ArtifactMeta) -> Result<CostModel> {
        Ok(CostModel { topo: derive_topology(meta)?, memo: HashMap::new() })
    }
}

/// Interpreter numerics + AIE cost model — see the module docs.
pub struct SimBackend {
    interp: InterpBackend,
    params: HwParams,
    models: Mutex<HashMap<String, CostModel>>,
}

impl SimBackend {
    /// Environment-configured backend (lenient tier resolution, like
    /// [`InterpBackend::new`]).
    pub fn new() -> SimBackend {
        SimBackend::over(InterpBackend::new())
    }

    /// Strict tier resolution — a malformed `EA4RCA_KERNEL_TIER` /
    /// `EA4RCA_POOL_THREADS` is a startup error (used by
    /// `BackendKind::create`).
    pub fn from_env() -> Result<SimBackend> {
        Ok(SimBackend::over(InterpBackend::from_env()?))
    }

    fn over(interp: InterpBackend) -> SimBackend {
        SimBackend {
            interp,
            params: HwParams::vck5000(),
            models: Mutex::new(HashMap::new()),
        }
    }

    /// Prediction with a loud error path (prepare uses this; the trait's
    /// `predict` flattens it to `Option`).
    fn predict_inner(&self, meta: &ArtifactMeta, batch: usize) -> Result<CostPrediction> {
        let mut models = lock_clean(&self.models);
        let model = match models.entry(meta.name.clone()) {
            Entry::Occupied(e) => e.into_mut(),
            Entry::Vacant(v) => v.insert(CostModel::build(meta)?),
        };
        if let Some(p) = model.memo.get(&batch) {
            return Ok(*p);
        }
        let pred = predict_lane(&self.params, &meta.name, &model.topo, batch);
        model.memo.insert(batch, pred);
        Ok(pred)
    }
}

impl Default for SimBackend {
    fn default() -> Self {
        SimBackend::new()
    }
}

impl Backend for SimBackend {
    fn platform(&self) -> String {
        format!(
            "sim-aie (event-driven VCK5000 cost model; numerics: {})",
            self.interp.platform()
        )
    }

    /// Prepare both halves of the pipeline: the interpreter's prepared
    /// artifact (numerics) and the cost model (topology + the
    /// single-job prediction), so serving warm-up pays the one-time
    /// setup and a topology problem is a load-time error, not a silent
    /// missing prediction.
    fn prepare(&self, manifest: &Manifest, meta: &ArtifactMeta) -> Result<()> {
        self.interp.prepare(manifest, meta)?;
        self.predict_inner(meta, 1)?;
        Ok(())
    }

    fn cache_stats(&self) -> CacheStats {
        // cost models build 1:1 with the interpreter's prepared
        // artifacts, so the numeric cache counters tell the whole story
        self.interp.cache_stats()
    }

    fn kernel_tier(&self, meta: &ArtifactMeta) -> Option<crate::runtime::tier::KernelTier> {
        // numerics (and therefore the tier) are the interpreter's
        self.interp.kernel_tier(meta)
    }

    fn predict(&self, meta: &ArtifactMeta, batch: usize) -> Option<CostPrediction> {
        self.predict_inner(meta, batch).ok()
    }

    fn execute(&self, meta: &ArtifactMeta, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.interp.execute(meta, inputs)
    }

    fn execute_batch(&self, meta: &ArtifactMeta, jobs: &[Vec<Tensor>]) -> Result<Vec<Vec<Tensor>>> {
        self.interp.execute_batch(meta, jobs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend_and_manifest() -> (SimBackend, Manifest) {
        (SimBackend::new(), Manifest::builtin("artifacts"))
    }

    #[test]
    fn every_builtin_artifact_has_a_topology_and_prepares() {
        let (b, m) = backend_and_manifest();
        for meta in m.artifacts.values() {
            let topo = derive_topology(meta).unwrap_or_else(|e| panic!("{}: {e}", meta.name));
            assert!(topo.cores() > 0, "{}", meta.name);
            assert_eq!(topo.pu.in_bytes_per_iter, wire_bytes(&meta.inputs), "{}", meta.name);
            b.prepare(&m, meta).unwrap_or_else(|e| panic!("{}: {e}", meta.name));
        }
    }

    #[test]
    fn derived_families_match_the_paper_structures() {
        let (_, m) = backend_and_manifest();
        assert_eq!(derive_topology(m.get("mm_pu128").unwrap()).unwrap().cores(), 64);
        assert_eq!(derive_topology(m.get("mm32").unwrap()).unwrap().cores(), 1);
        assert_eq!(derive_topology(m.get("mmt_cascade8").unwrap()).unwrap().cores(), 8);
        assert_eq!(derive_topology(m.get("filter2d_pu8").unwrap()).unwrap().cores(), 8);
        assert_eq!(derive_topology(m.get("fft1024").unwrap()).unwrap().cores(), 10);
    }

    #[test]
    fn carried_topology_wins_over_the_family_rule() {
        let (_, m) = backend_and_manifest();
        let mut meta = m.get("mm32").unwrap().clone();
        let carried = derive_topology(m.get("mm_pu128").unwrap()).unwrap();
        meta.topology = Some(PuTopology { copies: 3, ..carried });
        let topo = derive_topology(&meta).unwrap();
        assert_eq!(topo.cores(), 64, "carried 64-core topology beats the 1-core rule");
        assert_eq!(topo.copies, 3);
    }

    #[test]
    fn predictions_are_deterministic_and_scale_with_batch() {
        let (b, m) = backend_and_manifest();
        let meta = m.get("fft1024").unwrap();
        let p1 = b.predict(meta, 1).unwrap();
        let p1_again = b.predict(meta, 1).unwrap();
        assert_eq!(p1, p1_again);
        // a fresh backend instance predicts the identical number
        let fresh = SimBackend::new().predict(meta, 1).unwrap();
        assert_eq!(p1.latency_secs.to_bits(), fresh.latency_secs.to_bits());
        let p8 = b.predict(meta, 8).unwrap();
        assert!(p8.latency_secs > p1.latency_secs);
        assert!(p8.per_job_secs() <= p1.per_job_secs() * 1.001, "batching amortizes dispatch");
        assert!(p1.latency_secs > 0.0 && p1.energy_j > 0.0 && p1.power_w > 0.0);
        assert!(p1.compute_secs > 0.0);
    }

    #[test]
    fn carried_copies_widen_the_deployment() {
        // copies=6 spreads a 6-job dispatch over 6 PU copies in one
        // engine iteration: faster than 6 iterations of one copy, at
        // higher predicted power — the field is consumed, not carried
        // dead weight.
        let (b, m) = backend_and_manifest();
        let base = m.get("mm_pu128").unwrap().clone();
        let narrow = b.predict(&base, 6).unwrap();
        let mut wide_meta = base.clone();
        wide_meta.name = "mm_wide".into();
        let mut topo = derive_topology(&base).unwrap();
        topo.copies = 6;
        wide_meta.topology = Some(topo);
        let wide = b.predict(&wide_meta, 6).unwrap();
        assert!(wide.latency_secs < narrow.latency_secs, "{wide:?} vs {narrow:?}");
        assert!(wide.power_w > narrow.power_w);
    }

    #[test]
    fn unknown_artifact_predicts_none_and_prepare_fails_loudly() {
        let b = SimBackend::new();
        let meta = ArtifactMeta {
            name: "weird_thing".into(),
            file: "weird_thing.hlo.txt".into(),
            inputs: vec![],
            outputs: vec![],
            topology: None,
        };
        assert!(b.predict(&meta, 1).is_none());
        let err = derive_topology(&meta).unwrap_err().to_string();
        assert!(err.contains("weird_thing"), "{err}");
    }

    #[test]
    fn numerics_delegate_bitwise_to_interp() {
        use crate::util::rng::Rng;
        let (b, m) = backend_and_manifest();
        let interp = InterpBackend::new();
        let mut rng = Rng::new(77);
        let meta = m.get("mm_pu128").unwrap();
        let job = vec![
            Tensor::f32(&[128, 128], rng.normal_vec(128 * 128)),
            Tensor::f32(&[128, 128], rng.normal_vec(128 * 128)),
        ];
        assert_eq!(b.execute(meta, &job).unwrap(), interp.execute(meta, &job).unwrap());
    }
}
