//! Table-row formatting shared by the bench harnesses: turns
//! [`RunReport`](crate::coordinator::RunReport)s into the paper's table
//! rows (Tables 6-10) with consistent units.

use crate::coordinator::controller::RunReport;
use crate::util::table::{fmt_f, fmt_sci, Table};

/// A Table 6/7-style performance table (GOPS-class apps).
pub fn perf_table(title: &str) -> Table {
    Table::new(
        title,
        &["Problem Size", "PU Qty", "Time (ms)", "Tasks/sec", "GOPS", "GOPS/AIE",
          "Power (W)", "GOPS/W"],
    )
}

/// Append a report as a Table 6/7-style row.
pub fn perf_row(t: &mut Table, problem: &str, pus: &str, r: &RunReport, aie_override: Option<usize>) {
    let aie = aie_override.unwrap_or(r.active_aie);
    let gops_per_aie = r.gops / aie.max(1) as f64;
    t.row(&[
        problem.to_string(),
        pus.to_string(),
        fmt_f(r.time_secs * 1e3, 2),
        fmt_f(r.tasks_per_sec, 2),
        fmt_f(r.gops, 2),
        fmt_f(gops_per_aie, 3),
        fmt_f(r.power_w, 2),
        fmt_f(r.gops_per_w, 2),
    ]);
}

/// A Table 8-style FFT table (TPS-class apps).
pub fn fft_table(title: &str) -> Table {
    Table::new(
        title,
        &["Sample Size", "PU Qty", "Run Time (us)", "Tasks/sec", "Power (W)", "Tasks/sec/W"],
    )
}

/// Append an FFT row; `run_time_us` is per-task aggregate (the paper's
/// "Run Time" column = 1 / tasks_per_sec).
pub fn fft_row(t: &mut Table, n: usize, pus: &str, r: Option<&RunReport>) {
    match r {
        Some(r) => {
            t.row(&[
                n.to_string(),
                pus.to_string(),
                fmt_f(1e6 / r.tasks_per_sec, 2),
                fmt_f(r.tasks_per_sec, 2),
                fmt_f(r.power_w, 2),
                fmt_f(r.tasks_per_sec_per_w, 2),
            ]);
        }
        None => {
            t.row(&[
                n.to_string(),
                pus.to_string(),
                "N/A".into(),
                "N/A".into(),
                "N/A".into(),
                "N/A".into(),
            ]);
        }
    }
}

/// Format a tasks/sec in the paper's 9.43x10^7 style.
pub fn tasks_sci(tps: f64) -> String {
    fmt_sci(tps)
}

/// Paper-vs-measured comparison row for EXPERIMENTS.md-style output.
pub fn compare_line(metric: &str, paper: f64, measured: f64) -> String {
    let ratio = measured / paper;
    format!("{metric:<28} paper {paper:>12.2}  measured {measured:>12.2}  ratio {ratio:>5.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compare_line_format() {
        let l = compare_line("GOPS", 3421.02, 3400.0);
        assert!(l.contains("paper"));
        assert!(l.contains("0.99x"));
    }

    #[test]
    fn fft_na_row() {
        let mut t = fft_table("t");
        fft_row(&mut t, 8192, "2(25%)", None);
        assert!(t.render().contains("N/A"));
    }
}
