//! Table-row formatting shared by the bench harnesses: turns
//! [`RunReport`](crate::coordinator::RunReport)s into the paper's table
//! rows (Tables 6-10) with consistent units.

use crate::coordinator::controller::RunReport;
use crate::coordinator::server::ArtifactServeStats;
use crate::util::table::{fmt_f, fmt_sci, Table};

/// A Table 6/7-style performance table (GOPS-class apps).
pub fn perf_table(title: &str) -> Table {
    Table::new(
        title,
        &["Problem Size", "PU Qty", "Time (ms)", "Tasks/sec", "GOPS", "GOPS/AIE",
          "Power (W)", "GOPS/W"],
    )
}

/// Append a report as a Table 6/7-style row.
pub fn perf_row(t: &mut Table, problem: &str, pus: &str, r: &RunReport, aie_override: Option<usize>) {
    let aie = aie_override.unwrap_or(r.active_aie);
    let gops_per_aie = r.gops / aie.max(1) as f64;
    t.row(&[
        problem.to_string(),
        pus.to_string(),
        fmt_f(r.time_secs * 1e3, 2),
        fmt_f(r.tasks_per_sec, 2),
        fmt_f(r.gops, 2),
        fmt_f(gops_per_aie, 3),
        fmt_f(r.power_w, 2),
        fmt_f(r.gops_per_w, 2),
    ]);
}

/// A Table 8-style FFT table (TPS-class apps).
pub fn fft_table(title: &str) -> Table {
    Table::new(
        title,
        &["Sample Size", "PU Qty", "Run Time (us)", "Tasks/sec", "Power (W)", "Tasks/sec/W"],
    )
}

/// Append an FFT row; `run_time_us` is per-task aggregate (the paper's
/// "Run Time" column = 1 / tasks_per_sec).
pub fn fft_row(t: &mut Table, n: usize, pus: &str, r: Option<&RunReport>) {
    match r {
        Some(r) => {
            t.row(&[
                n.to_string(),
                pus.to_string(),
                fmt_f(1e6 / r.tasks_per_sec, 2),
                fmt_f(r.tasks_per_sec, 2),
                fmt_f(r.power_w, 2),
                fmt_f(r.tasks_per_sec_per_w, 2),
            ]);
        }
        None => {
            t.row(&[
                n.to_string(),
                pus.to_string(),
                "N/A".into(),
                "N/A".into(),
                "N/A".into(),
                "N/A".into(),
            ]);
        }
    }
}

/// Format a tasks/sec in the paper's 9.43x10^7 style.
pub fn tasks_sci(tps: f64) -> String {
    fmt_sci(tps)
}

/// The serving layer's predicted-vs-measured table (cost-model
/// calibration view: what the sim backend predicted for each dispatch
/// against what the substrate measured).
pub fn cost_table(title: &str) -> Table {
    Table::new(
        title,
        &["Artifact", "Tier", "Jobs", "Batches", "Measured ms/b", "Predicted ms/b",
          "Pred/Meas", "Energy (mJ/b)"],
    )
}

/// Append one artifact's predicted-vs-measured ledger as a row.
pub fn cost_row(t: &mut Table, artifact: &str, s: &ArtifactServeStats) {
    let measured_ms = s.measured_exec_secs / s.batches.max(1) as f64 * 1e3;
    let (predicted, energy, ratio) = if s.predicted_batches > 0 {
        (
            fmt_f(s.predicted_exec_secs / s.predicted_batches as f64 * 1e3, 3),
            fmt_f(s.predicted_energy_j / s.predicted_batches as f64 * 1e3, 3),
            s.ratio().map(|r| format!("{r:.2}x")).unwrap_or_else(|| "n/a".into()),
        )
    } else {
        ("n/a".into(), "n/a".into(), "n/a".into())
    };
    t.row(&[
        artifact.to_string(),
        s.tier.map(|k| k.name().to_string()).unwrap_or_else(|| "n/a".into()),
        s.jobs.to_string(),
        s.batches.to_string(),
        fmt_f(measured_ms, 3),
        predicted,
        ratio,
        energy,
    ]);
}

/// Paper-vs-measured comparison row for EXPERIMENTS.md-style output.
pub fn compare_line(metric: &str, paper: f64, measured: f64) -> String {
    let ratio = measured / paper;
    format!("{metric:<28} paper {paper:>12.2}  measured {measured:>12.2}  ratio {ratio:>5.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compare_line_format() {
        let l = compare_line("GOPS", 3421.02, 3400.0);
        assert!(l.contains("paper"));
        assert!(l.contains("0.99x"));
    }

    #[test]
    fn fft_na_row() {
        let mut t = fft_table("t");
        fft_row(&mut t, 8192, "2(25%)", None);
        assert!(t.render().contains("N/A"));
    }

    #[test]
    fn cost_rows_render_with_and_without_predictions() {
        let mut t = cost_table("predicted vs measured");
        cost_row(
            &mut t,
            "mm_pu128",
            &ArtifactServeStats {
                jobs: 8,
                batches: 2,
                measured_exec_secs: 4e-3,
                predicted_exec_secs: 3e-3,
                predicted_energy_j: 2e-4,
                predicted_batches: 2,
                tier: Some(crate::runtime::tier::KernelTier::Simd),
            },
        );
        cost_row(&mut t, "fft1024", &ArtifactServeStats {
            jobs: 3,
            batches: 3,
            measured_exec_secs: 3e-3,
            ..Default::default()
        });
        let r = t.render();
        assert!(r.contains("Tier"));
        assert!(r.contains("mm_pu128"));
        assert!(r.contains("simd"), "{r}");
        assert!(r.contains("0.75x"), "{r}");
        assert!(r.contains("n/a"), "{r}");
    }
}
