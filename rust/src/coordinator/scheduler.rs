//! The DU-PU pair scheduler: an event-driven simulation of the paper's
//! Figure 2 execution — every pair alternates a communication phase
//! (PLIO traffic between DU and PUs, AIE compute disabled) and a
//! computation phase (AIE enabled, DU prefetching the next task block) —
//! over the shared DDR controller.
//!
//! Groups (one DU + its PUs) run independently; the only cross-group
//! coupling is DDR FIFO contention, which is exactly the paper's
//! bottleneck story for high-PU-count configurations.

use crate::engine::compute::pu::ProcessingUnit;
use crate::engine::data::du::DataUnit;
use crate::engine::data::ssc::SscMode;
use crate::engine::data::tpc::{TaskBlock, TpcMode};
use crate::sim::comm::TransferMethod;
use crate::sim::ddr::{AmcMode, Ddr};
use crate::sim::params::HwParams;
use crate::sim::trace::{Phase, Trace};

/// How a group executes its iterations (paper §3.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecMode {
    /// The EA4RCA regular-CA design: aggregated communication phases
    /// alternating with compute (Table 2 method 3 at system level).
    #[default]
    Regular,
    /// Non-RCA fallback with stream buffering: communication overlaps
    /// compute through ping-pong windows (method 2) — partial
    /// separation, some degradation.
    Buffered,
    /// Non-RCA worst case: communication interleaves with compute in
    /// small grains, stalling the pipeline per grain (method 1).
    Interleaved,
}

/// One DU-PUs pair group plus its share of the workload.
#[derive(Debug, Clone)]
pub struct GroupSpec {
    pub name: String,
    pub du: DataUnit,
    pub pu: ProcessingUnit,
    /// Engine iterations this group executes (each iteration = every PU
    /// in the group solving one subtask).
    pub engine_iters: u64,
    /// Execution discipline (Regular unless modelling a non-RCA app).
    pub mode: ExecMode,
}

impl GroupSpec {
    pub fn new(name: impl Into<String>, du: DataUnit, pu: ProcessingUnit, engine_iters: u64) -> GroupSpec {
        GroupSpec { name: name.into(), du, pu, engine_iters, mode: ExecMode::Regular }
    }

    pub fn with_mode(mut self, mode: ExecMode) -> GroupSpec {
        self.mode = mode;
        self
    }

    /// One *serving lane*: a streaming DU (THR TPC — per-iteration
    /// operand fetch from DDR, per-iteration result write-back) serving
    /// `copies` deployed PU copies in parallel (PHD service). This is
    /// the GroupSpec shape the sim backend's cost model runs: every PU
    /// copy solves one serving job per engine iteration, so a dispatch
    /// of `k` jobs on a `copies`-wide deployment is
    /// `ceil(k / copies)` iterations of the lane.
    pub fn serving_lane(
        name: impl Into<String>,
        pu: ProcessingUnit,
        iters: u64,
        copies: usize,
    ) -> GroupSpec {
        let copies = copies.max(1);
        // every copy writes its result back each iteration
        let out_bytes = pu.out_bytes_per_iter * copies;
        GroupSpec {
            name: name.into(),
            du: DataUnit {
                name: "serve-DU".into(),
                amc_read: Some(AmcMode::Csb),
                amc_write: Some(AmcMode::Csb),
                tpc: TpcMode::Thr,
                ssc_send: SscMode::Phd,
                ssc_recv: SscMode::Phd,
                tb: TaskBlock::new(0, 0, out_bytes),
                pus: copies,
            },
            pu,
            engine_iters: iters.max(1),
            mode: ExecMode::Regular,
        }
    }
}

impl GroupSpec {
    pub fn validate(&self) -> Result<(), String> {
        self.du.validate()?;
        self.pu.validate()?;
        Ok(())
    }

    pub fn cores(&self) -> usize {
        self.du.pus * self.pu.cores()
    }
}

/// Per-group accounting out of a simulation run.
#[derive(Debug, Clone, Default)]
pub struct GroupStats {
    pub name: String,
    pub iters: u64,
    pub finish_ps: u64,
    pub compute_busy_ps: u64,
    pub comm_busy_ps: u64,
    pub stall_ps: u64,
}

/// The whole-run report.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Total wall-clock including dispatch and final write-back (secs).
    pub makespan_secs: f64,
    /// Mean fraction of the makespan PU cores spend computing.
    pub compute_duty: f64,
    /// Achieved DDR bandwidth over the run (GB/s).
    pub ddr_gbps: f64,
    /// DDR queueing time (contention indicator, secs).
    pub ddr_queue_secs: f64,
    pub groups: Vec<GroupStats>,
    pub trace: Trace,
}

/// Scheduler state for one group while the run is in flight.
struct GroupState {
    spec: GroupSpec,
    /// next engine iteration to run
    next_iter: u64,
    /// when the previous iteration's phases finished
    prev_end_ps: u64,
    /// completion times (fetch + process) per fetched TB index
    tb_ready_ps: Vec<u64>,
    /// index of the next TB to fetch
    next_tb_fetch: u64,
    stats: GroupStats,
    // cached per-iteration timings
    comm_per_pu_ps: u64,
    compute_ps: u64,
}

impl GroupState {
    fn tb_count(&self) -> u64 {
        match self.spec.du.tpc {
            TpcMode::Chl => 1,
            TpcMode::Thr => self.spec.engine_iters, // streamed per iteration
            TpcMode::Cup => {
                let per = self.spec.du.tb.engine_iters.max(1);
                self.spec.engine_iters.div_ceil(per)
            }
        }
    }

    fn tb_for_iter(&self, iter: u64) -> u64 {
        match self.spec.du.tpc {
            TpcMode::Chl => 0,
            TpcMode::Thr => iter,
            TpcMode::Cup => iter / self.spec.du.tb.engine_iters.max(1),
        }
    }

    /// Lower bound on when this group's next iteration could start.
    fn next_ready_lb(&self) -> u64 {
        let tb = self.tb_for_iter(self.next_iter) as usize;
        let tb_ready = self.tb_ready_ps.get(tb).copied().unwrap_or(u64::MAX);
        self.prev_end_ps.max(tb_ready.min(u64::MAX - 1))
    }
}

/// The simulation engine.
pub struct SimEngine {
    pub params: HwParams,
    pub trace_enabled: bool,
}

impl SimEngine {
    pub fn new(params: HwParams) -> SimEngine {
        SimEngine { params, trace_enabled: false }
    }

    pub fn with_trace(mut self, on: bool) -> SimEngine {
        self.trace_enabled = on;
        self
    }

    /// Run the groups to completion.
    pub fn run(&self, groups: &[GroupSpec]) -> SimReport {
        let p = &self.params;
        let mut ddr = Ddr::new(p);
        let mut trace = Trace::new(self.trace_enabled);
        let dispatch_ps = HwParams::ps(p.dispatch_secs);

        let mut states: Vec<GroupState> = groups
            .iter()
            .map(|g| {
                // Per-iteration phase lengths under the group's execution
                // discipline (§3.2: Regular = aggregated phases; Buffered
                // = method-2 ping-pong overlap; Interleaved = method-1
                // grain-by-grain crossover).
                let wire_bytes = g.pu.in_bytes_per_iter + g.pu.out_bytes_per_iter;
                let (comm, compute) = match g.mode {
                    ExecMode::Regular => (g.pu.comm_secs(p), g.pu.compute_secs(p)),
                    ExecMode::Buffered => {
                        let stream = TransferMethod::StreamAggregated.secs(p, wire_bytes);
                        (0.0, g.pu.compute_secs(p).max(stream))
                    }
                    ExecMode::Interleaved => {
                        let stream = TransferMethod::StreamInterleaved { grain_bytes: 64 }
                            .secs(p, wire_bytes);
                        (0.0, g.pu.compute_secs(p) + stream)
                    }
                };
                GroupState {
                    spec: g.clone(),
                    next_iter: 0,
                    prev_end_ps: dispatch_ps,
                    tb_ready_ps: Vec::new(),
                    next_tb_fetch: 0,
                    stats: GroupStats { name: g.name.clone(), ..Default::default() },
                    comm_per_pu_ps: HwParams::ps(comm),
                    compute_ps: HwParams::ps(compute),
                }
            })
            .collect();

        // Issue the initial TB fetch (and one prefetch) for every group.
        for (gi, st) in states.iter_mut().enumerate() {
            let prefetch_depth = st.tb_count().min(2);
            for _ in 0..prefetch_depth {
                Self::issue_fetch(p, &mut ddr, &mut trace, gi, st, dispatch_ps);
            }
        }

        // Advance the group with the earliest feasible next iteration.
        loop {
            let mut best: Option<(usize, u64)> = None;
            for (gi, st) in states.iter().enumerate() {
                if st.next_iter >= st.spec.engine_iters {
                    continue;
                }
                let lb = st.next_ready_lb();
                if best.map(|(_, t)| lb < t).unwrap_or(true) {
                    best = Some((gi, lb));
                }
            }
            let Some((gi, _)) = best else { break };
            self.step_group(&mut ddr, &mut trace, gi, &mut states[gi]);
        }

        // Final write-back drain: the makespan includes the last DDR write.
        let last_iter_end = states.iter().map(|s| s.prev_end_ps).max().unwrap_or(0);
        let makespan_ps = last_iter_end.max(ddr.busy_until());
        let makespan_secs = HwParams::secs(makespan_ps);

        // Duty: compute-busy core-time over total core-time.
        let mut busy_core_ps = 0.0_f64;
        let mut core_count = 0.0_f64;
        for st in &states {
            busy_core_ps += st.stats.compute_busy_ps as f64 * st.spec.cores() as f64;
            core_count += st.spec.cores() as f64;
        }
        let compute_duty = if makespan_ps > 0 && core_count > 0.0 {
            busy_core_ps / (core_count * makespan_ps as f64)
        } else {
            0.0
        };

        SimReport {
            makespan_secs,
            compute_duty,
            ddr_gbps: ddr.achieved_gbps(makespan_secs),
            ddr_queue_secs: HwParams::secs(ddr.total_queue_ps),
            groups: states.into_iter().map(|s| s.stats).collect(),
            trace,
        }
    }

    /// Issue the next TB fetch for a group (if any remain).
    fn issue_fetch(
        p: &HwParams,
        ddr: &mut Ddr,
        trace: &mut Trace,
        gi: usize,
        st: &mut GroupState,
        now_ps: u64,
    ) {
        if st.next_tb_fetch >= st.tb_count() {
            return;
        }
        let du = &st.spec.du;
        let ready = match (du.tpc, du.amc_read) {
            // THR streams per-iteration input: fetch the per-iteration
            // bytes for all PUs.
            (TpcMode::Thr, Some(mode)) => {
                let bytes = st.spec.pu.in_bytes_per_iter * du.pus;
                let (s, d) = ddr.transfer(now_ps, bytes, mode, p);
                trace.record(&format!("G{gi}.DU"), Phase::Fetch, s, d);
                d
            }
            (_, Some(mode)) if du.tb.read_bytes > 0 => {
                let (s, d) = ddr.transfer(now_ps, du.tb.read_bytes, mode, p);
                trace.record(&format!("G{gi}.DU"), Phase::Fetch, s, d);
                let proc = HwParams::ps(du.tb_process_secs(p));
                trace.record(&format!("G{gi}.DU"), Phase::Process, d, d + proc);
                d + proc
            }
            // No AMC read (MM-T): data is resident from the start.
            _ => now_ps,
        };
        let idx = st.next_tb_fetch as usize;
        if st.tb_ready_ps.len() <= idx {
            st.tb_ready_ps.resize(idx + 1, u64::MAX);
        }
        st.tb_ready_ps[idx] = ready;
        st.next_tb_fetch += 1;
    }

    /// Execute one engine iteration of one group.
    fn step_group(&self, ddr: &mut Ddr, trace: &mut Trace, gi: usize, st: &mut GroupState) {
        let p = &self.params;
        let iter = st.next_iter;
        let tb = st.tb_for_iter(iter) as usize;
        let data_ready = st.tb_ready_ps[tb];
        let phase_start = st.prev_end_ps.max(data_ready);
        if phase_start > st.prev_end_ps {
            st.stats.stall_ps += phase_start - st.prev_end_ps;
            trace.record(&format!("G{gi}.PU0"), Phase::Stall, st.prev_end_ps, phase_start);
        }

        // Communication phase (Fig 5 service discipline), then compute.
        let pus = st.spec.du.pus;
        let comm = st.comm_per_pu_ps;
        let compute = st.compute_ps;
        let mut iter_end = phase_start;
        for pu_idx in 0..pus {
            let off = HwParams::ps(
                st.spec
                    .du
                    .ssc_send
                    .service_start_offset(pu_idx, HwParams::secs(comm)),
            );
            let comm_start = phase_start + off;
            let comm_end = comm_start + comm;
            let compute_end = comm_end + compute;
            iter_end = iter_end.max(compute_end);
            if self.trace_enabled && pu_idx < 8 {
                let lane = format!("G{gi}.PU{pu_idx}");
                trace.record(&lane, Phase::Comm, comm_start, comm_end);
                trace.record(&lane, Phase::Compute, comm_end, compute_end);
            }
        }
        st.stats.comm_busy_ps += comm; // per-PU comm busy (lockstep accounting)
        st.stats.compute_busy_ps += compute;
        st.stats.iters += 1;
        st.prev_end_ps = iter_end;
        st.stats.finish_ps = iter_end;
        st.next_iter += 1;

        // Write-back of aggregated results (the TPC holds partials in
        // URAM and writes every `writeback_every` iterations).
        if let Some(mode) = st.spec.du.amc_write {
            let wb = st.spec.du.tb.writeback_bytes_per_iter;
            let every = st.spec.du.tb.writeback_every.max(1);
            if wb > 0 && (iter + 1) % every == 0 {
                ddr.transfer(iter_end, wb, mode, p);
            }
        }

        // Prefetch the next TB while the PUs compute (CUP pipelining):
        // triggered when we advance into a new TB region.
        let next_tb = st.tb_for_iter(st.next_iter.min(st.spec.engine_iters.saturating_sub(1)));
        if st.next_iter < st.spec.engine_iters && st.next_tb_fetch <= next_tb + 1 {
            Self::issue_fetch(p, ddr, trace, gi, st, phase_start);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::compute::cc::CcMode;
    use crate::engine::compute::dac::{Dac, DacMode};
    use crate::engine::compute::dcc::{Dcc, DccMode};
    use crate::engine::compute::pu::{ProcessingStructure, ProcessingUnit};
    use crate::engine::data::ssc::SscMode;
    use crate::engine::data::tpc::TaskBlock;
    use crate::sim::core::KernelClass;
    use crate::sim::ddr::AmcMode;

    fn mm_group(pus: usize, engine_iters: u64) -> GroupSpec {
        GroupSpec {
            name: format!("mm-{pus}pu"),
            du: DataUnit {
                name: "DU".into(),
                amc_read: Some(AmcMode::Jub),
                amc_write: Some(AmcMode::Csb),
                tpc: TpcMode::Cup,
                ssc_send: SscMode::Phd,
                ssc_recv: SscMode::Phd,
                tb: TaskBlock::new(27 * 128 * 128 * 4, 9, pus * 128 * 128 * 4),
                pus,
            },
            pu: ProcessingUnit::simple(
                "MM",
                vec![ProcessingStructure {
                    dacs: vec![Dac::new(vec![DacMode::Swh, DacMode::Bdc], 8, 64)],
                    cc: CcMode::Parallel(16, Box::new(CcMode::Cascade(4))),
                    dccs: vec![Dcc::new(DccMode::Swh, 4, 64)],
                }],
                KernelClass::F32Mac,
                2.0 * 128.0f64.powi(3),
                2 * 128 * 128 * 4,
                128 * 128 * 4,
            ),
            engine_iters,
            mode: ExecMode::Regular,
        }
    }

    #[test]
    fn mm_768_six_pu_near_paper() {
        // 768^3 with 6 PUs: 36 engine iterations -> paper 0.44 ms.
        let engine = SimEngine::new(HwParams::vck5000());
        let r = engine.run(&[mm_group(6, 36)]);
        let ms = r.makespan_secs * 1e3;
        assert!((ms - 0.44).abs() / 0.44 < 0.15, "makespan {ms} ms");
    }

    #[test]
    fn mm_6144_six_pu_near_paper() {
        // 6144^3: ceil(48^3/6) = 18432 iterations -> paper 135.59 ms.
        let engine = SimEngine::new(HwParams::vck5000());
        let r = engine.run(&[mm_group(6, 18432)]);
        let ms = r.makespan_secs * 1e3;
        assert!((ms - 135.59).abs() / 135.59 < 0.10, "makespan {ms} ms");
    }

    #[test]
    fn more_iterations_take_longer() {
        // The *incremental* cost of 90 extra iterations is ~90 x 7.65 us;
        // the fixed dispatch overhead does not grow.
        let engine = SimEngine::new(HwParams::vck5000());
        let a = engine.run(&[mm_group(6, 10)]).makespan_secs;
        let b = engine.run(&[mm_group(6, 100)]).makespan_secs;
        let delta_us = (b - a) * 1e6;
        assert!((delta_us - 90.0 * 7.65).abs() / (90.0 * 7.65) < 0.25, "{delta_us}");
    }

    #[test]
    fn duty_increases_with_scale() {
        // Dispatch overhead dilutes duty at small scale (Table 6's
        // GOPS/AIE shape).
        let engine = SimEngine::new(HwParams::vck5000());
        let small = engine.run(&[mm_group(6, 36)]).compute_duty;
        let large = engine.run(&[mm_group(6, 4096)]).compute_duty;
        assert!(large > small, "{large} vs {small}");
    }

    #[test]
    fn shd_slower_than_phd() {
        let engine = SimEngine::new(HwParams::vck5000());
        let mut g = mm_group(6, 64);
        let phd = engine.run(&[g.clone()]).makespan_secs;
        g.du.ssc_send = SscMode::Shd;
        let shd = engine.run(&[g]).makespan_secs;
        assert!(shd > phd * 1.3, "shd {shd} phd {phd}");
    }

    #[test]
    fn trace_records_pipeline() {
        let engine = SimEngine::new(HwParams::vck5000()).with_trace(true);
        let r = engine.run(&[mm_group(2, 4)]);
        assert!(!r.trace.spans.is_empty());
        let render = r.trace.render(60, 0, r.trace.horizon_ps());
        assert!(render.contains("G0.DU"));
        assert!(render.contains("G0.PU0"));
    }

    #[test]
    fn serving_lane_is_valid_deterministic_and_monotone() {
        let pu = mm_group(1, 1).pu;
        let lane = GroupSpec::serving_lane("mm_pu128", pu.clone(), 4, 1);
        assert!(lane.validate().is_ok());
        assert_eq!(lane.cores(), 64);
        let engine = SimEngine::new(HwParams::vck5000());
        let a = engine.run(&[GroupSpec::serving_lane("mm_pu128", pu.clone(), 4, 1)]);
        let b = engine.run(&[GroupSpec::serving_lane("mm_pu128", pu.clone(), 4, 1)]);
        assert_eq!(a.makespan_secs.to_bits(), b.makespan_secs.to_bits());
        // more iterations in the dispatch -> longer lane makespan
        let k8 = engine.run(&[GroupSpec::serving_lane("mm_pu128", pu.clone(), 8, 1)]);
        assert!(k8.makespan_secs > a.makespan_secs);
        // a copies-wide deployment is a valid multi-PU group
        let wide = GroupSpec::serving_lane("mm_pu128", pu, 2, 6);
        assert!(wide.validate().is_ok());
        assert_eq!(wide.cores(), 6 * 64);
    }

    #[test]
    fn groups_contend_on_ddr() {
        // Two groups sharing DDR must be slower than one group alone
        // whenever fetches overlap; and queue time must be non-zero for
        // simultaneous starts.
        let engine = SimEngine::new(HwParams::vck5000());
        let solo = engine.run(&[mm_group(3, 256)]);
        let duo = engine.run(&[mm_group(3, 256), mm_group(3, 256)]);
        assert!(duo.makespan_secs >= solo.makespan_secs);
        assert!(duo.ddr_queue_secs > 0.0);
    }
}
