//! The controller: upper-level integration, task deployment, and flow
//! control (§3.1). It takes a deployed design (group specs + resource
//! usage), runs the scheduler, applies the power model, and produces the
//! [`RunReport`] rows the benches print.

use crate::coordinator::scheduler::{GroupSpec, SimEngine, SimReport};
use crate::sim::core::KernelClass;
use crate::sim::memory::ResourceUsage;
use crate::sim::params::HwParams;
use crate::sim::power::{estimate, PowerBreakdownInput};

/// Everything a Table 6/7/8/9-style row needs.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub label: String,
    /// Wall-clock for the whole workload (secs).
    pub time_secs: f64,
    /// User-level tasks completed (the app defines what a task is).
    pub tasks: f64,
    pub tasks_per_sec: f64,
    /// Total arithmetic ops across the workload.
    pub total_ops: f64,
    pub gops: f64,
    /// Active AIE cores in this configuration.
    pub active_aie: usize,
    pub gops_per_aie: f64,
    pub power_w: f64,
    pub gops_per_w: f64,
    pub tasks_per_sec_per_w: f64,
    /// Mean PU compute duty over the run (power-model input, reported
    /// for EXPERIMENTS.md).
    pub compute_duty: f64,
    pub ddr_gbps: f64,
    pub sim: SimReport,
}

/// The controller for one deployed accelerator configuration.
pub struct Controller {
    pub params: HwParams,
    pub usage: ResourceUsage,
    pub class: KernelClass,
    pub trace: bool,
}

impl Controller {
    pub fn new(params: HwParams, usage: ResourceUsage, class: KernelClass) -> Controller {
        Controller { params, usage, class, trace: false }
    }

    pub fn with_trace(mut self, on: bool) -> Controller {
        self.trace = on;
        self
    }

    /// Deploy + run: validates the groups against the card, simulates,
    /// and assembles the report. `tasks` and `total_ops` are workload
    /// facts the app supplies (what a "task" is differs per table).
    pub fn run(
        &self,
        label: &str,
        groups: &[GroupSpec],
        tasks: f64,
        total_ops: f64,
    ) -> anyhow::Result<RunReport> {
        for g in groups {
            g.validate().map_err(anyhow::Error::msg)?;
        }
        self.usage.check(&self.params)?;

        let engine = SimEngine::new(self.params.clone()).with_trace(self.trace);
        let sim = engine.run(groups);

        let active_aie: usize = groups.iter().map(|g| g.cores()).sum();
        let active_plio: usize = groups.iter().map(|g| g.du.pus * g.pu.total_plios()).sum();
        // The power model's duty input is *arithmetic utilisation* —
        // achieved ops/s per core over the datapath's peak — which is what
        // makes MM-T (util 0.73) draw far more than MM (util 0.42) on
        // similar core counts (DESIGN.md §6).
        let peak_core_gops =
            self.class.ops_per_cycle(&self.params) * self.params.aie_clock_hz / 1e9;
        let arith_util = (total_ops / sim.makespan_secs / 1e9
            / active_aie.max(1) as f64
            / peak_core_gops)
            .clamp(0.0, 1.0);
        let power = estimate(
            &self.params,
            &PowerBreakdownInput {
                usage: self.usage,
                active_aie,
                compute_duty: arith_util,
                class: self.class,
                ddr_gbps: sim.ddr_gbps,
                active_plio,
            },
        )
        .total();

        let time = sim.makespan_secs;
        let gops = total_ops / time / 1e9;
        let tps = tasks / time;
        Ok(RunReport {
            label: label.to_string(),
            time_secs: time,
            tasks,
            tasks_per_sec: tps,
            total_ops,
            gops,
            active_aie,
            gops_per_aie: gops / active_aie.max(1) as f64,
            power_w: power,
            gops_per_w: gops / power,
            tasks_per_sec_per_w: tps / power,
            compute_duty: sim.compute_duty,
            ddr_gbps: sim.ddr_gbps,
            sim,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::ExecMode;
    use crate::engine::compute::cc::CcMode;
    use crate::engine::compute::dac::{Dac, DacMode};
    use crate::engine::compute::dcc::{Dcc, DccMode};
    use crate::engine::compute::pu::{ProcessingStructure, ProcessingUnit};
    use crate::engine::data::du::DataUnit;
    use crate::engine::data::ssc::SscMode;
    use crate::engine::data::tpc::{TaskBlock, TpcMode};
    use crate::sim::ddr::AmcMode;

    fn tiny_group() -> GroupSpec {
        GroupSpec {
            name: "t".into(),
            du: DataUnit {
                name: "du".into(),
                amc_read: Some(AmcMode::Csb),
                amc_write: Some(AmcMode::Csb),
                tpc: TpcMode::Cup,
                ssc_send: SscMode::Phd,
                ssc_recv: SscMode::Phd,
                tb: TaskBlock::new(4096, 4, 1024),
                pus: 2,
            },
            pu: ProcessingUnit::simple(
                "p",
                vec![ProcessingStructure {
                    dacs: vec![Dac::new(vec![DacMode::Swh], 1, 8)],
                    cc: CcMode::Parallel(8, Box::new(CcMode::Single)),
                    dccs: vec![Dcc::new(DccMode::Swh, 1, 8)],
                }],
                KernelClass::F32Mac,
                1e6,
                4096,
                1024,
            ),
            engine_iters: 32,
mode: ExecMode::Regular,
        }
    }

    #[test]
    fn report_is_internally_consistent() {
        let c = Controller::new(
            HwParams::vck5000(),
            ResourceUsage { aie: 16, plio: 4, ..Default::default() },
            KernelClass::F32Mac,
        );
        let r = c.run("test", &[tiny_group()], 10.0, 32.0 * 2.0 * 1e6).unwrap();
        assert!(r.time_secs > 0.0);
        assert!((r.tasks_per_sec - 10.0 / r.time_secs).abs() < 1e-9);
        assert!((r.gops - r.total_ops / r.time_secs / 1e9).abs() < 1e-9);
        assert!((r.gops_per_w - r.gops / r.power_w).abs() < 1e-9);
        assert_eq!(r.active_aie, 16);
        assert!(r.power_w > 0.0);
        assert!(r.compute_duty > 0.0 && r.compute_duty <= 1.0);
    }

    #[test]
    fn invalid_group_rejected() {
        let c = Controller::new(
            HwParams::vck5000(),
            ResourceUsage::default(),
            KernelClass::F32Mac,
        );
        let mut g = tiny_group();
        g.du.pus = 0;
        assert!(c.run("bad", &[g], 1.0, 1.0).is_err());
    }

    #[test]
    fn overcommitted_design_rejected() {
        let c = Controller::new(
            HwParams::vck5000(),
            ResourceUsage { aie: 1000, ..Default::default() },
            KernelClass::F32Mac,
        );
        assert!(c.run("over", &[tiny_group()], 1.0, 1.0).is_err());
    }
}
