//! [`Router`] — the cluster tier over N [`Shard`]s: global, cost-model-
//! aware placement of traffic across array shards, per-shard artifact
//! deployment maps, graceful shard drain/join, and a cluster-wide
//! [`ServeReport`] that merges per-shard ledgers with conservation
//! preserved.
//!
//! One shard is one logical AIE array with its own worker pool,
//! prepared-artifact caches, and cost book. The router is the serving-
//! layer analogue of WideSA-style whole-fabric mapping: instead of one
//! hand-placed region (one monolithic `Server`), work is placed across
//! every shard the target artifact is deployed on, weighted by each
//! shard's *predicted* backlog — queued admission weights plus in-
//! flight dispatch weights, both in cost-book microseconds (the same
//! `Backend::predict`-fed book the shard dispatcher uses for worker
//! placement; the router reuses it one level up).
//!
//! ```text
//! clients ──submit(artifact, …)──► Router
//!     │  placement: eligible shards = deployment map [artifact]
//!     │  (or every live shard on an open cluster); pick the shard
//!     │  minimizing backlog_weight + cost_hint(artifact); on
//!     │  saturation, spill to the next-cheapest eligible shard
//!     ▼
//!   Shard 0        Shard 1        …        Shard N-1
//!  (queue +       (queue +                (queue +
//!   dispatcher +   dispatcher +            dispatcher +
//!   workers)       workers)                workers)
//!     │
//!     ▼  drain(i): stop admitting on shard i, flush its queue,
//!        join its workers, fold its ShardReport into the cluster
//!        ledger — already-admitted jobs keep their replies
//!     ▼
//!  shutdown() ──► ServeReport: per-shard reports merged in shard-id
//!                 order (deterministic), conservation preserved
//! ```

use std::collections::BTreeMap;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::runtime::{BackendKind, Tensor};

use super::shard::{
    ArtifactServeStats, JobResult, Pending, Shard, ShardConfig, ShardReport, SubmitError,
    WorkerStats, DEFAULT_SUBMIT_WAIT,
};

/// Cluster shape: how many array shards, and the per-shard serving
/// configuration (worker pool, batching, admission bound).
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Number of array shards (each its own worker pool + caches).
    pub shards: usize,
    /// Per-shard serving knobs.
    pub shard: ShardConfig,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig { shards: 1, shard: ShardConfig::default() }
    }
}

/// Why the router did not accept a submission.
#[derive(Debug)]
pub enum RouteError {
    /// The artifact is deployed on no shard — a placement-map miss, not
    /// a capacity problem. The message lists what *is* deployed so the
    /// rejection is actionable.
    Undeployed {
        artifact: String,
        /// Artifacts the cluster does carry (sorted, deduplicated).
        deployed: Vec<String>,
    },
    /// Every eligible shard refused admission (saturated or closed).
    Submit(SubmitError),
}

impl std::fmt::Display for RouteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RouteError::Undeployed { artifact, deployed } => write!(
                f,
                "artifact {artifact:?} is deployed on no shard (deployed: {})",
                if deployed.is_empty() { "none".to_string() } else { deployed.join(", ") }
            ),
            RouteError::Submit(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for RouteError {}

impl From<SubmitError> for RouteError {
    fn from(e: SubmitError) -> RouteError {
        RouteError::Submit(e)
    }
}

/// One shard's totals inside the cluster [`ServeReport`].
#[derive(Debug, Clone)]
pub struct ShardSummary {
    pub shard: usize,
    /// Submissions this shard accepted.
    pub jobs: u64,
    /// Jobs its workers completed (== `jobs` after a drain).
    pub completed: u64,
    /// Micro-batches its dispatcher formed.
    pub batches: u64,
    pub workers: usize,
}

/// Whole-cluster report produced by [`Router::shutdown`] (and, via the
/// one-shard facade, by `Server::shutdown`): the per-shard
/// [`ShardReport`]s merged in shard-id order, so the merge is
/// deterministic regardless of drain order. Counting fields are sums —
/// conservation (accepted == completed == per-worker sums == histogram
/// mass) survives the merge because nothing is re-derived.
#[derive(Debug)]
pub struct ServeReport {
    /// Every shard's workers, in (shard, worker) order, each stamped
    /// with its shard id.
    pub workers: Vec<WorkerStats>,
    /// Per-shard totals, in shard-id order.
    pub shards: Vec<ShardSummary>,
    /// Accepted submissions across the cluster (== jobs that received
    /// or will receive a reply; rejected submissions are not counted).
    pub total_jobs: u64,
    /// Micro-batches dispatched across the cluster.
    pub batches: u64,
    /// Per-artifact batch-size histogram, merged across shards:
    /// artifact -> (size -> count).
    pub batch_hist: BTreeMap<String, BTreeMap<usize, u64>>,
}

impl ServeReport {
    /// Merge per-shard reports into the cluster view. Input order does
    /// not matter: shards are sorted by id first, so the merged report
    /// (and its [`Display`](std::fmt::Display) rendering) is
    /// deterministic — the property the golden-report tests pin.
    pub fn from_shards(mut reports: Vec<ShardReport>) -> ServeReport {
        reports.sort_by_key(|r| r.shard);
        let mut workers = Vec::new();
        let mut shards = Vec::new();
        let mut total_jobs = 0u64;
        let mut batches = 0u64;
        let mut batch_hist: BTreeMap<String, BTreeMap<usize, u64>> = BTreeMap::new();
        for r in reports {
            shards.push(ShardSummary {
                shard: r.shard,
                jobs: r.total_jobs,
                completed: r.completed_jobs(),
                batches: r.batches,
                workers: r.workers.len(),
            });
            total_jobs += r.total_jobs;
            batches += r.batches;
            for (artifact, hist) in r.batch_hist {
                let merged = batch_hist.entry(artifact).or_default();
                for (size, count) in hist {
                    *merged.entry(size).or_insert(0) += count;
                }
            }
            workers.extend(r.workers);
        }
        ServeReport { workers, shards, total_jobs, batches, batch_hist }
    }

    /// Jobs that completed on workers, cluster-wide (== total_jobs
    /// after a full drain).
    pub fn completed_jobs(&self) -> u64 {
        self.workers.iter().map(|w| w.jobs).sum()
    }

    /// Mean micro-batch size for one artifact, if it was served.
    pub fn mean_batch_size(&self, artifact: &str) -> Option<f64> {
        let hist = self.batch_hist.get(artifact)?;
        let (mut jobs, mut batches) = (0u64, 0u64);
        for (&size, &count) in hist {
            jobs += size as u64 * count;
            batches += count;
        }
        (batches > 0).then(|| jobs as f64 / batches as f64)
    }

    /// Per-artifact predicted-vs-measured ledger, merged across every
    /// shard's workers (artifact-name order — BTreeMap).
    pub fn predicted_vs_measured(&self) -> BTreeMap<String, ArtifactServeStats> {
        let mut merged: BTreeMap<String, ArtifactServeStats> = BTreeMap::new();
        for w in &self.workers {
            for (artifact, lane) in &w.lanes {
                merged.entry(artifact.clone()).or_default().merge(lane);
            }
        }
        merged
    }

    /// Jobs completed per stream/tenant id, merged across the cluster
    /// (stream 0 collects untagged submissions). The multi-shard
    /// attribution that used to be positional.
    pub fn jobs_per_stream(&self) -> BTreeMap<u64, u64> {
        let mut merged: BTreeMap<u64, u64> = BTreeMap::new();
        for w in &self.workers {
            for (stream, jobs) in &w.streams {
                *merged.entry(*stream).or_insert(0) += jobs;
            }
        }
        merged
    }
}

/// Deterministic, counts-only rendering: artifacts in name order
/// (BTreeMap), shards in id order, workers in (shard, worker) order,
/// streams in id order. No wall-clock values, so a fully-drained
/// deterministic run renders byte-identically and can serve as a test
/// golden.
impl std::fmt::Display for ServeReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "cluster: {} jobs in {} micro-batches over {} shard(s)",
            self.total_jobs,
            self.batches,
            self.shards.len()
        )?;
        for (artifact, hist) in &self.batch_hist {
            let sizes: Vec<String> =
                hist.iter().map(|(size, count)| format!("{size}x{count}")).collect();
            let mean = self.mean_batch_size(artifact).unwrap_or(0.0);
            writeln!(f, "  {artifact:<16} mean batch {mean:.2} [{}]", sizes.join(" "))?;
        }
        for s in &self.shards {
            writeln!(
                f,
                "  shard {}: {} jobs accepted, {} completed, {} batches, {} workers",
                s.shard, s.jobs, s.completed, s.batches, s.workers
            )?;
        }
        for w in &self.workers {
            writeln!(
                f,
                "    shard {} worker {}: {} jobs in {} batches, {} errors",
                w.shard, w.worker, w.jobs, w.batches, w.errors
            )?;
        }
        let streams = self.jobs_per_stream();
        // an all-untagged run has nothing to attribute
        if streams.keys().any(|&s| s != 0) {
            for (stream, jobs) in &streams {
                writeln!(f, "  stream {stream}: {jobs} jobs")?;
            }
        }
        Ok(())
    }
}

struct ShardSlot {
    shard: Shard,
    /// Artifacts deployed on this shard (empty on an open cluster:
    /// any artifact may be routed here).
    deployed: Vec<String>,
}

/// The cluster router: owns N shards and places every submission.
pub struct Router {
    /// `None` marks a drained (retired) shard; indices are stable shard
    /// ids for the life of the cluster.
    slots: Vec<Option<ShardSlot>>,
    /// Whether placement is enforced (`start_with_placement`) or open
    /// (`start`: any artifact on any live shard).
    enforce_placement: bool,
    /// Reports of shards drained before shutdown, folded into the final
    /// cluster report.
    retired: Vec<ShardReport>,
}

impl Router {
    /// Start an *open* cluster: `cluster.shards` shards, each warming
    /// the same `warmup` list, any artifact routable to any shard. The
    /// one-shard `Server` facade is exactly `Router::start` with
    /// `shards: 1`.
    pub fn start(
        kind: BackendKind,
        cluster: ClusterConfig,
        artifact_dir: impl Into<std::path::PathBuf>,
        warmup: &[&str],
    ) -> Result<Router> {
        let dir: std::path::PathBuf = artifact_dir.into();
        let placement: Vec<Vec<String>> =
            vec![warmup.iter().map(|s| s.to_string()).collect(); cluster.shards];
        Router::start_inner(kind, cluster, dir, placement, true, false)
    }

    /// Start a cluster with explicit per-shard deployment maps: shard
    /// `i` warms and serves exactly `placement[i]`. A submission for an
    /// artifact on no shard's map is rejected with a readable
    /// [`RouteError::Undeployed`] instead of failing worker-side.
    /// `warm: false` keeps the maps but skips the cache warm-up (the
    /// `--no-warm` cold A/B).
    pub fn start_with_placement(
        kind: BackendKind,
        cluster: ClusterConfig,
        artifact_dir: impl Into<std::path::PathBuf>,
        placement: Vec<Vec<String>>,
        warm: bool,
    ) -> Result<Router> {
        let dir: std::path::PathBuf = artifact_dir.into();
        Router::start_inner(kind, cluster, dir, placement, warm, true)
    }

    fn start_inner(
        kind: BackendKind,
        cluster: ClusterConfig,
        dir: std::path::PathBuf,
        placement: Vec<Vec<String>>,
        warm: bool,
        enforce_placement: bool,
    ) -> Result<Router> {
        if cluster.shards == 0 {
            bail!("need at least one shard");
        }
        if placement.len() != cluster.shards {
            bail!(
                "placement maps {} shard(s) but the cluster has {}",
                placement.len(),
                cluster.shards
            );
        }
        let mut slots = Vec::with_capacity(cluster.shards);
        for (id, deployed) in placement.into_iter().enumerate() {
            let warmup: Vec<&str> =
                if warm { deployed.iter().map(String::as_str).collect() } else { Vec::new() };
            let shard = Shard::start(id, kind, cluster.shard.clone(), dir.clone(), &warmup)
                .with_context(|| format!("starting shard {id}"))?;
            slots.push(Some(ShardSlot { shard, deployed }));
        }
        Ok(Router { slots, enforce_placement, retired: Vec::new() })
    }

    /// Total shards ever started (drained ones included — ids are
    /// stable).
    pub fn shards(&self) -> usize {
        self.slots.len()
    }

    /// Shards still admitting work.
    pub fn live_shards(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Worker threads across live shards.
    pub fn workers(&self) -> usize {
        self.slots
            .iter()
            .flatten()
            .map(|s| s.shard.workers())
            .sum()
    }

    /// Every artifact deployed on at least one live shard (sorted,
    /// deduplicated). Empty on an open cluster with no warm lists.
    pub fn deployed_artifacts(&self) -> Vec<String> {
        let mut all: Vec<String> = self
            .slots
            .iter()
            .flatten()
            .flat_map(|s| s.deployed.iter().cloned())
            .collect();
        all.sort();
        all.dedup();
        all
    }

    /// Live shard ids eligible for `artifact`, cheapest placement
    /// first: predicted backlog (queued + in-flight cost-book weight)
    /// plus the shard's per-job cost hint for this artifact; ties break
    /// to the lowest shard id for determinism.
    fn placement_order(&self, artifact: &str) -> Result<Vec<usize>, RouteError> {
        let mut eligible: Vec<(u64, usize)> = self
            .slots
            .iter()
            .enumerate()
            .filter_map(|(id, slot)| slot.as_ref().map(|s| (id, s)))
            .filter(|(_, s)| {
                !self.enforce_placement || s.deployed.iter().any(|a| a == artifact)
            })
            .map(|(id, s)| (s.shard.backlog_weight() + s.shard.cost_hint(artifact), id))
            .collect();
        if eligible.is_empty() {
            if self.live_shards() == 0 {
                return Err(RouteError::Submit(SubmitError::Closed));
            }
            return Err(RouteError::Undeployed {
                artifact: artifact.to_string(),
                deployed: self.deployed_artifacts(),
            });
        }
        eligible.sort();
        Ok(eligible.into_iter().map(|(_, id)| id).collect())
    }

    fn slot(&self, id: usize) -> &ShardSlot {
        self.slots[id].as_ref().expect("placement_order only yields live shards")
    }

    /// Non-blocking submit with spillover: try every eligible shard in
    /// placement order; shed ([`SubmitError::Saturated`]) only when the
    /// whole eligible set is saturated.
    pub fn try_submit(
        &self,
        artifact: &str,
        inputs: Vec<Tensor>,
    ) -> Result<Pending, RouteError> {
        self.try_submit_stream(artifact, 0, inputs)
    }

    /// [`Router::try_submit`] with a stream/tenant tag.
    pub fn try_submit_stream(
        &self,
        artifact: &str,
        stream: u64,
        inputs: Vec<Tensor>,
    ) -> Result<Pending, RouteError> {
        let order = self.placement_order(artifact)?;
        let mut inputs = inputs;
        let mut last = SubmitError::Saturated;
        for id in order {
            // rejection hands the tensors back, so a saturated shard
            // costs nothing and the next-cheapest eligible shard gets
            // the same job (spillover before shedding)
            match self.slot(id).shard.submit_stream_reclaim(artifact, stream, inputs, None) {
                Ok(p) => return Ok(p),
                Err((e, reclaimed)) => {
                    last = e;
                    inputs = reclaimed;
                }
            }
        }
        Err(RouteError::Submit(last))
    }

    /// Blocking submit (bounded by [`DEFAULT_SUBMIT_WAIT`]).
    pub fn submit(&self, artifact: &str, inputs: Vec<Tensor>) -> Result<Pending, RouteError> {
        self.submit_timeout_stream(artifact, 0, inputs, DEFAULT_SUBMIT_WAIT)
    }

    /// Blocking submit with a stream/tenant tag.
    pub fn submit_stream(
        &self,
        artifact: &str,
        stream: u64,
        inputs: Vec<Tensor>,
    ) -> Result<Pending, RouteError> {
        self.submit_timeout_stream(artifact, stream, inputs, DEFAULT_SUBMIT_WAIT)
    }

    /// Submit, waiting at most `wait` for queue space on the chosen
    /// shard. Placement happens once, up front (waiting re-places
    /// nothing: the cheapest shard at decision time gets the job, the
    /// bounded wait is its admission backpressure).
    pub fn submit_timeout_stream(
        &self,
        artifact: &str,
        stream: u64,
        inputs: Vec<Tensor>,
        wait: Duration,
    ) -> Result<Pending, RouteError> {
        let order = self.placement_order(artifact)?;
        Ok(self
            .slot(order[0])
            .shard
            .submit_stream(artifact, stream, inputs, Some(wait))?)
    }

    /// Gracefully drain one shard: stop admitting on it, flush its
    /// queue through its workers (every already-admitted job keeps its
    /// reply), join its threads, and fold its [`ShardReport`] into the
    /// cluster ledger. The shard's id stays retired; remaining shards
    /// keep serving.
    pub fn drain(&mut self, shard: usize) -> Result<ShardReport> {
        let slot = self
            .slots
            .get_mut(shard)
            .and_then(Option::take)
            .ok_or_else(|| anyhow::anyhow!("shard {shard} is not live (already drained?)"))?;
        let report = slot.shard.drain().with_context(|| format!("draining shard {shard}"))?;
        self.retired.push(report.clone());
        Ok(report)
    }

    /// Drain every remaining shard (in id order) and merge all per-
    /// shard reports — retired and live — into the cluster-wide
    /// [`ServeReport`].
    pub fn shutdown(mut self) -> Result<ServeReport> {
        let live: Vec<usize> = self
            .slots
            .iter()
            .enumerate()
            .filter_map(|(id, s)| s.as_ref().map(|_| id))
            .collect();
        for id in live {
            self.drain(id)?;
        }
        Ok(ServeReport::from_shards(std::mem::take(&mut self.retired)))
    }
}

/// Drive an open-loop arrival stream against the cluster. Each arrival
/// is `(at_secs, artifact, stream, inputs)` with `at_secs` relative to
/// the first call; the driver sleeps until each arrival is due and
/// submits with [`Router::try_submit_stream`], so a saturated cluster
/// *sheds* the job (counted in the second return value) instead of
/// stalling the arrival clock — offered load stays honest under
/// overload. An undeployed artifact is an error up front, not a shed.
pub fn route_open_loop(
    router: &Router,
    arrivals: impl IntoIterator<Item = (f64, String, u64, Vec<Tensor>)>,
) -> Result<(Vec<JobResult>, u64)> {
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    let mut shed = 0u64;
    for (at_secs, artifact, stream, inputs) in arrivals {
        let due = t0 + Duration::from_secs_f64(at_secs);
        if let Some(wait) = due.checked_duration_since(std::time::Instant::now()) {
            std::thread::sleep(wait);
        }
        match router.try_submit_stream(&artifact, stream, inputs) {
            Ok(p) => pending.push(p),
            Err(RouteError::Submit(SubmitError::Saturated)) => shed += 1,
            Err(e) => bail!("open-loop submit failed: {e}"),
        }
    }
    let mut results = Vec::with_capacity(pending.len());
    for p in pending {
        results.push(p.wait()?);
    }
    Ok((results, shed))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shard_report(shard: usize, artifact: &str, jobs: u64) -> ShardReport {
        let mut batch_hist: BTreeMap<String, BTreeMap<usize, u64>> = BTreeMap::new();
        batch_hist.entry(artifact.to_string()).or_default().insert(2, jobs / 2);
        let mut streams = BTreeMap::new();
        streams.insert(shard as u64 + 1, jobs);
        ShardReport {
            shard,
            workers: vec![WorkerStats {
                shard,
                worker: 0,
                jobs,
                batches: jobs / 2,
                streams,
                ..Default::default()
            }],
            total_jobs: jobs,
            batches: jobs / 2,
            batch_hist,
        }
    }

    #[test]
    fn merge_is_deterministic_regardless_of_drain_order() {
        // the same per-shard reports, presented in two different drain
        // orders, must merge to byte-identical cluster reports — the
        // golden-report property
        let make = || {
            vec![
                shard_report(2, "mm_pu128", 8),
                shard_report(0, "fft1024", 4),
                shard_report(1, "mm_pu128", 6),
            ]
        };
        let mut scrambled = make();
        scrambled.rotate_left(2);
        let a = ServeReport::from_shards(make());
        let b = ServeReport::from_shards(scrambled);
        assert_eq!(a.to_string(), b.to_string());
        // shards sorted by id, workers stamped and ordered
        assert_eq!(a.shards.iter().map(|s| s.shard).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(a.workers.iter().map(|w| w.shard).collect::<Vec<_>>(), vec![0, 1, 2]);
        // conservation survives the merge: sums, never re-derived
        assert_eq!(a.total_jobs, 18);
        assert_eq!(a.completed_jobs(), 18);
        // the histogram merged across shards, keyed by artifact name
        assert_eq!(a.batch_hist["mm_pu128"][&2], 7);
        assert_eq!(a.batch_hist["fft1024"][&2], 2);
        // per-stream attribution merged across shards
        let streams = a.jobs_per_stream();
        assert_eq!(streams[&1], 4);
        assert_eq!(streams[&2], 6);
        assert_eq!(streams[&3], 8);
    }

    #[test]
    fn display_orders_artifacts_by_name() {
        let report = ServeReport::from_shards(vec![
            shard_report(0, "zz_last", 4),
            shard_report(1, "aa_first", 4),
        ]);
        let text = report.to_string();
        let aa = text.find("aa_first").expect("aa_first rendered");
        let zz = text.find("zz_last").expect("zz_last rendered");
        assert!(aa < zz, "artifact sections must sort by name:\n{text}");
        // counts-only: no wall-clock values to destabilize goldens
        assert!(!text.contains("ms"), "{text}");
    }

    #[test]
    fn undeployed_error_is_readable() {
        let e = RouteError::Undeployed {
            artifact: "fft1024".to_string(),
            deployed: vec!["mm_pu128".to_string(), "mmt_cascade8".to_string()],
        };
        let msg = e.to_string();
        assert!(msg.contains("fft1024"), "{msg}");
        assert!(msg.contains("no shard"), "{msg}");
        assert!(msg.contains("mm_pu128"), "{msg}");
    }
}
