//! [`Shard`] — the per-array serving unit: one bounded admission
//! queue, one micro-batching dispatcher, one worker pool over one
//! logical AIE array (per-worker runtimes with prepared-artifact
//! caches), and one [`CostBook`] of per-artifact execution costs.
//!
//! A shard is the paper's PS controller receiving tasks "from the upper
//! level" (§3.1) scoped to a single array. The cluster tier
//! ([`super::router::Router`]) owns N of these and places traffic
//! across them; the legacy [`super::server::Server`] is the N=1 case.
//!
//! ```text
//! clients --submit/try_submit--> admission queue (bounded; Saturated
//!             when full)              |
//!                                dispatcher thread: coalesce same-
//!                                artifact jobs into micro-batches
//!                                (max_batch / max_linger), place each
//!                                batch on the least-loaded worker by
//!                                *predicted execution cost* (queue
//!                                depth weighted by the cost book, not
//!                                raw job count)
//!                                     |
//!                        worker threads (own Runtime + backend each)
//!                        execute_batch --> per-job replies with a
//!                        queue-vs-exec latency split + the batch's
//!                        CostPrediction when the backend carries a
//!                        cost model (the sim backend)
//! ```
//!
//! Each worker thread owns its *own* backend instance (runtime +
//! prepared-artifact cache). Backends are not `Send` in general (the
//! real PJRT client is thread-bound), and per-worker instances also
//! mirror the DU-PU pair isolation — workers never share hot state.
//! Workers warm their cache at startup from the caller's warm-up list
//! (artifact-load time), so first-job latency is not a compile/plan
//! outlier, and reuse their batch scratch across dispatches.
//! Micro-batching mirrors the paper's PS controller organising data
//! movement around the compute substrate: compatible jobs reach a
//! worker as one dispatch, so the interpreter's stacked kernels (and a
//! real array's DMA bursts) amortize per-task overhead. Metrics are
//! aggregated shard-side into a [`ShardReport`] at drain.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

// Poison-recovering lock shared with the runtime backends (see
// `util::sync` for the recovery rationale). All lock sites in this
// module go through `lock_clean` or the matching
// `unwrap_or_else(PoisonError::into_inner)` on condvar waits.
use crate::util::sync::lock_clean;

use crate::runtime::{BackendKind, CostPrediction, Runtime, Tensor};

/// How long a blocking submit waits for queue space before giving up
/// with [`SubmitError::Saturated`] (blocking forever would hide
/// overload from the caller — the bug this layer is designed to avoid).
pub const DEFAULT_SUBMIT_WAIT: Duration = Duration::from_secs(30);

/// Per-shard serving-path tuning knobs (re-exported as `ServerConfig`
/// for the one-shard facade).
#[derive(Debug, Clone)]
pub struct ShardConfig {
    /// Worker thread count (each owns a backend instance).
    pub n_workers: usize,
    /// Most jobs coalesced into one dispatch. 1 disables batching.
    pub max_batch: usize,
    /// How long the dispatcher holds an under-full batch open waiting
    /// for more same-artifact arrivals. Zero dispatches immediately.
    pub max_linger: Duration,
    /// Admission-queue capacity; beyond it submissions saturate.
    pub queue_cap: usize,
}

impl Default for ShardConfig {
    fn default() -> Self {
        ShardConfig {
            n_workers: 4,
            max_batch: 8,
            max_linger: Duration::from_micros(200),
            queue_cap: 256,
        }
    }
}

/// Why a submission was not accepted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The bounded admission queue is full — shed load or retry later.
    Saturated,
    /// The shard (or the whole cluster) is shutting down.
    Closed,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Saturated => write!(f, "admission queue saturated"),
            SubmitError::Closed => write!(f, "server closed"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// One inference/compute request.
struct Job {
    artifact: String,
    /// Stable stream/tenant id the submitter tagged the job with
    /// (0 for untagged submissions) — carried through to [`JobResult`]
    /// and the per-stream attribution in the reports.
    stream: u64,
    inputs: Vec<Tensor>,
    reply: mpsc::Sender<JobResult>,
    submitted: Instant,
    /// Admission weight charged to the shard's backlog when this job
    /// was enqueued (cost-book microseconds); released when the
    /// dispatcher pulls the job into a batch.
    charged: u64,
}

/// The completed job, with the end-to-end latency split into its queue
/// and execution components.
#[derive(Debug)]
pub struct JobResult {
    pub outputs: Result<Vec<Tensor>>,
    /// Seconds from submit until the worker started executing the
    /// micro-batch this job rode in (admission + dispatch + linger).
    pub queue_secs: f64,
    /// Wall-clock seconds this job's micro-batch spent executing. The
    /// client waits for the whole batch, so this is the job's real
    /// execution wait; divide by `batch_size` for the amortized per-job
    /// compute share.
    pub exec_secs: f64,
    /// How many jobs shared the dispatch that produced this result.
    pub batch_size: usize,
    /// Index of the shard that served the job.
    pub shard: usize,
    /// Index of the worker (within its shard) that executed the job
    /// (`usize::MAX` for jobs that failed before reaching any worker).
    pub worker: usize,
    /// The stream/tenant id the job was submitted with (0 = untagged).
    pub stream: u64,
    /// Predicted AIE cost of the micro-batch this job rode in (latency,
    /// energy, phase breakdown), when the backend carries a cost model
    /// (the sim backend); `None` on measuring-only backends. The
    /// prediction covers the whole dispatch — use
    /// [`CostPrediction::per_job_secs`] for this job's amortized share.
    pub predicted: Option<CostPrediction>,
}

impl JobResult {
    /// End-to-end seconds from submit to completion (what the client
    /// actually waited: queue + full batch execution).
    pub fn latency_secs(&self) -> f64 {
        self.queue_secs + self.exec_secs
    }
}

/// A pending reply handle.
pub struct Pending {
    rx: mpsc::Receiver<JobResult>,
}

impl Pending {
    /// Block until the job completes.
    pub fn wait(self) -> Result<JobResult> {
        self.rx.recv().context("worker dropped the job")
    }
}

/// Admission queue shared between clients and the dispatcher.
struct AdmissionState {
    queue: VecDeque<Job>,
    closed: bool,
    /// Successful submissions only — a rejected or failed enqueue must
    /// never inflate [`ShardReport::total_jobs`].
    accepted: u64,
    /// Sum of the queued jobs' charged admission weights — the
    /// not-yet-dispatched half of [`Shard::backlog_weight`].
    queued_weight: u64,
}

struct Shared {
    state: Mutex<AdmissionState>,
    /// Signalled on enqueue (wakes the dispatcher).
    not_empty: Condvar,
    /// Signalled when the dispatcher frees queue space (wakes blocked
    /// submitters).
    not_full: Condvar,
    cap: usize,
}

/// A coalesced same-artifact dispatch, carrying the placement weight
/// the dispatcher charged so the worker can release exactly that much.
struct Batch {
    jobs: Vec<Job>,
    weight: u64,
}

/// Per-artifact per-job execution-cost estimates (microseconds), shared
/// between the dispatcher (which weights queue depth by predicted cost
/// instead of raw job count), the workers (which publish cost-model
/// predictions, or measured costs on backends without a model), and the
/// cluster router (which weights *shard* placement by the same book).
pub(crate) struct CostBook {
    per_job_us: Mutex<HashMap<String, f64>>,
}

impl CostBook {
    fn new() -> CostBook {
        CostBook { per_job_us: Mutex::new(HashMap::new()) }
    }

    /// Placement weight of a `k`-job batch: per-job cost in whole
    /// microseconds. An artifact the book has not seen borrows the
    /// book's median per-job cost so its weight is commensurate with
    /// the known entries; with an empty book everything weighs 1 per
    /// job, which is the old job-count balancing.
    pub(crate) fn batch_weight(&self, artifact: &str, k: usize) -> u64 {
        let book = lock_clean(&self.per_job_us);
        let per_job = book.get(artifact).copied().or_else(|| {
            let mut costs: Vec<f64> = book.values().copied().collect();
            if costs.is_empty() {
                return None;
            }
            costs.sort_by(f64::total_cmp);
            Some(costs[costs.len() / 2])
        });
        match per_job {
            Some(us) => ((us * k as f64).round() as u64).max(1),
            None => k.max(1) as u64,
        }
    }

    /// Publish a cost-model prediction (authoritative: overwrites).
    fn record_predicted(&self, artifact: &str, per_job_secs: f64) {
        lock_clean(&self.per_job_us).insert(artifact.to_string(), per_job_secs * 1e6);
    }

    /// Publish a measurement. Smoothed (EWMA, alpha 0.3) so one noisy
    /// batch does not whipsaw placement.
    fn record_measured(&self, artifact: &str, per_job_secs: f64) {
        let mut book = lock_clean(&self.per_job_us);
        let us = per_job_secs * 1e6;
        book.entry(artifact.to_string())
            .and_modify(|old| *old += 0.3 * (us - *old))
            .or_insert(us);
    }
}

/// One artifact's predicted-vs-measured ledger (a worker's view; the
/// reports merge them leader-side).
#[derive(Debug, Default, Clone)]
pub struct ArtifactServeStats {
    pub jobs: u64,
    pub batches: u64,
    /// Sum of measured batch execution walls (secs).
    pub measured_exec_secs: f64,
    /// Sum of predicted batch latencies (secs) over predicted batches.
    pub predicted_exec_secs: f64,
    /// Sum of predicted batch energies (J) over predicted batches.
    pub predicted_energy_j: f64,
    /// Batches that carried a cost-model prediction.
    pub predicted_batches: u64,
    /// The kernel tier that served this lane (from the worker runtime's
    /// prepared-artifact cache; `None` on tier-less substrates). Makes
    /// a debug-mode or non-AVX2 serving run self-describing.
    pub tier: Option<crate::runtime::tier::KernelTier>,
}

impl ArtifactServeStats {
    pub(crate) fn merge(&mut self, other: &ArtifactServeStats) {
        self.jobs += other.jobs;
        self.batches += other.batches;
        self.measured_exec_secs += other.measured_exec_secs;
        self.predicted_exec_secs += other.predicted_exec_secs;
        self.predicted_energy_j += other.predicted_energy_j;
        self.predicted_batches += other.predicted_batches;
        // workers of one deployment resolve the same tier; keep the
        // first seen
        self.tier = self.tier.or(other.tier);
    }

    /// Predicted/measured mean-batch-latency ratio, when both exist.
    pub fn ratio(&self) -> Option<f64> {
        if self.predicted_batches == 0 || self.measured_exec_secs <= 0.0 {
            return None;
        }
        let meas = self.measured_exec_secs / self.batches.max(1) as f64;
        let pred = self.predicted_exec_secs / self.predicted_batches as f64;
        Some(pred / meas)
    }
}

/// Per-worker accounting returned at drain.
#[derive(Debug, Default, Clone)]
pub struct WorkerStats {
    /// The shard this worker belonged to (stamped by the report merge).
    pub shard: usize,
    pub worker: usize,
    pub jobs: u64,
    pub batches: u64,
    pub exec_secs: f64,
    pub errors: u64,
    /// Per-artifact predicted-vs-measured ledger.
    pub lanes: BTreeMap<String, ArtifactServeStats>,
    /// Jobs completed per stream/tenant id (0 = untagged submissions).
    pub streams: BTreeMap<u64, u64>,
}

/// Dispatcher-side accounting (batch shapes).
#[derive(Default)]
struct DispatchStats {
    batches: u64,
    /// artifact -> (batch size -> how many batches of that size)
    batch_hist: BTreeMap<String, BTreeMap<usize, u64>>,
}

/// One shard's whole-run accounting, produced by [`Shard::drain`]. The
/// cluster-wide [`super::router::ServeReport`] merges these with
/// conservation preserved (jobs are summed, never re-derived).
#[derive(Debug, Clone)]
pub struct ShardReport {
    /// The shard's id within its cluster (0 for the one-shard facade).
    pub shard: usize,
    pub workers: Vec<WorkerStats>,
    /// Accepted submissions (== jobs that received or will receive a
    /// reply; rejected submissions are not counted).
    pub total_jobs: u64,
    /// Micro-batches dispatched.
    pub batches: u64,
    /// Per-artifact batch-size histogram: artifact -> (size -> count).
    pub batch_hist: BTreeMap<String, BTreeMap<usize, u64>>,
}

impl ShardReport {
    /// Jobs that completed on this shard's workers (== total_jobs after
    /// a drain).
    pub fn completed_jobs(&self) -> u64 {
        self.workers.iter().map(|w| w.jobs).sum()
    }
}

/// The running per-array serving unit.
pub struct Shard {
    id: usize,
    shared: Arc<Shared>,
    costs: Arc<CostBook>,
    /// Per-worker in-flight dispatch weights (cost-book microseconds);
    /// their sum plus the queued weight is the shard's backlog.
    loads: Vec<Arc<AtomicU64>>,
    dispatcher: Option<JoinHandle<DispatchStats>>,
    handles: Vec<JoinHandle<WorkerStats>>,
}

impl Shard {
    /// Spawn the shard's workers over the artifact directory, warming
    /// up the given artifacts in every worker. Every worker thread
    /// instantiates its own backend (no shared substrate state); a
    /// dispatcher thread owns micro-batch formation and least-loaded
    /// placement.
    pub fn start(
        id: usize,
        kind: BackendKind,
        config: ShardConfig,
        artifact_dir: impl Into<std::path::PathBuf>,
        warmup: &[&str],
    ) -> Result<Shard> {
        if config.n_workers == 0 {
            bail!("need at least one worker");
        }
        if config.max_batch == 0 {
            bail!("max_batch must be at least 1");
        }
        if config.queue_cap == 0 {
            bail!("queue_cap must be at least 1");
        }
        let dir: std::path::PathBuf = artifact_dir.into();
        let warm: Vec<String> = warmup.iter().map(|s| s.to_string()).collect();
        let mut senders = Vec::new();
        let mut handles = Vec::new();
        let mut loads = Vec::new();
        // the shared cost book: workers publish predicted (or measured)
        // per-job costs, the dispatcher weights placement with them
        let costs = Arc::new(CostBook::new());
        // readiness barrier: workers report once their runtime is up
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        for w in 0..config.n_workers {
            // a couple of batches of runway per worker keeps the
            // dispatcher ahead without hiding queueing from the metric
            let (tx, rx) = mpsc::sync_channel::<Batch>(2);
            let load = Arc::new(AtomicU64::new(0));
            let dir = dir.clone();
            let warm = warm.clone();
            let ready = ready_tx.clone();
            let wload = Arc::clone(&load);
            let wcosts = Arc::clone(&costs);
            let handle = std::thread::Builder::new()
                .name(format!("ea4rca-s{id}-worker-{w}"))
                .spawn(move || worker_main(id, w, kind, dir, warm, rx, ready, wload, wcosts))
                .context("spawning worker")?;
            senders.push(tx);
            handles.push(handle);
            loads.push(load);
        }
        drop(ready_tx);
        for _ in 0..config.n_workers {
            ready_rx.recv().context("worker died during startup")??;
        }
        let shared = Arc::new(Shared {
            state: Mutex::new(AdmissionState {
                queue: VecDeque::with_capacity(config.queue_cap),
                closed: false,
                accepted: 0,
                queued_weight: 0,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cap: config.queue_cap,
        });
        let dshared = Arc::clone(&shared);
        let dcosts = Arc::clone(&costs);
        let dloads: Vec<Arc<AtomicU64>> = loads.iter().map(Arc::clone).collect();
        let (max_batch, max_linger) = (config.max_batch, config.max_linger);
        let dispatcher = std::thread::Builder::new()
            .name(format!("ea4rca-s{id}-dispatch"))
            .spawn(move || {
                dispatcher_main(id, dshared, senders, dloads, dcosts, max_batch, max_linger)
            })
            .context("spawning dispatcher")?;
        Ok(Shard { id, shared, costs, loads, dispatcher: Some(dispatcher), handles })
    }

    pub fn id(&self) -> usize {
        self.id
    }

    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Submit a job, waiting up to [`DEFAULT_SUBMIT_WAIT`] for queue
    /// space; returns a reply handle, or [`SubmitError::Saturated`]
    /// when the shard stays overloaded for that long.
    pub fn submit(&self, artifact: &str, inputs: Vec<Tensor>) -> Result<Pending, SubmitError> {
        self.enqueue(artifact, 0, inputs, Some(DEFAULT_SUBMIT_WAIT)).map_err(|(e, _)| e)
    }

    /// Non-blocking submit: [`SubmitError::Saturated`] immediately when
    /// the admission queue is full (open-loop clients shed load here).
    pub fn try_submit(
        &self,
        artifact: &str,
        inputs: Vec<Tensor>,
    ) -> Result<Pending, SubmitError> {
        self.enqueue(artifact, 0, inputs, None).map_err(|(e, _)| e)
    }

    /// Submit, waiting at most `wait` for queue space.
    pub fn submit_timeout(
        &self,
        artifact: &str,
        inputs: Vec<Tensor>,
        wait: Duration,
    ) -> Result<Pending, SubmitError> {
        self.enqueue(artifact, 0, inputs, Some(wait)).map_err(|(e, _)| e)
    }

    /// Stream-tagged submit: like the untagged variants, but the job
    /// carries a stable stream/tenant id into its [`JobResult`] and the
    /// per-stream report attribution. `wait: None` is non-blocking.
    pub fn submit_stream(
        &self,
        artifact: &str,
        stream: u64,
        inputs: Vec<Tensor>,
        wait: Option<Duration>,
    ) -> Result<Pending, SubmitError> {
        self.enqueue(artifact, stream, inputs, wait).map_err(|(e, _)| e)
    }

    /// [`Shard::submit_stream`] that hands the input tensors back on
    /// rejection (admission never consumes them before accepting), so
    /// the router can spill the same job to another shard without
    /// cloning tensors up front.
    pub(crate) fn submit_stream_reclaim(
        &self,
        artifact: &str,
        stream: u64,
        inputs: Vec<Tensor>,
        wait: Option<Duration>,
    ) -> Result<Pending, (SubmitError, Vec<Tensor>)> {
        self.enqueue(artifact, stream, inputs, wait)
    }

    /// The shard's current backlog in cost-book microseconds: queued
    /// jobs' admission weights plus every worker's in-flight dispatch
    /// weight. The router's placement metric.
    pub fn backlog_weight(&self) -> u64 {
        let queued = lock_clean(&self.shared.state).queued_weight;
        queued + self.loads.iter().map(|l| l.load(Ordering::SeqCst)).sum::<u64>()
    }

    /// This shard's estimated per-job cost (microseconds) for one job
    /// of `artifact`, from its cost book (median for unseen artifacts,
    /// 1 on an empty book).
    pub fn cost_hint(&self, artifact: &str) -> u64 {
        self.costs.batch_weight(artifact, 1)
    }

    fn enqueue(
        &self,
        artifact: &str,
        stream: u64,
        inputs: Vec<Tensor>,
        wait: Option<Duration>,
    ) -> Result<Pending, (SubmitError, Vec<Tensor>)> {
        let mut st = lock_clean(&self.shared.state);
        if st.closed {
            return Err((SubmitError::Closed, inputs));
        }
        if st.queue.len() >= self.shared.cap {
            let Some(wait) = wait else {
                return Err((SubmitError::Saturated, inputs));
            };
            let deadline = Instant::now() + wait;
            while st.queue.len() >= self.shared.cap {
                if st.closed {
                    return Err((SubmitError::Closed, inputs));
                }
                let now = Instant::now();
                if now >= deadline {
                    return Err((SubmitError::Saturated, inputs));
                }
                let (guard, _) = self
                    .shared
                    .not_full
                    .wait_timeout(st, deadline - now)
                    .unwrap_or_else(PoisonError::into_inner);
                st = guard;
            }
            if st.closed {
                return Err((SubmitError::Closed, inputs));
            }
        }
        let (reply, rx) = mpsc::channel();
        // the job's admission weight: charged to the shard backlog now,
        // released when the dispatcher pulls it into a batch
        let charged = self.costs.batch_weight(artifact, 1);
        st.queue.push_back(Job {
            artifact: artifact.to_string(),
            stream,
            inputs,
            reply,
            submitted: Instant::now(),
            charged,
        });
        st.queued_weight += charged;
        st.accepted += 1;
        drop(st);
        self.shared.not_empty.notify_one();
        Ok(Pending { rx })
    }

    /// Close admission, drain the queue through the workers, and join
    /// everything. Every accepted job gets its reply before the report
    /// is produced. (The router calls this for graceful shard drain;
    /// the one-shard facade's `shutdown` is the same operation.)
    pub fn drain(mut self) -> Result<ShardReport> {
        {
            let mut st = lock_clean(&self.shared.state);
            st.closed = true;
        }
        self.shared.not_empty.notify_all();
        self.shared.not_full.notify_all();
        let dstats = self
            .dispatcher
            .take()
            .expect("dispatcher joined once")
            .join()
            .map_err(|_| anyhow::anyhow!("dispatcher panicked"))?;
        // dispatcher return drops the worker senders -> workers drain.
        // A panicked worker must not cost the caller the whole report:
        // its stats are lost (a default row marks the gap) but every
        // other worker's accounting — and the run's reply guarantees,
        // upheld by the dispatcher's dead-worker rerouting — survive.
        let mut workers = Vec::new();
        for (i, h) in std::mem::take(&mut self.handles).into_iter().enumerate() {
            workers.push(h.join().unwrap_or_else(|_| WorkerStats {
                shard: self.id,
                worker: i,
                ..Default::default()
            }));
        }
        let total_jobs = lock_clean(&self.shared.state).accepted;
        Ok(ShardReport {
            shard: self.id,
            workers,
            total_jobs,
            batches: dstats.batches,
            batch_hist: dstats.batch_hist,
        })
    }

    #[cfg(test)]
    fn shared_for_tests(&self) -> Arc<Shared> {
        Arc::clone(&self.shared)
    }
}

/// Pull up to `want` jobs for `artifact` out of the queue (front to
/// back, preserving both per-artifact FIFO order and the relative order
/// of everything left behind), releasing their admission weights.
fn take_same_artifact(
    st: &mut AdmissionState,
    artifact: &str,
    want: usize,
    batch: &mut Vec<Job>,
) {
    if want == 0 {
        return;
    }
    let mut taken = 0;
    let mut i = 0;
    while i < st.queue.len() && taken < want {
        if st.queue[i].artifact == artifact {
            // remove(i) preserves the order of the remaining jobs
            let job = st.queue.remove(i).expect("index in bounds");
            st.queued_weight = st.queued_weight.saturating_sub(job.charged);
            batch.push(job);
            taken += 1;
        } else {
            i += 1;
        }
    }
}

fn dispatcher_main(
    shard_id: usize,
    shared: Arc<Shared>,
    senders: Vec<mpsc::SyncSender<Batch>>,
    loads: Vec<Arc<AtomicU64>>,
    costs: Arc<CostBook>,
    max_batch: usize,
    max_linger: Duration,
) -> DispatchStats {
    let mut stats = DispatchStats::default();
    // a worker whose channel closed is dead: never route to it again
    let mut alive = vec![true; senders.len()];
    loop {
        let mut st = lock_clean(&shared.state);
        loop {
            if !st.queue.is_empty() {
                break;
            }
            if st.closed {
                return stats;
            }
            st = shared
                .not_empty
                .wait(st)
                .unwrap_or_else(PoisonError::into_inner);
        }
        let first = st.queue.pop_front().expect("queue non-empty");
        st.queued_weight = st.queued_weight.saturating_sub(first.charged);
        let artifact = first.artifact.clone();
        let mut jobs = vec![first];
        take_same_artifact(&mut st, &artifact, max_batch - jobs.len(), &mut jobs);
        // linger: hold an under-full batch open briefly for more
        // same-artifact arrivals (skipped during drain)
        if jobs.len() < max_batch && !st.closed && !max_linger.is_zero() {
            let deadline = Instant::now() + max_linger;
            while jobs.len() < max_batch && !st.closed {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, _) = shared
                    .not_empty
                    .wait_timeout(st, deadline - now)
                    .unwrap_or_else(PoisonError::into_inner);
                st = guard;
                take_same_artifact(&mut st, &artifact, max_batch - jobs.len(), &mut jobs);
            }
        }
        drop(st);
        shared.not_full.notify_all();

        stats.batches += 1;
        // cost-model-aware placement weight: the batch's predicted
        // execution cost (per-job cost book x batch size), falling back
        // to raw job count for artifacts the book has not seen
        let weight = costs.batch_weight(&artifact, jobs.len());
        *stats
            .batch_hist
            .entry(artifact)
            .or_default()
            .entry(jobs.len())
            .or_insert(0) += 1;
        // least-loaded placement by in-flight predicted cost (ties ->
        // lowest id); a dead worker is marked and the batch
        // re-dispatched to a survivor, so one crash costs capacity, not
        // correctness
        let mut batch = Batch { jobs, weight };
        loop {
            let Some(w) = (0..senders.len())
                .filter(|&i| alive[i])
                .min_by_key(|&i| loads[i].load(Ordering::SeqCst))
            else {
                // every worker is gone: fail the batch so clients
                // unblock with an error instead of hanging
                let k = batch.jobs.len();
                for job in batch.jobs {
                    let _ = job.reply.send(JobResult {
                        outputs: Err(anyhow::anyhow!("all workers gone")),
                        queue_secs: job.submitted.elapsed().as_secs_f64(),
                        exec_secs: 0.0,
                        batch_size: k,
                        shard: shard_id,
                        worker: usize::MAX,
                        stream: job.stream,
                        predicted: None,
                    });
                }
                break;
            };
            loads[w].fetch_add(batch.weight, Ordering::SeqCst);
            match senders[w].send(batch) {
                Ok(()) => break,
                Err(send_err) => {
                    batch = send_err.0;
                    loads[w].fetch_sub(batch.weight, Ordering::SeqCst);
                    alive[w] = false;
                }
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_main(
    shard_id: usize,
    id: usize,
    kind: BackendKind,
    dir: std::path::PathBuf,
    warmup: Vec<String>,
    rx: mpsc::Receiver<Batch>,
    ready: mpsc::Sender<Result<()>>,
    load: Arc<AtomicU64>,
    costs: Arc<CostBook>,
) -> WorkerStats {
    let mut stats = WorkerStats { shard: shard_id, worker: id, ..Default::default() };
    let rt = match Runtime::with_backend(kind, dir).and_then(|rt| {
        let names: Vec<&str> = warmup.iter().map(String::as_str).collect();
        rt.warmup(&names)?;
        Ok(rt)
    }) {
        Ok(rt) => {
            let _ = ready.send(Ok(()));
            rt
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return stats;
        }
    };
    // seed the cost book from the cost model at artifact-load time, so
    // the dispatcher places cost-aware from the very first batch
    for name in &warmup {
        if let Some(p) = rt.predict(name, 1) {
            costs.record_predicted(name, p.per_job_secs());
        }
    }
    // input-list scratch reused across batch executions: the per-batch
    // cost is moving Tensors, never reallocating the outer Vec
    let mut inputs: Vec<Vec<Tensor>> = Vec::new();
    while let Ok(batch) = rx.recv() {
        let Batch { mut jobs, weight } = batch;
        let k = jobs.len();
        let artifact = std::mem::take(&mut jobs[0].artifact);
        inputs.clear();
        inputs.extend(jobs.iter_mut().map(|j| std::mem::take(&mut j.inputs)));
        let t0 = Instant::now();
        let results = rt.execute_batch(&artifact, &inputs);
        let exec = t0.elapsed().as_secs_f64();
        load.fetch_sub(weight, Ordering::SeqCst);
        stats.jobs += k as u64;
        stats.batches += 1;
        stats.exec_secs += exec;
        for job in &jobs {
            *stats.streams.entry(job.stream).or_insert(0) += 1;
        }
        // attach the cost model's view of this dispatch (memoized per
        // batch size, so the steady state is a table lookup) and keep
        // the shared cost book current for the dispatcher. Only batches
        // that actually executed feed the book and the ledger — an
        // artifact-level failure completes in microseconds and would
        // otherwise poison placement weights and the predicted-vs-
        // measured report with near-zero "costs".
        let predicted = rt.predict(&artifact, k);
        if results.is_ok() {
            match &predicted {
                Some(p) => costs.record_predicted(&artifact, p.per_job_secs()),
                None => costs.record_measured(&artifact, exec / k.max(1) as f64),
            }
            let lane = stats.lanes.entry(artifact.clone()).or_default();
            lane.jobs += k as u64;
            lane.batches += 1;
            lane.measured_exec_secs += exec;
            if lane.tier.is_none() {
                lane.tier = rt.kernel_tier(&artifact);
            }
            if let Some(p) = &predicted {
                lane.predicted_exec_secs += p.latency_secs;
                lane.predicted_energy_j += p.energy_j;
                lane.predicted_batches += 1;
            }
        }
        let reply_one = |job: Job, outputs: Result<Vec<Tensor>>, errors: &mut u64| {
            if outputs.is_err() {
                *errors += 1;
            }
            let queue_secs = t0.saturating_duration_since(job.submitted).as_secs_f64();
            let _ = job.reply.send(JobResult {
                outputs,
                queue_secs,
                // the whole batch's wall time: what this client waited
                exec_secs: exec,
                batch_size: k,
                shard: shard_id,
                worker: id,
                stream: job.stream,
                predicted,
            }); // client may have gone away
        };
        match results {
            Ok(per_job) => {
                for (job, outputs) in jobs.into_iter().zip(per_job) {
                    reply_one(job, outputs, &mut stats.errors);
                }
            }
            Err(e) => {
                // artifact-level failure: every job in the batch gets
                // the same story
                let msg = format!("{e:#}");
                for job in jobs {
                    reply_one(job, Err(anyhow::anyhow!("{msg}")), &mut stats.errors);
                }
            }
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_book_weights_batches() {
        let book = CostBook::new();
        // empty book: weight degrades to the job count
        assert_eq!(book.batch_weight("mm", 4), 4);
        assert_eq!(book.batch_weight("mm", 0), 1);
        // a prediction takes over: 250 us/job -> a 4-job batch is 1000
        book.record_predicted("mm", 250e-6);
        assert_eq!(book.batch_weight("mm", 4), 1000);
        // predictions are authoritative (overwrite, no smoothing)
        book.record_predicted("mm", 100e-6);
        assert_eq!(book.batch_weight("mm", 1), 100);
        // sub-microsecond jobs still cost at least 1
        book.record_predicted("tiny", 1e-9);
        assert_eq!(book.batch_weight("tiny", 2), 1);
        // unseen artifacts borrow the book median (sorted [~0, 100],
        // upper middle 100 us/job) so their weights stay commensurate
        assert_eq!(book.batch_weight("unseen", 2), 200);
    }

    #[test]
    fn cost_book_smooths_measurements() {
        let book = CostBook::new();
        book.record_measured("fft", 100e-6);
        assert_eq!(book.batch_weight("fft", 1), 100);
        // EWMA alpha 0.3: 100 + 0.3*(200-100) = 130
        book.record_measured("fft", 200e-6);
        assert_eq!(book.batch_weight("fft", 1), 130);
    }

    #[test]
    fn cost_book_recovers_from_a_poisoning_panic() {
        // a worker that dies while holding the book must not take the
        // dispatcher (batch_weight) or surviving workers (record_*)
        // down with it
        let book = Arc::new(CostBook::new());
        let poisoner = Arc::clone(&book);
        let _ = std::thread::spawn(move || {
            let _guard = poisoner.per_job_us.lock().unwrap();
            panic!("injected: worker died holding the cost book");
        })
        .join();
        assert!(book.per_job_us.is_poisoned());
        book.record_predicted("mm", 250e-6);
        assert_eq!(book.batch_weight("mm", 4), 1000);
        book.record_measured("fft", 100e-6);
        assert_eq!(book.batch_weight("fft", 1), 100);
    }

    #[test]
    fn panicked_thread_holding_the_admission_lock_still_lets_drain_report() {
        // the regression: a panic while a shared lock is held used to
        // cascade — submit panicked, then the dispatcher, then the
        // drain joins. With poison recovery the shard keeps serving and
        // drain still produces the report.
        let shard = Shard::start(
            0,
            BackendKind::Interp,
            ShardConfig { n_workers: 1, ..ShardConfig::default() },
            "artifacts",
            &[],
        )
        .unwrap();
        let shared = shard.shared_for_tests();
        let _ = std::thread::spawn(move || {
            let _guard = shared.state.lock().unwrap();
            panic!("injected: worker died holding the admission lock");
        })
        .join();
        assert!(shard.shared.state.is_poisoned());

        let inputs = vec![
            Tensor::f32(&[32, 32], vec![0.5; 32 * 32]),
            Tensor::f32(&[32, 32], vec![0.25; 32 * 32]),
        ];
        let result = shard.submit("mm32", inputs).unwrap().wait().unwrap();
        assert!(result.outputs.is_ok(), "{:?}", result.outputs);

        let report = shard.drain().unwrap();
        assert_eq!(report.total_jobs, 1);
        assert_eq!(report.completed_jobs(), 1);
    }

    #[test]
    fn backlog_weight_charges_and_releases() {
        // an idle shard has no backlog; queued-then-drained jobs charge
        // and fully release their admission weights
        let shard = Shard::start(
            7,
            BackendKind::Interp,
            ShardConfig { n_workers: 1, ..ShardConfig::default() },
            "artifacts",
            &["mm32"],
        )
        .unwrap();
        assert_eq!(shard.id(), 7);
        let mut pending = Vec::new();
        for _ in 0..4 {
            let inputs = vec![
                Tensor::f32(&[32, 32], vec![0.5; 32 * 32]),
                Tensor::f32(&[32, 32], vec![0.25; 32 * 32]),
            ];
            pending.push(shard.submit("mm32", inputs).unwrap());
        }
        for p in pending {
            let r = p.wait().unwrap();
            assert!(r.outputs.is_ok());
            assert_eq!(r.shard, 7);
            assert_eq!(r.stream, 0, "untagged submissions carry stream 0");
        }
        // every reply received -> nothing queued, nothing in flight
        assert_eq!(shard.backlog_weight(), 0);
        // the cost hint is the book's view (mm32 was warmed + served)
        assert!(shard.cost_hint("mm32") >= 1);
        let report = shard.drain().unwrap();
        assert_eq!(report.shard, 7);
        assert_eq!(report.completed_jobs(), 4);
    }

    #[test]
    fn stream_ids_ride_through_to_results_and_stats() {
        let shard = Shard::start(
            0,
            BackendKind::Interp,
            ShardConfig { n_workers: 1, ..ShardConfig::default() },
            "artifacts",
            &["mm32"],
        )
        .unwrap();
        let mut pending = Vec::new();
        for stream in [11u64, 22, 11] {
            let inputs = vec![
                Tensor::f32(&[32, 32], vec![1.0; 32 * 32]),
                Tensor::f32(&[32, 32], vec![2.0; 32 * 32]),
            ];
            pending.push((stream, shard.submit_stream("mm32", stream, inputs, None).unwrap()));
        }
        for (stream, p) in pending {
            let r = p.wait().unwrap();
            assert!(r.outputs.is_ok());
            assert_eq!(r.stream, stream);
        }
        let report = shard.drain().unwrap();
        let mut streams: BTreeMap<u64, u64> = BTreeMap::new();
        for w in &report.workers {
            for (s, n) in &w.streams {
                *streams.entry(*s).or_insert(0) += n;
            }
        }
        assert_eq!(streams.get(&11), Some(&2));
        assert_eq!(streams.get(&22), Some(&1));
    }

    #[test]
    fn lane_ledger_merges_and_ratios() {
        let mut a = ArtifactServeStats {
            jobs: 4,
            batches: 2,
            measured_exec_secs: 2.0,
            predicted_exec_secs: 1.0,
            predicted_energy_j: 0.5,
            predicted_batches: 2,
            tier: None,
        };
        let b = ArtifactServeStats {
            jobs: 2,
            batches: 2,
            measured_exec_secs: 2.0,
            predicted_exec_secs: 3.0,
            predicted_energy_j: 0.5,
            predicted_batches: 2,
            tier: Some(crate::runtime::tier::KernelTier::Scalar),
        };
        a.merge(&b);
        assert_eq!(a.jobs, 6);
        assert_eq!(a.batches, 4);
        // measured mean 1.0 s/batch, predicted mean 1.0 s/batch
        assert!((a.ratio().unwrap() - 1.0).abs() < 1e-12);
        // the merge adopts the first tier seen
        assert_eq!(a.tier, Some(crate::runtime::tier::KernelTier::Scalar));
        let empty = ArtifactServeStats::default();
        assert!(empty.ratio().is_none());
    }
}
