//! The EA4RCA controller layer (paper §3.1-§3.2): task deployment, the
//! alternating compute/communicate execution of DU-PU pairs, and run
//! reporting.
//!
//! * [`scheduler`] — the event-driven simulation of DU-PU pair groups
//!   over the shared DDR (Fig 2's pipeline).
//! * [`controller`] — ties a deployed design + workload to the scheduler
//!   and the power model, and (optionally) routes real task data through
//!   the PJRT runtime for numerical validation.
//! * [`shard`] — one logical AIE array's serving unit: micro-batched,
//!   backpressure-aware leader/worker serving over per-worker runtimes.
//! * [`router`] — the cluster tier: N shards, cost-model-aware global
//!   placement, per-shard deployment maps, drain/join, merged reports.
//! * [`server`] — the one-shard compatibility facade (`Server` is the
//!   N=1 case of the cluster layer).

pub mod controller;
pub mod router;
pub mod scheduler;
pub mod server;
pub mod shard;

pub use controller::{Controller, RunReport};
pub use router::{route_open_loop, ClusterConfig, RouteError, Router, ServeReport, ShardSummary};
pub use scheduler::{ExecMode, GroupSpec, SimEngine, SimReport};
pub use server::{Server, ServerConfig, SubmitError};
pub use shard::{Shard, ShardConfig, ShardReport};
