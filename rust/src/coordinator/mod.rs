//! The EA4RCA controller layer (paper §3.1-§3.2): task deployment, the
//! alternating compute/communicate execution of DU-PU pairs, and run
//! reporting.
//!
//! * [`scheduler`] — the event-driven simulation of DU-PU pair groups
//!   over the shared DDR (Fig 2's pipeline).
//! * [`controller`] — ties a deployed design + workload to the scheduler
//!   and the power model, and (optionally) routes real task data through
//!   the PJRT runtime for numerical validation.
//! * [`server`] — the deployment shape: micro-batched, backpressure-
//!   aware leader/worker serving over per-worker runtimes.

pub mod controller;
pub mod scheduler;
pub mod server;

pub use controller::{Controller, RunReport};
pub use scheduler::{ExecMode, GroupSpec, SimEngine, SimReport};
pub use server::{Server, ServeReport, ServerConfig, SubmitError};
