//! `Server` — the one-shard compatibility facade over the cluster
//! layer.
//!
//! The serving machinery that used to live here (admission queue,
//! dispatcher, worker pool, cost book) is now
//! [`super::shard::Shard`] — one logical AIE array — with
//! [`super::router::Router`] placing traffic across N of them. This
//! module keeps the original single-`Server` API as the exact N=1
//! case: a `Server` is one `Shard`, its `shutdown()` report is the
//! cluster merge of that one shard's ledger, and every legacy name
//! (`ServerConfig`, `SubmitError`, `JobResult`, `Pending`,
//! `ServeReport`, `ArtifactServeStats`, `WorkerStats`, `serve_batch`,
//! `serve_open_loop`) re-exports from the new layers so existing
//! callers compile unchanged.

use std::time::Duration;

use anyhow::{bail, Result};

use crate::runtime::{BackendKind, Tensor};
use crate::util::stats::{summarize, Summary};

use super::shard::Shard;

// The per-shard knobs ARE the legacy server knobs — `ServerConfig` is
// an alias, so struct literals like
// `ServerConfig { n_workers: 4, ..ServerConfig::default() }` still
// work everywhere.
pub use super::router::ServeReport;
pub use super::shard::{
    ArtifactServeStats, JobResult, Pending, ShardConfig as ServerConfig, SubmitError,
    WorkerStats, DEFAULT_SUBMIT_WAIT,
};

/// The running one-shard server: shard 0 of a cluster of one.
pub struct Server {
    shard: Shard,
}

impl Server {
    /// Spawn workers over the artifact directory with the default
    /// serving configuration, warming up the given artifacts in every
    /// worker. The backend comes from `$EA4RCA_BACKEND` (default:
    /// interpreter).
    pub fn start(
        n_workers: usize,
        artifact_dir: impl Into<std::path::PathBuf>,
        warmup: &[&str],
    ) -> Result<Server> {
        Server::start_with_backend(BackendKind::from_env()?, n_workers, artifact_dir, warmup)
    }

    /// [`Server::start`] with an explicit backend.
    pub fn start_with_backend(
        kind: BackendKind,
        n_workers: usize,
        artifact_dir: impl Into<std::path::PathBuf>,
        warmup: &[&str],
    ) -> Result<Server> {
        let config = ServerConfig { n_workers, ..ServerConfig::default() };
        Server::start_with_config(kind, config, artifact_dir, warmup)
    }

    /// Full-control constructor: one shard with this exact
    /// configuration. Placement is open (any artifact may be
    /// submitted; the warm-up list only pre-builds caches), matching
    /// the pre-cluster behaviour.
    pub fn start_with_config(
        kind: BackendKind,
        config: ServerConfig,
        artifact_dir: impl Into<std::path::PathBuf>,
        warmup: &[&str],
    ) -> Result<Server> {
        Ok(Server { shard: Shard::start(0, kind, config, artifact_dir, warmup)? })
    }

    /// Submit a job, waiting up to [`DEFAULT_SUBMIT_WAIT`] for queue
    /// space; returns a reply handle, or [`SubmitError::Saturated`]
    /// when the server stays overloaded for that long.
    pub fn submit(&self, artifact: &str, inputs: Vec<Tensor>) -> Result<Pending, SubmitError> {
        self.shard.submit(artifact, inputs)
    }

    /// Non-blocking submit: [`SubmitError::Saturated`] immediately when
    /// the admission queue is full (open-loop clients shed load here).
    pub fn try_submit(
        &self,
        artifact: &str,
        inputs: Vec<Tensor>,
    ) -> Result<Pending, SubmitError> {
        self.shard.try_submit(artifact, inputs)
    }

    /// Submit, waiting at most `wait` for queue space.
    pub fn submit_timeout(
        &self,
        artifact: &str,
        inputs: Vec<Tensor>,
        wait: Duration,
    ) -> Result<Pending, SubmitError> {
        self.shard.submit_timeout(artifact, inputs, wait)
    }

    /// Submit with a stream/tenant tag carried through to the
    /// [`JobResult`] and the per-stream report ledger.
    pub fn submit_stream(
        &self,
        artifact: &str,
        stream: u64,
        inputs: Vec<Tensor>,
    ) -> Result<Pending, SubmitError> {
        self.shard.submit_stream(artifact, stream, inputs, Some(DEFAULT_SUBMIT_WAIT))
    }

    pub fn workers(&self) -> usize {
        self.shard.workers()
    }

    /// Close admission, drain the queue through the workers, and join
    /// everything. Every accepted job gets its reply before the report
    /// is produced. The report is the cluster merge of this one
    /// shard's ledger.
    pub fn shutdown(self) -> Result<ServeReport> {
        Ok(ServeReport::from_shards(vec![self.shard.drain()?]))
    }
}

/// Convenience: serve a closed-loop batch and return latency stats.
pub fn serve_batch(
    server: &Server,
    jobs: Vec<(String, Vec<Tensor>)>,
) -> Result<(Vec<JobResult>, Summary)> {
    let mut pending = Vec::with_capacity(jobs.len());
    for (artifact, inputs) in jobs {
        pending.push(server.submit(&artifact, inputs)?);
    }
    let mut results = Vec::with_capacity(pending.len());
    for p in pending {
        results.push(p.wait()?);
    }
    let latencies: Vec<f64> = results.iter().map(|r| r.latency_secs()).collect();
    let summary = summarize(&latencies);
    Ok((results, summary))
}

/// Convenience: drive an open-loop arrival stream against the server.
/// Each arrival is `(at_secs, artifact, inputs)` with `at_secs`
/// relative to the first call; the driver sleeps until each arrival is
/// due and submits with [`Server::try_submit`], so a saturated
/// admission queue *sheds* the job (counted in the second return
/// value) instead of stalling the arrival clock — offered load stays
/// honest under overload.
pub fn serve_open_loop(
    server: &Server,
    arrivals: impl IntoIterator<Item = (f64, &'static str, Vec<Tensor>)>,
) -> Result<(Vec<JobResult>, u64)> {
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    let mut shed = 0u64;
    for (at_secs, artifact, inputs) in arrivals {
        let due = t0 + Duration::from_secs_f64(at_secs);
        if let Some(wait) = due.checked_duration_since(std::time::Instant::now()) {
            std::thread::sleep(wait);
        }
        match server.try_submit(artifact, inputs) {
            Ok(p) => pending.push(p),
            Err(SubmitError::Saturated) => shed += 1,
            Err(e) => bail!("open-loop submit failed: {e}"),
        }
    }
    let mut results = Vec::with_capacity(pending.len());
    for p in pending {
        results.push(p.wait()?);
    }
    Ok((results, shed))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn facade_is_the_one_shard_cluster() {
        let server =
            Server::start_with_backend(BackendKind::Interp, 1, "artifacts", &[]).unwrap();
        assert_eq!(server.workers(), 1);
        let inputs = vec![
            Tensor::f32(&[32, 32], vec![0.5; 32 * 32]),
            Tensor::f32(&[32, 32], vec![0.25; 32 * 32]),
        ];
        let result = server.submit("mm32", inputs).unwrap().wait().unwrap();
        assert!(result.outputs.is_ok(), "{:?}", result.outputs);
        // the facade is shard 0 of a cluster of one, and its report is
        // the one-shard cluster merge
        assert_eq!(result.shard, 0);
        let report = server.shutdown().unwrap();
        assert_eq!(report.total_jobs, 1);
        assert_eq!(report.completed_jobs(), 1);
        assert_eq!(report.shards.len(), 1);
        assert_eq!(report.shards[0].shard, 0);
        assert_eq!(report.shards[0].completed, 1);
    }

    #[test]
    fn bad_configs_still_rejected_through_the_facade() {
        assert!(Server::start_with_backend(BackendKind::Interp, 0, "artifacts", &[]).is_err());
        let bad = ServerConfig { max_batch: 0, ..ServerConfig::default() };
        assert!(Server::start_with_config(BackendKind::Interp, bad, "artifacts", &[]).is_err());
    }
}
