//! The serving layer: a leader/worker request server over the runtime
//! — the deployment shape of the coordinator (the paper's PS controller
//! receiving tasks "from the upper level", §3.1, running as a
//! long-lived service).
//!
//! Each worker thread owns its *own* backend instance (runtime +
//! executable/kernel cache). Backends are not `Send` in general (the
//! real PJRT client is thread-bound), and per-worker instances also
//! mirror the DU-PU pair isolation — workers never share hot state.
//! The leader round-robins jobs over workers through bounded mpsc
//! channels; replies come back on per-job channels. Latency/throughput
//! metrics are aggregated leader-side.

use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::runtime::{BackendKind, Runtime, Tensor};
use crate::util::stats::{summarize, Summary};

/// One inference/compute request.
pub struct Job {
    pub artifact: String,
    pub inputs: Vec<Tensor>,
    reply: mpsc::Sender<JobResult>,
    submitted: Instant,
}

/// The completed job.
#[derive(Debug)]
pub struct JobResult {
    pub outputs: Result<Vec<Tensor>>,
    /// Seconds from submit to completion (queueing + execution).
    pub latency_secs: f64,
    pub worker: usize,
}

/// A pending reply handle.
pub struct Pending {
    rx: mpsc::Receiver<JobResult>,
}

impl Pending {
    /// Block until the job completes.
    pub fn wait(self) -> Result<JobResult> {
        self.rx.recv().context("worker dropped the job")
    }
}

/// The running server.
pub struct Server {
    senders: Vec<mpsc::SyncSender<Job>>,
    handles: Vec<JoinHandle<WorkerStats>>,
    next: usize,
    submitted: u64,
}

/// Per-worker accounting returned at shutdown.
#[derive(Debug, Default, Clone)]
pub struct WorkerStats {
    pub worker: usize,
    pub jobs: u64,
    pub exec_secs: f64,
    pub errors: u64,
}

/// Whole-run report produced by [`Server::shutdown`].
#[derive(Debug)]
pub struct ServeReport {
    pub workers: Vec<WorkerStats>,
    pub total_jobs: u64,
}

impl Server {
    /// Spawn `n_workers` workers over the artifact directory, warming
    /// up the given artifacts in every worker. The backend comes from
    /// `$EA4RCA_BACKEND` (default: interpreter).
    pub fn start(
        n_workers: usize,
        artifact_dir: impl Into<std::path::PathBuf>,
        warmup: &[&str],
    ) -> Result<Server> {
        Server::start_with_backend(BackendKind::from_env()?, n_workers, artifact_dir, warmup)
    }

    /// [`Server::start`] with an explicit backend. Every worker thread
    /// instantiates its own backend (no shared substrate state).
    pub fn start_with_backend(
        kind: BackendKind,
        n_workers: usize,
        artifact_dir: impl Into<std::path::PathBuf>,
        warmup: &[&str],
    ) -> Result<Server> {
        if n_workers == 0 {
            bail!("need at least one worker");
        }
        let dir: std::path::PathBuf = artifact_dir.into();
        let warm: Vec<String> = warmup.iter().map(|s| s.to_string()).collect();
        let mut senders = Vec::new();
        let mut handles = Vec::new();
        // readiness barrier: workers report once their runtime is up
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        for w in 0..n_workers {
            let (tx, rx) = mpsc::sync_channel::<Job>(64);
            let dir = dir.clone();
            let warm = warm.clone();
            let ready = ready_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("ea4rca-worker-{w}"))
                .spawn(move || worker_main(w, kind, dir, warm, rx, ready))
                .context("spawning worker")?;
            senders.push(tx);
            handles.push(handle);
        }
        drop(ready_tx);
        for _ in 0..n_workers {
            ready_rx.recv().context("worker died during startup")??;
        }
        Ok(Server { senders, handles, next: 0, submitted: 0 })
    }

    /// Submit a job (round-robin); returns a reply handle.
    pub fn submit(&mut self, artifact: &str, inputs: Vec<Tensor>) -> Result<Pending> {
        let (reply, rx) = mpsc::channel();
        let job = Job {
            artifact: artifact.to_string(),
            inputs,
            reply,
            submitted: Instant::now(),
        };
        let w = self.next % self.senders.len();
        self.next += 1;
        self.submitted += 1;
        self.senders[w].send(job).map_err(|_| anyhow::anyhow!("worker {w} gone"))?;
        Ok(Pending { rx })
    }

    pub fn workers(&self) -> usize {
        self.senders.len()
    }

    /// Drain and join all workers.
    pub fn shutdown(self) -> Result<ServeReport> {
        drop(self.senders);
        let mut workers = Vec::new();
        for h in self.handles {
            workers.push(h.join().map_err(|_| anyhow::anyhow!("worker panicked"))?);
        }
        Ok(ServeReport { workers, total_jobs: self.submitted })
    }
}

fn worker_main(
    id: usize,
    kind: BackendKind,
    dir: std::path::PathBuf,
    warmup: Vec<String>,
    rx: mpsc::Receiver<Job>,
    ready: mpsc::Sender<Result<()>>,
) -> WorkerStats {
    let mut stats = WorkerStats { worker: id, ..Default::default() };
    let rt = match Runtime::with_backend(kind, dir).and_then(|rt| {
        let names: Vec<&str> = warmup.iter().map(String::as_str).collect();
        rt.warmup(&names)?;
        Ok(rt)
    }) {
        Ok(rt) => {
            let _ = ready.send(Ok(()));
            rt
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return stats;
        }
    };
    while let Ok(job) = rx.recv() {
        let t0 = Instant::now();
        let outputs = rt.execute(&job.artifact, &job.inputs);
        let exec = t0.elapsed().as_secs_f64();
        stats.jobs += 1;
        stats.exec_secs += exec;
        if outputs.is_err() {
            stats.errors += 1;
        }
        let result = JobResult {
            outputs,
            latency_secs: job.submitted.elapsed().as_secs_f64(),
            worker: id,
        };
        let _ = job.reply.send(result); // client may have gone away
    }
    stats
}

/// Convenience: serve a closed-loop batch and return latency stats.
pub fn serve_batch(
    server: &mut Server,
    jobs: Vec<(String, Vec<Tensor>)>,
) -> Result<(Vec<JobResult>, Summary)> {
    let mut pending = Vec::with_capacity(jobs.len());
    for (artifact, inputs) in jobs {
        pending.push(server.submit(&artifact, inputs)?);
    }
    let mut results = Vec::with_capacity(pending.len());
    for p in pending {
        results.push(p.wait()?);
    }
    let latencies: Vec<f64> = results.iter().map(|r| r.latency_secs).collect();
    let summary = summarize(&latencies);
    Ok((results, summary))
}
