//! The Code Repository of the AIE Graph Code Generator (paper Fig 6):
//!
//! * **Kernel Manager** — the registry of AIE kernel sources the GUI PU
//!   Editor offers; configs referencing unknown kernels are rejected,
//!   and each kernel carries its arithmetic class + the artifact that
//!   implements it on this substrate.
//! * **Graph Manager** — Stored Graphs: complete PU designs saved as
//!   configuration files that can be reloaded or integrated into a new
//!   design.
//! * **Graph Fusion** — integrating stored graphs into the current
//!   design: several PU configs fuse into one deployable project
//!   (combined ADF entry point + whole-card resource check).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::sim::core::KernelClass;
use crate::sim::memory::ResourceUsage;
use crate::sim::params::HwParams;

use super::config::PuConfig;
use super::generator::{self, GeneratedProject};

/// A registered AIE kernel source.
#[derive(Debug, Clone)]
pub struct KernelInfo {
    pub name: &'static str,
    pub class: KernelClass,
    /// The AOT artifact implementing this kernel's PU-level graph.
    pub artifact: &'static str,
    /// One-line description shown by the editor.
    pub about: &'static str,
}

/// The Kernel Manager: the kernels this repository ships.
pub fn kernel_catalogue() -> Vec<KernelInfo> {
    vec![
        KernelInfo {
            name: "mm32",
            class: KernelClass::F32Mac,
            artifact: "mm_pu128",
            about: "32x32x32 float MM (CHARM-optimal single-core load)",
        },
        KernelInfo {
            name: "mm32_i8",
            class: KernelClass::I32Mac,
            artifact: "mm32_i8",
            about: "32x32x32 int8 MM, int32 accumulate",
        },
        KernelInfo {
            name: "mm32_i16",
            class: KernelClass::I32Mac,
            artifact: "mm32_i16",
            about: "32x32x32 int16 MM, int32 accumulate",
        },
        KernelInfo {
            name: "filter2d",
            class: KernelClass::I32Mac,
            artifact: "filter2d_pu8",
            about: "5x5 int32 filter over a 32x32 tile (+halo)",
        },
        KernelInfo {
            name: "fft",
            class: KernelClass::Cint16Butterfly,
            artifact: "fft1024",
            about: "radix-2 DIT butterfly stages, split re/im planes",
        },
    ]
}

/// Look a kernel up by name.
pub fn find_kernel(name: &str) -> Option<KernelInfo> {
    kernel_catalogue().into_iter().find(|k| k.name == name)
}

/// Validate a config against the Kernel Manager (name known, class
/// consistent).
pub fn validate_kernel(cfg: &PuConfig) -> Result<KernelInfo> {
    let info = find_kernel(&cfg.kernel)
        .with_context(|| format!("kernel {:?} is not in the repository", cfg.kernel))?;
    if info.class != cfg.pu.class {
        bail!(
            "config class {:?} does not match kernel {:?}'s class {:?}",
            cfg.pu.class,
            cfg.kernel,
            info.class
        );
    }
    Ok(info)
}

/// The Graph Manager: stored graphs on disk.
#[derive(Debug)]
pub struct GraphManager {
    pub dir: PathBuf,
}

impl GraphManager {
    pub fn new(dir: impl Into<PathBuf>) -> GraphManager {
        GraphManager { dir: dir.into() }
    }

    pub fn store(&self, cfg: &PuConfig) -> Result<PathBuf> {
        std::fs::create_dir_all(&self.dir)?;
        let path = self.dir.join(format!("{}.json", cfg.name));
        std::fs::write(&path, cfg.to_json().to_string_pretty())?;
        Ok(path)
    }

    pub fn load(&self, name: &str) -> Result<PuConfig> {
        PuConfig::from_file(&self.dir.join(format!("{name}.json")))
    }

    /// Stored-graph names, sorted. `read_dir` yields filesystem order,
    /// which differs across platforms (and across runs on some
    /// filesystems) — the sort is what makes `info`-style listings and
    /// tests deterministic everywhere.
    pub fn list(&self) -> Result<Vec<String>> {
        let mut names = Vec::new();
        if !self.dir.exists() {
            return Ok(names);
        }
        for entry in std::fs::read_dir(&self.dir)? {
            let p = entry?.path();
            if p.extension().map(|e| e == "json").unwrap_or(false) {
                if let Some(stem) = p.file_stem().and_then(|s| s.to_str()) {
                    names.push(stem.to_string());
                }
            }
        }
        names.sort_unstable();
        Ok(names)
    }
}

/// A fused multi-PU project (Graph Fusion output).
#[derive(Debug)]
pub struct FusedProject {
    pub parts: Vec<(PuConfig, GeneratedProject)>,
    pub top_cpp: String,
    pub total_aie: usize,
    pub total_plio: usize,
}

/// Fuse several stored graphs into one deployable design, checking the
/// combined footprint against the card.
pub fn fuse(p: &HwParams, configs: &[PuConfig]) -> Result<FusedProject> {
    if configs.is_empty() {
        bail!("nothing to fuse");
    }
    // duplicate names would collide in the generated C++
    let mut seen = BTreeMap::new();
    for c in configs {
        if seen.insert(c.name.clone(), ()).is_some() {
            bail!("duplicate PU name {:?} in fusion set", c.name);
        }
        validate_kernel(c)?;
    }

    let mut total = ResourceUsage::default();
    let mut parts = Vec::new();
    let mut top = String::new();
    top.push_str("// Auto-generated fused design (Graph Fusion, Fig 6).\n");
    for cfg in configs {
        let proj = generator::generate(cfg)?;
        total = total.add(&ResourceUsage {
            aie: cfg.pu.cores() * cfg.copies,
            plio: cfg.pu.total_plios() * cfg.copies,
            ..Default::default()
        });
        top.push_str(&format!("#include \"{}/graph.h\"\n", cfg.name));
        parts.push((cfg.clone(), proj));
    }
    top.push('\n');
    for (cfg, _) in &parts {
        for c in 0..cfg.copies {
            top.push_str(&format!("{}_pu {}_{c};\n", cfg.name, cfg.name));
        }
    }
    total.check(p).context("fused design exceeds the card")?;
    Ok(FusedProject {
        total_aie: total.aie,
        total_plio: total.plio,
        parts,
        top_cpp: top,
    })
}

impl FusedProject {
    /// Write the fused project tree: `<dir>/<pu>/graph.{h,cpp}` + top.cpp.
    pub fn write_to(&self, dir: &Path) -> Result<()> {
        for (cfg, proj) in &self.parts {
            proj.write_to(&dir.join(&cfg.name))?;
        }
        std::fs::write(dir.join("top.cpp"), &self.top_cpp)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mm_cfg() -> PuConfig {
        PuConfig::from_json_text(
            &std::fs::read_to_string(
                std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/mm.json"),
            )
            .unwrap(),
        )
        .unwrap()
    }

    fn fft_cfg() -> PuConfig {
        PuConfig::from_json_text(
            &std::fs::read_to_string(
                std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/fft.json"),
            )
            .unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn kernel_catalogue_covers_configs() {
        for cfg in [mm_cfg(), fft_cfg()] {
            validate_kernel(&cfg).unwrap();
        }
    }

    #[test]
    fn unknown_kernel_rejected() {
        let mut cfg = mm_cfg();
        cfg.kernel = "nope".into();
        assert!(validate_kernel(&cfg).is_err());
    }

    #[test]
    fn class_mismatch_rejected() {
        let mut cfg = mm_cfg();
        cfg.kernel = "filter2d".into(); // i32 kernel under an f32 config
        assert!(validate_kernel(&cfg).is_err());
    }

    #[test]
    fn graph_manager_roundtrip() {
        let dir = std::env::temp_dir().join("ea4rca_graphs_test");
        let _ = std::fs::remove_dir_all(&dir);
        let gm = GraphManager::new(&dir);
        let cfg = mm_cfg();
        gm.store(&cfg).unwrap();
        assert_eq!(gm.list().unwrap(), vec!["mm".to_string()]);
        let back = gm.load("mm").unwrap();
        assert_eq!(back.pu, cfg.pu);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn list_is_sorted_not_filesystem_order() {
        // files created in deliberately scrambled order; whatever order
        // the filesystem returns them in, list() must be sorted
        let dir = std::env::temp_dir().join("ea4rca_graphs_order_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        for name in ["zeta", "alpha", "mid", "beta"] {
            std::fs::write(dir.join(format!("{name}.json")), "{}").unwrap();
        }
        std::fs::write(dir.join("notes.txt"), "ignored").unwrap();
        let gm = GraphManager::new(&dir);
        assert_eq!(
            gm.list().unwrap(),
            vec!["alpha".to_string(), "beta".into(), "mid".into(), "zeta".into()]
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fusion_checks_the_card() {
        let p = HwParams::vck5000();
        // MM (384 cores) + FFT (80 cores) = 464 > 400: must be rejected
        let err = fuse(&p, &[mm_cfg(), fft_cfg()]).unwrap_err();
        assert!(err.to_string().contains("exceeds the card"), "{err}");
        // MM alone fuses fine
        let f = fuse(&p, &[mm_cfg()]).unwrap();
        assert_eq!(f.total_aie, 384);
        assert!(f.top_cpp.contains("mm_pu mm_0;"));
        assert!(f.top_cpp.contains("mm_pu mm_5;"));
        // a trimmed MM (2 copies) + FFT fits: 128 + 80
        let mut small_mm = mm_cfg();
        small_mm.copies = 2;
        let f = fuse(&p, &[small_mm, fft_cfg()]).unwrap();
        assert_eq!(f.total_aie, 2 * 64 + 8 * 10);
        assert!(f.top_cpp.contains("fft_pu fft_7;"));
    }

    #[test]
    fn fusion_rejects_duplicates() {
        let p = HwParams::vck5000();
        let mut a = mm_cfg();
        a.copies = 1;
        let b = a.clone();
        assert!(fuse(&p, &[a, b]).is_err());
    }
}
