//! The AIE Graph Code Generator (paper §3.5, Fig 6).
//!
//! The paper's tool takes a Graph Configuration File describing a PU
//! (DAC / CC / DCC connectivity) and one-click generates the compilable
//! AIE project. This module is that pipeline on our substrate:
//!
//! * [`config`]    — parse + validate the JSON configuration file into a
//!   [`ProcessingUnit`](crate::engine::compute::pu::ProcessingUnit)
//!   (the Generator Core's "parse PU information" stage).
//! * [`generator`] — the DAC/CC/DCC generators + Component Connector +
//!   Project Creator: emits ADF-style C++ graph code (`graph.h`,
//!   `graph.cpp`, a `Makefile` stub targeting the Xilinx backend) and
//!   the simulator-side group description.
//!
//! `configs/*.json` in the repo root hold the four accelerators'
//! configuration files; `ea4rca generate --config <file>` runs the
//! pipeline from the CLI, and `benches/fig7_pu_structures.rs` prints the
//! Fig 7 structures from the same source of truth.

pub mod config;
pub mod generator;
pub mod repository;

pub use config::PuConfig;
pub use generator::GeneratedProject;
pub use repository::{fuse, GraphManager};
