//! Graph Configuration File parsing + validation.
//!
//! Schema (JSON):
//!
//! ```json
//! {
//!   "name": "mm",
//!   "kernel": "mm32",              // artifact / AIE kernel source name
//!   "class": "f32mac",             // f32mac | i32mac | cint16butterfly
//!   "psts": [
//!     {"dacs": [{"modes": ["SWH", "BDC"], "plios": 8, "serves": 64}],
//!      "cc": "Parallel<16>*Cascade<4>",
//!      "dccs": [{"mode": "SWH", "plios": 4, "serves": 64}]}
//!   ],
//!   "ops_per_iter": 4194304,
//!   "in_bytes": 131072,
//!   "out_bytes": 65536,
//!   "serial_comm": false,          // optional
//!   "handoff_bytes": 0,            // optional
//!   "copies": 6                    // PUs deployed
//! }
//! ```
//!
//! A top-level `"artifact"` key may additionally override the runtime
//! artifact; it belongs to the design facade (`api::Design`) and is
//! ignored by this parser.

use anyhow::{bail, Context, Result};

use crate::engine::compute::cc::parse_cc_validated as parse_cc;
use crate::engine::compute::dac::{Dac, DacMode};
use crate::engine::compute::dcc::{Dcc, DccMode};
use crate::engine::compute::pu::{ProcessingStructure, ProcessingUnit};
use crate::sim::core::KernelClass;
use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct PuConfig {
    pub name: String,
    /// Artifact / AIE kernel source name — the key that ties this
    /// configuration to a runtime artifact in the unified pipeline.
    pub kernel: String,
    pub copies: usize,
    pub pu: ProcessingUnit,
}

fn parse_class(s: &str) -> Result<KernelClass> {
    match s.to_ascii_lowercase().as_str() {
        "f32mac" => Ok(KernelClass::F32Mac),
        "i32mac" => Ok(KernelClass::I32Mac),
        "cint16butterfly" => Ok(KernelClass::Cint16Butterfly),
        other => bail!("unknown kernel class {other:?}"),
    }
}

fn parse_dac(j: &Json) -> Result<Dac> {
    let modes = j
        .get("modes")
        .and_then(Json::as_arr)
        .context("DAC needs a 'modes' array")?
        .iter()
        .map(|m| {
            DacMode::parse(m.as_str().context("DAC mode must be a string")?)
                .map_err(anyhow::Error::msg)
        })
        .collect::<Result<Vec<_>>>()?;
    let plios = j.get("plios").and_then(Json::as_usize).context("DAC needs 'plios'")?;
    let serves = j.get("serves").and_then(Json::as_usize).context("DAC needs 'serves'")?;
    Ok(Dac::new(modes, plios, serves))
}

fn parse_dcc(j: &Json) -> Result<Dcc> {
    let mode = DccMode::parse(
        j.get("mode").and_then(Json::as_str).context("DCC needs 'mode'")?,
    )
    .map_err(anyhow::Error::msg)?;
    let plios = j.get("plios").and_then(Json::as_usize).context("DCC needs 'plios'")?;
    let serves = j.get("serves").and_then(Json::as_usize).context("DCC needs 'serves'")?;
    Ok(Dcc::new(mode, plios, serves))
}

impl PuConfig {
    pub fn from_json_text(text: &str) -> Result<PuConfig> {
        let root = Json::parse(text).context("configuration is not valid JSON")?;
        PuConfig::from_json(&root)
    }

    pub fn from_file(path: &std::path::Path) -> Result<PuConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        PuConfig::from_json_text(&text)
    }

    pub fn from_json(root: &Json) -> Result<PuConfig> {
        let name = root
            .get("name")
            .and_then(Json::as_str)
            .context("config needs 'name'")?
            .to_string();
        let kernel = root
            .get("kernel")
            .and_then(Json::as_str)
            .context("config needs 'kernel'")?
            .to_string();
        let class = parse_class(
            root.get("class").and_then(Json::as_str).context("config needs 'class'")?,
        )?;
        let copies = root.get("copies").and_then(Json::as_usize).unwrap_or(1);
        if copies == 0 {
            bail!("'copies' must be >= 1");
        }

        let psts_json = root
            .get("psts")
            .and_then(Json::as_arr)
            .context("config needs a 'psts' array")?;
        if psts_json.is_empty() {
            bail!("'psts' must not be empty");
        }
        let mut psts = Vec::new();
        for (i, pj) in psts_json.iter().enumerate() {
            let cc = parse_cc(
                pj.get("cc")
                    .and_then(Json::as_str)
                    .with_context(|| format!("pst[{i}] needs 'cc'"))?,
            )
            .map_err(anyhow::Error::msg)?;
            let dacs = pj
                .get("dacs")
                .and_then(Json::as_arr)
                .with_context(|| format!("pst[{i}] needs 'dacs'"))?
                .iter()
                .map(parse_dac)
                .collect::<Result<Vec<_>>>()?;
            let dccs = pj
                .get("dccs")
                .and_then(Json::as_arr)
                .with_context(|| format!("pst[{i}] needs 'dccs'"))?
                .iter()
                .map(parse_dcc)
                .collect::<Result<Vec<_>>>()?;
            psts.push(ProcessingStructure { dacs, cc, dccs });
        }

        let ops = root
            .get("ops_per_iter")
            .and_then(Json::as_f64)
            .context("config needs 'ops_per_iter'")?;
        let in_bytes = root
            .get("in_bytes")
            .and_then(Json::as_usize)
            .context("config needs 'in_bytes'")?;
        let out_bytes = root
            .get("out_bytes")
            .and_then(Json::as_usize)
            .context("config needs 'out_bytes'")?;

        let mut pu = ProcessingUnit::simple(&name, psts, class, ops, in_bytes, out_bytes);
        pu.serial_comm = root
            .get("serial_comm")
            .and_then(Json::as_bool)
            .unwrap_or(false);
        pu.handoff_bytes = root
            .get("handoff_bytes")
            .and_then(Json::as_usize)
            .unwrap_or(0);
        pu.validate().map_err(anyhow::Error::msg)?;

        Ok(PuConfig { name, kernel, copies, pu })
    }

    /// Serialize back to the configuration-file JSON (the GUI PU Editor's
    /// Configuration Generator in the paper — round-trips for golden
    /// tests).
    pub fn to_json(&self) -> Json {
        let class = match self.pu.class {
            KernelClass::F32Mac => "f32mac",
            KernelClass::I32Mac => "i32mac",
            KernelClass::Cint16Butterfly => "cint16butterfly",
        };
        let psts: Vec<Json> = self
            .pu
            .psts
            .iter()
            .map(|pst| {
                Json::obj(vec![
                    (
                        "dacs",
                        Json::arr(
                            pst.dacs
                                .iter()
                                .map(|d| {
                                    Json::obj(vec![
                                        (
                                            "modes",
                                            Json::arr(
                                                d.modes
                                                    .iter()
                                                    .map(|m| Json::str(m.name()))
                                                    .collect(),
                                            ),
                                        ),
                                        ("plios", Json::num(d.plios as f64)),
                                        ("serves", Json::num(d.serves_cores as f64)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                    ("cc", Json::str(pst.cc.to_string())),
                    (
                        "dccs",
                        Json::arr(
                            pst.dccs
                                .iter()
                                .map(|d| {
                                    Json::obj(vec![
                                        ("mode", Json::str(d.mode.name())),
                                        ("plios", Json::num(d.plios as f64)),
                                        ("serves", Json::num(d.serves_cores as f64)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect();
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("kernel", Json::str(&self.kernel)),
            ("class", Json::str(class)),
            ("copies", Json::num(self.copies as f64)),
            ("psts", Json::arr(psts)),
            ("ops_per_iter", Json::num(self.pu.ops_per_iter)),
            ("in_bytes", Json::num(self.pu.in_bytes_per_iter as f64)),
            ("out_bytes", Json::num(self.pu.out_bytes_per_iter as f64)),
            ("serial_comm", Json::Bool(self.pu.serial_comm)),
            ("handoff_bytes", Json::num(self.pu.handoff_bytes as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub const MM_CONFIG: &str = r#"{
        "name": "mm", "kernel": "mm32", "class": "f32mac", "copies": 6,
        "psts": [{
            "dacs": [{"modes": ["SWH", "BDC"], "plios": 8, "serves": 64}],
            "cc": "Parallel<16>*Cascade<4>",
            "dccs": [{"mode": "SWH", "plios": 4, "serves": 64}]
        }],
        "ops_per_iter": 4194304, "in_bytes": 131072, "out_bytes": 65536
    }"#;

    #[test]
    fn parses_mm_config() {
        let c = PuConfig::from_json_text(MM_CONFIG).unwrap();
        assert_eq!(c.name, "mm");
        assert_eq!(c.copies, 6);
        assert_eq!(c.pu.cores(), 64);
        assert_eq!(c.pu.total_plios(), 12);
        assert_eq!(c.pu.class, KernelClass::F32Mac);
    }

    #[test]
    fn roundtrips_through_json() {
        let c = PuConfig::from_json_text(MM_CONFIG).unwrap();
        let text = c.to_json().to_string_pretty();
        let c2 = PuConfig::from_json_text(&text).unwrap();
        assert_eq!(c.pu, c2.pu);
        assert_eq!(c.copies, c2.copies);
    }

    #[test]
    fn rejects_invalid_cc() {
        let bad = MM_CONFIG.replace("Parallel<16>*Cascade<4>", "Waffle<9>");
        assert!(PuConfig::from_json_text(&bad).is_err());
    }

    #[test]
    fn rejects_dir_to_multicore() {
        let bad = MM_CONFIG.replace(r#""modes": ["SWH", "BDC"]"#, r#""modes": ["DIR"]"#);
        assert!(PuConfig::from_json_text(&bad).is_err());
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(PuConfig::from_json_text(r#"{"name": "x"}"#).is_err());
        assert!(PuConfig::from_json_text("not json").is_err());
    }
}
