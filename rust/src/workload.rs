//! Synthetic workload generation: deterministic task streams for the
//! serving layer and the sweep benches. The paper evaluates fixed-size
//! batch workloads; real deployments see mixed streams — this module
//! generates both, seeded and reproducible, in closed-loop (submit as
//! fast as the server accepts) and open-loop (Poisson arrivals at a
//! target rate, independent of service time) shapes.

use anyhow::{bail, Result};

use crate::runtime::tensor::{fft_ref, filter2d_ref, matmul_ref};
use crate::runtime::{ArtifactMeta, DType, Tensor};
use crate::util::rng::Rng;

/// The task kinds the serving layer accepts (one per accelerator).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    /// 128^3 MM block (artifact `mm_pu128`).
    MmBlock,
    /// 8-tile Filter2D batch (artifact `filter2d_pu8`).
    FilterBatch,
    /// 1024-point FFT (artifact `fft1024`).
    Fft1024,
    /// MM-T chain (artifact `mmt_cascade8`).
    MmtChain,
}

impl TaskKind {
    pub fn artifact(&self) -> &'static str {
        match self {
            TaskKind::MmBlock => "mm_pu128",
            TaskKind::FilterBatch => "filter2d_pu8",
            TaskKind::Fft1024 => "fft1024",
            TaskKind::MmtChain => "mmt_cascade8",
        }
    }

    /// Generate one task's input tensors.
    pub fn gen_inputs(&self, rng: &mut Rng) -> Vec<Tensor> {
        match self {
            TaskKind::MmBlock => vec![
                Tensor::f32(&[128, 128], rng.normal_vec(128 * 128)),
                Tensor::f32(&[128, 128], rng.normal_vec(128 * 128)),
            ],
            TaskKind::FilterBatch => vec![
                Tensor::i32(&[8, 36, 36], rng.int_vec_i32(8 * 36 * 36, -128, 127)),
                Tensor::i32(&[5, 5], rng.int_vec_i32(25, -8, 8)),
            ],
            TaskKind::Fft1024 => vec![
                Tensor::f32(&[1024], rng.normal_vec(1024)),
                Tensor::f32(&[1024], rng.normal_vec(1024)),
            ],
            TaskKind::MmtChain => vec![
                Tensor::f32(&[32, 256], rng.normal_vec(32 * 256)),
                Tensor::f32(&[256, 32], rng.normal_vec(256 * 32)),
            ],
        }
    }

    pub fn all() -> [TaskKind; 4] {
        [TaskKind::MmBlock, TaskKind::FilterBatch, TaskKind::Fft1024, TaskKind::MmtChain]
    }
}

/// A task-stream specification: kinds with relative weights.
#[derive(Debug, Clone)]
pub struct Mix {
    pub entries: Vec<(TaskKind, f64)>,
}

impl Mix {
    /// Every name [`Mix::parse`] accepts — the CLI's `--mix` vocabulary.
    pub const NAMES: [&'static str; 6] =
        ["uniform", "mm-heavy", "mm", "fft", "filter2d", "mmt"];

    /// Parse a mix name (the one place the `--mix` vocabulary is
    /// matched). A typo'd name gets an error that lists every valid
    /// mix, so the CLI is self-documenting.
    pub fn parse(s: &str) -> Result<Mix> {
        Ok(match s {
            "uniform" => Mix::uniform(),
            "mm-heavy" => Mix::mm_heavy(),
            "mm" => Mix::single(TaskKind::MmBlock),
            "fft" => Mix::single(TaskKind::Fft1024),
            "filter2d" => Mix::single(TaskKind::FilterBatch),
            "mmt" => Mix::single(TaskKind::MmtChain),
            other => bail!(
                "unknown mix {other:?} (valid mixes: {})",
                Mix::NAMES.join(" | ")
            ),
        })
    }

    pub fn uniform() -> Mix {
        Mix { entries: TaskKind::all().iter().map(|k| (*k, 1.0)).collect() }
    }

    pub fn single(kind: TaskKind) -> Mix {
        Mix { entries: vec![(kind, 1.0)] }
    }

    /// An MM-heavy serving mix (the paper's operator-service scenario).
    pub fn mm_heavy() -> Mix {
        Mix {
            entries: vec![
                (TaskKind::MmBlock, 6.0),
                (TaskKind::Fft1024, 2.0),
                (TaskKind::FilterBatch, 2.0),
            ],
        }
    }

    /// Sample one task kind from the weighted mix.
    pub fn pick(&self, rng: &mut Rng) -> TaskKind {
        let total: f64 = self.entries.iter().map(|(_, w)| w).sum();
        let mut x = rng.f64() * total;
        for (k, w) in &self.entries {
            if x < *w {
                return *k;
            }
            x -= w;
        }
        self.entries.last().expect("non-empty mix").0
    }
}

/// Seeded random inputs for one job of an arbitrary artifact, driven
/// entirely by its manifest metadata (shapes + dtypes) — the one place
/// meta-driven input generation lives, shared by the `run` cross-check
/// and the backend-equivalence tests.
pub fn seeded_inputs(meta: &ArtifactMeta, rng: &mut Rng) -> Vec<Tensor> {
    meta.inputs
        .iter()
        .map(|tm| match tm.dtype {
            DType::F32 => Tensor::f32(&tm.shape, rng.normal_vec(tm.elements())),
            DType::I32 => Tensor::i32(&tm.shape, rng.int_vec_i32(tm.elements(), -16, 16)),
        })
        .collect()
}

/// Generate a deterministic stream of `n` tasks from a mix.
pub fn generate_stream(mix: &Mix, n: usize, seed: u64) -> Vec<(TaskKind, Vec<Tensor>)> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let kind = mix.pick(&mut rng);
            let inputs = kind.gen_inputs(&mut rng);
            (kind, inputs)
        })
        .collect()
}

/// One request in an open-loop arrival stream.
#[derive(Debug)]
pub struct Arrival {
    /// Seconds after stream start at which this job arrives.
    pub at_secs: f64,
    pub kind: TaskKind,
    /// Stable stream/tenant id: the generating stream's seed, the same
    /// for every arrival of one `open_loop_stream` call. Carried
    /// through submission into `JobResult` and the per-stream report
    /// ledger, so merged multi-shard reports can attribute jobs per
    /// stream instead of positionally.
    pub stream: u64,
    pub inputs: Vec<Tensor>,
}

/// Generate a deterministic open-loop stream: `n` tasks whose
/// inter-arrival gaps are exponentially distributed with mean
/// `1/rate_hz` (a Poisson process). Unlike the closed-loop
/// [`generate_stream`], arrival times do not depend on how fast the
/// server drains — driving a server with this stream and `try_submit`
/// measures saturation behaviour at a controlled offered load.
pub fn open_loop_stream(mix: &Mix, n: usize, seed: u64, rate_hz: f64) -> Vec<Arrival> {
    assert!(rate_hz > 0.0, "arrival rate must be positive");
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    (0..n)
        .map(|_| {
            // inverse-CDF exponential; 1-u in (0,1] keeps ln finite
            t += -(1.0 - rng.f64()).ln() / rate_hz;
            let kind = mix.pick(&mut rng);
            let inputs = kind.gen_inputs(&mut rng);
            Arrival { at_secs: t, kind, stream: seed, inputs }
        })
        .collect()
}

/// Reference (oracle) outputs for one task, computed with the
/// `runtime::tensor::*_ref` kernels — what a correct backend, batched
/// or not, must return for these inputs. Dimensions come from the
/// input tensors themselves, so this oracle tracks [`TaskKind::gen_inputs`]
/// (and the manifest shapes it mirrors) with no duplicated constants.
pub fn reference_outputs(kind: TaskKind, inputs: &[Tensor]) -> Vec<Tensor> {
    match kind {
        TaskKind::MmBlock | TaskKind::MmtChain => {
            let (m, k) = (inputs[0].shape()[0], inputs[0].shape()[1]);
            let n = inputs[1].shape()[1];
            let c = matmul_ref(
                inputs[0].as_f32().expect("mm inputs are f32"),
                inputs[1].as_f32().expect("mm inputs are f32"),
                m,
                k,
                n,
            );
            vec![Tensor::f32(&[m, n], c)]
        }
        TaskKind::FilterBatch => {
            let tiles = inputs[0].as_i32().expect("filter tiles are i32");
            let kern = inputs[1].as_i32().expect("filter kernel is i32");
            let (batch, ih, iw) =
                (inputs[0].shape()[0], inputs[0].shape()[1], inputs[0].shape()[2]);
            let taps = inputs[1].shape()[0];
            let (oh, ow) = (ih - (taps - 1), iw - (taps - 1));
            let mut out = Vec::with_capacity(batch * oh * ow);
            for t in 0..batch {
                let tile = &tiles[t * ih * iw..(t + 1) * ih * iw];
                out.extend(filter2d_ref(tile, ih, iw, kern, taps));
            }
            vec![Tensor::i32(&[batch, oh, ow], out)]
        }
        TaskKind::Fft1024 => {
            let n = inputs[0].shape()[0];
            let (re, im) = fft_ref(
                inputs[0].as_f32().expect("fft planes are f32"),
                inputs[1].as_f32().expect("fft planes are f32"),
            );
            vec![Tensor::f32(&[n], re), Tensor::f32(&[n], im)]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_is_deterministic() {
        let a = generate_stream(&Mix::uniform(), 16, 7);
        let b = generate_stream(&Mix::uniform(), 16, 7);
        assert_eq!(a.len(), 16);
        for ((ka, ta), (kb, tb)) in a.iter().zip(&b) {
            assert_eq!(ka, kb);
            assert_eq!(ta.len(), tb.len());
            assert_eq!(ta[0], tb[0]);
        }
    }

    #[test]
    fn different_seeds_differ() {
        let a = generate_stream(&Mix::single(TaskKind::Fft1024), 4, 1);
        let b = generate_stream(&Mix::single(TaskKind::Fft1024), 4, 2);
        assert_ne!(a[0].1[0], b[0].1[0]);
    }

    #[test]
    fn mix_parse_covers_the_vocabulary() {
        for name in Mix::NAMES {
            let mix = Mix::parse(name).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(!mix.entries.is_empty(), "{name}");
        }
        // the single-kind names map to their artifact's task kind
        assert_eq!(Mix::parse("fft").unwrap().entries[0].0, TaskKind::Fft1024);
        assert_eq!(Mix::parse("mmt").unwrap().entries[0].0, TaskKind::MmtChain);
    }

    #[test]
    fn mix_parse_error_lists_every_valid_mix() {
        let err = Mix::parse("waffle").unwrap_err().to_string();
        assert!(err.contains("waffle"), "{err}");
        for name in Mix::NAMES {
            assert!(err.contains(name), "error must list {name:?}: {err}");
        }
    }

    #[test]
    fn mix_respects_single() {
        let s = generate_stream(&Mix::single(TaskKind::MmBlock), 32, 3);
        assert!(s.iter().all(|(k, _)| *k == TaskKind::MmBlock));
    }

    #[test]
    fn input_shapes_match_artifacts() {
        let mut rng = Rng::new(1);
        for kind in TaskKind::all() {
            let inputs = kind.gen_inputs(&mut rng);
            assert!(!inputs.is_empty(), "{kind:?}");
            assert!(!inputs[0].is_empty());
        }
    }

    #[test]
    fn seeded_inputs_follow_the_manifest_and_are_deterministic() {
        let m = crate::runtime::Manifest::builtin("artifacts");
        for name in ["mm_pu128", "mm32_i8", "filter2d_pu8", "fft1024"] {
            let meta = m.get(name).unwrap();
            let a = seeded_inputs(meta, &mut Rng::new(9));
            let b = seeded_inputs(meta, &mut Rng::new(9));
            assert_eq!(a.len(), meta.inputs.len(), "{name}");
            for (t, tm) in a.iter().zip(&meta.inputs) {
                assert_eq!(t.shape(), tm.shape.as_slice(), "{name}");
                assert_eq!(t.dtype(), tm.dtype, "{name}");
            }
            assert_eq!(a, b, "{name}: same seed must give identical inputs");
        }
    }

    #[test]
    fn weighted_mix_skews() {
        let mix = Mix::mm_heavy();
        let s = generate_stream(&mix, 400, 11);
        let mm = s.iter().filter(|(k, _)| *k == TaskKind::MmBlock).count();
        assert!(mm > 180, "mm count {mm} of 400");
    }

    #[test]
    fn open_loop_is_deterministic_and_monotone() {
        let a = open_loop_stream(&Mix::uniform(), 32, 7, 1000.0);
        let b = open_loop_stream(&Mix::uniform(), 32, 7, 1000.0);
        assert_eq!(a.len(), 32);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at_secs, y.at_secs);
            assert_eq!(x.kind, y.kind);
            assert_eq!(x.inputs[0], y.inputs[0]);
            // the stream/tenant id is the generating seed, stable
            // across every arrival of the stream
            assert_eq!(x.stream, 7);
        }
        for w in a.windows(2) {
            assert!(w[1].at_secs > w[0].at_secs, "arrival times must increase");
        }
        assert!(a[0].at_secs > 0.0);
    }

    #[test]
    fn open_loop_hits_the_target_rate() {
        // 2000 exponential gaps at 500 Hz: the span concentrates near
        // n/rate = 4 s (std of the sum is rate^-1 * sqrt(n) ~ 0.09 s)
        let s = open_loop_stream(&Mix::single(TaskKind::Fft1024), 2000, 13, 500.0);
        let span = s.last().unwrap().at_secs;
        assert!((3.5..=4.5).contains(&span), "span {span}");
    }

    #[test]
    fn reference_outputs_shapes_match_artifacts() {
        let mut rng = Rng::new(2);
        for kind in TaskKind::all() {
            let inputs = kind.gen_inputs(&mut rng);
            let outs = reference_outputs(kind, &inputs);
            match kind {
                TaskKind::MmBlock => assert_eq!(outs[0].shape(), &[128, 128]),
                TaskKind::FilterBatch => assert_eq!(outs[0].shape(), &[8, 32, 32]),
                TaskKind::Fft1024 => {
                    assert_eq!(outs.len(), 2);
                    assert_eq!(outs[0].shape(), &[1024]);
                }
                TaskKind::MmtChain => assert_eq!(outs[0].shape(), &[32, 32]),
            }
        }
    }

    #[test]
    fn reference_outputs_are_the_identity_oracle() {
        // A @ I == A through the mm oracle
        let mut a = vec![0.0f32; 128 * 128];
        for (i, v) in a.iter_mut().enumerate() {
            *v = (i % 17) as f32 - 8.0;
        }
        let mut eye = vec![0.0f32; 128 * 128];
        for i in 0..128 {
            eye[i * 128 + i] = 1.0;
        }
        let outs = reference_outputs(
            TaskKind::MmBlock,
            &[Tensor::f32(&[128, 128], a.clone()), Tensor::f32(&[128, 128], eye)],
        );
        assert_eq!(outs[0].as_f32().unwrap(), a.as_slice());
    }
}
