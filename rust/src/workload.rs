//! Synthetic workload generation: deterministic task streams for the
//! serving layer and the sweep benches. The paper evaluates fixed-size
//! batch workloads; real deployments see mixed streams — this module
//! generates both, seeded and reproducible.

use crate::runtime::Tensor;
use crate::util::rng::Rng;

/// The task kinds the serving layer accepts (one per accelerator).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    /// 128^3 MM block (artifact `mm_pu128`).
    MmBlock,
    /// 8-tile Filter2D batch (artifact `filter2d_pu8`).
    FilterBatch,
    /// 1024-point FFT (artifact `fft1024`).
    Fft1024,
    /// MM-T chain (artifact `mmt_cascade8`).
    MmtChain,
}

impl TaskKind {
    pub fn artifact(&self) -> &'static str {
        match self {
            TaskKind::MmBlock => "mm_pu128",
            TaskKind::FilterBatch => "filter2d_pu8",
            TaskKind::Fft1024 => "fft1024",
            TaskKind::MmtChain => "mmt_cascade8",
        }
    }

    /// Generate one task's input tensors.
    pub fn gen_inputs(&self, rng: &mut Rng) -> Vec<Tensor> {
        match self {
            TaskKind::MmBlock => vec![
                Tensor::f32(&[128, 128], rng.normal_vec(128 * 128)),
                Tensor::f32(&[128, 128], rng.normal_vec(128 * 128)),
            ],
            TaskKind::FilterBatch => vec![
                Tensor::i32(&[8, 36, 36], rng.int_vec_i32(8 * 36 * 36, -128, 127)),
                Tensor::i32(&[5, 5], rng.int_vec_i32(25, -8, 8)),
            ],
            TaskKind::Fft1024 => vec![
                Tensor::f32(&[1024], rng.normal_vec(1024)),
                Tensor::f32(&[1024], rng.normal_vec(1024)),
            ],
            TaskKind::MmtChain => vec![
                Tensor::f32(&[32, 256], rng.normal_vec(32 * 256)),
                Tensor::f32(&[256, 32], rng.normal_vec(256 * 32)),
            ],
        }
    }

    pub fn all() -> [TaskKind; 4] {
        [TaskKind::MmBlock, TaskKind::FilterBatch, TaskKind::Fft1024, TaskKind::MmtChain]
    }
}

/// A task-stream specification: kinds with relative weights.
#[derive(Debug, Clone)]
pub struct Mix {
    pub entries: Vec<(TaskKind, f64)>,
}

impl Mix {
    pub fn uniform() -> Mix {
        Mix { entries: TaskKind::all().iter().map(|k| (*k, 1.0)).collect() }
    }

    pub fn single(kind: TaskKind) -> Mix {
        Mix { entries: vec![(kind, 1.0)] }
    }

    /// An MM-heavy serving mix (the paper's operator-service scenario).
    pub fn mm_heavy() -> Mix {
        Mix {
            entries: vec![
                (TaskKind::MmBlock, 6.0),
                (TaskKind::Fft1024, 2.0),
                (TaskKind::FilterBatch, 2.0),
            ],
        }
    }

    fn pick(&self, rng: &mut Rng) -> TaskKind {
        let total: f64 = self.entries.iter().map(|(_, w)| w).sum();
        let mut x = rng.f64() * total;
        for (k, w) in &self.entries {
            if x < *w {
                return *k;
            }
            x -= w;
        }
        self.entries.last().expect("non-empty mix").0
    }
}

/// Generate a deterministic stream of `n` tasks from a mix.
pub fn generate_stream(mix: &Mix, n: usize, seed: u64) -> Vec<(TaskKind, Vec<Tensor>)> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let kind = mix.pick(&mut rng);
            let inputs = kind.gen_inputs(&mut rng);
            (kind, inputs)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_is_deterministic() {
        let a = generate_stream(&Mix::uniform(), 16, 7);
        let b = generate_stream(&Mix::uniform(), 16, 7);
        assert_eq!(a.len(), 16);
        for ((ka, ta), (kb, tb)) in a.iter().zip(&b) {
            assert_eq!(ka, kb);
            assert_eq!(ta.len(), tb.len());
            assert_eq!(ta[0], tb[0]);
        }
    }

    #[test]
    fn different_seeds_differ() {
        let a = generate_stream(&Mix::single(TaskKind::Fft1024), 4, 1);
        let b = generate_stream(&Mix::single(TaskKind::Fft1024), 4, 2);
        assert_ne!(a[0].1[0], b[0].1[0]);
    }

    #[test]
    fn mix_respects_single() {
        let s = generate_stream(&Mix::single(TaskKind::MmBlock), 32, 3);
        assert!(s.iter().all(|(k, _)| *k == TaskKind::MmBlock));
    }

    #[test]
    fn input_shapes_match_artifacts() {
        let mut rng = Rng::new(1);
        for kind in TaskKind::all() {
            let inputs = kind.gen_inputs(&mut rng);
            assert!(!inputs.is_empty(), "{kind:?}");
            assert!(!inputs[0].is_empty());
        }
    }

    #[test]
    fn weighted_mix_skews() {
        let mix = Mix::mm_heavy();
        let s = generate_stream(&mix, 400, 11);
        let mm = s.iter().filter(|(k, _)| *k == TaskKind::MmBlock).count();
        assert!(mm > 180, "mm count {mm} of 400");
    }
}
