//! Deterministic PRNG (splitmix64 seeding + xoshiro256**).
//!
//! Replaces the `rand` crate (not in the offline vendor set). Used by the
//! property-test framework, workload generators, and the examples. Fully
//! deterministic given a seed — required for reproducible experiments.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// xoshiro256** next.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Debiased via rejection sampling.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + self.below(span) as i64
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_i64(lo as i64, hi as i64) as usize
    }

    /// Uniform float in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform float in `[lo, hi)`.
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f64() as f32
    }

    /// Standard normal via Box–Muller (enough for test data).
    pub fn normal_f32(&mut self) -> f32 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// A vector of standard-normal float32s (MM test operands).
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32()).collect()
    }

    /// A vector of small exact integers as f32 (exact float MM checks).
    pub fn int_vec_f32(&mut self, n: usize, lo: i64, hi: i64) -> Vec<f32> {
        (0..n).map(|_| self.range_i64(lo, hi) as f32).collect()
    }

    pub fn int_vec_i32(&mut self, n: usize, lo: i64, hi: i64) -> Vec<i32> {
        (0..n).map(|_| self.range_i64(lo, hi) as i32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn range_inclusive_bounds_hit() {
        let mut r = Rng::new(4);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..2000 {
            let v = r.range_i64(-2, 2);
            assert!((-2..=2).contains(&v));
            lo_seen |= v == -2;
            hi_seen |= v == 2;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut r = Rng::new(6);
        let xs: Vec<f64> = (0..20000).map(|_| r.normal_f32() as f64).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
