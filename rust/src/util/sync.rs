//! Poison-recovering lock acquisition, shared by every layer that
//! serves traffic (coordinator shard/router) or backs a serving worker
//! (runtime engine + backends).
//!
//! A thread that panics while holding a `Mutex` poisons it; with bare
//! `.lock().unwrap()` that one crash cascades — every later taker of
//! the lock panics in turn (submitters, the dispatcher, finally
//! `drain()`), so a single worker bug takes the whole shard down. Every
//! critical section in this codebase leaves the protected state
//! consistent at each unlock point (plain queue/map/set mutations, no
//! multi-step invariants spanning an unwind), so recovering the guard
//! is safe and keeps the process serving. The policy is enforced
//! statically: `tools/verify.py` check 8 rejects `.lock().unwrap()` in
//! the serving-path modules, and the concurrency analyzer
//! (`tools/analyze`, `make race-gate`) tracks `lock_clean` acquisitions
//! in its inter-procedural lock graph.
//!
//! Condvar waits recover the same way at their call sites via
//! `unwrap_or_else(PoisonError::into_inner)` — the wait APIs return the
//! guard inside the error, so there is no one-size helper for them.

use std::sync::{Mutex, MutexGuard, PoisonError};

/// Lock `m`, recovering the guard from a poisoned mutex instead of
/// propagating the panic of whichever thread died holding it.
pub fn lock_clean<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn recovers_a_poisoned_mutex() {
        let m = Arc::new(Mutex::new(7u32));
        let poisoner = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _guard = poisoner.lock().unwrap();
            panic!("injected: die holding the lock");
        })
        .join();
        assert!(m.is_poisoned());
        assert_eq!(*lock_clean(&m), 7);
        *lock_clean(&m) = 8;
        assert_eq!(*lock_clean(&m), 8);
    }

    #[test]
    fn plain_lock_still_works() {
        let m = Mutex::new(1i32);
        *lock_clean(&m) += 1;
        assert_eq!(*lock_clean(&m), 2);
    }
}
