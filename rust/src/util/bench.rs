//! `BENCH_*.json` emission for the bench harnesses.
//!
//! Each measuring bench (`serve_throughput`, `prepared_cache`,
//! `cost_model`) records its headline numbers through a
//! [`BenchRecorder`] and writes `BENCH_<name>.json` at the repository
//! root on exit. The committed files are the measured perf trajectory
//! future PRs diff against, so the format is deliberately boring and
//! deterministic:
//!
//! * object keys are sorted ([`Json`] uses a `BTreeMap`), so re-running
//!   a bench produces a byte-stable file apart from the values that
//!   actually changed;
//! * every metric carries its unit next to its value — a reader (or a
//!   CI diff) never has to guess whether `1.86` is seconds or a ratio;
//! * the environment block records what the numbers mean: build mode
//!   (a debug-mode run is marked `debug` and must never be committed as
//!   a baseline), os/arch, and the parallelism the machine offered.
//!
//! Writing is best-effort: a read-only checkout still runs the bench
//! and prints its tables; only the JSON side-channel is skipped (with a
//! note on stderr).

use std::path::PathBuf;

use super::json::Json;

/// Collects metrics for one bench run and writes `BENCH_<name>.json`.
#[derive(Debug)]
pub struct BenchRecorder {
    name: String,
    metrics: Vec<(String, f64, String)>,
    notes: Vec<(String, String)>,
}

impl BenchRecorder {
    pub fn new(name: &str) -> BenchRecorder {
        BenchRecorder { name: name.to_string(), metrics: Vec::new(), notes: Vec::new() }
    }

    /// Record one measurement. `key` is dotted-path style
    /// (`"pure_mm.batched.jobs_per_sec"`); `unit` is human-readable
    /// (`"jobs/s"`, `"ms"`, `"x"`).
    pub fn metric(&mut self, key: &str, value: f64, unit: &str) -> &mut Self {
        self.metrics.push((key.to_string(), value, unit.to_string()));
        self
    }

    /// Record a free-form context note (workload shape, knob settings).
    pub fn note(&mut self, key: &str, value: impl std::fmt::Display) -> &mut Self {
        self.notes.push((key.to_string(), value.to_string()));
        self
    }

    /// The build mode this binary was compiled with. Committed
    /// baselines must say `release`; a `debug` file is a local
    /// experiment, not a trajectory point.
    pub fn build_mode() -> &'static str {
        if cfg!(debug_assertions) {
            "debug"
        } else {
            "release"
        }
    }

    /// Assemble the JSON document (separated from [`Self::write`] so
    /// tests can pin the format without touching the filesystem).
    pub fn to_json(&self) -> Json {
        let metrics = Json::Obj(
            self.metrics
                .iter()
                .map(|(k, v, unit)| {
                    (
                        k.clone(),
                        Json::obj(vec![("value", Json::num(*v)), ("unit", Json::str(unit))]),
                    )
                })
                .collect(),
        );
        let notes =
            Json::Obj(self.notes.iter().map(|(k, v)| (k.clone(), Json::str(v))).collect());
        let parallelism = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0);
        Json::obj(vec![
            ("bench", Json::str(&self.name)),
            ("status", Json::str("measured")),
            (
                "environment",
                Json::obj(vec![
                    ("build_mode", Json::str(Self::build_mode())),
                    ("os", Json::str(std::env::consts::OS)),
                    ("arch", Json::str(std::env::consts::ARCH)),
                    ("available_parallelism", Json::num(parallelism as f64)),
                ]),
            ),
            ("notes", notes),
            ("metrics", metrics),
        ])
    }

    /// Where the file goes: `$EA4RCA_BENCH_DIR` if set, else the crate
    /// root (where the committed baselines live).
    pub fn output_path(&self) -> PathBuf {
        let dir = std::env::var_os("EA4RCA_BENCH_DIR")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")));
        dir.join(format!("BENCH_{}.json", self.name))
    }

    /// Write `BENCH_<name>.json`. Best-effort: failure is a note on
    /// stderr, never a bench abort.
    pub fn write(&self) {
        let path = self.output_path();
        let text = self.to_json().to_string_pretty() + "\n";
        match std::fs::write(&path, text) {
            Ok(()) => println!("wrote {}", path.display()),
            Err(e) => eprintln!("note: could not write {}: {e}", path.display()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_is_deterministic_and_typed() {
        let mut r = BenchRecorder::new("example");
        r.metric("b.second", 2.5, "ms").metric("a.first", 1.0, "jobs/s").note("workers", 4);
        let a = r.to_json().to_string_pretty();
        let b = r.to_json().to_string_pretty();
        assert_eq!(a, b, "same recorder must render byte-identically");
        let parsed = Json::parse(&a).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str(), Some("example"));
        assert_eq!(parsed.get("status").unwrap().as_str(), Some("measured"));
        let metrics = parsed.get("metrics").unwrap().as_obj().unwrap();
        // BTreeMap: keys come out sorted regardless of insertion order
        let keys: Vec<&str> = metrics.keys().map(String::as_str).collect();
        assert_eq!(keys, vec!["a.first", "b.second"]);
        let m = metrics["b.second"].as_obj().unwrap();
        assert_eq!(m["value"].as_f64(), Some(2.5));
        assert_eq!(m["unit"].as_str(), Some("ms"));
        let env = parsed.get("environment").unwrap();
        assert!(matches!(env.get("build_mode").unwrap().as_str(), Some("debug" | "release")));
        assert_eq!(
            parsed.get("notes").unwrap().get("workers").unwrap().as_str(),
            Some("4")
        );
    }

    #[test]
    fn output_path_honours_env_override() {
        // (env vars are process-global; keep the assertion scoped to the
        // default path so parallel tests cannot race on the override)
        let r = BenchRecorder::new("example");
        let p = r.output_path();
        assert!(p.ends_with("BENCH_example.json"), "{}", p.display());
    }
}
