//! In-tree property-testing mini-framework (stand-in for `proptest`,
//! which is not in the offline vendor set — DESIGN.md §1).
//!
//! A property takes a deterministic [`Rng`] and either passes or returns a
//! failure description. The runner executes `cases` seeds; on failure it
//! *shrinks* by replaying with reduced size hints and reports the smallest
//! failing seed/size pair, so failures are reproducible from the printed
//! seed.
//!
//! ```no_run
//! // (no_run: rustdoc test binaries do not inherit the xla rpath)
//! use ea4rca::util::prop::{check, Config};
//! check(Config::default().cases(16), "add commutes", |rng, size| {
//!     let a = rng.range_i64(-(size as i64) - 1, size as i64);
//!     let b = rng.range_i64(-(size as i64) - 1, size as i64);
//!     if a + b == b + a { Ok(()) } else { Err(format!("{a} {b}")) }
//! });
//! ```

use super::rng::Rng;

#[derive(Debug, Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 100, seed: 0xEA4C_A000, max_size: 64 }
    }
}

impl Config {
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
    pub fn max_size(mut self, s: usize) -> Self {
        self.max_size = s;
        self
    }
}

/// Result of a property over one case.
pub type CaseResult = Result<(), String>;

/// Run `prop` over `config.cases` deterministic cases. The `size`
/// parameter grows from 1 to `max_size` across cases so early failures
/// are small. Panics with a reproduction line on failure.
pub fn check<F>(config: Config, name: &str, mut prop: F)
where
    F: FnMut(&mut Rng, usize) -> CaseResult,
{
    for case in 0..config.cases {
        let size = 1 + case * config.max_size / config.cases.max(1);
        let case_seed = config.seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng, size) {
            // Shrink: retry the same seed at smaller sizes; keep the
            // smallest size that still fails.
            let mut smallest = (size, msg.clone());
            let mut lo = 1;
            let mut hi = size;
            while lo < hi {
                let mid = (lo + hi) / 2;
                let mut rng = Rng::new(case_seed);
                match prop(&mut rng, mid) {
                    Err(m) => {
                        smallest = (mid, m);
                        hi = mid;
                    }
                    Ok(()) => lo = mid + 1,
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {case_seed:#x}, \
                 shrunk size {}): {}",
                smallest.0, smallest.1
            );
        }
    }
}

/// Assert-style helper for property bodies.
pub fn ensure(cond: bool, msg: impl FnOnce() -> String) -> CaseResult {
    if cond {
        Ok(())
    } else {
        Err(msg())
    }
}

/// Approximate float comparison for property bodies.
pub fn close(a: f64, b: f64, tol: f64) -> CaseResult {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{a} !~ {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(Config::default().cases(25), "trivial", |_, _| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_name() {
        check(Config::default().cases(5), "always fails", |_, _| {
            Err("nope".into())
        });
    }

    #[test]
    fn shrinks_to_smallest_failing_size() {
        // Property fails for size >= 10; the panic must report size 10.
        let result = std::panic::catch_unwind(|| {
            check(
                Config::default().cases(50).max_size(64),
                "size-threshold",
                |_, size| ensure(size < 10, || format!("size {size}")),
            );
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("shrunk size 10"), "got: {msg}");
    }

    #[test]
    fn sizes_grow() {
        let mut max_seen = 0;
        check(Config::default().cases(64).max_size(32), "size sweep", |_, s| {
            max_seen = max_seen.max(s);
            Ok(())
        });
        assert!(max_seen >= 30, "max size seen {max_seen}");
    }

    #[test]
    fn close_accepts_and_rejects() {
        assert!(close(1.0, 1.0 + 1e-9, 1e-6).is_ok());
        assert!(close(1.0, 2.0, 1e-6).is_err());
    }
}
