//! Aligned ASCII table printer — the bench harnesses use this to emit the
//! same rows the paper's tables report.

/// A simple column-aligned table with a title, header row, and body rows.
#[derive(Debug, Default, Clone)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: build a row from display-ables.
    pub fn row_disp(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = width[i].max(h.chars().count());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let sep: String = width
            .iter()
            .map(|w| format!("+{}", "-".repeat(w + 2)))
            .collect::<String>()
            + "+";
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!("| {:>w$} ", c, w = width[i]));
            }
            line.push('|');
            line
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("{}\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with a sensible number of significant digits for tables.
pub fn fmt_f(v: f64, decimals: usize) -> String {
    format!("{:.*}", decimals, v)
}

/// Format a large count in scientific notation like the paper's 9.43x10^7.
pub fn fmt_sci(v: f64) -> String {
    if v == 0.0 {
        return "0".to_string();
    }
    let exp = v.abs().log10().floor() as i32;
    let mant = v / 10f64.powi(exp);
    format!("{:.2}e{}", mant, exp)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["a", "long-col"]);
        t.row(&["1".into(), "2".into()]);
        t.row(&["100".into(), "x".into()]);
        let r = t.render();
        assert!(r.contains("| long-col |"));
        // every body line has the same width
        let lines: Vec<&str> = r.lines().skip(1).collect();
        let w = lines[0].len();
        assert!(lines.iter().all(|l| l.len() == w), "{r}");
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn sci_format() {
        assert_eq!(fmt_sci(9.43e7), "9.43e7");
        assert_eq!(fmt_sci(0.0), "0");
        assert_eq!(fmt_sci(1.0), "1.00e0");
    }
}
