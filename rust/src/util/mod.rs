//! Utility substrates built in-tree because the offline vendor set only
//! contains the `xla` crate closure (DESIGN.md §1 substitution table):
//!
//! * [`json`]  — a small recursive-descent JSON parser + writer (replaces
//!   `serde_json`) used for the artifact manifest and graph configs.
//! * [`rng`]   — deterministic xorshift/splitmix PRNG (replaces `rand`).
//! * [`prop`]  — a property-testing mini-framework with generators and
//!   failure-case shrinking (replaces `proptest`).
//! * [`table`] — aligned ASCII table printer for the bench harnesses.
//! * [`stats`] — mean/stddev/percentile helpers for measurements.
//! * [`cli`]   — tiny flag/option parser (replaces `clap`).
//! * [`bench`] — `BENCH_*.json` emission for the measuring benches.
//! * [`sync`]  — poison-recovering lock helper shared by the serving
//!   path (coordinator + runtime backends).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod table;
