//! Tiny command-line parser (stand-in for `clap`, not vendored offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.
//! Each binary declares its options up front so `--help` is generated.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

#[derive(Debug, Default, Clone)]
pub struct Cli {
    pub program: String,
    pub about: String,
    specs: Vec<OptSpec>,
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    pub positional: Vec<String>,
}

impl Cli {
    pub fn new(program: &str, about: &str) -> Cli {
        Cli {
            program: program.to_string(),
            about: about.to_string(),
            ..Default::default()
        }
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.specs.push(OptSpec { name, help, takes_value: true, default: Some(default) });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(OptSpec { name, help, takes_value: false, default: None });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for spec in &self.specs {
            let val = if spec.takes_value {
                format!(" <value>{}", spec.default.map(|d| format!(" [default: {d}]")).unwrap_or_default())
            } else {
                String::new()
            };
            s.push_str(&format!("  --{}{}\n      {}\n", spec.name, val, spec.help));
        }
        s.push_str("  --help\n      print this help\n");
        s
    }

    /// Parse an argv slice (without the program name). Returns an error
    /// string on unknown or malformed options; the caller decides whether
    /// to print usage and exit.
    pub fn parse(mut self, args: &[String]) -> Result<Cli, String> {
        let mut i = 0;
        while i < args.len() {
            let arg = &args[i];
            if arg == "--help" || arg == "-h" {
                return Err(self.usage());
            }
            if let Some(stripped) = arg.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| format!("unknown option --{name}\n\n{}", self.usage()))?
                    .clone();
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{name} needs a value"))?
                        }
                    };
                    self.values.insert(name, val);
                } else {
                    if inline_val.is_some() {
                        return Err(format!("--{name} takes no value"));
                    }
                    self.flags.insert(name, true);
                }
            } else {
                self.positional.push(arg.clone());
            }
            i += 1;
        }
        Ok(self)
    }

    /// Parse the real process args; print help/error and exit on failure.
    pub fn parse_env(self) -> Cli {
        let args: Vec<String> = std::env::args().skip(1).collect();
        match self.parse(&args) {
            Ok(cli) => cli,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(2);
            }
        }
    }

    pub fn get(&self, name: &str) -> String {
        if let Some(v) = self.values.get(name) {
            return v.clone();
        }
        self.specs
            .iter()
            .find(|s| s.name == name && s.takes_value)
            .and_then(|s| s.default)
            .unwrap_or_else(|| panic!("option --{name} not declared"))
            .to_string()
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} must be an integer"))
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} must be a number"))
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_options_and_flags() {
        let cli = Cli::new("t", "test")
            .opt("size", "768", "problem size")
            .flag("verbose", "chatty")
            .parse(&argv(&["--size", "1536", "--verbose", "pos1"]))
            .unwrap();
        assert_eq!(cli.get_usize("size"), 1536);
        assert!(cli.has("verbose"));
        assert_eq!(cli.positional, vec!["pos1"]);
    }

    #[test]
    fn equals_form() {
        let cli = Cli::new("t", "")
            .opt("mode", "a", "")
            .parse(&argv(&["--mode=b"]))
            .unwrap();
        assert_eq!(cli.get("mode"), "b");
    }

    #[test]
    fn defaults_apply() {
        let cli = Cli::new("t", "").opt("size", "42", "").parse(&[]).unwrap();
        assert_eq!(cli.get_usize("size"), 42);
    }

    #[test]
    fn unknown_option_errors() {
        let err = Cli::new("t", "").parse(&argv(&["--nope"])).unwrap_err();
        assert!(err.contains("unknown option"));
    }

    #[test]
    fn help_returns_usage() {
        let err = Cli::new("prog", "about text")
            .opt("x", "1", "the x")
            .parse(&argv(&["--help"]))
            .unwrap_err();
        assert!(err.contains("prog — about text"));
        assert!(err.contains("--x"));
    }

    #[test]
    fn missing_value_errors() {
        let err = Cli::new("t", "")
            .opt("k", "", "")
            .parse(&argv(&["--k"]))
            .unwrap_err();
        assert!(err.contains("needs a value"));
    }
}
