//! Tiny command-line parser (stand-in for `clap`, not vendored offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.
//! Each binary declares its options up front so `--help` is generated.
//!
//! All parsing and value access is `Result`-based: malformed or missing
//! flags produce a [`CliError`] with a readable message (the `ea4rca`
//! binary turns those into exit code 2 — no panics, no backtraces).

use std::collections::BTreeMap;
use std::fmt;

/// A usage error (or a help request). The binary prints `msg` and exits
/// with code 2 (or 0 for `help`).
#[derive(Debug, Clone)]
pub struct CliError {
    pub msg: String,
    /// True when the user asked for `--help` — not an error.
    pub help: bool,
}

impl CliError {
    fn new(msg: impl Into<String>) -> CliError {
        CliError { msg: msg.into(), help: false }
    }
}

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for CliError {}

#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

#[derive(Debug, Default, Clone)]
pub struct Cli {
    pub program: String,
    pub about: String,
    specs: Vec<OptSpec>,
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    pub positional: Vec<String>,
}

impl Cli {
    pub fn new(program: &str, about: &str) -> Cli {
        Cli {
            program: program.to_string(),
            about: about.to_string(),
            ..Default::default()
        }
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.specs.push(OptSpec { name, help, takes_value: true, default: Some(default) });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(OptSpec { name, help, takes_value: false, default: None });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for spec in &self.specs {
            let val = if spec.takes_value {
                format!(" <value>{}", spec.default.map(|d| format!(" [default: {d}]")).unwrap_or_default())
            } else {
                String::new()
            };
            s.push_str(&format!("  --{}{}\n      {}\n", spec.name, val, spec.help));
        }
        s.push_str("  --help\n      print this help\n");
        s
    }

    /// Parse an argv slice (without the program name). Returns a
    /// [`CliError`] on unknown or malformed options (or on `--help`,
    /// with `help = true`); the caller decides how to exit.
    pub fn parse(mut self, args: &[String]) -> Result<Cli, CliError> {
        let mut i = 0;
        while i < args.len() {
            let arg = &args[i];
            if arg == "--help" || arg == "-h" {
                return Err(CliError { msg: self.usage(), help: true });
            }
            if let Some(stripped) = arg.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| {
                        CliError::new(format!("unknown option --{name}\n\n{}", self.usage()))
                    })?
                    .clone();
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| CliError::new(format!("--{name} needs a value")))?
                        }
                    };
                    self.values.insert(name, val);
                } else {
                    if inline_val.is_some() {
                        return Err(CliError::new(format!("--{name} takes no value")));
                    }
                    self.flags.insert(name, true);
                }
            } else {
                self.positional.push(arg.clone());
            }
            i += 1;
        }
        Ok(self)
    }

    /// Parse the real process args; print help/error and exit on failure
    /// (0 for help, 2 for usage errors).
    pub fn parse_env(self) -> Cli {
        let args: Vec<String> = std::env::args().skip(1).collect();
        match self.parse(&args) {
            Ok(cli) => cli,
            Err(e) if e.help => {
                print!("{}", e.msg);
                std::process::exit(0);
            }
            Err(e) => {
                eprintln!("{e}");
                std::process::exit(2);
            }
        }
    }

    /// The string value of a declared option (given or default).
    pub fn get(&self, name: &str) -> Result<String, CliError> {
        if let Some(v) = self.values.get(name) {
            return Ok(v.clone());
        }
        self.specs
            .iter()
            .find(|s| s.name == name && s.takes_value)
            .and_then(|s| s.default)
            .map(str::to_string)
            .ok_or_else(|| CliError::new(format!("option --{name} not declared")))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, CliError> {
        let v = self.get(name)?;
        v.parse().map_err(|_| {
            CliError::new(format!("--{name} must be a non-negative integer, got {v:?}"))
        })
    }

    pub fn get_u64(&self, name: &str) -> Result<u64, CliError> {
        let v = self.get(name)?;
        v.parse().map_err(|_| {
            CliError::new(format!("--{name} must be a non-negative integer, got {v:?}"))
        })
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, CliError> {
        let v = self.get(name)?;
        v.parse()
            .map_err(|_| CliError::new(format!("--{name} must be a number, got {v:?}")))
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_options_and_flags() {
        let cli = Cli::new("t", "test")
            .opt("size", "768", "problem size")
            .flag("verbose", "chatty")
            .parse(&argv(&["--size", "1536", "--verbose", "pos1"]))
            .unwrap();
        assert_eq!(cli.get_usize("size").unwrap(), 1536);
        assert!(cli.has("verbose"));
        assert_eq!(cli.positional, vec!["pos1"]);
    }

    #[test]
    fn equals_form() {
        let cli = Cli::new("t", "")
            .opt("mode", "a", "")
            .parse(&argv(&["--mode=b"]))
            .unwrap();
        assert_eq!(cli.get("mode").unwrap(), "b");
    }

    #[test]
    fn defaults_apply() {
        let cli = Cli::new("t", "").opt("size", "42", "").parse(&[]).unwrap();
        assert_eq!(cli.get_usize("size").unwrap(), 42);
    }

    #[test]
    fn u64_values_parse_and_reject() {
        let cli = Cli::new("t", "")
            .opt("seed", "1", "")
            .parse(&argv(&["--seed", "18446744073709551615"]))
            .unwrap();
        assert_eq!(cli.get_u64("seed").unwrap(), u64::MAX);
        let cli = Cli::new("t", "")
            .opt("seed", "1", "")
            .parse(&argv(&["--seed", "-3"]))
            .unwrap();
        assert!(cli.get_u64("seed").is_err());
    }

    #[test]
    fn unknown_option_errors() {
        let err = Cli::new("t", "").parse(&argv(&["--nope"])).unwrap_err();
        assert!(err.to_string().contains("unknown option"));
        assert!(!err.help);
    }

    #[test]
    fn help_returns_usage_marked_as_help() {
        let err = Cli::new("prog", "about text")
            .opt("x", "1", "the x")
            .parse(&argv(&["--help"]))
            .unwrap_err();
        assert!(err.help);
        assert!(err.to_string().contains("prog — about text"));
        assert!(err.to_string().contains("--x"));
    }

    #[test]
    fn missing_value_errors() {
        let err = Cli::new("t", "")
            .opt("k", "", "")
            .parse(&argv(&["--k"]))
            .unwrap_err();
        assert!(err.to_string().contains("needs a value"));
    }

    #[test]
    fn malformed_values_are_errors_not_panics() {
        let cli = Cli::new("t", "")
            .opt("size", "768", "")
            .opt("rate", "1.5", "")
            .parse(&argv(&["--size", "banana", "--rate", "fast"]))
            .unwrap();
        let e = cli.get_usize("size").unwrap_err();
        assert!(e.to_string().contains("must be a non-negative integer"), "{e}");
        assert!(e.to_string().contains("banana"), "{e}");
        let e = cli.get_f64("rate").unwrap_err();
        assert!(e.to_string().contains("must be a number"), "{e}");
    }

    #[test]
    fn undeclared_option_is_an_error() {
        let cli = Cli::new("t", "").parse(&[]).unwrap();
        assert!(cli.get("ghost").is_err());
        assert!(cli.get_usize("ghost").is_err());
    }
}
