//! Minimal JSON: recursive-descent parser + pretty writer.
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null). Numbers are kept as `f64`; the integer
//! accessors check exactness. Good enough for the artifact manifest and
//! the graph-configuration files — not a general-purpose speed demon.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Object keys are sorted (BTreeMap) so output is
/// deterministic — handy for golden tests of the code generator.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters after value"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 9e15 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` with a readable error path for config validation.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    // -- writer ------------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !o.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.skip_ws();
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of plain utf-8 bytes
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

/// Convenience constructors used by the manifest writer and codegen.
impl Json {
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::str("hi"));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_i64(), Some(1));
        assert_eq!(a[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"k\" 1}").is_err());
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"arr":[1,2.5,"s"],"nested":{"t":true,"n":null}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string_compact();
        assert_eq!(Json::parse(&out).unwrap(), v);
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::str("a\"b\\c\nd\te\u{1}");
        let out = v.to_string_compact();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse(r#""é中""#).unwrap(),
            Json::str("é中")
        );
    }

    #[test]
    fn integer_accessors_guard_fractions() {
        assert_eq!(Json::Num(1.5).as_i64(), None);
        assert_eq!(Json::Num(-2.0).as_usize(), None);
        assert_eq!(Json::Num(7.0).as_usize(), Some(7));
    }
}
