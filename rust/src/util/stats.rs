//! Small statistics helpers for the bench harnesses (stand-in for
//! criterion's estimators).

/// Summary of a sample of measurements.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
}

pub fn summarize(samples: &[f64]) -> Summary {
    assert!(!samples.is_empty(), "no samples");
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = if n > 1 {
        samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    let mut sorted = samples.to_vec();
    // A NaN sample (e.g. a poisoned latency) must not panic the sort
    // (the old partial_cmp().unwrap()) or poison the low-end stats:
    // canonicalize to positive NaN first — runtime arithmetic can
    // produce -NaN, which total_cmp would order *before* every real
    // number — so every NaN sorts to the end, past max.
    for v in &mut sorted {
        if v.is_nan() {
            *v = f64::NAN;
        }
    }
    sorted.sort_by(f64::total_cmp);
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        p50: percentile(&sorted, 50.0),
        p95: percentile(&sorted, 95.0),
    }
}

/// Linear-interpolation percentile over a pre-sorted slice.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    debug_assert!(
        sorted
            .windows(2)
            .all(|w| w[0].total_cmp(&w[1]) != std::cmp::Ordering::Greater),
        "percentile input must be sorted (total order)"
    );
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Time a closure `n` times, returning per-call seconds.
pub fn time_n<F: FnMut()>(n: usize, mut f: F) -> Vec<f64> {
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let t0 = std::time::Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64());
    }
    out
}

/// Warm up then measure: the standard bench loop shape.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    summarize(&time_n(iters, f))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = summarize(&[2.0, 2.0, 2.0]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p50, 2.0);
    }

    #[test]
    fn summary_moments() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.std - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.p50 - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert_eq!(percentile(&v, 0.0), 0.0);
        assert_eq!(percentile(&v, 100.0), 10.0);
        assert_eq!(percentile(&v, 50.0), 5.0);
    }

    #[test]
    fn summarize_survives_nan_samples() {
        // regression: partial_cmp().unwrap() used to panic the sort on
        // any NaN sample; total_cmp sends NaN to the end instead
        let s = summarize(&[2.0, f64::NAN, 1.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.p50, 2.0); // sorted: [1, 2, NaN]
        assert!(s.max.is_nan());
        assert!(s.mean.is_nan());
        // negative-sign NaN (what 0.0/0.0 actually produces on x86)
        // must also land at the end, not poison min/p50
        let s = summarize(&[2.0, -f64::NAN, 1.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.p50, 2.0);
        assert!(s.max.is_nan());
        // all-NaN is also survivable
        let s = summarize(&[f64::NAN, f64::NAN]);
        assert!(s.min.is_nan() && s.max.is_nan());
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "must be sorted")]
    fn percentile_rejects_unsorted_input_in_debug() {
        percentile(&[3.0, 1.0, 2.0], 50.0);
    }

    #[test]
    fn time_n_counts() {
        let samples = time_n(5, || {});
        assert_eq!(samples.len(), 5);
        assert!(samples.iter().all(|s| *s >= 0.0));
    }
}
