//! Vitis Libraries single-core FFT baseline (paper Table 10).
//!
//! The official library implementation runs one AIE core per FFT
//! (<1% utilisation); the paper reports 713 826.80 tasks/s at 1024
//! points and uses it as the 1024-point speed reference (the 0.20x row —
//! the CCC2023 FFT was *slower* than Vitis at 1024).

use crate::sim::core::fft_ops;
use crate::sim::params::HwParams;

use super::BaselineRow;

pub fn row() -> BaselineRow {
    BaselineRow {
        design: "Vitis[1]",
        app: "FFT",
        problem: "1024",
        dtype: "CInt16",
        tasks_per_sec: Some(713_826.80),
        gops: None,
        efficiency: None,
        efficiency_unit: "TPS/W",
    }
}

/// Simulated single-core Vitis-like FFT: all log2(N) stages on one core,
/// dual stream ports with ping-pong window buffers, so communication
/// overlaps compute (the library's aggregated-window design).
pub fn simulated_tasks_per_sec(p: &HwParams, n: usize) -> f64 {
    let compute = fft_ops(n) / p.cint16_ops_per_cycle / p.aie_clock_hz
        + p.kernel_setup_cycles / p.aie_clock_hz;
    let comm = (2 * n * 4) as f64 / (2.0 * p.stream_bytes_per_sec);
    1.0 / compute.max(comm)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simulated_vitis_near_published() {
        let p = HwParams::vck5000();
        let tps = simulated_tasks_per_sec(&p, 1024);
        let published = 713_826.80;
        assert!((tps - published).abs() / published < 0.35, "{tps}");
    }

    #[test]
    fn single_core_much_slower_than_ea4rca() {
        let p = HwParams::vck5000();
        // EA4RCA 8-PU 1024-pt: ~2.3M tasks/s
        assert!(simulated_tasks_per_sec(&p, 1024) < 1.0e6);
    }
}
