//! SOTA baselines for Table 10.
//!
//! The paper compares against published numbers from CHARM [47] (MM),
//! the CCC2023 challenge winners (Filter2D, FFT) and the Vitis library
//! single-core FFT. Those systems are closed testbeds we cannot run, so
//! each baseline here carries (a) the paper's published figures as
//! ground truth for the ratio computation — exactly what the paper
//! itself does in Table 10 — and (b) a simulated "why it is slower"
//! model on our substrate (utilisation-limited configurations of the
//! same framework primitives) used by the ablation benches.

pub mod ccc2023;
pub mod charm;
pub mod vitis;

/// A published baseline row (the paper's Table 10 left side).
#[derive(Debug, Clone)]
pub struct BaselineRow {
    pub design: &'static str,
    pub app: &'static str,
    pub problem: &'static str,
    pub dtype: &'static str,
    pub tasks_per_sec: Option<f64>,
    pub gops: Option<f64>,
    /// GOPS/W for MM-class rows, TPS/W for FFT rows.
    pub efficiency: Option<f64>,
    pub efficiency_unit: &'static str,
}

/// All published baseline rows used by Table 10.
pub fn all_rows() -> Vec<BaselineRow> {
    let mut v = vec![charm::row()];
    v.extend(ccc2023::rows());
    v.push(vitis::row());
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_cover_all_apps() {
        let rows = all_rows();
        for app in ["MM", "Filter2D", "FFT"] {
            assert!(rows.iter().any(|r| r.app == app), "missing {app}");
        }
        assert!(rows.len() >= 6);
    }

    #[test]
    fn charm_numbers() {
        let c = charm::row();
        assert_eq!(c.gops, Some(3270.0));
        assert_eq!(c.efficiency, Some(62.40));
    }
}
