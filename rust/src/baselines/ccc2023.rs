//! CCC2023 challenge baselines (Filter2D champion, FFT runner-up).
//!
//! Published figures from the paper's Table 10. Their designs use small
//! fractions of the AIE array (13.5% / 2.25%), which is the whole point
//! of the comparison: EA4RCA's contribution is organising *many* cores.

use crate::sim::core::{filter_ops, KernelClass};
use crate::sim::params::HwParams;

use super::BaselineRow;

pub fn rows() -> Vec<BaselineRow> {
    vec![
        BaselineRow {
            design: "CCC2023[3]",
            app: "Filter2D",
            problem: "4K (3x3)",
            dtype: "Int32",
            tasks_per_sec: Some(289.32),
            gops: Some(39.22),
            efficiency: Some(5.04),
            efficiency_unit: "GOPS/W",
        },
        BaselineRow {
            design: "CCC2023[3]",
            app: "Filter2D",
            problem: "8K (3x3)",
            dtype: "Int32",
            tasks_per_sec: Some(98.78),
            gops: Some(59.72),
            efficiency: Some(7.68),
            efficiency_unit: "GOPS/W",
        },
        BaselineRow {
            design: "CCC2023[3]",
            app: "FFT",
            problem: "1024",
            dtype: "CInt16",
            tasks_per_sec: Some(142_857.14),
            gops: None,
            efficiency: Some(26_396.37),
            efficiency_unit: "TPS/W",
        },
        BaselineRow {
            design: "CCC2023[3]",
            app: "FFT",
            problem: "4096",
            dtype: "CInt16",
            tasks_per_sec: Some(135_685.21),
            gops: None,
            efficiency: Some(22_796.57),
            efficiency_unit: "TPS/W",
        },
        BaselineRow {
            design: "CCC2023[3]",
            app: "FFT",
            problem: "8192",
            dtype: "CInt16",
            tasks_per_sec: Some(106_382.97),
            gops: None,
            efficiency: Some(16_396.88),
            efficiency_unit: "TPS/W",
        },
    ]
}

/// Simulated CCC2023-champion-like Filter2D: 13.5% of the array (54
/// cores), stream-interleaved service (no phase aggregation), 3x3 taps.
pub fn simulated_filter2d_gops(p: &HwParams) -> f64 {
    let cores = 54.0;
    let tile_pixels = 32.0 * 32.0;
    let ops = filter_ops(1024, 3);
    let compute = ops / KernelClass::I32Mac.ops_per_cycle(p) / p.aie_clock_hz
        + p.kernel_setup_cycles / p.aie_clock_hz;
    // stream-interleaved pixel feed: every 64 B grain stalls the pipe
    let bytes = tile_pixels + tile_pixels; // 8-bit in + out
    let grains = bytes / 64.0;
    let comm = bytes / p.stream_bytes_per_sec
        + grains * p.stream_interrupt_stall_cycles / p.aie_clock_hz;
    cores * ops / (compute + comm) / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn five_rows() {
        assert_eq!(rows().len(), 5);
    }

    #[test]
    fn simulated_filter2d_is_low_utilisation() {
        // The champion design lands ~40-60 GOPS (paper: 39-60), far under
        // EA4RCA's ~1000.
        let p = HwParams::vck5000();
        let g = simulated_filter2d_gops(&p);
        assert!(g > 20.0 && g < 120.0, "{g}");
    }
}
