//! CHARM [47] — the MM SOTA on VCK5000 (FPGA'23).
//!
//! Published figures (paper Table 10): 3270 GOPS float MM at
//! 62.40 GOPS/W using 384 AIE cores. CHARM's design point differs from
//! EA4RCA's in the data path: its dedicated-accelerator composition
//! leaves less PLIO-level reuse, modelled here as a lower effective duty
//! on the same PU primitive (used by `benches/ablate_aggregation.rs` to
//! show *why* the EA4RCA schedule edges it out).

use crate::sim::params::HwParams;

use super::BaselineRow;

pub fn row() -> BaselineRow {
    BaselineRow {
        design: "CHARM[47]",
        app: "MM",
        problem: "N/A",
        dtype: "Float",
        tasks_per_sec: None,
        gops: Some(3270.0),
        efficiency: Some(62.40),
        efficiency_unit: "GOPS/W",
    }
}

/// Simulated CHARM-like configuration on our substrate: same 384 cores,
/// stream-fed operands (no DMA-aggregated communication phases), which
/// is the paper's Table 2 method-2 regime.
pub fn simulated_gops(p: &HwParams) -> f64 {
    let cores = 384.0;
    // per 32^3 task: ideal compute + stream-fed operand time
    let compute = 65536.0 / p.f32_ops_per_cycle / p.aie_clock_hz
        + p.kernel_setup_cycles / p.aie_clock_hz;
    // 5/8 of the stream time is exposed (partial double-buffering in
    // CHARM's dataflow; calibrated to its published 3270 GOPS)
    let stream = 12288.0 / p.stream_bytes_per_sec * 0.625;
    let per_task = compute + stream;
    cores * 65536.0 / per_task / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simulated_charm_lands_near_published() {
        let p = HwParams::vck5000();
        let g = simulated_gops(&p);
        assert!((g - 3270.0).abs() / 3270.0 < 0.15, "{g}");
    }

    #[test]
    fn ea4rca_beats_simulated_charm() {
        // the MM accelerator's 3421 GOPS must exceed the baseline model
        let p = HwParams::vck5000();
        assert!(simulated_gops(&p) < 3421.0);
    }
}
