//! `ea4rca` — the leader binary: CLI over the framework.
//!
//! Every subcommand routes through the design-entry facade
//! (`ea4rca::api`): configs parse into a `Design` (the JSON frontend),
//! `run`/`sweep` report through `Design::report`, `generate`/`fuse`
//! drive the code generator off the same object, `exec` takes the
//! design's warmed runtime, and `serve` deploys the design catalogue as
//! a `Deployment`.
//!
//! Subcommands:
//!   run       — simulate an accelerator configuration and print its row
//!   exec      — route real task data through the runtime (numerics)
//!   serve     — leader/worker request serving over per-worker runtimes
//!   generate  — run the AIE Graph Code Generator on a config file
//!   lint      — static design-rule checker over configs/designs
//!   resources — print the Table 5 resource-utilisation table
//!   info      — backend platform + artifact inventory
//!
//! The execution backend is selected with `--backend interp|sim|pjrt`
//! on `run`/`serve` (or `EA4RCA_BACKEND` for every command; the flag
//! wins). Default: the pure-Rust interpreter, which needs no artifacts
//! on disk and no native libraries. `sim` runs the same numerics plus
//! the event-driven AIE cost model, attaching predicted latency/energy
//! to every result.
//!
//! Exit codes: 0 success, 1 runtime error, 2 usage error.

use anyhow::{bail, Result};

use ea4rca::api::{self, designs, DeployOptions, Deployment, Design};
use ea4rca::apps::{fft, filter2d, mm, mmt, table5_usage};
use ea4rca::report;
use ea4rca::runtime::{BackendKind, Manifest, Runtime, Tensor};
use ea4rca::sim::params::HwParams;
use ea4rca::util::cli::{Cli, CliError};
use ea4rca::util::rng::Rng;
use ea4rca::util::table::Table;

fn main() {
    match real_main() {
        Ok(()) => {}
        Err(e) => {
            // Usage problems (bad flags, --help) are not runtime errors:
            // help prints to stdout and exits 0, misuse exits 2.
            if let Some(cli_err) = e.downcast_ref::<CliError>() {
                if cli_err.help {
                    print!("{}", cli_err.msg);
                    std::process::exit(0);
                }
                eprintln!("error: {cli_err}");
                std::process::exit(2);
            }
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}

fn usage() -> String {
    "ea4rca <run|exec|serve|generate|lint|resources|info> [options]\n\
     \n\
     ea4rca run --app mm --size 768 --pus 6 [--trace] [--backend interp|sim|pjrt]\n\
     ea4rca run --app filter2d --height 3480 --width 2160 --pus 44\n\
     ea4rca run --app fft --size 1024 --pus 8 --tasks 4096\n\
     ea4rca run --app mmt --iters 20000\n\
     ea4rca exec --app mm --size 256 --seed 7\n\
     ea4rca serve --workers 4 --jobs 256 --mix mm-heavy --batch 8 --linger-us 200\n\
     ea4rca serve --backend sim                   (cost-model-aware serving: predicted latency/energy per result)\n\
     ea4rca serve --rate 2000 --queue-cap 128     (open-loop arrivals, shed on saturation)\n\
     ea4rca serve --no-warm                       (cold caches: A/B the prepared-artifact warm-up)\n\
     ea4rca serve --shards 2 --workers 2          (shard cluster: cost-weighted placement across arrays)\n\
     ea4rca sweep --table 6|7|8|9            (regenerate a paper table)\n\
     ea4rca generate --config configs/mm.json --out generated/mm\n\
     ea4rca fuse --configs configs/fft.json,configs/mm_small.json --out generated/fused\n\
     ea4rca lint --all                       (design-rule check configs/, the catalogue, the serving shape)\n\
     ea4rca lint --config configs/mm.json\n\
     ea4rca lint --app mm                    (also: filter2d | fft | mmt)\n\
     ea4rca resources\n\
     ea4rca info\n\
     \n\
     backend precedence: --backend flag > EA4RCA_BACKEND env > interp (default)\n"
        .to_string()
}

/// Resolve the execution backend for a command: the `--backend` flag
/// when given, else `$EA4RCA_BACKEND`, else the interpreter.
fn backend_from(cli: &Cli) -> Result<BackendKind> {
    let v = cli.get("backend")?;
    if v.is_empty() {
        return BackendKind::from_env();
    }
    match BackendKind::parse(&v) {
        Ok(kind) => Ok(kind),
        Err(_) => Err(CliError {
            msg: format!("--backend must be interp | sim | pjrt, got {v:?}"),
            help: false,
        }
        .into()),
    }
}

fn real_main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first().cloned() else {
        print!("{}", usage());
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "run" => cmd_run(rest),
        "exec" => cmd_exec(rest),
        "serve" => cmd_serve(rest),
        "sweep" => cmd_sweep(rest),
        "generate" => cmd_generate(rest),
        "lint" => cmd_lint(rest),
        "fuse" => cmd_fuse(rest),
        "resources" => cmd_resources(),
        "info" => cmd_info(),
        "--help" | "-h" | "help" => {
            print!("{}", usage());
            Ok(())
        }
        other => Err(CliError {
            msg: format!("unknown command {other:?}\n\n{}", usage()),
            help: false,
        }
        .into()),
    }
}

fn cmd_run(args: &[String]) -> Result<()> {
    let cli = Cli::new("ea4rca run", "simulate an accelerator configuration")
        .opt("app", "mm", "mm | filter2d | fft | mmt")
        .opt("size", "768", "MM edge / FFT points")
        .opt("height", "3480", "Filter2D frame height")
        .opt("width", "2160", "Filter2D frame width")
        .opt("pus", "6", "active PU quantity")
        .opt("tasks", "4096", "FFT batch size")
        .opt("iters", "20000", "MM-T chain iterations")
        .opt(
            "backend",
            "",
            "numeric cross-check backend: interp | sim | pjrt \
             (flag wins over EA4RCA_BACKEND; default interp)",
        )
        .flag("trace", "record + print the phase timeline")
        .parse(args)?;

    let p = HwParams::vck5000();
    let trace = cli.has("trace");
    // validate the backend choice up front: a typo'd --backend must be a
    // usage error before the simulation runs, not after
    let backend = backend_from(&cli)?;
    let app = cli.get("app")?;
    // every app routes through the design facade: the `run` paths call
    // Design::report under the hood, and the cross-check below reuses
    // the same catalogue design for its runtime + artifact
    let report = match app.as_str() {
        "mm" => mm::run(&p, cli.get_usize("size")?, cli.get_usize("pus")?, trace)?,
        "filter2d" => filter2d::run(
            &p,
            cli.get_usize("height")?,
            cli.get_usize("width")?,
            cli.get_usize("pus")?,
            trace,
        )?,
        "fft" => {
            match fft::run(
                &p,
                cli.get_usize("size")?,
                cli.get_usize("pus")?,
                cli.get_usize("tasks")? as u64,
                trace,
            )? {
                Some(r) => r,
                None => {
                    println!(
                        "N/A — {} points exceed the AIE core memory of {} PUs (Table 8)",
                        cli.get("size")?,
                        cli.get("pus")?
                    );
                    return Ok(());
                }
            }
        }
        "mmt" => mmt::run(&p, cli.get_usize("iters")? as u64, trace)?,
        other => {
            return Err(CliError {
                msg: format!("unknown app {other:?}\n\n{}", usage()),
                help: false,
            }
            .into())
        }
    };
    let design = designs::for_app(&app, cli.get_usize("size")?)?;

    println!("{}", report.label);
    println!("  time        : {:.3} ms", report.time_secs * 1e3);
    println!("  tasks/sec   : {:.2} ({})", report.tasks_per_sec, report::tasks_sci(report.tasks_per_sec));
    println!("  GOPS        : {:.2}", report.gops);
    println!("  GOPS/AIE    : {:.3} over {} cores", report.gops_per_aie, report.active_aie);
    println!("  power       : {:.2} W", report.power_w);
    println!("  GOPS/W      : {:.2}", report.gops_per_w);
    println!("  TPS/W       : {:.2}", report.tasks_per_sec_per_w);
    println!("  duty        : {:.3}", report.compute_duty);
    println!("  DDR         : {:.2} GB/s (queue {:.1} us)",
        report.ddr_gbps, report.sim.ddr_queue_secs * 1e6);
    if trace {
        let horizon = report.sim.trace.horizon_ps().min(HwParams::ps(1e-3));
        println!("\n{}", report.sim.trace.render(100, 0, horizon.max(1)));
    }

    // Unified-pipeline cross-check: push one representative serving job
    // of this design through the runtime on the selected backend and
    // line its measured per-job cost up against the AIE cost model
    // (when the backend carries one). Timing-model and numerics paths,
    // one command, one Design.
    match cross_check(backend, &design) {
        Ok(line) => println!("{line}"),
        Err(e) => println!("  x-check     : skipped ({e:#})"),
    }
    Ok(())
}

/// Execute one seeded job of `design`'s artifact on `kind`, reporting
/// measured (and, on a cost-model backend, predicted) per-job cost.
fn cross_check(kind: BackendKind, design: &Design) -> Result<String> {
    let rt = design.runtime_with(kind, Manifest::default_dir())?;
    let artifact = design.artifact();
    let meta = rt.manifest().get(artifact)?;
    let inputs = ea4rca::workload::seeded_inputs(meta, &mut Rng::new(7));
    let t0 = std::time::Instant::now();
    rt.execute(artifact, &inputs)?;
    let measured = t0.elapsed().as_secs_f64();
    let mut line = format!(
        "  x-check     : {artifact} via {} backend — measured {:.3} ms/job",
        rt.backend_kind().name(),
        measured * 1e3
    );
    if let Some(p) = rt.predict(artifact, 1) {
        line.push_str(&format!(
            " | predicted {:.3} ms, {:.3} mJ on the AIE (cost model)",
            p.latency_secs * 1e3,
            p.energy_j * 1e3
        ));
    }
    Ok(line)
}

fn cmd_exec(args: &[String]) -> Result<()> {
    let cli = Cli::new("ea4rca exec", "run real task data through the runtime")
        .opt("app", "mm", "mm | filter2d | fft | mmt")
        .opt("size", "256", "MM edge (multiple of 128) / FFT points")
        .opt("seed", "7", "workload RNG seed")
        .parse(args)?;
    let app = cli.get("app")?;
    // the facade hands out the runtime: the app's Design knows its
    // artifact and warms it (backend from $EA4RCA_BACKEND as before).
    // An unknown app or a bad FFT size stays a usage error (exit 2).
    let design = designs::for_app(&app, cli.get_usize("size")?).map_err(|e| CliError {
        msg: format!("{e:#}\n\n{}", usage()),
        help: false,
    })?;
    let rt = design.runtime()?;
    println!("backend: {}", rt.platform());
    let mut rng = Rng::new(cli.get_u64("seed")?);
    match app.as_str() {
        "mm" => {
            let n = cli.get_usize("size")?;
            let a = rng.normal_vec(n * n);
            let b = rng.normal_vec(n * n);
            let t0 = std::time::Instant::now();
            let c = mm::matmul_via_pus(&rt, &a, &b, n)?;
            let dt = t0.elapsed().as_secs_f64();
            let want = ea4rca::runtime::tensor::matmul_ref(&a, &b, n, n, n);
            let err = c
                .iter()
                .zip(&want)
                .map(|(x, y)| (x - y).abs() as f64)
                .fold(0.0, f64::max);
            println!("mm {n}^3 via PUs: {:.3} s, max |err| vs oracle = {err:.2e}", dt);
            println!("effective {:.2} GOPS on the CPU substrate", 2.0 * (n as f64).powi(3) / dt / 1e9);
        }
        "fft" => {
            let n = cli.get_usize("size")?;
            let re = rng.normal_vec(n);
            let im = rng.normal_vec(n);
            let (or_, oi) = fft::fft_via_pu(&rt, &re, &im)?;
            let (wr, wi) = ea4rca::runtime::tensor::fft_ref(&re, &im);
            let err = or_
                .iter()
                .zip(&wr)
                .chain(oi.iter().zip(&wi))
                .map(|(x, y)| (x - y).abs() as f64)
                .fold(0.0, f64::max);
            println!("fft {n}-pt via PU: max |err| vs oracle = {err:.2e}");
        }
        "filter2d" => {
            let (h, w) = (128, 128);
            let img: Vec<i32> = (0..(h + 4) * (w + 4))
                .map(|_| rng.range_i64(-128, 127) as i32)
                .collect();
            let kern: Vec<i32> = (0..25).map(|_| rng.range_i64(-8, 8) as i32).collect();
            let out = filter2d::filter_image_via_pus(&rt, &img, h, w, &kern)?;
            let want = ea4rca::runtime::tensor::filter2d_ref(&img, h + 4, w + 4, &kern, 5);
            let ok = out == want;
            println!("filter2d {h}x{w} via PUs: exact match = {ok}");
            if !ok {
                bail!("filter2d numerics mismatch");
            }
        }
        "mmt" => {
            let a = rng.normal_vec(32 * 256);
            let b = rng.normal_vec(256 * 32);
            let c = mmt::chain_via_pu(&rt, &a, &b)?;
            let want = ea4rca::runtime::tensor::matmul_ref(&a, &b, 32, 256, 32);
            let err = c
                .iter()
                .zip(&want)
                .map(|(x, y)| (x - y).abs() as f64)
                .fold(0.0, f64::max);
            println!("mmt cascade8: max |err| vs oracle = {err:.2e}");
        }
        other => {
            return Err(CliError {
                msg: format!("unknown app {other:?}\n\n{}", usage()),
                help: false,
            }
            .into())
        }
    }
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<()> {
    use ea4rca::coordinator::server::JobResult;
    use ea4rca::util::stats::summarize;
    use ea4rca::workload::{generate_stream, open_loop_stream, Mix};
    let cli = Cli::new(
        "ea4rca serve",
        "micro-batched leader/worker request serving over the runtime",
    )
    .opt("shards", "1", "array shards (independent serving units; router places by predicted cost)")
    .opt("workers", "4", "worker thread count per shard")
    .opt("jobs", "256", "total jobs in the stream")
    .opt("mix", "mm-heavy", "uniform | mm-heavy | mm | fft | filter2d | mmt")
    .opt("seed", "1", "workload seed")
    .opt("batch", "8", "max micro-batch size (1 disables batching)")
    .opt("linger-us", "200", "max microseconds an under-full batch waits for company")
    .opt("queue-cap", "256", "admission queue capacity (backpressure bound)")
    .opt("rate", "0", "open-loop arrival rate in jobs/s (0 = closed loop)")
    .opt(
        "backend",
        "",
        "worker backend: interp | sim | pjrt (sim attaches predicted latency/energy \
         to every result; flag wins over EA4RCA_BACKEND)",
    )
    .flag(
        "no-warm",
        "skip the per-worker artifact warm-up (first jobs pay prepare; A/B for the prepared-artifact cache)",
    )
    .parse(args)?;
    // the one mix parser: a typo'd --mix is a usage error listing the
    // valid vocabulary
    let mix = Mix::parse(&cli.get("mix")?).map_err(|e| CliError {
        msg: format!("{e:#}"),
        help: false,
    })?;
    let n_jobs = cli.get_usize("jobs")?;
    let seed = cli.get_u64("seed")?;
    let rate = cli.get_f64("rate")?;
    // deploy the whole serving catalogue through the facade: the
    // designs carry their artifacts, the deployment warms them (unless
    // --no-warm, the cold A/B where first jobs pay prepare on-path)
    let opts = DeployOptions {
        backend: backend_from(&cli)?,
        shards: cli.get_usize("shards")?,
        workers: cli.get_usize("workers")?,
        max_batch: cli.get_usize("batch")?,
        max_linger: std::time::Duration::from_micros(cli.get_u64("linger-us")?),
        queue_cap: cli.get_usize("queue-cap")?,
        artifact_dir: Manifest::default_dir(),
        warm: !cli.has("no-warm"),
    };
    println!("backend: {}", opts.backend.name());
    // the kernel-dispatch configuration the workers will resolve (same
    // environment, same detection) — so a scalar-fallback run announces
    // itself up front, not just in the post-run lane table
    let tiers = ea4rca::runtime::TierConfig::from_env_lenient();
    println!(
        "kernels: {} tier, pool={} threads (EA4RCA_KERNEL_TIER / EA4RCA_POOL_THREADS)",
        tiers.tier, tiers.pool_threads
    );
    let deployment = Deployment::start(&designs::catalogue(), &opts)?;
    if deployment.shards() > 1 {
        println!(
            "cluster: {} shards x {} workers (cost-weighted placement)",
            deployment.shards(),
            opts.workers
        );
    }

    let t0 = std::time::Instant::now();
    let (results, shed) = if rate > 0.0 {
        // open loop: arrivals at the target rate; a saturated queue
        // sheds the job instead of blocking the arrival clock
        let arrivals = open_loop_stream(&mix, n_jobs, seed, rate)
            .into_iter()
            .map(|a| (a.at_secs, a.kind.artifact().to_string(), a.stream, a.inputs));
        deployment.open_loop_streams(arrivals)?
    } else {
        // closed loop: submit everything, let backpressure pace us
        let mut pending = Vec::with_capacity(n_jobs);
        for (kind, inputs) in generate_stream(&mix, n_jobs, seed) {
            pending.push(deployment.submit_to(kind.artifact(), inputs)?);
        }
        let results: Vec<JobResult> =
            pending.into_iter().map(|p| p.wait()).collect::<Result<_>>()?;
        (results, 0)
    };
    let wall = t0.elapsed().as_secs_f64();

    let served = results.len();
    let errors = results.iter().filter(|r| r.outputs.is_err()).count();
    println!(
        "served {served} of {n_jobs} jobs in {wall:.2} s -> {:.0} jobs/s ({errors} errors, {shed} shed)",
        served as f64 / wall
    );
    if !results.is_empty() {
        let total = summarize(&results.iter().map(JobResult::latency_secs).collect::<Vec<_>>());
        let queue = summarize(&results.iter().map(|r| r.queue_secs).collect::<Vec<_>>());
        let exec = summarize(&results.iter().map(|r| r.exec_secs).collect::<Vec<_>>());
        println!(
            "latency ms: mean {:.2} | p50 {:.2} | p95 {:.2} | max {:.2}",
            total.mean * 1e3, total.p50 * 1e3, total.p95 * 1e3, total.max * 1e3
        );
        println!(
            "  queue ms: mean {:.2} | p95 {:.2}    exec ms: mean {:.2} | p95 {:.2}",
            queue.mean * 1e3, queue.p95 * 1e3, exec.mean * 1e3, exec.p95 * 1e3
        );
    }
    let report = deployment.shutdown()?;
    println!("micro-batches: {} dispatched", report.batches);
    for (artifact, hist) in &report.batch_hist {
        let sizes: Vec<String> =
            hist.iter().map(|(size, count)| format!("{size}x{count}")).collect();
        let mean = report.mean_batch_size(artifact).unwrap_or(0.0);
        println!("  {artifact:<16} mean batch {mean:.2} [{}]", sizes.join(" "));
    }
    if report.shards.len() > 1 {
        for s in &report.shards {
            println!(
                "  shard {}: {} jobs accepted, {} completed, {} batches",
                s.shard, s.jobs, s.completed, s.batches
            );
        }
    }
    for w in &report.workers {
        println!(
            "  shard {} worker {}: {} jobs in {} batches, {:.1} ms busy",
            w.shard, w.worker, w.jobs, w.batches, w.exec_secs * 1e3
        );
    }
    // the cost model's view of the run, against what actually happened
    // — plus which kernel tier served each lane (interp runs carry the
    // tier even without predictions)
    let pvm = report.predicted_vs_measured();
    if pvm.values().any(|s| s.predicted_batches > 0 || s.tier.is_some()) {
        let mut t = ea4rca::report::cost_table("predicted vs measured (AIE cost model)");
        for (artifact, lane) in &pvm {
            ea4rca::report::cost_row(&mut t, artifact, lane);
        }
        t.print();
    }
    Ok(())
}

fn cmd_generate(args: &[String]) -> Result<()> {
    let cli = Cli::new("ea4rca generate", "AIE Graph Code Generator")
        .opt("config", "configs/mm.json", "graph configuration file")
        .opt("out", "generated", "output directory")
        .flag("print", "print graph.h to stdout instead of writing")
        .parse(args)?;
    // the JSON frontend of the facade: parse + validate once, then the
    // Design drives the generator
    let design = Design::from_path(std::path::Path::new(&cli.get("config")?))?;
    if cli.has("print") {
        println!("{}", design.generate()?.graph_h);
    } else {
        let dir = std::path::PathBuf::from(cli.get("out")?);
        design.generate_into(&dir)?;
        println!(
            "generated {}/graph.h (+.cpp, Makefile, pu_config.json): PU '{}', {} cores, {} PLIOs, {} copies",
            dir.display(),
            design.name(),
            design.cores(),
            design.total_plios(),
            design.copies()
        );
    }
    Ok(())
}

fn cmd_lint(args: &[String]) -> Result<()> {
    use ea4rca::analysis::{lint_all, lint_design, lint_path, Lint, ServeShape};
    let cli = Cli::new("ea4rca lint", "static design-rule checker (DRC)")
        .opt("config", "", "lint one graph configuration file")
        .opt("app", "", "lint one catalogue design: mm | filter2d | fft | mmt")
        .opt("size", "1024", "FFT points for --app fft")
        .opt("configs-dir", "configs", "config directory swept by --all")
        .opt("shards", "1", "serving shape checked by --all: array shards")
        .opt("workers", "4", "serving shape: worker threads per shard")
        .opt("batch", "8", "serving shape: max micro-batch size")
        .opt("queue-cap", "256", "serving shape: admission queue capacity")
        .opt("rate", "0", "declared open-loop arrival rate in jobs/s (0 = closed loop)")
        .flag("all", "lint every configs/*.json, the design catalogue, and the serving shape")
        .parse(args)?;
    let shape = ServeShape {
        shards: cli.get_usize("shards")?,
        workers: cli.get_usize("workers")?,
        max_batch: cli.get_usize("batch")?,
        queue_cap: cli.get_usize("queue-cap")?,
        rate: cli.get_f64("rate")?,
    };
    let config = cli.get("config")?;
    let app = cli.get("app")?;
    let lint = if cli.has("all") {
        lint_all(std::path::Path::new(&cli.get("configs-dir")?), &shape)
    } else if !config.is_empty() {
        let path = std::path::PathBuf::from(&config);
        let origin = path
            .file_name()
            .and_then(|n| n.to_str())
            .unwrap_or("<config>")
            .to_string();
        let mut lint = Lint::default();
        lint.push(origin, lint_path(&path));
        lint
    } else if !app.is_empty() {
        let design = designs::for_app(&app, cli.get_usize("size")?)?;
        let mut lint = Lint::default();
        lint.push(format!("design({})", design.name()), lint_design(&design));
        lint
    } else {
        return Err(CliError {
            msg: format!("lint needs --config <file>, --app <name>, or --all\n\n{}", usage()),
            help: false,
        }
        .into());
    };
    print!("{}", lint.render());
    if lint.has_errors() {
        // findings already printed in full; exit 1 without main()'s
        // "error:" wrapper repeating them
        std::process::exit(1);
    }
    Ok(())
}

fn cmd_sweep(args: &[String]) -> Result<()> {
    use ea4rca::report::{fft_row, fft_table, perf_row, perf_table};
    let cli = Cli::new("ea4rca sweep", "regenerate a paper table")
        .opt("table", "6", "paper table number: 6 | 7 | 8 | 9")
        .parse(args)?;
    let p = HwParams::vck5000();
    match cli.get("table")?.as_str() {
        "6" => {
            let mut t = perf_table("Table 6 — MM accelerator (Float)");
            for size in [768usize, 1536, 3072, 6144] {
                for pus in [6usize, 3, 1] {
                    let r = ea4rca::apps::mm::run(&p, size, pus, false)?;
                    perf_row(&mut t, &format!("{size}^3"), &pus.to_string(), &r, None);
                }
            }
            t.print();
        }
        "7" => {
            let mut t = perf_table("Table 7 — Filter2D accelerator (Int32, 5x5)");
            for (h, w, l) in [(128usize, 128usize, "128x128"), (3480, 2160, "4K"),
                              (7680, 4320, "8K"), (15360, 8640, "16K")] {
                for pus in [44usize, 20, 4] {
                    let r = filter2d::run(&p, h, w, pus, false)?;
                    perf_row(&mut t, l, &pus.to_string(), &r, Some(pus * 8));
                }
            }
            t.print();
        }
        "8" => {
            let mut t = fft_table("Table 8 — FFT accelerator (CInt16)");
            for n in [8192usize, 4096, 2048, 1024] {
                for pus in [8usize, 4, 2] {
                    let r = fft::run(&p, n, pus, 4096, false)?;
                    fft_row(&mut t, n, &pus.to_string(), r.as_ref());
                }
            }
            t.print();
        }
        "9" => {
            let r = mmt::run(&p, 20_000, false)?;
            println!(
                "MM-T: {} tasks/s | {:.2} GOPS | {:.2} GOPS/AIE | {:.2} W | {:.2} GOPS/W",
                report::tasks_sci(r.tasks_per_sec),
                r.gops,
                r.gops_per_aie,
                r.power_w,
                r.gops_per_w
            );
        }
        other => {
            return Err(CliError {
                msg: format!("unknown table {other:?} (use 6|7|8|9)"),
                help: false,
            }
            .into())
        }
    }
    Ok(())
}

fn cmd_fuse(args: &[String]) -> Result<()> {
    let cli = Cli::new("ea4rca fuse", "Graph Fusion: combine stored graphs into one design")
        .opt("configs", "configs/fft.json,configs/mm_small.json", "comma-separated config files")
        .opt("out", "generated/fused", "output directory")
        .parse(args)?;
    let p = HwParams::vck5000();
    let fusees: Vec<Design> = cli
        .get("configs")?
        .split(',')
        .map(|f| Design::from_path(std::path::Path::new(f.trim())))
        .collect::<Result<_>>()?;
    let fused = api::fuse(&p, &fusees)?;
    let out = std::path::PathBuf::from(cli.get("out")?);
    fused.write_to(&out)?;
    println!(
        "fused {} PU types into {}/: {} AIE cores ({}%), {} PLIOs",
        fused.parts.len(),
        out.display(),
        fused.total_aie,
        fused.total_aie * 100 / p.total_aie,
        fused.total_plio
    );
    Ok(())
}

fn cmd_resources() -> Result<()> {
    let p = HwParams::vck5000();
    let mut t = Table::new(
        "Table 5 — hardware resource utilisation",
        &["Apps", "LUT", "FF", "BRAM", "URAM", "DSP", "AIE", "DU", "PU"],
    );
    for (app, du, pu) in [("MM", 1, 6), ("Filter2D", 11, 44), ("FFT", 8, 8), ("MM-T", 50, 50)] {
        let u = table5_usage(app)?;
        let mut row = vec![app.to_string()];
        row.extend(u.table5_row(&p));
        row.push(du.to_string());
        row.push(pu.to_string());
        t.row(&row);
    }
    t.print();
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("ea4rca v{}", ea4rca::VERSION);
    let rt = Runtime::new()?;
    println!("backend: {} ({})", rt.backend_kind().name(), rt.platform());
    println!(
        "kernel tiers: simd {} on this CPU (EA4RCA_KERNEL_TIER / EA4RCA_POOL_THREADS)",
        if ea4rca::runtime::KernelTier::simd_supported() { "available" } else { "unavailable" }
    );
    println!("artifacts ({}):", rt.manifest().dir.display());
    for (name, meta) in &rt.manifest().artifacts {
        let ins: Vec<String> = meta
            .inputs
            .iter()
            .map(|t| format!("{}{:?}", t.dtype.tag(), t.shape))
            .collect();
        println!("  {name:<16} {} -> {} outputs", ins.join(", "), meta.outputs.len());
    }
    // smoke: run mm32 once
    let mut rng = Rng::new(1);
    let a = Tensor::f32(&[32, 32], rng.normal_vec(1024));
    let b = Tensor::f32(&[32, 32], rng.normal_vec(1024));
    let out = rt.execute("mm32", &[a, b])?;
    println!("mm32 smoke: output shape {:?} OK", out[0].shape());
    Ok(())
}
