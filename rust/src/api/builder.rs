//! The fluent, typed design builder — the programmatic frontend of the
//! design-entry API.
//!
//! A [`DesignBuilder`] assembles the same facts a Graph Configuration
//! File carries (kernel, arithmetic class, PSTs, per-iteration ops and
//! wire bytes, deployed copies), but with the component vocabulary
//! typed: DAC/DCC modes are enums, the CC is the paper's `Parallel<n>*
//! Cascade<k>` notation parsed and validated at [`DesignBuilder::build`].
//! Errors accumulate so a chain reads fluently and reports every
//! problem at once instead of panicking mid-chain.

use anyhow::{bail, Result};

use crate::codegen::config::PuConfig;
use crate::engine::compute::cc::{parse_cc_validated, CcMode};
use crate::engine::compute::dac::{Dac, DacMode};
use crate::engine::compute::dcc::{Dcc, DccMode};
use crate::engine::compute::pu::{ProcessingStructure, ProcessingUnit};
use crate::sim::core::KernelClass;

use super::design::Design;

/// Builder for one Processing Structure (a DAC set, a Component
/// Connector, a DCC set — paper §3.3, Fig 3). Obtained inside
/// [`DesignBuilder::pst`]'s closure.
pub struct PstBuilder {
    dacs: Vec<Dac>,
    cc: Option<CcMode>,
    dccs: Vec<Dcc>,
    errors: Vec<String>,
}

impl PstBuilder {
    fn new() -> PstBuilder {
        PstBuilder { dacs: Vec::new(), cc: None, dccs: Vec::new(), errors: Vec::new() }
    }

    /// Add a Data Allocation Component: its (stacked) modes, the PLIO
    /// ports it owns, and how many CC cores it serves.
    pub fn dac(mut self, modes: &[DacMode], plios: usize, serves: usize) -> Self {
        self.dacs.push(Dac::new(modes.to_vec(), plios, serves));
        self
    }

    /// Set the Component Connector from the paper's notation
    /// (`Single`, `Cascade<4>`, `Parallel<16>*Cascade<4>`,
    /// `Butterfly[4]`). A malformed spec becomes a build error.
    pub fn cc(mut self, spec: &str) -> Self {
        match parse_cc_validated(spec) {
            Ok(cc) => self.cc = Some(cc),
            Err(e) => self.errors.push(format!("cc {spec:?}: {e}")),
        }
        self
    }

    /// Add a Data Collection Component.
    pub fn dcc(mut self, mode: DccMode, plios: usize, serves: usize) -> Self {
        self.dccs.push(Dcc::new(mode, plios, serves));
        self
    }

    fn finish(self) -> Result<ProcessingStructure, Vec<String>> {
        let PstBuilder { dacs, cc, dccs, mut errors } = self;
        let Some(cc) = cc else {
            errors.push("pst needs a .cc(\"...\") component connector".into());
            return Err(errors);
        };
        if !errors.is_empty() {
            return Err(errors);
        }
        Ok(ProcessingStructure { dacs, cc, dccs })
    }
}

/// The fluent design entry point — see [`Design::for_algorithm`].
pub struct DesignBuilder {
    name: String,
    kernel: Option<String>,
    class: Option<KernelClass>,
    copies: usize,
    psts: Vec<ProcessingStructure>,
    /// `.pst(...)` invocations (not successful pushes): error labels
    /// must point at the PST the caller wrote, even after an earlier
    /// one failed.
    pst_calls: usize,
    ops_per_iter: Option<f64>,
    wire: Option<(usize, usize)>,
    serial_comm: bool,
    handoff_bytes: usize,
    artifact: Option<String>,
    errors: Vec<String>,
}

impl DesignBuilder {
    pub(crate) fn new(name: impl Into<String>) -> DesignBuilder {
        DesignBuilder {
            name: name.into(),
            kernel: None,
            class: None,
            copies: 1,
            psts: Vec::new(),
            pst_calls: 0,
            ops_per_iter: None,
            wire: None,
            serial_comm: false,
            handoff_bytes: 0,
            artifact: None,
            errors: Vec::new(),
        }
    }

    /// AIE kernel source this design's cores run. Must exist in the
    /// Kernel Manager ([`crate::codegen::repository::kernel_catalogue`]);
    /// unknown kernels are a build error.
    pub fn kernel(mut self, name: impl Into<String>) -> Self {
        self.kernel = Some(name.into());
        self
    }

    /// Arithmetic class of the kernel (checked against the Kernel
    /// Manager's record at build time).
    pub fn class(mut self, class: KernelClass) -> Self {
        self.class = Some(class);
        self
    }

    /// Add one Processing Structure via its own fluent builder.
    pub fn pst(mut self, f: impl FnOnce(PstBuilder) -> PstBuilder) -> Self {
        self.pst_calls += 1;
        let idx = self.pst_calls;
        match f(PstBuilder::new()).finish() {
            Ok(pst) => self.psts.push(pst),
            Err(errs) => self
                .errors
                .extend(errs.into_iter().map(|e| format!("pst#{idx}: {e}"))),
        }
        self
    }

    /// PU copies the design deploys (default 1).
    pub fn copies(mut self, copies: usize) -> Self {
        self.copies = copies;
        self
    }

    /// Total arithmetic ops one PU performs per engine iteration.
    pub fn ops_per_iter(mut self, ops: f64) -> Self {
        self.ops_per_iter = Some(ops);
        self
    }

    /// Unique bytes entering / leaving one PU per iteration over PLIO.
    pub fn wire_bytes(mut self, in_bytes: usize, out_bytes: usize) -> Self {
        self.wire = Some((in_bytes, out_bytes));
        self
    }

    /// Serialize input and output in the communication phase
    /// (single-duplex wiring such as the FFT PU's DIR ports).
    pub fn serial_comm(mut self, on: bool) -> Self {
        self.serial_comm = on;
        self
    }

    /// Bytes handed between PSTs over the core stream fabric per
    /// iteration (multi-PST PUs).
    pub fn handoff_bytes(mut self, bytes: usize) -> Self {
        self.handoff_bytes = bytes;
        self
    }

    /// Override the runtime artifact this design executes as. Without
    /// it the Kernel Manager's kernel → artifact mapping applies; the
    /// override exists for PU-level graphs whose artifact differs from
    /// the kernel default (e.g. the MM-T cascade runs `mmt_cascade8`
    /// although its per-core kernel is `mm32`).
    pub fn artifact(mut self, artifact: impl Into<String>) -> Self {
        self.artifact = Some(artifact.into());
        self
    }

    /// Validate everything and produce the [`Design`]. All accumulated
    /// problems are reported together in the error.
    pub fn build(self) -> Result<Design> {
        let DesignBuilder {
            name,
            kernel,
            class,
            copies,
            psts,
            pst_calls: _,
            ops_per_iter,
            wire,
            serial_comm,
            handoff_bytes,
            artifact,
            mut errors,
        } = self;
        if kernel.is_none() {
            errors.push("missing .kernel(...)".into());
        }
        if class.is_none() {
            errors.push("missing .class(...)".into());
        }
        if ops_per_iter.is_none() {
            errors.push("missing .ops_per_iter(...)".into());
        }
        if wire.is_none() {
            errors.push("missing .wire_bytes(in, out)".into());
        }
        if psts.is_empty() {
            errors.push("needs at least one .pst(...)".into());
        }
        if copies == 0 {
            errors.push(".copies(...) must be >= 1".into());
        }
        if !errors.is_empty() {
            bail!("design {name:?} is not buildable: {}", errors.join("; "));
        }
        let (in_bytes, out_bytes) = wire.expect("checked above");
        let mut pu = ProcessingUnit::simple(
            &name,
            psts,
            class.expect("checked above"),
            ops_per_iter.expect("checked above"),
            in_bytes,
            out_bytes,
        );
        pu.serial_comm = serial_comm;
        pu.handoff_bytes = handoff_bytes;
        let config = PuConfig { name, kernel: kernel.expect("checked above"), copies, pu };
        Design::with_artifact(config, artifact)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mm_chain() -> DesignBuilder {
        Design::for_algorithm("mm")
            .kernel("mm32")
            .class(KernelClass::F32Mac)
            .pst(|p| {
                p.dac(&[DacMode::Swh, DacMode::Bdc], 8, 64)
                    .cc("Parallel<16>*Cascade<4>")
                    .dcc(DccMode::Swh, 4, 64)
            })
            .ops_per_iter(2.0 * 128.0 * 128.0 * 128.0)
            .wire_bytes(2 * 128 * 128 * 4, 128 * 128 * 4)
            .copies(6)
    }

    #[test]
    fn builds_the_paper_mm_design() {
        let d = mm_chain().build().unwrap();
        assert_eq!(d.name(), "mm");
        assert_eq!(d.copies(), 6);
        assert_eq!(d.cores(), 64);
        assert_eq!(d.total_plios(), 12);
        assert_eq!(d.artifact(), "mm_pu128");
    }

    #[test]
    fn missing_pieces_are_reported_together() {
        let err = Design::for_algorithm("empty").build().unwrap_err().to_string();
        for needle in [".kernel", ".class", ".ops_per_iter", ".wire_bytes", ".pst"] {
            assert!(err.contains(needle), "{err}");
        }
    }

    #[test]
    fn bad_cc_is_a_build_error_not_a_panic() {
        let err = mm_chain()
            .pst(|p| p.dac(&[DacMode::Swh], 1, 8).cc("Waffle<9>").dcc(DccMode::Swh, 1, 8))
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("Waffle"), "{err}");
    }

    #[test]
    fn pst_errors_are_numbered_by_invocation() {
        // an earlier failed PST must not shift later labels: both bad
        // PSTs report under their own number
        let err = Design::for_algorithm("two-bad")
            .kernel("mm32")
            .class(KernelClass::F32Mac)
            .pst(|p| p.dac(&[DacMode::Swh], 1, 8).cc("Bad<1>").dcc(DccMode::Swh, 1, 8))
            .pst(|p| p.dac(&[DacMode::Swh], 1, 8).cc("AlsoBad").dcc(DccMode::Swh, 1, 8))
            .ops_per_iter(1e6)
            .wire_bytes(64, 64)
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("pst#1") && err.contains("pst#2"), "{err}");
    }

    #[test]
    fn pst_without_cc_is_a_build_error() {
        let err = Design::for_algorithm("nocc")
            .kernel("mm32")
            .class(KernelClass::F32Mac)
            .pst(|p| p.dac(&[DacMode::Swh], 1, 8).dcc(DccMode::Swh, 1, 8))
            .ops_per_iter(1e6)
            .wire_bytes(64, 64)
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("component connector"), "{err}");
    }

    #[test]
    fn unknown_kernel_and_class_mismatch_rejected() {
        let err = mm_chain().kernel("nope").build().unwrap_err().to_string();
        assert!(err.contains("nope"), "{err}");
        let err = mm_chain()
            .class(KernelClass::I32Mac)
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("class"), "{err}");
    }

    #[test]
    fn zero_copies_rejected() {
        assert!(mm_chain().copies(0).build().is_err());
    }
}
