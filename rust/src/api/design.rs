//! [`Design`] — a validated accelerator design and the single object
//! the rest of the framework hangs off: graph generation, cost
//! prediction, simulation reports, runtimes, and deployments.

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use crate::codegen::config::PuConfig;
use crate::codegen::generator::{self, GeneratedProject};
use crate::codegen::repository::{self, FusedProject};
use crate::coordinator::controller::{Controller, RunReport};
use crate::coordinator::scheduler::{ExecMode, GroupSpec};
use crate::engine::data::du::DataUnit;
use crate::runtime::backend::sim::predict_lane;
use crate::runtime::manifest::PuTopology;
use crate::runtime::{BackendKind, CostPrediction, Manifest, Runtime};
use crate::sim::memory::ResourceUsage;
use crate::sim::params::HwParams;
use crate::util::json::Json;

use super::builder::DesignBuilder;
use super::deploy::{DeployOptions, Deployment};

/// A validated top-down design: the Graph Configuration (PU structure,
/// kernel, copies) plus the derived artifact topology. Built fluently
/// with [`Design::for_algorithm`] or parsed from the JSON frontend with
/// [`Design::from_path`] / [`Design::from_json_text`]; both frontends
/// land in the same validation.
#[derive(Debug, Clone, PartialEq)]
pub struct Design {
    config: PuConfig,
    topology: PuTopology,
    /// Runtime artifact this design executes as (Kernel Manager mapping
    /// unless overridden by the builder).
    artifact: String,
}

/// One DU-PU lane of a simulated workload: the data unit serving this
/// design's PU and how many engine iterations it runs.
#[derive(Debug, Clone)]
pub struct Lane {
    pub du: DataUnit,
    pub engine_iters: u64,
}

/// Workload facts for [`Design::report`] — everything a Table 6/7/8/9
/// row needs beyond the design itself.
#[derive(Debug, Clone)]
pub struct ReportParams {
    pub label: String,
    /// The deployed DU-PU lanes (homogeneous apps use one; FFT deploys
    /// 8 identical pairs; Filter2D mixes full and partial DUs).
    pub lanes: Vec<Lane>,
    /// User-level tasks the workload completes (app-defined).
    pub tasks: f64,
    /// Useful arithmetic ops across the workload (padding is waste).
    pub total_ops: f64,
    /// Whole-card resource footprint to validate and feed the power model.
    pub usage: ResourceUsage,
    /// Execution discipline (Regular unless modelling a non-RCA app).
    pub mode: ExecMode,
    pub trace: bool,
}

impl Design {
    /// Start a fluent design for `algorithm` (the PU/config name).
    ///
    /// ```
    /// use ea4rca::api::Design;
    /// use ea4rca::engine::compute::dac::DacMode;
    /// use ea4rca::engine::compute::dcc::DccMode;
    /// use ea4rca::sim::core::KernelClass;
    ///
    /// let design = Design::for_algorithm("mm")
    ///     .kernel("mm32")
    ///     .class(KernelClass::F32Mac)
    ///     .pst(|p| {
    ///         p.dac(&[DacMode::Swh, DacMode::Bdc], 8, 64)
    ///             .cc("Parallel<16>*Cascade<4>")
    ///             .dcc(DccMode::Swh, 4, 64)
    ///     })
    ///     .ops_per_iter(2.0 * 128.0 * 128.0 * 128.0)
    ///     .wire_bytes(2 * 128 * 128 * 4, 128 * 128 * 4)
    ///     .copies(6)
    ///     .build()?;
    /// assert_eq!(design.cores(), 64);
    /// assert_eq!(design.artifact(), "mm_pu128");
    /// // the JSON frontend is the same design
    /// let back = Design::from_json_text(&design.to_json_text())?;
    /// assert_eq!(back, design);
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn for_algorithm(algorithm: impl Into<String>) -> DesignBuilder {
        DesignBuilder::new(algorithm)
    }

    /// Wrap an already-parsed Graph Configuration. Validation is the
    /// same as the builder's: PU structure, positive copies, and the
    /// kernel checked against the Kernel Manager.
    pub fn from_config(config: PuConfig) -> Result<Design> {
        Design::with_artifact(config, None)
    }

    pub(crate) fn with_artifact(config: PuConfig, artifact: Option<String>) -> Result<Design> {
        config.pu.validate().map_err(anyhow::Error::msg)?;
        if config.copies == 0 {
            bail!("design {:?}: copies must be >= 1", config.name);
        }
        let info = repository::validate_kernel(&config)?;
        let artifact = artifact.unwrap_or_else(|| info.artifact.to_string());
        let topology = PuTopology::from_config(&config);
        Ok(Design { config, topology, artifact })
    }

    /// The JSON frontend: parse a Graph Configuration File's text. An
    /// optional top-level `"artifact"` key carries a runtime-artifact
    /// override (what the builder's `.artifact(...)` sets); without it
    /// the Kernel Manager's kernel → artifact mapping applies.
    pub fn from_json_text(text: &str) -> Result<Design> {
        let root = Json::parse(text)
            .map_err(|e| anyhow::anyhow!("configuration is not valid JSON: {e}"))?;
        let artifact = root.get("artifact").and_then(Json::as_str).map(String::from);
        Design::with_artifact(PuConfig::from_json(&root)?, artifact)
    }

    /// The JSON frontend: parse a Graph Configuration File on disk.
    pub fn from_path(path: impl AsRef<Path>) -> Result<Design> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Design::from_json_text(&text)
            .map_err(|e| e.context(format!("parsing {}", path.display())))
    }

    /// Serialize back to the configuration-file JSON (round-trips:
    /// `Design::from_json_text(&d.to_json_text())` equals `d`). The
    /// `"artifact"` key is emitted only when this design overrides the
    /// Kernel Manager's default, so shipped configs serialize byte-
    /// compatibly with what they parse from.
    pub fn to_json(&self) -> Json {
        let mut root = self.config.to_json();
        let default = repository::validate_kernel(&self.config)
            .map(|info| info.artifact.to_string())
            .ok();
        if default.as_deref() != Some(self.artifact.as_str()) {
            if let Json::Obj(map) = &mut root {
                map.insert("artifact".to_string(), Json::str(&self.artifact));
            }
        }
        root
    }

    pub fn to_json_text(&self) -> String {
        self.to_json().to_string_pretty()
    }

    // -- accessors ---------------------------------------------------------

    pub fn name(&self) -> &str {
        &self.config.name
    }

    pub fn kernel(&self) -> &str {
        &self.config.kernel
    }

    /// Runtime artifact this design executes as.
    pub fn artifact(&self) -> &str {
        &self.artifact
    }

    pub fn copies(&self) -> usize {
        self.config.copies
    }

    /// AIE cores of one PU copy.
    pub fn cores(&self) -> usize {
        self.config.pu.cores()
    }

    pub fn total_plios(&self) -> usize {
        self.config.pu.total_plios()
    }

    /// The validated Graph Configuration this design owns.
    pub fn config(&self) -> &PuConfig {
        &self.config
    }

    /// The artifact topology (PU structure + deployed copies) the cost
    /// model runs.
    pub fn topology(&self) -> &PuTopology {
        &self.topology
    }

    // -- pipeline stages ---------------------------------------------------

    /// Run the static design-rule checker over this design: budgets,
    /// placeability, port arithmetic, kernel compatibility, cost-model
    /// smells, and a wiring audit of the emitted graph — every violated
    /// rule as a structured [`crate::analysis::Diagnostic`], no runtime
    /// touched. [`Design::generate`] and [`Design::deploy`] gate on
    /// this report (errors fail, warnings print); call it directly for
    /// the findings themselves, e.g. to prune a design search.
    pub fn check(&self) -> crate::analysis::Report {
        crate::analysis::check_design(self)
    }

    /// Run the AIE Graph Code Generator: the compilable graph project
    /// plus the `pu_config.json` topology handoff. Gated on
    /// [`Design::check`]: Error-severity findings fail with the
    /// diagnostic text, warnings print to stderr and generation
    /// proceeds.
    pub fn generate(&self) -> Result<GeneratedProject> {
        self.check()
            .gate(&format!("design {:?}", self.config.name))?;
        generator::generate(&self.config)
    }

    /// [`Design::generate`] and write the project tree into `dir`.
    pub fn generate_into(&self, dir: impl AsRef<Path>) -> Result<GeneratedProject> {
        let proj = self.generate()?;
        proj.write_to(dir.as_ref())?;
        Ok(proj)
    }

    /// Predicted cost of dispatching `batch` serving jobs on this
    /// design's deployed topology (VCK5000 parameters) — the event-
    /// driven AIE cost model, no runtime or artifacts needed.
    /// Deterministic for a given (design, batch).
    pub fn predict(&self, batch: usize) -> CostPrediction {
        self.predict_on(&HwParams::vck5000(), batch)
    }

    /// [`Design::predict`] against explicit hardware parameters.
    pub fn predict_on(&self, p: &HwParams, batch: usize) -> CostPrediction {
        predict_lane(p, &self.artifact, &self.topology, batch)
    }

    /// Simulate a workload on this design and produce the Controller's
    /// [`RunReport`] row (deploy-validate, event-driven simulation,
    /// power model) — the `run`/`sweep` path of the pipeline.
    pub fn report(&self, p: &HwParams, w: &ReportParams) -> Result<RunReport> {
        if w.lanes.is_empty() {
            bail!("design {:?}: report needs at least one lane", self.config.name);
        }
        let groups: Vec<GroupSpec> = w
            .lanes
            .iter()
            .enumerate()
            .map(|(i, lane)| GroupSpec {
                name: format!("{}-L{i}", self.config.name),
                du: lane.du.clone(),
                pu: self.config.pu.clone(),
                engine_iters: lane.engine_iters,
                mode: w.mode,
            })
            .collect();
        Controller::new(p.clone(), w.usage, self.config.pu.class)
            .with_trace(w.trace)
            .run(&w.label, &groups, w.tasks, w.total_ops)
    }

    /// A runtime for this design's numerics: backend from
    /// `$EA4RCA_BACKEND`, default artifact directory, the design's
    /// artifact warmed when the manifest carries it.
    pub fn runtime(&self) -> Result<Runtime> {
        self.runtime_with(BackendKind::from_env()?, Manifest::default_dir())
    }

    /// [`Design::runtime`] with an explicit backend and artifact dir.
    pub fn runtime_with(
        &self,
        kind: BackendKind,
        dir: impl Into<PathBuf>,
    ) -> Result<Runtime> {
        let rt = Runtime::with_backend(kind, dir)?;
        // warm the design's artifact when it exists; a design whose
        // artifact is absent still gets a runtime (the execute path
        // reports the missing artifact readably)
        if rt.manifest().get(&self.artifact).is_ok() {
            rt.warmup(&[self.artifact.as_str()])?;
        }
        Ok(rt)
    }

    /// Deploy this design as a serving [`Deployment`] (leader/worker
    /// server, micro-batching, cost-aware placement, warm caches).
    pub fn deploy(&self, opts: &DeployOptions) -> Result<Deployment> {
        Deployment::start(std::slice::from_ref(self), opts)
    }
}

/// Graph Fusion through the facade: combine several designs into one
/// deployable project, checked against the card.
pub fn fuse(p: &HwParams, designs: &[Design]) -> Result<FusedProject> {
    let configs: Vec<PuConfig> = designs.iter().map(|d| d.config().clone()).collect();
    repository::fuse(p, &configs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::designs;

    #[test]
    fn json_frontend_roundtrips() {
        let d = designs::mm();
        let text = d.to_json_text();
        let back = Design::from_json_text(&text).unwrap();
        assert_eq!(back, d);
        assert_eq!(back.artifact(), "mm_pu128");
    }

    #[test]
    fn report_requires_a_lane() {
        let d = designs::mm();
        let w = ReportParams {
            label: "empty".into(),
            lanes: Vec::new(),
            tasks: 1.0,
            total_ops: 1.0,
            usage: ResourceUsage::default(),
            mode: ExecMode::Regular,
            trace: false,
        };
        assert!(d.report(&HwParams::vck5000(), &w).is_err());
    }

    #[test]
    fn predict_is_deterministic() {
        let d = designs::fft(1024).unwrap();
        let a = d.predict(4);
        let b = d.predict(4);
        assert_eq!(a.latency_secs.to_bits(), b.latency_secs.to_bits());
        assert!(a.latency_secs > 0.0 && a.power_w > 0.0 && a.energy_j > 0.0);
    }

    #[test]
    fn fuse_checks_the_card_through_the_facade() {
        let p = HwParams::vck5000();
        // MM (384 cores) + FFT (80) overflow the 400-core card
        let err = fuse(&p, &[designs::mm(), designs::fft(1024).unwrap()]).unwrap_err();
        assert!(format!("{err:#}").contains("exceeds the card"), "{err:#}");
        let f = fuse(&p, &[designs::mm()]).unwrap();
        assert_eq!(f.total_aie, 384);
    }
}
