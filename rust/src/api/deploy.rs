//! [`Deployment`] — the serving stage of the design-entry API: a
//! running leader/worker server (micro-batching, backpressure,
//! cost-model-aware placement) wrapped in a typed handle that knows
//! which designs it carries.

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::coordinator::server::{
    serve_open_loop, JobResult, Pending, Server, ServeReport, ServerConfig,
};
use crate::runtime::{BackendKind, Manifest, Tensor};

use super::design::Design;

/// Deployment knobs: the worker substrate plus the serving-path tuning
/// of [`ServerConfig`]. `warm: true` (default) pre-builds every
/// deployed artifact's prepared state in every worker at load time.
#[derive(Debug, Clone)]
pub struct DeployOptions {
    pub backend: BackendKind,
    pub workers: usize,
    pub max_batch: usize,
    pub max_linger: Duration,
    pub queue_cap: usize,
    pub artifact_dir: PathBuf,
    pub warm: bool,
}

impl Default for DeployOptions {
    /// Defaults mirror the CLI's precedence below the `--backend` flag:
    /// a valid `$EA4RCA_BACKEND` selects the backend, otherwise the
    /// interpreter (a malformed value falls back rather than panicking
    /// inside `Default` — set `backend` explicitly to get an error).
    fn default() -> Self {
        let sc = ServerConfig::default();
        DeployOptions {
            backend: BackendKind::from_env().unwrap_or(BackendKind::Interp),
            workers: sc.n_workers,
            max_batch: sc.max_batch,
            max_linger: sc.max_linger,
            queue_cap: sc.queue_cap,
            artifact_dir: Manifest::default_dir(),
            warm: true,
        }
    }
}

/// A running deployment of one or more [`Design`]s. Submissions are
/// typed against the deployed artifact set — a job for an artifact this
/// deployment does not carry is an immediate readable error, not a
/// worker-side failure. [`Deployment::shutdown`] drains every accepted
/// job and returns the [`ServeReport`].
pub struct Deployment {
    server: Server,
    artifacts: Vec<String>,
}

impl Deployment {
    /// Deploy `designs` as one serving fleet: per-worker runtimes on
    /// `opts.backend`, every design's artifact warmed (unless
    /// `opts.warm` is off), micro-batch dispatch across workers.
    pub fn start(designs: &[Design], opts: &DeployOptions) -> Result<Deployment> {
        if designs.is_empty() {
            bail!("deployment needs at least one design");
        }
        let mut artifacts: Vec<String> = Vec::new();
        for d in designs {
            if !artifacts.iter().any(|a| a == d.artifact()) {
                artifacts.push(d.artifact().to_string());
            }
        }
        let config = ServerConfig {
            n_workers: opts.workers,
            max_batch: opts.max_batch,
            max_linger: opts.max_linger,
            queue_cap: opts.queue_cap,
        };
        let warm: Vec<&str> = if opts.warm {
            artifacts.iter().map(String::as_str).collect()
        } else {
            Vec::new()
        };
        let server =
            Server::start_with_config(opts.backend, config, opts.artifact_dir.clone(), &warm)?;
        Ok(Deployment { server, artifacts })
    }

    /// The deployed artifact set (primary design first).
    pub fn artifacts(&self) -> &[String] {
        &self.artifacts
    }

    pub fn workers(&self) -> usize {
        self.server.workers()
    }

    fn ensure_deployed(&self, artifact: &str) -> Result<()> {
        if self.artifacts.iter().any(|a| a == artifact) {
            return Ok(());
        }
        bail!(
            "artifact {artifact:?} is not part of this deployment (deployed: {})",
            self.artifacts.join(", ")
        )
    }

    /// Submit one job to the primary (first-deployed) design.
    pub fn submit(&self, inputs: Vec<Tensor>) -> Result<Pending> {
        let artifact = self.artifacts[0].clone();
        Ok(self.server.submit(&artifact, inputs)?)
    }

    /// Submit one job to a specific deployed artifact. Backpressure
    /// applies: a saturated admission queue surfaces as an error after
    /// the bounded wait instead of blocking forever.
    pub fn submit_to(&self, artifact: &str, inputs: Vec<Tensor>) -> Result<Pending> {
        self.ensure_deployed(artifact)?;
        Ok(self.server.submit(artifact, inputs)?)
    }

    /// Synchronous one-job round trip on the primary design: submit,
    /// wait, unwrap the outputs (exec-style validation and smoke tests).
    pub fn execute(&self, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        self.submit(inputs)?.wait()?.outputs
    }

    /// Drive an open-loop arrival stream against the deployment; a
    /// saturated queue sheds the job (second return value) instead of
    /// stalling the arrival clock. Every arrival's artifact is checked
    /// against the deployed set up front — same typed guarantee as
    /// [`Deployment::submit_to`] — before the clock starts.
    pub fn open_loop(
        &self,
        arrivals: impl IntoIterator<Item = (f64, &'static str, Vec<Tensor>)>,
    ) -> Result<(Vec<JobResult>, u64)> {
        let arrivals: Vec<_> = arrivals.into_iter().collect();
        for (_, artifact, _) in &arrivals {
            self.ensure_deployed(artifact)?;
        }
        serve_open_loop(&self.server, arrivals)
    }

    /// Close admission, drain every accepted job, join the workers, and
    /// return the run's [`ServeReport`].
    pub fn shutdown(self) -> Result<ServeReport> {
        self.server.shutdown()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::designs;

    #[test]
    fn empty_deployment_rejected() {
        assert!(Deployment::start(&[], &DeployOptions::default()).is_err());
    }

    #[test]
    fn undeployed_artifact_is_a_typed_error() {
        let opts = DeployOptions { workers: 1, ..DeployOptions::default() };
        let dep = designs::mm().deploy(&opts).unwrap();
        assert_eq!(dep.artifacts(), &["mm_pu128".to_string()]);
        let err = dep.submit_to("fft1024", Vec::new()).unwrap_err().to_string();
        assert!(err.contains("fft1024") && err.contains("mm_pu128"), "{err}");
        // the open-loop path enforces the same contract up front
        let err = dep
            .open_loop([(0.0, "fft1024", Vec::new())])
            .unwrap_err()
            .to_string();
        assert!(err.contains("fft1024"), "{err}");
        dep.shutdown().unwrap();
    }

    #[test]
    fn duplicate_designs_deploy_one_artifact_lane() {
        let opts = DeployOptions { workers: 1, ..DeployOptions::default() };
        let dep =
            Deployment::start(&[designs::mm(), designs::mm()], &opts).unwrap();
        assert_eq!(dep.artifacts().len(), 1);
        dep.shutdown().unwrap();
    }
}
