//! [`Deployment`] — the serving stage of the design-entry API: a
//! running shard cluster (micro-batching, backpressure, cost-model-
//! aware placement across N array shards) wrapped in a typed handle
//! that knows which designs it carries.

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::coordinator::router::{route_open_loop, ClusterConfig, Router};
use crate::coordinator::server::{JobResult, Pending, ServeReport, ServerConfig};
use crate::coordinator::shard::ShardReport;
use crate::runtime::{BackendKind, Manifest, Tensor};

use super::design::Design;

/// Deployment knobs: the cluster shape (`shards` array shards, each
/// with `workers` worker threads) plus the per-shard serving-path
/// tuning of [`ServerConfig`]. `warm: true` (default) pre-builds every
/// deployed artifact's prepared state in every worker at load time.
#[derive(Debug, Clone)]
pub struct DeployOptions {
    pub backend: BackendKind,
    /// Array shards — independent serving units with their own worker
    /// pools, caches, and cost books. 1 (default) is the classic
    /// single-array deployment.
    pub shards: usize,
    /// Worker threads per shard.
    pub workers: usize,
    pub max_batch: usize,
    pub max_linger: Duration,
    pub queue_cap: usize,
    pub artifact_dir: PathBuf,
    pub warm: bool,
}

impl Default for DeployOptions {
    /// Defaults mirror the CLI's precedence below the `--backend` flag:
    /// a valid `$EA4RCA_BACKEND` selects the backend, otherwise the
    /// interpreter (a malformed value falls back rather than panicking
    /// inside `Default` — set `backend` explicitly to get an error).
    fn default() -> Self {
        let sc = ServerConfig::default();
        DeployOptions {
            backend: BackendKind::from_env().unwrap_or(BackendKind::Interp),
            shards: 1,
            workers: sc.n_workers,
            max_batch: sc.max_batch,
            max_linger: sc.max_linger,
            queue_cap: sc.queue_cap,
            artifact_dir: Manifest::default_dir(),
            warm: true,
        }
    }
}

/// A running deployment of one or more [`Design`]s over a shard
/// cluster. Submissions are typed against the deployed artifact set —
/// a job for an artifact this deployment does not carry is an
/// immediate readable error, not a worker-side failure — and placed on
/// the shard with the cheapest predicted backlog.
/// [`Deployment::shutdown`] drains every shard and returns the merged
/// cluster [`ServeReport`].
pub struct Deployment {
    router: Router,
    artifacts: Vec<String>,
}

impl Deployment {
    /// Deploy `designs` as one serving fleet: `opts.shards` shards,
    /// each with per-worker runtimes on `opts.backend` and the full
    /// artifact catalogue deployed (replicated placement — every shard
    /// can serve every design, the router balances by predicted cost),
    /// every artifact warmed per shard unless `opts.warm` is off.
    pub fn start(designs: &[Design], opts: &DeployOptions) -> Result<Deployment> {
        if designs.is_empty() {
            bail!("deployment needs at least one design");
        }
        let mut artifacts: Vec<String> = Vec::new();
        for d in designs {
            if !artifacts.iter().any(|a| a == d.artifact()) {
                artifacts.push(d.artifact().to_string());
            }
        }
        let cluster = ClusterConfig {
            shards: opts.shards,
            shard: ServerConfig {
                n_workers: opts.workers,
                max_batch: opts.max_batch,
                max_linger: opts.max_linger,
                queue_cap: opts.queue_cap,
            },
        };
        let placement = vec![artifacts.clone(); opts.shards];

        // Static design-rule check before any thread spawns: every
        // design's rule set plus the serving-shape and placement lints.
        // Errors fail the deployment with the diagnostic text; warnings
        // print and deployment proceeds.
        let mut report = crate::analysis::Report::new();
        for d in designs {
            report.merge(d.check());
        }
        let shape = crate::analysis::ServeShape {
            shards: opts.shards,
            workers: opts.workers,
            max_batch: opts.max_batch,
            queue_cap: opts.queue_cap,
            rate: 0.0,
        };
        report.merge(crate::analysis::check_serving(designs, &shape, "deployment"));
        report.merge(crate::analysis::check_placement(&artifacts, &placement, "deployment"));
        report.gate("deployment")?;

        let router = Router::start_with_placement(
            opts.backend,
            cluster,
            opts.artifact_dir.clone(),
            placement,
            opts.warm,
        )?;
        Ok(Deployment { router, artifacts })
    }

    /// The deployed artifact set (primary design first).
    pub fn artifacts(&self) -> &[String] {
        &self.artifacts
    }

    /// Worker threads across all live shards.
    pub fn workers(&self) -> usize {
        self.router.workers()
    }

    /// Array shards in the cluster (drained shards included — ids are
    /// stable for the deployment's lifetime).
    pub fn shards(&self) -> usize {
        self.router.shards()
    }

    fn ensure_deployed(&self, artifact: &str) -> Result<()> {
        if self.artifacts.iter().any(|a| a == artifact) {
            return Ok(());
        }
        bail!(
            "artifact {artifact:?} is not part of this deployment (deployed: {})",
            self.artifacts.join(", ")
        )
    }

    /// Submit one job to the primary (first-deployed) design.
    pub fn submit(&self, inputs: Vec<Tensor>) -> Result<Pending> {
        let artifact = self.artifacts[0].clone();
        Ok(self.router.submit(&artifact, inputs)?)
    }

    /// Submit one job to a specific deployed artifact. Backpressure
    /// applies: a saturated admission queue surfaces as an error after
    /// the bounded wait instead of blocking forever.
    pub fn submit_to(&self, artifact: &str, inputs: Vec<Tensor>) -> Result<Pending> {
        self.ensure_deployed(artifact)?;
        Ok(self.router.submit(artifact, inputs)?)
    }

    /// [`Deployment::submit_to`] with a stream/tenant tag, carried into
    /// the [`JobResult`] and the report's per-stream attribution.
    pub fn submit_stream_to(
        &self,
        artifact: &str,
        stream: u64,
        inputs: Vec<Tensor>,
    ) -> Result<Pending> {
        self.ensure_deployed(artifact)?;
        Ok(self.router.submit_stream(artifact, stream, inputs)?)
    }

    /// Synchronous one-job round trip on the primary design: submit,
    /// wait, unwrap the outputs (exec-style validation and smoke tests).
    pub fn execute(&self, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        self.submit(inputs)?.wait()?.outputs
    }

    /// Drive an open-loop arrival stream against the deployment; a
    /// saturated cluster sheds the job (second return value) instead of
    /// stalling the arrival clock. Every arrival's artifact is checked
    /// against the deployed set up front — same typed guarantee as
    /// [`Deployment::submit_to`] — before the clock starts.
    pub fn open_loop(
        &self,
        arrivals: impl IntoIterator<Item = (f64, &'static str, Vec<Tensor>)>,
    ) -> Result<(Vec<JobResult>, u64)> {
        self.open_loop_streams(
            arrivals.into_iter().map(|(at, artifact, inputs)| (at, artifact.to_string(), 0, inputs)),
        )
    }

    /// [`Deployment::open_loop`] with stream/tenant tags: arrivals are
    /// `(at_secs, artifact, stream, inputs)` — the shape
    /// `workload::open_loop_stream` produces — so the merged report can
    /// attribute jobs per stream.
    pub fn open_loop_streams(
        &self,
        arrivals: impl IntoIterator<Item = (f64, String, u64, Vec<Tensor>)>,
    ) -> Result<(Vec<JobResult>, u64)> {
        let arrivals: Vec<_> = arrivals.into_iter().collect();
        for (_, artifact, _, _) in &arrivals {
            self.ensure_deployed(artifact)?;
        }
        route_open_loop(&self.router, arrivals)
    }

    /// Gracefully retire one shard: stop admitting on it, flush its
    /// queue (every already-admitted job keeps its reply), join its
    /// threads, and fold its ledger into the final cluster report. The
    /// remaining shards keep serving.
    pub fn drain_shard(&mut self, shard: usize) -> Result<ShardReport> {
        self.router.drain(shard)
    }

    /// Close admission, drain every shard, join the workers, and
    /// return the run's merged cluster [`ServeReport`].
    pub fn shutdown(self) -> Result<ServeReport> {
        self.router.shutdown()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::designs;

    #[test]
    fn empty_deployment_rejected() {
        assert!(Deployment::start(&[], &DeployOptions::default()).is_err());
    }

    #[test]
    fn zero_shards_rejected() {
        let opts = DeployOptions { shards: 0, ..DeployOptions::default() };
        assert!(Deployment::start(&[designs::mm()], &opts).is_err());
    }

    #[test]
    fn undeployed_artifact_is_a_typed_error() {
        let opts = DeployOptions { workers: 1, ..DeployOptions::default() };
        let dep = designs::mm().deploy(&opts).unwrap();
        assert_eq!(dep.artifacts(), &["mm_pu128".to_string()]);
        let err = dep.submit_to("fft1024", Vec::new()).unwrap_err().to_string();
        assert!(err.contains("fft1024") && err.contains("mm_pu128"), "{err}");
        // the open-loop path enforces the same contract up front
        let err = dep
            .open_loop([(0.0, "fft1024", Vec::new())])
            .unwrap_err()
            .to_string();
        assert!(err.contains("fft1024"), "{err}");
        dep.shutdown().unwrap();
    }

    #[test]
    fn duplicate_designs_deploy_one_artifact_lane() {
        let opts = DeployOptions { workers: 1, ..DeployOptions::default() };
        let dep =
            Deployment::start(&[designs::mm(), designs::mm()], &opts).unwrap();
        assert_eq!(dep.artifacts().len(), 1);
        dep.shutdown().unwrap();
    }
}
