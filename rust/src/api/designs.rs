//! The shipped accelerator catalogue as ~15-line builder calls — the
//! paper's four evaluation designs (Table 4 / Fig 7) expressed through
//! the design-entry API, one [`Design`] each. These are the same PU
//! structures `apps::*` simulate and `configs/*.json` serialize (a
//! facade test pins all three representations together); they are what
//! `ea4rca serve` deploys.

use anyhow::{bail, Result};

use crate::engine::compute::dac::DacMode;
use crate::engine::compute::dcc::DccMode;
use crate::sim::core::{fft_ops, filter_ops, KernelClass};

use super::design::Design;

/// The MM accelerator (Fig 7a): Parallel<16>*Cascade<4> PUs, SWH+BDC
/// in, SWH out, 6 copies at 96% of the array.
pub fn mm() -> Design {
    Design::for_algorithm("mm")
        .kernel("mm32")
        .class(KernelClass::F32Mac)
        .pst(|p| {
            p.dac(&[DacMode::Swh, DacMode::Bdc], 8, 64)
                .cc("Parallel<16>*Cascade<4>")
                .dcc(DccMode::Swh, 4, 64)
        })
        .ops_per_iter(2.0 * 128.0 * 128.0 * 128.0)
        .wire_bytes(2 * 128 * 128 * 4, 128 * 128 * 4)
        .copies(6)
        .build()
        .expect("the paper's MM design always builds")
}

/// The Filter2D accelerator (Fig 7b): Parallel<8> PUs filtering one
/// 32x32 tile (+2px halo) per core, 44 copies.
pub fn filter2d() -> Design {
    Design::for_algorithm("filter2d")
        .kernel("filter2d")
        .class(KernelClass::I32Mac)
        .pst(|p| {
            p.dac(&[DacMode::Swh], 1, 8)
                .cc("Parallel<8>*Single")
                .dcc(DccMode::Swh, 1, 8)
        })
        .ops_per_iter(8.0 * filter_ops(32 * 32, 5))
        .wire_bytes(8 * 36 * 36, 8 * 32 * 32)
        .copies(44)
        .build()
        .expect("the paper's Filter2D design always builds")
}

/// The FFT accelerator (Fig 7c) for `n`-point tasks: Butterfly[4] stage
/// group handing off to Parallel<2>*Cascade<3> over the stream fabric,
/// DIR ports serializing input and output, 8 copies. Errors on a
/// non-power-of-two size.
pub fn fft(n: usize) -> Result<Design> {
    if !n.is_power_of_two() || n < 2 {
        bail!("FFT size must be a power of two >= 2, got {n}");
    }
    Design::for_algorithm("fft")
        .kernel("fft")
        .class(KernelClass::Cint16Butterfly)
        .pst(|p| p.dac(&[DacMode::Bdc], 1, 4).cc("Butterfly[4]").dcc(DccMode::Dir, 1, 1))
        .pst(|p| {
            p.dac(&[DacMode::Dir], 1, 1)
                .cc("Parallel<2>*Cascade<3>")
                .dcc(DccMode::Dir, 1, 1)
        })
        .ops_per_iter(fft_ops(n))
        .wire_bytes(n * 4, n * 4)
        .serial_comm(true)
        .handoff_bytes(n * 4)
        .artifact(format!("fft{n}"))
        .copies(8)
        .build()
}

/// MM-T (Table 9): 50 Cascade<8> chains saturating the array, data
/// resident (nothing on the wire per iteration). Its per-core kernel is
/// `mm32`; the PU-level artifact is the chained `mmt_cascade8`.
pub fn mmt() -> Design {
    Design::for_algorithm("mmt")
        .kernel("mm32")
        .class(KernelClass::F32Mac)
        .pst(|p| p.dac(&[DacMode::Dir], 1, 1).cc("Cascade<8>").dcc(DccMode::Dir, 1, 1))
        .ops_per_iter(8.0 * 2.0 * 32.0 * 32.0 * 32.0)
        .wire_bytes(0, 0)
        .artifact("mmt_cascade8")
        .copies(50)
        .build()
        .expect("the paper's MM-T design always builds")
}

/// Every serving design `ea4rca serve` deploys (the workload mixes'
/// artifact vocabulary: mm_pu128, filter2d_pu8, fft1024, mmt_cascade8).
pub fn catalogue() -> Vec<Design> {
    vec![mm(), filter2d(), fft(1024).expect("1024 is a power of two"), mmt()]
}

/// CLI-facing lookup: the design behind an `--app` name — the single
/// place the app vocabulary maps to designs, shared by `run`'s
/// cross-check and `exec`. Only the FFT design depends on a size
/// (`fft_points`); the others ignore it.
pub fn for_app(app: &str, fft_points: usize) -> Result<Design> {
    Ok(match app {
        "mm" => mm(),
        "filter2d" => filter2d(),
        "fft" => fft(fft_points)?,
        "mmt" => mmt(),
        other => bail!("unknown app {other:?} (known: mm, filter2d, fft, mmt)"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalogue_covers_the_serving_artifacts() {
        let arts: Vec<&str> = vec!["mm_pu128", "filter2d_pu8", "fft1024", "mmt_cascade8"];
        let designs = catalogue();
        assert_eq!(designs.len(), arts.len());
        for (d, a) in designs.iter().zip(arts) {
            assert_eq!(d.artifact(), a);
        }
    }

    #[test]
    fn fft_rejects_ragged_sizes() {
        assert!(fft(1000).is_err());
        assert!(fft(0).is_err());
        assert_eq!(fft(4096).unwrap().artifact(), "fft4096");
    }

    // NOTE: parity with the apps' PU constructors and the shipped
    // configs/*.json is pinned by the integration suite
    // (rust/tests/api_facade.rs::builder_json_and_apps_agree), which
    // exercises all three representations in one place.
}
