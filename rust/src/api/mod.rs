//! The top-down design-entry API — one typed pipeline from "describe
//! the RCA algorithm" to "serve traffic", the paper's customized design
//! framework (§3) as a programmable facade.
//!
//! ```text
//! DesignBuilder ──build()──> Design ──┬── generate()  AIE graph project + pu_config.json
//!   (fluent, typed)   ^               ├── predict()   AIE cost model (no runtime needed)
//!                     │               ├── report()    Controller RunReport row (sim + power)
//!  JSON frontend ─────┘               ├── runtime()   warmed numerics runtime
//!  (from_path / from_json_text,       └── deploy() ─> Deployment (leader/worker serving,
//!   to_json round-trip)                               typed submit, shutdown -> ServeReport)
//! ```
//!
//! A design is described once — kernel, arithmetic class, the DAC/CC/DCC
//! processing structures, per-iteration op/byte facts, deployed copies —
//! and every downstream stage (code generation, performance prediction,
//! table-style simulation reports, serving) hangs off the resulting
//! [`Design`]. Graph Configuration Files are just the other frontend of
//! the same object: [`Design::from_path`] parses them,
//! [`Design::to_json`] writes them back, and both frontends share one
//! validation (PU structure, Kernel Manager membership, class match).
//!
//! The shipped accelerators live in [`designs`] as builder calls; a new
//! workload is one more ~20-line builder chain, not a JSON file plus
//! hand-wired glue:
//!
//! ```
//! use ea4rca::api::{designs, DeployOptions};
//!
//! // predict before deploying: the event-driven AIE cost model needs
//! // no runtime, no artifacts, no server
//! let fft = designs::fft(1024)?;
//! let one = fft.predict(1);
//! let eight = fft.predict(8);
//! assert!(eight.per_job_secs() <= one.per_job_secs());
//!
//! // deploy and serve through the same object
//! let dep = fft.deploy(&DeployOptions { workers: 1, ..Default::default() })?;
//! let mut rng = ea4rca::util::rng::Rng::new(7);
//! let inputs = ea4rca::workload::TaskKind::Fft1024.gen_inputs(&mut rng);
//! let outputs = dep.execute(inputs)?;
//! assert_eq!(outputs[0].shape(), &[1024]);
//! let report = dep.shutdown()?;
//! assert_eq!(report.completed_jobs(), 1);
//! # Ok::<(), anyhow::Error>(())
//! ```

pub mod builder;
pub mod deploy;
pub mod designs;

mod design;

pub use builder::{DesignBuilder, PstBuilder};
pub use deploy::{DeployOptions, Deployment};
pub use design::{fuse, Design, Lane, ReportParams};
