//! # EA4RCA — Efficient AIE accelerator design framework for Regular
//! # Communication-Avoiding algorithms
//!
//! A reproduction of the paper's system (Zhang et al., cs.AR 2024) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1/2** (build-time Python, `python/compile/`): Pallas kernels
//!   for each accelerator's per-core subtask and JAX graphs for each PU,
//!   AOT-lowered once to `artifacts/*.hlo.txt`.
//! * **Layer 3** (this crate): the EA4RCA framework itself — computing
//!   engine ([`engine::compute`]), data engine ([`engine::data`]),
//!   controller/scheduler ([`coordinator`]), the AIE Graph code generator
//!   ([`codegen`]), the four accelerators ([`apps`]) and the SOTA
//!   baselines ([`baselines`]) — running over a calibrated VCK5000
//!   simulator ([`sim`]) with real numerics executed through PJRT
//!   ([`runtime`]).
//!
//! See DESIGN.md for the substitution table (what the paper ran on silicon
//! vs what this repo simulates) and EXPERIMENTS.md for paper-vs-measured
//! results for every table and figure.

pub mod apps;
pub mod baselines;
pub mod codegen;
pub mod coordinator;
pub mod engine;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod workload;

/// Crate version, exposed for the CLI.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
