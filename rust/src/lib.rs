//! # EA4RCA — Efficient AIE accelerator design framework for Regular
//! # Communication-Avoiding algorithms
//!
//! A reproduction of the paper's system (Zhang et al., cs.AR 2024) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1/2** (build-time Python, `python/compile/`): Pallas kernels
//!   for each accelerator's per-core subtask and JAX graphs for each PU,
//!   AOT-lowered once to `artifacts/*.hlo.txt`.
//! * **Layer 3** (this crate): the EA4RCA framework itself — entered
//!   through the typed design facade ([`api`]: `DesignBuilder` →
//!   [`Design`] → [`Deployment`], with JSON configs as a second
//!   frontend of the same object) over the computing
//!   engine ([`engine::compute`]), data engine ([`engine::data`]),
//!   controller/scheduler ([`coordinator`]), the AIE Graph code generator
//!   ([`codegen`]), the static design-rule checker ([`analysis`], the
//!   `lint` subcommand), the four accelerators ([`apps`]) and the SOTA
//!   baselines ([`baselines`]) — running over a calibrated VCK5000
//!   simulator ([`sim`]) with real numerics executed through a pluggable
//!   [`runtime::Backend`]: the pure-Rust interpreter (default, hermetic),
//!   the sim backend (interpreter numerics + the event-driven AIE cost
//!   model, unifying the two stacks behind one artifact pipeline — see
//!   DESIGN.md "One artifact pipeline"), or the PJRT CPU client
//!   (`--features pjrt`).
//!
//! See DESIGN.md for the substitution table (what the paper ran on silicon
//! vs what this repo provides) and EXPERIMENTS.md for how to run the
//! tier-1 tests and regenerate the paper tables; README.md covers
//! building with and without the `pjrt` feature.

pub mod analysis;
pub mod api;
pub mod apps;
pub mod baselines;
pub mod codegen;
pub mod coordinator;
pub mod engine;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod workload;

pub use api::{DeployOptions, Deployment, Design, DesignBuilder};

/// Compiles the README's code examples as doctests, so the quick-start
/// builder chain cannot drift from the real API.
#[doc = include_str!("../../README.md")]
#[cfg(doctest)]
pub struct ReadmeDoctests;

/// Crate version, exposed for the CLI.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
