//! Lint drivers: walk configs and the design catalogue, collect one
//! [`Report`] per subject, and render a deterministic, golden-stable
//! text report for the `lint` CLI subcommand.

use std::path::Path;

use crate::api::{designs, Design};
use crate::codegen::config::PuConfig;
use crate::util::json::Json;

use super::serving::{check_placement, check_serving, ServeShape};
use super::{Diagnostic, Location, Report, RuleId, Severity};

/// The result of a lint run: one report per subject, in a stable
/// order (config files sorted by name, then catalogue designs, then
/// the serving shape).
#[derive(Debug, Default)]
pub struct Lint {
    pub subjects: Vec<(String, Report)>,
}

impl Lint {
    pub fn push(&mut self, origin: impl Into<String>, report: Report) {
        self.subjects.push((origin.into(), report));
    }

    pub fn count(&self, sev: Severity) -> usize {
        self.subjects.iter().map(|(_, r)| r.count(sev)).sum()
    }

    pub fn has_errors(&self) -> bool {
        self.subjects.iter().any(|(_, r)| r.has_errors())
    }

    /// Does any subject's report carry this rule?
    pub fn has(&self, rule: RuleId) -> bool {
        self.subjects.iter().any(|(_, r)| r.has(rule))
    }

    /// Render the whole run: subjects in order, findings sorted within
    /// each, and a one-line summary. Byte-stable for a given tree —
    /// origins are bare file names / design labels, never absolute
    /// paths — so goldens can pin it.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (origin, report) in &self.subjects {
            out.push_str(&format!("== {origin}\n"));
            if report.is_empty() {
                out.push_str("   OK\n");
                continue;
            }
            for d in report.sorted() {
                out.push_str(&format!("   {}\n", d.grouped_line()));
                if let Some(h) = &d.hint {
                    out.push_str(&format!("      hint: {h}\n"));
                }
            }
        }
        out.push_str(&format!(
            "lint: {} subjects checked, {} errors, {} warnings, {} infos\n",
            self.subjects.len(),
            self.count(Severity::Error),
            self.count(Severity::Warn),
            self.count(Severity::Info),
        ));
        out
    }
}

/// Lint one config's JSON text. Unparseable text is itself a finding
/// (DRC-000), not a driver error — `lint` never aborts mid-run.
pub fn lint_config_text(text: &str, origin: &str) -> Report {
    let root = match Json::parse(text) {
        Ok(root) => root,
        Err(e) => {
            let mut r = Report::new();
            r.push(Diagnostic::new(
                RuleId::ConfigInvalid,
                Location::new(origin),
                format!("not valid JSON: {e}"),
            ));
            return r;
        }
    };
    let artifact = root.get("artifact").and_then(Json::as_str).map(String::from);
    match PuConfig::from_json(&root) {
        Ok(cfg) => super::rules::check_config(&cfg, artifact.as_deref(), origin),
        Err(e) => {
            let mut r = Report::new();
            r.push(Diagnostic::new(
                RuleId::ConfigInvalid,
                Location::new(origin),
                format!("not a PU config: {e:#}"),
            ));
            r
        }
    }
}

/// Lint one config file. The subject label is the bare file name so
/// reports stay path-independent.
pub fn lint_path(path: &Path) -> Report {
    let origin = path
        .file_name()
        .and_then(|n| n.to_str())
        .unwrap_or("<config>")
        .to_string();
    match std::fs::read_to_string(path) {
        Ok(text) => lint_config_text(&text, &origin),
        Err(e) => {
            let mut r = Report::new();
            r.push(Diagnostic::new(
                RuleId::ConfigInvalid,
                Location::new(origin),
                format!("unreadable: {e}"),
            ));
            r
        }
    }
}

/// Lint a validated [`Design`] (catalogue entries, `--app` designs).
pub fn lint_design(d: &Design) -> Report {
    super::rules::check_design(d)
}

/// The `lint --all` sweep: every `*.json` under `configs_dir` (sorted
/// by file name), the four catalogue designs, and the serving shape
/// linted against the catalogue with its replicated placement map.
pub fn lint_all(configs_dir: &Path, shape: &ServeShape) -> Lint {
    let mut lint = Lint::default();

    let mut files: Vec<std::path::PathBuf> = Vec::new();
    match std::fs::read_dir(configs_dir) {
        Ok(entries) => {
            for entry in entries.flatten() {
                let p = entry.path();
                if p.extension().map(|e| e == "json").unwrap_or(false) {
                    files.push(p);
                }
            }
        }
        Err(e) => {
            let mut r = Report::new();
            r.push(Diagnostic::new(
                RuleId::ConfigInvalid,
                Location::new(configs_dir.display().to_string()),
                format!("cannot list config directory: {e}"),
            ));
            lint.push(configs_dir.display().to_string(), r);
        }
    }
    files.sort();
    for path in &files {
        let origin = path
            .file_name()
            .and_then(|n| n.to_str())
            .unwrap_or("<config>")
            .to_string();
        lint.push(origin, lint_path(path));
    }

    let catalogue = designs::catalogue();
    for d in &catalogue {
        lint.push(format!("design({})", d.name()), lint_design(d));
    }

    // The serving shape over the catalogue, with the same replicated
    // placement `Deployment::start` would build.
    let mut artifacts: Vec<String> = Vec::new();
    for d in &catalogue {
        if !artifacts.iter().any(|a| a == d.artifact()) {
            artifacts.push(d.artifact().to_string());
        }
    }
    let placement = vec![artifacts.clone(); shape.shards];
    let label = shape.label();
    let mut report = check_serving(&catalogue, shape, &label);
    report.merge(check_placement(&artifacts, &placement, &label));
    lint.push(label, report);

    lint
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn garbage_text_is_a_config_invalid_finding() {
        let r = lint_config_text("not json at all", "junk.json");
        assert!(r.has(RuleId::ConfigInvalid));
        assert!(r.has_errors());
        let r = lint_config_text(r#"{"name": "x"}"#, "partial.json");
        assert!(r.has(RuleId::ConfigInvalid), "{:?}", r.sorted());
    }

    #[test]
    fn missing_file_is_a_finding_not_a_panic() {
        let r = lint_path(Path::new("/no/such/config.json"));
        assert!(r.has(RuleId::ConfigInvalid));
    }

    #[test]
    fn render_is_grouped_with_summary() {
        let mut lint = Lint::default();
        lint.push("clean.json", Report::new());
        let mut bad = Report::new();
        bad.push(Diagnostic::new(
            RuleId::ArrayBudget,
            Location::new("bad.json"),
            "too many cores",
        ));
        lint.push("bad.json", bad);
        let text = lint.render();
        assert!(text.contains("== clean.json\n   OK\n"), "{text}");
        assert!(text.contains("== bad.json\n   error[DRC-001]"), "{text}");
        assert!(
            text.ends_with("lint: 2 subjects checked, 1 errors, 0 warnings, 0 infos\n"),
            "{text}"
        );
    }
}
