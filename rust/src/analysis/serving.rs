//! Serving-layer lints: cluster shapes and placement maps that are
//! legal to construct but can never serve well — stranded artifacts,
//! zero-capacity dimensions, batches that outgrow the queue, and
//! declared arrival rates the predicted service capacity cannot match.

use crate::api::Design;
use crate::coordinator::ServerConfig;
use crate::sim::params::HwParams;

use super::{Diagnostic, Location, Report, RuleId};

/// The serving shape the lints reason about: the cluster dimensions of
/// `DeployOptions`/`ClusterConfig` plus an optional declared open-loop
/// arrival rate (`--rate`, jobs/s; 0 = closed-loop, no rate lint).
#[derive(Debug, Clone, PartialEq)]
pub struct ServeShape {
    pub shards: usize,
    pub workers: usize,
    pub max_batch: usize,
    pub queue_cap: usize,
    pub rate: f64,
}

impl Default for ServeShape {
    /// One shard with the stock per-shard tuning, closed loop.
    fn default() -> Self {
        let sc = ServerConfig::default();
        ServeShape {
            shards: 1,
            workers: sc.n_workers,
            max_batch: sc.max_batch,
            queue_cap: sc.queue_cap,
            rate: 0.0,
        }
    }
}

impl ServeShape {
    /// Deterministic subject label for lint reports and goldens.
    pub fn label(&self) -> String {
        let rate = if self.rate > 0.0 {
            format!("{}/s", self.rate)
        } else {
            "closed".to_string()
        };
        format!(
            "serving(shards={}, workers={}, batch={}, queue={}, rate={rate})",
            self.shards, self.workers, self.max_batch, self.queue_cap
        )
    }
}

/// Lint a serving shape against the designs it would carry.
pub fn check_serving(designs: &[Design], shape: &ServeShape, origin: &str) -> Report {
    let mut r = Report::new();

    // DRC-105: a zero dimension means the cluster can serve nothing
    // (or `Router::start` fails outright).
    for (dim, value) in [
        ("shards", shape.shards),
        ("workers", shape.workers),
        ("max_batch", shape.max_batch),
        ("queue_cap", shape.queue_cap),
    ] {
        if value == 0 {
            r.push(
                Diagnostic::new(
                    RuleId::ZeroCapacity,
                    Location::at(origin, dim),
                    format!("{dim} is 0; the cluster cannot serve"),
                )
                .hint("every serving dimension must be >= 1"),
            );
        }
    }
    let dims_ok = shape.shards > 0
        && shape.workers > 0
        && shape.max_batch > 0
        && shape.queue_cap > 0;

    // DRC-104: the dispatcher can never coalesce a full batch if the
    // admission queue cannot even hold one.
    if shape.max_batch > shape.queue_cap && shape.queue_cap > 0 {
        r.push(
            Diagnostic::new(
                RuleId::BatchExceedsQueue,
                Location::new(origin),
                format!(
                    "max_batch {} exceeds queue_cap {}; full batches can never form",
                    shape.max_batch, shape.queue_cap
                ),
            )
            .hint("raise queue_cap or lower max_batch"),
        );
    }

    // DRC-106: declared open-loop rate vs predicted service capacity.
    // Capacity = shards x workers x mean per-design batch throughput,
    // straight off the cost model (no runtime needed). A rate above it
    // guarantees the queue fills and jobs shed.
    if shape.rate > 0.0 && dims_ok && !designs.is_empty() {
        let p = HwParams::vck5000();
        let mean_tput = designs
            .iter()
            .map(|d| {
                let pred = d.predict_on(&p, shape.max_batch);
                shape.max_batch as f64 / pred.latency_secs.max(1e-12)
            })
            .sum::<f64>()
            / designs.len() as f64;
        let capacity = (shape.shards * shape.workers) as f64 * mean_tput;
        if shape.rate > capacity {
            let fill_secs =
                (shape.shards * shape.queue_cap) as f64 / (shape.rate - capacity);
            r.push(
                Diagnostic::new(
                    RuleId::RateOverload,
                    Location::new(origin),
                    format!(
                        "declared rate {:.0} jobs/s exceeds predicted capacity \
                         {capacity:.0} jobs/s; queues fill in ~{:.1} ms and \
                         arrivals shed",
                        shape.rate,
                        fill_secs * 1e3
                    ),
                )
                .hint("add shards/workers, raise max_batch, or lower the rate"),
            );
        }
    }

    r
}

/// Lint a placement map (`placement[shard] = artifacts served there`)
/// against the artifact set a deployment carries.
pub fn check_placement(
    artifacts: &[String],
    placement: &[Vec<String>],
    origin: &str,
) -> Report {
    let mut r = Report::new();

    // DRC-101: an artifact on no shard is undeployable — every submit
    // for it is rejected even though the deployment "carries" it.
    for a in artifacts {
        if !placement.iter().any(|shard| shard.contains(a)) {
            r.push(
                Diagnostic::new(
                    RuleId::PlacementStranded,
                    Location::new(origin),
                    format!("artifact {a:?} is on no shard's placement map"),
                )
                .hint("place the artifact on at least one shard or drop its design"),
            );
        }
    }

    for (si, shard) in placement.iter().enumerate() {
        // DRC-102: a shard that serves nothing still burns workers.
        if shard.is_empty() {
            r.push(
                Diagnostic::new(
                    RuleId::PlacementEmptyShard,
                    Location::at(origin, format!("shard#{si}")),
                    "shard placement map is empty; its workers serve nothing"
                        .to_string(),
                )
                .hint("place at least one artifact on the shard or drop it"),
            );
        }
        // DRC-103: a placed name outside the deploy set is dead config.
        for name in shard {
            if !artifacts.iter().any(|a| a == name) {
                r.push(
                    Diagnostic::new(
                        RuleId::PlacementUnknownArtifact,
                        Location::at(origin, format!("shard#{si}")),
                        format!("placement names {name:?}, which the deployment does not carry"),
                    )
                    .hint("placement maps may only name deployed artifacts"),
                );
            }
        }
    }

    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::designs;

    #[test]
    fn default_shape_is_clean() {
        let r = check_serving(&designs::catalogue(), &ServeShape::default(), "serve");
        assert!(r.is_empty(), "{:?}", r.sorted());
    }

    #[test]
    fn zero_workers_is_an_error() {
        let shape = ServeShape { workers: 0, ..ServeShape::default() };
        let r = check_serving(&designs::catalogue(), &shape, "serve");
        assert!(r.has(RuleId::ZeroCapacity));
        assert!(r.has_errors());
    }

    #[test]
    fn absurd_rate_warns_with_fill_time() {
        let shape = ServeShape { rate: 1e9, ..ServeShape::default() };
        let r = check_serving(&designs::catalogue(), &shape, "serve");
        assert!(r.has(RuleId::RateOverload), "{:?}", r.sorted());
        assert!(!r.has_errors(), "rate overload is a warning");
        let d = r.iter().find(|d| d.rule == RuleId::RateOverload).unwrap();
        assert!(d.message.contains("ms"), "{}", d.message);
    }

    #[test]
    fn placement_lints_fire() {
        let arts = vec!["mm_pu128".to_string(), "fft1024".to_string()];
        let placement = vec![
            vec!["mm_pu128".to_string(), "ghost".to_string()],
            Vec::new(),
        ];
        let r = check_placement(&arts, &placement, "deployment");
        assert!(r.has(RuleId::PlacementStranded)); // fft1024 nowhere
        assert!(r.has(RuleId::PlacementEmptyShard)); // shard#1
        assert!(r.has(RuleId::PlacementUnknownArtifact)); // ghost
    }

    #[test]
    fn label_is_deterministic() {
        assert_eq!(
            ServeShape::default().label(),
            "serving(shards=1, workers=4, batch=8, queue=256, rate=closed)"
        );
        let open = ServeShape { rate: 2000.0, ..ServeShape::default() };
        assert_eq!(
            open.label(),
            "serving(shards=1, workers=4, batch=8, queue=256, rate=2000/s)"
        );
    }
}
