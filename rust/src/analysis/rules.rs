//! The per-design rule set: budgets, placeability, port arithmetic,
//! kernel-catalogue compatibility, cost-model smells, and wiring
//! audits of the emitted graph code.
//!
//! Everything here is total and static: no rule panics, touches a
//! runtime, or stops at the first finding — a bad design gets *all*
//! of its diagnostics in one pass, which is what makes this usable as
//! the autotuner's pruning oracle.

use crate::api::Design;
use crate::codegen::config::PuConfig;
use crate::codegen::generator;
use crate::codegen::repository;
use crate::runtime::Manifest;
use crate::sim::array::AieArray;
use crate::sim::params::HwParams;

use super::{Diagnostic, Location, Report, RuleId};

/// Check a validated [`Design`] (the `Design::check()` facade): the
/// full config rule set with the design's resolved artifact.
pub fn check_design(d: &Design) -> Report {
    check_config_on(
        &HwParams::vck5000(),
        d.config(),
        Some(d.artifact()),
        &format!("design({})", d.name()),
    )
}

/// Check a raw config against the VCK5000. `artifact` is the runtime
/// artifact override (a design's `.artifact(...)` / the JSON
/// `"artifact"` key); without it the Kernel Manager mapping applies.
pub fn check_config(cfg: &PuConfig, artifact: Option<&str>, origin: &str) -> Report {
    check_config_on(&HwParams::vck5000(), cfg, artifact, origin)
}

/// [`check_config`] against explicit hardware parameters.
pub fn check_config_on(
    p: &HwParams,
    cfg: &PuConfig,
    artifact: Option<&str>,
    origin: &str,
) -> Report {
    let mut r = Report::new();
    let cores = cfg.pu.cores();
    let total_cores = cores * cfg.copies;

    // DRC-001: raw core budget.
    if total_cores > p.total_aie {
        r.push(
            Diagnostic::new(
                RuleId::ArrayBudget,
                Location::new(origin),
                format!(
                    "{} copies x {cores} cores = {total_cores} AIE cores, \
                     but the array has {}",
                    cfg.copies, p.total_aie
                ),
            )
            .hint(format!(
                "at most {} copies of this PU fit the core budget",
                p.total_aie / cores.max(1)
            )),
        );
    }

    // DRC-002: PLIO budget.
    let plios = cfg.pu.total_plios();
    let total_plios = plios * cfg.copies;
    if total_plios > p.total_plio {
        r.push(
            Diagnostic::new(
                RuleId::PlioBudget,
                Location::new(origin),
                format!(
                    "{} copies x {plios} PLIOs = {total_plios} ports, \
                     but the device has {}",
                    cfg.copies, p.total_plio
                ),
            )
            .hint(format!(
                "at most {} copies of this PU fit the PLIO budget",
                p.total_plio / plios.max(1)
            )),
        );
    }

    // DRC-003: placement dry-run. Only meaningful when the raw budget
    // fits — an over-budget design already failed DRC-001 and would
    // trivially fail here too.
    if total_cores <= p.total_aie {
        let mut arr = AieArray::new(p);
        for copy in 0..cfg.copies {
            if let Err(e) = arr.place(cores) {
                r.push(
                    Diagnostic::new(
                        RuleId::UnplaceablePu,
                        Location::at(origin, format!("copy#{}", copy + 1)),
                        format!("placement dry-run failed: {e}"),
                    )
                    .hint(
                        "partial trailing columns fragment the array; prefer PU \
                         shapes that tile the 8-row column height",
                    ),
                );
                break;
            }
        }
    }

    // Per-PST structural rules.
    for (pi, pst) in cfg.pu.psts.iter().enumerate() {
        let cc_cores = pst.cc.cores();

        // DRC-004: cascade chains run along array rows; a chain longer
        // than one row span needs a turn, which costs an extra hop the
        // cost model does not see.
        let depth = pst.cc.chain_depth();
        if depth > p.array_rows {
            r.push(
                Diagnostic::new(
                    RuleId::CascadeLongChain,
                    Location::at(origin, format!("pst#{}", pi + 1)),
                    format!(
                        "cascade chain depth {depth} exceeds the {}-row column \
                         height; the chain must fold across columns",
                        p.array_rows
                    ),
                )
                .hint(format!(
                    "split into Parallel<n>*Cascade<k> with k <= {}",
                    p.array_rows
                )),
            );
        }

        // DRC-005: per-DAC/DCC port oversubscription.
        for (di, dac) in pst.dacs.iter().enumerate() {
            if dac.plios > dac.serves_cores {
                r.push(
                    Diagnostic::new(
                        RuleId::PlioOversubscribed,
                        Location::at(origin, format!("pst#{}/dac#{di}", pi + 1)),
                        format!(
                            "DAC {} has {} PLIOs but serves only {} cores",
                            dac.label(),
                            dac.plios,
                            dac.serves_cores
                        ),
                    )
                    .hint("each PLIO wire needs its own leader core: plios <= serves"),
                );
            }
        }
        for (di, dcc) in pst.dccs.iter().enumerate() {
            if dcc.plios > dcc.serves_cores {
                r.push(
                    Diagnostic::new(
                        RuleId::PlioOversubscribed,
                        Location::at(origin, format!("pst#{}/dcc#{di}", pi + 1)),
                        format!(
                            "DCC {} has {} PLIOs but serves only {} cores",
                            dcc.mode.name(),
                            dcc.plios,
                            dcc.serves_cores
                        ),
                    )
                    .hint("each PLIO wire needs its own leader core: plios <= serves"),
                );
            }
        }

        // DRC-006: serve-slice sums past the CC's kernel array.
        let dac_serves: usize = pst.dacs.iter().map(|d| d.serves_cores).sum();
        if dac_serves > cc_cores {
            r.push(
                Diagnostic::new(
                    RuleId::CoreSliceOverrun,
                    Location::at(origin, format!("pst#{}/dacs", pi + 1)),
                    format!(
                        "DACs serve {dac_serves} cores in total but the CC has {cc_cores}"
                    ),
                )
                .hint("DAC core slices are disjoint; their serves must sum to <= CC cores"),
            );
        }
        let dcc_serves: usize = pst.dccs.iter().map(|d| d.serves_cores).sum();
        if dcc_serves > cc_cores {
            r.push(
                Diagnostic::new(
                    RuleId::CoreSliceOverrun,
                    Location::at(origin, format!("pst#{}/dccs", pi + 1)),
                    format!(
                        "DCCs serve {dcc_serves} cores in total but the CC has {cc_cores}"
                    ),
                )
                .hint("DCC core slices are disjoint; their serves must sum to <= CC cores"),
            );
        }
    }

    // DRC-007/008: Kernel Manager compatibility.
    let mut resolved_artifact = artifact.map(String::from);
    match repository::find_kernel(&cfg.kernel) {
        None => {
            let known: Vec<&str> =
                repository::kernel_catalogue().iter().map(|k| k.name).collect();
            r.push(
                Diagnostic::new(
                    RuleId::KernelUnknown,
                    Location::new(origin),
                    format!("kernel {:?} is not in the kernel catalogue", cfg.kernel),
                )
                .hint(format!("known kernels: {}", known.join(", "))),
            );
        }
        Some(info) => {
            if info.class != cfg.pu.class {
                r.push(
                    Diagnostic::new(
                        RuleId::KernelClassMismatch,
                        Location::new(origin),
                        format!(
                            "config class {:?} does not match kernel {:?}'s class {:?}",
                            cfg.pu.class, cfg.kernel, info.class
                        ),
                    )
                    .hint("pick a kernel of the config's class or fix the class field"),
                );
            }
            if resolved_artifact.is_none() {
                resolved_artifact = Some(info.artifact.to_string());
            }
        }
    }

    // DRC-009: the resolved artifact should exist in the builtin
    // manifest, or serving will only work with a custom artifact dir.
    if let Some(name) = &resolved_artifact {
        if Manifest::builtin(Manifest::default_dir()).get(name).is_err() {
            r.push(
                Diagnostic::new(
                    RuleId::ArtifactNotBuiltin,
                    Location::new(origin),
                    format!("artifact {name:?} is not a builtin manifest entry"),
                )
                .hint("deployment needs a manifest that carries this artifact"),
            );
        }
    }

    // DRC-010: comm-bound designs waste the array (the paper's whole
    // point is communication avoidance).
    let io_bytes = cfg.pu.in_bytes_per_iter + cfg.pu.out_bytes_per_iter;
    if io_bytes > 0 {
        let comm = cfg.pu.comm_secs(p);
        let compute = cfg.pu.compute_secs(p);
        if comm > compute {
            r.push(
                Diagnostic::new(
                    RuleId::CommBound,
                    Location::new(origin),
                    format!(
                        "communication {:.2} us exceeds compute {:.2} us per iteration",
                        comm * 1e6,
                        compute * 1e6
                    ),
                )
                .hint("add PLIOs, shrink the per-iteration tile, or raise ops_per_iter"),
            );
        }
    }

    // DRC-011: double-buffered per-core tile I/O vs core-local memory.
    if cores > 0 {
        let per_core = 2 * io_bytes / cores;
        if per_core > p.core_mem_bytes {
            r.push(
                Diagnostic::new(
                    RuleId::CoreMemOverflow,
                    Location::new(origin),
                    format!(
                        "double-buffered tile I/O needs ~{per_core} B per core, \
                         but cores have {} B",
                        p.core_mem_bytes
                    ),
                )
                .hint("shrink the per-iteration tile or spread it over more cores"),
            );
        }
    }

    // DRC-012..014: the graph code generator and its emitted wiring.
    match generator::generate(cfg) {
        Err(e) => {
            r.push(
                Diagnostic::new(
                    RuleId::GraphEmitFailed,
                    Location::new(origin),
                    format!("graph code generator refused the config: {e:#}"),
                )
                .hint("fix the port/slice arithmetic the generator reported"),
            );
        }
        Ok(proj) => {
            r.merge(check_graph_text(&proj.graph_h, origin));
        }
    }

    r
}

/// Scraped shape of an emitted `graph.h`.
struct GraphShape {
    in_ports: usize,
    out_ports: usize,
    /// Kernel-array sizes per PST index.
    kernels: Vec<usize>,
}

fn scrape_graph(graph_h: &str) -> GraphShape {
    let mut shape = GraphShape { in_ports: 0, out_ports: 0, kernels: Vec::new() };
    for line in graph_h.lines() {
        let t = line.trim_start();
        if let Some(rest) = t.strip_prefix("input_plio  in[") {
            if let Some(n) = rest.strip_suffix("];").and_then(|s| s.parse().ok()) {
                shape.in_ports = n;
            }
        } else if let Some(rest) = t.strip_prefix("output_plio out[") {
            if let Some(n) = rest.strip_suffix("];").and_then(|s| s.parse().ok()) {
                shape.out_ports = n;
            }
        } else if let Some(rest) = t.strip_prefix("kernel k") {
            if let Some((pi, tail)) = rest.split_once('[') {
                if let (Ok(pi), Some(Ok(n))) = (
                    pi.parse::<usize>(),
                    tail.strip_suffix("];").map(|s| s.parse::<usize>()),
                ) {
                    if shape.kernels.len() <= pi {
                        shape.kernels.resize(pi + 1, 0);
                    }
                    shape.kernels[pi] = n;
                }
            }
        }
    }
    shape
}

/// Audit emitted ADF graph code (`graph.h` text) for wiring legality:
/// every declared PLIO port wired exactly once, every core's stream
/// `in[0]`/`out[0]` wired at most once. Cascade wires (loop-emitted,
/// index `base + i`) are inter-core accumulator links and exempt.
///
/// In the pipeline this runs on freshly generated output as a
/// regression net behind the generator's own validation; it equally
/// accepts hand-edited or stored graph text.
pub fn check_graph_text(graph_h: &str, origin: &str) -> Report {
    let mut r = Report::new();
    let shape = scrape_graph(graph_h);

    // PLIO ports: exactly one wire each.
    for port in 0..shape.in_ports {
        let pat = format!("(in[{port}].out[0],");
        match graph_h.matches(&pat).count() {
            0 => r.push(
                Diagnostic::new(
                    RuleId::GraphDanglingPort,
                    Location::at(origin, format!("in[{port}]")),
                    "declared input PLIO is never wired to a core".to_string(),
                )
                .hint("drop the port from the DAC plios count or wire it"),
            ),
            1 => {}
            n => r.push(
                Diagnostic::new(
                    RuleId::GraphDoubleWire,
                    Location::at(origin, format!("in[{port}]")),
                    format!("input PLIO is wired {n} times; ADF allows one"),
                ),
            ),
        }
    }
    for port in 0..shape.out_ports {
        let pat = format!(" out[{port}].in[0]);");
        match graph_h.matches(&pat).count() {
            0 => r.push(
                Diagnostic::new(
                    RuleId::GraphDanglingPort,
                    Location::at(origin, format!("out[{port}]")),
                    "declared output PLIO is never fed by a core".to_string(),
                )
                .hint("drop the port from the DCC plios count or wire it"),
            ),
            1 => {}
            n => r.push(
                Diagnostic::new(
                    RuleId::GraphDoubleWire,
                    Location::at(origin, format!("out[{port}]")),
                    format!("output PLIO is fed {n} times; ADF allows one"),
                ),
            ),
        }
    }

    // Core stream ports: at most one wire each (interior cores are fed
    // over cascade wires instead and legitimately have zero).
    for (pi, &cores) in shape.kernels.iter().enumerate() {
        for core in 0..cores {
            let feed = format!("k{pi}[{core}].in[0])");
            let n = graph_h.matches(&feed).count();
            if n > 1 {
                r.push(Diagnostic::new(
                    RuleId::GraphDoubleWire,
                    Location::at(origin, format!("k{pi}[{core}].in[0]")),
                    format!("core stream input is fed {n} times; ADF allows one"),
                ));
            }
            let drain = format!("connect<stream>(k{pi}[{core}].out[0]");
            let n = graph_h.matches(&drain).count();
            if n > 1 {
                r.push(Diagnostic::new(
                    RuleId::GraphDoubleWire,
                    Location::at(origin, format!("k{pi}[{core}].out[0]")),
                    format!("core stream output is drained {n} times; ADF allows one"),
                ));
            }
        }
    }

    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::designs;

    #[test]
    fn catalogue_designs_are_clean() {
        for d in designs::catalogue() {
            let r = check_design(&d);
            assert!(
                r.is_empty(),
                "design {} should be DRC-clean:\n{:?}",
                d.name(),
                r.sorted()
            );
        }
    }

    #[test]
    fn generated_catalogue_graphs_audit_clean() {
        for d in designs::catalogue() {
            let proj = generator::generate(d.config()).unwrap();
            let r = check_graph_text(&proj.graph_h, d.name());
            assert!(r.is_empty(), "{}: {:?}", d.name(), r.sorted());
        }
    }

    #[test]
    fn over_budget_copies_trip_array_budget() {
        let mut cfg = designs::mm().config().clone();
        cfg.copies = 7; // 7 x 64 = 448 > 400
        let r = check_config(&cfg, None, "mm7");
        assert!(r.has(RuleId::ArrayBudget), "{:?}", r.sorted());
        assert!(!r.has(RuleId::PlioBudget));
        assert!(r.has_errors());
    }

    #[test]
    fn fragmentation_trips_unplaceable_only() {
        // 12-core PUs (1.5 columns) consume a 2-column span each; 33
        // copies = 396 cores fit the raw budget but only 25 place.
        let cfg = PuConfig::from_json_text(
            r#"{
            "name": "frag", "kernel": "mm32", "class": "f32mac", "copies": 33,
            "psts": [{
                "dacs": [{"modes": ["SWH"], "plios": 1, "serves": 12}],
                "cc": "Parallel<4>*Cascade<3>",
                "dccs": [{"mode": "SWH", "plios": 1, "serves": 12}]
            }],
            "ops_per_iter": 786432, "in_bytes": 1024, "out_bytes": 1024
        }"#,
        )
        .unwrap();
        let r = check_config(&cfg, None, "frag");
        assert!(r.has(RuleId::UnplaceablePu), "{:?}", r.sorted());
        assert!(!r.has(RuleId::ArrayBudget));
        let diag = r.iter().find(|d| d.rule == RuleId::UnplaceablePu).unwrap();
        assert_eq!(diag.location.detail.as_deref(), Some("copy#26"));
    }
}
