//! Static design-rule checker (DRC).
//!
//! The codegen and serving layers validate eagerly but locally: an
//! over-budget PST or a placement map that strands an artifact only
//! surfaces as a runtime error deep inside `generate()`/`deploy()`.
//! This module is the opposite: a cheap, total, *static* pass over a
//! design (or raw config, or emitted graph text, or serving shape)
//! that reports **every** violated rule at once as structured
//! [`Diagnostic`]s, never panics, and never touches a runtime.
//!
//! Layering:
//! - [`rules`] — per-design rules (array/PLIO budgets, placement
//!   dry-run on [`crate::sim::array::AieArray`], port arithmetic,
//!   kernel catalogue checks, graph-wiring audits of emitted code).
//! - [`serving`] — cluster-shape lints (stranded artifacts, zero
//!   capacity, queue/batch interactions, declared-rate overload).
//! - [`lint`] — drivers that walk `configs/*.json` + the
//!   [`crate::api::designs`] catalogue and render deterministic,
//!   golden-stable reports for the `lint` CLI subcommand.
//!
//! Integration seams: `Design::check()` runs [`rules::check_design`];
//! `Design::generate()`/`deploy()` gate on it (errors fail with the
//! diagnostic text, warnings print to stderr); `lint --all` is part of
//! `make verify` and CI. The ROADMAP autotuner prunes with this same
//! oracle.

pub mod lint;
pub mod rules;
pub mod serving;

pub use lint::{lint_all, lint_config_text, lint_design, lint_path, Lint};
pub use rules::{check_config, check_config_on, check_design, check_graph_text};
pub use serving::{check_placement, check_serving, ServeShape};

use std::fmt;

/// How bad a finding is. Ordering is by decreasing severity so that
/// `Error < Warn < Info` sorts errors first in rendered reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Severity {
    Error,
    Warn,
    Info,
}

impl Severity {
    pub fn name(&self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warn => "warning",
            Severity::Info => "info",
        }
    }
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// The stable rule registry. Codes are permanent once shipped:
/// `DRC-0xx` are design/graph rules, `DRC-1xx` are serving rules.
/// Declaration order is sort order (derive `Ord`), and matches the
/// numeric code order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum RuleId {
    /// DRC-000: the config could not be parsed at all.
    ConfigInvalid,
    /// DRC-001: copies x PU cores exceed the AIE array core budget.
    ArrayBudget,
    /// DRC-002: copies x PU PLIOs exceed the device PLIO budget.
    PlioBudget,
    /// DRC-003: the PU footprint cannot be placed on the array even
    /// though the raw core budget fits (column-span fragmentation).
    UnplaceablePu,
    /// DRC-004: a CC cascade chain is longer than one array column.
    CascadeLongChain,
    /// DRC-005: a DAC/DCC declares more PLIOs than cores it serves.
    PlioOversubscribed,
    /// DRC-006: DAC or DCC serve ranges sum past the CC core count.
    CoreSliceOverrun,
    /// DRC-007: the named kernel is not in the kernel catalogue.
    KernelUnknown,
    /// DRC-008: the kernel's class does not match the PU class.
    KernelClassMismatch,
    /// DRC-009: the resolved artifact is not a builtin manifest entry.
    ArtifactNotBuiltin,
    /// DRC-010: predicted comm time exceeds compute time (comm-bound).
    CommBound,
    /// DRC-011: per-core tile I/O footprint exceeds core local memory.
    CoreMemOverflow,
    /// DRC-012: the graph code generator refused the config.
    GraphEmitFailed,
    /// DRC-013: a core port or PLIO is wired more than once in the
    /// emitted graph code.
    GraphDoubleWire,
    /// DRC-014: a declared PLIO port is never wired in the emitted
    /// graph code.
    GraphDanglingPort,
    /// DRC-101: an artifact in the deploy set is on no shard's
    /// placement map.
    PlacementStranded,
    /// DRC-102: a shard's placement map is empty (it can serve
    /// nothing).
    PlacementEmptyShard,
    /// DRC-103: a placement map names an artifact outside the deploy
    /// set.
    PlacementUnknownArtifact,
    /// DRC-104: max_batch exceeds queue_cap, so a full batch can never
    /// accumulate.
    BatchExceedsQueue,
    /// DRC-105: a serving dimension (shards/workers/queue/batch) is 0.
    ZeroCapacity,
    /// DRC-106: the declared arrival rate exceeds predicted service
    /// capacity, guaranteeing shedding once the queue fills.
    RateOverload,
}

impl RuleId {
    /// Every rule, in code order. Fixture tests iterate this to prove
    /// the registry stays sorted and collision-free.
    pub const ALL: [RuleId; 21] = [
        RuleId::ConfigInvalid,
        RuleId::ArrayBudget,
        RuleId::PlioBudget,
        RuleId::UnplaceablePu,
        RuleId::CascadeLongChain,
        RuleId::PlioOversubscribed,
        RuleId::CoreSliceOverrun,
        RuleId::KernelUnknown,
        RuleId::KernelClassMismatch,
        RuleId::ArtifactNotBuiltin,
        RuleId::CommBound,
        RuleId::CoreMemOverflow,
        RuleId::GraphEmitFailed,
        RuleId::GraphDoubleWire,
        RuleId::GraphDanglingPort,
        RuleId::PlacementStranded,
        RuleId::PlacementEmptyShard,
        RuleId::PlacementUnknownArtifact,
        RuleId::BatchExceedsQueue,
        RuleId::ZeroCapacity,
        RuleId::RateOverload,
    ];

    /// The stable `DRC-xxx` code.
    pub fn code(&self) -> &'static str {
        match self {
            RuleId::ConfigInvalid => "DRC-000",
            RuleId::ArrayBudget => "DRC-001",
            RuleId::PlioBudget => "DRC-002",
            RuleId::UnplaceablePu => "DRC-003",
            RuleId::CascadeLongChain => "DRC-004",
            RuleId::PlioOversubscribed => "DRC-005",
            RuleId::CoreSliceOverrun => "DRC-006",
            RuleId::KernelUnknown => "DRC-007",
            RuleId::KernelClassMismatch => "DRC-008",
            RuleId::ArtifactNotBuiltin => "DRC-009",
            RuleId::CommBound => "DRC-010",
            RuleId::CoreMemOverflow => "DRC-011",
            RuleId::GraphEmitFailed => "DRC-012",
            RuleId::GraphDoubleWire => "DRC-013",
            RuleId::GraphDanglingPort => "DRC-014",
            RuleId::PlacementStranded => "DRC-101",
            RuleId::PlacementEmptyShard => "DRC-102",
            RuleId::PlacementUnknownArtifact => "DRC-103",
            RuleId::BatchExceedsQueue => "DRC-104",
            RuleId::ZeroCapacity => "DRC-105",
            RuleId::RateOverload => "DRC-106",
        }
    }

    /// Short kebab-case slug used in rendered diagnostics.
    pub fn slug(&self) -> &'static str {
        match self {
            RuleId::ConfigInvalid => "config-invalid",
            RuleId::ArrayBudget => "array-core-budget",
            RuleId::PlioBudget => "plio-budget",
            RuleId::UnplaceablePu => "unplaceable-pu",
            RuleId::CascadeLongChain => "cascade-long-chain",
            RuleId::PlioOversubscribed => "plio-oversubscribed",
            RuleId::CoreSliceOverrun => "core-slice-overrun",
            RuleId::KernelUnknown => "kernel-unknown",
            RuleId::KernelClassMismatch => "kernel-class-mismatch",
            RuleId::ArtifactNotBuiltin => "artifact-not-builtin",
            RuleId::CommBound => "comm-bound",
            RuleId::CoreMemOverflow => "core-mem-overflow",
            RuleId::GraphEmitFailed => "graph-emit-failed",
            RuleId::GraphDoubleWire => "graph-double-wire",
            RuleId::GraphDanglingPort => "graph-dangling-port",
            RuleId::PlacementStranded => "placement-stranded",
            RuleId::PlacementEmptyShard => "placement-empty-shard",
            RuleId::PlacementUnknownArtifact => "placement-unknown-artifact",
            RuleId::BatchExceedsQueue => "batch-exceeds-queue",
            RuleId::ZeroCapacity => "zero-capacity",
            RuleId::RateOverload => "rate-overload",
        }
    }

    /// The severity every finding of this rule carries.
    pub fn severity(&self) -> Severity {
        match self {
            RuleId::ConfigInvalid
            | RuleId::ArrayBudget
            | RuleId::PlioBudget
            | RuleId::UnplaceablePu
            | RuleId::PlioOversubscribed
            | RuleId::CoreSliceOverrun
            | RuleId::KernelUnknown
            | RuleId::KernelClassMismatch
            | RuleId::GraphEmitFailed
            | RuleId::GraphDoubleWire
            | RuleId::GraphDanglingPort
            | RuleId::PlacementStranded
            | RuleId::ZeroCapacity => Severity::Error,
            RuleId::CascadeLongChain
            | RuleId::CommBound
            | RuleId::CoreMemOverflow
            | RuleId::PlacementEmptyShard
            | RuleId::PlacementUnknownArtifact
            | RuleId::BatchExceedsQueue
            | RuleId::RateOverload => Severity::Warn,
            RuleId::ArtifactNotBuiltin => Severity::Info,
        }
    }

    /// One-line description for `lint --rules` style listings.
    pub fn summary(&self) -> &'static str {
        match self {
            RuleId::ConfigInvalid => "config file does not parse as a PU config",
            RuleId::ArrayBudget => "copies x PU cores exceed the AIE array core budget",
            RuleId::PlioBudget => "copies x PU PLIOs exceed the device PLIO budget",
            RuleId::UnplaceablePu => "PU footprint cannot be placed (column fragmentation)",
            RuleId::CascadeLongChain => "CC cascade chain longer than one array column",
            RuleId::PlioOversubscribed => "DAC/DCC declares more PLIOs than cores it serves",
            RuleId::CoreSliceOverrun => "DAC/DCC serve ranges overrun the CC core count",
            RuleId::KernelUnknown => "kernel name not present in the kernel catalogue",
            RuleId::KernelClassMismatch => "kernel class incompatible with the PU class",
            RuleId::ArtifactNotBuiltin => "resolved artifact is not a builtin manifest entry",
            RuleId::CommBound => "predicted communication time exceeds compute time",
            RuleId::CoreMemOverflow => "per-core tile I/O exceeds core local memory",
            RuleId::GraphEmitFailed => "graph code generator refused the config",
            RuleId::GraphDoubleWire => "core port or PLIO wired more than once in graph code",
            RuleId::GraphDanglingPort => "declared PLIO port never wired in graph code",
            RuleId::PlacementStranded => "artifact deployed on no shard's placement map",
            RuleId::PlacementEmptyShard => "shard placement map is empty",
            RuleId::PlacementUnknownArtifact => "placement names an artifact outside the deploy set",
            RuleId::BatchExceedsQueue => "max_batch exceeds queue_cap; full batches never form",
            RuleId::ZeroCapacity => "a serving dimension (shards/workers/queue/batch) is zero",
            RuleId::RateOverload => "declared arrival rate exceeds predicted service capacity",
        }
    }
}

impl fmt::Display for RuleId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} {}", self.code(), self.slug())
    }
}

/// Where a finding points: a subject (`mm.json`, `design(fft)`,
/// `deployment`) plus an optional finer-grained detail
/// (`copy#26`, `pst#1/dac#0`, `shard#2`).
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Location {
    pub origin: String,
    pub detail: Option<String>,
}

impl Location {
    pub fn new(origin: impl Into<String>) -> Self {
        Location { origin: origin.into(), detail: None }
    }

    pub fn at(origin: impl Into<String>, detail: impl Into<String>) -> Self {
        Location { origin: origin.into(), detail: Some(detail.into()) }
    }
}

impl fmt::Display for Location {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.detail {
            Some(d) => write!(f, "{} ({})", self.origin, d),
            None => f.write_str(&self.origin),
        }
    }
}

/// One finding: a rule, where it fired, what happened, and (usually)
/// how to fix it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    pub rule: RuleId,
    pub severity: Severity,
    pub location: Location,
    pub message: String,
    pub hint: Option<String>,
}

impl Diagnostic {
    /// Severity is taken from the rule; it is per-rule, not per-site.
    pub fn new(rule: RuleId, location: Location, message: impl Into<String>) -> Self {
        Diagnostic {
            rule,
            severity: rule.severity(),
            location,
            message: message.into(),
            hint: None,
        }
    }

    pub fn hint(mut self, hint: impl Into<String>) -> Self {
        self.hint = Some(hint.into());
        self
    }

    /// Single-line form with the origin elided, for reports already
    /// grouped by subject. The detail (if any) stays.
    pub fn grouped_line(&self) -> String {
        match &self.location.detail {
            Some(d) => format!(
                "{}[{}] {} at {}: {}",
                self.severity,
                self.rule.code(),
                self.rule.slug(),
                d,
                self.message
            ),
            None => format!(
                "{}[{}] {}: {}",
                self.severity,
                self.rule.code(),
                self.rule.slug(),
                self.message
            ),
        }
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}[{}] {} at {}: {}",
            self.severity,
            self.rule.code(),
            self.rule.slug(),
            self.location,
            self.message
        )?;
        if let Some(h) = &self.hint {
            write!(f, "\n    hint: {h}")?;
        }
        Ok(())
    }
}

/// An ordered collection of findings for one or more subjects.
/// Rendering is deterministic: sorted by (origin, severity, rule,
/// detail, message) so golden tests can pin output byte-stable.
#[derive(Debug, Clone, Default)]
pub struct Report {
    diags: Vec<Diagnostic>,
}

impl Report {
    pub fn new() -> Self {
        Report::default()
    }

    pub fn push(&mut self, diag: Diagnostic) {
        self.diags.push(diag);
    }

    pub fn merge(&mut self, other: Report) {
        self.diags.extend(other.diags);
    }

    pub fn is_empty(&self) -> bool {
        self.diags.is_empty()
    }

    pub fn len(&self) -> usize {
        self.diags.len()
    }

    /// Findings in deterministic render order.
    pub fn sorted(&self) -> Vec<&Diagnostic> {
        let mut v: Vec<&Diagnostic> = self.diags.iter().collect();
        v.sort_by(|a, b| {
            (&a.location.origin, a.severity, a.rule, &a.location.detail, &a.message).cmp(&(
                &b.location.origin,
                b.severity,
                b.rule,
                &b.location.detail,
                &b.message,
            ))
        });
        v
    }

    pub fn iter(&self) -> impl Iterator<Item = &Diagnostic> {
        self.diags.iter()
    }

    /// Did any finding fire for this rule?
    pub fn has(&self, rule: RuleId) -> bool {
        self.diags.iter().any(|d| d.rule == rule)
    }

    pub fn has_errors(&self) -> bool {
        self.diags.iter().any(|d| d.severity == Severity::Error)
    }

    pub fn count(&self, sev: Severity) -> usize {
        self.diags.iter().filter(|d| d.severity == sev).count()
    }

    /// All Error-severity findings rendered one per line, sorted.
    pub fn render_errors(&self) -> String {
        let mut out = String::new();
        for d in self.sorted() {
            if d.severity == Severity::Error {
                out.push_str(&format!("  {d}\n"));
            }
        }
        out
    }

    /// Errors-fail / warnings-print gate used by `Design::generate()`
    /// and `Deployment::start`: non-error findings go to stderr, any
    /// error aborts with the full diagnostic text in the error chain.
    pub fn gate(&self, what: &str) -> anyhow::Result<()> {
        for d in self.sorted() {
            if d.severity != Severity::Error {
                eprintln!("{d}");
            }
        }
        if self.has_errors() {
            anyhow::bail!(
                "{what} fails the design-rule check:\n{}",
                self.render_errors().trim_end()
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_codes_unique_and_sorted() {
        let codes: Vec<&str> = RuleId::ALL.iter().map(|r| r.code()).collect();
        let mut dedup = codes.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), codes.len(), "duplicate rule codes");
        // Declaration order must match code order (Ord derives from it).
        let mut by_code = codes.clone();
        by_code.sort();
        assert_eq!(codes, by_code, "RuleId declaration order != code order");
        let mut slugs: Vec<&str> = RuleId::ALL.iter().map(|r| r.slug()).collect();
        slugs.sort();
        slugs.dedup();
        assert_eq!(slugs.len(), RuleId::ALL.len(), "duplicate rule slugs");
    }

    #[test]
    fn severity_orders_errors_first() {
        assert!(Severity::Error < Severity::Warn);
        assert!(Severity::Warn < Severity::Info);
    }

    #[test]
    fn report_sorts_and_gates() {
        let mut r = Report::new();
        r.push(Diagnostic::new(
            RuleId::CommBound,
            Location::new("b"),
            "warn here",
        ));
        r.push(
            Diagnostic::new(RuleId::ArrayBudget, Location::new("a"), "too big")
                .hint("reduce copies"),
        );
        let sorted = r.sorted();
        assert_eq!(sorted[0].rule, RuleId::ArrayBudget);
        assert!(r.has(RuleId::ArrayBudget));
        assert!(r.has_errors());
        assert_eq!(r.count(Severity::Error), 1);
        assert_eq!(r.count(Severity::Warn), 1);
        let err = r.gate("subject x").unwrap_err();
        let text = format!("{err:#}");
        assert!(text.contains("subject x fails the design-rule check"), "{text}");
        assert!(text.contains("DRC-001"), "{text}");
        assert!(text.contains("too big"), "{text}");
    }

    #[test]
    fn diagnostic_display_includes_hint_and_detail() {
        let d = Diagnostic::new(
            RuleId::PlioOversubscribed,
            Location::at("mm.json", "pst#0/dac#1"),
            "4 plios serve 2 cores",
        )
        .hint("drop plios to <= serves");
        let s = format!("{d}");
        assert!(s.contains("error[DRC-005] plio-oversubscribed at mm.json (pst#0/dac#1)"), "{s}");
        assert!(s.contains("hint: drop plios"), "{s}");
        assert!(d.grouped_line().starts_with("error[DRC-005] plio-oversubscribed at pst#0/dac#1:"));
    }
}
