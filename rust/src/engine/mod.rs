//! The two EA4RCA engines (paper §3, Figure 1):
//!
//! * [`compute`] — the computing engine: processing units (PU) built from
//!   Data Allocation Components (DAC), Computing Components (CC), and
//!   Data Collection Components (DCC), optionally in multiple processing
//!   structures (PST).
//! * [`data`] — the data engine: data units (DU) built from Memory Access
//!   Components (AMC), Task Processing Components (TPC), and Stream
//!   Service Components (SSC), over the shared DDR.
//!
//! Component *modes* are the paper's Tables 1/4 taxonomy; each mode
//! carries validation rules, resource cost, and timing semantics the
//! coordinator's scheduler consumes.

pub mod compute;
pub mod data;
