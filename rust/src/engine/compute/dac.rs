//! Data Allocation Component (DAC) — distributes DU data to CC cores.
//!
//! The paper's four modes (§3.3):
//!
//! * `DIR` — direct PLIO-to-core wire; single-core CCs only.
//! * `BDC` — broadcast: one PLIO's data copied to many cores in a cycle.
//! * `SWH` — switch: one PLIO time-shares distinct data to many cores.
//! * `DCA` — a dedicated AIE core doing data organisation (costs 1 core).
//!
//! The MM accelerator's input side is `SWH+BDC`: 4 PLIOs carry MatA and 4
//! carry MatB, each packet-switched 4 ways and broadcast along a
//! Cascade<4> row (Fig 7a).

use crate::sim::params::HwParams;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DacMode {
    Dir,
    Bdc,
    Swh,
    Dca,
}

impl DacMode {
    pub fn name(&self) -> &'static str {
        match self {
            DacMode::Dir => "DIR",
            DacMode::Bdc => "BDC",
            DacMode::Swh => "SWH",
            DacMode::Dca => "DCA",
        }
    }

    pub fn parse(s: &str) -> Result<DacMode, String> {
        match s.trim().to_ascii_uppercase().as_str() {
            "DIR" => Ok(DacMode::Dir),
            "BDC" => Ok(DacMode::Bdc),
            "SWH" => Ok(DacMode::Swh),
            "DCA" => Ok(DacMode::Dca),
            other => Err(format!("unknown DAC mode: {other}")),
        }
    }

    /// Extra AIE cores this mode consumes.
    pub fn extra_cores(&self) -> usize {
        match self {
            DacMode::Dca => 1,
            _ => 0,
        }
    }
}

/// One DAC instance: a mode (or stacked modes, e.g. SWH feeding BDC),
/// the PLIO ports it owns, and how many CC cores it serves.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Dac {
    pub modes: Vec<DacMode>,
    pub plios: usize,
    pub serves_cores: usize,
}

impl Dac {
    pub fn new(modes: Vec<DacMode>, plios: usize, serves_cores: usize) -> Dac {
        Dac { modes, plios, serves_cores }
    }

    pub fn label(&self) -> String {
        self.modes
            .iter()
            .map(|m| m.name())
            .collect::<Vec<_>>()
            .join("+")
    }

    /// Validity rules from the paper.
    pub fn validate(&self, cc_cores: usize) -> Result<(), String> {
        if self.modes.is_empty() {
            return Err("DAC needs at least one mode".into());
        }
        if self.plios == 0 {
            return Err("DAC needs at least one PLIO".into());
        }
        if self.serves_cores == 0 || self.serves_cores > cc_cores {
            return Err(format!(
                "DAC serves {} cores but the CC has {cc_cores}",
                self.serves_cores
            ));
        }
        if self.modes.contains(&DacMode::Dir) && self.serves_cores != 1 {
            return Err("DIR is only applicable to a single-core computing component".into());
        }
        Ok(())
    }

    /// Seconds to move `bytes` of per-iteration input through this DAC.
    ///
    /// BDC copies one stream to many cores, so the wire time is the
    /// single-copy time; SWH time-shares, so distinct payloads serialize
    /// on the port — both reduce to `bytes / (plios * plio_rate)` where
    /// `bytes` counts *unique* traffic entering the PU. DCA adds its
    /// organisation latency.
    pub fn transfer_secs(&self, p: &HwParams, unique_bytes: usize) -> f64 {
        let wire = unique_bytes as f64 / (self.plios as f64 * p.plio_bytes_per_sec());
        let dca_latency = if self.modes.contains(&DacMode::Dca) {
            // one pass over the data at stream rate inside the helper core
            unique_bytes as f64 / p.stream_bytes_per_sec * 0.25
        } else {
            0.0
        };
        wire + dca_latency
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_and_parse() {
        for m in [DacMode::Dir, DacMode::Bdc, DacMode::Swh, DacMode::Dca] {
            assert_eq!(DacMode::parse(m.name()).unwrap(), m);
        }
        assert!(DacMode::parse("XYZ").is_err());
    }

    #[test]
    fn dir_requires_single_core() {
        let d = Dac::new(vec![DacMode::Dir], 1, 4);
        assert!(d.validate(4).is_err());
        let d = Dac::new(vec![DacMode::Dir], 1, 1);
        assert!(d.validate(1).is_ok());
    }

    #[test]
    fn mm_dac_label() {
        let d = Dac::new(vec![DacMode::Swh, DacMode::Bdc], 8, 64);
        assert_eq!(d.label(), "SWH+BDC");
        assert!(d.validate(64).is_ok());
    }

    #[test]
    fn transfer_time_scales_with_plios() {
        let p = HwParams::vck5000();
        let one = Dac::new(vec![DacMode::Swh], 1, 8).transfer_secs(&p, 65536);
        let four = Dac::new(vec![DacMode::Swh], 4, 8).transfer_secs(&p, 65536);
        assert!((one / four - 4.0).abs() < 1e-9);
    }

    #[test]
    fn dca_adds_latency_and_a_core() {
        let p = HwParams::vck5000();
        let plain = Dac::new(vec![DacMode::Swh], 1, 8);
        let dca = Dac::new(vec![DacMode::Dca], 1, 8);
        assert!(dca.transfer_secs(&p, 4096) > plain.transfer_secs(&p, 4096));
        assert_eq!(DacMode::Dca.extra_cores(), 1);
    }

    #[test]
    fn mm_input_phase_is_3_4us() {
        // 8 PLIOs carrying A+B = 131072 B -> 3.41 us (DESIGN.md §6).
        let p = HwParams::vck5000();
        let d = Dac::new(vec![DacMode::Swh, DacMode::Bdc], 8, 64);
        let secs = d.transfer_secs(&p, 131072);
        assert!((secs * 1e6 - 3.41).abs() < 0.02, "{}", secs * 1e6);
    }
}
