//! Data Collection Component (DCC) — collects CC results back to the DU.
//!
//! Same structure as the DAC minus broadcast ("broadcasting is not
//! applicable during data collection" — §3.3): modes DIR, SWH, DCA.

use crate::sim::params::HwParams;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DccMode {
    Dir,
    Swh,
    Dca,
}

impl DccMode {
    pub fn name(&self) -> &'static str {
        match self {
            DccMode::Dir => "DIR",
            DccMode::Swh => "SWH",
            DccMode::Dca => "DCA",
        }
    }

    pub fn parse(s: &str) -> Result<DccMode, String> {
        match s.trim().to_ascii_uppercase().as_str() {
            "DIR" => Ok(DccMode::Dir),
            "SWH" => Ok(DccMode::Swh),
            "DCA" => Ok(DccMode::Dca),
            "BDC" => Err("BDC is not applicable to a DCC (no broadcast on collection)".into()),
            other => Err(format!("unknown DCC mode: {other}")),
        }
    }

    pub fn extra_cores(&self) -> usize {
        match self {
            DccMode::Dca => 1,
            _ => 0,
        }
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Dcc {
    pub mode: DccMode,
    pub plios: usize,
    pub serves_cores: usize,
}

impl Dcc {
    pub fn new(mode: DccMode, plios: usize, serves_cores: usize) -> Dcc {
        Dcc { mode, plios, serves_cores }
    }

    pub fn validate(&self, cc_cores: usize) -> Result<(), String> {
        if self.plios == 0 {
            return Err("DCC needs at least one PLIO".into());
        }
        if self.serves_cores == 0 || self.serves_cores > cc_cores {
            return Err(format!(
                "DCC serves {} cores but the CC has {cc_cores}",
                self.serves_cores
            ));
        }
        if self.mode == DccMode::Dir && self.serves_cores != 1 {
            return Err("DIR collection needs exactly one served core".into());
        }
        Ok(())
    }

    /// Seconds to collect `bytes` of per-iteration results.
    pub fn transfer_secs(&self, p: &HwParams, bytes: usize) -> f64 {
        let wire = bytes as f64 / (self.plios as f64 * p.plio_bytes_per_sec());
        let dca_latency = if self.mode == DccMode::Dca {
            bytes as f64 / p.stream_bytes_per_sec * 0.25
        } else {
            0.0
        };
        wire + dca_latency
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bdc_rejected() {
        assert!(DccMode::parse("BDC").is_err());
    }

    #[test]
    fn parse_ok() {
        assert_eq!(DccMode::parse("swh").unwrap(), DccMode::Swh);
        assert_eq!(DccMode::parse("DIR").unwrap(), DccMode::Dir);
        assert_eq!(DccMode::parse("DCA").unwrap(), DccMode::Dca);
    }

    #[test]
    fn dir_single_core_rule() {
        assert!(Dcc::new(DccMode::Dir, 1, 2).validate(4).is_err());
        assert!(Dcc::new(DccMode::Dir, 1, 1).validate(4).is_ok());
    }

    #[test]
    fn mm_output_phase_is_3_4us() {
        // 4 SWH PLIOs collecting 65536 B -> 3.41 us.
        let p = HwParams::vck5000();
        let d = Dcc::new(DccMode::Swh, 4, 64);
        let secs = d.transfer_secs(&p, 65536);
        assert!((secs * 1e6 - 3.41).abs() < 0.02, "{}", secs * 1e6);
    }
}
