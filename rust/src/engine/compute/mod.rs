//! The computing engine: PU = { PST* }, PST = { DAC*, CC, DCC* }.

pub mod cc;
pub mod dac;
pub mod dcc;
pub mod pu;

pub use cc::CcMode;
pub use dac::{Dac, DacMode};
pub use dcc::{Dcc, DccMode};
pub use pu::{ProcessingStructure, ProcessingUnit};
