//! Computing Component (CC) — the AIE-core organisation inside a PU.
//!
//! The paper's four implementation modes (§3.3):
//!
//! * `Single`        — one core matches the DU's data rate.
//! * `Cascade<k>`    — k cores chained through the cascade accumulator
//!   wires; each handles a K-slab of the subtask.
//! * `Parallel<n>*M` — n non-interconnected groups of mode M.
//! * `Butterfly`     — the FFT-specific component (a fixed group of cores
//!   wired for the butterfly data exchange).
//!
//! Modes compose: the paper's MM CC is `Parallel<16>*Cascade<4>`.

use std::fmt;

use crate::sim::core::{KernelClass, KernelInvocation};
use crate::sim::params::HwParams;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CcMode {
    Single,
    Cascade(usize),
    Parallel(usize, Box<CcMode>),
    Butterfly { cores: usize },
}

impl CcMode {
    /// Total AIE cores in this organisation.
    pub fn cores(&self) -> usize {
        match self {
            CcMode::Single => 1,
            CcMode::Cascade(k) => *k,
            CcMode::Parallel(n, inner) => n * inner.cores(),
            CcMode::Butterfly { cores } => *cores,
        }
    }

    /// Depth of the longest dependency chain (pipeline fill stages):
    /// cascade stages serialize within one subtask, parallel groups do
    /// not.
    pub fn chain_depth(&self) -> usize {
        match self {
            CcMode::Single => 1,
            CcMode::Cascade(k) => *k,
            CcMode::Parallel(_, inner) => inner.chain_depth(),
            // Butterfly stages pipeline log-deep but the component is
            // internally balanced; depth 1 per stage-group.
            CcMode::Butterfly { .. } => 1,
        }
    }

    /// Validity rules from the paper's text.
    pub fn validate(&self) -> Result<(), String> {
        match self {
            CcMode::Single => Ok(()),
            CcMode::Cascade(k) if *k >= 2 => Ok(()),
            CcMode::Cascade(k) => Err(format!("Cascade<{k}> needs >= 2 cores")),
            CcMode::Parallel(n, inner) => {
                if *n < 2 {
                    return Err(format!("Parallel<{n}> needs >= 2 groups"));
                }
                if matches!(**inner, CcMode::Parallel(..)) {
                    return Err("Parallel directly inside Parallel is redundant \
                                — multiply the group counts"
                        .to_string());
                }
                inner.validate()
            }
            CcMode::Butterfly { cores } if *cores >= 2 && cores.is_power_of_two() => Ok(()),
            CcMode::Butterfly { cores } => {
                Err(format!("Butterfly needs a power-of-two core count, got {cores}"))
            }
        }
    }

    /// Compute-phase seconds for one PU iteration: `ops` total arithmetic
    /// spread over the parallel groups, chained through `chain_depth`
    /// cascade stages. In steady state the cascade is pipelined, so the
    /// chain costs one stage's time plus a per-stage handoff, not
    /// depth x stage.
    pub fn compute_secs(&self, p: &HwParams, class: KernelClass, ops: f64) -> f64 {
        let cores = self.cores() as f64;
        let ops_per_core = ops / cores;
        let inv = KernelInvocation::new(class, ops_per_core);
        // cascade handoff: accumulator push between pipelined stages
        // (~16 cycles each in steady state; the bulk of the real handoff
        // cost is already inside kernel_setup_cycles' calibration)
        let handoff = (self.chain_depth() - 1) as f64 * 16.0 / p.aie_clock_hz;
        inv.secs(p) + handoff
    }
}

impl fmt::Display for CcMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CcMode::Single => write!(f, "Single"),
            CcMode::Cascade(k) => write!(f, "Cascade<{k}>"),
            CcMode::Parallel(n, inner) => write!(f, "Parallel<{n}>*{inner}"),
            CcMode::Butterfly { cores } => write!(f, "Butterfly[{cores}]"),
        }
    }
}

/// Parse the paper's notation: `Single`, `Cascade<4>`,
/// `Parallel<16>*Cascade<4>`, `Butterfly[8]`.
pub fn parse_cc(s: &str) -> Result<CcMode, String> {
    let s = s.trim();
    if let Some(rest) = s.strip_prefix("Parallel<") {
        let (n, tail) = rest
            .split_once('>')
            .ok_or_else(|| format!("bad Parallel syntax: {s}"))?;
        let n: usize = n.parse().map_err(|_| format!("bad Parallel count: {n}"))?;
        let inner = tail
            .strip_prefix('*')
            .ok_or_else(|| format!("Parallel<{n}> needs '*<inner>'"))?;
        return Ok(CcMode::Parallel(n, Box::new(parse_cc(inner)?)));
    }
    if let Some(rest) = s.strip_prefix("Cascade<") {
        let n = rest
            .strip_suffix('>')
            .ok_or_else(|| format!("bad Cascade syntax: {s}"))?;
        let n: usize = n.parse().map_err(|_| format!("bad Cascade count: {n}"))?;
        return Ok(CcMode::Cascade(n));
    }
    if let Some(rest) = s.strip_prefix("Butterfly[") {
        let n = rest
            .strip_suffix(']')
            .ok_or_else(|| format!("bad Butterfly syntax: {s}"))?;
        let cores: usize = n.parse().map_err(|_| format!("bad Butterfly count: {n}"))?;
        return Ok(CcMode::Butterfly { cores });
    }
    if s == "Single" {
        return Ok(CcMode::Single);
    }
    Err(format!("unknown CC mode: {s}"))
}

/// Parse + validate in one step (the configuration-file entry point).
pub fn parse_cc_validated(s: &str) -> Result<CcMode, String> {
    let cc = parse_cc(s)?;
    cc.validate()?;
    Ok(cc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mm_cc_is_64_cores() {
        let cc = CcMode::Parallel(16, Box::new(CcMode::Cascade(4)));
        assert_eq!(cc.cores(), 64);
        assert_eq!(cc.chain_depth(), 4);
        assert!(cc.validate().is_ok());
        assert_eq!(cc.to_string(), "Parallel<16>*Cascade<4>");
    }

    #[test]
    fn parse_roundtrip() {
        for s in ["Single", "Cascade<8>", "Parallel<16>*Cascade<4>", "Butterfly[4]",
                  "Parallel<2>*Cascade<3>", "Parallel<8>*Single"] {
            let cc = parse_cc(s).unwrap();
            assert_eq!(parse_cc(&cc.to_string()).unwrap(), cc, "{s}");
        }
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_cc("Cascade<x>").is_err());
        assert!(parse_cc("Parallel<4>").is_err());
        assert!(parse_cc("Waffle").is_err());
        // syntactically fine but structurally invalid: caught by the
        // validating entry point the config parser uses
        assert!(parse_cc_validated("Butterfly[3]").is_err());
        assert!(parse_cc_validated("Cascade<1>").is_err());
        assert!(parse_cc_validated("Parallel<16>*Cascade<4>").is_ok());
    }

    #[test]
    fn validation_rules() {
        assert!(CcMode::Cascade(1).validate().is_err());
        assert!(CcMode::Parallel(1, Box::new(CcMode::Single)).validate().is_err());
        let nested = CcMode::Parallel(2, Box::new(CcMode::Parallel(2, Box::new(CcMode::Single))));
        assert!(nested.validate().is_err());
        assert!(CcMode::Butterfly { cores: 4 }.validate().is_ok());
    }

    #[test]
    fn compute_secs_scales_with_cores() {
        let p = HwParams::vck5000();
        let single = CcMode::Single.compute_secs(&p, KernelClass::F32Mac, 65536.0);
        let para = CcMode::Parallel(16, Box::new(CcMode::Cascade(4)))
            .compute_secs(&p, KernelClass::F32Mac, 64.0 * 65536.0);
        // 64 cores doing 64x the work in (roughly) the single-core time
        assert!((para - single).abs() / single < 0.01, "{para} vs {single}");
    }

    #[test]
    fn mm_pu_compute_phase_near_4_24us() {
        // Each core gets one 32^3 task per PU iteration (DESIGN.md §6).
        let p = HwParams::vck5000();
        let cc = CcMode::Parallel(16, Box::new(CcMode::Cascade(4)));
        let secs = cc.compute_secs(&p, KernelClass::F32Mac, 2.0 * 128.0 * 128.0 * 128.0);
        assert!((secs * 1e6 - 4.24).abs() < 0.2, "{}", secs * 1e6);
    }
}
