//! Processing Unit (PU) and Processing Structure (PST).
//!
//! A PU solves one subtask per iteration. A subtask may have several
//! processing stages; each stage is a PST = { DACs, CC, DCCs } (§3.3,
//! Fig 3). The FFT PU has two PSTs (Butterfly stage-group + the
//! Parallel<2>*Cascade<3> tail); the other accelerators have one.

use crate::sim::core::KernelClass;
use crate::sim::params::HwParams;

use super::cc::CcMode;
use super::dac::Dac;
use super::dcc::Dcc;

#[derive(Debug, Clone, PartialEq)]
pub struct ProcessingStructure {
    pub dacs: Vec<Dac>,
    pub cc: CcMode,
    pub dccs: Vec<Dcc>,
}

impl ProcessingStructure {
    pub fn validate(&self) -> Result<(), String> {
        self.cc.validate()?;
        let cores = self.cc.cores();
        if self.dacs.is_empty() {
            return Err("PST needs at least one DAC".into());
        }
        if self.dccs.is_empty() {
            return Err("PST needs at least one DCC".into());
        }
        for d in &self.dacs {
            d.validate(cores)?;
        }
        for d in &self.dccs {
            d.validate(cores)?;
        }
        Ok(())
    }

    /// AIE cores including DCA helper cores.
    pub fn cores(&self) -> usize {
        self.cc.cores()
            + self.dacs.iter().flat_map(|d| &d.modes).map(|m| m.extra_cores()).sum::<usize>()
            + self.dccs.iter().map(|d| d.mode.extra_cores()).sum::<usize>()
    }

    pub fn in_plios(&self) -> usize {
        self.dacs.iter().map(|d| d.plios).sum()
    }

    pub fn out_plios(&self) -> usize {
        self.dccs.iter().map(|d| d.plios).sum()
    }

    /// Input-distribution seconds for `bytes` of unique per-iteration
    /// traffic, split proportionally across this PST's DACs by port count.
    pub fn in_secs(&self, p: &HwParams, bytes: usize) -> f64 {
        let total_plios = self.in_plios().max(1);
        self.dacs
            .iter()
            .map(|d| d.transfer_secs(p, bytes * d.plios / total_plios))
            .fold(0.0_f64, f64::max)
    }

    pub fn out_secs(&self, p: &HwParams, bytes: usize) -> f64 {
        let total_plios = self.out_plios().max(1);
        self.dccs
            .iter()
            .map(|d| d.transfer_secs(p, bytes * d.plios / total_plios))
            .fold(0.0_f64, f64::max)
    }
}

/// A full processing unit.
#[derive(Debug, Clone, PartialEq)]
pub struct ProcessingUnit {
    pub name: String,
    pub psts: Vec<ProcessingStructure>,
    /// Arithmetic class of this PU's kernels.
    pub class: KernelClass,
    /// Total arithmetic ops per PU iteration.
    pub ops_per_iter: f64,
    /// Unique input bytes entering the PU per iteration (over PLIO).
    pub in_bytes_per_iter: usize,
    /// Result bytes leaving the PU per iteration.
    pub out_bytes_per_iter: usize,
    /// If true the comm phase serializes input and output (single-duplex
    /// wiring, e.g. the FFT PU's DIR ports); default is full-duplex
    /// overlap.
    pub serial_comm: bool,
    /// Bytes handed between PSTs over the core stream fabric per
    /// iteration (multi-PST PUs); the slowest of {stage compute, handoff}
    /// paces the pipeline.
    pub handoff_bytes: usize,
}

impl ProcessingUnit {
    /// Construct with the common defaults (full-duplex comm, no handoff).
    #[allow(clippy::too_many_arguments)]
    pub fn simple(
        name: &str,
        psts: Vec<ProcessingStructure>,
        class: KernelClass,
        ops_per_iter: f64,
        in_bytes_per_iter: usize,
        out_bytes_per_iter: usize,
    ) -> ProcessingUnit {
        ProcessingUnit {
            name: name.to_string(),
            psts,
            class,
            ops_per_iter,
            in_bytes_per_iter,
            out_bytes_per_iter,
            serial_comm: false,
            handoff_bytes: 0,
        }
    }
}

impl ProcessingUnit {
    pub fn validate(&self) -> Result<(), String> {
        if self.psts.is_empty() {
            return Err("PU needs at least one PST".into());
        }
        for pst in &self.psts {
            pst.validate()?;
        }
        if self.ops_per_iter <= 0.0 {
            return Err("PU ops_per_iter must be positive".into());
        }
        Ok(())
    }

    pub fn cores(&self) -> usize {
        self.psts.iter().map(|p| p.cores()).sum()
    }

    pub fn in_plios(&self) -> usize {
        // PST chains share the PU's external input ports: external input
        // enters PST#1; later PSTs are fed core-to-core. External ports
        // are PST#1's DAC ports plus any later PST marked external — we
        // take PST#1 in, last PST out (the paper's FFT wiring).
        self.psts.first().map(|p| p.in_plios()).unwrap_or(0)
    }

    pub fn out_plios(&self) -> usize {
        self.psts.last().map(|p| p.out_plios()).unwrap_or(0)
    }

    pub fn total_plios(&self) -> usize {
        self.in_plios() + self.out_plios()
    }

    /// Compute-phase seconds for one PU iteration: the PSTs pipeline, so
    /// the steady-state iteration time is the max stage time; ops are
    /// attributed to stages proportionally to their core counts. When the
    /// PU moves intermediate data between PSTs over the stream fabric,
    /// that handoff is itself a pipeline stage.
    pub fn compute_secs(&self, p: &HwParams) -> f64 {
        let total_cores: usize = self.psts.iter().map(|s| s.cc.cores()).sum();
        let stage_max = self
            .psts
            .iter()
            .map(|s| {
                let share = self.ops_per_iter * s.cc.cores() as f64 / total_cores as f64;
                s.cc.compute_secs(p, self.class, share)
            })
            .fold(0.0_f64, f64::max);
        let handoff = self.handoff_bytes as f64 / p.stream_bytes_per_sec;
        stage_max.max(handoff)
    }

    /// Communication-phase seconds for one iteration: input distribution
    /// and result collection overlap (full-duplex PLIO) unless
    /// `serial_comm` is set, in which case they serialize.
    pub fn comm_secs(&self, p: &HwParams) -> f64 {
        let in_secs = self
            .psts
            .first()
            .map(|s| s.in_secs(p, self.in_bytes_per_iter))
            .unwrap_or(0.0);
        let out_secs = self
            .psts
            .last()
            .map(|s| s.out_secs(p, self.out_bytes_per_iter))
            .unwrap_or(0.0);
        if self.serial_comm {
            in_secs + out_secs
        } else {
            in_secs.max(out_secs)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::compute::dac::DacMode;
    use crate::engine::compute::dcc::DccMode;

    /// The paper's MM PU (Fig 7a): SWH+BDC in (8 PLIO), Parallel<16>*
    /// Cascade<4>, SWH out (4 PLIO).
    pub fn mm_pu() -> ProcessingUnit {
        ProcessingUnit::simple(
            "MM",
            vec![ProcessingStructure {
                dacs: vec![Dac::new(vec![DacMode::Swh, DacMode::Bdc], 8, 64)],
                cc: CcMode::Parallel(16, Box::new(CcMode::Cascade(4))),
                dccs: vec![Dcc::new(DccMode::Swh, 4, 64)],
            }],
            KernelClass::F32Mac,
            2.0 * 128.0 * 128.0 * 128.0,
            2 * 128 * 128 * 4,
            128 * 128 * 4,
        )
    }

    #[test]
    fn mm_pu_shape_matches_paper() {
        let pu = mm_pu();
        assert!(pu.validate().is_ok());
        assert_eq!(pu.cores(), 64);
        assert_eq!(pu.total_plios(), 12); // 8 in + 4 out, Table: 72/6 PUs
    }

    #[test]
    fn mm_pu_iteration_time_near_7_65us() {
        let p = HwParams::vck5000();
        let pu = mm_pu();
        let total = pu.compute_secs(&p) + pu.comm_secs(&p);
        // DESIGN.md §6: ~4.24 us compute + ~3.41 us comm
        assert!((total * 1e6 - 7.65).abs() < 0.25, "{}", total * 1e6);
    }

    #[test]
    fn multi_pst_pipelines() {
        let p = HwParams::vck5000();
        let fft_like = ProcessingUnit::simple(
            "FFT",
            vec![
                ProcessingStructure {
                    dacs: vec![Dac::new(vec![DacMode::Bdc], 1, 4)],
                    cc: CcMode::Butterfly { cores: 4 },
                    dccs: vec![Dcc::new(DccMode::Dir, 1, 1)],
                },
                ProcessingStructure {
                    dacs: vec![Dac::new(vec![DacMode::Dir], 1, 1)],
                    cc: CcMode::Parallel(2, Box::new(CcMode::Cascade(3))),
                    dccs: vec![Dcc::new(DccMode::Dir, 1, 1)],
                },
            ],
            KernelClass::Cint16Butterfly,
            51200.0,
            4096,
            4096,
        );
        assert!(fft_like.validate().is_ok());
        assert_eq!(fft_like.cores(), 10);
        // pipeline: iteration time is the max stage, less than the sum
        let t = fft_like.compute_secs(&p);
        let sum: f64 = fft_like
            .psts
            .iter()
            .map(|s| {
                let share = 51200.0 * s.cc.cores() as f64 / 10.0;
                s.cc.compute_secs(&p, KernelClass::Cint16Butterfly, share)
            })
            .sum();
        assert!(t < sum);
    }

    #[test]
    fn multi_dac_pst_splits_traffic() {
        // The paper's MM input side is really two DAC sets (4 PLIOs for
        // MatA + 4 for MatB); modelled as one 8-PLIO DAC or two 4-PLIO
        // DACs, the input phase must take the same time (proportional
        // traffic split, phases in parallel).
        let p = HwParams::vck5000();
        let one = ProcessingStructure {
            dacs: vec![Dac::new(vec![DacMode::Swh, DacMode::Bdc], 8, 64)],
            cc: CcMode::Parallel(16, Box::new(CcMode::Cascade(4))),
            dccs: vec![Dcc::new(DccMode::Swh, 4, 64)],
        };
        let two = ProcessingStructure {
            dacs: vec![
                Dac::new(vec![DacMode::Swh, DacMode::Bdc], 4, 64), // MatA
                Dac::new(vec![DacMode::Swh, DacMode::Bdc], 4, 64), // MatB
            ],
            cc: CcMode::Parallel(16, Box::new(CcMode::Cascade(4))),
            dccs: vec![Dcc::new(DccMode::Swh, 4, 64)],
        };
        assert!(two.validate().is_ok());
        assert_eq!(one.in_plios(), two.in_plios());
        let bytes = 2 * 128 * 128 * 4;
        let t1 = one.in_secs(&p, bytes);
        let t2 = two.in_secs(&p, bytes);
        assert!((t1 - t2).abs() / t1 < 1e-9, "{t1} vs {t2}");
    }

    #[test]
    fn uneven_dacs_bottleneck_on_the_smaller() {
        // a 1-PLIO DAC serving half the traffic of a 7-PLIO DAC paces
        // the phase (max over DACs, not mean)
        let p = HwParams::vck5000();
        let pst = ProcessingStructure {
            dacs: vec![
                Dac::new(vec![DacMode::Swh], 1, 8),
                Dac::new(vec![DacMode::Swh], 7, 56),
            ],
            cc: CcMode::Parallel(8, Box::new(CcMode::Cascade(8))),
            dccs: vec![Dcc::new(DccMode::Swh, 1, 64)],
        };
        let bytes = 8 * 65536;
        let t = pst.in_secs(&p, bytes);
        // the 1-PLIO DAC gets bytes/8 over one port
        let expect = (bytes / 8) as f64 / p.plio_bytes_per_sec();
        assert!((t - expect).abs() / expect < 1e-9, "{t} vs {expect}");
    }

    #[test]
    fn invalid_pu_rejected() {
        let mut pu = mm_pu();
        pu.psts.clear();
        assert!(pu.validate().is_err());
        let mut pu = mm_pu();
        pu.ops_per_iter = 0.0;
        assert!(pu.validate().is_err());
    }
}
