//! Task Processing Component (TPC) — task decomposition and aggregation.
//!
//! The paper's three modes (§3.4):
//!
//! * `CUP` (Cache Update) — every Task Event (TEV) pulls a fresh Task
//!   Block (TB) from the AMC/SSC into the on-chip cache, processes it,
//!   and emits results.
//! * `CHL` (Cache Hold) — the TB stays resident; TEVs re-run over it
//!   (small data, heavy compute — MM-T).
//! * `THR` (Through) — no TEV at all; AMC output wired straight to the
//!   SSC with no buffer.

use crate::sim::params::HwParams;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TpcMode {
    Cup,
    Chl,
    Thr,
}

impl TpcMode {
    pub fn name(&self) -> &'static str {
        match self {
            TpcMode::Cup => "CUP",
            TpcMode::Chl => "CHL",
            TpcMode::Thr => "THR",
        }
    }

    pub fn parse(s: &str) -> Result<TpcMode, String> {
        match s.trim().to_ascii_uppercase().as_str() {
            "CUP" => Ok(TpcMode::Cup),
            "CHL" => Ok(TpcMode::Chl),
            "THR" => Ok(TpcMode::Thr),
            other => Err(format!("unknown TPC mode: {other}")),
        }
    }

    /// Does this mode re-read DDR for every TB?
    pub fn refetches(&self) -> bool {
        matches!(self, TpcMode::Cup)
    }

    /// Does this mode use on-chip TB cache at all?
    pub fn buffers(&self) -> bool {
        !matches!(self, TpcMode::Thr)
    }
}

/// A Task Block: the minimum data set one Task Event consumes (§3.4).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TaskBlock {
    /// Bytes fetched from DDR per TB.
    pub read_bytes: usize,
    /// Engine iterations one TB sustains (data reuse factor — the MM TB
    /// of 27 128x128 matrices feeds 9 engine iterations).
    pub engine_iters: u64,
    /// Result bytes written back to DDR per write-back event.
    pub writeback_bytes_per_iter: usize,
    /// Engine iterations between write-back events (1 = every iteration;
    /// the MM TPC accumulates C partials in URAM and writes a C block
    /// only once its K-sweep completes).
    pub writeback_every: u64,
}

impl TaskBlock {
    pub fn new(read_bytes: usize, engine_iters: u64, wb_bytes: usize) -> TaskBlock {
        TaskBlock {
            read_bytes,
            engine_iters,
            writeback_bytes_per_iter: wb_bytes,
            writeback_every: 1,
        }
    }

    /// PL-side decompose pipeline-fill latency for one TB: the TPC
    /// streams the block through its logic at the PL word rate
    /// (512 bits/cycle), *overlapped* with SSC service — only the first
    /// iteration's slice must be processed before service can start.
    pub fn process_secs(&self, p: &HwParams) -> f64 {
        let pl_bytes_per_sec = 64.0 * p.pl_clock_hz; // 512 b/cycle
        let first_slice = self.read_bytes as f64 / self.engine_iters.max(1) as f64;
        first_slice / pl_bytes_per_sec
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_names() {
        for m in [TpcMode::Cup, TpcMode::Chl, TpcMode::Thr] {
            assert_eq!(TpcMode::parse(m.name()).unwrap(), m);
        }
        assert!(TpcMode::parse("HOLD").is_err());
    }

    #[test]
    fn mode_semantics() {
        assert!(TpcMode::Cup.refetches());
        assert!(!TpcMode::Chl.refetches());
        assert!(!TpcMode::Thr.refetches());
        assert!(TpcMode::Cup.buffers());
        assert!(TpcMode::Chl.buffers());
        assert!(!TpcMode::Thr.buffers());
    }

    #[test]
    fn mm_tb_process_fill_latency() {
        // 27 x 128x128 float matrices = 1.77 MB; the first of 9 slices
        // (196 KiB) fills the decompose pipeline in ~10 us at 19.2 GB/s.
        let p = HwParams::vck5000();
        let tb = TaskBlock::new(27 * 128 * 128 * 4, 9, 6 * 128 * 128 * 4);
        let secs = tb.process_secs(&p);
        assert!((secs * 1e6 - 10.24).abs() < 0.2, "{}", secs * 1e6);
        assert_eq!(tb.writeback_every, 1);
    }
}
