//! Stream Service Component (SSC) — maps DU task data onto the PU PLIOs.
//!
//! The paper's four service disciplines (§3.4, Fig 5):
//!
//! * `PSD` — Parallel Same Data: one subproblem broadcast to all PUs at
//!   once (sender only).
//! * `SHD` — Serial Heterogeneous Data: distinct subproblems served one
//!   PU after another; a slow PU delays everyone behind it.
//! * `PHD` — Parallel Heterogeneous Data: distinct subproblems served
//!   concurrently, but the whole batch must be staged in the DU buffer
//!   first (URAM cost).
//! * `THR` — Through: direct wire, exactly one PU.

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SscMode {
    Psd,
    Shd,
    Phd,
    Thr,
}

impl SscMode {
    pub fn name(&self) -> &'static str {
        match self {
            SscMode::Psd => "PSD",
            SscMode::Shd => "SHD",
            SscMode::Phd => "PHD",
            SscMode::Thr => "THR",
        }
    }

    pub fn parse(s: &str) -> Result<SscMode, String> {
        match s.trim().to_ascii_uppercase().as_str() {
            "PSD" => Ok(SscMode::Psd),
            "SHD" => Ok(SscMode::Shd),
            "PHD" => Ok(SscMode::Phd),
            "THR" => Ok(SscMode::Thr),
            other => Err(format!("unknown SSC mode: {other}")),
        }
    }

    /// Validity: PSD is a sender-only mode; THR serves exactly one PU.
    pub fn validate(&self, n_pus: usize, is_sender: bool) -> Result<(), String> {
        match self {
            SscMode::Psd if !is_sender => {
                Err("PSD is only defined for the Sender side".into())
            }
            SscMode::Thr if n_pus != 1 => {
                Err(format!("THR serves exactly one PU, group has {n_pus}"))
            }
            _ => Ok(()),
        }
    }

    /// Needs the batch staged in the DU buffer before service starts?
    pub fn needs_staging(&self) -> bool {
        matches!(self, SscMode::Phd)
    }

    /// Start offset of PU `idx`'s service within a group comm phase whose
    /// per-PU wire time is `per_pu_secs` (this is Fig 5's timing): serial
    /// modes stagger, parallel modes do not.
    pub fn service_start_offset(&self, idx: usize, per_pu_secs: f64) -> f64 {
        match self {
            SscMode::Shd => idx as f64 * per_pu_secs,
            SscMode::Psd | SscMode::Phd | SscMode::Thr => 0.0,
        }
    }

    /// Duration of the whole group's service phase for `n_pus` PUs.
    pub fn group_service_secs(&self, n_pus: usize, per_pu_secs: f64) -> f64 {
        match self {
            SscMode::Shd => n_pus as f64 * per_pu_secs,
            SscMode::Psd | SscMode::Phd | SscMode::Thr => per_pu_secs,
        }
    }

    /// DU buffer bytes needed to serve `n_pus` PUs of `per_pu_bytes` each.
    pub fn staging_bytes(&self, n_pus: usize, per_pu_bytes: usize) -> usize {
        match self {
            SscMode::Phd => n_pus * per_pu_bytes,
            SscMode::Psd => per_pu_bytes,
            SscMode::Shd => per_pu_bytes, // double-buffered single slot
            SscMode::Thr => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for m in [SscMode::Psd, SscMode::Shd, SscMode::Phd, SscMode::Thr] {
            assert_eq!(SscMode::parse(m.name()).unwrap(), m);
        }
        assert!(SscMode::parse("ABC").is_err());
    }

    #[test]
    fn psd_receiver_invalid() {
        assert!(SscMode::Psd.validate(4, true).is_ok());
        assert!(SscMode::Psd.validate(4, false).is_err());
    }

    #[test]
    fn thr_single_pu_only() {
        assert!(SscMode::Thr.validate(1, true).is_ok());
        assert!(SscMode::Thr.validate(2, true).is_err());
    }

    #[test]
    fn fig5_timing_shapes() {
        // 4 PUs, 1 us each: SHD takes 4 us and staggers; PHD takes 1 us
        // but needs 4x buffer.
        let per = 1e-6;
        assert_eq!(SscMode::Shd.group_service_secs(4, per), 4e-6);
        assert_eq!(SscMode::Phd.group_service_secs(4, per), 1e-6);
        assert_eq!(SscMode::Shd.service_start_offset(2, per), 2e-6);
        assert_eq!(SscMode::Phd.service_start_offset(2, per), 0.0);
        assert_eq!(SscMode::Phd.staging_bytes(4, 1000), 4000);
        assert_eq!(SscMode::Shd.staging_bytes(4, 1000), 1000);
    }

    #[test]
    fn phd_stages() {
        assert!(SscMode::Phd.needs_staging());
        assert!(!SscMode::Shd.needs_staging());
    }
}
