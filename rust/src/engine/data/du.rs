//! Data Unit (DU): AMC + TPC + SSC, serving a group of PUs.

use crate::sim::ddr::AmcMode;
use crate::sim::params::HwParams;

use super::ssc::SscMode;
use super::tpc::{TaskBlock, TpcMode};

/// A configured data unit.
#[derive(Debug, Clone, PartialEq)]
pub struct DataUnit {
    pub name: String,
    /// AMC read mode (None = no DDR reads, e.g. MM-T's Null AMC).
    pub amc_read: Option<AmcMode>,
    /// AMC write mode for result write-back.
    pub amc_write: Option<AmcMode>,
    pub tpc: TpcMode,
    pub ssc_send: SscMode,
    pub ssc_recv: SscMode,
    /// Task-block geometry (meaningless for THR TPCs).
    pub tb: TaskBlock,
    /// PUs this DU serves (the DU-PUs pair ratio).
    pub pus: usize,
}

impl DataUnit {
    pub fn validate(&self) -> Result<(), String> {
        if self.pus == 0 {
            return Err("DU must serve at least one PU".into());
        }
        self.ssc_send.validate(self.pus, true)?;
        self.ssc_recv.validate(self.pus, false)?;
        if self.tpc == TpcMode::Thr && self.tb.engine_iters != 0 && self.tb.read_bytes != 0 {
            return Err("THR TPC has no task blocks; zero the TB geometry".into());
        }
        if self.tpc != TpcMode::Thr && self.tb.engine_iters == 0 {
            return Err("buffered TPC needs tb.engine_iters >= 1".into());
        }
        if self.tpc == TpcMode::Cup && self.amc_read.is_none() {
            return Err("CUP TPC refetches TBs and needs an AMC read mode".into());
        }
        Ok(())
    }

    /// URAM staging demand in bytes for the send side, per engine
    /// iteration of `per_pu_bytes` subproblems (Fig 5 / §3.4).
    pub fn staging_bytes(&self, per_pu_bytes: usize) -> usize {
        self.ssc_send.staging_bytes(self.pus, per_pu_bytes)
            + self.ssc_recv.staging_bytes(self.pus, per_pu_bytes)
    }

    /// TB processing seconds (PL side), zero for THR.
    pub fn tb_process_secs(&self, p: &HwParams) -> f64 {
        if self.tpc.buffers() {
            self.tb.process_secs(p)
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mm_du() -> DataUnit {
        DataUnit {
            name: "MM-DU".into(),
            amc_read: Some(AmcMode::Jub),
            amc_write: Some(AmcMode::Csb),
            tpc: TpcMode::Cup,
            ssc_send: SscMode::Phd,
            ssc_recv: SscMode::Phd,
            tb: TaskBlock::new(27 * 128 * 128 * 4, 9, 6 * 128 * 128 * 4),
            pus: 6,
        }
    }

    #[test]
    fn mm_du_valid() {
        assert!(mm_du().validate().is_ok());
    }

    #[test]
    fn cup_needs_amc() {
        let mut du = mm_du();
        du.amc_read = None;
        assert!(du.validate().is_err());
    }

    #[test]
    fn thr_needs_no_tb() {
        let mut du = mm_du();
        du.tpc = TpcMode::Thr;
        du.ssc_send = SscMode::Thr;
        du.ssc_recv = SscMode::Thr;
        du.pus = 1;
        assert!(du.validate().is_err()); // TB geometry still set
        du.tb = TaskBlock::new(0, 0, 0);
        assert!(du.validate().is_ok());
    }

    #[test]
    fn zero_pus_invalid() {
        let mut du = mm_du();
        du.pus = 0;
        assert!(du.validate().is_err());
    }

    #[test]
    fn staging_accounts_both_sides() {
        let du = mm_du();
        // PHD stages all 6 PUs both directions
        assert_eq!(du.staging_bytes(1000), 12_000);
    }
}
