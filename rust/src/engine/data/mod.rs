//! The data engine: DU = { AMC, TPC, SSC } over shared DDR (§3.4).

pub mod du;
pub mod ssc;
pub mod tpc;

pub use du::DataUnit;
pub use ssc::SscMode;
pub use tpc::{TaskBlock, TpcMode};
