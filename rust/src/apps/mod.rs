//! The four accelerators of the paper's evaluation (§4, Table 4):
//!
//! | App      | CC                       | DAC     | DCC | AMC  | TPC | SSC |
//! |----------|--------------------------|---------|-----|------|-----|-----|
//! | MM       | Parallel<16>*Cascade<4>  | SWH+BDC | SWH | JUB  | CUP | PHD |
//! | Filter2D | Parallel<8>              | SWH     | SWH | JUB  | CUP | PHD |
//! | FFT      | Butterfly + P<2>*Casc<3> | BDC/DIR | DIR | CSB  | CUP | PHD |
//! | MM-T     | Cascade<8>               | DIR     | DIR | Null | CHL | THR |
//!
//! Each app module provides the paper's PU/DU constructors, a `run`
//! that simulates a workload and returns a
//! [`RunReport`](crate::coordinator::RunReport) row — routed through
//! the design facade ([`crate::api::designs`] +
//! [`Design::report`](crate::api::Design::report), so the apps are
//! workload frontends, not hand-wired Controller glue) — and a
//! `*_via_pu(s)` path that routes actual task data through the runtime
//! for numerical validation.

pub mod fft;
pub mod filter2d;
pub mod mm;
pub mod mmt;

use anyhow::{bail, Result};

use crate::sim::memory::ResourceUsage;

/// Table 5's per-app resource rows (the paper's measured utilisation;
/// our designs must match these shapes). Unknown app names are an
/// error, not a panic — callers (the CLI in particular) surface them
/// with usage instead of aborting.
pub fn table5_usage(app: &str) -> Result<ResourceUsage> {
    let usage = match app {
        "MM" => ResourceUsage { lut: 11403, ff: 105609, bram: 778, uram: 315, dsp: 0, aie: 384, plio: 72 },
        "Filter2D" => ResourceUsage { lut: 248546, ff: 455277, bram: 526, uram: 0, dsp: 168, aie: 352, plio: 88 },
        "FFT" => ResourceUsage { lut: 122650, ff: 214782, bram: 562, uram: 0, dsp: 96, aie: 80, plio: 32 },
        "MM-T" => ResourceUsage { lut: 61039, ff: 96791, bram: 34, uram: 0, dsp: 0, aie: 400, plio: 100 },
        other => bail!("unknown app {other:?} (known: MM, Filter2D, FFT, MM-T)"),
    };
    Ok(usage)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::params::HwParams;

    #[test]
    fn all_designs_fit_the_card() {
        let p = HwParams::vck5000();
        for app in ["MM", "Filter2D", "FFT", "MM-T"] {
            table5_usage(app).unwrap().check(&p).unwrap();
        }
    }

    #[test]
    fn unknown_app_is_an_error_not_a_panic() {
        let err = table5_usage("NotAnApp").unwrap_err().to_string();
        assert!(err.contains("NotAnApp"), "{err}");
        assert!(err.contains("known:"), "{err}");
    }

    #[test]
    fn aie_percentages_match_table5() {
        let p = HwParams::vck5000();
        let pct = |app: &str| table5_usage(app).unwrap().aie as f64 / p.total_aie as f64;
        assert!((pct("MM") - 0.96).abs() < 1e-9);
        assert!((pct("Filter2D") - 0.88).abs() < 1e-9);
        assert!((pct("FFT") - 0.20).abs() < 1e-9);
        assert!((pct("MM-T") - 1.00).abs() < 1e-9);
    }
}
