//! The MM accelerator (paper §4.2, Fig 7a, Table 6).
//!
//! Design: each PU = Parallel<16>*Cascade<4> (64 cores) computing a
//! 128x128x128 MM per iteration; DAC = SWH+BDC over 8 PLIOs (4 MatA +
//! 4 MatB, each multiplexed 4 ways and broadcast along a cascade row);
//! DCC = SWH over 4 PLIOs. One DU serves 6 PUs (PHD): TB = 27 128x128
//! matrices fetched JUB (56% URAM), sustaining 9 engine iterations;
//! results are aggregated/accumulated by the TPC and written back CSB.
//!
//! Real numerics: the `mm_pu128` artifact (the Layer-2 JAX graph of one
//! PU iteration, built on the Layer-1 `mm32` Pallas kernel) executes the
//! same block decomposition through PJRT.

use anyhow::{bail, Result};

use crate::api::{designs, Lane, ReportParams};
use crate::coordinator::controller::RunReport;
use crate::coordinator::scheduler::ExecMode;
use crate::engine::compute::cc::CcMode;
use crate::engine::compute::dac::{Dac, DacMode};
use crate::engine::compute::dcc::{Dcc, DccMode};
use crate::engine::compute::pu::{ProcessingStructure, ProcessingUnit};
use crate::engine::data::du::DataUnit;
use crate::engine::data::ssc::SscMode;
use crate::engine::data::tpc::{TaskBlock, TpcMode};
use crate::runtime::tensor::Tensor;
use crate::runtime::Runtime;
use crate::sim::core::KernelClass;
use crate::sim::ddr::AmcMode;
use crate::sim::params::HwParams;

/// PU-iteration tile edge (the PU solves TILE^3 per iteration).
pub const TILE: usize = 128;
/// Deployed PU count (96% of the array).
pub const MAX_PUS: usize = 6;

/// The paper's MM processing unit.
pub fn mm_pu() -> ProcessingUnit {
    ProcessingUnit::simple(
        "MM-PU",
        vec![ProcessingStructure {
            dacs: vec![Dac::new(vec![DacMode::Swh, DacMode::Bdc], 8, 64)],
            cc: CcMode::Parallel(16, Box::new(CcMode::Cascade(4))),
            dccs: vec![Dcc::new(DccMode::Swh, 4, 64)],
        }],
        KernelClass::F32Mac,
        2.0 * (TILE * TILE * TILE) as f64,
        2 * TILE * TILE * 4,
        TILE * TILE * 4,
    )
}

/// The paper's MM data unit serving `pus` PUs. `k_blocks` is the K-sweep
/// length (size/128): the TPC accumulates C partials in URAM and writes a
/// C block back only once its K-sweep completes.
pub fn mm_du(pus: usize, k_blocks: u64) -> DataUnit {
    let mut tb = TaskBlock::new(
        27 * TILE * TILE * 4, // 27 128x128 float matrices
        9,
        pus * TILE * TILE * 4,
    );
    tb.writeback_every = k_blocks.max(1);
    DataUnit {
        name: "MM-DU".into(),
        amc_read: Some(AmcMode::Jub),
        amc_write: Some(AmcMode::Csb),
        tpc: TpcMode::Cup,
        ssc_send: SscMode::Phd,
        ssc_recv: SscMode::Phd,
        tb,
        pus,
    }
}

/// Formula 1: iterations for one 32^3-loaded AIE core on an MxKxN MM.
pub fn iter_kernel(m: usize, k: usize, n: usize) -> u64 {
    (m.div_ceil(32) * k.div_ceil(32) * n.div_ceil(32)) as u64
}

/// Formula 2: computing-engine iterations for an MxKxN MM on `pus` PUs.
pub fn iter_computing_engine(m: usize, k: usize, n: usize, pus: usize) -> u64 {
    let blocks = (m.div_ceil(TILE) * k.div_ceil(TILE) * n.div_ceil(TILE)) as u64;
    blocks.div_ceil(pus as u64)
}

/// Simulate one square MM of edge `size` on `pus` active PUs.
pub fn run(p: &HwParams, size: usize, pus: usize, trace: bool) -> Result<RunReport> {
    run_rect(p, size, size, size, pus, trace)
}

/// Simulate an arbitrary M x K x N MM — the paper's "task scale
/// adaptation": the TPC pads partial blocks to full 128^3 subtasks
/// (Formula 2 rounds every dimension up), so any size deploys on the
/// same accelerator.
pub fn run_rect(
    p: &HwParams,
    m: usize,
    k: usize,
    n: usize,
    pus: usize,
    trace: bool,
) -> Result<RunReport> {
    if pus == 0 || pus > MAX_PUS {
        bail!("MM supports 1..={MAX_PUS} PUs, got {pus}");
    }
    if m == 0 || k == 0 || n == 0 {
        bail!("MM dimensions must be positive");
    }
    // GOPS counts useful arithmetic only (padding work is waste — this
    // is the honest adaptive-scale accounting for ragged sizes).
    let total_ops = 2.0 * m as f64 * k as f64 * n as f64;
    let label = if m == k && k == n {
        format!("{m}^3 float {pus}PU")
    } else {
        format!("{m}x{k}x{n} float {pus}PU")
    };
    designs::mm().report(
        p,
        &ReportParams {
            label,
            lanes: vec![Lane {
                du: mm_du(pus, k.div_ceil(TILE) as u64),
                engine_iters: iter_computing_engine(m, k, n, pus),
            }],
            tasks: 1.0,
            total_ops,
            usage: super::table5_usage("MM")?,
            mode: ExecMode::Regular,
            trace,
        },
    )
}

// ---------------------------------------------------------------------------
// Real-numerics path (PJRT)
// ---------------------------------------------------------------------------

/// Multiply two square row-major float matrices whose edge is a multiple
/// of 128 by routing every 128^3 block product through the `mm_pu128`
/// artifact — exactly the DU's decompose/aggregate duty (TPC accumulate).
pub fn matmul_via_pus(rt: &Runtime, a: &[f32], b: &[f32], size: usize) -> Result<Vec<f32>> {
    if size % TILE != 0 {
        bail!("size {size} must be a multiple of {TILE} (the DU pads real tasks)");
    }
    let nb = size / TILE;
    let mut c = vec![0.0f32; size * size];
    // A-blocks are reused across the bj sweep: extract each row of A
    // blocks once per bi (DU-side data reuse, the TB's raison d'etre).
    for bi in 0..nb {
        let a_row: Vec<Tensor> = (0..nb).map(|bk| extract_block(a, size, bi, bk)).collect();
        for bj in 0..nb {
            let mut acc = vec![0.0f32; TILE * TILE];
            for (bk, a_blk) in a_row.iter().enumerate() {
                let b_blk = extract_block(b, size, bk, bj);
                let out = rt.execute("mm_pu128", &[a_blk.clone(), b_blk])?;
                let part = out[0].as_f32()?;
                // TPC aggregation: accumulate the K-partials.
                for (dst, src) in acc.iter_mut().zip(part) {
                    *dst += *src;
                }
            }
            paste_block(&mut c, &acc, size, bi, bj);
        }
    }
    Ok(c)
}

/// Multiply float matrices of ANY size: pads to 128-multiples (the DU's
/// padding duty for adaptive task scales), runs the padded product
/// through the PUs, and crops the result.
pub fn matmul_any(
    rt: &Runtime,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
) -> Result<Vec<f32>> {
    if a.len() != m * k || b.len() != k * n {
        bail!("operand shapes do not match m/k/n");
    }
    let (mp, kp, np_) = (
        m.div_ceil(TILE) * TILE,
        k.div_ceil(TILE) * TILE,
        n.div_ceil(TILE) * TILE,
    );
    if mp != kp || kp != np_ {
        // The square fast path below assumes one padded edge; pad all
        // three dims to the max so matmul_via_pus applies.
        let edge = mp.max(kp).max(np_);
        let pa = pad(a, m, k, edge);
        let pb = pad(b, k, n, edge);
        let pc = matmul_via_pus(rt, &pa, &pb, edge)?;
        return Ok(crop(&pc, edge, m, n));
    }
    let pa = pad(a, m, k, mp);
    let pb = pad(b, k, n, mp);
    let pc = matmul_via_pus(rt, &pa, &pb, mp)?;
    Ok(crop(&pc, mp, m, n))
}

fn pad(src: &[f32], rows: usize, cols: usize, edge: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; edge * edge];
    for r in 0..rows {
        out[r * edge..r * edge + cols].copy_from_slice(&src[r * cols..(r + 1) * cols]);
    }
    out
}

fn crop(src: &[f32], edge: usize, rows: usize, cols: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * cols];
    for r in 0..rows {
        out[r * cols..(r + 1) * cols].copy_from_slice(&src[r * edge..r * edge + cols]);
    }
    out
}

/// Extract a TILE x TILE block as a ready-to-send tensor (single copy).
fn extract_block(src: &[f32], size: usize, bi: usize, bj: usize) -> Tensor {
    let mut blk = vec![0.0f32; TILE * TILE];
    for r in 0..TILE {
        let s = (bi * TILE + r) * size + bj * TILE;
        blk[r * TILE..(r + 1) * TILE].copy_from_slice(&src[s..s + TILE]);
    }
    Tensor::f32(&[TILE, TILE], blk)
}

fn paste_block(dst: &mut [f32], src: &[f32], size: usize, bi: usize, bj: usize) {
    for r in 0..TILE {
        let d = (bi * TILE + r) * size + bj * TILE;
        dst[d..d + TILE].copy_from_slice(&src[r * TILE..(r + 1) * TILE]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formulas_match_paper() {
        // §4.2: 128^3 needs 64 kernel iterations; 6 PUs on 768^3 need
        // ceil(6*6*6/6) = 36 engine iterations.
        assert_eq!(iter_kernel(128, 128, 128), 64);
        assert_eq!(iter_computing_engine(768, 768, 768, 6), 36);
        assert_eq!(iter_computing_engine(6144, 6144, 6144, 1), 110_592);
        // non-multiples round up
        assert_eq!(iter_computing_engine(129, 128, 128, 1), 2);
    }

    #[test]
    fn pu_matches_table4_shape() {
        let pu = mm_pu();
        assert_eq!(pu.cores(), 64);
        assert_eq!(pu.total_plios(), 12);
        assert!(pu.validate().is_ok());
    }

    #[test]
    fn run_rejects_bad_pu_counts() {
        let p = HwParams::vck5000();
        assert!(run(&p, 768, 0, false).is_err());
        assert!(run(&p, 768, 7, false).is_err());
    }

    #[test]
    fn table6_anchor_rows() {
        let p = HwParams::vck5000();
        // 768^3, 6 PUs: paper 0.44 ms / 2050 GOPS.
        let r = run(&p, 768, 6, false).unwrap();
        assert!((r.time_secs * 1e3 - 0.44).abs() / 0.44 < 0.15, "{}", r.time_secs * 1e3);
        // 6144^3, 6 PUs: paper 135.59 ms / 3421 GOPS.
        let r = run(&p, 6144, 6, false).unwrap();
        assert!((r.time_secs * 1e3 - 135.59).abs() / 135.59 < 0.10, "{}", r.time_secs * 1e3);
        assert!((r.gops - 3421.0).abs() / 3421.0 < 0.10, "{}", r.gops);
    }

    #[test]
    fn gops_per_aie_converges_with_scale() {
        // Table 6's shape: the per-core gap between 1 and 6 PUs closes as
        // the task grows.
        let p = HwParams::vck5000();
        let small_gap = {
            let a = run(&p, 768, 1, false).unwrap().gops_per_aie;
            let b = run(&p, 768, 6, false).unwrap().gops_per_aie;
            (a - b).abs() / a
        };
        let large_gap = {
            let a = run(&p, 3072, 1, false).unwrap().gops_per_aie;
            let b = run(&p, 3072, 6, false).unwrap().gops_per_aie;
            (a - b).abs() / a
        };
        assert!(large_gap < small_gap, "{large_gap} vs {small_gap}");
    }

    #[test]
    fn rect_and_ragged_sizes_adapt() {
        let p = HwParams::vck5000();
        // rectangular
        let r = run_rect(&p, 768, 1536, 384, 6, false).unwrap();
        assert!(r.time_secs > 0.0);
        // ragged: 130^3 pads to 2x2x2 blocks -> 8 subtasks, efficiency
        // drops vs an exact 256^3 (padding waste is not counted as work)
        let ragged = run_rect(&p, 130, 130, 130, 1, false).unwrap();
        let exact = run_rect(&p, 256, 256, 256, 1, false).unwrap();
        assert!(ragged.gops_per_aie < exact.gops_per_aie);
        assert!(run_rect(&p, 0, 128, 128, 1, false).is_err());
    }

    #[test]
    fn power_increases_with_pus() {
        let p = HwParams::vck5000();
        let w1 = run(&p, 1536, 1, false).unwrap().power_w;
        let w6 = run(&p, 1536, 6, false).unwrap().power_w;
        assert!(w6 > w1 + 15.0, "{w1} {w6}");
    }
}
