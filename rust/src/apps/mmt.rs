//! MM-T: the AIE compute-throughput probe (paper Table 9).
//!
//! 50 Cascade<8> chains (400 cores, 100% of the array), each core doing
//! one 32x32x32 float MM per chain iteration. The data engine is Null:
//! TPC = CHL holds the operands resident, SSC = THR wires each chain
//! straight through — no DDR traffic, no communication phases. What's
//! left is the sustained arithmetic rate of the array, which is exactly
//! what the paper uses MM-T to measure.
//!
//! Real numerics: the `mmt_cascade8` artifact is the Layer-2 graph of
//! one chain (8 chained `mm32_acc` Pallas calls).

use anyhow::{bail, Result};

use crate::api::{designs, Lane, ReportParams};
use crate::coordinator::controller::RunReport;
use crate::coordinator::scheduler::ExecMode;
use crate::engine::compute::cc::CcMode;
use crate::engine::compute::dac::{Dac, DacMode};
use crate::engine::compute::dcc::{Dcc, DccMode};
use crate::engine::compute::pu::{ProcessingStructure, ProcessingUnit};
use crate::engine::data::du::DataUnit;
use crate::engine::data::ssc::SscMode;
use crate::engine::data::tpc::{TaskBlock, TpcMode};
use crate::runtime::tensor::Tensor;
use crate::runtime::Runtime;
use crate::sim::core::KernelClass;
use crate::sim::params::HwParams;

/// Chains deployed (50 x Cascade<8> = 400 cores).
pub const CHAINS: usize = 50;
/// Cores per chain.
pub const CASCADE: usize = 8;
/// The base task: a 32^3 float MM per core per chain iteration.
pub const TASK_OPS: f64 = 2.0 * 32.0 * 32.0 * 32.0;

pub fn mmt_pu() -> ProcessingUnit {
    ProcessingUnit::simple(
        "MMT-PU",
        vec![ProcessingStructure {
            dacs: vec![Dac::new(vec![DacMode::Dir], 1, 1)],
            cc: CcMode::Cascade(CASCADE),
            dccs: vec![Dcc::new(DccMode::Dir, 1, 1)],
        }],
        KernelClass::F32Mac,
        // one 32^3 task per core per iteration; the cascade pipelines so
        // the iteration time is one task's time (steady state).
        CASCADE as f64 * TASK_OPS,
        0, // CHL: operands resident, nothing on the PLIOs per iteration
        0,
    )
}

pub fn mmt_du() -> DataUnit {
    DataUnit {
        name: "MMT-DU".into(),
        amc_read: None, // Null AMC (Table 4)
        amc_write: None,
        tpc: TpcMode::Chl,
        ssc_send: SscMode::Thr,
        ssc_recv: SscMode::Thr,
        tb: TaskBlock::new(0, 1, 0),
        pus: 1,
    }
}

/// Simulate `iters` chain iterations across all 50 chains.
pub fn run(p: &HwParams, iters: u64, trace: bool) -> Result<RunReport> {
    if iters == 0 {
        bail!("need at least one iteration");
    }
    let lanes: Vec<Lane> = (0..CHAINS)
        .map(|_| Lane { du: mmt_du(), engine_iters: iters })
        .collect();
    let tasks = (iters as usize * CHAINS * CASCADE) as f64;
    let total_ops = tasks * TASK_OPS;
    designs::mmt().report(
        p,
        &ReportParams {
            label: format!("MM-T x{iters}"),
            lanes,
            tasks,
            total_ops,
            usage: super::table5_usage("MM-T")?,
            mode: ExecMode::Regular,
            trace,
        },
    )
}

// ---------------------------------------------------------------------------
// Real-numerics path (PJRT)
// ---------------------------------------------------------------------------

/// One chain iteration: C = sum_k A_k B_k through `mmt_cascade8`.
pub fn chain_via_pu(rt: &Runtime, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
    if a.len() != 32 * 256 || b.len() != 256 * 32 {
        bail!("MM-T chain operands are 32x256 and 256x32");
    }
    let out = rt.execute(
        "mmt_cascade8",
        &[Tensor::f32(&[32, 256], a.to_vec()), Tensor::f32(&[256, 32], b.to_vec())],
    )?;
    Ok(out[0].as_f32()?.to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn design_uses_whole_array() {
        let pu = mmt_pu();
        assert!(pu.validate().is_ok());
        assert_eq!(pu.cores() * CHAINS, 400);
    }

    #[test]
    fn table9_anchor() {
        // Paper averages: 9.43e7 tasks/s, 6181.56 GOPS, 94.22 GOPS/W.
        let p = HwParams::vck5000();
        let r = run(&p, 20_000, false).unwrap();
        assert!((r.tasks_per_sec - 9.43e7).abs() / 9.43e7 < 0.05, "{}", r.tasks_per_sec);
        assert!((r.gops - 6181.56).abs() / 6181.56 < 0.05, "{}", r.gops);
        assert!((r.gops_per_aie - 15.45).abs() / 15.45 < 0.05, "{}", r.gops_per_aie);
        assert!((r.power_w - 65.61).abs() / 65.61 < 0.20, "{}", r.power_w);
    }

    #[test]
    fn no_ddr_traffic() {
        let p = HwParams::vck5000();
        let r = run(&p, 500, false).unwrap();
        assert_eq!(r.ddr_gbps, 0.0);
    }

    #[test]
    fn zero_iters_rejected() {
        let p = HwParams::vck5000();
        assert!(run(&p, 0, false).is_err());
    }
}
