//! The Filter2D accelerator (paper Fig 7b, Table 7).
//!
//! Design: each PU = Parallel<8> (8 cores, one 32x32 output tile each,
//! 5x5 filter with a 2-pixel halo); DAC/DCC = SWH on single PLIOs. One
//! DU serves 4 PUs (PHD); 11 DU-PU groups fill 88% of the array. Pixels
//! travel as 8-bit over the data path (images are 8-bit; the int32 of
//! Table 3 is the arithmetic/accumulator width — see EXPERIMENTS.md
//! notes), tiles are padded to full 32x32.
//!
//! Real numerics: the `filter2d_pu8` artifact (Layer-2 batched Pallas
//! kernel, 8 tiles = the Parallel<8> CC) through PJRT, with the TPC's
//! tile decompose / reassemble logic on the rust side.

use anyhow::{bail, Result};

use crate::api::{designs, Lane, ReportParams};
use crate::coordinator::controller::RunReport;
use crate::coordinator::scheduler::ExecMode;
use crate::engine::compute::cc::CcMode;
use crate::engine::compute::dac::{Dac, DacMode};
use crate::engine::compute::dcc::{Dcc, DccMode};
use crate::engine::compute::pu::{ProcessingStructure, ProcessingUnit};
use crate::engine::data::du::DataUnit;
use crate::engine::data::ssc::SscMode;
use crate::engine::data::tpc::{TaskBlock, TpcMode};
use crate::runtime::tensor::Tensor;
use crate::runtime::Runtime;
use crate::sim::core::{filter_ops, KernelClass};
use crate::sim::ddr::AmcMode;
use crate::sim::params::HwParams;

pub const TILE: usize = 32;
pub const TAPS: usize = 5;
pub const HALO: usize = TAPS - 1;
pub const IN_TILE: usize = TILE + HALO; // 36
/// Cores per PU (Parallel<8>).
pub const CORES_PER_PU: usize = 8;
/// PUs per DU (the 1:4 pair ratio).
pub const PUS_PER_DU: usize = 4;
/// Deployed PUs (44 = 11 DUs x 4).
pub const MAX_PUS: usize = 44;

/// Bytes of one input halo tile on the wire (8-bit pixels).
const IN_TILE_BYTES: usize = IN_TILE * IN_TILE;
/// Bytes of one output tile on the wire.
const OUT_TILE_BYTES: usize = TILE * TILE;

pub fn filter2d_pu() -> ProcessingUnit {
    ProcessingUnit::simple(
        "F2D-PU",
        vec![ProcessingStructure {
            dacs: vec![Dac::new(vec![DacMode::Swh], 1, CORES_PER_PU)],
            cc: CcMode::Parallel(CORES_PER_PU, Box::new(CcMode::Single)),
            dccs: vec![Dcc::new(DccMode::Swh, 1, CORES_PER_PU)],
        }],
        KernelClass::I32Mac,
        CORES_PER_PU as f64 * filter_ops(TILE * TILE, TAPS),
        CORES_PER_PU * IN_TILE_BYTES,
        CORES_PER_PU * OUT_TILE_BYTES,
    )
}

pub fn filter2d_du(pus: usize) -> DataUnit {
    DataUnit {
        name: "F2D-DU".into(),
        amc_read: Some(AmcMode::Jub),
        amc_write: Some(AmcMode::Csb),
        tpc: TpcMode::Cup,
        ssc_send: SscMode::Phd,
        ssc_recv: SscMode::Phd,
        // 4 engine iterations of tiles per TB
        tb: TaskBlock::new(
            4 * pus * CORES_PER_PU * IN_TILE_BYTES,
            4,
            pus * CORES_PER_PU * OUT_TILE_BYTES,
        ),
        pus,
    }
}

/// Tile count for an H x W image (padded up to whole tiles).
pub fn tiles(h: usize, w: usize) -> u64 {
    (h.div_ceil(TILE) * w.div_ceil(TILE)) as u64
}

/// Build the DU-PU lane set for `pus` active PUs (whole DUs first, then
/// a partial group — the paper's 20-PU config is 5 DUs x 4).
fn lanes_for(pus: usize, total_tiles: u64) -> Vec<Lane> {
    let mut lanes = Vec::new();
    let full = pus / PUS_PER_DU;
    let rem = pus % PUS_PER_DU;
    let n_groups = full + usize::from(rem > 0);
    // Tiles split across groups proportionally to their PU counts; each
    // engine iteration of a group consumes pus*8 tiles.
    let mut remaining = total_tiles;
    for gi in 0..n_groups {
        let g_pus = if gi < full { PUS_PER_DU } else { rem };
        let share = (total_tiles * g_pus as u64).div_ceil(pus as u64);
        let share = share.min(remaining);
        remaining -= share;
        let per_iter = (g_pus * CORES_PER_PU) as u64;
        lanes.push(Lane {
            du: filter2d_du(g_pus),
            engine_iters: share.div_ceil(per_iter),
        });
    }
    lanes
}

/// Simulate one H x W frame with a 5x5 kernel on `pus` active PUs.
pub fn run(p: &HwParams, h: usize, w: usize, pus: usize, trace: bool) -> Result<RunReport> {
    if pus == 0 || pus > MAX_PUS {
        bail!("Filter2D supports 1..={MAX_PUS} PUs, got {pus}");
    }
    let total_tiles = tiles(h, w);
    // Tiny frames cannot occupy every PU (the paper's 128x128 rows).
    let usable = pus.min((total_tiles as usize).div_ceil(CORES_PER_PU).max(1));
    designs::filter2d().report(
        p,
        &ReportParams {
            label: format!("{h}x{w} 5x5 {pus}PU"),
            lanes: lanes_for(usable, total_tiles),
            tasks: 1.0,
            total_ops: filter_ops(h * w, TAPS),
            usage: super::table5_usage("Filter2D")?,
            mode: ExecMode::Regular,
            trace,
        },
    )
}

// ---------------------------------------------------------------------------
// Real-numerics path (PJRT)
// ---------------------------------------------------------------------------

/// Filter a padded image through the `filter2d_pu8` artifact in batches
/// of 8 tiles (one PU iteration per call). `img` is (h+4) x (w+4) int32
/// row-major (halo included); returns the h x w filtered interior.
pub fn filter_image_via_pus(
    rt: &Runtime,
    img: &[i32],
    h: usize,
    w: usize,
    kernel: &[i32],
) -> Result<Vec<i32>> {
    if h % TILE != 0 || w % TILE != 0 {
        bail!("image must be padded to whole {TILE}x{TILE} tiles");
    }
    if kernel.len() != TAPS * TAPS {
        bail!("kernel must be {TAPS}x{TAPS}");
    }
    let iw = w + HALO;
    let th = h / TILE;
    let tw = w / TILE;
    let n_tiles = th * tw;
    let mut out = vec![0i32; h * w];
    let k_t = Tensor::i32(&[TAPS, TAPS], kernel.to_vec());

    let mut batch = Vec::with_capacity(8);
    let mut batch_ids = Vec::with_capacity(8);
    let flush = |batch: &mut Vec<i32>, ids: &mut Vec<usize>, out: &mut Vec<i32>| -> Result<()> {
        if ids.is_empty() {
            return Ok(());
        }
        // pad the last batch to 8 tiles (the DU pads real tasks)
        let real = ids.len();
        batch.resize(8 * IN_TILE * IN_TILE, 0);
        let res = rt.execute(
            "filter2d_pu8",
            &[Tensor::i32(&[8, IN_TILE, IN_TILE], batch.clone()), k_t.clone()],
        )?;
        let data = res[0].as_i32()?;
        for (slot, &tid) in ids.iter().enumerate().take(real) {
            let (ti, tj) = (tid / tw, tid % tw);
            for r in 0..TILE {
                let src = slot * TILE * TILE + r * TILE;
                let dst = (ti * TILE + r) * w + tj * TILE;
                out[dst..dst + TILE].copy_from_slice(&data[src..src + TILE]);
            }
        }
        batch.clear();
        ids.clear();
        Ok(())
    };

    for tid in 0..n_tiles {
        let (ti, tj) = (tid / tw, tid % tw);
        for r in 0..IN_TILE {
            let s = (ti * TILE + r) * iw + tj * TILE;
            batch.extend_from_slice(&img[s..s + IN_TILE]);
        }
        batch_ids.push(tid);
        if batch_ids.len() == 8 {
            flush(&mut batch, &mut batch_ids, &mut out)?;
        }
    }
    flush(&mut batch, &mut batch_ids, &mut out)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pu_shape() {
        let pu = filter2d_pu();
        assert!(pu.validate().is_ok());
        assert_eq!(pu.cores(), 8);
        assert_eq!(pu.total_plios(), 2);
    }

    #[test]
    fn tile_counts() {
        assert_eq!(tiles(128, 128), 16);
        assert_eq!(tiles(3480, 2160), 109 * 68);
        assert_eq!(tiles(15360, 8640), 480 * 270);
    }

    #[test]
    fn group_split_matches_pu_counts() {
        let g = lanes_for(44, 129_600);
        assert_eq!(g.len(), 11);
        assert!(g.iter().all(|x| x.du.pus == 4));
        let g = lanes_for(20, 10_000);
        assert_eq!(g.len(), 5);
        let g = lanes_for(6, 10_000);
        assert_eq!(g.len(), 2);
        assert_eq!(g[1].du.pus, 2);
    }

    #[test]
    fn table7_16k_anchor() {
        // 15360x8640, 44 PUs: paper 6.32 ms / 1050 GOPS.
        let p = HwParams::vck5000();
        let r = run(&p, 15360, 8640, 44, false).unwrap();
        let ms = r.time_secs * 1e3;
        assert!((ms - 6.32).abs() / 6.32 < 0.25, "time {ms} ms");
        assert!((r.gops - 1050.0).abs() / 1050.0 < 0.25, "gops {}", r.gops);
    }

    #[test]
    fn tiny_frame_cannot_use_more_pus() {
        // 128x128 = 16 tiles: 4 vs 44 PUs are within a few percent
        // (Table 7's first block), both dominated by dispatch.
        let p = HwParams::vck5000();
        let t44 = run(&p, 128, 128, 44, false).unwrap().time_secs;
        let t4 = run(&p, 128, 128, 4, false).unwrap().time_secs;
        assert!((t44 - t4).abs() / t4 < 0.2, "{t44} vs {t4}");
    }

    #[test]
    fn big_frames_scale_with_pus() {
        let p = HwParams::vck5000();
        let t44 = run(&p, 7680, 4320, 44, false).unwrap().time_secs;
        let t4 = run(&p, 7680, 4320, 4, false).unwrap().time_secs;
        assert!(t4 / t44 > 5.0, "t4={t4} t44={t44}");
    }

    #[test]
    fn single_core_efficiency_drops_with_more_pus() {
        // Table 7: GOPS/AIE 3.061 (4 PU) vs 2.984 (44 PU) at 16K.
        let p = HwParams::vck5000();
        let few = run(&p, 15360, 8640, 4, false).unwrap().gops_per_aie;
        let many = run(&p, 15360, 8640, 44, false).unwrap().gops_per_aie;
        assert!(few >= many, "{few} vs {many}");
        assert!((few - 3.06).abs() / 3.06 < 0.2, "{few}");
    }
}
