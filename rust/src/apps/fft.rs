//! The FFT accelerator (paper Fig 7c, Table 8).
//!
//! Design: each PU has two processing structures — PST#1 a Butterfly
//! component (BDC in), PST#2 a Parallel<2>*Cascade<3> group (DIR wiring)
//! — 10 cores per PU; 8 DU-PU pairs (1:1), AMC = CSB, TPC = CUP,
//! SSC = PHD. Intermediate stage data moves between the PSTs over the
//! core stream fabric, which paces the pipeline for large N; input and
//! output serialize on the DIR ports (`serial_comm`).
//!
//! The paper's dtype is cint16; the numerics substrate carries complex
//! data as two float32 planes (DESIGN.md), while the simulator uses
//! cint16 byte widths (4 B/sample either way).
//!
//! Feasibility: an 8192-point task across only 2 PUs exceeds the AIE
//! core memory (Table 8's N/A cell) — checked via
//! [`fft_fits`](crate::sim::memory::fft_fits).

use anyhow::{bail, Result};

use crate::api::{designs, Lane, ReportParams};
use crate::coordinator::controller::RunReport;
use crate::coordinator::scheduler::ExecMode;
use crate::engine::compute::cc::CcMode;
use crate::engine::compute::dac::{Dac, DacMode};
use crate::engine::compute::dcc::{Dcc, DccMode};
use crate::engine::compute::pu::{ProcessingStructure, ProcessingUnit};
use crate::engine::data::du::DataUnit;
use crate::engine::data::ssc::SscMode;
use crate::engine::data::tpc::{TaskBlock, TpcMode};
use crate::runtime::tensor::Tensor;
use crate::runtime::Runtime;
use crate::sim::core::{fft_ops, KernelClass};
use crate::sim::ddr::AmcMode;
use crate::sim::memory::fft_fits;
use crate::sim::params::HwParams;

/// Cores per PU: Butterfly[4] + Parallel<2>*Cascade<3> = 10.
pub const CORES_PER_PU: usize = 10;
/// Deployed PU (and DU) count.
pub const MAX_PUS: usize = 8;
/// Bytes per complex sample on the wire (cint16 = 2 x int16).
pub const BYTES_PER_SAMPLE: usize = 4;

pub fn fft_pu(n: usize) -> ProcessingUnit {
    let mut pu = ProcessingUnit::simple(
        "FFT-PU",
        vec![
            ProcessingStructure {
                dacs: vec![Dac::new(vec![DacMode::Bdc], 1, 4)],
                cc: CcMode::Butterfly { cores: 4 },
                dccs: vec![Dcc::new(DccMode::Dir, 1, 1)],
            },
            ProcessingStructure {
                dacs: vec![Dac::new(vec![DacMode::Dir], 1, 1)],
                cc: CcMode::Parallel(2, Box::new(CcMode::Cascade(3))),
                dccs: vec![Dcc::new(DccMode::Dir, 1, 1)],
            },
        ],
        KernelClass::Cint16Butterfly,
        fft_ops(n),
        n * BYTES_PER_SAMPLE,
        n * BYTES_PER_SAMPLE,
    );
    pu.serial_comm = true; // DIR in/out do not overlap
    pu.handoff_bytes = n * BYTES_PER_SAMPLE; // PST#1 -> PST#2 stream traffic
    pu
}

pub fn fft_du(n: usize, batch_iters: u64) -> DataUnit {
    DataUnit {
        name: "FFT-DU".into(),
        amc_read: Some(AmcMode::Csb),
        amc_write: Some(AmcMode::Csb),
        tpc: TpcMode::Cup,
        ssc_send: SscMode::Phd,
        ssc_recv: SscMode::Phd,
        // 8 tasks per TB, streamed CSB
        tb: TaskBlock::new(
            8 * n * BYTES_PER_SAMPLE,
            8.min(batch_iters.max(1)),
            n * BYTES_PER_SAMPLE,
        ),
        pus: 1,
    }
}

/// Simulate a batch of `tasks` N-point FFTs on `pus` active PU pairs.
/// Returns `None` when the configuration is infeasible (Table 8 N/A).
pub fn run(
    p: &HwParams,
    n: usize,
    pus: usize,
    tasks: u64,
    trace: bool,
) -> Result<Option<RunReport>> {
    if pus == 0 || pus > MAX_PUS {
        bail!("FFT supports 1..={MAX_PUS} PUs, got {pus}");
    }
    if !n.is_power_of_two() {
        bail!("FFT size must be a power of two, got {n}");
    }
    // Table 8 feasibility: task working set across the active PUs' cores.
    if !fft_fits(p, n, pus * CORES_PER_PU) {
        return Ok(None);
    }
    let per_pu = tasks.div_ceil(pus as u64);
    // 8 (or fewer) identical DU-PU pairs, one lane each
    let lanes: Vec<Lane> = (0..pus)
        .map(|_| Lane { du: fft_du(n, per_pu), engine_iters: per_pu })
        .collect();
    let total_ops = fft_ops(n) * (per_pu * pus as u64) as f64;
    let report = designs::fft(n)?.report(
        p,
        &ReportParams {
            label: format!("{n}-pt cint16 {pus}PU"),
            lanes,
            tasks: (per_pu * pus as u64) as f64,
            total_ops,
            usage: super::table5_usage("FFT")?,
            mode: ExecMode::Regular,
            trace,
        },
    )?;
    Ok(Some(report))
}

// ---------------------------------------------------------------------------
// Real-numerics path (PJRT)
// ---------------------------------------------------------------------------

/// Run one N-point FFT through the `fft{n}` artifact (complex data as
/// split float32 planes).
pub fn fft_via_pu(rt: &Runtime, re: &[f32], im: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
    let n = re.len();
    if im.len() != n {
        bail!("re/im length mismatch");
    }
    let name = format!("fft{n}");
    let out = rt.execute(
        &name,
        &[Tensor::f32(&[n], re.to_vec()), Tensor::f32(&[n], im.to_vec())],
    )?;
    Ok((out[0].as_f32()?.to_vec(), out[1].as_f32()?.to_vec()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pu_shape() {
        let pu = fft_pu(1024);
        assert!(pu.validate().is_ok());
        assert_eq!(pu.cores(), 10);
        assert!(pu.serial_comm);
    }

    #[test]
    fn na_cell_is_none() {
        let p = HwParams::vck5000();
        assert!(run(&p, 8192, 2, 64, false).unwrap().is_none()); // the N/A
        assert!(run(&p, 8192, 4, 64, false).unwrap().is_some());
        assert!(run(&p, 4096, 2, 64, false).unwrap().is_some());
    }

    #[test]
    fn table8_anchor_1024_8pu() {
        // Paper: 0.43 us/task aggregate -> 2.33M tasks/s on 8 PUs.
        let p = HwParams::vck5000();
        let r = run(&p, 1024, 8, 4096, false).unwrap().unwrap();
        let per_task_us = 1e6 / r.tasks_per_sec;
        assert!((per_task_us - 0.43).abs() / 0.43 < 0.25, "{per_task_us}");
    }

    #[test]
    fn scaling_with_n_superlinear() {
        // Table 8: per-task time roughly 2.1x per doubling of N.
        let p = HwParams::vck5000();
        let t1 = 1.0 / run(&p, 1024, 8, 2048, false).unwrap().unwrap().tasks_per_sec;
        let t2 = 1.0 / run(&p, 2048, 8, 2048, false).unwrap().unwrap().tasks_per_sec;
        let ratio = t2 / t1;
        assert!(ratio > 1.8 && ratio < 2.6, "ratio {ratio}");
    }

    #[test]
    fn scaling_with_pus_linear() {
        let p = HwParams::vck5000();
        let t8 = run(&p, 1024, 8, 4096, false).unwrap().unwrap().tasks_per_sec;
        let t4 = run(&p, 1024, 4, 4096, false).unwrap().unwrap().tasks_per_sec;
        let ratio = t8 / t4;
        assert!(ratio > 1.7 && ratio < 2.3, "ratio {ratio}");
    }

    #[test]
    fn rejects_bad_sizes() {
        let p = HwParams::vck5000();
        assert!(run(&p, 1000, 8, 16, false).is_err());
        assert!(run(&p, 1024, 0, 16, false).is_err());
    }
}
