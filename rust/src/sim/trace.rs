//! Event timeline capture + ASCII rendering.
//!
//! Every simulated run can record phase spans per lane (a lane is a DU or
//! a PU); the renderer draws the Figure 2 style pipeline diagram (compute
//! and communication phases alternating and overlapping across DU-PU
//! pairs) and the Figure 5 SSC service timings.

use std::collections::BTreeMap;

use super::params::HwParams;

/// What a lane is doing during a span.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// PU computing (AIE enable on).
    Compute,
    /// DU <-> PU communication (PLIO traffic).
    Comm,
    /// DU fetching a task block from DDR.
    Fetch,
    /// DU task processing (decompose/aggregate).
    Process,
    /// waiting on a dependency (stall).
    Stall,
}

impl Phase {
    pub fn glyph(&self) -> char {
        match self {
            Phase::Compute => '#',
            Phase::Comm => '=',
            Phase::Fetch => 'F',
            Phase::Process => 'p',
            Phase::Stall => '.',
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Phase::Compute => "compute",
            Phase::Comm => "comm",
            Phase::Fetch => "fetch",
            Phase::Process => "process",
            Phase::Stall => "stall",
        }
    }
}

/// One recorded span on one lane.
#[derive(Debug, Clone)]
pub struct Span {
    pub lane: String,
    pub phase: Phase,
    pub start_ps: u64,
    pub end_ps: u64,
}

/// The trace sink. Recording can be disabled (len-0 overhead in the hot
/// path of large sweeps).
#[derive(Debug, Default, Clone)]
pub struct Trace {
    pub enabled: bool,
    pub spans: Vec<Span>,
}

impl Trace {
    pub fn new(enabled: bool) -> Trace {
        Trace { enabled, spans: Vec::new() }
    }

    pub fn record(&mut self, lane: &str, phase: Phase, start_ps: u64, end_ps: u64) {
        if !self.enabled || end_ps <= start_ps {
            return;
        }
        self.spans.push(Span { lane: lane.to_string(), phase, start_ps, end_ps });
    }

    /// Total busy picoseconds per (lane, phase) — duty-cycle accounting.
    pub fn busy_ps(&self) -> BTreeMap<(String, &'static str), u64> {
        let mut m = BTreeMap::new();
        for s in &self.spans {
            *m.entry((s.lane.clone(), s.phase.name())).or_insert(0) += s.end_ps - s.start_ps;
        }
        m
    }

    /// Total busy picoseconds per phase aggregated across all lanes — the
    /// whole-run phase breakdown (what the sim backend's cost predictions
    /// report as fetch/comm/compute shares).
    pub fn phase_totals_ps(&self) -> BTreeMap<&'static str, u64> {
        let mut m = BTreeMap::new();
        for s in &self.spans {
            *m.entry(s.phase.name()).or_insert(0) += s.end_ps - s.start_ps;
        }
        m
    }

    /// Fraction of `[0, horizon]` a lane spends in `phase`.
    pub fn duty(&self, lane: &str, phase: Phase, horizon_ps: u64) -> f64 {
        if horizon_ps == 0 {
            return 0.0;
        }
        let busy: u64 = self
            .spans
            .iter()
            .filter(|s| s.lane == lane && s.phase == phase)
            .map(|s| s.end_ps.min(horizon_ps).saturating_sub(s.start_ps.min(horizon_ps)))
            .sum();
        busy as f64 / horizon_ps as f64
    }

    /// Mean compute duty across all PU lanes (power-model input).
    pub fn mean_pu_compute_duty(&self, horizon_ps: u64) -> f64 {
        let lanes: Vec<String> = {
            let mut v: Vec<String> = self
                .spans
                .iter()
                .filter(|s| s.lane.starts_with("PU"))
                .map(|s| s.lane.clone())
                .collect();
            v.sort();
            v.dedup();
            v
        };
        if lanes.is_empty() {
            return 0.0;
        }
        lanes.iter().map(|l| self.duty(l, Phase::Compute, horizon_ps)).sum::<f64>()
            / lanes.len() as f64
    }

    /// ASCII timeline: one row per lane, `width` character columns over
    /// `[t0, t1]`. This is the Figure 2 / Figure 5 renderer.
    pub fn render(&self, width: usize, t0_ps: u64, t1_ps: u64) -> String {
        assert!(t1_ps > t0_ps);
        let mut lanes: Vec<String> = self.spans.iter().map(|s| s.lane.clone()).collect();
        lanes.sort();
        lanes.dedup();
        let lane_w = lanes.iter().map(|l| l.len()).max().unwrap_or(4).max(4);
        let span_ps = (t1_ps - t0_ps) as f64;
        let mut out = String::new();
        out.push_str(&format!(
            "{:w$} |{}|\n",
            "lane",
            format!(
                " {:.2} us .. {:.2} us ({} cols)",
                HwParams::secs(t0_ps) * 1e6,
                HwParams::secs(t1_ps) * 1e6,
                width
            ),
            w = lane_w
        ));
        for lane in &lanes {
            let mut row = vec![' '; width];
            for s in self.spans.iter().filter(|s| &s.lane == lane) {
                if s.end_ps <= t0_ps || s.start_ps >= t1_ps {
                    continue;
                }
                let a = ((s.start_ps.max(t0_ps) - t0_ps) as f64 / span_ps * width as f64) as usize;
                let b = (((s.end_ps.min(t1_ps) - t0_ps) as f64 / span_ps * width as f64).ceil())
                    as usize;
                for c in row.iter_mut().take(b.min(width)).skip(a) {
                    *c = s.phase.glyph();
                }
            }
            out.push_str(&format!(
                "{:w$} |{}|\n",
                lane,
                row.iter().collect::<String>(),
                w = lane_w
            ));
        }
        out.push_str("legend: #=compute ===comm F=ddr-fetch p=process .=stall\n");
        out
    }

    pub fn horizon_ps(&self) -> u64 {
        self.spans.iter().map(|s| s.end_ps).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_trace_records_nothing() {
        let mut t = Trace::new(false);
        t.record("PU0", Phase::Compute, 0, 100);
        assert!(t.spans.is_empty());
    }

    #[test]
    fn duty_accounting() {
        let mut t = Trace::new(true);
        t.record("PU0", Phase::Compute, 0, 600);
        t.record("PU0", Phase::Comm, 600, 1000);
        assert!((t.duty("PU0", Phase::Compute, 1000) - 0.6).abs() < 1e-12);
        assert!((t.duty("PU0", Phase::Comm, 1000) - 0.4).abs() < 1e-12);
        assert!((t.mean_pu_compute_duty(1000) - 0.6).abs() < 1e-12);
    }

    #[test]
    fn render_contains_lanes_and_glyphs() {
        let mut t = Trace::new(true);
        t.record("DU0", Phase::Fetch, 0, 500);
        t.record("PU0", Phase::Compute, 500, 1000);
        let s = t.render(40, 0, 1000);
        assert!(s.contains("DU0"));
        assert!(s.contains("PU0"));
        assert!(s.contains('F'));
        assert!(s.contains('#'));
    }

    #[test]
    fn zero_length_spans_dropped() {
        let mut t = Trace::new(true);
        t.record("PU0", Phase::Compute, 5, 5);
        assert!(t.spans.is_empty());
    }

    #[test]
    fn busy_map() {
        let mut t = Trace::new(true);
        t.record("DU0", Phase::Fetch, 0, 10);
        t.record("DU0", Phase::Fetch, 20, 40);
        let m = t.busy_ps();
        assert_eq!(m[&("DU0".to_string(), "fetch")], 30);
    }

    #[test]
    fn phase_totals_aggregate_across_lanes() {
        let mut t = Trace::new(true);
        t.record("DU0", Phase::Fetch, 0, 10);
        t.record("DU1", Phase::Fetch, 5, 25);
        t.record("PU0", Phase::Compute, 10, 40);
        let m = t.phase_totals_ps();
        assert_eq!(m["fetch"], 30);
        assert_eq!(m["compute"], 30);
        assert!(!m.contains_key("stall"));
    }
}
