//! Calibrated VCK5000 hardware constants.
//!
//! Every free constant of the simulator lives here, fixed from the paper's
//! own measurements (DESIGN.md §6) and *held constant across all
//! experiments* — no per-table fitting. Times are carried in picoseconds
//! (u64) so the event loop is exactly deterministic.
//!
//! Calibration anchors (all from the paper):
//!
//! * **Table 2** (32^3 MM, single core, ideal simulation; 65 536 FLOP,
//!   12 288 B of operand+result traffic):
//!     - ideal compute = 65 536 / (16 ops/cyc * 1.33 GHz) = 3.080 µs
//!     - method 3 (DMA+agg)    = 3.080 + 12 288 B / 42.56 GB/s + 0.12 µs
//!                             = 3.49 µs  ✓  -> pins `dma_*`
//!     - method 2 (stream+agg) = 3.080 + 12 288 B / 2.222 GB/s
//!                             = 8.61 µs  ✓  -> pins `stream_bytes_per_sec`
//!       (effective leaf bandwidth through the stream-switch fabric)
//!     - method 1 (stream interleaved, 16-float grains) = method 2 +
//!       192 interrupts * 155.5 cyc = 31.06 µs ✓ -> pins
//!       `stream_interrupt_stall_cycles`
//! * **Table 9** (MM-T): 6181.56 GOPS on 400 cores = 15.45 GOPS/core
//!   sustained. Peak is 16 ops/cyc; the gap is the per-invocation
//!   overhead: 65 536/15.45e9 s = (4096 + 1545) cycles
//!   -> `kernel_setup_cycles` = 1545.
//! * **Table 6 power column**: power rises ~6.84 W per 64-core MM PU and
//!   MM-T (400 cores, higher duty) draws 65.6 W -> utilisation-scaled
//!   per-core power (see `power.rs` for the model equations).

/// Picoseconds per second.
pub const PS_PER_SEC: f64 = 1e12;

#[derive(Debug, Clone)]
pub struct HwParams {
    // ---- clocks ----
    /// AIE array clock (Hz).
    pub aie_clock_hz: f64,
    /// PL fabric clock (Hz).
    pub pl_clock_hz: f64,

    // ---- array geometry ----
    /// AIE array columns (VCK5000: 50).
    pub array_cols: usize,
    /// AIE array rows (VCK5000: 8).
    pub array_rows: usize,

    // ---- per-core compute ----
    /// Peak float ops/cycle (8 MACs * 2 ops on the 1024-bit SIMD unit).
    pub f32_ops_per_cycle: f64,
    /// Sustained int32 ops/cycle for MAC-style kernels (Filter2D) —
    /// int32 multiply is narrow on AIE1.
    pub i32_ops_per_cycle: f64,
    /// Sustained cint16 butterfly ops/cycle (complex MACs decomposed).
    pub cint16_ops_per_cycle: f64,
    /// Per-kernel-invocation overhead (lock acquire, loop prologue, DMA
    /// descriptor handling) in AIE cycles. Calibrated from Table 9.
    pub kernel_setup_cycles: f64,

    // ---- per-core memory ----
    /// Data memory per AIE core (bytes). VCK5000 AIE1: 32 KiB.
    pub core_mem_bytes: usize,

    // ---- communication ----
    /// Effective per-leaf stream bandwidth through the switch fabric
    /// (bytes/s). Calibrated from Table 2 method 2.
    pub stream_bytes_per_sec: f64,
    /// Per-core DMA rate once running (bytes/s): 32 B/cycle.
    pub dma_bytes_per_sec: f64,
    /// Fixed DMA transfer setup time (seconds). From Table 2 method 3.
    pub dma_setup_secs: f64,
    /// Pipeline stall per stream interruption when communication crosses
    /// computation (Table 2 method 1), in AIE cycles per grain.
    pub stream_interrupt_stall_cycles: f64,
    /// PLIO port width (bits per PL cycle). 128 per §3.4.
    pub plio_bits_per_cycle: f64,

    // ---- DDR ----
    /// Peak DDR bandwidth (bytes/s). VCK5000: 102.4 GB/s.
    pub ddr_peak_bytes_per_sec: f64,
    /// AMC-mode efficiency factors (fraction of peak).
    pub ddr_eff_csb: f64,
    pub ddr_eff_jub: f64,
    pub ddr_eff_unod: f64,
    /// Fixed DDR request setup (seconds) charged per AMC transfer.
    pub ddr_setup_secs: f64,

    // ---- controller ----
    /// PS-side task dispatch + pipeline fill/drain overhead charged once
    /// per user task (seconds). Dominates tiny workloads (the paper's
    /// 128x128 Filter2D rows, where TPS saturates ~6.4k/s).
    pub dispatch_secs: f64,

    // ---- PL resources (VCK5000 totals used for Table 5 percentages) ----
    pub total_lut: usize,
    pub total_ff: usize,
    pub total_bram: usize,
    pub total_uram: usize,
    pub total_dsp: usize,
    pub total_aie: usize,
    pub total_plio: usize,

    // ---- power model (PDM substitute; equations in power.rs) ----
    /// Card static power (W).
    pub power_static_w: f64,
    /// Power of one AIE core at 100% float duty (W).
    pub power_per_aie_w: f64,
    /// Datapath-width scale on per-core power for int32 work.
    pub power_int32_scale: f64,
    /// Datapath-width scale for cint16 butterfly work.
    pub power_cint16_scale: f64,
    /// PL power per kLUT configured (W).
    pub power_per_klut_w: f64,
    /// PL power per BRAM (W).
    pub power_per_bram_w: f64,
    /// PL power per URAM (W).
    pub power_per_uram_w: f64,
    /// PL power per DSP (W).
    pub power_per_dsp_w: f64,
    /// Power per active PLIO port (W).
    pub power_per_plio_w: f64,
    /// DDR I/O power per GB/s of achieved bandwidth (W).
    pub power_per_gbps_w: f64,
}

impl Default for HwParams {
    fn default() -> Self {
        HwParams::vck5000()
    }
}

impl HwParams {
    /// The calibrated VCK5000 model used by every experiment.
    pub fn vck5000() -> HwParams {
        HwParams {
            aie_clock_hz: 1.33e9,
            pl_clock_hz: 300e6,
            array_cols: 50,
            array_rows: 8,
            f32_ops_per_cycle: 16.0,
            i32_ops_per_cycle: 3.0,
            cint16_ops_per_cycle: 48.0,
            kernel_setup_cycles: 1545.0,
            core_mem_bytes: 32 * 1024,
            stream_bytes_per_sec: 2.222e9,
            dma_bytes_per_sec: 32.0 * 1.33e9, // 42.56 GB/s
            dma_setup_secs: 0.12e-6,
            stream_interrupt_stall_cycles: 155.5,
            plio_bits_per_cycle: 128.0,
            ddr_peak_bytes_per_sec: 102.4e9,
            ddr_eff_csb: 0.90,
            ddr_eff_jub: 0.62,
            ddr_eff_unod: 0.08,
            ddr_setup_secs: 0.12e-6,
            dispatch_secs: 120e-6,
            total_lut: 899_840,
            total_ff: 1_799_680,
            total_bram: 967,
            total_uram: 463,
            total_dsp: 1_968,
            total_aie: 400,
            total_plio: 156,
            power_static_w: 0.9,
            power_per_aie_w: 0.202,
            power_int32_scale: 0.35,
            power_cint16_scale: 1.4,
            power_per_klut_w: 0.02,
            power_per_bram_w: 0.002,
            power_per_uram_w: 0.003,
            power_per_dsp_w: 0.01,
            power_per_plio_w: 0.12,
            power_per_gbps_w: 0.03,
        }
    }

    /// AIE cycle time in seconds.
    pub fn aie_cycle_secs(&self) -> f64 {
        1.0 / self.aie_clock_hz
    }

    /// PLIO port bandwidth in bytes/s (128 b/PL-cycle at 300 MHz = 4.8 GB/s).
    pub fn plio_bytes_per_sec(&self) -> f64 {
        self.plio_bits_per_cycle / 8.0 * self.pl_clock_hz
    }

    /// Peak float GOPS of one core.
    pub fn peak_f32_gops_per_core(&self) -> f64 {
        self.f32_ops_per_cycle * self.aie_clock_hz / 1e9
    }

    /// Convert seconds to integer picoseconds (the sim's time unit).
    pub fn ps(secs: f64) -> u64 {
        (secs * PS_PER_SEC).round() as u64
    }

    /// Convert picoseconds back to seconds.
    pub fn secs(ps: u64) -> f64 {
        ps as f64 / PS_PER_SEC
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plio_rate_matches_spec() {
        let p = HwParams::vck5000();
        // 128 bit / PL cycle at 300 MHz = 4.8 GB/s
        assert!((p.plio_bytes_per_sec() - 4.8e9).abs() < 1e6);
    }

    #[test]
    fn mmt_sustained_rate_matches_table9() {
        let p = HwParams::vck5000();
        // one 32^3 task: 4096 compute cycles + setup
        let task_cycles = 65536.0 / p.f32_ops_per_cycle + p.kernel_setup_cycles;
        let task_secs = task_cycles / p.aie_clock_hz;
        let gops_per_core = 65536.0 / task_secs / 1e9;
        // Table 9: 6181.56 GOPS / 400 cores = 15.45 GOPS/core.
        assert!((gops_per_core - 15.45).abs() < 0.02, "{gops_per_core}");
    }

    #[test]
    fn table2_methods_reproduce() {
        let p = HwParams::vck5000();
        let compute = 65536.0 / p.f32_ops_per_cycle / p.aie_clock_hz;
        let bytes = 12288.0;
        let m3 = compute + bytes / p.dma_bytes_per_sec + p.dma_setup_secs;
        let m2 = compute + bytes / p.stream_bytes_per_sec;
        let grains = bytes / 64.0; // 16 floats per grain
        let m1 = m2 + grains * p.stream_interrupt_stall_cycles / p.aie_clock_hz;
        assert!((m3 * 1e6 - 3.49).abs() < 0.02, "m3={}", m3 * 1e6);
        assert!((m2 * 1e6 - 8.61).abs() < 0.02, "m2={}", m2 * 1e6);
        assert!((m1 * 1e6 - 31.06).abs() < 0.10, "m1={}", m1 * 1e6);
    }

    #[test]
    fn array_has_400_cores() {
        let p = HwParams::vck5000();
        assert_eq!(p.array_cols * p.array_rows, p.total_aie);
    }

    #[test]
    fn ps_roundtrip() {
        let s = 3.49e-6;
        assert!((HwParams::secs(HwParams::ps(s)) - s).abs() < 1e-12);
    }

    #[test]
    fn dma_faster_than_stream() {
        let p = HwParams::vck5000();
        assert!(p.dma_bytes_per_sec > 8.0 * p.stream_bytes_per_sec);
    }
}
