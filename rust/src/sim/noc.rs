//! The AIE-array stream-switch network (NoC) model.
//!
//! Versal's AIE array routes inter-core streams through per-tile stream
//! switches (§1's "flexible and convenient high-speed network of chips").
//! The model is XY dimension-ordered routing over the 8x50 tile grid:
//! each hop adds latency, and each switch-to-switch link has finite
//! bandwidth shared by the circuits crossing it. The EA4RCA framework
//! minimises inter-PU traffic (paper §3.3: "data channels between PUs
//! are only open during the communication phase ... minimise inter-PU
//! communication"), and this module is what quantifies the cost when a
//! deployment *does* need it — plus the placement-distance accounting
//! behind `benches/ablate_placement.rs`.

use super::array::Region;
use super::params::HwParams;

/// A tile coordinate in the AIE array.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Tile {
    pub col: usize,
    pub row: usize,
}

/// Per-hop latency in AIE cycles (a registered stream switch stage).
pub const CYCLES_PER_HOP: f64 = 1.0;

/// XY (column-then-row) dimension-ordered route between two tiles.
/// Returns the sequence of tiles traversed, excluding the source.
pub fn route(from: Tile, to: Tile) -> Vec<Tile> {
    let mut path = Vec::new();
    let mut cur = from;
    while cur.col != to.col {
        cur.col = if to.col > cur.col { cur.col + 1 } else { cur.col - 1 };
        path.push(cur);
    }
    while cur.row != to.row {
        cur.row = if to.row > cur.row { cur.row + 1 } else { cur.row - 1 };
        path.push(cur);
    }
    path
}

/// Manhattan hop count between two tiles.
pub fn hops(from: Tile, to: Tile) -> usize {
    from.col.abs_diff(to.col) + from.row.abs_diff(to.row)
}

/// Centre tile of a placed region (the PU's representative coordinate).
pub fn region_centre(r: &Region) -> Tile {
    Tile { col: r.col0 + r.cols / 2, row: r.row0 + r.rows / 2 }
}

/// A reserved stream circuit between two tiles.
#[derive(Debug, Clone)]
pub struct Circuit {
    pub from: Tile,
    pub to: Tile,
    pub hops: usize,
}

/// The NoC: tracks per-link circuit loads for contention accounting.
#[derive(Debug)]
pub struct Noc {
    cols: usize,
    rows: usize,
    /// circuits crossing each tile's switch (col-major)
    load: Vec<u32>,
    pub circuits: Vec<Circuit>,
}

impl Noc {
    pub fn new(p: &HwParams) -> Noc {
        Noc {
            cols: p.array_cols,
            rows: p.array_rows,
            load: vec![0; p.array_cols * p.array_rows],
            circuits: Vec::new(),
        }
    }

    fn idx(&self, t: Tile) -> usize {
        t.col * self.rows + t.row
    }

    /// Reserve a circuit; every switch along the XY route gains load.
    pub fn connect(&mut self, from: Tile, to: Tile) -> Circuit {
        assert!(from.col < self.cols && from.row < self.rows, "from out of array");
        assert!(to.col < self.cols && to.row < self.rows, "to out of array");
        for t in route(from, to) {
            let i = self.idx(t);
            self.load[i] += 1;
        }
        let c = Circuit { from, to, hops: hops(from, to) };
        self.circuits.push(c.clone());
        c
    }

    /// Max circuits sharing any one switch (the contention hot spot).
    pub fn max_switch_load(&self) -> u32 {
        self.load.iter().copied().max().unwrap_or(0)
    }

    /// Transfer seconds for `bytes` over a circuit: hop latency plus the
    /// wire time derated by the hottest switch it crosses (circuits
    /// time-share a switch's stream ports).
    pub fn transfer_secs(&self, p: &HwParams, c: &Circuit, bytes: usize) -> f64 {
        let latency = c.hops as f64 * CYCLES_PER_HOP / p.aie_clock_hz;
        let share = route(c.from, c.to)
            .iter()
            .map(|t| self.load[self.idx(*t)])
            .max()
            .unwrap_or(1)
            .max(1) as f64;
        latency + bytes as f64 * share / p.stream_bytes_per_sec
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xy_route_shape() {
        let path = route(Tile { col: 0, row: 0 }, Tile { col: 3, row: 2 });
        assert_eq!(path.len(), 5);
        assert_eq!(path.last(), Some(&Tile { col: 3, row: 2 }));
        // column-first: first three steps move along columns
        assert!(path[..3].iter().all(|t| t.row == 0));
    }

    #[test]
    fn hops_is_manhattan() {
        assert_eq!(hops(Tile { col: 1, row: 1 }, Tile { col: 4, row: 7 }), 9);
        assert_eq!(hops(Tile { col: 2, row: 3 }, Tile { col: 2, row: 3 }), 0);
    }

    #[test]
    fn contention_raises_transfer_time() {
        let p = HwParams::vck5000();
        let mut noc = Noc::new(&p);
        let a = Tile { col: 0, row: 0 };
        let b = Tile { col: 10, row: 0 };
        let c1 = noc.connect(a, b);
        let solo = noc.transfer_secs(&p, &c1, 4096);
        // five more circuits over the same switches
        for _ in 0..5 {
            noc.connect(a, b);
        }
        let contended = noc.transfer_secs(&p, &c1, 4096);
        assert!(contended > solo * 4.0, "{solo} vs {contended}");
        assert_eq!(noc.max_switch_load(), 6);
    }

    #[test]
    fn disjoint_circuits_do_not_interact() {
        let p = HwParams::vck5000();
        let mut noc = Noc::new(&p);
        let c1 = noc.connect(Tile { col: 0, row: 0 }, Tile { col: 5, row: 0 });
        let before = noc.transfer_secs(&p, &c1, 4096);
        noc.connect(Tile { col: 20, row: 3 }, Tile { col: 30, row: 3 });
        let after = noc.transfer_secs(&p, &c1, 4096);
        assert_eq!(before, after);
    }

    #[test]
    fn region_centres() {
        let r = Region { col0: 8, row0: 0, cols: 8, rows: 8 };
        assert_eq!(region_centre(&r), Tile { col: 12, row: 4 });
    }

    #[test]
    #[should_panic(expected = "out of array")]
    fn rejects_out_of_array() {
        let p = HwParams::vck5000();
        let mut noc = Noc::new(&p);
        noc.connect(Tile { col: 0, row: 0 }, Tile { col: 99, row: 0 });
    }
}
