//! The 8x50 AIE array and PU placement.
//!
//! Placement matters for two things in the model: (a) feasibility — a PU's
//! cores must be a contiguous rectangle-ish region so cascade wires exist
//! (cascade chains run along rows on the real silicon), and (b) the
//! utilisation numbers of Table 5. The placer is a simple column-major
//! first-fit over whole columns, which matches how the paper packs
//! 64-core PUs (8 rows x 8 columns per PU, 6 PUs = 48 of 50 columns).
//!
//! A PU whose core count is `k*rows + rem` (full columns plus a partial
//! trailing column — the FFT PU's 10 cores, for example) is placed as
//! the full-height block and an adjacent partial column, so the cascade
//! region stays contiguous; see [`AieArray::place`].

use anyhow::{bail, Result};

use super::params::HwParams;

/// A placed rectangular region of cores.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Region {
    pub col0: usize,
    pub row0: usize,
    pub cols: usize,
    pub rows: usize,
}

impl Region {
    pub fn cores(&self) -> usize {
        self.cols * self.rows
    }
}

/// One placed PU: a contiguous span of columns, made of a full-height
/// column block and/or a partial trailing column. Cascade chains run
/// along rows, and the regions share a column boundary, so the wiring
/// invariant (every core reachable from the slice leader without
/// crossing foreign cores) holds for the whole placement.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Placement {
    /// One region (rectangular PU) or two (full block + partial column).
    pub regions: Vec<Region>,
}

impl Placement {
    pub fn cores(&self) -> usize {
        self.regions.iter().map(Region::cores).sum()
    }

    /// The main (largest) region — what NoC routing anchors on.
    pub fn primary(&self) -> &Region {
        self.regions
            .iter()
            .max_by_key(|r| r.cores())
            .expect("placement has at least one region")
    }
}

/// The AIE array with an occupancy grid.
#[derive(Debug, Clone)]
pub struct AieArray {
    pub cols: usize,
    pub rows: usize,
    occupied: Vec<bool>, // col-major
}

impl AieArray {
    pub fn new(p: &HwParams) -> AieArray {
        AieArray {
            cols: p.array_cols,
            rows: p.array_rows,
            occupied: vec![false; p.array_cols * p.array_rows],
        }
    }

    fn idx(&self, col: usize, row: usize) -> usize {
        col * self.rows + row
    }

    fn region_free(&self, r: &Region) -> bool {
        for c in r.col0..r.col0 + r.cols {
            for w in r.row0..r.row0 + r.rows {
                if self.occupied[self.idx(c, w)] {
                    return false;
                }
            }
        }
        true
    }

    fn mark(&mut self, r: &Region, val: bool) {
        for c in r.col0..r.col0 + r.cols {
            for w in r.row0..r.row0 + r.rows {
                let i = self.idx(c, w);
                self.occupied[i] = val;
            }
        }
    }

    /// First free row offset that fits `rows` consecutive free cells in
    /// one column, if any.
    fn fit_in_column(&self, col: usize, rows: usize) -> Option<usize> {
        (0..=self.rows - rows).find(|&row0| {
            self.region_free(&Region { col0: col, row0, cols: 1, rows })
        })
    }

    /// Place `cores` as a column-major block (first fit): full-height
    /// columns first, plus — when the count does not tile the array
    /// height — a partial column immediately after the block, so the
    /// whole PU stays a contiguous column span (the cascade invariant).
    /// Fails with a readable error only when no column span fits.
    pub fn place(&mut self, cores: usize) -> Result<Placement> {
        if cores == 0 {
            bail!("cannot place an empty PU");
        }
        let full_cols = cores / self.rows;
        let rem = cores % self.rows;

        // Purely partial PU (< one column): first fit anywhere.
        if full_cols == 0 {
            for col0 in 0..self.cols {
                if let Some(row0) = self.fit_in_column(col0, rem) {
                    let r = Region { col0, row0, cols: 1, rows: rem };
                    self.mark(&r, true);
                    return Ok(Placement { regions: vec![r] });
                }
            }
            bail!(
                "no room for a {cores}-core PU (used {}/{})",
                self.used(),
                self.total()
            );
        }

        let span = full_cols + usize::from(rem > 0);
        if span > self.cols {
            bail!(
                "a {cores}-core PU needs {span} contiguous columns but the array \
                 is only {} columns wide",
                self.cols
            );
        }
        for col0 in 0..=self.cols - span {
            let block = Region { col0, row0: 0, cols: full_cols, rows: self.rows };
            if !self.region_free(&block) {
                continue;
            }
            if rem == 0 {
                self.mark(&block, true);
                return Ok(Placement { regions: vec![block] });
            }
            // the trailing partial column must touch the block
            if let Some(row0) = self.fit_in_column(col0 + full_cols, rem) {
                let tail = Region { col0: col0 + full_cols, row0, cols: 1, rows: rem };
                self.mark(&block, true);
                self.mark(&tail, true);
                return Ok(Placement { regions: vec![block, tail] });
            }
        }
        bail!(
            "no room for a {cores}-core PU ({full_cols} full columns + {rem} cores; \
             used {}/{})",
            self.used(),
            self.total()
        );
    }

    /// Release a placement (all of its regions).
    pub fn free(&mut self, p: &Placement) {
        for r in &p.regions {
            self.mark(r, false);
        }
    }

    pub fn used(&self) -> usize {
        self.occupied.iter().filter(|o| **o).count()
    }

    pub fn total(&self) -> usize {
        self.cols * self.rows
    }

    pub fn utilization(&self) -> f64 {
        self.used() as f64 / self.total() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn six_mm_pus_fit_like_the_paper() {
        let p = HwParams::vck5000();
        let mut arr = AieArray::new(&p);
        let mut placements = Vec::new();
        for _ in 0..6 {
            placements.push(arr.place(64).unwrap()); // 8x8 each
        }
        assert_eq!(arr.used(), 384);
        assert!((arr.utilization() - 0.96).abs() < 1e-9);
        // a seventh 64-core PU must not fit (only 2 columns left)
        assert!(arr.place(64).is_err());
        // but a small partial-column PU still does
        assert!(arr.place(8).is_ok());
        for pl in &placements {
            assert_eq!(pl.cores(), 64);
            assert_eq!(pl.regions.len(), 1);
        }
    }

    #[test]
    fn free_releases_space() {
        let p = HwParams::vck5000();
        let mut arr = AieArray::new(&p);
        let pl = arr.place(400).unwrap();
        assert_eq!(arr.used(), 400);
        arr.free(&pl);
        assert_eq!(arr.used(), 0);
        assert!(arr.place(64).is_ok());
    }

    #[test]
    fn mixed_full_plus_partial_pu_places_contiguously() {
        // 12 = 1.5 columns of 8: one full column + a 4-core tail in the
        // next column — previously a bail, now the golden shape.
        let p = HwParams::vck5000();
        let mut arr = AieArray::new(&p);
        let pl = arr.place(12).unwrap();
        assert_eq!(pl.cores(), 12);
        assert_eq!(
            pl.regions,
            vec![
                Region { col0: 0, row0: 0, cols: 1, rows: 8 },
                Region { col0: 1, row0: 0, cols: 1, rows: 4 },
            ]
        );
        assert_eq!(pl.primary().cores(), 8);
        assert_eq!(arr.used(), 12);
        // partial single column is still fine
        assert!(arr.place(6).is_ok());
    }

    #[test]
    fn fft_pus_place_directly() {
        // The FFT PU is 10 cores (Butterfly[4] + Parallel<2>*Cascade<3>):
        // 1 full column + 2 cores. Eight of them fit in 16 columns.
        let p = HwParams::vck5000();
        let mut arr = AieArray::new(&p);
        let pls: Vec<_> = (0..8).map(|_| arr.place(10).unwrap()).collect();
        assert_eq!(arr.used(), 80);
        for (i, pl) in pls.iter().enumerate() {
            assert_eq!(pl.cores(), 10);
            assert_eq!(pl.regions.len(), 2);
            // contiguous column span: tail column is block column + 1
            assert_eq!(pl.regions[1].col0, pl.regions[0].col0 + pl.regions[0].cols);
            assert_eq!(pl.regions[0].col0, i * 2, "first-fit packs left to right");
        }
    }

    #[test]
    fn truly_full_array_is_a_readable_error() {
        let p = HwParams::vck5000();
        let mut arr = AieArray::new(&p);
        arr.place(400).unwrap(); // the whole 8x50 array
        let err = arr.place(12).unwrap_err().to_string();
        assert!(err.contains("no room"), "{err}");
        assert!(err.contains("400/400"), "{err}");
    }

    #[test]
    fn oversized_pu_is_a_readable_error_not_a_panic() {
        // wider than the array: 401 cores = 50 full columns + 1, i.e. a
        // 51-column span on a 50-column array — must bail, not index
        // out of bounds
        let p = HwParams::vck5000();
        let mut arr = AieArray::new(&p);
        for cores in [401usize, 409, 500, 10_000] {
            let err = arr.place(cores).unwrap_err().to_string();
            assert!(err.contains("columns"), "{cores}: {err}");
        }
        assert_eq!(arr.used(), 0, "failed placements must not mark cells");
        // exactly the full array still fits
        assert_eq!(arr.place(400).unwrap().cores(), 400);
    }

    #[test]
    fn place_free_replace_reuses_freed_regions() {
        // Lifecycle churn: free a placement in the middle of the array
        // and the next same-shape PU lands exactly in the hole.
        let p = HwParams::vck5000();
        let mut arr = AieArray::new(&p);
        let a = arr.place(64).unwrap();
        let b = arr.place(64).unwrap();
        let c = arr.place(64).unwrap();
        assert_eq!(arr.used(), 192);
        arr.free(&b);
        assert_eq!(arr.used(), 128);
        let b2 = arr.place(64).unwrap();
        assert_eq!(b2, b, "first fit reuses the freed region");
        assert_eq!(arr.used(), 192);
        arr.free(&a);
        arr.free(&b2);
        arr.free(&c);
        assert_eq!(arr.used(), 0);
        assert!((arr.utilization() - 0.0).abs() < 1e-12);
    }

    #[test]
    fn utilization_consistent_across_mixed_churn() {
        let p = HwParams::vck5000();
        let mut arr = AieArray::new(&p);
        let mut live = Vec::new();
        let mut expect = 0usize;
        for (i, cores) in [10usize, 64, 6, 12, 8, 26].iter().enumerate() {
            let pl = arr.place(*cores).unwrap();
            assert_eq!(pl.cores(), *cores);
            expect += cores;
            assert_eq!(arr.used(), expect, "after place #{i}");
            live.push(pl);
        }
        // free every other placement, then re-place the same shapes
        for pl in live.iter().step_by(2) {
            arr.free(pl);
            expect -= pl.cores();
        }
        assert_eq!(arr.used(), expect);
        for pl in live.iter().step_by(2) {
            let again = arr.place(pl.cores()).unwrap();
            assert_eq!(again.cores(), pl.cores());
            expect += pl.cores();
        }
        assert_eq!(arr.used(), expect);
        assert!((arr.utilization() - expect as f64 / 400.0).abs() < 1e-12);
    }

    #[test]
    fn filter2d_fills_88_percent() {
        let p = HwParams::vck5000();
        let mut arr = AieArray::new(&p);
        for _ in 0..44 {
            arr.place(8).unwrap(); // Parallel<8> = one column per PU
        }
        assert_eq!(arr.used(), 352);
        assert!((arr.utilization() - 0.88).abs() < 1e-9);
    }
}
