//! The 8x50 AIE array and PU placement.
//!
//! Placement matters for two things in the model: (a) feasibility — a PU's
//! cores must be a contiguous rectangle-ish region so cascade wires exist
//! (cascade chains run along rows on the real silicon), and (b) the
//! utilisation numbers of Table 5. The placer is a simple column-major
//! first-fit over whole columns, which matches how the paper packs
//! 64-core PUs (8 rows x 8 columns per PU, 6 PUs = 48 of 50 columns).

use anyhow::{bail, Result};

use super::params::HwParams;

/// A placed rectangular region of cores.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Region {
    pub col0: usize,
    pub row0: usize,
    pub cols: usize,
    pub rows: usize,
}

impl Region {
    pub fn cores(&self) -> usize {
        self.cols * self.rows
    }
}

/// The AIE array with an occupancy grid.
#[derive(Debug, Clone)]
pub struct AieArray {
    pub cols: usize,
    pub rows: usize,
    occupied: Vec<bool>, // col-major
}

impl AieArray {
    pub fn new(p: &HwParams) -> AieArray {
        AieArray {
            cols: p.array_cols,
            rows: p.array_rows,
            occupied: vec![false; p.array_cols * p.array_rows],
        }
    }

    fn idx(&self, col: usize, row: usize) -> usize {
        col * self.rows + row
    }

    fn region_free(&self, r: &Region) -> bool {
        for c in r.col0..r.col0 + r.cols {
            for w in r.row0..r.row0 + r.rows {
                if self.occupied[self.idx(c, w)] {
                    return false;
                }
            }
        }
        true
    }

    fn mark(&mut self, r: &Region, val: bool) {
        for c in r.col0..r.col0 + r.cols {
            for w in r.row0..r.row0 + r.rows {
                let i = self.idx(c, w);
                self.occupied[i] = val;
            }
        }
    }

    /// Place `cores` as a full-height column block (first fit). The paper
    /// packs PUs column-wise so cascade rows stay contiguous.
    pub fn place(&mut self, cores: usize) -> Result<Region> {
        if cores == 0 {
            bail!("cannot place an empty PU");
        }
        // Prefer full-height column blocks; fall back to a partial column.
        let full_cols = cores / self.rows;
        let rem = cores % self.rows;
        if full_cols > 0 && rem != 0 {
            bail!(
                "PU of {cores} cores does not tile the {}-row array; \
                 pad the CC to a multiple of {} or use fewer cores",
                self.rows,
                self.rows
            );
        }
        let (want_cols, want_rows) = if full_cols > 0 { (full_cols, self.rows) } else { (1, rem) };
        for col0 in 0..=self.cols.saturating_sub(want_cols) {
            for row0 in 0..=self.rows - want_rows {
                let r = Region { col0, row0, cols: want_cols, rows: want_rows };
                if self.region_free(&r) {
                    self.mark(&r, true);
                    return Ok(r);
                }
            }
        }
        bail!("no room for a {cores}-core PU (used {}/{})", self.used(), self.total());
    }

    pub fn free(&mut self, r: &Region) {
        self.mark(r, false);
    }

    pub fn used(&self) -> usize {
        self.occupied.iter().filter(|o| **o).count()
    }

    pub fn total(&self) -> usize {
        self.cols * self.rows
    }

    pub fn utilization(&self) -> f64 {
        self.used() as f64 / self.total() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn six_mm_pus_fit_like_the_paper() {
        let p = HwParams::vck5000();
        let mut arr = AieArray::new(&p);
        let mut regions = Vec::new();
        for _ in 0..6 {
            regions.push(arr.place(64).unwrap()); // 8x8 each
        }
        assert_eq!(arr.used(), 384);
        assert!((arr.utilization() - 0.96).abs() < 1e-9);
        // a seventh 64-core PU must not fit (only 2 columns left)
        assert!(arr.place(64).is_err());
        // but a small partial-column PU still does
        assert!(arr.place(8).is_ok());
        for r in &regions {
            assert_eq!(r.cores(), 64);
        }
    }

    #[test]
    fn free_releases_space() {
        let p = HwParams::vck5000();
        let mut arr = AieArray::new(&p);
        let r = arr.place(400).unwrap();
        assert_eq!(arr.used(), 400);
        arr.free(&r);
        assert_eq!(arr.used(), 0);
        assert!(arr.place(64).is_ok());
    }

    #[test]
    fn rejects_non_tiling_pu() {
        let p = HwParams::vck5000();
        let mut arr = AieArray::new(&p);
        assert!(arr.place(12).is_err()); // 12 = 1.5 columns of 8
        assert!(arr.place(6).is_ok()); // partial single column is fine
    }

    #[test]
    fn filter2d_fills_88_percent() {
        let p = HwParams::vck5000();
        let mut arr = AieArray::new(&p);
        for _ in 0..44 {
            arr.place(8).unwrap(); // Parallel<8> = one column per PU
        }
        assert_eq!(arr.used(), 352);
        assert!((arr.utilization() - 0.88).abs() < 1e-9);
    }
}
