//! The resource ledger: what a deployed accelerator design consumes on
//! the card — AIE cores, PLIO ports, PL fabric (LUT/FF/BRAM/URAM/DSP),
//! and per-core data memory. This regenerates Table 5 and enforces the
//! feasibility checks behind Table 8's "N/A" cell (8192-point FFT on two
//! PUs exceeds AIE core memory).

use std::fmt;

use anyhow::{bail, Result};

use super::params::HwParams;

/// Resources consumed by a design (Table 5's columns).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ResourceUsage {
    pub lut: usize,
    pub ff: usize,
    pub bram: usize,
    pub uram: usize,
    pub dsp: usize,
    pub aie: usize,
    pub plio: usize,
}

impl ResourceUsage {
    pub fn add(&self, other: &ResourceUsage) -> ResourceUsage {
        ResourceUsage {
            lut: self.lut + other.lut,
            ff: self.ff + other.ff,
            bram: self.bram + other.bram,
            uram: self.uram + other.uram,
            dsp: self.dsp + other.dsp,
            aie: self.aie + other.aie,
            plio: self.plio + other.plio,
        }
    }

    pub fn scaled(&self, n: usize) -> ResourceUsage {
        ResourceUsage {
            lut: self.lut * n,
            ff: self.ff * n,
            bram: self.bram * n,
            uram: self.uram * n,
            dsp: self.dsp * n,
            aie: self.aie * n,
            plio: self.plio * n,
        }
    }

    /// Validate against the card's totals.
    pub fn check(&self, p: &HwParams) -> Result<()> {
        let checks = [
            ("LUT", self.lut, p.total_lut),
            ("FF", self.ff, p.total_ff),
            ("BRAM", self.bram, p.total_bram),
            ("URAM", self.uram, p.total_uram),
            ("DSP", self.dsp, p.total_dsp),
            ("AIE", self.aie, p.total_aie),
            ("PLIO", self.plio, p.total_plio),
        ];
        for (name, used, total) in checks {
            if used > total {
                bail!("design exceeds {name}: {used} > {total}");
            }
        }
        Ok(())
    }

    /// Percentage strings like Table 5 ("384(96%)").
    pub fn table5_row(&self, p: &HwParams) -> Vec<String> {
        let pct = |used: usize, total: usize| {
            format!("{}({}%)", used, (used as f64 / total as f64 * 100.0).round())
        };
        vec![
            pct(self.lut, p.total_lut),
            pct(self.ff, p.total_ff),
            pct(self.bram, p.total_bram),
            pct(self.uram, p.total_uram),
            pct(self.dsp, p.total_dsp),
            pct(self.aie, p.total_aie),
        ]
    }
}

impl fmt::Display for ResourceUsage {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "LUT={} FF={} BRAM={} URAM={} DSP={} AIE={} PLIO={}",
            self.lut, self.ff, self.bram, self.uram, self.dsp, self.aie, self.plio
        )
    }
}

/// Per-core data-memory budget check for a kernel's working set.
///
/// An AIE1 core has 32 KiB of data memory; a working set that exceeds it
/// cannot be deployed on a single core (it must be split or the design
/// rejected). `ping_pong` doubles the buffer (the aggregated-communication
/// design keeps a second buffer filling while the first computes).
pub fn core_working_set_fits(p: &HwParams, bytes: usize, ping_pong: bool) -> bool {
    let need = if ping_pong { bytes * 2 } else { bytes };
    need <= p.core_mem_bytes
}

/// Aggregate AIE data memory available to a group of cores.
pub fn group_mem_bytes(p: &HwParams, cores: usize) -> usize {
    cores * p.core_mem_bytes
}

/// FFT feasibility (Table 8's N/A rule): an N-point cint16 FFT task
/// buffered across `cores` AIE cores needs in/out ping-pong buffers plus
/// per-stage intermediates; calibrated so 8192 fails on 2 PUs (20 cores)
/// and fits on 4 (40 cores), while 4096 fits on 2 PUs — exactly the
/// paper's feasibility boundary.
pub const FFT_BYTES_PER_SAMPLE: usize = 96;

pub fn fft_fits(p: &HwParams, n_samples: usize, cores: usize) -> bool {
    n_samples * FFT_BYTES_PER_SAMPLE <= group_mem_bytes(p, cores)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn usage_addition_and_scaling() {
        let pu = ResourceUsage { aie: 64, plio: 12, ..Default::default() };
        let six = pu.scaled(6);
        assert_eq!(six.aie, 384);
        assert_eq!(six.plio, 72);
        let with_du = six.add(&ResourceUsage { uram: 315, bram: 778, ..Default::default() });
        assert_eq!(with_du.uram, 315);
        assert_eq!(with_du.aie, 384);
    }

    #[test]
    fn check_rejects_overcommit() {
        let p = HwParams::vck5000();
        let ok = ResourceUsage { aie: 400, ..Default::default() };
        assert!(ok.check(&p).is_ok());
        let over = ResourceUsage { aie: 401, ..Default::default() };
        assert!(over.check(&p).is_err());
    }

    #[test]
    fn table5_mm_percentages() {
        let p = HwParams::vck5000();
        let mm = ResourceUsage { lut: 11403, ff: 105609, bram: 778, uram: 315, dsp: 0, aie: 384, plio: 72 };
        let row = mm.table5_row(&p);
        assert_eq!(row[5], "384(96%)"); // the paper's AIE 96% cell
        assert_eq!(row[2], "778(80%)"); // BRAM 80%
        assert_eq!(row[3], "315(68%)"); // URAM 68%
    }

    #[test]
    fn core_working_set() {
        let p = HwParams::vck5000();
        // 3 x 32x32 float = 12 KiB fits even double-buffered
        assert!(core_working_set_fits(&p, 3 * 32 * 32 * 4, true));
        // 20 KiB fits single but not ping-pong
        assert!(core_working_set_fits(&p, 20 * 1024, false));
        assert!(!core_working_set_fits(&p, 20 * 1024, true));
    }

    #[test]
    fn fft_feasibility_matches_table8() {
        let p = HwParams::vck5000();
        let cores_per_pu = 10; // 80 AIE / 8 PUs
        assert!(!fft_fits(&p, 8192, 2 * cores_per_pu)); // the N/A cell
        assert!(fft_fits(&p, 8192, 4 * cores_per_pu));
        assert!(fft_fits(&p, 4096, 2 * cores_per_pu));
        assert!(fft_fits(&p, 1024, 2 * cores_per_pu));
    }
}
