//! Single-AIE-core compute timing: how long one core takes to run one
//! kernel invocation of a given arithmetic shape.
//!
//! The model is `cycles = ops / ops_per_cycle(dtype) + setup`, where the
//! per-dtype sustained rates and the invocation setup are calibrated in
//! [`params`](super::params). "Ideal" mode (the AIE simulator the paper's
//! Table 2 uses) drops the setup term.

use super::params::HwParams;

/// The arithmetic class of a kernel, which selects the per-cycle rate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelClass {
    /// float MAC kernels (MM, MM-T)
    F32Mac,
    /// int32 MAC kernels (Filter2D)
    I32Mac,
    /// cint16 butterfly kernels (FFT)
    Cint16Butterfly,
}

impl KernelClass {
    pub fn ops_per_cycle(&self, p: &HwParams) -> f64 {
        match self {
            KernelClass::F32Mac => p.f32_ops_per_cycle,
            KernelClass::I32Mac => p.i32_ops_per_cycle,
            KernelClass::Cint16Butterfly => p.cint16_ops_per_cycle,
        }
    }

    /// Element width in bytes as moved over the data path. cint16 = 4
    /// (2 x int16); the paper's Filter2D transports 8-bit pixels
    /// (int32 arithmetic, int8 I/O — see EXPERIMENTS.md notes).
    pub fn io_bytes_per_elem(&self) -> usize {
        match self {
            KernelClass::F32Mac => 4,
            KernelClass::I32Mac => 1,
            KernelClass::Cint16Butterfly => 4,
        }
    }
}

/// One kernel invocation on one core.
#[derive(Debug, Clone, Copy)]
pub struct KernelInvocation {
    pub class: KernelClass,
    /// Arithmetic operations in this invocation (mul and add counted
    /// separately, matching the paper's GOPS accounting).
    pub ops: f64,
}

impl KernelInvocation {
    pub fn new(class: KernelClass, ops: f64) -> Self {
        KernelInvocation { class, ops }
    }

    /// Compute cycles on one core, including the invocation setup.
    pub fn cycles(&self, p: &HwParams) -> f64 {
        self.ops / self.class.ops_per_cycle(p) + p.kernel_setup_cycles
    }

    /// Compute cycles in the paper's "ideal simulation state" (Table 2):
    /// no invocation overhead, peak issue rate.
    pub fn cycles_ideal(&self, p: &HwParams) -> f64 {
        self.ops / self.class.ops_per_cycle(p)
    }

    pub fn secs(&self, p: &HwParams) -> f64 {
        self.cycles(p) / p.aie_clock_hz
    }

    pub fn secs_ideal(&self, p: &HwParams) -> f64 {
        self.cycles_ideal(p) / p.aie_clock_hz
    }
}

/// Ops for an M x K x N matrix multiply (2 ops per MAC).
pub fn mm_ops(m: usize, k: usize, n: usize) -> f64 {
    2.0 * m as f64 * k as f64 * n as f64
}

/// Ops for a `taps x taps` filter over `pixels` output pixels.
pub fn filter_ops(pixels: usize, taps: usize) -> f64 {
    2.0 * (taps * taps) as f64 * pixels as f64
}

/// Ops for an N-point radix-2 FFT: N/2*log2(N) butterflies, 10 real ops
/// each (4 mul + 6 add for the complex MAC + combine).
pub fn fft_ops(n: usize) -> f64 {
    let stages = (n as f64).log2();
    10.0 * (n as f64 / 2.0) * stages
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mm32_task_time_matches_mmt() {
        let p = HwParams::vck5000();
        let inv = KernelInvocation::new(KernelClass::F32Mac, mm_ops(32, 32, 32));
        // Table 9 implies 4.2414 us/task sustained.
        assert!((inv.secs(&p) * 1e6 - 4.241).abs() < 0.01, "{}", inv.secs(&p) * 1e6);
    }

    #[test]
    fn ideal_is_faster() {
        let p = HwParams::vck5000();
        let inv = KernelInvocation::new(KernelClass::F32Mac, mm_ops(32, 32, 32));
        assert!(inv.secs_ideal(&p) < inv.secs(&p));
        // ideal 32^3 = 3.08 us (Table 2 anchor)
        assert!((inv.secs_ideal(&p) * 1e6 - 3.08).abs() < 0.01);
    }

    #[test]
    fn op_counts() {
        assert_eq!(mm_ops(32, 32, 32), 65536.0);
        assert_eq!(filter_ops(1024, 5), 51200.0);
        assert_eq!(fft_ops(1024), 10.0 * 512.0 * 10.0);
    }

    #[test]
    fn int_kernels_slower_than_float() {
        let p = HwParams::vck5000();
        let f = KernelInvocation::new(KernelClass::F32Mac, 1e6).secs(&p);
        let i = KernelInvocation::new(KernelClass::I32Mac, 1e6).secs(&p);
        assert!(i > f);
    }
}
