//! Communication timing: stream vs DMA core transfers, and PLIO links.
//!
//! The three AIE-side transfer disciplines are exactly the paper's
//! Table 2 methods; [`TransferMethod::secs`] reproduces that table (see
//! `params.rs` for the calibration) and `benches/table2_methods.rs`
//! regenerates it.

use super::params::HwParams;

/// How data moves between a core and its neighbourhood.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransferMethod {
    /// Stream, interleaved with computation in `grain_bytes` grains —
    /// every grain interrupts the compute pipeline (Table 2 method 1).
    StreamInterleaved { grain_bytes: usize },
    /// Stream, aggregated: all data moved while compute is off
    /// (Table 2 method 2).
    StreamAggregated,
    /// DMA, aggregated: bulk DMA while the core is off
    /// (Table 2 method 3; the EA4RCA communication phase).
    DmaAggregated,
}

impl TransferMethod {
    /// Pure transfer seconds for `bytes` (excludes the compute it crosses).
    pub fn secs(&self, p: &HwParams, bytes: usize) -> f64 {
        match self {
            TransferMethod::StreamInterleaved { grain_bytes } => {
                let grains = (bytes as f64 / *grain_bytes as f64).ceil();
                bytes as f64 / p.stream_bytes_per_sec
                    + grains * p.stream_interrupt_stall_cycles / p.aie_clock_hz
            }
            TransferMethod::StreamAggregated => bytes as f64 / p.stream_bytes_per_sec,
            TransferMethod::DmaAggregated => {
                bytes as f64 / p.dma_bytes_per_sec + p.dma_setup_secs
            }
        }
    }
}

/// A dedicated point-to-point PLIO link (PL <-> AIE edge port).
/// Each link is sequential: transfers queue FIFO.
#[derive(Debug, Clone)]
pub struct PlioLink {
    pub bytes_per_sec: f64,
    busy_until_ps: u64,
    pub total_bytes: u64,
}

impl PlioLink {
    pub fn new(p: &HwParams) -> PlioLink {
        PlioLink {
            bytes_per_sec: p.plio_bytes_per_sec(),
            busy_until_ps: 0,
            total_bytes: 0,
        }
    }

    /// Enqueue a transfer of `bytes` at `now_ps`; returns completion time.
    pub fn transfer(&mut self, now_ps: u64, bytes: usize) -> u64 {
        let start = now_ps.max(self.busy_until_ps);
        let dur = HwParams::ps(bytes as f64 / self.bytes_per_sec);
        self.busy_until_ps = start + dur;
        self.total_bytes += bytes as u64;
        self.busy_until_ps
    }

    /// Time to move `bytes` over `ports` parallel links, ignoring queueing
    /// (used for phase-length estimates).
    pub fn parallel_secs(p: &HwParams, bytes: usize, ports: usize) -> f64 {
        assert!(ports > 0);
        (bytes as f64 / ports as f64) / p.plio_bytes_per_sec()
    }

    pub fn busy_until(&self) -> u64 {
        self.busy_until_ps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_ordering() {
        let p = HwParams::vck5000();
        let bytes = 12288;
        let m1 = TransferMethod::StreamInterleaved { grain_bytes: 64 }.secs(&p, bytes);
        let m2 = TransferMethod::StreamAggregated.secs(&p, bytes);
        let m3 = TransferMethod::DmaAggregated.secs(&p, bytes);
        assert!(m1 > m2 && m2 > m3, "{m1} {m2} {m3}");
    }

    #[test]
    fn plio_link_fifo_queues() {
        let p = HwParams::vck5000();
        let mut link = PlioLink::new(&p);
        let t1 = link.transfer(0, 4800); // 1 us at 4.8 GB/s
        let t2 = link.transfer(0, 4800); // queued behind the first
        assert_eq!(t1, HwParams::ps(1e-6));
        assert_eq!(t2, HwParams::ps(2e-6));
        assert_eq!(link.total_bytes, 9600);
    }

    #[test]
    fn plio_idle_gap_not_charged() {
        let p = HwParams::vck5000();
        let mut link = PlioLink::new(&p);
        link.transfer(0, 4800);
        let t = link.transfer(HwParams::ps(10e-6), 4800);
        assert_eq!(t, HwParams::ps(11e-6));
    }

    #[test]
    fn parallel_ports_divide_time() {
        let p = HwParams::vck5000();
        let one = PlioLink::parallel_secs(&p, 16384, 1);
        let four = PlioLink::parallel_secs(&p, 16384, 4);
        assert!((one / four - 4.0).abs() < 1e-9);
    }
}
