//! Analytic power model — the Power Design Manager substitute.
//!
//! Model (constants in [`params`](super::params), fit jointly to the
//! paper's Table 6 power column and MM-T's 65.61 W — DESIGN.md §6):
//!
//! ```text
//! P = static
//!   + sum_cores( per_aie * duty * dtype_scale )
//!   + kLUT*w_lut + BRAM*w_bram + URAM*w_uram + DSP*w_dsp
//!   + active_plio * w_plio
//!   + achieved_DDR_GBps * w_ddr
//! ```
//!
//! `duty` is the fraction of wall-clock the cores spend computing —
//! this is what makes MM-T (no communication phases, duty ~0.73) draw
//! far more than the MM accelerator (duty ~0.42) on more cores.

use super::core::KernelClass;
use super::memory::ResourceUsage;
use super::params::HwParams;

/// Inputs to one power estimate.
#[derive(Debug, Clone)]
pub struct PowerBreakdownInput {
    pub usage: ResourceUsage,
    /// Number of AIE cores actively clocking (<= usage.aie: configs with
    /// fewer active PUs than deployed leave cores idle).
    pub active_aie: usize,
    /// Fraction of wall-clock the active cores spend computing (0..=1).
    pub compute_duty: f64,
    /// Arithmetic class of the active kernels (datapath width scaling).
    pub class: KernelClass,
    /// Achieved DDR bandwidth in GB/s.
    pub ddr_gbps: f64,
    /// PLIO ports actually carrying traffic.
    pub active_plio: usize,
}

#[derive(Debug, Clone, PartialEq)]
pub struct PowerBreakdown {
    pub static_w: f64,
    pub aie_w: f64,
    pub pl_w: f64,
    pub plio_w: f64,
    pub ddr_w: f64,
}

impl PowerBreakdown {
    pub fn total(&self) -> f64 {
        self.static_w + self.aie_w + self.pl_w + self.plio_w + self.ddr_w
    }
}

pub fn estimate(p: &HwParams, input: &PowerBreakdownInput) -> PowerBreakdown {
    let dtype_scale = match input.class {
        KernelClass::F32Mac => 1.0,
        KernelClass::I32Mac => p.power_int32_scale,
        KernelClass::Cint16Butterfly => p.power_cint16_scale,
    };
    let duty = input.compute_duty.clamp(0.0, 1.0);
    let aie_w = input.active_aie as f64 * p.power_per_aie_w * duty * dtype_scale;
    let pl_w = input.usage.lut as f64 / 1000.0 * p.power_per_klut_w
        + input.usage.bram as f64 * p.power_per_bram_w
        + input.usage.uram as f64 * p.power_per_uram_w
        + input.usage.dsp as f64 * p.power_per_dsp_w;
    let plio_w = input.active_plio as f64 * p.power_per_plio_w;
    let ddr_w = input.ddr_gbps * p.power_per_gbps_w;
    PowerBreakdown { static_w: p.power_static_w, aie_w, pl_w, plio_w, ddr_w }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mm_usage() -> ResourceUsage {
        ResourceUsage { lut: 11403, ff: 105609, bram: 778, uram: 315, dsp: 0, aie: 384, plio: 72 }
    }

    #[test]
    fn mmt_power_near_paper() {
        // Table 9: 65.61 W average at 400 cores, duty ~15.45/21.28 = 0.726,
        // 100 PLIOs (50 Cascade<8> chains, 1 in + 1 out each).
        let p = HwParams::vck5000();
        let est = estimate(
            &p,
            &PowerBreakdownInput {
                usage: ResourceUsage { lut: 61039, ff: 96791, bram: 34, uram: 0, dsp: 0, aie: 400, plio: 100 },
                active_aie: 400,
                compute_duty: 15.45 / 21.28,
                class: KernelClass::F32Mac,
                ddr_gbps: 0.0,
                active_plio: 100,
            },
        );
        let total = est.total();
        assert!((total - 65.61).abs() / 65.61 < 0.15, "MM-T power {total}");
    }

    #[test]
    fn mm_power_scales_with_pus() {
        let p = HwParams::vck5000();
        let mk = |pus: usize| {
            estimate(
                &p,
                &PowerBreakdownInput {
                    usage: mm_usage(),
                    active_aie: 64 * pus,
                    compute_duty: 8.9 / 21.28,
                    class: KernelClass::F32Mac,
                    ddr_gbps: 1.0,
                    active_plio: 12 * pus,
                },
            )
            .total()
        };
        let (p1, p3, p6) = (mk(1), mk(3), mk(6));
        assert!(p1 < p3 && p3 < p6);
        // slope per PU roughly constant (paper: ~6.8 W / PU)
        let s1 = (p3 - p1) / 2.0;
        let s2 = (p6 - p3) / 3.0;
        assert!((s1 - s2).abs() < 0.2, "{s1} {s2}");
        assert!((s1 - 6.8).abs() < 1.5, "slope {s1}");
    }

    #[test]
    fn duty_dominates() {
        let p = HwParams::vck5000();
        let base = PowerBreakdownInput {
            usage: mm_usage(),
            active_aie: 384,
            compute_duty: 0.4,
            class: KernelClass::F32Mac,
            ddr_gbps: 0.0,
            active_plio: 72,
        };
        let low = estimate(&p, &base).total();
        let high = estimate(&p, &PowerBreakdownInput { compute_duty: 0.8, ..base }).total();
        assert!(high > low + 20.0);
    }

    #[test]
    fn int32_draws_less_than_float() {
        let p = HwParams::vck5000();
        let mk = |class| {
            estimate(
                &p,
                &PowerBreakdownInput {
                    usage: ResourceUsage { aie: 100, ..Default::default() },
                    active_aie: 100,
                    compute_duty: 1.0,
                    class,
                    ddr_gbps: 0.0,
                    active_plio: 0,
                },
            )
            .total()
        };
        assert!(mk(KernelClass::I32Mac) < mk(KernelClass::F32Mac));
    }
}
