//! The shared DDR controller: a FIFO burst server.
//!
//! All DUs' AMC transfers contend here. Each transfer runs at
//! `peak * mode_efficiency` once started; requests queue in arrival
//! order (one memory controller). Queueing is what degrades multi-DU
//! configurations at small task scales (Tables 6/7's PU-count columns).

use super::params::HwParams;

/// AMC access modes (paper §3.4, Algorithm 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AmcMode {
    /// Complete Sequence Burst: address-ordered, max efficiency.
    Csb,
    /// Jump Burst: bursts from scattered start addresses.
    Jub,
    /// Unordered: single-element access, no bursts.
    Unod,
}

impl AmcMode {
    pub fn efficiency(&self, p: &HwParams) -> f64 {
        match self {
            AmcMode::Csb => p.ddr_eff_csb,
            AmcMode::Jub => p.ddr_eff_jub,
            AmcMode::Unod => p.ddr_eff_unod,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AmcMode::Csb => "CSB",
            AmcMode::Jub => "JUB",
            AmcMode::Unod => "UNOD",
        }
    }
}

/// The DDR controller. Time unit: picoseconds.
#[derive(Debug, Clone)]
pub struct Ddr {
    peak_bytes_per_sec: f64,
    setup_ps: u64,
    busy_until_ps: u64,
    pub total_bytes: u64,
    pub total_requests: u64,
    /// Total picoseconds requests spent waiting in queue (contention).
    pub total_queue_ps: u64,
}

impl Ddr {
    pub fn new(p: &HwParams) -> Ddr {
        Ddr {
            peak_bytes_per_sec: p.ddr_peak_bytes_per_sec,
            setup_ps: HwParams::ps(p.ddr_setup_secs),
            busy_until_ps: 0,
            total_bytes: 0,
            total_requests: 0,
            total_queue_ps: 0,
        }
    }

    /// Enqueue a transfer of `bytes` in `mode` at `now_ps`.
    /// Returns (start_ps, done_ps).
    pub fn transfer(&mut self, now_ps: u64, bytes: usize, mode: AmcMode, p: &HwParams) -> (u64, u64) {
        let start = now_ps.max(self.busy_until_ps);
        self.total_queue_ps += start - now_ps;
        let rate = self.peak_bytes_per_sec * mode.efficiency(p);
        let dur = self.setup_ps + HwParams::ps(bytes as f64 / rate);
        self.busy_until_ps = start + dur;
        self.total_bytes += bytes as u64;
        self.total_requests += 1;
        (start, self.busy_until_ps)
    }

    pub fn busy_until(&self) -> u64 {
        self.busy_until_ps
    }

    /// Achieved bandwidth over a window (for the power model).
    pub fn achieved_gbps(&self, window_secs: f64) -> f64 {
        if window_secs <= 0.0 {
            return 0.0;
        }
        self.total_bytes as f64 / window_secs / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_efficiency_ordering() {
        let p = HwParams::vck5000();
        assert!(AmcMode::Csb.efficiency(&p) > AmcMode::Jub.efficiency(&p));
        assert!(AmcMode::Jub.efficiency(&p) > AmcMode::Unod.efficiency(&p));
    }

    #[test]
    fn transfers_queue_fifo() {
        let p = HwParams::vck5000();
        let mut ddr = Ddr::new(&p);
        let (s1, d1) = ddr.transfer(0, 92_160, AmcMode::Csb, &p); // 1 us at 92.16 GB/s
        let (s2, d2) = ddr.transfer(0, 92_160, AmcMode::Csb, &p);
        assert_eq!(s1, 0);
        assert_eq!(s2, d1);
        assert!(d2 > d1);
        assert!(ddr.total_queue_ps > 0);
        assert_eq!(ddr.total_requests, 2);
    }

    #[test]
    fn unod_is_much_slower() {
        let p = HwParams::vck5000();
        let mut a = Ddr::new(&p);
        let mut b = Ddr::new(&p);
        let (_, csb) = a.transfer(0, 1 << 20, AmcMode::Csb, &p);
        let (_, unod) = b.transfer(0, 1 << 20, AmcMode::Unod, &p);
        assert!(unod as f64 / csb as f64 > 8.0);
    }

    #[test]
    fn achieved_bandwidth_accounting() {
        let p = HwParams::vck5000();
        let mut ddr = Ddr::new(&p);
        ddr.transfer(0, 1_000_000_000, AmcMode::Csb, &p);
        assert!((ddr.achieved_gbps(1.0) - 1.0).abs() < 1e-9);
    }
}
