//! AIE/ACAP substrate simulator.
//!
//! The paper's testbed is a VCK5000 Versal card; none exists here, so this
//! module is the substitution (DESIGN.md §1): an event-driven model of the
//! pieces of the ACAP architecture the EA4RCA framework exercises —
//!
//! * [`params`]  — the calibrated hardware constants (clock rates,
//!   bandwidths, capacities) of the VCK5000, fixed once from the paper's
//!   own micro-measurements and held constant across all experiments.
//! * [`core`]    — single-AIE-core compute timing (VLIW SIMD model).
//! * [`comm`]    — stream vs DMA vs PLIO transfer timing.
//! * [`ddr`]     — the shared DDR controller (FIFO burst server).
//! * [`memory`]  — the resource ledger: AIE cores, PLIO ports, LUT/FF/
//!   BRAM/URAM/DSP, core-local data memory (Table 5's columns).
//! * [`array`]   — the 8x50 AIE array and PU placement.
//! * [`power`]   — the analytic power model (PDM substitute).
//! * [`trace`]   — event timeline capture + ASCII rendering (Fig 2/5).

pub mod array;
pub mod comm;
pub mod core;
pub mod ddr;
pub mod memory;
pub mod noc;
pub mod params;
pub mod power;
pub mod trace;

pub use params::HwParams;
