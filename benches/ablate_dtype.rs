//! Ablation — operand width on the MM accelerator (paper §4.3: "If the
//! low bit types such as Int8 or Int16 are used, higher energy
//! efficiency will be obtained"). Projects the Table 6 peak row under
//! int16/int8 operands: more MACs per cycle on the same datapath, fewer
//! bytes on every wire, lower per-core power.
//!
//! The int8/int16 kernels are real (python/compile/kernels/mm_lowbit.py,
//! AOT-compiled to mm32_i8 / mm32_i16 and verified by pytest +
//! integration tests); the projection scales the calibrated float model
//! by the packing factors below.
//!
//! Run: `cargo bench --bench ablate_dtype`

use ea4rca::apps::mm;
use ea4rca::sim::params::HwParams;
use ea4rca::util::table::{fmt_f, Table};

struct DtypeProfile {
    name: &'static str,
    /// MAC packing factor vs float32 on the 1024-bit SIMD unit.
    mac_factor: f64,
    /// Bytes per element on the wires.
    bytes: f64,
    /// Per-core power scale at equal duty (narrower datapath).
    power_scale: f64,
}

fn main() {
    let p = HwParams::vck5000();
    let profiles = [
        DtypeProfile { name: "Float", mac_factor: 1.0, bytes: 4.0, power_scale: 1.00 },
        DtypeProfile { name: "Int16", mac_factor: 2.0, bytes: 2.0, power_scale: 0.72 },
        DtypeProfile { name: "Int8", mac_factor: 4.0, bytes: 1.0, power_scale: 0.55 },
    ];

    // calibrated float baseline: 6144^3, 6 PUs (Table 6 peak row)
    let base = mm::run(&p, 6144, 6, false).expect("baseline");

    let mut t = Table::new(
        "Ablation — operand width on the MM accelerator (6144^3, 6 PUs, projected)",
        &["DType", "GOPS", "GOPS/AIE", "Power (W)", "GOPS/W", "eff. vs Float"],
    );
    let mut float_eff = 0.0;
    for prof in &profiles {
        // compute phase shrinks by the MAC factor; comm phase shrinks by
        // the byte factor; per-iteration time re-composed from the
        // calibrated float split (4.24 us compute / 3.41 us comm).
        let compute = 4.24e-6 / prof.mac_factor;
        let comm = 3.41e-6 * prof.bytes / 4.0;
        let float_iter = 4.24e-6 + 3.41e-6;
        let speedup = float_iter / (compute + comm);
        let gops = base.gops * speedup;
        // power: AIE term scales with power_scale (narrow datapath) and
        // with the higher duty; PL/static terms unchanged.
        let aie_w = (base.power_w - 12.0) * prof.power_scale * (compute / (compute + comm))
            / (4.24e-6 / float_iter);
        let power = 12.0 + aie_w;
        let eff = gops / power;
        if prof.name == "Float" {
            float_eff = eff;
        }
        t.row(&[
            prof.name.to_string(),
            fmt_f(gops, 1),
            fmt_f(gops / 384.0, 2),
            fmt_f(power, 1),
            fmt_f(eff, 1),
            format!("{:.2}x", eff / float_eff),
        ]);
    }
    t.print();
    println!(
        "\nthe paper's §4.3 claim holds on the model: int16 and int8 deliver \
         higher GOPS *and* higher GOPS/W (narrower wires shrink the \
         communication phase as fast as the MACs speed the compute phase)."
    );
}
