//! The sim backend's cost model, surveyed: predicted latency / power /
//! energy / phase breakdown for every serving artifact across batch
//! sizes, plus a determinism check (two independent runtimes must agree
//! to the bit — the dispatcher's placement weights depend on it).
//!
//! Run: `cargo bench --bench cost_model`

use ea4rca::runtime::{BackendKind, Manifest, Runtime};
use ea4rca::util::bench::BenchRecorder;
use ea4rca::util::table::{fmt_f, Table};

fn main() {
    let rt = Runtime::with_backend(BackendKind::Sim, Manifest::default_dir())
        .expect("sim runtime");
    let twin = Runtime::with_backend(BackendKind::Sim, Manifest::default_dir())
        .expect("twin runtime");
    let mut rec = BenchRecorder::new("cost_model");
    rec.note("backend", "sim")
        .note("workload", "predicted dispatch cost per artifact across batch sizes");

    let mut t = Table::new(
        "AIE cost model — predicted dispatch cost per artifact",
        &["Artifact", "Batch", "Latency (us)", "us/job", "Power (W)", "Energy (uJ)",
          "Compute (us)", "Comm (us)", "Fetch (us)", "Stall (us)"],
    );
    for artifact in ["mm_pu128", "filter2d_pu8", "fft1024", "fft4096", "mmt_cascade8"] {
        for batch in [1usize, 4, 8] {
            let p = rt
                .predict(artifact, batch)
                .unwrap_or_else(|| panic!("{artifact}: no prediction"));
            // determinism: an independent runtime predicts the same bits
            let q = twin.predict(artifact, batch).expect("twin prediction");
            assert_eq!(
                p.latency_secs.to_bits(),
                q.latency_secs.to_bits(),
                "{artifact} x{batch}: cost model not deterministic"
            );
            t.row(&[
                artifact.to_string(),
                batch.to_string(),
                fmt_f(p.latency_secs * 1e6, 2),
                fmt_f(p.per_job_secs() * 1e6, 2),
                fmt_f(p.power_w, 2),
                fmt_f(p.energy_j * 1e6, 2),
                fmt_f(p.compute_secs * 1e6, 2),
                fmt_f(p.comm_secs * 1e6, 2),
                fmt_f(p.fetch_secs * 1e6, 2),
                fmt_f(p.stall_secs * 1e6, 2),
            ]);
            rec.metric(&format!("{artifact}.x{batch}.latency_us"), p.latency_secs * 1e6, "us")
                .metric(&format!("{artifact}.x{batch}.us_per_job"), p.per_job_secs() * 1e6, "us")
                .metric(&format!("{artifact}.x{batch}.power_w"), p.power_w, "W")
                .metric(&format!("{artifact}.x{batch}.energy_uj"), p.energy_j * 1e6, "uJ");
        }
        // batching must amortize the fixed dispatch overhead
        let p1 = rt.predict(artifact, 1).unwrap();
        let p8 = rt.predict(artifact, 8).unwrap();
        assert!(
            p8.per_job_secs() <= p1.per_job_secs() * 1.001,
            "{artifact}: batch of 8 costs more per job than singles"
        );
    }
    t.print();
    println!(
        "\npredictions are deterministic across runtimes and amortize with batch \
         size — these are the weights the serving dispatcher places batches by."
    );
    rec.write();
}
