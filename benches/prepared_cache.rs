//! §Perf — the prepared-artifact cache: warm vs cold execution.
//!
//! The paper's throughput comes from paying setup once (graph build,
//! twiddle generation, placement) and then streaming data through a
//! fixed pipeline. This bench measures that amortization on the
//! interpreter backend:
//!
//! * **cold** — a fresh `Runtime` per job: every execution pays
//!   prepare (kernel resolve + shape validation + `FftPlan`
//!   construction, the trig-heavy part) before running.
//! * **warm** — one `Runtime` across all jobs: the plan is built once
//!   and every later job is a cache hit.
//!
//! The cache-hit counters verify the build-once invariant, and a final
//! serving section shows the first-job latency outlier that worker
//! warm-up (`ea4rca serve` without `--no-warm`) removes on an
//! fft-heavy mix.
//!
//! Run: `cargo bench --bench prepared_cache` (or `make warm-bench`)

use std::time::Instant;

use ea4rca::coordinator::server::{Server, ServerConfig};
use ea4rca::runtime::{BackendKind, Manifest, Runtime, Tensor};
use ea4rca::util::bench::BenchRecorder;
use ea4rca::util::rng::Rng;
use ea4rca::util::stats::summarize;
use ea4rca::util::table::{fmt_f, Table};

const ITERS: usize = 40;

/// Per-job seconds with a fresh runtime every time (cold prepare on
/// the execution path).
fn run_cold(name: &str, inputs: &[Tensor]) -> Vec<f64> {
    (0..ITERS)
        .map(|_| {
            let rt = Runtime::with_backend(BackendKind::Interp, Manifest::default_dir())
                .expect("runtime");
            let t0 = Instant::now();
            rt.execute(name, inputs).expect("cold execute");
            t0.elapsed().as_secs_f64()
        })
        .collect()
}

/// Per-job seconds against one long-lived, warmed runtime.
fn run_warm(name: &str, inputs: &[Tensor]) -> Vec<f64> {
    let rt =
        Runtime::with_backend(BackendKind::Interp, Manifest::default_dir()).expect("runtime");
    rt.warmup(&[name]).expect("warmup");
    let samples = (0..ITERS)
        .map(|_| {
            let t0 = Instant::now();
            rt.execute(name, inputs).expect("warm execute");
            t0.elapsed().as_secs_f64()
        })
        .collect();
    // the build-once invariant, checked where it is measured
    let cs = rt.cache_stats();
    assert_eq!(cs.builds, 1, "{name}: prepared state must be built exactly once");
    assert_eq!(cs.hits, ITERS as u64, "{name}: every job must be a cache hit");
    let stats = rt.stats();
    assert_eq!(stats[name].prepare_builds, 1, "{name}");
    samples
}

fn fft_inputs(rng: &mut Rng, n: usize) -> Vec<Tensor> {
    vec![
        Tensor::f32(&[n], rng.normal_vec(n)),
        Tensor::f32(&[n], rng.normal_vec(n)),
    ]
}

fn main() {
    let mut rng = Rng::new(31);
    let mut rec = BenchRecorder::new("prepared_cache");
    rec.note("iters", ITERS)
        .note("backend", "interp")
        .note("workload", "warm vs cold per-job cost; serving first-job outlier");
    let mut t = Table::new(
        "prepared-artifact cache: warm vs cold per-job cost (interp)",
        &["artifact", "cold mean (ms)", "warm mean (ms)", "warm p50 (ms)", "speedup"],
    );
    let mut fft_speedup = 0.0;
    for (name, n) in [("fft8192", 8192usize), ("fft1024", 1024)] {
        let inputs = fft_inputs(&mut rng, n);
        let cold = summarize(&run_cold(name, &inputs));
        let warm = summarize(&run_warm(name, &inputs));
        let speedup = cold.mean / warm.mean;
        if name == "fft8192" {
            fft_speedup = speedup;
        }
        t.row(&[
            name.to_string(),
            fmt_f(cold.mean * 1e3, 3),
            fmt_f(warm.mean * 1e3, 3),
            fmt_f(warm.p50 * 1e3, 3),
            format!("{speedup:.2}x"),
        ]);
        rec.metric(&format!("{name}.cold_mean_ms"), cold.mean * 1e3, "ms")
            .metric(&format!("{name}.warm_mean_ms"), warm.mean * 1e3, "ms")
            .metric(&format!("{name}.warm_speedup"), speedup, "x");
    }
    // mm for scale: prepare is just dims there, so warm ~ cold
    let mm_inputs = vec![
        Tensor::f32(&[128, 128], rng.normal_vec(128 * 128)),
        Tensor::f32(&[128, 128], rng.normal_vec(128 * 128)),
    ];
    let cold = summarize(&run_cold("mm_pu128", &mm_inputs));
    let warm = summarize(&run_warm("mm_pu128", &mm_inputs));
    t.row(&[
        "mm_pu128".to_string(),
        fmt_f(cold.mean * 1e3, 3),
        fmt_f(warm.mean * 1e3, 3),
        fmt_f(warm.p50 * 1e3, 3),
        format!("{:.2}x", cold.mean / warm.mean),
    ]);
    rec.metric("mm_pu128.cold_mean_ms", cold.mean * 1e3, "ms")
        .metric("mm_pu128.warm_mean_ms", warm.mean * 1e3, "ms")
        .metric("mm_pu128.warm_speedup", cold.mean / warm.mean, "x");
    t.print();
    println!(
        "acceptance (fft8192 warm >= 1.2x cold): {} ({fft_speedup:.2}x)",
        if fft_speedup >= 1.2 { "PASS" } else { "MISS" }
    );

    // ---- serving: worker warm-up removes the first-job outlier ----
    let n_jobs = 48;
    let mut first_vs_rest = Vec::new();
    for (label, warmup) in [("warmed", vec!["fft8192"]), ("cold start", vec![])] {
        let server = Server::start_with_config(
            BackendKind::Interp,
            ServerConfig { n_workers: 2, ..ServerConfig::default() },
            Manifest::default_dir(),
            &warmup,
        )
        .expect("server");
        let mut pending = Vec::new();
        for _ in 0..n_jobs {
            pending.push(
                server
                    .submit("fft8192", fft_inputs(&mut rng, 8192))
                    .expect("submit"),
            );
        }
        let lats: Vec<f64> = pending
            .into_iter()
            .map(|p| p.wait().expect("reply").latency_secs())
            .collect();
        server.shutdown().expect("shutdown");
        let s = summarize(&lats);
        first_vs_rest.push((label, s.p50 * 1e3, s.max * 1e3));
    }
    println!("\nfft8192 serving latency, {n_jobs} jobs x 2 workers:");
    for (label, p50, max) in &first_vs_rest {
        println!("  {label:<10} p50 {p50:.3} ms | max {max:.3} ms");
        let key = if *label == "warmed" { "serving_warmed" } else { "serving_cold_start" };
        rec.metric(&format!("{key}.p50_ms"), *p50, "ms")
            .metric(&format!("{key}.max_ms"), *max, "ms");
    }
    println!("(cold-start max carries the per-worker plan build; warmed should not)");
    rec.write();
}
