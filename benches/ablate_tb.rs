//! Ablation — task-block size on the MM design. The paper's TB is 27
//! 128x128 matrices (56% URAM) sustaining 9 engine iterations; smaller
//! TBs refetch more often (DDR pressure), larger ones buy little and
//! cost URAM. Sweeps the reuse factor at fixed total work.
//!
//! Run: `cargo bench --bench ablate_tb`

use ea4rca::apps::mm;
use ea4rca::coordinator::scheduler::{ExecMode, GroupSpec, SimEngine};
use ea4rca::sim::params::HwParams;
use ea4rca::util::table::{fmt_f, Table};

fn main() {
    let p = HwParams::vck5000();
    let engine = SimEngine::new(p.clone());
    let mut t = Table::new(
        "Ablation — TB reuse factor (MM, 6 PUs, 504 iterations)",
        &["TB matrices", "engine iters/TB", "URAM est (%)", "makespan (ms)", "stall (us)"],
    );
    // TB bytes scale with the reuse factor: r iterations need 3r matrices
    // (r A-blocks + r B-blocks + r C staging) in the 3x3x3-style blocking.
    for reuse in [1u64, 3, 9, 18, 36] {
        let matrices = 3 * reuse as usize;
        let mut du = mm::mm_du(6, 6);
        du.tb.read_bytes = matrices * 128 * 128 * 4;
        du.tb.engine_iters = reuse;
        let g = GroupSpec {
            name: format!("tb{reuse}"),
            du,
            pu: mm::mm_pu(),
            engine_iters: 504,
mode: ExecMode::Regular,
        };
        let r = engine.run(&[g]);
        let stall: u64 = r.groups.iter().map(|g| g.stall_ps).sum();
        // URAM estimate: TB bytes over the card's 463 x 36 KiB URAMs
        let uram_pct = (matrices * 128 * 128 * 4) as f64
            / (p.total_uram as f64 * 36.0 * 1024.0)
            * 100.0;
        t.row(&[
            matrices.to_string(),
            reuse.to_string(),
            fmt_f(uram_pct, 0),
            fmt_f(r.makespan_secs * 1e3, 3),
            fmt_f(stall as f64 / 1e6, 1),
        ]);
    }
    t.print();
    println!(
        "\nthe paper's 27-matrix TB (9 iterations, ~56% URAM incl. staging) sits \
         at the knee: smaller TBs stall on DDR refetch, larger ones only add \
         URAM pressure."
    );
}
