//! Ablation — the DU:PU pair ratio on the MM design. The paper deploys
//! 1:6; this sweeps 1:1 .. 1:8 at a fixed 48-block workload share per
//! PU and shows where the shared data engine starts to bite.
//!
//! Run: `cargo bench --bench ablate_du_pu`

use ea4rca::apps::mm;
use ea4rca::coordinator::scheduler::{ExecMode, GroupSpec, SimEngine};
use ea4rca::sim::params::HwParams;
use ea4rca::util::table::{fmt_f, Table};

fn main() {
    let p = HwParams::vck5000();
    let engine = SimEngine::new(p.clone());
    let mut t = Table::new(
        "Ablation — DU:PU ratio (MM PU, 256 iterations per PU)",
        &["DU:PU", "makespan (ms)", "per-PU-iter (us)", "compute duty", "DDR queue (us)"],
    );
    let iters_per_pu = 256u64;
    let mut per_iter_1 = 0.0;
    for pus in [1usize, 2, 4, 6, 8] {
        let g = GroupSpec {
            name: format!("1:{pus}"),
            du: mm::mm_du(pus, 6),
            pu: mm::mm_pu(),
            engine_iters: iters_per_pu,
mode: ExecMode::Regular,
        };
        let r = engine.run(&[g]);
        let per_iter = r.makespan_secs / iters_per_pu as f64 * 1e6;
        if pus == 1 {
            per_iter_1 = per_iter;
        }
        t.row(&[
            format!("1:{pus}"),
            fmt_f(r.makespan_secs * 1e3, 3),
            fmt_f(per_iter, 2),
            fmt_f(r.compute_duty, 3),
            fmt_f(r.ddr_queue_secs * 1e6, 1),
        ]);
    }
    t.print();
    println!(
        "\none DU sustains 6 PUs with <15% per-iteration penalty vs 1:1 \
         (per-iter 1:1 = {per_iter_1:.2} us) — the paper's 1:6 choice is on \
         the flat part of the curve; beyond it the TB fetch pipeline and \
         write-back traffic erode the margin."
    );
}
