//! Table 8 — FFT accelerator performance across sample sizes and PU
//! quantities, including the infeasible 8192/2PU N/A cell.
//!
//! Run: `cargo bench --bench table8_fft`

use ea4rca::apps::fft;
use ea4rca::report::{compare_line, fft_row, fft_table};
use ea4rca::sim::params::HwParams;

fn main() {
    let p = HwParams::vck5000();
    let mut t = fft_table("Table 8 — FFT accelerator (CInt16)");
    let wall = std::time::Instant::now();
    for n in [8192usize, 4096, 2048, 1024] {
        for (pus, label) in [(8, "8(100%)"), (4, "4(50%)"), (2, "2(25%)")] {
            let r = fft::run(&p, n, pus, 4096, false).expect("run");
            fft_row(&mut t, n, label, r.as_ref());
        }
    }
    t.print();
    println!("(sweep simulated in {:.2} s wall-clock)\n", wall.elapsed().as_secs_f64());

    let anchors = [
        (1024, 8, 2_325_581.40, 0.43),
        (2048, 8, 1_123_595.51, 0.89),
        (4096, 8, 526_315.79, 1.90),
        (8192, 8, 250_000.00, 4.00),
        (1024, 2, 588_235.29, 1.70),
    ];
    for (n, pus, paper_tps, paper_us) in anchors {
        let r = fft::run(&p, n, pus, 4096, false).unwrap().unwrap();
        println!("{}", compare_line(&format!("{n}-pt {pus}PU tasks/sec"), paper_tps, r.tasks_per_sec));
        println!("{}", compare_line(&format!("{n}-pt {pus}PU us/task"), paper_us, 1e6 / r.tasks_per_sec));
    }
    assert!(fft::run(&p, 8192, 2, 64, false).unwrap().is_none(), "N/A cell must hold");
    println!("\n8192-pt / 2PU: N/A (exceeds AIE core memory) — matches the paper");
}
