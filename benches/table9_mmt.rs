//! Table 9 — MM-T, the AIE compute-throughput probe: three runs + the
//! average, as the paper reports.
//!
//! Run: `cargo bench --bench table9_mmt`

use ea4rca::apps::mmt;
use ea4rca::report::{compare_line, tasks_sci};
use ea4rca::sim::params::HwParams;
use ea4rca::util::table::{fmt_f, Table};

fn main() {
    let p = HwParams::vck5000();
    let mut t = Table::new(
        "Table 9 — performance testing of AIE computing based on MM (MM-T)",
        &["ID", "Data Type", "AIE freq", "Tasks/sec", "GOPS", "GOPS/AIE", "Power (W)", "GOPS/W"],
    );
    let mut sum_tps = 0.0;
    let mut sum_gops = 0.0;
    let mut sum_w = 0.0;
    // Three runs at different batch lengths (the simulator is
    // deterministic; the paper's three runs vary by measurement noise,
    // ours by workload length -> amortisation of dispatch).
    for (id, iters) in [(1u32, 20_000u64), (2, 40_000), (3, 30_000)] {
        let r = mmt::run(&p, iters, false).expect("run");
        sum_tps += r.tasks_per_sec;
        sum_gops += r.gops;
        sum_w += r.power_w;
        t.row(&[
            id.to_string(),
            "Float".into(),
            "1.33GHZ".into(),
            tasks_sci(r.tasks_per_sec),
            fmt_f(r.gops, 2),
            fmt_f(r.gops_per_aie, 2),
            fmt_f(r.power_w, 2),
            fmt_f(r.gops_per_w, 2),
        ]);
    }
    let (tps, gops, w) = (sum_tps / 3.0, sum_gops / 3.0, sum_w / 3.0);
    t.row(&[
        "Average".into(),
        "N/A".into(),
        "N/A".into(),
        tasks_sci(tps),
        fmt_f(gops, 2),
        fmt_f(gops / 400.0, 2),
        fmt_f(w, 2),
        fmt_f(gops / w, 2),
    ]);
    t.print();

    println!();
    println!("{}", compare_line("avg tasks/sec", 9.43e7, tps));
    println!("{}", compare_line("avg GOPS", 6181.56, gops));
    println!("{}", compare_line("avg GOPS/AIE", 15.45, gops / 400.0));
    println!("{}", compare_line("avg power (W)", 65.61, w));
    println!("{}", compare_line("avg GOPS/W", 94.22, gops / w));
}
