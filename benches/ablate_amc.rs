//! Ablation — AMC access mode on the MM TB fetch (CSB vs JUB vs UNOD).
//! The paper picks JUB (block reads from scattered row starts); CSB is
//! infeasible for blocked matrices without a layout change, UNOD wrecks
//! the pipeline. This shows the quantitative gap.
//!
//! Run: `cargo bench --bench ablate_amc`

use ea4rca::apps::mm;
use ea4rca::coordinator::scheduler::{ExecMode, GroupSpec, SimEngine};
use ea4rca::sim::ddr::AmcMode;
use ea4rca::sim::params::HwParams;
use ea4rca::util::table::{fmt_f, Table};

fn main() {
    let p = HwParams::vck5000();
    let engine = SimEngine::new(p.clone());
    let mut t = Table::new(
        "Ablation — AMC mode on the MM TB fetch (6 PUs, 512 iterations)",
        &["AMC mode", "DDR eff", "makespan (ms)", "GOPS", "vs JUB"],
    );
    let mut jub_ms = 0.0;
    let total_ops = 512.0 * 6.0 * 2.0 * 128.0f64.powi(3);
    let mut rows = Vec::new();
    for mode in [AmcMode::Csb, AmcMode::Jub, AmcMode::Unod] {
        let mut du = mm::mm_du(6, 6);
        du.amc_read = Some(mode);
        let g = GroupSpec {
            name: mode.name().into(),
            du,
            pu: mm::mm_pu(),
            engine_iters: 512,
            mode: ExecMode::Regular,
        };
        let r = engine.run(&[g]);
        if mode == AmcMode::Jub {
            jub_ms = r.makespan_secs;
        }
        rows.push((mode, r.makespan_secs));
    }
    for (mode, ms) in &rows {
        t.row(&[
            mode.name().to_string(),
            fmt_f(mode.efficiency(&p), 2),
            fmt_f(ms * 1e3, 3),
            fmt_f(total_ops / ms / 1e9, 1),
            format!("{:.2}x", ms / jub_ms),
        ]);
    }
    t.print();
    let unod = rows.iter().find(|(m, _)| *m == AmcMode::Unod).unwrap().1;
    let csb = rows.iter().find(|(m, _)| *m == AmcMode::Csb).unwrap().1;
    assert!(unod > jub_ms, "UNOD must be slower than JUB");
    assert!(csb <= jub_ms * 1.01, "CSB must be at least as fast as JUB");
    println!(
        "\nJUB keeps {:.0}% of CSB's throughput while allowing blocked access; \
         UNOD collapses the fetch pipeline ({:.1}x slower) — the paper's \
         Algorithm 1 mode choice, quantified.",
        csb / jub_ms * 100.0,
        unod / jub_ms
    );
}
