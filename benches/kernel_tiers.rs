//! §Perf — kernel dispatch tiers: scalar vs SIMD vs SIMD+pool.
//!
//! ISSUE #9's acceptance bench. For every hot interp kernel at its
//! paper shape, measure micro-batch throughput under three
//! configurations of the same backend:
//!
//! * **scalar**   — `TierConfig::scalar()`: portable reference kernels,
//!   no worker pool (the pre-tier baseline);
//! * **simd**     — the detected kernel tier, pool disabled: isolates
//!   the AVX2/FMA micro-kernel win;
//! * **simd+pool** — detected tier plus a worker pool as wide as the
//!   machine: the full batch path `serve` runs.
//!
//! On a CPU without AVX2+FMA the "simd" rows honestly degrade to the
//! scalar tier (the config resolves to scalar and the table says so) —
//! the comparison is still emitted, which is the point: the committed
//! `BENCH_kernel_tiers.json` always records what this machine can do,
//! never silently skips.
//!
//! Acceptance line: batched f32 matmul (mm_pu128) at least 4x scalar
//! under simd+pool. A one-core machine cannot pass the pool leg and a
//! non-AVX2 machine cannot pass the SIMD leg; the MISS is printed, not
//! hidden.
//!
//! Run: `cargo bench --bench kernel_tiers` (or `make tier-bench`)

use std::time::Instant;

use ea4rca::runtime::backend::interp::InterpBackend;
use ea4rca::runtime::backend::Backend;
use ea4rca::runtime::tensor::DType;
use ea4rca::runtime::{KernelTier, Manifest, Tensor, TierConfig};
use ea4rca::util::bench::BenchRecorder;
use ea4rca::util::rng::Rng;
use ea4rca::util::stats::summarize;
use ea4rca::util::table::{fmt_f, Table};

/// Dispatches per measurement (each dispatch is one `execute_batch`).
const ITERS: usize = 12;
/// Jobs per micro-batch — comfortably past MIN_PARALLEL_JOBS so the
/// pool leg actually engages.
const BATCH: usize = 16;

struct Leg {
    label: &'static str,
    cfg: TierConfig,
}

fn legs() -> Vec<Leg> {
    let detected = TierConfig::detect();
    vec![
        Leg { label: "scalar", cfg: TierConfig::scalar() },
        Leg { label: "simd", cfg: TierConfig { tier: detected.tier, pool_threads: 1 } },
        Leg { label: "simd+pool", cfg: detected },
    ]
}

fn gen_jobs(meta: &ea4rca::runtime::manifest::ArtifactMeta, rng: &mut Rng) -> Vec<Vec<Tensor>> {
    (0..BATCH)
        .map(|_| {
            meta.inputs
                .iter()
                .map(|tm| match tm.dtype {
                    DType::F32 => Tensor::f32(&tm.shape, rng.normal_vec(tm.elements())),
                    DType::I32 => {
                        Tensor::i32(&tm.shape, rng.int_vec_i32(tm.elements(), -128, 127))
                    }
                })
                .collect()
        })
        .collect()
}

/// Mean seconds per dispatch of `jobs` on `backend` (one warm-up
/// dispatch first, so prepare cost never rides a sample).
fn time_dispatch(
    backend: &InterpBackend,
    meta: &ea4rca::runtime::manifest::ArtifactMeta,
    jobs: &[Vec<Tensor>],
) -> f64 {
    backend.execute_batch(meta, jobs).expect("warmup dispatch");
    let samples: Vec<f64> = (0..ITERS)
        .map(|_| {
            let t0 = Instant::now();
            backend.execute_batch(meta, jobs).expect("dispatch");
            t0.elapsed().as_secs_f64()
        })
        .collect();
    summarize(&samples).mean
}

fn main() {
    let manifest = Manifest::builtin("artifacts");
    let mut rng = Rng::new(59);
    let mut rec = BenchRecorder::new("kernel_tiers");
    let detected = TierConfig::detect();
    rec.note("iters", ITERS)
        .note("batch_jobs", BATCH)
        .note("detected_tier", detected.tier)
        .note("pool_threads", detected.pool_threads)
        .note(
            "workload",
            "per-kernel micro-batch throughput: scalar vs simd vs simd+pool (interp)",
        );

    let mut t = Table::new(
        "kernel dispatch tiers: micro-batch throughput (interp)",
        &["artifact", "scalar j/s", "simd j/s", "simd+pool j/s", "simd x", "pool x"],
    );

    // the hot kernels at their paper shapes (Tables 6-8 workloads)
    let artifacts =
        ["mm32", "mm_pu128", "mmt_cascade8", "mm32_i8", "filter2d_pu8", "fft1024", "fft4096"];
    let mut mm_pu128_speedup = 0.0;
    for name in artifacts {
        let meta = manifest.get(name).expect("builtin artifact");
        let jobs = gen_jobs(meta, &mut rng);
        let mut jps = Vec::new();
        for leg in legs() {
            let backend = InterpBackend::with_tiers(leg.cfg);
            let secs = time_dispatch(&backend, meta, &jobs);
            let rate = BATCH as f64 / secs;
            jps.push(rate);
            rec.metric(&format!("{name}.{}.jobs_per_sec", leg.label), rate, "jobs/s");
        }
        let simd_x = jps[1] / jps[0];
        let pool_x = jps[2] / jps[0];
        if name == "mm_pu128" {
            mm_pu128_speedup = pool_x;
        }
        rec.metric(&format!("{name}.simd_speedup"), simd_x, "x")
            .metric(&format!("{name}.pool_speedup"), pool_x, "x");
        t.row(&[
            name.to_string(),
            fmt_f(jps[0], 1),
            fmt_f(jps[1], 1),
            fmt_f(jps[2], 1),
            format!("{simd_x:.2}x"),
            format!("{pool_x:.2}x"),
        ]);
    }
    t.print();

    println!(
        "detected tier: {} (pool={} threads); simd column runs the {} tier",
        detected.tier,
        detected.pool_threads,
        if KernelTier::simd_supported() { "AVX2/FMA" } else { "scalar-fallback" },
    );
    // the acceptance comparison is emitted on every machine: a one-core
    // or non-AVX2 box prints its MISS instead of skipping the line
    println!(
        "acceptance (mm_pu128 batched f32 matmul, simd+pool >= 4x scalar): {} ({:.2}x)",
        if mm_pu128_speedup >= 4.0 { "PASS" } else { "MISS" },
        mm_pu128_speedup
    );
    rec.metric("acceptance.mm_pu128_speedup", mm_pu128_speedup, "x")
        .metric(
            "acceptance.pass",
            if mm_pu128_speedup >= 4.0 { 1.0 } else { 0.0 },
            "bool",
        );
    rec.write();
}
