//! Ablation — execution discipline at system level (paper §3.2's closing
//! paragraph): what happens when a non-RCA deployment cannot use regular
//! communication phases. Runs the MM design under the three disciplines
//! (Regular = the EA4RCA pattern, Buffered = method-2 ping-pong overlap,
//! Interleaved = method-1 crossover) — the whole-accelerator analogue of
//! Table 2.
//!
//! Run: `cargo bench --bench ablate_nonrca`

use ea4rca::apps::mm;
use ea4rca::coordinator::scheduler::{ExecMode, GroupSpec, SimEngine};
use ea4rca::sim::params::HwParams;
use ea4rca::util::table::{fmt_f, Table};

fn main() {
    let p = HwParams::vck5000();
    let engine = SimEngine::new(p.clone());
    let iters = 512u64;
    let total_ops = iters as f64 * 6.0 * 2.0 * 128.0f64.powi(3);

    let mut t = Table::new(
        "Ablation — execution discipline on the MM design (6 PUs, 512 iterations)",
        &["discipline", "makespan (ms)", "GOPS", "vs Regular"],
    );
    let mut regular_ms = 0.0;
    let mut rows = Vec::new();
    for (mode, label) in [
        (ExecMode::Regular, "Regular (EA4RCA phases)"),
        (ExecMode::Buffered, "Buffered (method 2)"),
        (ExecMode::Interleaved, "Interleaved (method 1)"),
    ] {
        let g = GroupSpec::new("mm", mm::mm_du(6, 6), mm::mm_pu(), iters).with_mode(mode);
        let r = engine.run(&[g]);
        if mode == ExecMode::Regular {
            regular_ms = r.makespan_secs;
        }
        rows.push((label, r.makespan_secs));
    }
    for (label, ms) in &rows {
        t.row(&[
            label.to_string(),
            fmt_f(ms * 1e3, 3),
            fmt_f(total_ops / ms / 1e9, 1),
            format!("{:.2}x", ms / regular_ms),
        ]);
    }
    t.print();
    assert!(rows[2].1 > rows[1].1 && rows[1].1 >= regular_ms * 0.8);
    println!(
        "\nregular communication aggregation wins at system level exactly as \
         Table 2 showed per-core; interleaved crossover costs {:.1}x — the \
         degradation §3.2 predicts for non-RCA deployments.",
        rows[2].1 / regular_ms
    );
}
